//! TCP client demo: drive the coordinator's serving front end-to-end.
//!
//! Starts an in-process [`cgra_mte::coordinator::Server`] on an ephemeral
//! port (the same binary `cgra-mte serve-tcp` exposes), then acts as an
//! external tenant: submits a burst of requests over the socket and
//! prints the replies — scheduling, slice allocation, fast-DPR accounting
//! and PJRT execution all happen server-side per request.
//!
//! ```sh
//! make artifacts && cargo run --release --example tcp_client
//! ```

use std::io::{BufRead, BufReader, Write};

use cgra_mte::config::presets;
use cgra_mte::coordinator::Server;

fn main() -> cgra_mte::Result<()> {
    let mut cfg = presets::paper_default();
    cfg.artifacts_dir = std::env::var("CGRA_MTE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());

    println!("starting server (compiles all artifacts once)...");
    let server = Server::start(&cfg, "127.0.0.1:0")?;
    println!("server on {}\n", server.addr);

    let stream = std::net::TcpStream::connect(server.addr)
        .map_err(|e| cgra_mte::Error::io(server.addr.to_string(), e))?;
    let mut writer = stream.try_clone().map_err(|e| cgra_mte::Error::io("clone", e))?;
    let mut reader = BufReader::new(stream);

    let mut send = |line: &str| -> cgra_mte::Result<String> {
        writer
            .write_all(format!("{line}\n").as_bytes())
            .map_err(|e| cgra_mte::Error::io("write", e))?;
        let mut reply = String::new();
        reader
            .read_line(&mut reply)
            .map_err(|e| cgra_mte::Error::io("read", e))?;
        Ok(reply.trim_end().to_string())
    };

    // one request per tenant/app, plus a deliberate protocol error
    for line in [
        "SUBMIT 0 resnet18",
        "SUBMIT 1 mobilenet",
        "SUBMIT 2 camera",
        "SUBMIT 3 harris",
        "SUBMIT 7 camera", // bad tenant → ERR
        "STATS",
    ] {
        let reply = send(line)?;
        println!("> {line}\n< {reply}");
    }
    let bye = send("QUIT")?;
    println!("> QUIT\n< {bye}");

    server.shutdown();
    println!("\nserver drained and shut down cleanly");
    Ok(())
}
