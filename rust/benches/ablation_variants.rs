//! Ablation (DESIGN.md §6.2) — pre-compiled variant count per task.
//!
//! The paper pre-compiles only two variants per task (§3.1: "we only
//! pre-compile each task to two different variants… co-optimizing
//! compilation and scheduling policy may improve NTAT and throughput
//! further").  This sweep runs with 1 variant (a only), the paper's 2,
//! and the full set (3 for Harris), quantifying how much headroom the
//! variant library gives the greedy scheduler.

use cgra_mte::config::{presets, RegionPolicyKind, WorkloadConfig};
use cgra_mte::metrics::Table;
use cgra_mte::sim::run_cloud_with;
use cgra_mte::tasks::{AppId, TaskLibrary};

fn limited_library(max_variants: usize) -> TaskLibrary {
    let mut lib = TaskLibrary::table1();
    let tasks: Vec<_> = lib.iter().cloned().collect();
    for mut t in tasks {
        t.variants.truncate(max_variants);
        lib.insert(t);
    }
    lib
}

fn main() {
    let mut table = Table::new(
        "variant-count ablation (flexible regions, cloud scenario)",
        &["variants/task", "mean NTAT", "rel tput", "array util", "makespan ms"],
    );
    let mut first_tputs: Option<Vec<f64>> = None;
    for (label, max) in [("1 (a only)", 1usize), ("2 (paper)", 2), ("all (3 for Harris)", 3)] {
        let mut cfg = presets::cloud_scenario(RegionPolicyKind::FlexibleShape);
        if let WorkloadConfig::Cloud(ref mut c) = cfg.workload {
            c.duration_ms = 3000.0;
            c.mean_interarrival_ms = [30.0, 15.0, 12.0, 15.0];
        }
        let report = run_cloud_with(&cfg, limited_library(max)).expect("runs");
        let svc = report.throughput.service_throughput();
        let tputs: Vec<f64> = AppId::ALL
            .iter()
            .map(|a| svc.get(a).copied().unwrap_or(0.0))
            .collect();
        let rel = match &first_tputs {
            None => {
                first_tputs = Some(tputs.clone());
                1.0
            }
            Some(base) => {
                tputs.iter().zip(base).map(|(t, b)| t / b.max(1e-12)).sum::<f64>() / 4.0
            }
        };
        table.row(&[
            label.to_string(),
            format!("{:.2}", report.mean_ntat_across_apps()),
            format!("{rel:.2}x"),
            format!("{:.0}%", report.array_utilization * 100.0),
            format!("{:.0}", report.makespan_cycles as f64 / 500e3),
        ]);
    }
    print!("{}", table.render());
    println!(
        "shape: variants trade footprint for speed — with only the small\n\
         'a' mappings, waits shrink (lower NTAT) but per-request service\n\
         throughput drops; the paper's two variants buy throughput at\n\
         modest NTAT cost, matching its note that co-optimizing\n\
         compilation and scheduling is the remaining headroom."
    );
}
