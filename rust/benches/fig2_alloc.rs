//! Figure 2 — resource allocation under the four region mechanisms.
//!
//! Reproduces the paper's allocation cartoon with real allocator state:
//! a current task occupies the machine while a next task arrives, under
//! (a) baseline, (b) fixed-size with unrolling, (c) variably-sized
//! merging, and (d) flexible-shape decoupled allocation.  Occupancy maps
//! are rendered (`#` busy / `.` free) and the waste of each mechanism is
//! quantified.

use cgra_mte::abstraction::SliceDemand;
use cgra_mte::config::{ArchConfig, RegionPolicyKind, SchedulerConfig};
use cgra_mte::regions::{AllocOutcome, RegionManager};

fn main() {
    let arch = ArchConfig::default();
    // The running task: a ResNet conv3_x variant a (4 GLB, 2 array).
    let current = SliceDemand::new(4, 2);
    // The next task: camera pipeline needing throughput (Table 1: b = 14 GLB, 6 array;
    // a = 4 GLB, 4 array).
    let next_small = SliceDemand::new(4, 4);
    let next_big = SliceDemand::new(14, 6);

    for policy in RegionPolicyKind::ALL {
        let sched = SchedulerConfig {
            region_policy: policy,
            unit_glb_slices: 4,
            unit_array_slices: 1,
            ..SchedulerConfig::default()
        };
        let mut mgr = RegionManager::new(&arch, &sched);
        println!("--- Fig. 2{} — {} ---", ['a', 'b', 'c', 'd'][policy as usize % 4], policy.name());

        let cur = match mgr.try_allocate(&current) {
            AllocOutcome::Allocated(r) => {
                println!("current task ({current}): allocated {r}");
                Some(r)
            }
            other => {
                println!("current task ({current}): {other:?}");
                None
            }
        };

        let attempt = |mgr: &mut RegionManager, d: &SliceDemand| match policy {
            RegionPolicyKind::FixedSize => mgr.try_allocate_replicated(d, 3),
            _ => mgr.try_allocate(d),
        };
        for (label, d) in [("next (camera a)", &next_small), ("next (camera b)", &next_big)] {
            match attempt(&mut mgr, d) {
                AllocOutcome::Allocated(r) => {
                    let waste_glb = r.glb_slices().saturating_sub(d.glb_slices);
                    let waste_arr = r.array_slices().saturating_sub(d.array_slices);
                    println!(
                        "{label} ({d}): allocated {r}   overhead: +{waste_glb} GLB, +{waste_arr} array"
                    );
                    mgr.release(r.id).expect("just allocated");
                }
                other => println!("{label} ({d}): {other:?} — must wait"),
            }
        }
        println!("{}", mgr.render());
        let (fg, fa) = mgr.fragmentation();
        println!("fragmentation: glb {fg:.2}, array {fa:.2}\n");
        if let Some(r) = cur {
            let _ = mgr.release(r.id);
        }
    }
    println!(
        "shape to check against the paper: baseline forces waiting; fixed\n\
         serves only unit-sized tasks (unrolled copies); variable merges\n\
         but over-allocates the coupled resource; flexible allocates both\n\
         demands exactly and coexists with the current task."
    );
}
