//! Ablation (DESIGN.md §6.4) — bitstream relocation on/off.
//!
//! The paper's addition over Amber's DPR is *region-agnostic* bitstreams
//! plus a destination register: a cached bitstream maps to any free
//! region.  Without relocation (Amber-style), a cached image only
//! matches the region it was compiled for, so most placements pay the
//! host-DMA miss penalty.  Measured on the autonomous scenario, where
//! reconfiguration sits on the frame-latency path.

use cgra_mte::config::{presets, RegionPolicyKind, WorkloadConfig};
use cgra_mte::metrics::Table;
use cgra_mte::sim::run_edge;

fn main() {
    let mut table = Table::new(
        "relocation ablation (flexible regions + fast-DPR, autonomous scenario)",
        &["relocation", "mean latency ms", "reconfig share", "dpr hit-rate"],
    );
    for relocation in [true, false] {
        let mut cfg = presets::edge_scenario(RegionPolicyKind::FlexibleShape);
        cfg.dpr.relocation = relocation;
        if let WorkloadConfig::Edge(ref mut e) = cfg.workload {
            e.frames = 600;
        }
        let clk = cfg.arch.core_clock_mhz;
        let report = run_edge(&cfg).expect("runs");
        table.row(&[
            if relocation { "on (paper)" } else { "off (Amber-style)" }.to_string(),
            format!("{:.3}", report.mean_latency_ms(clk)),
            format!("{:.1}%", report.latency.reconfig_share() * 100.0),
            format!("{:.0}%", report.dpr_stats.hit_rate() * 100.0),
        ]);
    }
    print!("{}", table.render());
    println!(
        "shape: without relocation the preloaded cache only hits when a\n\
         task happens to land on its home region — hit-rate collapses and\n\
         the reconfiguration share of latency rises toward the AXI regime."
    );
}
