//! Simulator hot-path throughput bench with a perf-regression gate.
//!
//! Measures end-to-end simulator throughput — processed events per
//! wall-clock second — on the presets the hot-path overhaul targets:
//! the past-saturation churn preset, the mixed-criticality QoS preset,
//! and the 2-shard pool preset.  "Events" is the deterministic count of
//! arrivals + completions + launches a run processes, so the metric is
//! `fixed work / measured wall time`; the minimum wall time across
//! samples is used (least scheduler noise).
//!
//! Output: `BENCH_simperf.json` (shared `cgra_mte::bench::jsonw`
//! schema).  Regression gate: when a committed baseline exists at
//! `benches/simperf_baseline.json`, any scenario whose events/sec falls
//! below 90% of its baseline fails the bench (exit 1) — the CI leg runs
//! `--smoke`.  When no baseline exists the bench writes one and passes
//! (bootstrap); regenerate deliberately with
//! `UPDATE_SIMPERF_BASELINE=1` after a validated perf change and commit
//! the refreshed baseline alongside it.

use std::time::Instant;

use cgra_mte::bench::jsonw;
use cgra_mte::config::{
    presets, Config, DefragPolicyKind, PlacementPolicyKind, RegionPolicyKind, WorkloadConfig,
};
use cgra_mte::metrics::export;
use cgra_mte::sim::{run_cloud, run_cloud_pool};
use cgra_mte::util::json::Json;

const GATE_FRACTION: f64 = 0.9; // fail below 90% of baseline events/sec

struct Scenario {
    name: &'static str,
    cfg: Config,
    pool: bool,
}

fn scenarios(smoke: bool) -> Vec<Scenario> {
    let dur = |full: f64| if smoke { full / 4.0 } else { full };
    let mut churn =
        presets::churn_scenario(RegionPolicyKind::FlexibleShape, DefragPolicyKind::CostAware);
    set_duration(&mut churn, dur(4_000.0));
    let mut qos = presets::mixed_criticality_scenario(true);
    set_duration(&mut qos, dur(3_000.0));
    let mut pool = presets::pool_scenario(2, PlacementPolicyKind::LeastLoaded);
    set_duration(&mut pool, dur(2_000.0));
    vec![
        Scenario { name: "churn", cfg: churn, pool: false },
        Scenario { name: "mixed-criticality", cfg: qos, pool: false },
        Scenario { name: "pool-2", cfg: pool, pool: true },
    ]
}

fn set_duration(cfg: &mut Config, duration_ms: f64) {
    if let WorkloadConfig::Cloud(ref mut c) = cfg.workload {
        c.duration_ms = duration_ms;
    }
}

/// Deterministic per-run event count: arrivals + completions + launches.
fn events(s: &Scenario) -> u64 {
    if s.pool {
        let r = run_cloud_pool(&s.cfg).expect("pool run");
        r.submitted + r.completed + r.launches
    } else {
        let r = run_cloud(&s.cfg).expect("cloud run");
        r.submitted + r.completed + r.launches
    }
}

struct Row {
    name: &'static str,
    events: u64,
    best_wall_s: f64,
    events_per_sec: f64,
}

fn measure(s: &Scenario, samples: u32) -> Row {
    // the sim is a pure function of the config: the event count is
    // fixed work, checked for determinism before timing
    let n = events(s);
    assert_eq!(n, events(s), "{}: event count must be deterministic", s.name);
    assert!(n > 0, "{}: empty run measures nothing", s.name);
    let mut best = f64::INFINITY;
    for _ in 0..samples {
        let t0 = Instant::now();
        std::hint::black_box(events(s));
        best = best.min(t0.elapsed().as_secs_f64());
    }
    Row { name: s.name, events: n, best_wall_s: best, events_per_sec: n as f64 / best }
}

fn baseline_path() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("benches/simperf_baseline.json")
}

/// Baseline events/sec per scenario, if a baseline file is committed.
fn read_baseline() -> Option<Vec<(String, f64)>> {
    let text = std::fs::read_to_string(baseline_path()).ok()?;
    let doc = Json::parse(&text).ok()?;
    let mut out = Vec::new();
    for row in doc.get("rows")?.items() {
        let name = row.get("scenario")?.as_str()?.to_string();
        let eps = row.req_f64("events_per_sec").ok()?;
        out.push((name, eps));
    }
    Some(out)
}

fn rows_json(rows: &[Row]) -> String {
    jsonw::arr(
        &rows
            .iter()
            .map(|r| {
                jsonw::obj(&[
                    ("scenario", jsonw::str_val(r.name)),
                    ("events", jsonw::num_u(r.events)),
                    ("best_wall_s", jsonw::num_f(r.best_wall_s)),
                    ("events_per_sec", jsonw::num_f(r.events_per_sec)),
                ])
            })
            .collect::<Vec<_>>(),
    )
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let samples = if smoke { 3 } else { 8 };
    let t0 = Instant::now();

    let rows: Vec<Row> = scenarios(smoke).iter().map(|s| measure(s, samples)).collect();

    let mode = if smoke { "smoke" } else { "full" };
    println!("simperf — simulator hot-path throughput ({mode} mode)");
    for r in &rows {
        println!(
            "  {:<18} {:>12} events   {:>9.4} s best   {:>14.0} events/s",
            r.name, r.events, r.best_wall_s, r.events_per_sec
        );
    }

    // ---- regression gate against the committed baseline
    let update = std::env::var("UPDATE_SIMPERF_BASELINE").map_or(false, |v| v == "1");
    let baseline = if update { None } else { read_baseline() };
    let mut gate_status = "bootstrapped";
    let mut failures = Vec::new();
    let mut checked = Vec::new();
    if let Some(base) = &baseline {
        gate_status = "pass";
        for r in &rows {
            match base.iter().find(|(n, _)| n == r.name) {
                Some((_, base_eps)) => {
                    let ratio = r.events_per_sec / base_eps;
                    checked.push((r.name, *base_eps, ratio));
                    if ratio < GATE_FRACTION {
                        failures.push(format!(
                            "{}: {:.0} events/s is {:.1}% of baseline {:.0} (floor {:.0}%)",
                            r.name,
                            r.events_per_sec,
                            ratio * 100.0,
                            base_eps,
                            GATE_FRACTION * 100.0
                        ));
                    }
                }
                None => failures.push(format!(
                    "{}: scenario missing from baseline — regenerate with UPDATE_SIMPERF_BASELINE=1",
                    r.name
                )),
            }
        }
        for (name, base_eps, ratio) in &checked {
            println!(
                "  gate {:<13} baseline {:>12.0} events/s   current/baseline = {:.2}",
                name, base_eps, ratio
            );
        }
    } else {
        let doc = jsonw::obj(&[
            ("bench", jsonw::str_val("simperf-baseline")),
            ("smoke", jsonw::bool_val(smoke)),
            ("rows", rows_json(&rows)),
        ]);
        export::write_file(baseline_path(), &doc).expect("write baseline json");
        println!(
            "  {} baseline at {}",
            if update { "regenerated" } else { "bootstrapped" },
            baseline_path().display()
        );
    }

    let doc = jsonw::obj(&[
        ("bench", jsonw::str_val("simperf")),
        ("smoke", jsonw::bool_val(smoke)),
        ("samples", jsonw::num_u(samples as u64)),
        ("gate_fraction", jsonw::num_f(GATE_FRACTION)),
        ("gate_status", jsonw::str_val(if failures.is_empty() { gate_status } else { "fail" })),
        ("rows", rows_json(&rows)),
    ]);
    let path = "BENCH_simperf.json";
    export::write_file(path, &doc).expect("write bench json");
    println!("wrote {path}");
    println!("bench wall time: {:.1} s", t0.elapsed().as_secs_f64());

    if !failures.is_empty() {
        for f in &failures {
            eprintln!("perf regression FAILED: {f}");
        }
        std::process::exit(1);
    }
}
