//! QoS ablation — preemptive EDF vs FIFO on the mixed-criticality
//! preset, at identical offered load.
//!
//! The enforced claim: with the autonomous tenants (camera, Harris)
//! running **Critical** with frame-scale deadlines and the cloud
//! tenants (ResNet-18, MobileNet) running **BestEffort** at the churn
//! preset's past-saturation load, the QoS subsystem's preemptive EDF
//! schedule strictly beats the FIFO schedule on **Critical-class p99
//! latency** and **deadline-miss rate** — and the win is non-vacuous:
//! FIFO actually misses deadlines, preemptions actually happen, and
//! every checkpointed victim resumes (BestEffort still completes 100%
//! of its admitted requests; starvation is bounded by the aging knob).
//!
//! Output: a human table plus machine-readable `BENCH_qos.json`
//! (schema shared with the other ablations via `cgra_mte::bench::jsonw`).
//! `--smoke` shrinks the duration — the CI liveness mode; the sim is
//! deterministic, so the acceptance bars are enforced in smoke and full
//! alike.

use cgra_mte::bench::jsonw;
use cgra_mte::config::{presets, QosClass, WorkloadConfig};
use cgra_mte::metrics::{export, Table};
use cgra_mte::qos::ClassSlo;
use cgra_mte::sim::run_cloud;

struct Row {
    label: &'static str,
    critical: ClassSlo,
    best_effort: ClassSlo,
    preemptions: u64,
    victims_evicted: u64,
    victims_resumed: u64,
    makespan_ms: f64,
    ntat: f64,
    /// cycles → ms divisor for this run's clock
    cycles_per_ms: f64,
}

impl Row {
    fn crit_p99_ms(&self) -> f64 {
        self.critical.p99_latency / self.cycles_per_ms
    }
}

fn run(label: &'static str, preemptive: bool, duration_ms: f64) -> Row {
    let mut cfg = presets::mixed_criticality_scenario(preemptive);
    if let WorkloadConfig::Cloud(ref mut c) = cfg.workload {
        c.duration_ms = duration_ms;
    }
    let cycles_per_ms = cfg.arch.core_clock_mhz as f64 * 1e3;
    let r = run_cloud(&cfg).expect("mixed-criticality run");
    assert_eq!(r.submitted, r.completed, "offered load must drain fully");
    let qos = r.qos.expect("[qos] enabled by the preset");
    Row {
        label,
        critical: qos.class(QosClass::Critical).clone(),
        best_effort: qos.class(QosClass::BestEffort).clone(),
        preemptions: qos.preemptions,
        victims_evicted: qos.victims_evicted,
        victims_resumed: qos.victims_resumed,
        makespan_ms: r.makespan_cycles as f64 / cycles_per_ms,
        ntat: r.mean_ntat_across_apps(),
        cycles_per_ms,
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let duration_ms = if smoke { 600.0 } else { 2_000.0 };
    let t0 = std::time::Instant::now();

    let fifo = run("fifo", false, duration_ms);
    let edf = run("edf+preempt", true, duration_ms);

    let mut table = Table::new(
        "QoS — mixed-criticality preset, equal offered load",
        &[
            "schedule", "crit p50 ms", "crit p99 ms", "crit missed", "miss rate", "preempts",
            "resumed", "BE p99 ms", "makespan ms", "ntat",
        ],
    );
    for r in [&fifo, &edf] {
        table.row(&[
            r.label.to_string(),
            format!("{:.3}", r.critical.p50_latency / r.cycles_per_ms),
            format!("{:.3}", r.crit_p99_ms()),
            format!("{}/{}", r.critical.missed, r.critical.deadlined),
            format!("{:.3}", r.critical.miss_rate()),
            r.preemptions.to_string(),
            r.victims_resumed.to_string(),
            format!("{:.3}", r.best_effort.p99_latency / r.cycles_per_ms),
            format!("{:.1}", r.makespan_ms),
            format!("{:.2}", r.ntat),
        ]);
    }
    print!("{}", table.render());

    let p99_wins = edf.crit_p99_ms() < fifo.crit_p99_ms();
    let miss_wins = edf.critical.miss_rate() < fifo.critical.miss_rate();
    let fifo_misses = fifo.critical.missed > 0;
    let preempted = edf.preemptions > 0;
    let all_resumed = edf.victims_resumed == edf.victims_evicted;
    let be_completes = edf.best_effort.completed == fifo.best_effort.completed;
    println!(
        "critical p99 {:.3} ms (edf) vs {:.3} ms (fifo) — {}; miss rate {:.3} vs {:.3} — {}",
        edf.crit_p99_ms(),
        fifo.crit_p99_ms(),
        if p99_wins { "PASS" } else { "FAIL" },
        edf.critical.miss_rate(),
        fifo.critical.miss_rate(),
        if miss_wins { "PASS" } else { "FAIL" },
    );

    let row_json = |r: &Row| {
        let class_json = |c: &ClassSlo| {
            jsonw::obj(&[
                ("completed", jsonw::num_u(c.completed)),
                ("deadlined", jsonw::num_u(c.deadlined)),
                ("missed", jsonw::num_u(c.missed)),
                ("miss_rate", jsonw::num_f(c.miss_rate())),
                ("p50_ms", jsonw::num_f(c.p50_latency / r.cycles_per_ms)),
                ("p95_ms", jsonw::num_f(c.p95_latency / r.cycles_per_ms)),
                ("p99_ms", jsonw::num_f(c.p99_latency / r.cycles_per_ms)),
                ("mean_slack_ms", jsonw::num_f(c.mean_slack / r.cycles_per_ms)),
            ])
        };
        jsonw::obj(&[
            ("schedule", jsonw::str_val(r.label)),
            ("critical", class_json(&r.critical)),
            ("best_effort", class_json(&r.best_effort)),
            ("preemptions", jsonw::num_u(r.preemptions)),
            ("victims_evicted", jsonw::num_u(r.victims_evicted)),
            ("victims_resumed", jsonw::num_u(r.victims_resumed)),
            ("makespan_ms", jsonw::num_f(r.makespan_ms)),
            ("mean_ntat", jsonw::num_f(r.ntat)),
        ])
    };
    let doc = jsonw::obj(&[
        ("bench", jsonw::str_val("ablation_qos")),
        ("scenario", jsonw::str_val("mixed-criticality: edf+preempt vs fifo")),
        ("smoke", jsonw::bool_val(smoke)),
        ("duration_ms", jsonw::num_f(duration_ms)),
        ("rows", jsonw::arr(&[row_json(&fifo), row_json(&edf)])),
        (
            "delta",
            jsonw::obj(&[
                ("edf_p99_wins", jsonw::bool_val(p99_wins)),
                ("edf_miss_rate_wins", jsonw::bool_val(miss_wins)),
                ("fifo_misses_deadlines", jsonw::bool_val(fifo_misses)),
                ("preemptions_engaged", jsonw::bool_val(preempted)),
                ("all_victims_resumed", jsonw::bool_val(all_resumed)),
                (
                    "p99_ratio",
                    jsonw::num_f(if fifo.crit_p99_ms() > 0.0 {
                        edf.crit_p99_ms() / fifo.crit_p99_ms()
                    } else {
                        f64::NAN
                    }),
                ),
            ]),
        ),
    ]);
    let path = "BENCH_qos.json";
    export::write_file(path, &doc).expect("write bench json");
    println!("wrote {path}");
    println!("bench wall time: {:.1} s", t0.elapsed().as_secs_f64());

    // Acceptance is enforced, not just printed.
    let mut failed = false;
    if !p99_wins {
        eprintln!(
            "acceptance FAILED: edf critical p99 {:.3} ms not strictly below fifo {:.3} ms",
            edf.crit_p99_ms(),
            fifo.crit_p99_ms()
        );
        failed = true;
    }
    if !miss_wins {
        eprintln!(
            "acceptance FAILED: edf miss rate {:.3} not strictly below fifo {:.3}",
            edf.critical.miss_rate(),
            fifo.critical.miss_rate()
        );
        failed = true;
    }
    if !fifo_misses {
        eprintln!("acceptance FAILED: fifo never missed a deadline (vacuous comparison)");
        failed = true;
    }
    if !preempted {
        eprintln!("acceptance FAILED: the preemption engine never fired");
        failed = true;
    }
    if !all_resumed {
        eprintln!(
            "acceptance FAILED: {} victims evicted but only {} resumed",
            edf.victims_evicted, edf.victims_resumed
        );
        failed = true;
    }
    if !be_completes {
        eprintln!("acceptance FAILED: best-effort completion count diverged across schedules");
        failed = true;
    }
    if failed {
        std::process::exit(1);
    }
}
