//! Ablation (DESIGN.md §6.1) — array-slice width.
//!
//! The paper picks 4 columns per array-slice.  Wider slices (8/16 cols)
//! quantize demands coarser, wasting compute; this sweep quantifies the
//! cost on the cloud scenario under flexible-shape regions.
//!
//! Table 1 demands are published in units of 4-column slices, so they
//! are re-quantized (ceil) to each ablated width — a task needing 6
//! narrow slices needs 3 double-width ones.

use cgra_mte::config::{presets, RegionPolicyKind, WorkloadConfig};
use cgra_mte::metrics::Table;
use cgra_mte::sim::run_cloud_with;
use cgra_mte::tasks::TaskLibrary;

fn requantized_library(width: u32) -> TaskLibrary {
    let scale = width / 4;
    let mut lib = TaskLibrary::table1();
    let tasks: Vec<_> = lib.iter().cloned().collect();
    for mut t in tasks {
        for v in &mut t.variants {
            v.demand.array_slices = v.demand.array_slices.div_ceil(scale);
        }
        lib.insert(t);
    }
    lib
}

fn main() {
    let mut table = Table::new(
        "slice-width ablation (flexible regions, cloud scenario)",
        &["slice cols", "array slices", "mean NTAT", "array util", "glb util", "makespan ms"],
    );
    for width in [4u32, 8, 16] {
        let mut cfg = presets::slice_width_ablation(width);
        cfg.scheduler.region_policy = RegionPolicyKind::FlexibleShape;
        if let WorkloadConfig::Cloud(ref mut c) = cfg.workload {
            c.duration_ms = 3000.0;
            c.mean_interarrival_ms = [30.0, 15.0, 12.0, 15.0];
        }
        let report = run_cloud_with(&cfg, requantized_library(width)).expect("runs");
        table.row(&[
            width.to_string(),
            cfg.arch.array_slices().to_string(),
            format!("{:.2}", report.mean_ntat_across_apps()),
            format!("{:.0}%", report.array_utilization * 100.0),
            format!("{:.0}%", report.glb_utilization * 100.0),
            format!("{:.0}", report.makespan_cycles as f64 / 500e3),
        ]);
    }
    print!("{}", table.render());
    println!(
        "shape: 4- and 8-column slices perform comparably on this task set\n\
         (Table 1 demands are mostly even multiples), but 16-column slices\n\
         quantize the 8-wide array into just 2 allocation units and NTAT\n\
         collapses.  The paper's 4-column choice is the finest width that\n\
         keeps slices homogeneous (one MEM period) and one-bank-per-slice\n\
         DPR streaming feasible."
    );
}
