//! NoC ablation — comm-aware vs oblivious placement on the
//! streaming-pipeline preset, at identical offered load.
//!
//! The enforced claim: with two tenants running the three-stage
//! camera → demosaic → Harris chain (explicit inter-stage frame
//! streams) next to a camera and a Harris tenant at saturating rates,
//! **comm-aware placement** (corridor scoring + producer affinity)
//! strictly beats **oblivious placement** (first-fit, contention still
//! charged) on pipeline makespan — and the win is non-vacuous: the
//! oblivious schedule actually pays contention cycles, streams are
//! actually placed, and the comm-aware schedule actually lands
//! affinity hits.  A churn guard arm re-runs the past-saturation
//! defrag workload with the NoC armed and requires comm-aware not to
//! regress it.
//!
//! Output: a human table plus machine-readable `BENCH_noc.json`
//! (schema shared with the other ablations via `cgra_mte::bench::jsonw`;
//! per-run NoC counters use `cgra_mte::metrics::export::noc_json`'s
//! field names).  `--smoke` shrinks the duration — the CI liveness
//! mode; the sim is deterministic, so the acceptance bars are enforced
//! in smoke and full alike.

use cgra_mte::bench::jsonw;
use cgra_mte::config::{presets, Config, NocPlacementKind, WorkloadConfig};
use cgra_mte::metrics::{export, Table};
use cgra_mte::noc::NocReport;
use cgra_mte::sim::run_cloud;

struct Row {
    label: &'static str,
    noc: NocReport,
    submitted: u64,
    completed: u64,
    migrations: u64,
    makespan_ms: f64,
    ntat: f64,
}

fn run(label: &'static str, mut cfg: Config, duration_ms: f64) -> Row {
    if let WorkloadConfig::Cloud(ref mut c) = cfg.workload {
        c.duration_ms = duration_ms;
    }
    let cycles_per_ms = cfg.arch.core_clock_mhz as f64 * 1e3;
    let r = run_cloud(&cfg).expect("noc ablation run");
    Row {
        label,
        noc: r.noc.expect("[noc] enabled by the preset"),
        submitted: r.submitted,
        completed: r.completed,
        migrations: r.migrations,
        makespan_ms: r.makespan_cycles as f64 / cycles_per_ms,
        ntat: r.mean_ntat_across_apps(),
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let duration_ms = if smoke { 600.0 } else { 2_000.0 };
    let t0 = std::time::Instant::now();

    let aware = run(
        "pipeline comm-aware",
        presets::pipeline_scenario(NocPlacementKind::CommAware),
        duration_ms,
    );
    let obliv = run(
        "pipeline oblivious",
        presets::pipeline_scenario(NocPlacementKind::Oblivious),
        duration_ms,
    );
    let churn_aware = run(
        "churn comm-aware",
        presets::noc_churn_scenario(NocPlacementKind::CommAware),
        duration_ms,
    );
    let churn_obliv = run(
        "churn oblivious",
        presets::noc_churn_scenario(NocPlacementKind::Oblivious),
        duration_ms,
    );

    let mut table = Table::new(
        "NoC — comm-aware vs oblivious placement, equal offered load",
        &[
            "placement", "streams", "contended", "contention cyc", "affinity",
            "mean slow", "peak slow", "makespan ms", "ntat",
        ],
    );
    for r in [&aware, &obliv, &churn_aware, &churn_obliv] {
        table.row(&[
            r.label.to_string(),
            r.noc.streams_placed.to_string(),
            r.noc.contended_launches.to_string(),
            r.noc.contention_cycles.to_string(),
            r.noc.affinity_hits.to_string(),
            format!("{:.3}", r.noc.mean_slowdown),
            format!("{:.3}", r.noc.peak_slowdown),
            format!("{:.1}", r.makespan_ms),
            format!("{:.2}", r.ntat),
        ]);
    }
    print!("{}", table.render());

    let makespan_wins = aware.makespan_ms < obliv.makespan_ms;
    let streams_engaged = obliv.noc.streams_placed > 0 && aware.noc.streams_placed > 0;
    let contention_engaged = obliv.noc.contended_launches > 0;
    let affinity_engaged = aware.noc.affinity_hits > 0;
    let drains = aware.submitted == aware.completed && obliv.submitted == obliv.completed;
    let churn_ok = churn_aware.makespan_ms <= churn_obliv.makespan_ms * 1.05;
    println!(
        "pipeline makespan {:.1} ms (comm-aware) vs {:.1} ms (oblivious) — {}; churn {:.1} vs {:.1} — {}",
        aware.makespan_ms,
        obliv.makespan_ms,
        if makespan_wins { "PASS" } else { "FAIL" },
        churn_aware.makespan_ms,
        churn_obliv.makespan_ms,
        if churn_ok { "PASS" } else { "FAIL" },
    );

    let row_json = |r: &Row| {
        jsonw::obj(&[
            ("placement", jsonw::str_val(r.label)),
            ("noc", export::noc_json(&r.noc)),
            ("submitted", jsonw::num_u(r.submitted)),
            ("completed", jsonw::num_u(r.completed)),
            ("migrations", jsonw::num_u(r.migrations)),
            ("makespan_ms", jsonw::num_f(r.makespan_ms)),
            ("mean_ntat", jsonw::num_f(r.ntat)),
        ])
    };
    let doc = jsonw::obj(&[
        ("bench", jsonw::str_val("ablation_noc")),
        ("scenario", jsonw::str_val("streaming pipeline: comm-aware vs oblivious")),
        ("smoke", jsonw::bool_val(smoke)),
        ("duration_ms", jsonw::num_f(duration_ms)),
        (
            "rows",
            jsonw::arr(&[
                row_json(&aware),
                row_json(&obliv),
                row_json(&churn_aware),
                row_json(&churn_obliv),
            ]),
        ),
        (
            "delta",
            jsonw::obj(&[
                ("comm_aware_makespan_wins", jsonw::bool_val(makespan_wins)),
                ("contention_engaged", jsonw::bool_val(contention_engaged)),
                ("affinity_engaged", jsonw::bool_val(affinity_engaged)),
                ("churn_no_regression", jsonw::bool_val(churn_ok)),
                (
                    "makespan_ratio",
                    jsonw::num_f(if obliv.makespan_ms > 0.0 {
                        aware.makespan_ms / obliv.makespan_ms
                    } else {
                        f64::NAN
                    }),
                ),
            ]),
        ),
    ]);
    let path = "BENCH_noc.json";
    export::write_file(path, &doc).expect("write bench json");
    println!("wrote {path}");
    println!("bench wall time: {:.1} s", t0.elapsed().as_secs_f64());

    // Acceptance is enforced, not just printed.
    let mut failed = false;
    if !makespan_wins {
        eprintln!(
            "acceptance FAILED: comm-aware makespan {:.1} ms not strictly below oblivious {:.1} ms",
            aware.makespan_ms, obliv.makespan_ms
        );
        failed = true;
    }
    if !streams_engaged {
        eprintln!("acceptance FAILED: no streams placed (vacuous comparison)");
        failed = true;
    }
    if !contention_engaged {
        eprintln!("acceptance FAILED: the oblivious schedule never paid contention (vacuous)");
        failed = true;
    }
    if !affinity_engaged {
        eprintln!("acceptance FAILED: comm-aware placement never landed an affinity hit");
        failed = true;
    }
    if !drains {
        eprintln!(
            "acceptance FAILED: offered load did not drain ({}/{} aware, {}/{} oblivious)",
            aware.completed, aware.submitted, obliv.completed, obliv.submitted
        );
        failed = true;
    }
    if !churn_ok {
        eprintln!(
            "acceptance FAILED: comm-aware churn makespan {:.1} ms regressed past oblivious {:.1} ms +5%",
            churn_aware.makespan_ms, churn_obliv.makespan_ms
        );
        failed = true;
    }
    if failed {
        std::process::exit(1);
    }
}
