//! Figure 5 — autonomous-system average latency, normalized to the
//! baseline, split into reconfiguration (red) and wait+execution (blue).
//!
//! Paper's result: flexible regions + fast-DPR reduce total latency by
//! 60.8 %; reconfiguration falls from 14.4 % of baseline latency to <5 %.
//! The baseline uses AXI4-Lite DPR, all partitioned mechanisms use
//! fast-DPR (Fig. 5 caption).

use cgra_mte::config::{presets, RegionPolicyKind, WorkloadConfig};
use cgra_mte::metrics::Table;
use cgra_mte::sim::{run_edge, EdgeReport};

const FRAMES: u32 = 600;
const SEEDS: [u64; 3] = [5, 17, 29];

fn run(policy: RegionPolicyKind, seed: u64) -> EdgeReport {
    let mut cfg = presets::edge_scenario(policy);
    if let WorkloadConfig::Edge(ref mut e) = cfg.workload {
        e.frames = FRAMES;
        e.seed = seed;
    }
    run_edge(&cfg).expect("edge sim runs")
}

fn main() {
    let t0 = std::time::Instant::now();
    let clk = presets::paper_default().arch.core_clock_mhz;
    let mut table = Table::new(
        "Fig. 5 — autonomous system, normalized mean frame latency",
        &[
            "mechanism", "DPR", "total", "reconfig", "wait+exec", "reconfig share", "mean ms",
            "p50 ms", "p95 ms", "p99 ms",
        ],
    );

    let mut rows = Vec::new();
    for policy in RegionPolicyKind::ALL {
        let (mut total, mut reconf, mut wait) = (0.0, 0.0, 0.0);
        let (mut p50, mut p95, mut p99) = (0.0, 0.0, 0.0);
        let mut mode = None;
        for seed in SEEDS {
            let r = run(policy, seed);
            total += r.latency.mean_total() / SEEDS.len() as f64;
            reconf += r.latency.mean_reconfig() / SEEDS.len() as f64;
            wait += r.latency.mean_wait_exec() / SEEDS.len() as f64;
            p50 += r.p50_latency_ms(clk) / SEEDS.len() as f64;
            p95 += r.p95_latency_ms(clk) / SEEDS.len() as f64;
            p99 += r.p99_latency_ms(clk) / SEEDS.len() as f64;
            mode = Some(r.dpr_mode);
        }
        rows.push((policy, mode.unwrap(), total, reconf, wait, p50, p95, p99));
    }
    let base_total = rows[0].2;
    for (policy, mode, total, reconf, wait, p50, p95, p99) in &rows {
        table.row(&[
            policy.name().to_string(),
            format!("{mode:?}"),
            format!("{:.2}", total / base_total),
            format!("{:.3}", reconf / base_total),
            format!("{:.2}", wait / base_total),
            format!("{:.1}%", reconf / total * 100.0),
            format!("{:.3}", total / (clk as f64 * 1e3)),
            format!("{p50:.3}"),
            format!("{p95:.3}"),
            format!("{p99:.3}"),
        ]);
    }
    print!("{}", table.render());

    let flex = rows.iter().find(|(p, ..)| *p == RegionPolicyKind::FlexibleShape).unwrap();
    let base = &rows[0];
    println!(
        "flexible+fast-DPR vs baseline+AXI: {:.1}% latency reduction \
         (paper: 60.8%); reconfig share {:.1}% → {:.1}% (paper: 14.4% → <5%)",
        (1.0 - flex.2 / base.2) * 100.0,
        base.3 / base.2 * 100.0,
        flex.3 / flex.2 * 100.0,
    );
    println!("bench wall time: {:.1} s", t0.elapsed().as_secs_f64());
}
