//! Migration ablation — sustained utilization under a long-running
//! churn workload with defragmentation off / greedy / cost-aware.
//!
//! The claim to quantify: under past-saturation churn the slice maps
//! fragment, free-but-noncontiguous slices pile up, and `NoFit` stalls
//! grow; live migration (checkpoint → fast-DPR relocation → GLB copy →
//! resume) recovers that capacity, so the same offered load finishes in
//! a shorter makespan at higher sustained utilization with fewer `NoFit`
//! events.  Arrivals are seed-identical across the three policies —
//! only the defrag policy differs.
//!
//! Output: a human table plus machine-readable `BENCH_migration.json`
//! (schema shared with `fig4_cloud.rs` via `cgra_mte::bench::jsonw`) so
//! the perf trajectory is tracked across PRs.
//!
//! `--smoke` runs one short seed — the CI liveness mode.

use cgra_mte::bench::jsonw;
use cgra_mte::config::{presets, DefragPolicyKind, RegionPolicyKind, WorkloadConfig};
use cgra_mte::metrics::{export, Table};
use cgra_mte::sim::{run_cloud, CloudReport};

const FULL_SEEDS: [u64; 3] = [11, 23, 47];
const SMOKE_SEEDS: [u64; 1] = [11];
const FULL_DURATION_MS: f64 = 2_000.0;
const SMOKE_DURATION_MS: f64 = 400.0;

/// Seed-averaged metrics for one defrag policy.
#[derive(Clone, Copy, Debug, Default)]
struct Row {
    glb_util: f64,
    array_util: f64,
    frag_glb: f64,
    frag_arr: f64,
    nofit: f64,
    migrations: f64,
    migration_cycles: f64,
    rescued: f64,
    mean_ntat: f64,
    makespan: f64,
}

fn run(defrag: DefragPolicyKind, seed: u64, duration_ms: f64) -> CloudReport {
    let mut cfg = presets::churn_scenario(RegionPolicyKind::FlexibleShape, defrag);
    if let WorkloadConfig::Cloud(ref mut c) = cfg.workload {
        c.seed = seed;
        c.duration_ms = duration_ms;
    }
    run_cloud(&cfg).expect("churn sim runs")
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let seeds: &[u64] = if smoke { &SMOKE_SEEDS } else { &FULL_SEEDS };
    let duration_ms = if smoke { SMOKE_DURATION_MS } else { FULL_DURATION_MS };
    let t0 = std::time::Instant::now();

    let policies = DefragPolicyKind::ALL;
    let mut rows = vec![Row::default(); policies.len()];
    for (pi, policy) in policies.iter().enumerate() {
        for &seed in seeds {
            let r = run(*policy, seed, duration_ms);
            assert_eq!(r.submitted, r.completed, "churn must drain");
            let n = seeds.len() as f64;
            let row = &mut rows[pi];
            row.glb_util += r.glb_utilization / n;
            row.array_util += r.array_utilization / n;
            row.frag_glb += r.frag.0 / n;
            row.frag_arr += r.frag.1 / n;
            row.nofit += r.nofit_events as f64 / n;
            row.migrations += r.migrations as f64 / n;
            row.migration_cycles += r.migration_cycles as f64 / n;
            row.rescued += r.rescued_launches as f64 / n;
            row.mean_ntat += r.mean_ntat_across_apps() / n;
            row.makespan += r.makespan_cycles as f64 / n;
        }
    }

    let mut table = Table::new(
        "Migration ablation — flexible-shape churn (equal offered load)",
        &[
            "defrag", "arr util", "glb util", "arr frag", "NoFit", "migr", "rescued",
            "mean NTAT", "makespan Mcyc",
        ],
    );
    for (pi, policy) in policies.iter().enumerate() {
        let r = &rows[pi];
        table.row(&[
            policy.name().to_string(),
            format!("{:.3}", r.array_util),
            format!("{:.3}", r.glb_util),
            format!("{:.3}", r.frag_arr),
            format!("{:.0}", r.nofit),
            format!("{:.0}", r.migrations),
            format!("{:.0}", r.rescued),
            format!("{:.2}", r.mean_ntat),
            format!("{:.1}", r.makespan / 1e6),
        ]);
    }
    print!("{}", table.render());

    let off = &rows[0];
    let cost_aware = &rows[2];
    let util_gain = cost_aware.array_util - off.array_util;
    let nofit_cut = off.nofit - cost_aware.nofit;
    let beats = cost_aware.array_util > off.array_util && cost_aware.nofit < off.nofit;
    println!(
        "cost-aware vs off: array util {:.3} -> {:.3} ({:+.1}%), NoFit {:.0} -> {:.0} ({:+.0}), \
         makespan {:.1} -> {:.1} Mcyc — {}",
        off.array_util,
        cost_aware.array_util,
        util_gain / off.array_util.max(1e-9) * 100.0,
        off.nofit,
        cost_aware.nofit,
        -nofit_cut,
        off.makespan / 1e6,
        cost_aware.makespan / 1e6,
        if beats { "PASS (cost-aware strictly better)" } else { "FAIL" }
    );

    let row_json = |policy: DefragPolicyKind, r: &Row| {
        jsonw::obj(&[
            ("defrag", jsonw::str_val(policy.name())),
            ("array_util", jsonw::num_f(r.array_util)),
            ("glb_util", jsonw::num_f(r.glb_util)),
            ("frag_glb", jsonw::num_f(r.frag_glb)),
            ("frag_arr", jsonw::num_f(r.frag_arr)),
            ("nofit_events", jsonw::num_f(r.nofit)),
            ("migrations", jsonw::num_f(r.migrations)),
            ("migration_cycles", jsonw::num_f(r.migration_cycles)),
            ("rescued_launches", jsonw::num_f(r.rescued)),
            ("mean_ntat", jsonw::num_f(r.mean_ntat)),
            ("makespan_cycles", jsonw::num_f(r.makespan)),
        ])
    };
    let doc = jsonw::obj(&[
        ("bench", jsonw::str_val("ablation_migration")),
        ("scenario", jsonw::str_val("cloud-churn/flexible")),
        ("smoke", jsonw::bool_val(smoke)),
        ("duration_ms", jsonw::num_f(duration_ms)),
        (
            "seeds",
            jsonw::arr(&seeds.iter().map(|&s| jsonw::num_u(s)).collect::<Vec<_>>()),
        ),
        (
            "rows",
            jsonw::arr(
                &policies
                    .iter()
                    .enumerate()
                    .map(|(pi, p)| row_json(*p, &rows[pi]))
                    .collect::<Vec<_>>(),
            ),
        ),
        (
            "delta",
            jsonw::obj(&[
                ("array_util_gain", jsonw::num_f(util_gain)),
                (
                    "array_util_gain_pct",
                    jsonw::num_f(util_gain / off.array_util.max(1e-9) * 100.0),
                ),
                ("nofit_reduction", jsonw::num_f(nofit_cut)),
                (
                    "makespan_speedup",
                    jsonw::num_f(off.makespan / cost_aware.makespan.max(1.0)),
                ),
                ("cost_aware_beats_off", jsonw::bool_val(beats)),
            ]),
        ),
    ]);
    let path = "BENCH_migration.json";
    export::write_file(path, &doc).expect("write bench json");
    println!("wrote {path}");
    println!(
        "bench wall time: {:.1} s ({} seeds x {} policies)",
        t0.elapsed().as_secs_f64(),
        seeds.len(),
        policies.len()
    );
    // The acceptance criterion is enforced, not just printed: the full
    // (seed-averaged) run must show cost-aware strictly better than off.
    // Smoke mode stays advisory — one short seed is a liveness check,
    // not a statistically meaningful comparison.
    if !smoke && !beats {
        eprintln!("acceptance FAILED: cost-aware did not strictly beat defrag-off");
        std::process::exit(1);
    }
}
