//! Figure 4 — cloud-system evaluation: NTAT (4a) and throughput (4b)
//! per application under the four region mechanisms, normalized to the
//! baseline CGRA.
//!
//! Paper's result: flexible-shape partitioning decreases NTAT by 23–28 %
//! and increases throughput 1.05×–1.24× over baseline.  The shape to
//! reproduce: ordering baseline < fixed < variable < flexible, with
//! NTAT reductions in the tens of percent and throughput gains in the
//! 1.05–1.3× band.

use cgra_mte::bench::jsonw;
use cgra_mte::config::{presets, RegionPolicyKind, WorkloadConfig};
use cgra_mte::metrics::{export, normalize, Table};
use cgra_mte::sim::{run_cloud, CloudReport};
use cgra_mte::tasks::AppId;

/// Arrival intensities calibrated so the baseline is pressured but not
/// collapsed (see EXPERIMENTS.md §Fig4 for the calibration sweep).
const MEAN_INTERARRIVAL_MS: [f64; 4] = [45.0, 25.0, 30.0, 28.0];
const DURATION_MS: f64 = 4_000.0;
const SEEDS: [u64; 3] = [11, 23, 47];

fn run(policy: RegionPolicyKind, seed: u64) -> CloudReport {
    let mut cfg = presets::cloud_scenario(policy);
    if let WorkloadConfig::Cloud(ref mut c) = cfg.workload {
        c.mean_interarrival_ms = MEAN_INTERARRIVAL_MS;
        c.duration_ms = DURATION_MS;
        c.seed = seed;
    }
    run_cloud(&cfg).expect("cloud sim runs")
}

fn main() {
    let t0 = std::time::Instant::now();
    // seed-averaged per-app metrics per mechanism
    let mut ntat = vec![[0.0f64; 4]; 4]; // [policy][app]
    let mut tput = vec![[0.0f64; 4]; 4];
    for (pi, policy) in RegionPolicyKind::ALL.iter().enumerate() {
        for seed in SEEDS {
            let report = run(*policy, seed);
            let n = report.ntat.mean_ntat();
            let s = report.throughput.service_throughput();
            for (ai, app) in AppId::ALL.iter().enumerate() {
                ntat[pi][ai] += n.get(app).copied().unwrap_or(0.0) / SEEDS.len() as f64;
                tput[pi][ai] += s.get(app).copied().unwrap_or(0.0) / SEEDS.len() as f64;
            }
        }
    }

    let mut t4a = Table::new(
        "Fig. 4a — NTAT normalized to baseline (lower is better)",
        &["app", "baseline", "fixed", "variable", "flexible"],
    );
    let mut t4b = Table::new(
        "Fig. 4b — throughput normalized to baseline (higher is better)",
        &["app", "baseline", "fixed", "variable", "flexible"],
    );
    for (ai, app) in AppId::ALL.iter().enumerate() {
        let base_n = ntat[0][ai];
        let base_t = tput[0][ai];
        t4a.row(&[
            app.name().to_string(),
            "1.00".into(),
            format!("{:.2}", normalize(ntat[1][ai], base_n)),
            format!("{:.2}", normalize(ntat[2][ai], base_n)),
            format!("{:.2}", normalize(ntat[3][ai], base_n)),
        ]);
        t4b.row(&[
            app.name().to_string(),
            "1.00".into(),
            format!("{:.2}", normalize(tput[1][ai], base_t)),
            format!("{:.2}", normalize(tput[2][ai], base_t)),
            format!("{:.2}", normalize(tput[3][ai], base_t)),
        ]);
    }
    print!("{}", t4a.render());
    print!("{}", t4b.render());

    // headline summary over apps
    let mean = |row: &[f64; 4]| row.iter().sum::<f64>() / 4.0;
    let flex_ntat: f64 = (0..4)
        .map(|ai| normalize(ntat[3][ai], ntat[0][ai]))
        .sum::<f64>()
        / 4.0;
    let flex_tput_lo = (0..4)
        .map(|ai| normalize(tput[3][ai], tput[0][ai]))
        .fold(f64::INFINITY, f64::min);
    let flex_tput_hi = (0..4)
        .map(|ai| normalize(tput[3][ai], tput[0][ai]))
        .fold(0.0f64, f64::max);
    println!(
        "flexible vs baseline: NTAT {:.0}% lower (paper: 23–28% lower); \
         throughput {:.2}x–{:.2}x (paper: 1.05x–1.24x)",
        (1.0 - flex_ntat) * 100.0,
        flex_tput_lo,
        flex_tput_hi
    );
    println!(
        "mean NTAT by mechanism: baseline {:.2}, fixed {:.2}, variable {:.2}, flexible {:.2}",
        mean(&ntat[0]),
        mean(&ntat[1]),
        mean(&ntat[2]),
        mean(&ntat[3])
    );

    // machine-readable trajectory file (schema shared with
    // ablation_migration via bench::jsonw)
    let mech_json = |pi: usize, policy: RegionPolicyKind| {
        let apps: Vec<String> = AppId::ALL
            .iter()
            .enumerate()
            .map(|(ai, app)| {
                jsonw::obj(&[
                    ("app", jsonw::str_val(app.name())),
                    ("ntat_norm", jsonw::num_f(normalize(ntat[pi][ai], ntat[0][ai]))),
                    ("tput_norm", jsonw::num_f(normalize(tput[pi][ai], tput[0][ai]))),
                ])
            })
            .collect();
        jsonw::obj(&[
            ("mechanism", jsonw::str_val(policy.name())),
            ("mean_ntat", jsonw::num_f(mean(&ntat[pi]))),
            ("apps", jsonw::arr(&apps)),
        ])
    };
    let doc = jsonw::obj(&[
        ("bench", jsonw::str_val("fig4_cloud")),
        ("duration_ms", jsonw::num_f(DURATION_MS)),
        (
            "seeds",
            jsonw::arr(&SEEDS.iter().map(|&s| jsonw::num_u(s)).collect::<Vec<_>>()),
        ),
        (
            "rows",
            jsonw::arr(
                &RegionPolicyKind::ALL
                    .iter()
                    .enumerate()
                    .map(|(pi, p)| mech_json(pi, *p))
                    .collect::<Vec<_>>(),
            ),
        ),
        ("flexible_ntat_norm", jsonw::num_f(flex_ntat)),
        (
            "flexible_tput_range",
            jsonw::arr(&[jsonw::num_f(flex_tput_lo), jsonw::num_f(flex_tput_hi)]),
        ),
    ]);
    let path = "BENCH_fig4_cloud.json";
    export::write_file(path, &doc).expect("write bench json");
    println!("wrote {path}");
    println!("bench wall time: {:.1} s ({} seeds x 4 mechanisms)", t0.elapsed().as_secs_f64(), SEEDS.len());
}
