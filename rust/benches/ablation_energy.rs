//! Energy ablation — the power-cap governor and the energy-aware
//! policies, quantified on the cloud presets.
//!
//! Two claims are enforced (not just printed):
//!
//! 1. **The cap holds.**  On the past-saturation churn preset, the
//!    power-cap governor keeps the windowed average power at or below
//!    `[energy].power_cap_watts`, while the uncapped run demonstrably
//!    exceeds that level (the cap binds, it is not vacuous).  The
//!    governor must also have actually refused options (`throttled`).
//! 2. **Energy-aware placement + selection win on EDP.**  At equal
//!    offered load on a sharded pool, `placement = energy-aware` +
//!    `policy = energy-aware` achieve a strictly lower energy-delay
//!    product (joules × drain-makespan seconds) than the
//!    `least-loaded` + max-throughput pairing: consolidation lets
//!    drained shards deep-sleep while the spread placement keeps every
//!    fabric's static overhead burning.
//!
//! Output: a human table plus machine-readable `BENCH_energy.json`
//! (schema shared with the other ablations via `cgra_mte::bench::jsonw`).
//! `--smoke` shrinks durations and the pool to 2 shards — the CI
//! liveness mode; the sim is deterministic, so both acceptance bars are
//! enforced in smoke and full alike.

use cgra_mte::bench::jsonw;
use cgra_mte::config::{
    presets, Config, PlacementPolicyKind, SchedulerPolicyKind, WorkloadConfig,
};
use cgra_mte::energy::EnergyReport;
use cgra_mte::metrics::{export, Table};
use cgra_mte::sim::{run_cloud, run_cloud_pool};

/// Governor cap under test, watts.  Must sit above the drained-fabric
/// bypass worst case (~2.47 W: one harris-c plus the gated floor) so
/// the progress guarantee cannot overshoot it, and below the uncapped
/// churn plateau (~2.7+ W) so the cap actually binds.
const CAP_WATTS: f64 = 2.5;
/// Tolerance on the cap check: one-shot DPR/wake charges land inside
/// averaging windows as sub-milliwatt blips.
const CAP_TOL: f64 = 1.01;
/// Offered-load scale for the EDP comparison (half the Fig. 4
/// calibration point: one fabric can host the whole load, so placement
/// freedom — consolidate vs spread — is the differentiator).
const EDP_LOAD_SCALE: f64 = 0.5;

fn scale_load(cfg: &mut Config, scale: f64, duration_ms: f64) {
    if let WorkloadConfig::Cloud(ref mut c) = cfg.workload {
        c.duration_ms = duration_ms;
        for rate in c.mean_interarrival_ms.iter_mut() {
            *rate /= scale;
        }
    }
}

struct CapRow {
    label: &'static str,
    peak_w: f64,
    mean_w: f64,
    total_j: f64,
    throttled: u64,
    makespan_ms: f64,
    ntat: f64,
}

fn cap_run(cap: f64, duration_ms: f64) -> CapRow {
    let mut cfg = presets::energy_cap_scenario(cap);
    if let WorkloadConfig::Cloud(ref mut c) = cfg.workload {
        c.duration_ms = duration_ms;
    }
    let cycles_per_ms = cfg.arch.core_clock_mhz as f64 * 1e3;
    let r = run_cloud(&cfg).expect("churn run");
    assert_eq!(r.submitted, r.completed, "capped churn must still drain");
    let e = r.energy.expect("accounting on");
    CapRow {
        label: if cap > 0.0 { "capped" } else { "uncapped" },
        peak_w: e.peak_window_watts,
        mean_w: e.mean_watts,
        total_j: e.total_j,
        throttled: e.throttled,
        makespan_ms: r.makespan_cycles as f64 / cycles_per_ms,
        ntat: r.mean_ntat_across_apps(),
    }
}

struct EdpRow {
    label: &'static str,
    total_j: f64,
    makespan_s: f64,
    edp: f64,
    ntat: f64,
    mean_w: f64,
    energy: EnergyReport,
}

fn edp_run(
    label: &'static str,
    shards: u32,
    placement: PlacementPolicyKind,
    policy: SchedulerPolicyKind,
    duration_ms: f64,
) -> EdpRow {
    let mut cfg = presets::energy_pool_scenario(shards, placement);
    cfg.scheduler.policy = policy;
    scale_load(&mut cfg, EDP_LOAD_SCALE, duration_ms);
    let cycles_per_s = cfg.arch.core_clock_mhz as f64 * 1e6;
    let r = run_cloud_pool(&cfg).expect("pool run");
    assert_eq!(r.submitted, r.completed, "offered load must drain");
    let e = r.energy.expect("accounting on");
    let makespan_s = r.makespan_cycles as f64 / cycles_per_s;
    EdpRow {
        label,
        total_j: e.total_j,
        makespan_s,
        edp: e.total_j * makespan_s,
        ntat: r.mean_ntat_across_apps(),
        mean_w: e.mean_watts,
        energy: e,
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let churn_ms = if smoke { 600.0 } else { 2_000.0 };
    let pool_ms = if smoke { 400.0 } else { 1_500.0 };
    let shards = if smoke { 2 } else { 4 };
    let t0 = std::time::Instant::now();

    // ---- claim 1: the power cap holds -------------------------------
    let uncapped = cap_run(0.0, churn_ms);
    let capped = cap_run(CAP_WATTS, churn_ms);

    let mut cap_table = Table::new(
        "Power-cap governor — churn preset (windowed average power)",
        &["run", "peak W", "mean W", "total J", "throttled", "makespan ms", "ntat"],
    );
    for r in [&uncapped, &capped] {
        cap_table.row(&[
            r.label.to_string(),
            format!("{:.3}", r.peak_w),
            format!("{:.3}", r.mean_w),
            format!("{:.4}", r.total_j),
            r.throttled.to_string(),
            format!("{:.1}", r.makespan_ms),
            format!("{:.2}", r.ntat),
        ]);
    }
    print!("{}", cap_table.render());

    let cap_holds = capped.peak_w <= CAP_WATTS * CAP_TOL;
    let cap_binds = uncapped.peak_w > CAP_WATTS;
    let governor_engaged = capped.throttled > 0;
    println!(
        "cap {CAP_WATTS:.1} W: capped peak {:.3} W ({}), uncapped peak {:.3} W ({}), throttled {} ({})",
        capped.peak_w,
        if cap_holds { "HELD" } else { "VIOLATED" },
        uncapped.peak_w,
        if cap_binds { "cap binds" } else { "cap vacuous" },
        capped.throttled,
        if governor_engaged { "governor engaged" } else { "governor idle" },
    );

    // ---- claim 2: energy-aware beats least-loaded on EDP ------------
    let ll = edp_run(
        "least-loaded/greedy",
        shards,
        PlacementPolicyKind::LeastLoaded,
        SchedulerPolicyKind::GreedyThroughput,
        pool_ms,
    );
    let ea = edp_run(
        "energy-aware/energy-aware",
        shards,
        PlacementPolicyKind::EnergyAware,
        SchedulerPolicyKind::EnergyAware,
        pool_ms,
    );

    let mut edp_table = Table::new(
        "Energy-delay product — equal offered load, sharded pool",
        &["policies", "total J", "makespan s", "EDP J·s", "ntat", "mean W"],
    );
    for r in [&ll, &ea] {
        edp_table.row(&[
            r.label.to_string(),
            format!("{:.4}", r.total_j),
            format!("{:.4}", r.makespan_s),
            format!("{:.5}", r.edp),
            format!("{:.2}", r.ntat),
            format!("{:.3}", r.mean_w),
        ]);
    }
    print!("{}", edp_table.render());

    let edp_wins = ea.edp < ll.edp;
    let energy_wins = ea.total_j < ll.total_j;
    println!(
        "energy-aware EDP {:.5} vs least-loaded {:.5} — {} (energy {:.4} vs {:.4} J)",
        ea.edp,
        ll.edp,
        if edp_wins { "PASS (strictly lower)" } else { "FAIL" },
        ea.total_j,
        ll.total_j,
    );

    // ---- machine-readable trajectory --------------------------------
    let cap_json = |r: &CapRow| {
        jsonw::obj(&[
            ("run", jsonw::str_val(r.label)),
            ("peak_window_watts", jsonw::num_f(r.peak_w)),
            ("mean_watts", jsonw::num_f(r.mean_w)),
            ("total_j", jsonw::num_f(r.total_j)),
            ("throttled", jsonw::num_u(r.throttled)),
            ("makespan_ms", jsonw::num_f(r.makespan_ms)),
            ("mean_ntat", jsonw::num_f(r.ntat)),
        ])
    };
    let edp_json = |r: &EdpRow| {
        jsonw::obj(&[
            ("policies", jsonw::str_val(r.label)),
            ("total_j", jsonw::num_f(r.total_j)),
            ("makespan_s", jsonw::num_f(r.makespan_s)),
            ("edp_js", jsonw::num_f(r.edp)),
            ("mean_ntat", jsonw::num_f(r.ntat)),
            ("mean_watts", jsonw::num_f(r.mean_w)),
            ("static_j", jsonw::num_f(r.energy.static_j)),
            ("idle_j", jsonw::num_f(r.energy.idle_j)),
            ("gated_j", jsonw::num_f(r.energy.gated_j)),
            ("wakes", jsonw::num_u(r.energy.wakes)),
        ])
    };
    let doc = jsonw::obj(&[
        ("bench", jsonw::str_val("ablation_energy")),
        ("scenario", jsonw::str_val("cloud-churn cap + cloud-pool EDP")),
        ("smoke", jsonw::bool_val(smoke)),
        ("cap_watts", jsonw::num_f(CAP_WATTS)),
        ("edp_load_scale", jsonw::num_f(EDP_LOAD_SCALE)),
        ("edp_shards", jsonw::num_u(shards as u64)),
        (
            "cap_rows",
            jsonw::arr(&[cap_json(&uncapped), cap_json(&capped)]),
        ),
        ("edp_rows", jsonw::arr(&[edp_json(&ll), edp_json(&ea)])),
        (
            "delta",
            jsonw::obj(&[
                ("cap_holds", jsonw::bool_val(cap_holds)),
                ("cap_binds", jsonw::bool_val(cap_binds)),
                ("governor_engaged", jsonw::bool_val(governor_engaged)),
                ("energy_aware_edp_wins", jsonw::bool_val(edp_wins)),
                ("energy_aware_energy_wins", jsonw::bool_val(energy_wins)),
                (
                    "edp_ratio",
                    jsonw::num_f(if ll.edp > 0.0 { ea.edp / ll.edp } else { f64::NAN }),
                ),
            ]),
        ),
    ]);
    let path = "BENCH_energy.json";
    export::write_file(path, &doc).expect("write bench json");
    println!("wrote {path}");
    println!("bench wall time: {:.1} s", t0.elapsed().as_secs_f64());

    // Acceptance is enforced, not just printed: the sims are
    // deterministic, so a regression here is real, not noise.
    let mut failed = false;
    if !cap_holds {
        eprintln!(
            "acceptance FAILED: capped peak {:.3} W exceeds the {CAP_WATTS:.1} W cap",
            capped.peak_w
        );
        failed = true;
    }
    if !cap_binds {
        eprintln!(
            "acceptance FAILED: uncapped peak {:.3} W never exceeded the cap (vacuous test)",
            uncapped.peak_w
        );
        failed = true;
    }
    if !governor_engaged {
        eprintln!("acceptance FAILED: the governor never throttled an option");
        failed = true;
    }
    if !edp_wins {
        eprintln!(
            "acceptance FAILED: energy-aware EDP {:.5} not strictly below least-loaded {:.5}",
            ea.edp, ll.edp
        );
        failed = true;
    }
    if failed {
        std::process::exit(1);
    }
}
