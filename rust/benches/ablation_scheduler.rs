//! Ablation (DESIGN.md §6.3) — scheduler policy on top of the same
//! abstraction: greedy highest-throughput (paper) vs FCFS-first-fit vs
//! fair-share round-robin.  The point: the slice abstraction is
//! scheduler-agnostic; policies trade NTAT for fairness.

use cgra_mte::config::{presets, RegionPolicyKind, SchedulerPolicyKind, WorkloadConfig};
use cgra_mte::metrics::Table;
use cgra_mte::sim::run_cloud;
use cgra_mte::tasks::AppId;

fn main() {
    let mut table = Table::new(
        "scheduler-policy ablation (flexible regions, cloud scenario)",
        &["policy", "mean NTAT", "worst-app NTAT", "NTAT spread", "rel tput", "array util"],
    );
    let mut first_tputs: Option<Vec<f64>> = None;
    for policy in [
        SchedulerPolicyKind::GreedyThroughput,
        SchedulerPolicyKind::FcfsFirstFit,
        SchedulerPolicyKind::FairShare,
        SchedulerPolicyKind::ShortestJobFirst,
    ] {
        let mut cfg = presets::cloud_scenario(RegionPolicyKind::FlexibleShape);
        cfg.scheduler.policy = policy;
        if let WorkloadConfig::Cloud(ref mut c) = cfg.workload {
            c.duration_ms = 3000.0;
            c.mean_interarrival_ms = [30.0, 15.0, 12.0, 15.0];
        }
        let report = run_cloud(&cfg).expect("runs");
        let svc = report.throughput.service_throughput();
        let tputs: Vec<f64> = AppId::ALL
            .iter()
            .map(|a| svc.get(a).copied().unwrap_or(0.0))
            .collect();
        let rel = match &first_tputs {
            None => {
                first_tputs = Some(tputs.clone());
                1.0
            }
            Some(base) => {
                tputs.iter().zip(base).map(|(t, b)| t / b.max(1e-12)).sum::<f64>() / 4.0
            }
        };
        let per_app = report.ntat.mean_ntat();
        let worst = AppId::ALL
            .iter()
            .map(|a| per_app.get(a).copied().unwrap_or(0.0))
            .fold(0.0f64, f64::max);
        let best = AppId::ALL
            .iter()
            .map(|a| per_app.get(a).copied().unwrap_or(f64::INFINITY))
            .fold(f64::INFINITY, f64::min);
        table.row(&[
            policy.name().to_string(),
            format!("{:.2}", report.mean_ntat_across_apps()),
            format!("{:.2}", worst),
            format!("{:.2}", worst / best.max(1e-9)),
            format!("{rel:.2}x"),
            format!("{:.0}%", report.array_utilization * 100.0),
        ]);
    }
    print!("{}", table.render());
    println!(
        "shape: the abstraction is scheduler-agnostic — all three policies\n\
         run unmodified on the same slice currency.  greedy buys the best\n\
         per-request service throughput by taking big variants, at the\n\
         price of more blocking (higher NTAT) than footprint-frugal fcfs;\n\
         fair-share pays NTAT for rotation fairness."
    );
}
