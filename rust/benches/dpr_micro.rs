//! DPR microbenchmark — §2.3's fast-DPR claim in isolation.
//!
//! Reconfiguration cost per bitstream size under AXI4-Lite vs fast-DPR
//! (cache hit and miss), plus wall-clock cost of the engine model itself
//! (the L3 hot path — scheduling decisions call this on every launch).

use cgra_mte::abstraction::{SliceDemand, SliceRange};
use cgra_mte::bench::Bencher;
use cgra_mte::compiler::generate_bitstream;
use cgra_mte::config::{ArchConfig, DprConfig};
use cgra_mte::dpr::{Axi4LiteDpr, DprEngine, DprMode, FastDpr};
use cgra_mte::metrics::Table;

fn main() {
    let arch = ArchConfig::default();
    let cfg = DprConfig::default();
    let axi = Axi4LiteDpr::new(&arch, &cfg);
    let fast = FastDpr::new(&arch, &cfg);
    let us = |cycles: u64| cycles as f64 / arch.core_clock_mhz as f64;

    let mut table = Table::new(
        "reconfiguration cost vs task size (modeled, 500 MHz core / 100 MHz AXI)",
        &["array slices", "bitstream KiB", "AXI4-Lite µs", "fast-DPR hit µs", "fast-DPR miss µs", "speedup (hit)"],
    );
    for slices in [1u32, 2, 4, 6, 8] {
        let bs = generate_bitstream("bench.task", 'a', &SliceDemand::new(4, slices), &arch, &cfg);
        let axi_c = axi.reconfig_cycles(&bs);
        let hit_c = fast.stream_cycles(&bs);
        let miss_c = fast.host_load_cycles(&bs) + hit_c;
        table.row(&[
            slices.to_string(),
            format!("{}", bs.bytes() / 1024),
            format!("{:.1}", us(axi_c)),
            format!("{:.1}", us(hit_c)),
            format!("{:.1}", us(miss_c)),
            format!("{:.0}x", axi_c as f64 / hit_c as f64),
        ]);
    }
    print!("{}", table.render());
    println!(
        "shape: AXI cost scales with total bitstream size; fast-DPR is flat\n\
         (per-slice parallel streams) — the paper's whole-array reconfig\n\
         drops from ~ms to ~µs, which is what moves Fig. 5's red bars.\n"
    );

    // wall-clock cost of the model itself (L3 hot-path budget)
    let bench = Bencher::default();
    let bs = generate_bitstream("bench.task", 'a', &SliceDemand::new(7, 2), &arch, &cfg);
    let dest = SliceRange::new(2, 2);
    let mut engine = DprEngine::new(&arch, &cfg, DprMode::Fast);
    engine.preload(&bs);
    println!("{}", bench.run("DprEngine::reconfigure (hit)", || engine.reconfigure(&bs, &dest)).line());
    let mut axi_engine = DprEngine::new(&arch, &cfg, DprMode::Axi4Lite);
    println!("{}", bench.run("DprEngine::reconfigure (axi)", || axi_engine.reconfigure(&bs, &dest)).line());
    println!(
        "{}",
        bench
            .run("generate_bitstream", || {
                generate_bitstream("bench.task", 'a', &SliceDemand::new(7, 2), &arch, &cfg)
            })
            .line()
    );
}
