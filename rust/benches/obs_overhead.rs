//! Observability overhead bench with a hard gate.
//!
//! Runs the simperf presets three times per scenario — `[obs]`
//! disabled, `[obs]` enabled (lifecycle journal + metrics registry
//! live), and *full* obs (journal + registry + decision-provenance
//! ring + SLO burn-rate watchdog) — over identical fixed work and
//! compares events/sec.  The observability contract is two-tiered: the
//! baseline instrumentation costs at most 5% throughput and the full
//! stack at most 8%; the gate fails the bench (exit 1) when any
//! scenario's obs-on events/sec drops below those fractions of the
//! obs-off rate measured in the same process.  Off/on/full samples are
//! interleaved so machine drift hits all arms alike, and the minimum
//! wall time per arm is used (least scheduler noise).
//!
//! A smoke leg also cuts a flight record from the full-obs run and
//! round-trips it through the in-tree JSON parser + validator — the
//! postmortem artifact format is part of the gate.
//!
//! Output: `BENCH_obs.json` (shared `cgra_mte::bench::jsonw` schema).
//! The CI leg runs `--smoke` (quarter-length runs, fewer samples).

use std::time::Instant;

use cgra_mte::bench::jsonw;
use cgra_mte::config::{
    presets, Config, DefragPolicyKind, ObsConfig, PlacementPolicyKind, RegionPolicyKind,
    WorkloadConfig,
};
use cgra_mte::metrics::export;
use cgra_mte::obs::Obs;
use cgra_mte::sim::{run_cloud_observed, run_cloud_pool_observed, Trace};
use cgra_mte::tasks::TaskLibrary;

const MAX_OVERHEAD: f64 = 0.05; // journal + registry may cost at most 5% events/sec
const MAX_OVERHEAD_FULL: f64 = 0.08; // + provenance + watchdog: at most 8%
const JOURNAL_CAP: usize = 1 << 16;

struct Scenario {
    name: &'static str,
    cfg: Config,
    pool: bool,
}

fn scenarios(smoke: bool) -> Vec<Scenario> {
    let dur = |full: f64| if smoke { full / 4.0 } else { full };
    let mut churn =
        presets::churn_scenario(RegionPolicyKind::FlexibleShape, DefragPolicyKind::CostAware);
    set_duration(&mut churn, dur(2_000.0));
    let mut qos = presets::mixed_criticality_scenario(true);
    set_duration(&mut qos, dur(1_500.0));
    let mut pool = presets::pool_scenario(2, PlacementPolicyKind::LeastLoaded);
    set_duration(&mut pool, dur(1_000.0));
    vec![
        Scenario { name: "churn", cfg: churn, pool: false },
        Scenario { name: "mixed-criticality", cfg: qos, pool: false },
        Scenario { name: "pool-2", cfg: pool, pool: true },
    ]
}

fn set_duration(cfg: &mut Config, duration_ms: f64) {
    if let WorkloadConfig::Cloud(ref mut c) = cfg.workload {
        c.duration_ms = duration_ms;
    }
}

/// The `[obs]` knob set of the full arm: journal + registry +
/// provenance ring + burn-rate watchdog, all live.
fn full_obs_config() -> ObsConfig {
    ObsConfig {
        enabled: true,
        journal_cap: JOURNAL_CAP,
        provenance: true,
        watchdog: true,
        ..ObsConfig::default()
    }
}

/// One run through the observed entry point; returns the deterministic
/// event count (arrivals + completions + launches).  The trace stays
/// disabled in both arms — this bench isolates the obs cost.
fn run(s: &Scenario, obs: &mut Obs) -> u64 {
    let mut trace = Trace::disabled();
    if s.pool {
        let r = run_cloud_pool_observed(&s.cfg, TaskLibrary::table1(), &mut trace, obs)
            .expect("pool run");
        r.submitted + r.completed + r.launches
    } else {
        let r =
            run_cloud_observed(&s.cfg, TaskLibrary::table1(), &mut trace, obs).expect("cloud run");
        r.submitted + r.completed + r.launches
    }
}

struct Row {
    name: &'static str,
    events: u64,
    off_eps: f64,
    on_eps: f64,
    full_eps: f64,
    overhead: f64,
    overhead_full: f64,
}

fn measure(s: &Scenario, samples: u32) -> Row {
    // obs must be workload-transparent: same fixed work in every arm
    let n = run(s, &mut Obs::disabled());
    let n_on = run(s, &mut Obs::enabled(JOURNAL_CAP));
    assert_eq!(n, n_on, "{}: enabling obs changed the event count", s.name);
    let n_full = run(s, &mut Obs::from_obs_config(&full_obs_config()));
    assert_eq!(n, n_full, "{}: provenance/watchdog changed the event count", s.name);
    assert!(n > 0, "{}: empty run measures nothing", s.name);
    let mut best_off = f64::INFINITY;
    let mut best_on = f64::INFINITY;
    let mut best_full = f64::INFINITY;
    for _ in 0..samples {
        let t0 = Instant::now();
        std::hint::black_box(run(s, &mut Obs::disabled()));
        best_off = best_off.min(t0.elapsed().as_secs_f64());
        let mut obs = Obs::enabled(JOURNAL_CAP);
        let t1 = Instant::now();
        std::hint::black_box(run(s, &mut obs));
        best_on = best_on.min(t1.elapsed().as_secs_f64());
        let mut obs = Obs::from_obs_config(&full_obs_config());
        let t2 = Instant::now();
        std::hint::black_box(run(s, &mut obs));
        best_full = best_full.min(t2.elapsed().as_secs_f64());
    }
    let off_eps = n as f64 / best_off;
    let on_eps = n as f64 / best_on;
    let full_eps = n as f64 / best_full;
    Row {
        name: s.name,
        events: n,
        off_eps,
        on_eps,
        full_eps,
        overhead: 1.0 - on_eps / off_eps,
        overhead_full: 1.0 - full_eps / off_eps,
    }
}

/// Cut a flight record from a live full-obs run and round-trip it
/// through the in-tree JSON parser + validator.  Panics (failing the
/// bench) if the postmortem artifact format regressed.
fn flight_roundtrip() {
    let s = &scenarios(true)[0]; // churn, quarter length
    let ocfg = full_obs_config();
    let mut obs = Obs::from_obs_config(&ocfg);
    let events = run(s, &mut obs);
    let doc = cgra_mte::obs::flight_record(
        "bench:roundtrip",
        events,
        &obs.journal,
        obs.provenance.as_ref(),
        &obs.registry,
        &ocfg,
    );
    let rendered = format!("{doc}");
    let parsed =
        cgra_mte::util::json::Json::parse(&rendered).expect("flight record re-parses");
    let summary =
        cgra_mte::obs::validate_flight_record(&parsed).expect("flight record validates");
    assert_eq!(summary.reason, "bench:roundtrip");
    assert!(summary.journal_events > 0, "flight record carries no journal tail");
    println!(
        "flight-record round-trip ok: {} journal events, {} decisions, {} metric lines",
        summary.journal_events, summary.decisions, summary.metric_lines
    );
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let samples = if smoke { 3 } else { 8 };
    let t0 = Instant::now();

    let rows: Vec<Row> = scenarios(smoke).iter().map(|s| measure(s, samples)).collect();

    let mode = if smoke { "smoke" } else { "full" };
    println!("obs_overhead — observability cost on the simperf presets ({mode} mode)");
    let mut failures = Vec::new();
    for r in &rows {
        println!(
            "  {:<18} {:>12} events   {:>13.0} ev/s off   {:>13.0} ev/s on ({:>+5.2}%)   \
             {:>13.0} ev/s full ({:>+5.2}%)",
            r.name,
            r.events,
            r.off_eps,
            r.on_eps,
            r.overhead * 100.0,
            r.full_eps,
            r.overhead_full * 100.0
        );
        if r.overhead > MAX_OVERHEAD {
            failures.push(format!(
                "{}: obs costs {:.1}% events/sec (cap {:.0}%)",
                r.name,
                r.overhead * 100.0,
                MAX_OVERHEAD * 100.0
            ));
        }
        if r.overhead_full > MAX_OVERHEAD_FULL {
            failures.push(format!(
                "{}: full obs (provenance + watchdog) costs {:.1}% events/sec (cap {:.0}%)",
                r.name,
                r.overhead_full * 100.0,
                MAX_OVERHEAD_FULL * 100.0
            ));
        }
    }

    flight_roundtrip();

    let doc = jsonw::obj(&[
        ("bench", jsonw::str_val("obs_overhead")),
        ("smoke", jsonw::bool_val(smoke)),
        ("samples", jsonw::num_u(samples as u64)),
        ("max_overhead", jsonw::num_f(MAX_OVERHEAD)),
        ("max_overhead_full", jsonw::num_f(MAX_OVERHEAD_FULL)),
        ("gate_status", jsonw::str_val(if failures.is_empty() { "pass" } else { "fail" })),
        (
            "rows",
            jsonw::arr(
                &rows
                    .iter()
                    .map(|r| {
                        jsonw::obj(&[
                            ("scenario", jsonw::str_val(r.name)),
                            ("events", jsonw::num_u(r.events)),
                            ("events_per_sec_off", jsonw::num_f(r.off_eps)),
                            ("events_per_sec_on", jsonw::num_f(r.on_eps)),
                            ("events_per_sec_full", jsonw::num_f(r.full_eps)),
                            ("overhead", jsonw::num_f(r.overhead)),
                            ("overhead_full", jsonw::num_f(r.overhead_full)),
                        ])
                    })
                    .collect::<Vec<_>>(),
            ),
        ),
    ]);
    let path = "BENCH_obs.json";
    export::write_file(path, &doc).expect("write bench json");
    println!("wrote {path}");
    println!("bench wall time: {:.1} s", t0.elapsed().as_secs_f64());

    if !failures.is_empty() {
        for f in &failures {
            eprintln!("obs overhead gate FAILED: {f}");
        }
        std::process::exit(1);
    }
}
