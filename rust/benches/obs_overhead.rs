//! Observability overhead bench with a hard gate.
//!
//! Runs the simperf presets twice per scenario — `[obs]` disabled and
//! `[obs]` enabled (lifecycle journal + metrics registry live) — over
//! identical fixed work and compares events/sec.  The observability
//! contract is that the full instrumentation costs at most 5%
//! throughput: the gate fails the bench (exit 1) when any scenario's
//! obs-on events/sec drops below 95% of the obs-off rate measured in
//! the same process.  Off/on samples are interleaved so machine drift
//! hits both arms alike, and the minimum wall time per arm is used
//! (least scheduler noise).
//!
//! Output: `BENCH_obs.json` (shared `cgra_mte::bench::jsonw` schema).
//! The CI leg runs `--smoke` (quarter-length runs, fewer samples).

use std::time::Instant;

use cgra_mte::bench::jsonw;
use cgra_mte::config::{
    presets, Config, DefragPolicyKind, PlacementPolicyKind, RegionPolicyKind, WorkloadConfig,
};
use cgra_mte::metrics::export;
use cgra_mte::obs::Obs;
use cgra_mte::sim::{run_cloud_observed, run_cloud_pool_observed, Trace};
use cgra_mte::tasks::TaskLibrary;

const MAX_OVERHEAD: f64 = 0.05; // full obs may cost at most 5% events/sec
const JOURNAL_CAP: usize = 1 << 16;

struct Scenario {
    name: &'static str,
    cfg: Config,
    pool: bool,
}

fn scenarios(smoke: bool) -> Vec<Scenario> {
    let dur = |full: f64| if smoke { full / 4.0 } else { full };
    let mut churn =
        presets::churn_scenario(RegionPolicyKind::FlexibleShape, DefragPolicyKind::CostAware);
    set_duration(&mut churn, dur(2_000.0));
    let mut qos = presets::mixed_criticality_scenario(true);
    set_duration(&mut qos, dur(1_500.0));
    let mut pool = presets::pool_scenario(2, PlacementPolicyKind::LeastLoaded);
    set_duration(&mut pool, dur(1_000.0));
    vec![
        Scenario { name: "churn", cfg: churn, pool: false },
        Scenario { name: "mixed-criticality", cfg: qos, pool: false },
        Scenario { name: "pool-2", cfg: pool, pool: true },
    ]
}

fn set_duration(cfg: &mut Config, duration_ms: f64) {
    if let WorkloadConfig::Cloud(ref mut c) = cfg.workload {
        c.duration_ms = duration_ms;
    }
}

/// One run through the observed entry point; returns the deterministic
/// event count (arrivals + completions + launches).  The trace stays
/// disabled in both arms — this bench isolates the obs cost.
fn run(s: &Scenario, obs: &mut Obs) -> u64 {
    let mut trace = Trace::disabled();
    if s.pool {
        let r = run_cloud_pool_observed(&s.cfg, TaskLibrary::table1(), &mut trace, obs)
            .expect("pool run");
        r.submitted + r.completed + r.launches
    } else {
        let r =
            run_cloud_observed(&s.cfg, TaskLibrary::table1(), &mut trace, obs).expect("cloud run");
        r.submitted + r.completed + r.launches
    }
}

struct Row {
    name: &'static str,
    events: u64,
    off_eps: f64,
    on_eps: f64,
    overhead: f64,
}

fn measure(s: &Scenario, samples: u32) -> Row {
    // obs must be workload-transparent: same fixed work in both arms
    let n = run(s, &mut Obs::disabled());
    let n_on = run(s, &mut Obs::enabled(JOURNAL_CAP));
    assert_eq!(n, n_on, "{}: enabling obs changed the event count", s.name);
    assert!(n > 0, "{}: empty run measures nothing", s.name);
    let mut best_off = f64::INFINITY;
    let mut best_on = f64::INFINITY;
    for _ in 0..samples {
        let t0 = Instant::now();
        std::hint::black_box(run(s, &mut Obs::disabled()));
        best_off = best_off.min(t0.elapsed().as_secs_f64());
        let mut obs = Obs::enabled(JOURNAL_CAP);
        let t1 = Instant::now();
        std::hint::black_box(run(s, &mut obs));
        best_on = best_on.min(t1.elapsed().as_secs_f64());
    }
    let off_eps = n as f64 / best_off;
    let on_eps = n as f64 / best_on;
    Row { name: s.name, events: n, off_eps, on_eps, overhead: 1.0 - on_eps / off_eps }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let samples = if smoke { 3 } else { 8 };
    let t0 = Instant::now();

    let rows: Vec<Row> = scenarios(smoke).iter().map(|s| measure(s, samples)).collect();

    let mode = if smoke { "smoke" } else { "full" };
    println!("obs_overhead — observability cost on the simperf presets ({mode} mode)");
    let mut failures = Vec::new();
    for r in &rows {
        println!(
            "  {:<18} {:>12} events   {:>14.0} ev/s off   {:>14.0} ev/s on   {:>+6.2}% overhead",
            r.name, r.events, r.off_eps, r.on_eps, r.overhead * 100.0
        );
        if r.overhead > MAX_OVERHEAD {
            failures.push(format!(
                "{}: obs costs {:.1}% events/sec (cap {:.0}%)",
                r.name,
                r.overhead * 100.0,
                MAX_OVERHEAD * 100.0
            ));
        }
    }

    let doc = jsonw::obj(&[
        ("bench", jsonw::str_val("obs_overhead")),
        ("smoke", jsonw::bool_val(smoke)),
        ("samples", jsonw::num_u(samples as u64)),
        ("max_overhead", jsonw::num_f(MAX_OVERHEAD)),
        ("gate_status", jsonw::str_val(if failures.is_empty() { "pass" } else { "fail" })),
        (
            "rows",
            jsonw::arr(
                &rows
                    .iter()
                    .map(|r| {
                        jsonw::obj(&[
                            ("scenario", jsonw::str_val(r.name)),
                            ("events", jsonw::num_u(r.events)),
                            ("events_per_sec_off", jsonw::num_f(r.off_eps)),
                            ("events_per_sec_on", jsonw::num_f(r.on_eps)),
                            ("overhead", jsonw::num_f(r.overhead)),
                        ])
                    })
                    .collect::<Vec<_>>(),
            ),
        ),
    ]);
    let path = "BENCH_obs.json";
    export::write_file(path, &doc).expect("write bench json");
    println!("wrote {path}");
    println!("bench wall time: {:.1} s", t0.elapsed().as_secs_f64());

    if !failures.is_empty() {
        for f in &failures {
            eprintln!("obs overhead gate FAILED: {f}");
        }
        std::process::exit(1);
    }
}
