//! Shard-count ablation — offered load a fabric pool sustains before
//! its first `BUSY` rejection.
//!
//! The claim to quantify: the pool abstraction scales the serving path
//! horizontally.  Each shard is a full Amber-like fabric behind one
//! placement router with a bounded per-shard admission window; sweeping
//! the cloud scenario's arrival rates upward, a pool with more shards
//! must keep admitting (zero `BUSY`) at offered loads that already
//! saturate a smaller pool.  Arrivals are seed-identical across shard
//! counts at every scale — only the pool layout differs.
//!
//! Output: a human table plus machine-readable `BENCH_shards.json`
//! (schema shared with `ablation_migration.rs` via
//! `cgra_mte::bench::jsonw`) so the scaling trajectory is tracked
//! across PRs.
//!
//! `--smoke` runs shard counts {1, 2} over a short window — the CI
//! liveness mode.  The acceptance bar (2 shards sustain strictly more
//! than 1 before the first rejection) is enforced in both modes: the
//! sim is deterministic, so the comparison is stable even in smoke.

use cgra_mte::bench::jsonw;
use cgra_mte::config::{presets, PlacementPolicyKind, WorkloadConfig};
use cgra_mte::metrics::{export, Table};
use cgra_mte::sim::{run_cloud_pool, PoolCloudReport};

/// Per-shard open-request cap: small enough that saturation shows up
/// inside a short bench window.
const WINDOW: u32 = 8;
/// Arrival-rate multipliers over the Fig. 4 cloud calibration point.
const SCALES: [f64; 8] = [0.25, 0.5, 0.75, 1.0, 1.5, 2.0, 3.0, 4.0];
const SEED: u64 = 29;
const FULL_SHARDS: [u32; 3] = [1, 2, 4];
const SMOKE_SHARDS: [u32; 2] = [1, 2];
const FULL_DURATION_MS: f64 = 1_500.0;
const SMOKE_DURATION_MS: f64 = 300.0;

fn run(shards: u32, scale: f64, duration_ms: f64) -> PoolCloudReport {
    let mut cfg = presets::pool_scenario(shards, PlacementPolicyKind::LeastLoaded);
    cfg.pool.admission_window = WINDOW;
    if let WorkloadConfig::Cloud(ref mut c) = cfg.workload {
        c.duration_ms = duration_ms;
        c.seed = SEED;
        for rate in c.mean_interarrival_ms.iter_mut() {
            *rate /= scale;
        }
    }
    run_cloud_pool(&cfg).expect("pool sim runs")
}

/// One shard count's sweep outcome.
struct SweepRow {
    shards: u32,
    /// Highest scale with zero rejections before the first rejecting
    /// scale (ascending prefix).
    sustained: f64,
    /// First scale that rejected, if any.
    first_busy: Option<f64>,
    /// Rejections at the top of the sweep.
    rejections_at_max: u64,
    /// Per-scale (scale, busy_rejections, mean_ntat) detail.
    detail: Vec<(f64, u64, f64)>,
}

fn sweep(shards: u32, duration_ms: f64) -> SweepRow {
    let mut sustained = 0.0;
    let mut first_busy = None;
    let mut rejections_at_max = 0;
    let mut detail = Vec::new();
    for &scale in &SCALES {
        let r = run(shards, scale, duration_ms);
        assert_eq!(r.submitted, r.completed, "admitted requests must drain");
        detail.push((scale, r.busy_rejections, r.mean_ntat_across_apps()));
        rejections_at_max = r.busy_rejections;
        if r.busy_rejections == 0 && first_busy.is_none() {
            sustained = scale;
        } else if first_busy.is_none() {
            first_busy = Some(scale);
        }
    }
    SweepRow { shards, sustained, first_busy, rejections_at_max, detail }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let shard_counts: &[u32] = if smoke { &SMOKE_SHARDS } else { &FULL_SHARDS };
    let duration_ms = if smoke { SMOKE_DURATION_MS } else { FULL_DURATION_MS };
    let t0 = std::time::Instant::now();

    let rows: Vec<SweepRow> =
        shard_counts.iter().map(|&s| sweep(s, duration_ms)).collect();

    let mut table = Table::new(
        "Shard ablation — offered load sustained before first BUSY (cloud pool)",
        &["shards", "sustained scale", "first BUSY at", "rejections@4x"],
    );
    for r in &rows {
        table.row(&[
            r.shards.to_string(),
            format!("{:.2}x", r.sustained),
            r.first_busy.map_or("never".to_string(), |s| format!("{s:.2}x")),
            r.rejections_at_max.to_string(),
        ]);
    }
    print!("{}", table.render());

    let one = &rows[0];
    let two = &rows[1];
    let beats = two.sustained > one.sustained;
    println!(
        "2 shards vs 1: sustained scale {:.2}x -> {:.2}x — {}",
        one.sustained,
        two.sustained,
        if beats { "PASS (strictly higher offered load)" } else { "FAIL" }
    );

    let row_json = |r: &SweepRow| {
        jsonw::obj(&[
            ("shards", jsonw::num_u(r.shards as u64)),
            ("sustained_scale", jsonw::num_f(r.sustained)),
            (
                "first_busy_scale",
                r.first_busy.map_or("null".to_string(), jsonw::num_f),
            ),
            ("rejections_at_max", jsonw::num_u(r.rejections_at_max)),
            (
                "detail",
                jsonw::arr(
                    &r.detail
                        .iter()
                        .map(|(scale, busy, ntat)| {
                            jsonw::obj(&[
                                ("scale", jsonw::num_f(*scale)),
                                ("busy_rejections", jsonw::num_u(*busy)),
                                ("mean_ntat", jsonw::num_f(*ntat)),
                            ])
                        })
                        .collect::<Vec<_>>(),
                ),
            ),
        ])
    };
    let doc = jsonw::obj(&[
        ("bench", jsonw::str_val("ablation_shards")),
        ("scenario", jsonw::str_val("cloud-pool/flexible")),
        ("smoke", jsonw::bool_val(smoke)),
        ("duration_ms", jsonw::num_f(duration_ms)),
        ("seed", jsonw::num_u(SEED)),
        ("admission_window", jsonw::num_u(WINDOW as u64)),
        (
            "scales",
            jsonw::arr(&SCALES.iter().map(|&s| jsonw::num_f(s)).collect::<Vec<_>>()),
        ),
        ("rows", jsonw::arr(&rows.iter().map(row_json).collect::<Vec<_>>())),
        (
            "delta",
            jsonw::obj(&[
                ("sustained_1_shard", jsonw::num_f(one.sustained)),
                ("sustained_2_shards", jsonw::num_f(two.sustained)),
                ("two_beats_one", jsonw::bool_val(beats)),
            ]),
        ),
    ]);
    let path = "BENCH_shards.json";
    export::write_file(path, &doc).expect("write bench json");
    println!("wrote {path}");
    println!(
        "bench wall time: {:.1} s ({} shard counts x {} scales)",
        t0.elapsed().as_secs_f64(),
        shard_counts.len(),
        SCALES.len()
    );
    // Acceptance is enforced, not just printed, in smoke and full alike:
    // the simulation is deterministic, so 2 shards failing to out-sustain
    // 1 is a regression, not noise.
    if !beats {
        eprintln!("acceptance FAILED: 2 shards did not sustain a strictly higher offered load");
        std::process::exit(1);
    }
}
