//! Open-loop serving-front saturation bench: a standing army of idle
//! connections plus closed-loop load generators, run against three
//! arms — the thread-per-connection front (text), the reactor front
//! (text), and the reactor front (binary framing) — each on a fresh
//! server.
//!
//! The idle army is where the fronts diverge: a thread-per-connection
//! server pays one blocked thread and a 100 ms-timeout read tick per
//! idle socket forever (10 000 idle conns ≈ 100 000 wakeups/s of pure
//! overhead), while the reactor pays nothing until a socket turns
//! readable.  The army is sized to 10 000 in full mode, clamped to what
//! the process fd limit allows (each loopback connection costs two fds
//! in-process — client end + accepted end).
//!
//! Gate (full mode only): the reactor-text arm must beat the threaded
//! arm on accepted QPS outright, with p99 latency no worse than 1.25×
//! the threaded front's (headroom for wall-clock noise; QPS is the
//! primary signal).  `--smoke` runs a tiny army as a CI liveness check
//! and does not enforce the gate — wall-clock comparisons on loaded
//! shared runners are noise, not signal.
//!
//! Output: `BENCH_serve.json` (shared `cgra_mte::bench::jsonw` schema).

use std::net::TcpStream;
use std::time::{Duration, Instant};

use cgra_mte::bench::jsonw;
use cgra_mte::config::{presets, Config, ServerModeKind};
use cgra_mte::coordinator::frame::Opcode;
use cgra_mte::coordinator::Server;
use cgra_mte::metrics::export;
use cgra_mte::testutil::wire::{BinWireClient, WireClient};

const APPS: [&str; 4] = ["resnet18", "mobilenet", "camera", "harris"];

/// p99 headroom over the threaded arm: QPS is the primary gate signal,
/// latency only has to stay in the same league.
const P99_HEADROOM: f64 = 1.25;

struct ArmSpec {
    name: &'static str,
    mode: ServerModeKind,
    binary: bool,
}

const ARMS: [ArmSpec; 3] = [
    ArmSpec { name: "threaded-text", mode: ServerModeKind::Threaded, binary: false },
    ArmSpec { name: "reactor-text", mode: ServerModeKind::Reactor, binary: false },
    ArmSpec { name: "reactor-binary", mode: ServerModeKind::Reactor, binary: true },
];

struct ArmResult {
    name: &'static str,
    protocol: &'static str,
    idle_conns: usize,
    load_conns: u32,
    ok: u64,
    busy: u64,
    err: u64,
    accepted_qps: f64,
    p50_ms: f64,
    p99_ms: f64,
}

/// Soft fd limit of this process (`/proc/self/limits` on Linux; a
/// conservative constant elsewhere).
#[cfg(target_os = "linux")]
fn fd_soft_limit() -> u64 {
    std::fs::read_to_string("/proc/self/limits")
        .ok()
        .and_then(|text| {
            text.lines()
                .find(|l| l.starts_with("Max open files"))
                .and_then(|l| l.split_whitespace().nth(3))
                .and_then(|v| v.parse().ok())
        })
        .unwrap_or(1024)
}

#[cfg(not(target_os = "linux"))]
fn fd_soft_limit() -> u64 {
    1024
}

fn serve_config(mode: ServerModeKind) -> Config {
    let mut cfg = presets::paper_default();
    cfg.artifacts_dir = cgra_mte::runtime::SYNTHETIC_DIR.into();
    cfg.server.mode = mode;
    cfg.server.workers = 2;
    cfg.server.queue_depth = 64;
    cfg
}

/// Build the standing army of idle connections, paced so accept queues
/// never overflow.  Returns however many connected (the fd clamp should
/// make failures rare).
fn idle_army(addr: std::net::SocketAddr, target: usize) -> Vec<TcpStream> {
    let mut army = Vec::with_capacity(target);
    for i in 0..target {
        match TcpStream::connect(addr) {
            Ok(s) => army.push(s),
            Err(_) => {
                // give the accept side a beat, then try once more
                std::thread::sleep(Duration::from_millis(20));
                match TcpStream::connect(addr) {
                    Ok(s) => army.push(s),
                    Err(_) => break,
                }
            }
        }
        if i % 64 == 63 {
            std::thread::sleep(Duration::from_millis(1));
        }
    }
    army
}

/// One closed-loop load connection: `per_conn` requests, one in seven a
/// SUBMIT (BUSY counted, not retried), the rest single-line STATS.
/// Returns (ok, busy, err, per-request latencies in ms).
fn load_text(addr: std::net::SocketAddr, tenant: u32, per_conn: u32) -> (u64, u64, u64, Vec<f64>) {
    let (mut ok, mut busy, mut err) = (0u64, 0u64, 0u64);
    let mut lat = Vec::with_capacity(per_conn as usize);
    let mut client = match WireClient::connect(addr) {
        Ok(c) => c,
        Err(_) => return (0, 0, u64::from(per_conn), lat),
    };
    for i in 0..per_conn {
        let line = if i % 7 == 0 {
            format!("SUBMIT {tenant} {}", APPS[tenant as usize])
        } else {
            "STATS".to_string()
        };
        let t0 = Instant::now();
        match client.send(&line) {
            Ok(reply) => {
                lat.push(t0.elapsed().as_secs_f64() * 1e3);
                if reply.starts_with("BUSY") {
                    busy += 1;
                } else if reply.starts_with("ERR") {
                    err += 1;
                } else {
                    ok += 1;
                }
            }
            Err(_) => {
                err += 1;
                break;
            }
        }
    }
    let _ = client.send("QUIT");
    (ok, busy, err, lat)
}

/// The binary-framing twin of [`load_text`].
fn load_binary(
    addr: std::net::SocketAddr,
    tenant: u32,
    per_conn: u32,
) -> (u64, u64, u64, Vec<f64>) {
    let (mut ok, mut busy, mut err) = (0u64, 0u64, 0u64);
    let mut lat = Vec::with_capacity(per_conn as usize);
    let mut client = match BinWireClient::connect(addr) {
        Ok(c) => c,
        Err(_) => return (0, 0, u64::from(per_conn), lat),
    };
    for i in 0..per_conn {
        let (op, t, payload): (Opcode, u16, &str) = if i % 7 == 0 {
            (Opcode::Submit, tenant as u16, APPS[tenant as usize])
        } else {
            (Opcode::Stats, 0, "")
        };
        let t0 = Instant::now();
        match client.request(op, t, payload.as_bytes()) {
            Ok(reply) => {
                lat.push(t0.elapsed().as_secs_f64() * 1e3);
                match reply.opcode {
                    Opcode::ReplyBusy => busy += 1,
                    Opcode::ReplyErr => err += 1,
                    _ => ok += 1,
                }
            }
            Err(_) => {
                err += 1;
                break;
            }
        }
    }
    let _ = client.quit();
    (ok, busy, err, lat)
}

fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx]
}

fn run_arm(spec: &ArmSpec, idle_target: usize, load_conns: u32, per_conn: u32) -> ArmResult {
    let server = Server::start(&serve_config(spec.mode), "127.0.0.1:0").expect("server start");
    let addr = server.addr;

    let army = idle_army(addr, idle_target);
    let t0 = Instant::now();
    let threads: Vec<_> = (0..load_conns)
        .map(|c| {
            let binary = spec.binary;
            std::thread::spawn(move || {
                let tenant = c % 4;
                if binary {
                    load_binary(addr, tenant, per_conn)
                } else {
                    load_text(addr, tenant, per_conn)
                }
            })
        })
        .collect();
    let (mut ok, mut busy, mut err) = (0u64, 0u64, 0u64);
    let mut lat = Vec::new();
    for t in threads {
        let (o, b, e, l) = t.join().expect("load thread panicked");
        ok += o;
        busy += b;
        err += e;
        lat.extend(l);
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let idle_conns = army.len();
    drop(army);
    server.shutdown();

    lat.sort_by(f64::total_cmp);
    ArmResult {
        name: spec.name,
        protocol: if spec.binary { "binary" } else { "text" },
        idle_conns,
        load_conns,
        ok,
        busy,
        err,
        accepted_qps: ok as f64 / elapsed.max(1e-9),
        p50_ms: percentile(&lat, 0.50),
        p99_ms: percentile(&lat, 0.99),
    }
}

fn arms_json(arms: &[ArmResult]) -> String {
    jsonw::arr(
        &arms
            .iter()
            .map(|r| {
                jsonw::obj(&[
                    ("arm", jsonw::str_val(r.name)),
                    ("protocol", jsonw::str_val(r.protocol)),
                    ("idle_conns", jsonw::num_u(r.idle_conns as u64)),
                    ("load_conns", jsonw::num_u(u64::from(r.load_conns))),
                    ("ok", jsonw::num_u(r.ok)),
                    ("busy", jsonw::num_u(r.busy)),
                    ("err", jsonw::num_u(r.err)),
                    ("accepted_qps", jsonw::num_f(r.accepted_qps)),
                    ("p50_ms", jsonw::num_f(r.p50_ms)),
                    ("p99_ms", jsonw::num_f(r.p99_ms)),
                    ("busy_rate", jsonw::num_f(r.busy as f64 / (r.ok + r.busy).max(1) as f64)),
                ])
            })
            .collect::<Vec<_>>(),
    )
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let t0 = Instant::now();

    // each loopback connection costs two fds in this process; leave 256
    // for everything else (artifacts, sockets, the listener, stdio)
    let fd_budget = (fd_soft_limit().saturating_sub(256) / 2) as usize;
    let idle_target = if smoke { 16 } else { 10_000.min(fd_budget.max(64)) };
    let load_conns = if smoke { 4 } else { 64 };
    let per_conn = if smoke { 12 } else { 150 };

    let mode = if smoke { "smoke" } else { "full" };
    println!("serve-saturation — serving-front comparison ({mode} mode)");
    println!("  idle army target {idle_target} (fd budget {fd_budget})");
    println!("  load: {load_conns} conns × {per_conn} requests each");

    let results: Vec<ArmResult> =
        ARMS.iter().map(|spec| run_arm(spec, idle_target, load_conns, per_conn)).collect();

    for r in &results {
        println!(
            "  {:<15} idle={:<5} ok={:<6} busy={:<5} err={:<3} {:>8.0} req/s  p50 {:>6.2} ms  p99 {:>6.2} ms",
            r.name, r.idle_conns, r.ok, r.busy, r.err, r.accepted_qps, r.p50_ms, r.p99_ms
        );
    }

    // ---- the reactor-beats-thread-per-conn gate (full mode only)
    let threaded = &results[0];
    let reactor = &results[1];
    let qps_wins = reactor.accepted_qps > threaded.accepted_qps;
    let p99_holds = reactor.p99_ms <= threaded.p99_ms * P99_HEADROOM;
    let gate_pass = qps_wins && p99_holds;
    if !smoke {
        println!(
            "  gate: reactor {:.0} req/s vs threaded {:.0} req/s ({}), \
             p99 {:.2} ms vs {:.2} ms ×{P99_HEADROOM} ({})",
            reactor.accepted_qps,
            threaded.accepted_qps,
            if qps_wins { "pass" } else { "FAIL" },
            reactor.p99_ms,
            threaded.p99_ms,
            if p99_holds { "pass" } else { "FAIL" },
        );
    }

    let doc = jsonw::obj(&[
        ("bench", jsonw::str_val("serve-saturation")),
        ("smoke", jsonw::bool_val(smoke)),
        ("idle_conns_target", jsonw::num_u(idle_target as u64)),
        ("load_conns", jsonw::num_u(u64::from(load_conns))),
        ("requests_per_conn", jsonw::num_u(u64::from(per_conn))),
        ("p99_headroom", jsonw::num_f(P99_HEADROOM)),
        ("gate_enforced", jsonw::bool_val(!smoke)),
        ("gate_reactor_beats_threaded", jsonw::bool_val(gate_pass)),
        ("arms", arms_json(&results)),
    ]);
    let path = "BENCH_serve.json";
    export::write_file(path, &doc).expect("write bench json");
    println!("wrote {path}");
    println!("bench wall time: {:.1} s", t0.elapsed().as_secs_f64());

    // liveness floor in both modes: every arm must have served cleanly
    for r in &results {
        if r.ok == 0 || r.err > 0 {
            eprintln!("liveness FAILED: arm {} ok={} err={}", r.name, r.ok, r.err);
            std::process::exit(1);
        }
    }
    if !smoke && !gate_pass {
        eprintln!("serve-saturation gate FAILED: the reactor front must beat thread-per-conn");
        std::process::exit(1);
    }
}
