//! Table 1 — task variants with resource usage and throughput.
//!
//! Regenerates the paper's Table 1 two ways:
//! 1. the pinned task library (authoritative timing inputs), and
//! 2. the first-principles compiler flow (DFG → mapper → unroll), showing
//!    that the §2.2 quantization reproduces the paper's slice counts for
//!    the worked examples.

use cgra_mte::compiler::{dfg, map_dfg, unroll};
use cgra_mte::config::ArchConfig;
use cgra_mte::metrics::Table;
use cgra_mte::tasks::TaskLibrary;

fn main() {
    let lib = TaskLibrary::table1();
    let mut table = Table::new(
        "Table 1 (pinned library)",
        &["app/task", "ver", "tpt", "array", "GLB", "exec @500MHz"],
    );
    for t in lib.iter() {
        for v in &t.variants {
            table.row(&[
                t.id.to_string(),
                v.ver.to_string(),
                format!("{}", v.throughput),
                v.demand.array_slices.to_string(),
                v.demand.glb_slices.to_string(),
                format!("{:.2} ms", t.exec_cycles(v) as f64 / 500e3),
            ]);
        }
    }
    print!("{}", table.render());

    // first-principles cross-check (§2.2 worked example)
    let arch = ArchConfig::default();
    let mut check = Table::new(
        "compiler flow cross-check (DFG → mapper → unroll)",
        &["task", "unroll", "PE tiles", "MEM tiles", "array slices", "GLB slices", "tpt"],
    );
    for (name, base) in [
        ("resnet18.conv2_x", dfg::resnet_stage_dfg(2)),
        ("resnet18.conv3_x", dfg::resnet_stage_dfg(3)),
        ("mobilenet.conv_dw_pw_2_x", dfg::mobilenet_group_dfg(2)),
    ] {
        for factor in [1u32, 4] {
            let mapped = map_dfg(&unroll(&base, factor), &arch).expect("maps");
            check.row(&[
                name.to_string(),
                format!("x{factor}"),
                mapped.raw.pe_tiles.to_string(),
                mapped.raw.mem_tiles.to_string(),
                mapped.demand.array_slices.to_string(),
                mapped.demand.glb_slices.to_string(),
                format!("{}", mapped.throughput),
            ]);
        }
    }
    print!("{}", check.render());
    println!(
        "paper §2.2: conv2_x ⇒ 80 PE / 17 MEM / 2 array-slices / 7 GLB-slices;\n\
         4x unroll ⇒ 288 PE / 33 MEM / 6 array-slices, same GLB.  The pinned\n\
         library carries Table 1 verbatim; the flow above shows the\n\
         quantization lands within a slice of the published mapping."
    );
}
