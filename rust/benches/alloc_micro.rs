//! Region-allocator microbenchmark — the other L3 hot path.
//!
//! The scheduler calls `try_allocate`/`release` on every arrival and
//! completion event; this measures those operations per mechanism under
//! a steady churn pattern, plus the end-to-end scheduling step cost.

use cgra_mte::abstraction::SliceDemand;
use cgra_mte::bench::Bencher;
use cgra_mte::config::{presets, ArchConfig, RegionPolicyKind, SchedulerConfig};
use cgra_mte::dpr::DprMode;
use cgra_mte::regions::{AllocOutcome, RegionManager};
use cgra_mte::scheduler::{RequestQueue, Scheduler};
use cgra_mte::tasks::{AppId, AppRequest, TaskLibrary};

fn main() {
    let arch = ArchConfig::default();
    let bench = Bencher::default();

    for policy in RegionPolicyKind::ALL {
        let sched = SchedulerConfig { region_policy: policy, ..SchedulerConfig::default() };
        let mut mgr = RegionManager::new(&arch, &sched);
        let demand = SliceDemand::new(4, 1);
        let result = bench.run(&format!("alloc+release churn [{}]", policy.name()), || {
            match mgr.try_allocate(&demand) {
                AllocOutcome::Allocated(r) => {
                    mgr.release(r.id).expect("release");
                    1u32
                }
                _ => 0u32,
            }
        });
        println!("{}", result.line());
    }

    // full scheduling step with a populated ready queue; constructor
    // (bitstream generation + cache preload) measured separately from
    // the hot path (§Perf L3).
    let cfg = presets::cloud_scenario(RegionPolicyKind::FlexibleShape);
    let lib = TaskLibrary::table1();
    let construct = bench.run("Scheduler::new + preload_all (cold)", || {
        let mut s = Scheduler::new(&cfg, lib.clone(), DprMode::Fast);
        s.preload_all();
        s.running_count()
    });
    println!("{}", construct.line());

    let mut proto = Scheduler::new(&cfg, lib.clone(), DprMode::Fast);
    proto.preload_all();
    let result = bench.run("Scheduler::schedule step (8 ready, all fit)", || {
        let mut s = proto.clone();
        let mut q = RequestQueue::new();
        for i in 0..8u64 {
            q.submit(AppRequest::new(i, (i % 4) as u32, AppId::Harris, 0));
        }
        s.schedule(&mut q, 0).len()
    });
    println!("{}", result.line());
}
