//! Runtime hot-path benchmark — PJRT execution per artifact.
//!
//! Measures the per-request functional cost of every Table 1 artifact:
//! one-time compile, then steady-state execute latency.  This is the
//! wall-clock hot path of the live coordinator (the virtual-time costs
//! in Fig. 4/5 come from the Table 1 model instead).
//!
//! Skipped gracefully when `make artifacts` has not run.

use cgra_mte::bench::{BenchResult, Bencher};
use cgra_mte::metrics::Table;
use cgra_mte::runtime::RuntimeClient;

fn main() {
    let dir = std::env::var("CGRA_MTE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let mut rt = match RuntimeClient::from_dir(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            println!("runtime_exec: skipped ({e}); run `make artifacts` first");
            return;
        }
    };
    let names: Vec<String> = rt.manifest().iter().map(|a| a.name.clone()).collect();
    let bench = Bencher { warmup_iters: 2, samples: 8, iters_per_sample: 1 };

    let mut table = Table::new(
        "PJRT artifact execution (CPU, interpret-lowered Pallas)",
        &["artifact", "compile ms", "exec mean", "exec min", "output elems"],
    );
    for name in &names {
        let compile_us = rt.ensure_compiled(name).expect("compiles");
        let args = rt.golden_args(name).expect("inputs");
        let spec_out = rt.manifest().get(name).unwrap().output_elements();
        let result = bench.run(name, || rt.execute(name, &args).expect("executes").values.len());
        table.row(&[
            name.clone(),
            format!("{:.1}", compile_us / 1e3),
            BenchResult::fmt_ns(result.mean_ns),
            BenchResult::fmt_ns(result.min_ns),
            spec_out.to_string(),
        ]);
    }
    print!("{}", table.render());
}
