//! Quickstart: the hardware abstraction in five minutes.
//!
//! Builds the paper's CGRA, shows the slice abstraction, allocates
//! execution regions under the four mechanisms (Fig. 2), runs a fast-DPR
//! reconfiguration, and simulates a small multi-task burst.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use cgra_mte::abstraction::SliceDemand;
use cgra_mte::arch::Geometry;
use cgra_mte::compiler::generate_bitstream;
use cgra_mte::config::{presets, ArchConfig, RegionPolicyKind, SchedulerConfig};
use cgra_mte::dpr::{DprEngine, DprMode};
use cgra_mte::regions::RegionManager;
use cgra_mte::sim::run_cloud;
use cgra_mte::tasks::{TaskId, TaskLibrary};

fn main() -> cgra_mte::Result<()> {
    // 1. The baseline CGRA (paper §2.1, Fig. 1): 32×16 tiles, 32 GLB banks.
    let arch = ArchConfig::default();
    let geom = Geometry::new(&arch)?;
    println!(
        "CGRA: {} PE + {} MEM tiles, {} GLB banks ⇒ {} array-slices + {} GLB-slices\n",
        arch.pe_tiles(),
        arch.mem_tiles(),
        arch.glb_banks,
        arch.array_slices(),
        arch.glb_slices()
    );
    assert!(geom.slices_homogeneous(), "slices must be interchangeable");

    // 2. The abstraction (§2.2): tasks are quantized into slice demands.
    let lib = TaskLibrary::table1();
    let conv2 = lib.get(&TaskId::new("resnet18.conv2_x"))?;
    for v in &conv2.variants {
        println!(
            "conv2_x variant {}: {:>3} MACs/cycle on {} (exec {:.2} ms @500 MHz)",
            v.ver,
            v.throughput,
            v.demand,
            conv2.exec_cycles(v) as f64 / 500e3
        );
    }

    // 3. Flexible-shape regions (§2.3): GLB and array decoupled.
    let sched_cfg = SchedulerConfig::default();
    let mut mgr = RegionManager::new(&arch, &sched_cfg);
    // production paths handle NoFit/NeverFits; an idle paper-sized
    // machine always fits these two demands
    let allocate = |mgr: &mut RegionManager, demand: SliceDemand| match mgr.try_allocate(&demand) {
        cgra_mte::regions::AllocOutcome::Allocated(r) => r,
        other => unreachable!("{demand} must fit an idle machine, got {other:?}"),
    };
    let r1 = allocate(&mut mgr, SliceDemand::new(20, 2)); // conv5_x a: GLB-heavy
    let r2 = allocate(&mut mgr, SliceDemand::new(7, 4)); // harris b: array-heavy
    println!("\ncoexisting regions (impossible under coupled mechanisms):");
    println!("  {r1}\n  {r2}");
    println!("{}", mgr.render());

    // 4. fast-DPR (§2.3): preloaded, region-agnostic, microseconds.
    let dpr_cfg = cgra_mte::config::DprConfig::default();
    let bs = generate_bitstream("resnet18.conv2_x", 'a', &SliceDemand::new(7, 2), &arch, &dpr_cfg);
    let mut fast = DprEngine::new(&arch, &dpr_cfg, DprMode::Fast);
    let mut axi = DprEngine::new(&arch, &dpr_cfg, DprMode::Axi4Lite);
    fast.preload(&bs);
    let dest = r1.array[0];
    let f = fast.reconfigure(&bs, &dest);
    let a = axi.reconfigure(&bs, &dest);
    println!(
        "DPR for a 2-slice bitstream: AXI4-Lite {:.1} µs vs fast-DPR {:.1} µs ({}x)",
        a.cycles as f64 / 500.0,
        f.cycles as f64 / 500.0,
        a.cycles / f.cycles.max(1)
    );

    // 5. A small cloud burst end-to-end (timing model only; see
    //    examples/cloud_multitenant.rs for the PJRT functional path).
    let mut cfg = presets::cloud_scenario(RegionPolicyKind::FlexibleShape);
    if let cgra_mte::config::WorkloadConfig::Cloud(ref mut c) = cfg.workload {
        c.duration_ms = 300.0;
    }
    let report = run_cloud(&cfg)?;
    println!(
        "\n300 ms cloud burst (flexible): {} requests, mean NTAT {:.2}, array util {:.0}%",
        report.completed,
        report.mean_ntat_across_apps(),
        report.array_utilization * 100.0
    );
    Ok(())
}
