//! Autonomous-system example (paper §3.2, Fig. 3b).
//!
//! A 30 fps camera stream with dynamically triggered vision/ML tasks,
//! comparing the baseline CGRA (one task at a time, AXI4-Lite DPR)
//! against flexible-shape regions with fast-DPR — the paper's 60.8 %
//! latency-reduction experiment, plus a live render of the slice maps
//! over the first frames.
//!
//! ```sh
//! cargo run --release --example autonomous_edge
//! ```

use cgra_mte::config::{presets, RegionPolicyKind, WorkloadConfig};
use cgra_mte::metrics::Table;
use cgra_mte::sim::run_edge;

fn main() -> cgra_mte::Result<()> {
    let mut table = Table::new(
        "autonomous system — mean frame latency (600 frames @ 30 fps)",
        &["mechanism", "DPR", "mean (ms)", "p99 (ms)", "reconfig share", "vs baseline"],
    );

    let mut baseline_ms = None;
    for policy in RegionPolicyKind::ALL {
        let mut cfg = presets::edge_scenario(policy);
        if let WorkloadConfig::Edge(ref mut e) = cfg.workload {
            e.frames = 600;
        }
        let clk = cfg.arch.core_clock_mhz;
        let report = run_edge(&cfg)?;
        let mean_ms = report.mean_latency_ms(clk);
        let p99_ms = report.latency.p99_total() / (clk as f64 * 1e3);
        if policy == RegionPolicyKind::Baseline {
            baseline_ms = Some(mean_ms);
        }
        let vs = baseline_ms
            .map(|b| format!("{:+.1}%", (mean_ms / b - 1.0) * 100.0))
            .unwrap_or_default();
        table.row(&[
            policy.name().to_string(),
            format!("{:?}", report.dpr_mode),
            format!("{mean_ms:.3}"),
            format!("{p99_ms:.3}"),
            format!("{:.1}%", report.latency.reconfig_share() * 100.0),
            vs,
        ]);
    }
    print!("{}", table.render());
    println!(
        "\npaper's Fig. 5: flexible+fast-DPR cuts mean latency ~60.8% and\n\
         reconfiguration falls from 14.4% of latency to <5%."
    );
    Ok(())
}
