//! TCP client demo + loopback load generator for the worker-pool server.
//!
//! Starts an in-process [`cgra_mte::coordinator::Server`] on an ephemeral
//! port (the same binary `cgra-mte serve-tcp` exposes), then acts as
//! external tenants over real sockets via the shared
//! [`cgra_mte::testutil::wire::WireClient`].
//!
//! Two modes:
//!
//! * **demo** (default): one request per tenant/app plus deliberate
//!   protocol errors, printing every reply.
//! * **load** (`--load [--connections C] [--requests N]`): measures
//!   aggregate completed-SUBMIT throughput of C concurrent tenant
//!   connections (N requests each) against a single-connection
//!   synchronous baseline issuing the same C×N requests — the
//!   EXPERIMENTS.md §Loopback-throughput check.
//!
//! ```sh
//! cargo run --release --example tcp_client
//! cargo run --release --example tcp_client -- --load --connections 4 --requests 50
//! ```

use std::net::SocketAddr;
use std::time::Instant;

use cgra_mte::config::presets;
use cgra_mte::coordinator::Server;
use cgra_mte::testutil::wire::WireClient;

const APPS: [&str; 4] = ["resnet18", "mobilenet", "camera", "harris"];

fn demo(addr: SocketAddr) -> cgra_mte::Result<()> {
    let mut client = WireClient::connect(addr)?;
    for line in [
        "SUBMIT 0 resnet18",
        "SUBMIT 1 mobilenet",
        "SUBMIT 2 camera",
        "SUBMIT 3 harris",
        "SUBMIT 7 camera", // bad tenant → ERR
        "STATS",
        "STATS 2",
    ] {
        let reply = client.send(line)?;
        println!("> {line}\n< {reply}");
    }
    let bye = client.send("QUIT")?;
    println!("> QUIT\n< {bye}");
    Ok(())
}

fn load(addr: SocketAddr, connections: u32, requests: u32) -> cgra_mte::Result<()> {
    let total = connections * requests;

    // Phase 1 — single-connection synchronous baseline: the old serving
    // model (one blocking connection, batch of one) driven as fast as
    // the socket allows.
    let mut single = WireClient::connect(addr)?;
    let t0 = Instant::now();
    for i in 0..total {
        let tenant = i % 4;
        let (reply, _) = single.submit(tenant, APPS[tenant as usize])?;
        assert!(reply.starts_with("OK"), "unexpected reply: {reply}");
    }
    let base_secs = t0.elapsed().as_secs_f64();
    single.send("QUIT")?;
    let base_tput = total as f64 / base_secs;
    println!(
        "baseline  — 1 connection × {total} requests: {base_secs:.3} s  ({base_tput:.0} req/s)"
    );

    // Phase 2 — C concurrent tenant connections, N requests each: the
    // worker pool batches concurrent SUBMITs into shared scheduler
    // invocations and overlaps socket I/O with execution.
    let t0 = Instant::now();
    let threads: Vec<_> = (0..connections)
        .map(|c| {
            std::thread::spawn(move || -> cgra_mte::Result<u32> {
                let tenant = c % 4;
                let mut client = WireClient::connect(addr)?;
                let mut busy = 0;
                for _ in 0..requests {
                    let (reply, retries) = client.submit(tenant, APPS[tenant as usize])?;
                    assert!(reply.starts_with("OK"), "unexpected reply: {reply}");
                    busy += retries;
                }
                client.send("QUIT")?;
                Ok(busy)
            })
        })
        .collect();
    let mut busy_total = 0;
    for t in threads {
        busy_total += t.join().expect("load thread panicked")?;
    }
    let conc_secs = t0.elapsed().as_secs_f64();
    let conc_tput = total as f64 / conc_secs;
    println!(
        "concurrent — {connections} connections × {requests} requests: {conc_secs:.3} s  \
         ({conc_tput:.0} req/s, {busy_total} BUSY retries)"
    );
    println!("speedup: {:.2}x aggregate completed-SUBMIT throughput", conc_tput / base_tput);
    Ok(())
}

fn main() -> cgra_mte::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let flag_val = |name: &str| -> Option<u32> {
        args.iter()
            .position(|a| a == name)
            .and_then(|i| args.get(i + 1))
            .and_then(|v| v.parse().ok())
    };

    let mut cfg = presets::paper_default();
    cfg.artifacts_dir = cgra_mte::runtime::default_artifacts_dir();

    println!("starting server (compiles all artifacts once)...");
    let server = Server::start(&cfg, "127.0.0.1:0")?;
    println!(
        "server on {} ({} workers, queue depth {})\n",
        server.addr, cfg.server.workers, cfg.server.queue_depth
    );

    let result = if args.iter().any(|a| a == "--load") {
        load(
            server.addr,
            flag_val("--connections").unwrap_or(4),
            flag_val("--requests").unwrap_or(50),
        )
    } else {
        demo(server.addr)
    };

    server.shutdown();
    println!("\nserver drained and shut down cleanly");
    result
}
