//! End-to-end driver: the full system on a real small workload.
//!
//! Four tenants (Fig. 3a) submit a Poisson burst of application requests
//! to the live coordinator.  Every scheduled task executes its
//! AOT-compiled JAX/Pallas artifact through PJRT — real tensors in, real
//! tensors out, golden-verified — while the slice abstraction, greedy
//! scheduler, flexible-shape regions, and fast-DPR provide the paper's
//! timing model.  Results are recorded in EXPERIMENTS.md §End-to-end.
//!
//! ```sh
//! make artifacts && cargo run --release --example cloud_multitenant
//! ```

use cgra_mte::config::presets;
use cgra_mte::coordinator::{Leader, TenantId};
use cgra_mte::metrics::Table;
use cgra_mte::tasks::AppId;
use cgra_mte::util::rng::Rng;

fn main() -> cgra_mte::Result<()> {
    let mut cfg = presets::paper_default();
    cfg.artifacts_dir = cgra_mte::runtime::default_artifacts_dir();

    println!("starting leader (compiling all artifacts once — the request path never compiles)...");
    let mut leader = Leader::new(&cfg)?;
    println!("warmup: {:.0} ms\n", leader.stats().warmup_ms);

    // Poisson arrivals per tenant over a 100 ms window of virtual time.
    let cycles_per_ms = cfg.arch.core_clock_mhz as u64 * 1000;
    let mut rng = Rng::new(2023);
    let mut subs = Vec::new();
    let mean_gap_ms = [25.0, 12.0, 8.0, 10.0]; // per-tenant mean inter-arrival
    for tenant in 0..4u32 {
        let mut t_ms = 0.0;
        let mut stream = rng.fork(tenant as u64);
        loop {
            t_ms += stream.exponential(1.0 / mean_gap_ms[tenant as usize]);
            if t_ms > 100.0 {
                break;
            }
            subs.push((
                TenantId(tenant),
                AppId::ALL[tenant as usize],
                (t_ms * cycles_per_ms as f64) as u64,
            ));
        }
    }
    println!("submitting {} requests over a 100 ms window...", subs.len());
    let stats = leader.serve(&subs)?;

    let mut table = Table::new(
        "per-application results (virtual-time NTAT, real PJRT compute)",
        &["app", "requests", "mean NTAT", "p95 NTAT", "compute µs/req"],
    );
    for app in AppId::ALL {
        let outcomes: Vec<_> = stats.outcomes.iter().filter(|o| o.app == app).collect();
        if outcomes.is_empty() {
            continue;
        }
        let mut ntat = cgra_mte::util::stats::Summary::from_iter(outcomes.iter().map(|o| o.ntat));
        let compute: f64 =
            outcomes.iter().map(|o| o.compute_us).sum::<f64>() / outcomes.len() as f64;
        table.row(&[
            app.name().to_string(),
            outcomes.len().to_string(),
            format!("{:.2}", ntat.mean()),
            format!("{:.2}", ntat.percentile(95.0)),
            format!("{compute:.0}"),
        ]);
    }
    print!("{}", table.render());

    println!(
        "\ntotals: {} launches, {:.1} ms PJRT compute, all outputs golden-verified",
        stats.launches,
        stats.total_compute_us / 1e3
    );
    println!("final region state (should be all free):");
    println!("{}", leader.scheduler().regions().render());
    Ok(())
}
