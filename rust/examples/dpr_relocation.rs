//! Bitstream relocation demo (paper §2.3).
//!
//! "With this bitstream relocation feature, a user can pre-load
//! bitstreams of the next task to the GLB in advance and rapidly map it
//! to any next available region just by writing to a single register."
//!
//! This example preloads one region-agnostic bitstream, then maps the
//! same task to every array-slice in turn — each relocation is a cache
//! hit costing only the parallel stream time — and contrasts it with
//! (a) Amber-style region-aware bitstreams (hit only at the home region)
//! and (b) AXI4-Lite reconfiguration.  Functional equivalence across
//! destinations is shown by executing the task's artifact after each
//! relocation: the output is identical wherever the task lands.
//!
//! ```sh
//! make artifacts && cargo run --release --example dpr_relocation
//! ```

use cgra_mte::abstraction::{SliceDemand, SliceRange};
use cgra_mte::compiler::generate_bitstream;
use cgra_mte::config::{ArchConfig, DprConfig};
use cgra_mte::dpr::{DprEngine, DprMode};
use cgra_mte::runtime::RuntimeClient;

fn main() -> cgra_mte::Result<()> {
    let arch = ArchConfig::default();
    let dpr_cfg = DprConfig::default();
    let us = |cycles: u64| cycles as f64 / arch.core_clock_mhz as f64;

    // A 2-slice task bitstream (harris variant a).
    let demand = SliceDemand::new(4, 2);
    let bs = generate_bitstream("harris.corner", 'a', &demand, &arch, &dpr_cfg);
    println!(
        "bitstream {}: {} words ({} KiB), {} slices, region-agnostic={}\n",
        bs.id,
        bs.words,
        bs.bytes() / 1024,
        bs.array_slices,
        bs.region_agnostic
    );

    // 1. Relocation on: preload once, map anywhere — always a hit.
    let mut engine = DprEngine::new(&arch, &dpr_cfg, DprMode::Fast);
    engine.preload(&bs);
    println!("fast-DPR with relocation (paper):");
    for start in (0..arch.array_slices() - 1).step_by(2) {
        let out = engine.reconfigure(&bs, &SliceRange::new(start, 2));
        println!(
            "  → slices [{start}..{}): {:>7.1} µs  cache_hit={}",
            start + 2,
            us(out.cycles),
            out.cache_hit
        );
    }

    // 2. Relocation off (Amber): the cached image only matches its home.
    let mut no_reloc_cfg = dpr_cfg.clone();
    no_reloc_cfg.relocation = false;
    let mut amber = DprEngine::new(&arch, &no_reloc_cfg, DprMode::Fast);
    let mut aware = generate_bitstream("harris.corner", 'a', &demand, &arch, &no_reloc_cfg);
    aware.home_slice = 2;
    amber.preload(&aware);
    println!("\nfast-DPR without relocation (Amber-style, region-aware):");
    for start in [2u32, 4] {
        let out = amber.reconfigure(&aware, &SliceRange::new(start, 2));
        println!(
            "  → slices [{start}..{}): {:>7.1} µs  cache_hit={}  {}",
            start + 2,
            us(out.cycles),
            out.cache_hit,
            if out.cache_hit { "(home region)" } else { "(miss: host reload)" }
        );
    }

    // 3. AXI4-Lite baseline for scale.
    let mut axi = DprEngine::new(&arch, &dpr_cfg, DprMode::Axi4Lite);
    let out = axi.reconfigure(&bs, &SliceRange::new(0, 2));
    println!("\nAXI4-Lite baseline: {:.1} µs per reconfiguration", us(out.cycles));

    // 4. Functional equivalence across destinations: the artifact
    //    computes the same output wherever the slice abstraction put it.
    let dir = cgra_mte::runtime::default_artifacts_dir();
    match RuntimeClient::from_dir(&dir) {
        Ok(mut rt) => {
            let a = rt.verify_golden("harris_a")?;
            let b = rt.verify_golden("harris_a")?;
            assert_eq!(a.values, b.values);
            println!(
                "\nfunctional check: harris_a golden-verified twice (Σ={:+.4}), \
                 outputs identical across relocations",
                a.checksum().sum
            );
        }
        Err(_) => println!("\n(artifacts not built — run `make artifacts` for the functional check)"),
    }
    Ok(())
}
