//! Coordinator wire-protocol and worker-pool concurrency, end-to-end
//! over loopback TCP on the stub runtime backend (the synthetic manifest
//! needs no artifacts on disk, so these run in every offline `cargo
//! test`).  Covers the PR acceptance bar: ≥4 concurrent tenant
//! connections served correctly, STATS counter correctness, BUSY
//! backpressure, and aggregate completed-SUBMIT throughput strictly
//! above the single-connection synchronous baseline.
//!
//! Each test spins up a full server (workers + executor + accept loop)
//! and its own client threads, and one of them asserts a wall-clock
//! ordering — so the tests serialize on a shared lock to keep CPU
//! contention between them from distorting the timing comparison on
//! small CI runners.
#![cfg(not(feature = "xla"))]

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use cgra_mte::config::{presets, Config, ServerModeKind};
use cgra_mte::coordinator::Server;
use cgra_mte::testutil::wire::WireClient;

const APPS: [&str; 4] = ["resnet18", "mobilenet", "camera", "harris"];

/// Serializes the server tests (see module docs).
static SERIAL: Mutex<()> = Mutex::new(());

fn stub_config() -> Config {
    let mut cfg = presets::paper_default();
    cfg.artifacts_dir = cgra_mte::runtime::SYNTHETIC_DIR.into();
    cfg
}

fn reactor_config() -> Config {
    let mut cfg = stub_config();
    cfg.server.mode = ServerModeKind::Reactor;
    cfg
}

/// SUBMIT until served (retrying through BUSY), asserting an OK reply.
fn submit_ok(client: &mut WireClient, tenant: u32, app: &str) -> String {
    let (reply, _) = client.submit(tenant, app).expect("submit");
    assert!(reply.starts_with("OK "), "tenant {tenant}: {reply}");
    reply
}

#[test]
fn four_concurrent_connections_serve_end_to_end() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    const PER_CONN: u32 = 5;
    let server = Server::start(&stub_config(), "127.0.0.1:0").unwrap();
    let addr = server.addr;

    let threads: Vec<_> = (0..4u32)
        .map(|tenant| {
            std::thread::spawn(move || {
                let mut client = WireClient::connect(addr).expect("connect");
                for _ in 0..PER_CONN {
                    let reply = submit_ok(&mut client, tenant, APPS[tenant as usize]);
                    assert!(reply.contains("ntat="), "{reply}");
                    assert!(reply.contains("compute_us="), "{reply}");
                }
                assert_eq!(client.send("QUIT").expect("quit"), "BYE");
            })
        })
        .collect();
    for t in threads {
        t.join().expect("connection thread panicked");
    }

    // STATS counter correctness: 20 submissions admitted and served,
    // none lost, none failed.
    let mut client = WireClient::connect(addr).expect("connect");
    let stats = client.send("STATS").expect("stats");
    assert!(stats.contains("served=20"), "{stats}");
    assert!(stats.contains("queued=20"), "{stats}");
    assert!(stats.contains("failed=0"), "{stats}");
    assert!(stats.contains("pending=0"), "{stats}");
    for tenant in 0..4 {
        let per = client.send(&format!("STATS {tenant}")).expect("stats");
        assert!(
            per.contains(&format!("tenant={tenant} served={PER_CONN} queued={PER_CONN} rejected=")),
            "{per}"
        );
    }
    client.send("QUIT").expect("quit");
    server.shutdown();
}

#[test]
fn sequence_numbers_are_unique_across_connections() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let server = Server::start(&stub_config(), "127.0.0.1:0").unwrap();
    let addr = server.addr;

    let threads: Vec<_> = (0..4u32)
        .map(|tenant| {
            std::thread::spawn(move || {
                let mut client = WireClient::connect(addr).expect("connect");
                let seqs: Vec<u64> = (0..6)
                    .map(|_| {
                        let reply = submit_ok(&mut client, tenant, "harris");
                        let seq_field = reply
                            .split_whitespace()
                            .find(|f| f.starts_with("seq="))
                            .expect("seq field");
                        seq_field["seq=".len()..].parse().expect("seq number")
                    })
                    .collect();
                client.send("QUIT").expect("quit");
                seqs
            })
        })
        .collect();
    let mut all: Vec<u64> = threads
        .into_iter()
        .flat_map(|t| t.join().expect("thread"))
        .collect();
    all.sort_unstable();
    let before = all.len();
    all.dedup();
    assert_eq!(all.len(), before, "duplicate sequence numbers across connections");
    assert_eq!(all.len(), 24);
    server.shutdown();
}

#[test]
fn busy_backpressure_over_the_wire() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    // depth-1 queues and a camera burst: with four connections hammering
    // one tenant, the admission path must stay bounded — every reply is
    // either OK or a well-formed BUSY, and the server survives.
    let mut cfg = stub_config();
    cfg.server.queue_depth = 1;
    cfg.server.workers = 1;
    cfg.server.batch_max = 1;
    let server = Server::start(&cfg, "127.0.0.1:0").unwrap();
    let addr = server.addr;

    let threads: Vec<_> = (0..4u32)
        .map(|_| {
            std::thread::spawn(move || {
                let mut client = WireClient::connect(addr).expect("connect");
                let mut busy = 0u32;
                let mut ok = 0u32;
                for _ in 0..10 {
                    let reply = client.send("SUBMIT 0 camera").expect("submit");
                    if reply.starts_with("BUSY") {
                        assert_eq!(reply, "BUSY tenant=0 queue_depth=1");
                        busy += 1;
                    } else {
                        assert!(reply.starts_with("OK "), "{reply}");
                        ok += 1;
                    }
                }
                client.send("QUIT").expect("quit");
                (ok, busy)
            })
        })
        .collect();
    let (mut ok_total, mut busy_total) = (0, 0);
    for t in threads {
        let (ok, busy) = t.join().expect("thread");
        ok_total += ok;
        busy_total += busy;
    }
    assert_eq!(ok_total + busy_total, 40);
    assert!(ok_total > 0, "nothing served");

    let mut client = WireClient::connect(addr).expect("connect");
    let stats = client.send("STATS").expect("stats");
    assert!(stats.contains(&format!("served={ok_total}")), "{stats}");
    assert!(stats.contains(&format!("rejected={busy_total}")), "{stats}");
    client.send("QUIT").expect("quit");
    server.shutdown();
}

/// N clients against a 2-shard pool: per-shard completion streams merge
/// back into one Router sequence — every SUBMIT gets an `OK` (a merge
/// that handed a completion to the wrong shard's router would surface
/// as `Router::complete` rejecting an unknown seq, failing the batch
/// into `ERR` replies and a nonzero `failed` counter), seqs stay
/// globally unique across shards, and the per-tenant counters sum
/// across shards to the pool-wide totals.
#[test]
fn two_shard_pool_merges_completions_and_sums_tenant_counters() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    const PER_CONN: u32 = 6;
    let mut cfg = stub_config();
    cfg.pool.shards = 2;
    cfg.server.workers = 4;
    cfg.server.batch_max = 2;
    let server = Server::start(&cfg, "127.0.0.1:0").unwrap();
    let addr = server.addr;

    let threads: Vec<_> = (0..4u32)
        .map(|tenant| {
            std::thread::spawn(move || {
                let mut client = WireClient::connect(addr).expect("connect");
                let seqs: Vec<u64> = (0..PER_CONN)
                    .map(|_| {
                        let reply = submit_ok(&mut client, tenant, APPS[tenant as usize]);
                        let seq_field = reply
                            .split_whitespace()
                            .find(|f| f.starts_with("seq="))
                            .expect("seq field");
                        seq_field["seq=".len()..].parse().expect("seq number")
                    })
                    .collect();
                client.send("QUIT").expect("quit");
                seqs
            })
        })
        .collect();
    let mut all: Vec<u64> = threads
        .into_iter()
        .flat_map(|t| t.join().expect("client thread"))
        .collect();
    all.sort_unstable();
    let before = all.len();
    all.dedup();
    assert_eq!(all.len(), before, "duplicate seqs across shard leaders");
    assert_eq!(all.len(), 24);

    let mut client = WireClient::connect(addr).expect("connect");
    // pool-wide totals: nothing lost, nothing failed, and the aggregate
    // line knows its shard count
    let stats = client.send("STATS").expect("stats");
    assert!(stats.contains("served=24"), "{stats}");
    assert!(stats.contains("queued=24"), "{stats}");
    assert!(stats.contains("failed=0"), "{stats}");
    assert!(stats.contains("pending=0"), "{stats}");
    assert!(stats.contains("shards=2"), "{stats}");
    // per-tenant counters sum across shards to each tenant's total
    for tenant in 0..4 {
        let per = client.send(&format!("STATS {tenant}")).expect("stats");
        assert!(
            per.contains(&format!(
                "tenant={tenant} served={PER_CONN} queued={PER_CONN} rejected="
            )),
            "{per}"
        );
    }
    // STATS SHARDS enumerates both shards; their batch counts account
    // for every executed batch (24 submissions / batch_max=2 ⇒ ≥ 12)
    let shard_lines = client.stats_shards().expect("stats shards");
    assert_eq!(shard_lines.len(), 2, "{shard_lines:?}");
    let batches: u64 = shard_lines
        .iter()
        .map(|l| {
            assert!(l.starts_with("STATS shard="), "{l}");
            l.split_whitespace()
                .find_map(|f| f.strip_prefix("batches="))
                .expect("batches field")
                .parse::<u64>()
                .expect("batches number")
        })
        .sum();
    assert!(batches >= 12, "24 submissions at batch_max=2 need ≥ 12 batches, saw {batches}");
    // the aggregate STATS line names the active placement policy
    assert!(stats.contains("placement=least-loaded"), "{stats}");
    // STATS ENERGY shares the SHARDS framing; accounting is off in this
    // config, so every gauge reads zero but the reply is well-formed
    let (header, energy_lines) = client.stats_energy().expect("stats energy");
    assert!(header.contains("energy_j=0.000000"), "{header}");
    assert!(header.contains("placement=least-loaded"), "{header}");
    assert_eq!(energy_lines.len(), 2, "{energy_lines:?}");
    for l in &energy_lines {
        assert!(l.starts_with("STATS shard="), "{l}");
        assert!(l.contains("power_w=0.000"), "{l}");
        assert!(l.contains("throttled=0"), "{l}");
    }
    // STATS NOC: `[noc]` is off in this config, so the surface is dark
    let noc = client.stats_noc().expect("stats noc");
    assert_eq!(noc, "STATS noc=off");
    // control-plane defrag broadcasts to both shards and merges
    let defrag = client.send("DEFRAG").expect("defrag");
    assert!(defrag.starts_with("DEFRAG migrated=0"), "{defrag}");
    client.send("QUIT").expect("quit");
    server.shutdown();
}

/// Parse one Prometheus exposition line into `(series, value)`;
/// `# HELP` / `# TYPE` comment lines return `None`.  Panics on anything
/// malformed — this is the wire-format contract of the `METRICS`
/// command.
fn parse_metric(line: &str) -> Option<(String, f64)> {
    if line.starts_with('#') {
        assert!(
            line.starts_with("# TYPE ") || line.starts_with("# HELP "),
            "bad comment line: {line}"
        );
        return None;
    }
    let (series, value) = line.rsplit_once(' ').unwrap_or_else(|| panic!("no value: {line}"));
    let name = series.split('{').next().expect("series name");
    assert!(
        !name.is_empty()
            && name.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_'),
        "bad metric name: {line}"
    );
    let v: f64 = value.parse().unwrap_or_else(|_| panic!("bad value: {line}"));
    Some((series.to_string(), v))
}

/// `METRICS` scraped mid-load on an obs-enabled server: every scrape's
/// exposition parses line by line, and the admission identity
/// `queued == served + failed + inflight` holds *within each reply*
/// even while four connections race it (inflight is derived from the
/// same snapshot, so the books always balance).
#[test]
fn metrics_scrape_mid_load_parses_and_conserves() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    const PER_CONN: u32 = 8;
    let mut cfg = stub_config();
    cfg.obs.enabled = true;
    let server = Server::start(&cfg, "127.0.0.1:0").unwrap();
    let addr = server.addr;

    let load: Vec<_> = (0..4u32)
        .map(|tenant| {
            std::thread::spawn(move || {
                let mut client = WireClient::connect(addr).expect("connect");
                for _ in 0..PER_CONN {
                    submit_ok(&mut client, tenant, APPS[tenant as usize]);
                }
                client.send("QUIT").expect("quit");
            })
        })
        .collect();

    let mut scraper = WireClient::connect(addr).expect("connect");
    for _ in 0..10 {
        let lines = scraper.metrics().expect("metrics");
        let series: std::collections::BTreeMap<String, f64> =
            lines.iter().filter_map(|l| parse_metric(l)).collect();
        let get = |k: &str| *series.get(k).unwrap_or_else(|| panic!("missing {k}"));
        let queued = get("cgra_serve_queued_total");
        let served = get("cgra_serve_served_total");
        let failed = get("cgra_serve_failed_total");
        let inflight = get("cgra_serve_inflight");
        assert_eq!(queued, served + failed + inflight, "identity broke: {lines:?}");
        std::thread::sleep(Duration::from_millis(5));
    }
    for t in load {
        t.join().expect("load thread panicked");
    }

    // after the load drains: everything served, nothing in flight, and
    // the [obs] registry contributed the executor-fed series
    let lines = scraper.metrics().expect("metrics");
    let series: std::collections::BTreeMap<String, f64> =
        lines.iter().filter_map(|l| parse_metric(l)).collect();
    let total = (4 * PER_CONN) as f64;
    assert_eq!(series.get("cgra_serve_queued_total"), Some(&total));
    assert_eq!(series.get("cgra_serve_served_total"), Some(&total));
    assert_eq!(series.get("cgra_serve_inflight"), Some(&0.0));
    assert!(series.keys().any(|k| k.starts_with("cgra_serve_batches_total")), "{lines:?}");
    assert!(series.keys().any(|k| k.starts_with("cgra_dpr_cache_hits_total")), "{lines:?}");
    scraper.send("QUIT").expect("quit");
    server.shutdown();
}

/// The WATCH hub under backpressure on both fronts: a cap-1
/// per-subscriber queue and a submission burst that outruns the
/// threaded front's 100 ms drain tick.  The submission path must never
/// stall (the hub drops instead of blocking), every event published
/// while subscribed is either delivered or counted as dropped, and the
/// drop count surfaces in both the `WATCH done` trailer and the
/// METRICS exposition.
#[test]
fn watch_backpressure_drops_and_counts_instead_of_blocking() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    const BURST: u64 = 12;
    for mode in [ServerModeKind::Threaded, ServerModeKind::Reactor] {
        let mut cfg = stub_config();
        cfg.server.mode = mode;
        cfg.obs.enabled = true;
        cfg.obs.watch_queue_cap = 1;
        let server = Server::start(&cfg, "127.0.0.1:0").unwrap();
        let addr = server.addr;

        let mut watcher = WireClient::connect(addr).expect("connect watcher");
        watcher.watch_subscribe().expect("subscribe");

        // the burst: the executors publish each journal event the
        // moment it is recorded — a full subscriber queue must drop,
        // never stall the submission path
        let mut loader = WireClient::connect(addr).expect("connect loader");
        for i in 0..BURST {
            let tenant = (i % 4) as u32;
            submit_ok(&mut loader, tenant, APPS[tenant as usize]);
        }

        let (events, trailer) = watcher.watch_finish(0).expect("watch finish");
        let field = |k: &str| -> u64 {
            trailer
                .split_whitespace()
                .find_map(|f| f.strip_prefix(k))
                .unwrap_or_else(|| panic!("no {k} in {trailer}"))
                .parse()
                .unwrap_or_else(|_| panic!("bad {k} in {trailer}"))
        };
        let (delivered, dropped) = (field("events="), field("dropped="));
        assert_eq!(delivered as usize, events.len(), "{trailer}");
        // conservation: every event published while subscribed was
        // either delivered or dropped — none blocked, none lost
        assert!(
            delivered + dropped >= BURST,
            "{mode:?}: {delivered} delivered + {dropped} dropped < burst of {BURST}"
        );
        if mode == ServerModeKind::Threaded {
            // the burst lands inside at most a couple of 100 ms drain
            // windows, so the cap-1 queue must have overflowed
            assert!(dropped > 0, "no drops despite cap-1 queue: {trailer}");
            // the hub-wide counter agrees with the trailer
            let (_, lines) = loader.metrics_full().expect("metrics");
            let series: std::collections::BTreeMap<String, f64> =
                lines.iter().filter_map(|l| parse_metric(l)).collect();
            assert_eq!(
                series.get("cgra_obs_watch_dropped_total"),
                Some(&(dropped as f64)),
                "{lines:?}"
            );
        }
        loader.send("QUIT").expect("quit");
        watcher.send("QUIT").expect("quit");
        server.shutdown();
    }
}

/// Acceptance check: aggregate completed-SUBMIT throughput of ≥4
/// concurrent tenant connections strictly above the single-connection
/// synchronous baseline (same total request count, fresh server each to
/// keep the comparison fair).  The win comes from overlapping socket
/// round-trips across connections and folding concurrent SUBMITs into
/// shared scheduler invocations; the margin is large (typically 2-4x),
/// so a strict `<` comparison is stable despite wall-clock noise — and
/// the SERIAL lock keeps sibling tests from loading the machine during
/// the timed phases.
#[test]
fn concurrent_throughput_beats_single_connection_baseline() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    const CONNS: u32 = 4;
    const PER_CONN: u32 = 25;
    const TOTAL: u32 = CONNS * PER_CONN;

    // Phase 1: single-connection synchronous baseline.
    let base_server = Server::start(&stub_config(), "127.0.0.1:0").unwrap();
    let mut single = WireClient::connect(base_server.addr).expect("connect");
    submit_ok(&mut single, 0, "harris"); // warm the path before timing
    let t0 = Instant::now();
    for i in 0..TOTAL {
        let tenant = i % 4;
        submit_ok(&mut single, tenant, APPS[tenant as usize]);
    }
    let base_secs = t0.elapsed().as_secs_f64();
    single.send("QUIT").expect("quit");
    base_server.shutdown();

    // Phase 2: CONNS concurrent tenant connections, PER_CONN each.
    let conc_server = Server::start(&stub_config(), "127.0.0.1:0").unwrap();
    let addr = conc_server.addr;
    submit_ok(&mut WireClient::connect(addr).expect("connect"), 0, "harris"); // same warmup
    let t0 = Instant::now();
    let threads: Vec<_> = (0..CONNS)
        .map(|c| {
            std::thread::spawn(move || {
                let tenant = c % 4;
                let mut client = WireClient::connect(addr).expect("connect");
                for _ in 0..PER_CONN {
                    submit_ok(&mut client, tenant, APPS[tenant as usize]);
                }
                client.send("QUIT").expect("quit");
            })
        })
        .collect();
    for t in threads {
        t.join().expect("load thread panicked");
    }
    let conc_secs = t0.elapsed().as_secs_f64();
    conc_server.shutdown();

    let base_tput = TOTAL as f64 / base_secs;
    let conc_tput = TOTAL as f64 / conc_secs;
    assert!(
        conc_tput > base_tput,
        "worker-pool server not faster: concurrent {conc_tput:.0} req/s \
         vs single-connection baseline {base_tput:.0} req/s"
    );
}

/// Reconnect storm against the reactor front: many short-lived
/// connections (connect → SUBMIT → QUIT → drop) from concurrent
/// threads.  Slab slots are recycled through the free list with a
/// generation bump each time; a stale completion or a leaked pending
/// slot would surface as a lost reply (hang), a cross-connection reply,
/// or a counter leak in the conservation check at the end.
#[test]
fn reactor_reconnect_storm_conserves_admission_counters() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    const THREADS: u32 = 4;
    const RECONNECTS: u32 = 20;
    let server = Server::start(&reactor_config(), "127.0.0.1:0").unwrap();
    let addr = server.addr;

    let threads: Vec<_> = (0..THREADS)
        .map(|tenant| {
            std::thread::spawn(move || {
                for _ in 0..RECONNECTS {
                    let mut client = WireClient::connect(addr).expect("connect");
                    submit_ok(&mut client, tenant, APPS[tenant as usize]);
                    assert_eq!(client.send("QUIT").expect("quit"), "BYE");
                }
            })
        })
        .collect();
    for t in threads {
        t.join().expect("storm thread panicked");
    }

    let total = THREADS * RECONNECTS;
    let mut client = WireClient::connect(addr).expect("connect");
    let stats = client.send("STATS").expect("stats");
    assert!(stats.contains(&format!("served={total}")), "{stats}");
    assert!(stats.contains(&format!("queued={total}")), "{stats}");
    assert!(stats.contains("failed=0"), "{stats}");
    assert!(stats.contains("pending=0"), "{stats}");
    client.send("QUIT").expect("quit");
    server.shutdown();
}

/// Slow-loris defense: with `idle_timeout_ms` armed, a peer dribbling
/// one byte per tick without ever completing a request is reaped —
/// raw bytes do not count as progress — while a client that keeps
/// completing requests across the same wall-clock span stays connected,
/// and the server serves fresh clients afterwards.
#[test]
fn reactor_idle_timeout_reaps_slow_loris_but_not_active_clients() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let mut cfg = reactor_config();
    cfg.server.idle_timeout_ms = 150;
    let server = Server::start(&cfg, "127.0.0.1:0").unwrap();
    let addr = server.addr;

    // the active client: completes a request every ~50 ms for well past
    // the idle timeout — progress keeps it alive
    let active = std::thread::spawn(move || {
        let mut client = WireClient::connect(addr).expect("connect");
        for _ in 0..10 {
            submit_ok(&mut client, 0, "harris");
            std::thread::sleep(Duration::from_millis(50));
        }
        assert_eq!(client.send("QUIT").expect("quit"), "BYE");
    });

    // the slow loris: one byte of a never-finished line per 30 ms tick
    let mut loris = TcpStream::connect(addr).expect("connect");
    loris.set_read_timeout(Some(Duration::from_millis(30))).expect("read timeout");
    let deadline = Instant::now() + Duration::from_secs(5);
    let mut closed = false;
    while Instant::now() < deadline {
        if loris.write_all(b"S").is_err() {
            closed = true;
            break;
        }
        let mut probe = [0u8; 16];
        match loris.read(&mut probe) {
            Ok(0) => {
                closed = true;
                break;
            }
            Ok(_) => continue, // no reply is ever owed; tolerate noise
            Err(ref e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(_) => {
                closed = true;
                break;
            }
        }
    }
    assert!(closed, "slow-loris connection outlived the idle timeout");
    active.join().expect("active client panicked");

    // liveness + conservation after the reap
    let mut client = WireClient::connect(addr).expect("connect");
    submit_ok(&mut client, 1, "camera");
    let stats = client.send("STATS").expect("stats");
    assert!(stats.contains("served=11"), "{stats}");
    assert!(stats.contains("queued=11"), "{stats}");
    assert!(stats.contains("failed=0"), "{stats}");
    assert!(stats.contains("pending=0"), "{stats}");
    client.send("QUIT").expect("quit");
    server.shutdown();
}

/// The reactor front under BUSY backpressure: depth-1 queues, four
/// connections hammering one tenant.  Every reply is OK or a
/// well-formed BUSY, totals conserve, and the server survives — the
/// reactor twin of `busy_backpressure_over_the_wire`.
#[test]
fn reactor_busy_backpressure_over_the_wire() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let mut cfg = reactor_config();
    cfg.server.queue_depth = 1;
    cfg.server.workers = 1;
    cfg.server.batch_max = 1;
    let server = Server::start(&cfg, "127.0.0.1:0").unwrap();
    let addr = server.addr;

    let threads: Vec<_> = (0..4u32)
        .map(|_| {
            std::thread::spawn(move || {
                let mut client = WireClient::connect(addr).expect("connect");
                let mut busy = 0u32;
                let mut ok = 0u32;
                for _ in 0..10 {
                    let reply = client.send("SUBMIT 0 camera").expect("submit");
                    if reply.starts_with("BUSY") {
                        assert_eq!(reply, "BUSY tenant=0 queue_depth=1");
                        busy += 1;
                    } else {
                        assert!(reply.starts_with("OK "), "{reply}");
                        ok += 1;
                    }
                }
                client.send("QUIT").expect("quit");
                (ok, busy)
            })
        })
        .collect();
    let (mut ok_total, mut busy_total) = (0, 0);
    for t in threads {
        let (ok, busy) = t.join().expect("thread");
        ok_total += ok;
        busy_total += busy;
    }
    assert_eq!(ok_total + busy_total, 40);
    assert!(ok_total > 0, "nothing served");

    let mut client = WireClient::connect(addr).expect("connect");
    let stats = client.send("STATS").expect("stats");
    assert!(stats.contains(&format!("served={ok_total}")), "{stats}");
    assert!(stats.contains(&format!("rejected={busy_total}")), "{stats}");
    client.send("QUIT").expect("quit");
    server.shutdown();
}
