//! Paper-shape integration tests: the qualitative results of every
//! table/figure must hold (ordering, rough factors, crossovers) — these
//! are the assertions EXPERIMENTS.md reports quantitatively.

use cgra_mte::config::{presets, RegionPolicyKind, WorkloadConfig};
use cgra_mte::sim::{run_cloud, run_edge};
use cgra_mte::tasks::{AppId, TaskId, TaskLibrary, VariantId};

fn cloud_cfg(policy: RegionPolicyKind, seed: u64) -> cgra_mte::config::Config {
    let mut cfg = presets::cloud_scenario(policy);
    if let WorkloadConfig::Cloud(ref mut c) = cfg.workload {
        c.duration_ms = 3000.0;
        c.mean_interarrival_ms = [45.0, 25.0, 30.0, 28.0];
        c.seed = seed;
    }
    cfg
}

fn edge_cfg(policy: RegionPolicyKind, seed: u64) -> cgra_mte::config::Config {
    let mut cfg = presets::edge_scenario(policy);
    if let WorkloadConfig::Edge(ref mut e) = cfg.workload {
        e.frames = 300;
        e.seed = seed;
    }
    cfg
}

// ---------------------------------------------------------------- Table 1

#[test]
fn table1_matches_paper_verbatim() {
    let lib = TaskLibrary::table1();
    // every row of the paper's Table 1: (task, ver, tpt, array, glb)
    let rows: &[(&str, char, f64, u32, u32)] = &[
        ("resnet18.conv2_x", 'a', 64.0, 2, 7),
        ("resnet18.conv2_x", 'b', 256.0, 6, 7),
        ("resnet18.conv3_x", 'a', 64.0, 2, 4),
        ("resnet18.conv3_x", 'b', 256.0, 6, 4),
        ("resnet18.conv4_x", 'a', 64.0, 2, 6),
        ("resnet18.conv4_x", 'b', 256.0, 6, 6),
        ("resnet18.conv5_x", 'a', 64.0, 2, 20),
        ("resnet18.conv5_x", 'b', 128.0, 6, 20),
        ("mobilenet.conv_dw_pw_2_x", 'a', 52.0, 2, 4),
        ("mobilenet.conv_dw_pw_2_x", 'b', 208.0, 5, 4),
        ("mobilenet.conv_dw_pw_3_x", 'a', 52.0, 2, 4),
        ("mobilenet.conv_dw_pw_3_x", 'b', 104.0, 3, 4),
        ("mobilenet.conv_dw_pw_4_x", 'a', 52.0, 2, 4),
        ("mobilenet.conv_dw_pw_4_x", 'b', 104.0, 3, 4),
        ("camera.pipeline", 'a', 3.0, 4, 4),
        ("camera.pipeline", 'b', 12.0, 6, 14),
        ("harris.corner", 'a', 1.0, 2, 4),
        ("harris.corner", 'b', 2.0, 4, 7),
        ("harris.corner", 'c', 4.0, 7, 14),
    ];
    for &(task, ver, tpt, array, glb) in rows {
        let t = lib.get(&TaskId::new(task)).unwrap();
        let v = t.variant(VariantId(ver)).unwrap();
        assert_eq!(v.throughput, tpt, "{task}:{ver} throughput");
        assert_eq!(v.demand.array_slices, array, "{task}:{ver} array slices");
        assert_eq!(v.demand.glb_slices, glb, "{task}:{ver} glb slices");
    }
}

// ---------------------------------------------------------------- Fig. 4

#[test]
fn fig4_flexible_beats_baseline_on_every_app_ntat() {
    for seed in [11u64, 23] {
        let base = run_cloud(&cloud_cfg(RegionPolicyKind::Baseline, seed)).unwrap();
        let flex = run_cloud(&cloud_cfg(RegionPolicyKind::FlexibleShape, seed)).unwrap();
        let bn = base.ntat.mean_ntat();
        let fx = flex.ntat.mean_ntat();
        for app in AppId::ALL {
            assert!(
                fx[&app] < bn[&app],
                "seed {seed} {app}: flexible {} !< baseline {}",
                fx[&app],
                bn[&app]
            );
        }
    }
}

#[test]
fn fig4_mechanism_ordering_on_mean_ntat() {
    // baseline must be worst; flexible/variable must beat fixed.
    let seed = 11;
    let mean = |p| {
        run_cloud(&cloud_cfg(p, seed))
            .unwrap()
            .mean_ntat_across_apps()
    };
    let base = mean(RegionPolicyKind::Baseline);
    let fixed = mean(RegionPolicyKind::FixedSize);
    let variable = mean(RegionPolicyKind::VariableSize);
    let flexible = mean(RegionPolicyKind::FlexibleShape);
    assert!(fixed < base, "fixed {fixed} !< baseline {base}");
    assert!(variable < fixed, "variable {variable} !< fixed {fixed}");
    assert!(flexible < fixed, "flexible {flexible} !< fixed {fixed}");
}

#[test]
fn fig4_ntat_reduction_in_papers_band_or_better() {
    // paper: flexible reduces NTAT 23–28 % vs baseline.  Accept anything
    // from 15 % to 90 % — the shape claim is "tens of percent".
    let base = run_cloud(&cloud_cfg(RegionPolicyKind::Baseline, 11)).unwrap();
    let flex = run_cloud(&cloud_cfg(RegionPolicyKind::FlexibleShape, 11)).unwrap();
    let ratio = flex.mean_ntat_across_apps() / base.mean_ntat_across_apps();
    assert!(
        (0.10..=0.85).contains(&ratio),
        "flexible/baseline NTAT ratio {ratio} outside plausible band"
    );
}

#[test]
fn fig4_throughput_gain_for_most_apps() {
    // paper: 1.05x–1.24x per app.  Require: majority of apps gain, none
    // lose more than 15 %.
    let base = run_cloud(&cloud_cfg(RegionPolicyKind::Baseline, 11)).unwrap();
    let flex = run_cloud(&cloud_cfg(RegionPolicyKind::FlexibleShape, 11)).unwrap();
    let bt = base.throughput.service_throughput();
    let ft = flex.throughput.service_throughput();
    let ratios: Vec<f64> = AppId::ALL.iter().map(|a| ft[a] / bt[a]).collect();
    let gains = ratios.iter().filter(|&&r| r > 1.0).count();
    assert!(gains >= 2, "only {gains} apps gained: {ratios:?}");
    assert!(ratios.iter().all(|&r| r > 0.85), "{ratios:?}");
}

#[test]
fn fig4_utilization_of_packing_mechanisms_is_real() {
    let flex = run_cloud(&cloud_cfg(RegionPolicyKind::FlexibleShape, 11)).unwrap();
    // flexible packs multiple tasks: utilization strictly between 0 and 1,
    // and the machine finishes the same work sooner than the baseline.
    let base = run_cloud(&cloud_cfg(RegionPolicyKind::Baseline, 11)).unwrap();
    assert!(flex.array_utilization > 0.10);
    assert!(flex.makespan_cycles <= base.makespan_cycles);
}

// ---------------------------------------------------------------- Fig. 5

#[test]
fn fig5_headline_latency_reduction() {
    // paper: 60.8 % reduction.  Require > 35 % on every seed tested.
    for seed in [5u64, 17] {
        let base = run_edge(&edge_cfg(RegionPolicyKind::Baseline, seed)).unwrap();
        let flex = run_edge(&edge_cfg(RegionPolicyKind::FlexibleShape, seed)).unwrap();
        let reduction = 1.0 - flex.latency.mean_total() / base.latency.mean_total();
        assert!(
            reduction > 0.35,
            "seed {seed}: latency reduction only {:.1}%",
            reduction * 100.0
        );
    }
}

#[test]
fn fig5_reconfig_share_bands() {
    // paper: baseline 14.4 %, fast-DPR <5 %.
    let base = run_edge(&edge_cfg(RegionPolicyKind::Baseline, 5)).unwrap();
    let flex = run_edge(&edge_cfg(RegionPolicyKind::FlexibleShape, 5)).unwrap();
    let base_share = base.latency.reconfig_share();
    let flex_share = flex.latency.reconfig_share();
    assert!(
        (0.05..=0.35).contains(&base_share),
        "baseline reconfig share {base_share} not in double digits"
    );
    assert!(flex_share < 0.05, "fast-DPR share {flex_share} >= 5%");
}

#[test]
fn fig5_every_mechanism_meets_frame_deadline_mostly() {
    // 30 fps gives 33.3 ms; even the baseline's mean must be far below
    // (the scenario would otherwise diverge and the paper's averages
    // would be meaningless).
    for policy in RegionPolicyKind::ALL {
        let r = run_edge(&edge_cfg(policy, 5)).unwrap();
        let mean_ms = r.mean_latency_ms(500);
        assert!(mean_ms < 33.3, "{policy:?} mean {mean_ms} ms blows the frame budget");
    }
}
