//! Differential protocol conformance: the same scripted session runs
//! against (a) the threaded front speaking text, (b) the reactor front
//! speaking text, and (c) the reactor front speaking binary framing —
//! each on a fresh server with an identical config — and every reply
//! must be byte-identical across all three arms (modulo the one
//! wall-clock field, `compute_us=`, which is masked).  The script ends
//! on the full STATS report surface, so the three arms also prove
//! identical final server state, not just identical reply formatting.
//!
//! Everything here is strictly sequential (one request in flight at a
//! time, one arm at a time), which is what makes seq numbers, virtual
//! time, and checksums deterministic across arms.
#![cfg(not(feature = "xla"))]

use std::sync::Mutex;

use cgra_mte::config::{presets, Config, ServerModeKind};
use cgra_mte::coordinator::frame::Opcode;
use cgra_mte::coordinator::Server;
use cgra_mte::testutil::wire::{BinWireClient, WireClient};

/// Serializes against the other loopback server suites.
static SERIAL: Mutex<()> = Mutex::new(());

fn stub_config(mode: ServerModeKind) -> Config {
    let mut cfg = presets::paper_default();
    cfg.artifacts_dir = cgra_mte::runtime::SYNTHETIC_DIR.into();
    cfg.server.mode = mode;
    cfg
}

/// One scripted step, expressible in both wire encodings.
enum Step {
    /// SUBMIT: tenant plus the argument tail (`<app> [class] [deadline]`).
    Submit { tenant: u32, args: &'static str },
    /// STATS with a subcommand (`""` for the aggregate line).
    Stats(&'static str),
    /// EXPLAIN with the decimal request sequence number.
    Explain(&'static str),
    Watch,
    Dump,
    Defrag,
    Quit,
}

/// The conformance script.  Covers every request verb, every STATS
/// surface, every SUBMIT parse error, and ends on the full report
/// digest (aggregate + SHARDS + ENERGY + QOS) so final server state is
/// compared too.  No BUSY is possible: one request in flight against
/// the default queue depth.
const SCRIPT: &[Step] = &[
    Step::Submit { tenant: 0, args: "resnet18" },
    Step::Submit { tenant: 1, args: "mobilenet" },
    Step::Submit { tenant: 2, args: "camera critical 60000" },
    Step::Submit { tenant: 3, args: "harris best-effort" },
    Step::Submit { tenant: 1, args: "pipeline" },
    Step::Submit { tenant: 9, args: "camera" },
    Step::Submit { tenant: 0, args: "nosuchapp" },
    Step::Submit { tenant: 0, args: "camera wrongclass" },
    Step::Submit { tenant: 0, args: "camera critical soon" },
    Step::Stats("2"),
    Step::Stats("NOC"),
    Step::Defrag,
    Step::Stats(""),
    Step::Stats("SHARDS"),
    Step::Stats("ENERGY"),
    Step::Stats("QOS"),
    // obs is disabled in this config: all three observability verbs
    // must refuse identically on every arm
    Step::Explain("0"),
    Step::Watch,
    Step::Dump,
    Step::Quit,
];

/// Mask the single wall-clock field so transcripts compare stably.
fn mask(blob: &str) -> String {
    blob.lines()
        .map(|line| {
            line.split(' ')
                .map(|field| {
                    if field.starts_with("compute_us=") { "compute_us=X" } else { field }
                })
                .collect::<Vec<_>>()
                .join(" ")
        })
        .collect::<Vec<_>>()
        .join("\n")
}

/// Run the script over the text protocol; returns masked reply blobs.
fn run_text(mode: ServerModeKind) -> Vec<String> {
    let server = Server::start(&stub_config(mode), "127.0.0.1:0").unwrap();
    let mut client = WireClient::connect(server.addr).expect("connect");
    let mut transcript = Vec::new();
    for step in SCRIPT {
        let line = match step {
            Step::Submit { tenant, args } => format!("SUBMIT {tenant} {args}"),
            Step::Stats("") => "STATS".to_string(),
            Step::Stats(sub) => format!("STATS {sub}"),
            Step::Explain(req) => format!("EXPLAIN {req}"),
            Step::Watch => "WATCH".to_string(),
            Step::Dump => "DUMP".to_string(),
            Step::Defrag => "DEFRAG".to_string(),
            Step::Quit => "QUIT".to_string(),
        };
        transcript.push(mask(&client.send_blob(&line).expect("reply")));
    }
    server.shutdown();
    transcript
}

/// Run the script over the binary framing (reactor only); returns
/// masked reply payloads, asserting the framing invariants (reply
/// opcode mirrors the text token, request ids echo back) as it goes.
fn run_binary() -> Vec<String> {
    let server =
        Server::start(&stub_config(ServerModeKind::Reactor), "127.0.0.1:0").unwrap();
    let mut client = BinWireClient::connect(server.addr).expect("connect");
    let mut transcript = Vec::new();
    let mut expected_req_id = 0u64;
    for step in SCRIPT {
        let (opcode, tenant, payload): (Opcode, u16, &str) = match step {
            Step::Submit { tenant, args } => (Opcode::Submit, *tenant as u16, *args),
            Step::Stats(sub) => (Opcode::Stats, 0, *sub),
            Step::Explain(req) => (Opcode::Explain, 0, *req),
            Step::Watch => (Opcode::Watch, 0, ""),
            Step::Dump => (Opcode::Dump, 0, ""),
            Step::Defrag => (Opcode::Defrag, 0, ""),
            Step::Quit => (Opcode::Quit, 0, ""),
        };
        let reply = client.request(opcode, tenant, payload.as_bytes()).expect("reply");
        expected_req_id += 1;
        assert_eq!(reply.req_id, expected_req_id, "req_id echo");
        assert_eq!(
            reply.opcode,
            Opcode::for_reply_line(&reply.text),
            "reply opcode must mirror the text reply token: {}",
            reply.text
        );
        transcript.push(mask(&reply.text));
    }
    server.shutdown();
    transcript
}

#[test]
fn text_and_binary_protocols_are_byte_identical_across_fronts() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let threaded = run_text(ServerModeKind::Threaded);
    let reactor_text = run_text(ServerModeKind::Reactor);
    let reactor_binary = run_binary();

    assert_eq!(threaded.len(), SCRIPT.len());
    for (i, ((a, b), c)) in
        threaded.iter().zip(&reactor_text).zip(&reactor_binary).enumerate()
    {
        assert_eq!(a, b, "step {i}: threaded-text vs reactor-text");
        assert_eq!(a, c, "step {i}: threaded-text vs reactor-binary");
    }

    // the masked OK lines still carry the deterministic fields
    assert!(threaded[0].starts_with("OK seq=0 ntat="), "{}", threaded[0]);
    assert!(threaded[0].contains("compute_us=X"), "{}", threaded[0]);
    // parse errors surfaced identically
    assert_eq!(threaded[5], "ERR bad tenant (0-3)");
    assert_eq!(threaded[6], "ERR bad app (resnet18|mobilenet|camera|harris|pipeline)");
    // the digest steps really were multi-line report surfaces
    assert!(threaded[13].starts_with("STATS shards="), "{}", threaded[13]);
    assert!(threaded[13].lines().count() >= 2, "{}", threaded[13]);
    assert!(threaded[15].starts_with("STATS classes="), "{}", threaded[15]);
    // obs verbs refuse while [obs] is disabled
    for (i, reply) in threaded.iter().enumerate().take(19).skip(16) {
        assert_eq!(reply, "ERR obs disabled", "step {i}");
    }
    assert_eq!(threaded[19], "BYE");
}

/// Config with the second observability layer armed (journal +
/// provenance; watchdog stays off so no background alerts perturb the
/// scripted comparison).
fn obs_config(mode: ServerModeKind) -> Config {
    let mut cfg = stub_config(mode);
    cfg.obs.enabled = true;
    cfg.obs.provenance = true;
    cfg
}

/// What one arm observed over the obs verbs: compared field-by-field
/// across the three arms.  The flight record carries one wall-clock
/// field (`at`, milliseconds since server start), so DUMP is compared
/// by validated shape, not bytes.
struct ObsProbe {
    explain: String,
    events: Vec<String>,
    trailer: String,
    dump_reason: String,
    dump_version: u64,
    metrics_header: String,
}

fn probe_dump(json_line: &str) -> (String, u64) {
    let doc = cgra_mte::util::json::Json::parse(json_line).expect("flight record parses");
    let summary = cgra_mte::obs::validate_flight_record(&doc).expect("flight record validates");
    (summary.reason, summary.version)
}

/// Drive the obs verbs over the text protocol on one front.  A second
/// connection subscribes via WATCH *before* the submission that
/// generates events, so the streamed sequence is deterministic: every
/// journal write for a submission lands before its OK reply is
/// delivered.
fn run_obs_text(mode: ServerModeKind) -> ObsProbe {
    let server = Server::start(&obs_config(mode), "127.0.0.1:0").unwrap();
    let mut a = WireClient::connect(server.addr).expect("connect");
    let (ok, _) = a.submit(0, "resnet18").expect("submit");
    assert!(ok.starts_with("OK seq=0"), "{ok}");
    let (header, lines) = a.explain(0).expect("explain");
    assert!(header.starts_with("EXPLAIN req=0 lines="), "{header}");
    let explain = format!("{header}\n{}", lines.join("\n"));

    let mut b = WireClient::connect(server.addr).expect("connect watcher");
    b.watch_subscribe().expect("subscribe");
    let (ok, _) = a.submit(1, "mobilenet").expect("submit under watch");
    assert!(ok.starts_with("OK seq=1"), "{ok}");
    let (events, trailer) = b.watch_finish(1).expect("watch finish");

    let (dump_reason, dump_version) = probe_dump(&a.dump().expect("dump"));
    let (metrics_header, _) = a.metrics_full().expect("metrics");
    a.send("QUIT").expect("quit");
    server.shutdown();
    ObsProbe { explain, events, trailer, dump_reason, dump_version, metrics_header }
}

/// Same probe over binary framing (reactor only).
fn run_obs_binary() -> ObsProbe {
    let server =
        Server::start(&obs_config(ServerModeKind::Reactor), "127.0.0.1:0").unwrap();
    let mut a = BinWireClient::connect(server.addr).expect("connect");
    let (ok, _) = a.submit(0, "resnet18").expect("submit");
    assert!(ok.text.starts_with("OK seq=0"), "{}", ok.text);
    let reply = a.explain(0).expect("explain");
    assert_eq!(reply.opcode, Opcode::ReplyExplain, "{}", reply.text);
    let explain = reply.text;

    let mut b = BinWireClient::connect(server.addr).expect("connect watcher");
    b.watch_subscribe().expect("subscribe");
    let (ok, _) = a.submit(1, "mobilenet").expect("submit under watch");
    assert!(ok.text.starts_with("OK seq=1"), "{}", ok.text);
    let (event_frames, trailer_frame) = b.watch_finish(1).expect("watch finish");
    for f in &event_frames {
        assert_eq!(f.opcode, Opcode::ReplyEvent, "{}", f.text);
        assert_eq!(f.req_id, 0, "events are not replies to any request");
    }
    assert_eq!(trailer_frame.opcode, Opcode::ReplyWatch, "{}", trailer_frame.text);

    let dump = a.dump().expect("dump");
    assert_eq!(dump.opcode, Opcode::ReplyDump, "{}", dump.text);
    let (header, json_line) = dump.text.split_once('\n').expect("DUMP framing");
    assert_eq!(header, "DUMP lines=1");
    let (dump_reason, dump_version) = probe_dump(json_line);
    a.quit().expect("quit");
    server.shutdown();
    ObsProbe {
        explain,
        events: event_frames.into_iter().map(|f| f.text).collect(),
        trailer: trailer_frame.text,
        dump_reason,
        dump_version,
        // the binary client has no METRICS opcode (text-only verb);
        // reuse the text header shape from a text probe instead
        metrics_header: String::new(),
    }
}

#[test]
fn obs_verbs_agree_across_fronts() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let threaded = run_obs_text(ServerModeKind::Threaded);
    let reactor_text = run_obs_text(ServerModeKind::Reactor);
    let reactor_binary = run_obs_binary();

    // EXPLAIN chains are byte-identical (virtual-time journal +
    // provenance lines only)
    assert_eq!(threaded.explain, reactor_text.explain, "explain: threaded vs reactor-text");
    // binary EXPLAIN payload carries the same multi-line blob
    assert_eq!(threaded.explain, reactor_binary.explain, "explain: threaded vs reactor-binary");
    assert!(threaded.explain.contains("completed"), "{}", threaded.explain);
    assert!(threaded.explain.contains("req=0"), "{}", threaded.explain);

    // WATCH streamed the same event sequence on every arm
    assert!(!threaded.events.is_empty());
    assert_eq!(threaded.events, reactor_text.events, "events: threaded vs reactor-text");
    assert_eq!(threaded.events, reactor_binary.events, "events: threaded vs reactor-binary");
    assert!(threaded.events.iter().all(|e| e.starts_with("EVENT ")), "{:?}", threaded.events);
    assert!(
        threaded.events.iter().any(|e| e.contains("req=1")),
        "the watched submission's events are in the stream: {:?}",
        threaded.events
    );
    // nothing dropped at this rate, and delivery counts agree
    assert_eq!(threaded.trailer, reactor_text.trailer, "{}", threaded.trailer);
    assert_eq!(threaded.trailer, reactor_binary.trailer, "{}", threaded.trailer);
    assert!(threaded.trailer.ends_with("dropped=0"), "{}", threaded.trailer);

    // DUMP produced a valid flight record everywhere
    for p in [&threaded, &reactor_text, &reactor_binary] {
        assert_eq!(p.dump_reason, "verb:DUMP");
        assert_eq!(p.dump_version, threaded.dump_version);
    }

    // METRICS header carries the journal-drop count
    assert!(threaded.metrics_header.ends_with("dropped=0"), "{}", threaded.metrics_header);
    assert_eq!(threaded.metrics_header, reactor_text.metrics_header);
}

/// Text-only session shapes (unknown verbs, empty lines) have no frame
/// encoding; the two text fronts must still agree on them byte for
/// byte.
#[test]
fn text_only_error_shapes_match_across_fronts() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let mut transcripts = Vec::new();
    for mode in [ServerModeKind::Threaded, ServerModeKind::Reactor] {
        let server = Server::start(&stub_config(mode), "127.0.0.1:0").unwrap();
        let mut client = WireClient::connect(server.addr).expect("connect");
        let mut t = Vec::new();
        for line in ["FROB 1 camera", "", "   ", "submit 0 camera", "QUIT"] {
            t.push(client.send(line).expect("reply"));
        }
        server.shutdown();
        transcripts.push(t);
    }
    assert_eq!(transcripts[0][0], "ERR unknown command 'FROB'");
    assert_eq!(transcripts[0][1], "ERR empty command");
    assert_eq!(transcripts[0][2], "ERR empty command");
    // verbs are case-insensitive on both fronts
    assert!(transcripts[0][3].starts_with("OK seq="), "{}", transcripts[0][3]);
    assert_eq!(transcripts[0][4], "BYE");
    let masked: Vec<Vec<String>> =
        transcripts.iter().map(|t| t.iter().map(|b| mask(b)).collect()).collect();
    assert_eq!(masked[0], masked[1]);
}
