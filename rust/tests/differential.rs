//! Differential-equivalence harness: the hot-path overhaul (binary-heap
//! event queue, incremental free-run index, reusable fit-probe scratch,
//! single-pass completion draining) must not change a single observable
//! byte of any simulation.
//!
//! Every preset scenario plus 24 randomized seeded configurations runs
//! under the full traced pipeline; the event trace and the `{:?}` report
//! rendering are digested (FNV-1a 64) and compared against the
//! checked-in goldens in `tests/goldens/differential.txt`.  Each
//! scenario additionally runs twice in-process and must be
//! byte-identical with itself — the same-seed contract that holds with
//! or without goldens.
//!
//! Goldens bootstrap: when the goldens file does not exist yet, the
//! harness writes it and passes — from then on any behavioural drift
//! fails the suite.  `UPDATE_GOLDENS=1 cargo test --test differential`
//! regenerates it after an *intended* observable change (review the diff
//! of the goldens file like code).
//!
//! FairShare scheduling is deliberately absent here: PR 6 fixed its
//! hard-coded 4-tenant rotation modulus (now derived from the live
//! tenant span), an intended behavioural change whose new ordering is
//! pinned by `scheduler/core.rs` unit tests instead.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::fs;
use std::path::PathBuf;

use cgra_mte::config::{
    presets, Config, DefragPolicyKind, PlacementPolicyKind, RegionPolicyKind,
    SchedulerPolicyKind, WorkloadConfig,
};
use cgra_mte::sim::{
    run_cloud_pool_traced, run_cloud_traced, run_edge_pool_traced, run_edge_traced, Trace,
};
use cgra_mte::tasks::TaskLibrary;

/// FNV-1a 64-bit — tiny, dependency-free, stable across platforms.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Which traced runner drives a scenario.
#[derive(Clone, Copy)]
enum Runner {
    Cloud,
    CloudPool,
    Edge,
    EdgePool,
}

struct Case {
    name: String,
    digest: u64,
    events: usize,
}

/// Raw cycle-stamped trace lines — byte-exact, no ms rounding.
fn render(trace: &Trace) -> String {
    trace.events().map(|e| format!("{} {}\n", e.at, e.what())).collect()
}

/// Run `cfg` under `runner` with a fresh trace; return (trace, report).
fn run_once(cfg: &Config, runner: Runner) -> (String, String) {
    let mut t = Trace::new(1 << 20);
    let report = match runner {
        Runner::Cloud => {
            format!("{:?}", run_cloud_traced(cfg, TaskLibrary::table1(), &mut t).unwrap())
        }
        Runner::CloudPool => {
            format!("{:?}", run_cloud_pool_traced(cfg, TaskLibrary::table1(), &mut t).unwrap())
        }
        Runner::Edge => {
            format!("{:?}", run_edge_traced(cfg, TaskLibrary::table1(), &mut t).unwrap())
        }
        Runner::EdgePool => {
            format!("{:?}", run_edge_pool_traced(cfg, TaskLibrary::table1(), &mut t).unwrap())
        }
    };
    (render(&t), report)
}

/// Run twice, assert in-process byte-identity, digest the first run.
fn run_case(name: &str, cfg: &Config, runner: Runner) -> Case {
    let (trace1, report1) = run_once(cfg, runner);
    let (trace2, report2) = run_once(cfg, runner);
    assert_eq!(trace1, trace2, "{name}: same-seed traces diverged in-process");
    assert_eq!(report1, report2, "{name}: same-seed reports diverged in-process");
    assert!(!trace1.is_empty(), "{name}: trace must not be empty");
    let events = trace1.lines().count();
    let mut blob = trace1;
    blob.push('\u{1e}'); // record separator between trace and report
    blob.push_str(&report1);
    Case { name: name.to_string(), digest: fnv1a(blob.as_bytes()), events }
}

fn short_cloud(cfg: &mut Config, duration_ms: f64) {
    if let WorkloadConfig::Cloud(ref mut c) = cfg.workload {
        c.duration_ms = duration_ms;
    }
}

fn reseed_cloud(cfg: &mut Config, seed: u64) {
    if let WorkloadConfig::Cloud(ref mut c) = cfg.workload {
        c.seed = seed;
    }
}

fn short_edge(cfg: &mut Config, frames: u32) {
    if let WorkloadConfig::Edge(ref mut e) = cfg.workload {
        e.frames = frames;
    }
}

fn reseed_edge(cfg: &mut Config, seed: u64) {
    if let WorkloadConfig::Edge(ref mut e) = cfg.workload {
        e.seed = seed;
    }
}

/// All fixed preset scenarios (FairShare excluded, see module docs).
fn preset_cases() -> Vec<Case> {
    let mut cases = Vec::new();

    for policy in RegionPolicyKind::ALL {
        let mut cfg = presets::cloud_scenario(policy);
        short_cloud(&mut cfg, 400.0);
        cases.push(run_case(&format!("cloud/{policy:?}"), &cfg, Runner::Cloud));
    }
    for sched in [SchedulerPolicyKind::FcfsFirstFit, SchedulerPolicyKind::ShortestJobFirst] {
        let mut cfg = presets::cloud_scenario(RegionPolicyKind::FlexibleShape);
        cfg.scheduler.policy = sched;
        short_cloud(&mut cfg, 400.0);
        cases.push(run_case(&format!("cloud/{sched:?}"), &cfg, Runner::Cloud));
    }
    for defrag in DefragPolicyKind::ALL {
        let mut cfg = presets::churn_scenario(RegionPolicyKind::FlexibleShape, defrag);
        short_cloud(&mut cfg, 800.0);
        cases.push(run_case(&format!("churn/{defrag:?}"), &cfg, Runner::Cloud));
    }

    let mut edge = presets::edge_scenario(RegionPolicyKind::FlexibleShape);
    short_edge(&mut edge, 150);
    cases.push(run_case("edge/FlexibleShape", &edge, Runner::Edge));
    let mut edge_churn =
        presets::edge_churn_scenario(RegionPolicyKind::FlexibleShape, DefragPolicyKind::Greedy);
    short_edge(&mut edge_churn, 150);
    cases.push(run_case("edge/churn-Greedy", &edge_churn, Runner::Edge));

    let mut energy = presets::energy_scenario();
    short_cloud(&mut energy, 400.0);
    cases.push(run_case("energy/accounting", &energy, Runner::Cloud));
    let mut capped = presets::energy_cap_scenario(2.5);
    short_cloud(&mut capped, 400.0);
    cases.push(run_case("energy/cap-2.5w", &capped, Runner::Cloud));

    for preemptive in [true, false] {
        let mut cfg = presets::mixed_criticality_scenario(preemptive);
        short_cloud(&mut cfg, 600.0);
        let tag = if preemptive { "edf" } else { "fifo" };
        cases.push(run_case(&format!("qos/{tag}"), &cfg, Runner::Cloud));
    }

    let mut one = presets::pool_scenario(1, PlacementPolicyKind::LeastLoaded);
    short_cloud(&mut one, 400.0);
    cases.push(run_case("pool/1-shard", &one, Runner::CloudPool));
    for placement in PlacementPolicyKind::ALL {
        let mut cfg = presets::pool_scenario(2, placement);
        short_cloud(&mut cfg, 400.0);
        cases.push(run_case(&format!("pool/2-{placement:?}"), &cfg, Runner::CloudPool));
    }
    let mut epool = presets::energy_pool_scenario(2, PlacementPolicyKind::LeastLoaded);
    short_cloud(&mut epool, 400.0);
    cases.push(run_case("pool/2-energy", &epool, Runner::CloudPool));
    let mut edge_pool = presets::edge_scenario(RegionPolicyKind::FlexibleShape);
    edge_pool.pool.shards = 2;
    short_edge(&mut edge_pool, 120);
    cases.push(run_case("pool/edge-2", &edge_pool, Runner::EdgePool));

    cases
}

/// Deterministic splitmix64 over the trace index — no ambient entropy,
/// so the randomized fleet is identical on every run of the harness.
struct Mix(u64);

impl Mix {
    fn new(i: u64) -> Self {
        Mix(0x9e3779b97f4a7c15u64.wrapping_mul(i.wrapping_add(1)))
    }

    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9e3779b97f4a7c15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }

    fn pick(&mut self, n: u64) -> u64 {
        self.next() % n
    }
}

/// 24 randomized seeded configurations spanning every runner family,
/// region mechanism, (non-FairShare) scheduler policy and defrag knob.
fn randomized_cases() -> Vec<Case> {
    let scheds = [
        SchedulerPolicyKind::GreedyThroughput,
        SchedulerPolicyKind::FcfsFirstFit,
        SchedulerPolicyKind::ShortestJobFirst,
    ];
    let mut cases = Vec::new();
    for i in 0..24u64 {
        let mut mx = Mix::new(i);
        let seed = mx.next() | 1;
        let case = match i % 4 {
            0 => {
                let region = RegionPolicyKind::ALL[mx.pick(4) as usize];
                let sched = scheds[mx.pick(3) as usize];
                let mut cfg = presets::cloud_scenario(region);
                cfg.scheduler.policy = sched;
                short_cloud(&mut cfg, 200.0 + mx.pick(4) as f64 * 100.0);
                reseed_cloud(&mut cfg, seed);
                run_case(&format!("rand/{i:02}-cloud"), &cfg, Runner::Cloud)
            }
            1 => {
                let defrag = DefragPolicyKind::ALL[mx.pick(3) as usize];
                let mut cfg =
                    presets::churn_scenario(RegionPolicyKind::FlexibleShape, defrag);
                short_cloud(&mut cfg, 400.0 + mx.pick(3) as f64 * 200.0);
                reseed_cloud(&mut cfg, seed);
                run_case(&format!("rand/{i:02}-churn"), &cfg, Runner::Cloud)
            }
            2 => {
                let placement = PlacementPolicyKind::ALL[mx.pick(4) as usize];
                let shards = 1 + mx.pick(3) as u32;
                let mut cfg = presets::pool_scenario(shards, placement);
                short_cloud(&mut cfg, 200.0 + mx.pick(3) as f64 * 100.0);
                reseed_cloud(&mut cfg, seed);
                run_case(&format!("rand/{i:02}-pool"), &cfg, Runner::CloudPool)
            }
            _ => {
                let region = RegionPolicyKind::ALL[mx.pick(4) as usize];
                let mut cfg = presets::edge_scenario(region);
                short_edge(&mut cfg, 80 + mx.pick(5) as u32 * 20);
                reseed_edge(&mut cfg, seed);
                run_case(&format!("rand/{i:02}-edge"), &cfg, Runner::Edge)
            }
        };
        cases.push(case);
    }
    cases
}

fn goldens_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/goldens/differential.txt")
}

fn render_goldens(cases: &[Case]) -> String {
    let mut out = String::new();
    for c in cases {
        writeln!(out, "{} {:016x} {}", c.name, c.digest, c.events).unwrap();
    }
    out
}

/// One test drives every scenario: a single writer for the goldens file
/// (test binaries run `#[test]` fns concurrently) and one canonical
/// ordering for its lines.
#[test]
fn all_scenarios_match_goldens() {
    let mut cases = preset_cases();
    cases.extend(randomized_cases());
    let rendered = render_goldens(&cases);
    let path = goldens_path();

    let update = std::env::var("UPDATE_GOLDENS").map_or(false, |v| v == "1");
    let previous = fs::read_to_string(&path).ok();
    match previous {
        Some(prev) if !update => {
            if prev == rendered {
                return;
            }
            // per-scenario diagnostics before failing
            let old: BTreeMap<&str, &str> = prev
                .lines()
                .filter_map(|l| l.split_once(' '))
                .collect();
            let mut msg = String::from("differential goldens mismatch:\n");
            for c in &cases {
                let line = format!("{:016x} {}", c.digest, c.events);
                match old.get(c.name.as_str()) {
                    None => writeln!(msg, "  {}: missing from goldens (new scenario?)", c.name)
                        .unwrap(),
                    Some(&prev_line) if prev_line != line => writeln!(
                        msg,
                        "  {}: trace/report diverged (golden {prev_line}, got {line})",
                        c.name
                    )
                    .unwrap(),
                    Some(_) => {}
                }
            }
            for name in old.keys() {
                if !cases.iter().any(|c| c.name == *name) {
                    writeln!(msg, "  {name}: golden has no matching scenario").unwrap();
                }
            }
            msg.push_str(
                "byte-identity broken — if the observable change is intended, regenerate \
                 with UPDATE_GOLDENS=1 and review the goldens diff",
            );
            panic!("{msg}");
        }
        _ => {
            // bootstrap (first run) or explicit regeneration
            fs::create_dir_all(path.parent().unwrap()).expect("create goldens dir");
            fs::write(&path, &rendered).expect("write goldens");
            eprintln!(
                "differential: {} goldens for {} scenarios at {}",
                if update { "regenerated" } else { "bootstrapped" },
                cases.len(),
                path.display()
            );
        }
    }
}
