//! Fuzz-ish property tests for the hand-rolled parsers (JSON + TOML):
//! they must never panic on arbitrary input, and must round-trip the
//! documents the system actually produces.

use cgra_mte::config::{Config, TomlValue};
use cgra_mte::testutil::{forall_cfg, PropConfig};
use cgra_mte::util::json::Json;
use cgra_mte::util::rng::Rng;

/// Random byte soup biased toward structural characters.
fn soup(rng: &mut Rng, size: u32) -> String {
    const ALPHABET: &[u8] = br#"{}[]",:0123456789.eE+-truefalsn _ab\"#;
    let len = rng.below(size as u64 * 8 + 1) as usize;
    (0..len)
        .map(|_| ALPHABET[rng.below(ALPHABET.len() as u64) as usize] as char)
        .collect()
}

/// Random *valid* JSON document generator (bounded depth).
fn valid_json(rng: &mut Rng, depth: u32) -> String {
    match if depth == 0 { rng.below(4) } else { rng.below(6) } {
        0 => "null".into(),
        1 => if rng.chance(0.5) { "true" } else { "false" }.into(),
        2 => format!("{}", rng.uniform(-1e6, 1e6)),
        3 => format!("\"s{}\"", rng.below(1000)),
        4 => {
            let n = rng.below(4);
            let items: Vec<String> = (0..n).map(|_| valid_json(rng, depth - 1)).collect();
            format!("[{}]", items.join(","))
        }
        _ => {
            let n = rng.below(4);
            let items: Vec<String> = (0..n)
                .map(|i| format!("\"k{i}\":{}", valid_json(rng, depth - 1)))
                .collect();
            format!("{{{}}}", items.join(","))
        }
    }
}

#[test]
fn json_never_panics_on_soup() {
    forall_cfg(
        PropConfig { cases: 300, seed: 0xF00D, max_size: 32 },
        &soup,
        |text| {
            // must return Ok or Err, never panic
            let _ = Json::parse(text);
            true
        },
    );
}

#[test]
fn json_accepts_and_round_trips_valid_documents() {
    forall_cfg(
        PropConfig { cases: 200, seed: 0xBEEF, max_size: 8 },
        &|rng: &mut Rng, _| valid_json(rng, 4),
        |doc| {
            let Ok(v) = Json::parse(doc) else { return false };
            // Display output must re-parse to the same value
            match Json::parse(&v.to_string()) {
                Ok(v2) => v == v2,
                Err(_) => false,
            }
        },
    );
}

#[test]
fn toml_never_panics_on_soup() {
    forall_cfg(
        PropConfig { cases: 300, seed: 0x70D1, max_size: 32 },
        &|rng: &mut Rng, size: u32| {
            // line-structured soup
            let lines = rng.below(size as u64 / 4 + 2);
            (0..lines)
                .map(|_| soup(rng, 8))
                .collect::<Vec<_>>()
                .join("\n")
        },
        |text| {
            let _ = TomlValue::parse(text);
            true
        },
    );
}

#[test]
fn config_parser_never_panics_on_toml_soup() {
    forall_cfg(
        PropConfig { cases: 150, seed: 0xC0FF, max_size: 24 },
        &|rng: &mut Rng, _| {
            // plausible-looking config fragments with random values
            let mut doc = String::new();
            if rng.chance(0.8) {
                doc.push_str("[arch]\n");
                doc.push_str(&format!("cols = {}\n", rng.below(100)));
                doc.push_str(&format!("glb_banks = {}\n", rng.below(100)));
                doc.push_str(&format!("slice_cols = {}\n", rng.below(20)));
            }
            if rng.chance(0.5) {
                doc.push_str("[scheduler]\n");
                doc.push_str(&format!("unit_glb_slices = {}\n", rng.below(40)));
            }
            if rng.chance(0.5) {
                doc.push_str("[workload]\nkind = \"cloud\"\n");
                doc.push_str(&format!("duration_ms = {}\n", rng.below(10_000)));
            }
            doc
        },
        |doc| {
            // parse either succeeds with a valid config or errors cleanly
            match Config::from_toml_text(doc) {
                Ok(cfg) => cfg.validate().is_ok(),
                Err(_) => true,
            }
        },
    );
}

#[test]
fn real_manifest_survives_json_parser() {
    // the actual build product, when present
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
    if let Ok(text) = std::fs::read_to_string(path) {
        let v = Json::parse(&text).expect("manifest parses");
        assert!(v.get("artifacts").is_some());
        let shown = v.to_string();
        assert_eq!(Json::parse(&shown).expect("round trip"), v);
    }
}
