//! Property tests on the scheduler + simulation: arbitrary workloads
//! must always drain, conserve resources, and produce sane metrics
//! under every mechanism.

use cgra_mte::config::{presets, RegionPolicyKind, WorkloadConfig};
use cgra_mte::dpr::DprMode;
use cgra_mte::scheduler::{RequestQueue, Scheduler};
use cgra_mte::sim::{run_cloud, run_edge};
use cgra_mte::tasks::{AppId, AppRequest, TaskLibrary};
use cgra_mte::testutil::{forall_cfg, PropConfig};
use cgra_mte::util::rng::Rng;

/// Random burst: (tenant, app index, arrival offset in ms).
fn burst(rng: &mut Rng, size: u32) -> Vec<(u32, usize, u64)> {
    let len = 1 + rng.below(size as u64 + 1) as usize;
    (0..len)
        .map(|_| {
            (
                rng.below(4) as u32,
                rng.below(4) as usize,
                rng.below(50),
            )
        })
        .collect()
}

/// Drive a scheduler manually over a random burst; every request must
/// finish, every region must be released, NTAT-style accounting must be
/// non-negative.
fn drain_burst(policy: RegionPolicyKind, burst: &[(u32, usize, u64)]) -> bool {
    let cfg = presets::cloud_scenario(policy);
    let mut sched = Scheduler::new(&cfg, TaskLibrary::table1(), DprMode::Fast);
    sched.preload_all();
    let mut queue = RequestQueue::new();

    // submit everything up front (worst-case contention)
    for (seq, &(tenant, app, at_ms)) in burst.iter().enumerate() {
        let arrival = at_ms * 500_000;
        queue.submit(AppRequest::new(seq as u64, tenant, AppId::ALL[app], arrival));
    }

    // event loop: launch, complete earliest, repeat
    let mut now = 0u64;
    let mut running: Vec<(u64, cgra_mte::regions::RegionId)> = Vec::new();
    let mut safety = 0u32;
    loop {
        safety += 1;
        if safety > 100_000 {
            return false; // livelock
        }
        for launch in sched.schedule(&mut queue, now) {
            if launch.finish < now {
                return false;
            }
            running.push((launch.finish, launch.region));
        }
        if running.is_empty() {
            break;
        }
        running.sort_by_key(|&(t, _)| std::cmp::Reverse(t));
        let (t, region) = running.pop().expect("non-empty");
        now = t;
        let inst = match sched.complete(region, now) {
            Ok(i) => i,
            Err(_) => return false,
        };
        if queue.mark_complete(inst, now).is_err() {
            return false;
        }
    }
    queue.open_requests() == 0
        && sched.regions().active_count() == 0
        && sched.running_count() == 0
}

#[test]
fn any_burst_drains_under_every_mechanism() {
    for policy in RegionPolicyKind::ALL {
        forall_cfg(
            PropConfig { cases: 24, seed: 0x5EED ^ policy as u64, max_size: 20 },
            &burst,
            |b| drain_burst(policy, b),
        );
    }
}

#[test]
fn cloud_sim_drains_across_seeds_and_loads() {
    forall_cfg(
        PropConfig { cases: 12, seed: 99, max_size: 16 },
        &|rng: &mut Rng, size: u32| {
            (
                rng.next_u64(),
                20.0 + rng.uniform(0.0, 80.0),
                200.0 + size as f64 * 50.0,
            )
        },
        |&(seed, base_rate, duration)| {
            for policy in [RegionPolicyKind::Baseline, RegionPolicyKind::FlexibleShape] {
                let mut cfg = presets::cloud_scenario(policy);
                if let WorkloadConfig::Cloud(ref mut c) = cfg.workload {
                    c.seed = seed;
                    c.duration_ms = duration;
                    c.mean_interarrival_ms =
                        [base_rate * 1.5, base_rate, base_rate, base_rate * 1.2];
                }
                let Ok(report) = run_cloud(&cfg) else { return false };
                if report.submitted != report.completed {
                    return false;
                }
                // NTAT ≥ 1 for every request by construction
                if report.ntat.records().iter().any(|r| r.ntat() < 1.0 - 1e-9) {
                    return false;
                }
                // utilizations are fractions
                if !(0.0..=1.0).contains(&report.glb_utilization)
                    || !(0.0..=1.0).contains(&report.array_utilization)
                {
                    return false;
                }
            }
            true
        },
    );
}

#[test]
fn edge_sim_records_every_frame_across_seeds() {
    forall_cfg(
        PropConfig { cases: 10, seed: 4242, max_size: 12 },
        &|rng: &mut Rng, size: u32| (rng.next_u64(), 60 + size * 10),
        |&(seed, frames)| {
            for policy in [RegionPolicyKind::Baseline, RegionPolicyKind::FlexibleShape] {
                let mut cfg = presets::edge_scenario(policy);
                if let WorkloadConfig::Edge(ref mut e) = cfg.workload {
                    e.seed = seed;
                    e.frames = frames;
                }
                let Ok(report) = run_edge(&cfg) else { return false };
                if report.latency.len() as u32 != frames {
                    return false;
                }
                if report.latency.mean_total() <= 0.0 {
                    return false;
                }
            }
            true
        },
    );
}

#[test]
fn simulation_is_deterministic_per_seed() {
    forall_cfg(
        PropConfig { cases: 6, seed: 31337, max_size: 8 },
        &|rng: &mut Rng, _| rng.next_u64(),
        |&seed| {
            let mk = || {
                let mut cfg = presets::cloud_scenario(RegionPolicyKind::FlexibleShape);
                if let WorkloadConfig::Cloud(ref mut c) = cfg.workload {
                    c.seed = seed;
                    c.duration_ms = 400.0;
                }
                run_cloud(&cfg).expect("runs")
            };
            let a = mk();
            let b = mk();
            a.submitted == b.submitted
                && a.launches == b.launches
                && a.makespan_cycles == b.makespan_cycles
                && (a.mean_ntat_across_apps() - b.mean_ntat_across_apps()).abs() < 1e-12
        },
    );
}
