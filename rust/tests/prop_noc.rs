//! NoC subsystem invariants:
//!
//! 1. **Corridor conservation** — under arbitrary interleaved
//!    occupy/release traffic, no corridor ever grants more tracks than
//!    it physically has, and the incrementally maintained totals match
//!    the live span multiset exactly.
//! 2. **Alloc/release lockstep** — the allocator keeps the corridor map
//!    in lockstep with the slice maps under every region mechanism:
//!    each live region's span is charged while it runs, and releasing
//!    every region restores an all-idle corridor map.
//! 3. **Master switch** — with `[noc].enabled = false`, configured
//!    placement/affinity/fraction knobs change nothing: traces and
//!    reports are byte-identical to the plain preset and no NoC report
//!    surfaces.
//! 4. **Pipeline preset engages the subsystem** — the ablation scenario
//!    actually places streams, the oblivious arm actually pays
//!    contention, and the offered load drains fully either way.

use cgra_mte::abstraction::{CorridorMap, CorridorSpan, SliceDemand, SliceRange};
use cgra_mte::config::{
    presets, ArchConfig, NocPlacementKind, RegionPolicyKind, SchedulerConfig, WorkloadConfig,
};
use cgra_mte::regions::{AllocOutcome, ExecutionRegion, RegionManager};
use cgra_mte::sim::{run_cloud, run_cloud_traced, Trace};
use cgra_mte::tasks::TaskLibrary;
use cgra_mte::testutil::{forall_cfg, PropConfig};
use cgra_mte::util::rng::Rng;

/// A random traffic sequence over the paper geometry's 8 corridors:
/// (start, len, tracks, release-probability) tuples.
fn span_seq(rng: &mut Rng, size: u32) -> Vec<(u32, u32, u32, bool)> {
    let len = 4 + rng.below(size as u64 * 2 + 1) as usize;
    (0..len)
        .map(|_| {
            let start = rng.below(8) as u32;
            let span_len = rng.range_inclusive(1, (8 - start) as u64) as u32;
            let tracks = rng.range_inclusive(1, 12) as u32;
            (start, span_len, tracks, rng.chance(0.4))
        })
        .collect()
}

#[test]
fn grants_never_exceed_capacity_and_totals_stay_exact() {
    let cfg = PropConfig { cases: 64, seed: 0xC0881D08, max_size: 24 };
    forall_cfg(cfg, &span_seq, |ops| {
        // paper geometry: 8 corridors, 5 tracks × 4 cols = 20 each
        let mut m = CorridorMap::new(8, 20);
        let mut live: Vec<CorridorSpan> = Vec::new();
        let mut rng = Rng::new(ops.len() as u64 + 1);
        for &(start, span_len, tracks, release) in ops {
            if release && !live.is_empty() {
                let idx = rng.below(live.len() as u64) as usize;
                m.release(&live.swap_remove(idx));
            } else {
                let s = CorridorSpan::new(SliceRange::new(start, span_len), tracks);
                m.occupy(&s);
                live.push(s);
            }
            // conservation: grants are capped by the physical wires, the
            // oversubscription factor never dips below parity
            for c in 0..m.corridors() {
                if m.granted(c) > m.capacity() || m.oversub(c) < 1.0 {
                    return false;
                }
            }
            // exactness: the incremental total equals the live multiset
            let expect: u64 = live.iter().map(|s| s.range.len as u64 * s.tracks as u64).sum();
            if m.total_demand() != expect {
                return false;
            }
        }
        for s in live.drain(..) {
            m.release(&s);
        }
        m.is_idle() && m.oversubscribed_count() == 0
    });
}

#[test]
fn allocator_keeps_the_corridor_map_in_lockstep() {
    for policy in RegionPolicyKind::ALL {
        for comm_aware in [false, true] {
            let arch = ArchConfig::default();
            let sched = SchedulerConfig { region_policy: policy, ..SchedulerConfig::default() };
            let mut mgr = RegionManager::new(&arch, &sched);
            mgr.set_noc(&arch, comm_aware);
            assert!(mgr.noc_enabled());
            assert!(mgr.corridor_map().unwrap().is_idle());

            let mut rng = Rng::new(0x11_0C ^ policy as u64 ^ comm_aware as u64);
            let mut live: Vec<ExecutionRegion> = Vec::new();
            for _ in 0..200 {
                if rng.chance(0.4) && !live.is_empty() {
                    let idx = rng.below(live.len() as u64) as usize;
                    let region = live.swap_remove(idx);
                    mgr.release(region.id).unwrap();
                } else {
                    let demand = SliceDemand::new(
                        rng.range_inclusive(0, 12) as u32,
                        rng.range_inclusive(1, 4) as u32,
                    );
                    if let AllocOutcome::Allocated(r) = mgr.try_allocate(&demand) {
                        // lockstep: the committed span is charged now
                        let span = mgr.corridor_span(r.id);
                        let map = mgr.corridor_map().unwrap();
                        for c in span.range.iter() {
                            assert!(
                                map.demand(c) >= span.tracks,
                                "{policy:?}: corridor {c} missing region {}'s demand",
                                r.id
                            );
                        }
                        live.push(r);
                    }
                }
            }
            for region in live.drain(..) {
                mgr.release(region.id).unwrap();
            }
            let map = mgr.corridor_map().unwrap();
            assert!(
                map.is_idle(),
                "{policy:?} comm_aware={comm_aware}: corridor demand leaked: {}",
                map.render()
            );
            assert_eq!(map.oversubscribed_count(), 0);
            assert!(mgr.idle());
        }
    }
}

#[test]
fn disabled_noc_with_configured_knobs_changes_nothing() {
    let render = |trace: &Trace| -> String {
        trace.events().map(|e| format!("{} {}\n", e.at, e.what())).collect()
    };
    // plain preset, noc section untouched
    let mut plain_cfg = presets::cloud_scenario(RegionPolicyKind::FlexibleShape);
    if let WorkloadConfig::Cloud(ref mut c) = plain_cfg.workload {
        c.duration_ms = 400.0;
    }
    let mut t_plain = Trace::new(1 << 20);
    let plain = run_cloud_traced(&plain_cfg, TaskLibrary::table1(), &mut t_plain).unwrap();

    // same preset with every knob set but the master switch off
    let mut knobs = plain_cfg.clone();
    knobs.noc.placement = NocPlacementKind::Oblivious;
    knobs.noc.comm_fraction = 0.9;
    knobs.noc.stream_affinity = false;
    knobs.noc.defrag_align = false;
    assert!(!knobs.noc.enabled);
    let mut t_knobs = Trace::new(1 << 20);
    let with_knobs = run_cloud_traced(&knobs, TaskLibrary::table1(), &mut t_knobs).unwrap();

    assert_eq!(render(&t_plain), render(&t_knobs), "traces must be byte-identical");
    assert_eq!(format!("{plain:?}"), format!("{with_knobs:?}"), "reports must match");
    assert!(plain.noc.is_none() && with_knobs.noc.is_none());
}

#[test]
fn pipeline_preset_places_streams_charges_contention_and_drains() {
    let shorten = |mut cfg: cgra_mte::config::Config| {
        if let WorkloadConfig::Cloud(ref mut c) = cfg.workload {
            c.duration_ms = 400.0;
        }
        cfg
    };
    let aware_cfg = shorten(presets::pipeline_scenario(NocPlacementKind::CommAware));
    let aware = run_cloud(&aware_cfg).unwrap();
    assert_eq!(aware.submitted, aware.completed, "offered load must drain");
    let noc = aware.noc.expect("[noc] enabled by the preset");
    assert!(noc.streams_placed > 0, "pipeline stages must place streams");
    assert!(noc.mean_slowdown >= 1.0);
    assert!(noc.peak_slowdown >= noc.mean_slowdown);
    assert_eq!(noc.corridors, 8);
    assert_eq!(noc.capacity, 20);

    // the ablation's oblivious arm is well-formed at the same load and
    // the comparison is non-vacuous: first-fit placement pays contention
    let obliv_cfg = shorten(presets::pipeline_scenario(NocPlacementKind::Oblivious));
    let obliv = run_cloud(&obliv_cfg).unwrap();
    assert_eq!(obliv.submitted, aware.submitted, "equal offered load");
    assert_eq!(obliv.submitted, obliv.completed);
    let onoc = obliv.noc.expect("[noc] enabled by the preset");
    assert!(onoc.streams_placed > 0);
    assert!(
        onoc.contended_launches > 0,
        "oblivious placement must contend at saturating load"
    );
}
