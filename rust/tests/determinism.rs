//! Determinism regression: every simulator is a pure function of its
//! config + seed.  Each scenario (standard and churn presets, single
//! fabric and pool) runs twice with the same seed and must produce a
//! byte-identical event trace and a byte-identical report rendering —
//! the seeded-RNG contract the pool refactor must not disturb.

use cgra_mte::config::{
    presets, Config, DefragPolicyKind, PlacementPolicyKind, RegionPolicyKind, WorkloadConfig,
};
use cgra_mte::sim::{
    run_cloud, run_cloud_pool, run_cloud_pool_traced, run_cloud_traced, run_edge_pool_traced,
    run_edge_traced, Trace,
};
use cgra_mte::tasks::TaskLibrary;

fn render(trace: &Trace) -> String {
    trace.events().map(|e| format!("{} {}\n", e.at, e.what())).collect()
}

/// Run `f` twice; both (trace, report-debug) pairs must match exactly.
fn assert_twice_identical<F>(what: &str, f: F)
where
    F: Fn(&mut Trace) -> String,
{
    let mut t1 = Trace::new(1 << 20);
    let r1 = f(&mut t1);
    let mut t2 = Trace::new(1 << 20);
    let r2 = f(&mut t2);
    assert_eq!(render(&t1), render(&t2), "{what}: event traces diverged");
    assert_eq!(r1, r2, "{what}: reports diverged");
    assert!(t1.events().next().is_some(), "{what}: trace must not be empty");
}

fn short_cloud(cfg: &mut Config, duration_ms: f64) {
    if let WorkloadConfig::Cloud(ref mut c) = cfg.workload {
        c.duration_ms = duration_ms;
    }
}

fn short_edge(cfg: &mut Config, frames: u32) {
    if let WorkloadConfig::Edge(ref mut e) = cfg.workload {
        e.frames = frames;
    }
}

#[test]
fn cloud_sim_trace_and_report_are_deterministic() {
    let mut cfg = presets::cloud_scenario(RegionPolicyKind::FlexibleShape);
    short_cloud(&mut cfg, 500.0);
    assert_twice_identical("cloud/standard", |t| {
        format!("{:?}", run_cloud_traced(&cfg, TaskLibrary::table1(), t).unwrap())
    });
}

#[test]
fn cloud_churn_trace_and_report_are_deterministic() {
    // churn preset from PR 2: past-saturation load + cost-aware defrag —
    // the migration machinery must stay inside the seeded contract too
    let mut cfg =
        presets::churn_scenario(RegionPolicyKind::FlexibleShape, DefragPolicyKind::CostAware);
    short_cloud(&mut cfg, 1_000.0);
    assert_twice_identical("cloud/churn", |t| {
        format!("{:?}", run_cloud_traced(&cfg, TaskLibrary::table1(), t).unwrap())
    });
}

#[test]
fn edge_sim_trace_and_report_are_deterministic() {
    let mut cfg = presets::edge_scenario(RegionPolicyKind::FlexibleShape);
    short_edge(&mut cfg, 150);
    assert_twice_identical("edge/standard", |t| {
        format!("{:?}", run_edge_traced(&cfg, TaskLibrary::table1(), t).unwrap())
    });
}

#[test]
fn edge_churn_trace_and_report_are_deterministic() {
    let mut cfg = presets::edge_churn_scenario(
        RegionPolicyKind::FlexibleShape,
        DefragPolicyKind::Greedy,
    );
    short_edge(&mut cfg, 150);
    assert_twice_identical("edge/churn", |t| {
        format!("{:?}", run_edge_traced(&cfg, TaskLibrary::table1(), t).unwrap())
    });
}

/// Energy accounting is part of the seeded contract: repeat runs must
/// produce byte-identical traces, reports *and* `energy_json` exports —
/// floating-point integration included (single-threaded, fixed event
/// order, so every f64 operation replays exactly).
#[test]
fn energy_accounting_is_byte_deterministic() {
    use cgra_mte::metrics::export::energy_json;

    let mut cfg = presets::energy_scenario();
    short_cloud(&mut cfg, 500.0);
    assert_twice_identical("cloud/energy", |t| {
        let r = run_cloud_traced(&cfg, TaskLibrary::table1(), t).unwrap();
        let energy = r.energy.as_ref().expect("accounting enabled");
        format!("{:?}\n{}", r, energy_json(energy))
    });

    // the capped churn preset exercises the governor + gating together
    let mut capped = presets::energy_cap_scenario(2.5);
    short_cloud(&mut capped, 500.0);
    assert_twice_identical("cloud/energy-capped", |t| {
        let r = run_cloud_traced(&capped, TaskLibrary::table1(), t).unwrap();
        let energy = r.energy.as_ref().expect("accounting enabled");
        format!("{:?}\n{}", r, energy_json(energy))
    });
}

/// The QoS subsystem is part of the seeded contract: the
/// mixed-criticality preset (EDF + preemption, checkpointed evictions,
/// SLO tracking) must replay byte-identically, `qos_json` included.
#[test]
fn qos_mixed_criticality_is_byte_deterministic() {
    use cgra_mte::metrics::export::qos_json;

    let mut cfg = presets::mixed_criticality_scenario(true);
    short_cloud(&mut cfg, 600.0);
    assert_twice_identical("cloud/qos-mixed", |t| {
        let r = run_cloud_traced(&cfg, TaskLibrary::table1(), t).unwrap();
        let qos = r.qos.as_ref().expect("qos enabled");
        assert!(qos.victims_evicted > 0, "the preset must exercise preemption");
        format!("{:?}\n{}", r, qos_json(qos))
    });

    // the FIFO ablation arm of the same preset replays too
    let mut fifo = presets::mixed_criticality_scenario(false);
    short_cloud(&mut fifo, 600.0);
    assert_twice_identical("cloud/qos-fifo", |t| {
        let r = run_cloud_traced(&fifo, TaskLibrary::table1(), t).unwrap();
        format!("{:?}\n{}", r, qos_json(r.qos.as_ref().expect("qos enabled")))
    });
}

/// With no `[qos]` section (the default `enabled = false`), the
/// existing presets replay bit-for-bit — and their reports carry no QoS
/// payload at all (the master-switch guarantee; `tests/prop_qos.rs`
/// additionally proves configured-but-disabled knobs change nothing).
#[test]
fn qos_disabled_default_presets_carry_no_qos_payload() {
    let mut cfg = presets::cloud_scenario(RegionPolicyKind::FlexibleShape);
    short_cloud(&mut cfg, 400.0);
    let mut t = Trace::new(1 << 20);
    let r = run_cloud_traced(&cfg, TaskLibrary::table1(), &mut t).unwrap();
    assert!(r.qos.is_none());
    assert!(
        t.events().all(|e| !e.what().starts_with("preempt ")),
        "no preemption may occur with [qos] absent"
    );

    let mut edge = presets::edge_scenario(RegionPolicyKind::FlexibleShape);
    short_edge(&mut edge, 120);
    let mut te = Trace::new(1 << 20);
    let re = run_edge_traced(&edge, TaskLibrary::table1(), &mut te).unwrap();
    assert!(re.qos.is_none());
}

#[test]
fn cloud_pool_trace_and_report_are_deterministic() {
    for placement in PlacementPolicyKind::ALL {
        let mut cfg = presets::pool_scenario(2, placement);
        short_cloud(&mut cfg, 400.0);
        assert_twice_identical("cloud/pool-2", |t| {
            format!("{:?}", run_cloud_pool_traced(&cfg, TaskLibrary::table1(), t).unwrap())
        });
    }
}

#[test]
fn edge_pool_trace_and_report_are_deterministic() {
    let mut cfg = presets::edge_scenario(RegionPolicyKind::FlexibleShape);
    cfg.pool.shards = 2;
    short_edge(&mut cfg, 120);
    assert_twice_identical("edge/pool-2", |t| {
        format!("{:?}", run_edge_pool_traced(&cfg, TaskLibrary::table1(), t).unwrap())
    });
}

/// The differential harness (`tests/differential.rs`) replays 24
/// randomized seeded configurations against checked-in goldens; the
/// underlying contract — an arbitrary reseeded config replays
/// byte-identically — is pinned here on representative off-preset seeds.
#[test]
fn reseeded_cloud_configs_are_deterministic() {
    for (seed, duration_ms) in
        [(0x5eed_0001u64, 300.0), (0xbad_c0ffeu64, 450.0), (0x7e57_ab1eu64, 250.0)]
    {
        let mut cfg = presets::cloud_scenario(RegionPolicyKind::FlexibleShape);
        if let WorkloadConfig::Cloud(ref mut c) = cfg.workload {
            c.seed = seed;
            c.duration_ms = duration_ms;
        }
        assert_twice_identical(&format!("cloud/reseed-{seed:x}"), |t| {
            format!("{:?}", run_cloud_traced(&cfg, TaskLibrary::table1(), t).unwrap())
        });
    }
}

/// The simperf bench (`benches/simperf.rs`) measures a fixed amount of
/// work — arrivals + completions + launches per run — against wall
/// time.  `BENCH_simperf.json`'s `events` column must be a pure
/// function of the config; only the wall-time fields may vary between
/// runs.  This pins the work metric for both runner families the bench
/// drives.
#[test]
fn simperf_event_counts_are_deterministic() {
    let mut churn =
        presets::churn_scenario(RegionPolicyKind::FlexibleShape, DefragPolicyKind::CostAware);
    short_cloud(&mut churn, 500.0);
    let cloud_events = |cfg: &Config| {
        let r = run_cloud(cfg).unwrap();
        r.submitted + r.completed + r.launches
    };
    let n = cloud_events(&churn);
    assert!(n > 0, "churn preset must process events");
    assert_eq!(n, cloud_events(&churn), "cloud event count diverged");

    let mut pool = presets::pool_scenario(2, PlacementPolicyKind::LeastLoaded);
    short_cloud(&mut pool, 300.0);
    let pool_events = |cfg: &Config| {
        let r = run_cloud_pool(cfg).unwrap();
        r.submitted + r.completed + r.launches
    };
    let np = pool_events(&pool);
    assert!(np > 0, "pool preset must process events");
    assert_eq!(np, pool_events(&pool), "pool event count diverged");
}
