//! Energy-accounting invariants ([`cgra_mte::energy`]).
//!
//! * **Conservation** — on every simulated run, the sum of the
//!   per-component joule counters (PE + MEM + GLB + DPR + migration +
//!   idle/gated/static + wake) equals the accountant's total, and
//!   per-task / per-tenant attributions never exceed it.
//! * **Aggregation** — a pool report's total equals the sum of its
//!   shards' accountants.
//! * **Inertness** — with `[energy]` absent the reports carry no energy
//!   and nothing about the schedule changes; with accounting on but
//!   gating off, traces are byte-identical to the energy-off run (no
//!   wake latency is ever charged).

use cgra_mte::config::{presets, Config, DefragPolicyKind, RegionPolicyKind, WorkloadConfig};
use cgra_mte::energy::EnergyReport;
use cgra_mte::sim::{
    run_cloud, run_cloud_pool, run_cloud_traced, run_edge, run_edge_traced, Trace,
};
use cgra_mte::tasks::TaskLibrary;

fn render(trace: &Trace) -> String {
    trace.events().map(|e| format!("{} {}\n", e.at, e.what())).collect()
}

fn assert_conserves(r: &EnergyReport, what: &str) {
    let sum = r.component_sum_j();
    assert!(
        (sum - r.total_j).abs() <= 1e-9 * r.total_j.max(1e-12),
        "{what}: component sum {sum} != total {}",
        r.total_j
    );
    let tenants: f64 = r.per_tenant.iter().sum();
    let tasks: f64 = r.per_task.values().sum();
    assert!(
        tenants <= r.total_j * (1.0 + 1e-9),
        "{what}: tenant attribution {tenants} exceeds total {}",
        r.total_j
    );
    assert!(
        (tenants - tasks).abs() <= 1e-9 * r.total_j.max(1e-12),
        "{what}: tenant ({tenants}) and task ({tasks}) attributions must agree"
    );
    assert!(r.total_j > 0.0, "{what}: a run must burn energy");
    assert!(r.mean_watts > 0.0 && r.peak_window_watts >= 0.0, "{what}");
}

fn short_cloud(cfg: &mut Config, duration_ms: f64) {
    if let WorkloadConfig::Cloud(ref mut c) = cfg.workload {
        c.duration_ms = duration_ms;
    }
}

fn short_edge(cfg: &mut Config, frames: u32) {
    if let WorkloadConfig::Edge(ref mut e) = cfg.workload {
        e.frames = frames;
    }
}

#[test]
fn cloud_energy_conserves_across_components() {
    let mut cfg = presets::energy_scenario();
    short_cloud(&mut cfg, 400.0);
    let r = run_cloud(&cfg).unwrap();
    let energy = r.energy.expect("accounting enabled");
    assert_conserves(&energy, "cloud/standard");
    assert!(energy.pe_j > 0.0 && energy.glb_j > 0.0 && energy.dpr_j > 0.0);
    assert!(energy.wakes > 0, "gated fabric must record wakes");
}

#[test]
fn churn_energy_conserves_with_migrations() {
    let mut cfg =
        presets::churn_scenario(RegionPolicyKind::FlexibleShape, DefragPolicyKind::Greedy);
    cfg.energy.enabled = true;
    short_cloud(&mut cfg, 1_000.0);
    let r = run_cloud(&cfg).unwrap();
    assert!(r.migrations > 0, "churn must migrate for this test to bite");
    let energy = r.energy.expect("accounting enabled");
    assert_conserves(&energy, "cloud/churn");
    assert!(energy.migration_j > 0.0, "migrations must be priced in joules");
}

#[test]
fn edge_energy_conserves() {
    let mut cfg = presets::edge_scenario(RegionPolicyKind::FlexibleShape);
    cfg.energy.enabled = true;
    short_edge(&mut cfg, 120);
    let r = run_edge(&cfg).unwrap();
    let energy = r.energy.expect("accounting enabled");
    assert_conserves(&energy, "edge/standard");
}

#[test]
fn pool_energy_total_equals_shard_sum() {
    let mut cfg = presets::energy_pool_scenario(
        2,
        cgra_mte::config::PlacementPolicyKind::LeastLoaded,
    );
    short_cloud(&mut cfg, 400.0);
    let r = run_cloud_pool(&cfg).unwrap();
    let energy = r.energy.expect("accounting enabled");
    assert_conserves(&energy, "cloud/pool-2");
    let shard_sum: f64 = r.per_shard.iter().map(|s| s.energy_j).sum();
    assert!(
        (shard_sum - energy.total_j).abs() <= 1e-9 * energy.total_j,
        "per-shard sum {shard_sum} != merged total {}",
        energy.total_j
    );
    assert!(r.per_shard.iter().all(|s| s.energy_j > 0.0), "every shard has a floor");
}

#[test]
fn default_config_reports_no_energy() {
    let mut cfg = presets::cloud_scenario(RegionPolicyKind::FlexibleShape);
    short_cloud(&mut cfg, 300.0);
    let r = run_cloud(&cfg).unwrap();
    assert!(r.energy.is_none(), "accounting must be opt-in");
    let mut ecfg = presets::edge_scenario(RegionPolicyKind::FlexibleShape);
    short_edge(&mut ecfg, 90);
    assert!(run_edge(&ecfg).unwrap().energy.is_none());
}

/// Accounting with gating *off* charges no wake latency, so the event
/// timeline must be byte-identical to the energy-off run — the
/// golden-equivalence half of the acceptance bar.  (With gating on,
/// launches that wake domains legitimately shift by `wake_cycles`.)
#[test]
fn accounting_without_gating_leaves_traces_bit_identical() {
    let mut off = presets::cloud_scenario(RegionPolicyKind::FlexibleShape);
    short_cloud(&mut off, 400.0);
    let mut on = off.clone();
    on.energy.enabled = true;
    on.energy.gating = false;

    let mut t_off = Trace::new(1 << 20);
    let r_off = run_cloud_traced(&off, TaskLibrary::table1(), &mut t_off).unwrap();
    let mut t_on = Trace::new(1 << 20);
    let r_on = run_cloud_traced(&on, TaskLibrary::table1(), &mut t_on).unwrap();

    assert_eq!(render(&t_off), render(&t_on), "gating-off accounting must not move events");
    assert_eq!(r_off.makespan_cycles, r_on.makespan_cycles);
    assert_eq!(r_off.launches, r_on.launches);
    let energy = r_on.energy.expect("accounting on");
    assert_conserves(&energy, "cloud/no-gating");
    assert_eq!(energy.wakes, 0, "no gating, no wakes");
    assert_eq!(energy.gated_j, 0.0, "no slice is ever gated");
    assert_eq!(energy.wake_j, 0.0);

    // same property on the edge driver
    let mut eoff = presets::edge_scenario(RegionPolicyKind::FlexibleShape);
    short_edge(&mut eoff, 90);
    let mut eon = eoff.clone();
    eon.energy.enabled = true;
    eon.energy.gating = false;
    let mut te_off = Trace::new(1 << 20);
    run_edge_traced(&eoff, TaskLibrary::table1(), &mut te_off).unwrap();
    let mut te_on = Trace::new(1 << 20);
    run_edge_traced(&eon, TaskLibrary::table1(), &mut te_on).unwrap();
    assert_eq!(render(&te_off), render(&te_on));
}

/// Gating on: wake latency shifts launches, but the run still drains
/// and the gated floor shows up as a distinct (cheap) component.
#[test]
fn gating_burns_less_than_idle() {
    let mut gated = presets::energy_scenario();
    short_cloud(&mut gated, 400.0);
    let mut awake = gated.clone();
    awake.energy.gating = false;
    let rg = run_cloud(&gated).unwrap().energy.unwrap();
    let ra = run_cloud(&awake).unwrap().energy.unwrap();
    // the gated run converts awake-idle joules into a far smaller
    // gated-leakage bill: total energy strictly drops
    assert!(
        rg.total_j < ra.total_j,
        "gating {:.6} J must undercut always-awake {:.6} J",
        rg.total_j,
        ra.total_j
    );
    assert!(rg.gated_j > 0.0);
    assert!(rg.idle_j < ra.idle_j);
}
