//! Property tests for the binary wire framing
//! (`coordinator::frame`): encode→decode round-trips over arbitrary
//! opcodes/tenants/request-ids/payloads, coalesced multi-frame buffers,
//! and the incremental-decode guarantee that feeding a buffer one byte
//! at a time produces exactly the whole-buffer result.  These are the
//! codec-level half of the protocol conformance story — the live-server
//! half is `tests/protocol_conformance.rs`.

use cgra_mte::coordinator::frame::{self, Frame, FrameError, Opcode};
use cgra_mte::testutil::{forall, forall_cfg, PropConfig};
use cgra_mte::util::rng::Rng;

const ALL_OPCODES: [Opcode; 11] = [
    Opcode::Submit,
    Opcode::Stats,
    Opcode::Defrag,
    Opcode::Quit,
    Opcode::Shutdown,
    Opcode::ReplyOk,
    Opcode::ReplyBusy,
    Opcode::ReplyErr,
    Opcode::ReplyStats,
    Opcode::ReplyDefrag,
    Opcode::ReplyBye,
];

/// One arbitrary frame: opcode, tenant, req_id, payload bytes.
#[derive(Clone, Debug)]
struct ArbFrame {
    opcode: Opcode,
    tenant: u16,
    req_id: u64,
    payload: Vec<u8>,
}

fn arb_frame(rng: &mut Rng, size: u32) -> ArbFrame {
    // payload length scales with the size budget so shrinking finds
    // small counterexamples; cap well past one read-chunk boundary.
    let max_len = (size as usize * 64).min(frame::MAX_PAYLOAD);
    let len = rng.below(max_len as u64 + 1) as usize;
    ArbFrame {
        opcode: *rng.choose(&ALL_OPCODES),
        tenant: rng.next_u64() as u16,
        req_id: rng.next_u64(),
        payload: (0..len).map(|_| rng.next_u64() as u8).collect(),
    }
}

fn arb_frames(rng: &mut Rng, size: u32) -> Vec<ArbFrame> {
    let n = 1 + rng.below(4) as usize;
    (0..n).map(|_| arb_frame(rng, size)).collect()
}

fn encodes_back(f: &ArbFrame, decoded: &Frame<'_>) -> bool {
    decoded.opcode == f.opcode
        && decoded.tenant == f.tenant
        && decoded.req_id == f.req_id
        && decoded.payload == &f.payload[..]
}

#[test]
fn encode_decode_roundtrips_every_field() {
    forall(&arb_frame, |f| {
        let buf = frame::encode(f.opcode, f.tenant, f.req_id, &f.payload);
        if buf.len() != frame::encoded_len(f.payload.len()) {
            return false;
        }
        match frame::decode(&buf) {
            Ok(Some((decoded, consumed))) => consumed == buf.len() && encodes_back(f, &decoded),
            _ => false,
        }
    });
}

#[test]
fn empty_and_max_size_payloads_roundtrip() {
    for len in [0usize, 1, frame::MAX_PAYLOAD - 1, frame::MAX_PAYLOAD] {
        let payload = vec![0xA5u8; len];
        let buf = frame::encode(Opcode::Submit, 2, 99, &payload);
        let (decoded, consumed) = frame::decode(&buf).unwrap().expect("complete frame");
        assert_eq!(consumed, frame::HEADER_LEN + len);
        assert_eq!(decoded.payload.len(), len);
    }
}

#[test]
fn coalesced_multi_frame_buffers_decode_in_order() {
    forall(&arb_frames, |frames| {
        let mut buf = Vec::new();
        for f in frames {
            frame::encode_into(&mut buf, f.opcode, f.tenant, f.req_id, &f.payload);
        }
        let mut off = 0;
        for f in frames {
            match frame::decode(&buf[off..]) {
                Ok(Some((decoded, consumed))) => {
                    if !encodes_back(f, &decoded) {
                        return false;
                    }
                    off += consumed;
                }
                _ => return false,
            }
        }
        off == buf.len()
    });
}

/// The incremental contract: every strict prefix of a valid frame is
/// `Ok(None)` ("need more bytes"), and the byte-at-a-time path yields
/// the same frame as the whole-buffer path — i.e. decoding is a pure
/// function of the buffer prefix with no internal state to desync.
#[test]
fn byte_at_a_time_decode_equals_whole_buffer_decode() {
    // fewer cases: each case scans every prefix of the encoding.
    let cfg = PropConfig { cases: 32, max_size: 32, ..PropConfig::default() };
    forall_cfg(cfg, &arb_frame, |f| {
        let buf = frame::encode(f.opcode, f.tenant, f.req_id, &f.payload);
        for cut in 0..buf.len() {
            if frame::decode(&buf[..cut]) != Ok(None) {
                return false;
            }
        }
        match frame::decode(&buf) {
            Ok(Some((decoded, consumed))) => consumed == buf.len() && encodes_back(f, &decoded),
            _ => false,
        }
    });
}

/// Trailing bytes after a complete frame (the next frame, or garbage)
/// never change what the first decode returns.
#[test]
fn trailing_bytes_do_not_affect_the_first_frame() {
    forall(&arb_frame, |f| {
        let clean = frame::encode(f.opcode, f.tenant, f.req_id, &f.payload);
        let mut dirty = clean.clone();
        dirty.extend_from_slice(&[0x00, 0xFF, 0xC6, 0x47]);
        let a = frame::decode(&clean);
        let b = frame::decode(&dirty);
        match (a, b) {
            (Ok(Some((fa, ca))), Ok(Some((fb, cb)))) => ca == cb && fa == fb,
            _ => false,
        }
    });
}

/// Corrupting any single magic/version/opcode byte of a valid frame is
/// caught (as the matching error) no later than the full header.
#[test]
fn single_byte_header_corruption_is_always_detected() {
    let cfg = PropConfig { cases: 48, max_size: 16, ..PropConfig::default() };
    forall_cfg(cfg, &arb_frame, |f| {
        let buf = frame::encode(f.opcode, f.tenant, f.req_id, &f.payload);
        for offset in 0..6 {
            let mut bad = buf.clone();
            bad[offset] ^= 0xFF; // guaranteed to differ from the original
            let got = frame::decode(&bad[..frame::HEADER_LEN.min(bad.len())]);
            let ok = match offset {
                0..=3 => {
                    let byte = frame::MAGIC[offset] ^ 0xFF;
                    got == Err(FrameError::BadMagic { byte, offset })
                }
                4 => got == Err(FrameError::BadVersion(frame::VERSION ^ 0xFF)),
                _ => matches!(got, Err(FrameError::BadOpcode(_))),
            };
            if !ok {
                return false;
            }
        }
        true
    });
}
