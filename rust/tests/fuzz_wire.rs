//! Malformed-input robustness for the reactor front: a deterministic,
//! corpus-driven fuzz pass (no external fuzzer — seeded mutations from
//! the crate's own [`Rng`]) at two levels.
//!
//! 1. Decoder level: `frame::decode` over hand-built malformed buffers
//!    and seeded mutations of valid frames must never panic, and must
//!    be a deterministic pure function of its input.
//! 2. Live-server level: every corpus entry is thrown at one running
//!    reactor server over a fresh connection — truncated frames,
//!    oversized length prefixes, bad magic/version/opcode bytes,
//!    reply opcodes in requests, non-UTF-8 payloads, over-long and
//!    garbage text lines, and mid-frame disconnects.  Each must end in
//!    a clean per-connection error or close; afterwards the server
//!    still serves fresh clients and its admission counters conserve
//!    (`queued == served + failed`, `failed == 0`, `pending == 0`) —
//!    i.e. no hang, no panic, no leaked in-flight state.
#![cfg(not(feature = "xla"))]

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use cgra_mte::config::{presets, Config, ServerModeKind};
use cgra_mte::coordinator::frame::{self, MAGIC, Opcode};
use cgra_mte::coordinator::Server;
use cgra_mte::testutil::wire::WireClient;
use cgra_mte::util::rng::Rng;

/// Serializes against the other loopback server suites.
static SERIAL: Mutex<()> = Mutex::new(());

fn stub_config() -> Config {
    let mut cfg = presets::paper_default();
    cfg.artifacts_dir = cgra_mte::runtime::SYNTHETIC_DIR.into();
    cfg.server.mode = ServerModeKind::Reactor;
    cfg
}

/// The hand-built half of the corpus: byte strings that exercise every
/// protocol-violation path by construction.
fn handcrafted_corpus() -> Vec<Vec<u8>> {
    let valid = frame::encode(Opcode::Submit, 0, 7, b"harris");
    let mut corpus: Vec<Vec<u8>> = vec![
        // nothing at all / mid-negotiation disconnect
        vec![],
        vec![MAGIC[0]],
        // truncated frames: every strict prefix boundary of interest
        valid[..4].to_vec(),
        valid[..frame::HEADER_LEN - 1].to_vec(),
        valid[..valid.len() - 1].to_vec(),
        // bad magic at each offset
        vec![0x00, 0x01, 0x02],
        vec![MAGIC[0], 0xFF],
        vec![MAGIC[0], MAGIC[1], MAGIC[2], 0x99],
        // bad version / bad opcode
        {
            let mut b = valid.clone();
            b[4] = 0x7E;
            b
        },
        {
            let mut b = valid.clone();
            b[5] = 0x40;
            b
        },
        // reply opcode in a request
        frame::encode(Opcode::ReplyOk, 0, 1, b"OK"),
        // oversized length prefix (u32::MAX and MAX_PAYLOAD + 1)
        {
            let mut b = valid.clone();
            b[16..20].copy_from_slice(&u32::MAX.to_le_bytes());
            b
        },
        {
            let mut b = valid.clone();
            b[16..20].copy_from_slice(&((frame::MAX_PAYLOAD as u32 + 1).to_le_bytes()));
            b
        },
        // non-UTF-8 payloads in SUBMIT and STATS
        frame::encode(Opcode::Submit, 0, 2, &[0xFF, 0xFE, 0x80]),
        frame::encode(Opcode::Stats, 0, 3, &[0xC0, 0xC1]),
        // text garbage: invalid UTF-8 line, binary noise after text start
        b"\xFF\xFE garbage\n".to_vec(),
        b"SUBMIT 0 harris\x00\x01\n".to_vec(),
        // text parse errors
        b"SUBMIT\n".to_vec(),
        b"SUBMIT nine camera\n".to_vec(),
        b"SUBMIT 0\n".to_vec(),
        b"STATS BOGUS extra junk\n".to_vec(),
        b"\n\n\n".to_vec(),
    ];
    // an over-long text line (no newline) must be rejected, not buffered
    // without bound: one byte past MAX_LINE
    corpus.push(vec![b'A'; 64 * 1024 + 2]);
    corpus
}

/// Seeded mutations of a valid frame: flip one random byte, truncate at
/// a random point, or duplicate a random slice.  Deterministic per seed.
fn mutated_corpus(seed: u64, cases: usize) -> Vec<Vec<u8>> {
    let mut rng = Rng::new(seed);
    let valid = frame::encode(Opcode::Submit, 1, 9, b"camera critical 60000");
    (0..cases)
        .map(|_| {
            let mut buf = valid.clone();
            match rng.below(3) {
                0 => {
                    let i = rng.below(buf.len() as u64) as usize;
                    buf[i] ^= 1 << rng.below(8);
                }
                1 => {
                    let cut = rng.below(buf.len() as u64) as usize;
                    buf.truncate(cut);
                }
                _ => {
                    let at = rng.below(buf.len() as u64) as usize;
                    let extra = buf[..at].to_vec();
                    buf.extend_from_slice(&extra);
                }
            }
            // a single bit flip can turn SUBMIT (0x01) into SHUTDOWN
            // (0x05); keep the corpus from gracefully stopping the
            // server under test
            if buf.len() > 5 && buf[5] == Opcode::Shutdown.as_u8() {
                buf[5] = 0xEE;
            }
            buf
        })
        .collect()
}

/// Decoder-level fuzz: no panic, deterministic, and every complete
/// valid frame embedded at the front still decodes.
#[test]
fn decoder_never_panics_and_is_deterministic() {
    let mut corpus = handcrafted_corpus();
    corpus.extend(mutated_corpus(0xF0_22, 200));
    for buf in &corpus {
        let first = frame::decode(buf);
        let second = frame::decode(buf);
        assert_eq!(first, second, "decode must be a pure function of its input");
        if let Ok(Some((f, consumed))) = first {
            assert!(consumed <= buf.len());
            assert!(f.payload.len() <= frame::MAX_PAYLOAD);
            // decoding the remainder must not panic either
            let _ = frame::decode(&buf[consumed..]);
        }
    }
}

/// Write a corpus entry to a fresh connection against the live server,
/// optionally read whatever comes back, then drop the socket (half the
/// cases disconnect without reading — the mid-frame-disconnect shape).
fn throw_at_server(addr: std::net::SocketAddr, bytes: &[u8], read_back: bool) {
    let mut stream = match TcpStream::connect(addr) {
        Ok(s) => s,
        Err(e) => panic!("server stopped accepting: {e}"),
    };
    // ignore write errors: the server may already have closed on us
    // (e.g. after an oversized length prefix), which is exactly the
    // behavior under test
    let _ = stream.write_all(bytes);
    if read_back {
        stream
            .set_read_timeout(Some(Duration::from_millis(100)))
            .expect("set_read_timeout");
        let mut sink = [0u8; 4096];
        while let Ok(n) = stream.read(&mut sink) {
            if n == 0 {
                break;
            }
        }
    }
}

/// Parse one `field=<u64>` out of an aggregate STATS line.
fn stat_field(stats: &str, field: &str) -> u64 {
    stats
        .split_whitespace()
        .find_map(|f| f.strip_prefix(&format!("{field}=")))
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| panic!("missing {field}= in: {stats}"))
}

#[test]
fn live_reactor_survives_the_malformed_corpus() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let server = Server::start(&stub_config(), "127.0.0.1:0").unwrap();
    let addr = server.addr;

    let mut corpus = handcrafted_corpus();
    corpus.extend(mutated_corpus(0xF0_23, 40));
    for (i, bytes) in corpus.iter().enumerate() {
        // alternate between reading the error reply and slamming the
        // connection shut mid-exchange
        throw_at_server(addr, bytes, i % 2 == 0);
    }

    // a valid binary SUBMIT dribbled one byte at a time must still be
    // parsed incrementally and served
    let wire = frame::encode(Opcode::Submit, 2, 77, b"harris");
    let mut dribble = TcpStream::connect(addr).expect("connect");
    for b in &wire {
        dribble.write_all(std::slice::from_ref(b)).expect("dribble write");
        std::thread::sleep(Duration::from_millis(1));
    }
    let mut rbuf = Vec::new();
    let mut chunk = [0u8; 1024];
    let reply = loop {
        match frame::decode(&rbuf).expect("well-formed reply frame") {
            Some((f, _)) => {
                assert_eq!(f.opcode, Opcode::ReplyOk, "dribbled SUBMIT must serve");
                assert_eq!(f.req_id, 77, "req_id echo");
                break String::from_utf8(f.payload.to_vec()).expect("utf-8 reply");
            }
            None => {
                let n = dribble.read(&mut chunk).expect("read reply");
                assert!(n > 0, "server closed on a valid dribbled frame");
                rbuf.extend_from_slice(&chunk[..n]);
            }
        }
    };
    assert!(reply.starts_with("OK seq="), "{reply}");
    drop(dribble);

    // liveness: a fresh text client still gets served after the storm
    let mut client = WireClient::connect(addr).expect("connect after storm");
    let (reply, _) = client.submit(3, "camera").expect("submit");
    assert!(reply.starts_with("OK "), "{reply}");

    // conservation: wait for the pipeline to quiesce, then every
    // admitted submission must be accounted for — nothing leaked,
    // nothing failed, nothing stuck in-flight
    let deadline = Instant::now() + Duration::from_secs(10);
    let stats = loop {
        let stats = client.send("STATS").expect("stats");
        if stat_field(&stats, "pending") == 0 {
            break stats;
        }
        assert!(Instant::now() < deadline, "pipeline never quiesced: {stats}");
        std::thread::sleep(Duration::from_millis(50));
    };
    let queued = stat_field(&stats, "queued");
    let served = stat_field(&stats, "served");
    let failed = stat_field(&stats, "failed");
    assert_eq!(failed, 0, "{stats}");
    assert_eq!(queued, served + failed, "admission counters leaked: {stats}");
    assert!(served >= 2, "dribbled + liveness submissions must both serve: {stats}");
    client.send("QUIT").expect("quit");
    server.shutdown();
}
