//! End-to-end runtime integration: every artifact executes through the
//! runtime client and matches its golden checksum; the live coordinator
//! serves a mixed batch with real compute.
//!
//! The golden-execution tests target real PJRT numerics, so they are
//! compiled only with `--features xla` and skip silently when `make
//! artifacts` has not been run; the stub backend's equivalents live next
//! to the stub (`runtime/stub.rs`, `coordinator/leader.rs`) against the
//! synthetic manifest.

use std::path::{Path, PathBuf};

use cgra_mte::config::presets;
use cgra_mte::coordinator::Leader;
#[cfg(feature = "xla")]
use cgra_mte::coordinator::TenantId;
use cgra_mte::runtime::Manifest;
#[cfg(feature = "xla")]
use cgra_mte::runtime::RuntimeClient;
use cgra_mte::tasks::TaskLibrary;
#[cfg(feature = "xla")]
use cgra_mte::tasks::AppId;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

#[cfg(feature = "xla")]
#[test]
fn every_artifact_golden_verifies() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = RuntimeClient::from_dir(&dir).unwrap();
    let names: Vec<String> = rt.manifest().iter().map(|a| a.name.clone()).collect();
    assert!(names.len() >= 20, "{}", names.len());
    for name in &names {
        let out = rt.verify_golden(name).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(out.values.iter().all(|v| v.is_finite()), "{name}: non-finite output");
    }
    assert_eq!(rt.compiled_count(), names.len());
}

#[test]
fn manifest_covers_every_table1_variant() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    manifest.verify_files().unwrap();
    for t in TaskLibrary::table1().iter() {
        for v in &t.variants {
            let name = v.artifact.as_ref().expect("artifact name");
            let spec = manifest.get(name).unwrap_or_else(|_| panic!("missing {name}"));
            assert_eq!(spec.task, t.id.0, "{name} task mismatch");
            assert_eq!(spec.variant, v.ver.0.to_string(), "{name} variant mismatch");
        }
    }
}

#[cfg(feature = "xla")]
#[test]
fn executions_are_reproducible() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = RuntimeClient::from_dir(&dir).unwrap();
    for name in ["camera_pipeline_a", "mobilenet_dw_pw_3_b"] {
        let a = rt.execute_golden(name).unwrap();
        let b = rt.execute_golden(name).unwrap();
        assert_eq!(a.values, b.values, "{name} not deterministic");
    }
}

#[cfg(feature = "xla")]
#[test]
fn leader_serves_all_four_apps_with_real_compute() {
    let Some(dir) = artifacts_dir() else { return };
    let mut cfg = presets::paper_default();
    cfg.artifacts_dir = dir.display().to_string();
    let mut leader = Leader::new(&cfg).unwrap();

    let ms = 500_000u64;
    let subs: Vec<(TenantId, AppId, u64)> = (0..8)
        .map(|i| (TenantId((i % 4) as u32), AppId::ALL[(i % 4) as usize], i * ms))
        .collect();
    let stats = leader.serve(&subs).unwrap();

    assert_eq!(stats.outcomes.len(), 8);
    // ResNet expands to 4 tasks, MobileNet to 3, camera/harris to 1:
    // 2 requests each ⇒ 2*(4+3+1+1) = 18 launches
    assert_eq!(stats.launches, 18);
    assert!(stats.total_compute_us > 0.0);
    for outcome in &stats.outcomes {
        assert!(outcome.ntat >= 1.0);
        assert!(outcome.compute_us > 0.0);
        assert!(outcome.final_output_sum.is_finite());
    }
    // machine fully drained
    assert_eq!(leader.scheduler().regions().active_count(), 0);
}

#[test]
fn leader_rejects_missing_artifacts_dir() {
    let mut cfg = presets::paper_default();
    cfg.artifacts_dir = "/nonexistent/artifacts".into();
    assert!(Leader::new(&cfg).is_err());
}
