//! Property tests on the region allocator: under arbitrary interleaved
//! allocate/release sequences, the slice maps must conserve resources,
//! regions must never overlap, and every mechanism must respect its own
//! structural contract.

use cgra_mte::abstraction::SliceDemand;
use cgra_mte::config::{ArchConfig, RegionPolicyKind, SchedulerConfig};
use cgra_mte::regions::{AllocOutcome, ExecutionRegion, RegionManager};
use cgra_mte::testutil::{forall_cfg, PropConfig};
use cgra_mte::util::rng::Rng;

/// A random op sequence: (glb, array, release-probability) triples.
fn op_seq(rng: &mut Rng, size: u32) -> Vec<(u32, u32, bool)> {
    let len = 4 + rng.below(size as u64 * 2 + 1) as usize;
    (0..len)
        .map(|_| {
            (
                rng.range_inclusive(0, 24) as u32,
                rng.range_inclusive(1, 8) as u32,
                rng.chance(0.4),
            )
        })
        .collect()
}

fn no_overlaps(regions: &[ExecutionRegion]) -> bool {
    for (i, a) in regions.iter().enumerate() {
        for b in regions.iter().skip(i + 1) {
            for ra in &a.glb {
                for rb in &b.glb {
                    if ra.overlaps(rb) {
                        return false;
                    }
                }
            }
            for ra in &a.array {
                for rb in &b.array {
                    if ra.overlaps(rb) {
                        return false;
                    }
                }
            }
        }
    }
    true
}

fn check_policy(policy: RegionPolicyKind) {
    let cfg = PropConfig { cases: 48, seed: 0xA110C ^ policy as u64, max_size: 24 };
    forall_cfg(cfg, &op_seq, |ops| {
        let arch = ArchConfig::default();
        let sched = SchedulerConfig { region_policy: policy, ..SchedulerConfig::default() };
        let mut mgr = RegionManager::new(&arch, &sched);
        let mut live: Vec<ExecutionRegion> = Vec::new();
        let mut rng = Rng::new(ops.len() as u64);

        for &(glb, array, release) in ops {
            if release && !live.is_empty() {
                let idx = rng.below(live.len() as u64) as usize;
                let region = live.swap_remove(idx);
                if mgr.release(region.id).is_err() {
                    return false;
                }
            } else {
                let demand = SliceDemand::new(glb, array);
                match mgr.try_allocate(&demand) {
                    AllocOutcome::Allocated(r) => {
                        // structural contract: an accepted demand is
                        // always covered (mechanisms may over-allocate,
                        // never under-allocate).
                        let fp = r.footprint();
                        if !demand.fits_within(&fp) {
                            return false;
                        }
                        match policy {
                            RegionPolicyKind::FlexibleShape => {
                                // exact allocation, contiguous
                                if fp != demand || !r.is_contiguous() {
                                    return false;
                                }
                            }
                            RegionPolicyKind::VariableSize => {
                                if !r.is_contiguous() {
                                    return false;
                                }
                            }
                            _ => {}
                        }
                        live.push(r);
                    }
                    AllocOutcome::NoFit | AllocOutcome::NeverFits => {}
                }
            }
            // invariants after every op
            if !no_overlaps(&live) {
                return false;
            }
            let busy: u32 = live.iter().map(|r| r.glb_slices()).sum();
            let busy_a: u32 = live.iter().map(|r| r.array_slices()).sum();
            let (ug, ua) = mgr.utilization();
            if (ug * 32.0).round() as u32 != busy || (ua * 8.0).round() as u32 != busy_a {
                return false; // conservation violated
            }
            let (fg, fa) = mgr.fragmentation();
            if !(0.0..=1.0).contains(&fg) || !(0.0..=1.0).contains(&fa) {
                return false;
            }
        }
        // full teardown restores the idle machine
        for region in live.drain(..) {
            if mgr.release(region.id).is_err() {
                return false;
            }
        }
        let (ug, ua) = mgr.utilization();
        ug == 0.0 && ua == 0.0 && mgr.idle()
    });
}

#[test]
fn allocator_invariants_baseline() {
    check_policy(RegionPolicyKind::Baseline);
}

#[test]
fn allocator_invariants_fixed() {
    check_policy(RegionPolicyKind::FixedSize);
}

#[test]
fn allocator_invariants_variable() {
    check_policy(RegionPolicyKind::VariableSize);
}

#[test]
fn allocator_invariants_flexible() {
    check_policy(RegionPolicyKind::FlexibleShape);
}

#[test]
fn allocation_is_all_or_nothing_under_failure() {
    // when try_allocate returns NoFit, the maps must be untouched.
    forall_cfg(
        PropConfig { cases: 64, seed: 77, max_size: 32 },
        &op_seq,
        |ops| {
            let arch = ArchConfig::default();
            let sched = SchedulerConfig {
                region_policy: RegionPolicyKind::FlexibleShape,
                ..SchedulerConfig::default()
            };
            let mut mgr = RegionManager::new(&arch, &sched);
            // fill the machine almost completely
            let hog = match mgr.try_allocate(&SliceDemand::new(30, 7)) {
                AllocOutcome::Allocated(r) => r,
                _ => return false,
            };
            let (ug0, ua0) = mgr.utilization();
            for &(glb, array, _) in ops {
                if glb > 2 || array > 1 {
                    let _ = mgr.try_allocate(&SliceDemand::new(glb.max(3), array.max(2)));
                    let (ug, ua) = mgr.utilization();
                    if (ug, ua) != (ug0, ua0) && mgr.active_count() == 1 {
                        return false;
                    }
                }
            }
            mgr.release(hog.id).is_ok()
        },
    );
}
