//! QoS subsystem invariants on the mixed-criticality preset:
//!
//! 1. **Strict class ascent** — no task is ever preempted by a task of
//!    an equal or lower class; in particular a Critical task is never a
//!    victim (checked against every `preempt` trace line).
//! 2. **Exactly-once completion** — every checkpointed victim
//!    eventually resumes and its request completes exactly once
//!    (`submitted == completed`, zero checkpoints at drain, resumes
//!    equal evictions; the queue errors on any double completion).
//! 3. **Resource conservation** — preempt/resume cycles never leak or
//!    double-book slices (trace-level: every evicted region's launch
//!    exists; end-state: full drain with the scheduler's own invariant
//!    checks live throughout the run).
//! 4. **Master switch** — with `[qos].enabled = false`, configured
//!    classes/deadlines change nothing: traces and reports are
//!    byte-identical to the plain preset.

use std::collections::BTreeMap;

use cgra_mte::config::{presets, QosClass, WorkloadConfig};
use cgra_mte::sim::{run_cloud, run_cloud_traced, Trace};
use cgra_mte::tasks::TaskLibrary;

fn class_rank(name: &str) -> u32 {
    match name {
        "best-effort" => 0,
        "interactive" => 1,
        "critical" => 2,
        other => panic!("unknown class in trace: {other}"),
    }
}

fn field<'a>(line: &'a str, key: &str) -> &'a str {
    line.split_whitespace()
        .find_map(|tok| tok.strip_prefix(key).and_then(|v| v.strip_prefix('=')))
        .unwrap_or_else(|| panic!("missing {key}= in '{line}'"))
}

fn mixed_cfg(preemptive: bool, duration_ms: f64) -> cgra_mte::config::Config {
    let mut cfg = presets::mixed_criticality_scenario(preemptive);
    if let WorkloadConfig::Cloud(ref mut c) = cfg.workload {
        c.duration_ms = duration_ms;
    }
    cfg
}

#[test]
fn preemption_is_strictly_class_ascending_and_never_evicts_critical() {
    let cfg = mixed_cfg(true, 800.0);
    let mut trace = Trace::new(1 << 22);
    let report = run_cloud_traced(&cfg, TaskLibrary::table1(), &mut trace).unwrap();
    let qos = report.qos.expect("preset enables qos");
    assert!(qos.preemptions > 0, "the scenario must actually preempt");

    let mut preempt_lines = 0u64;
    for e in trace.events() {
        let what = e.what();
        if !what.starts_with("preempt ") {
            continue;
        }
        preempt_lines += 1;
        let victim = class_rank(field(&what, "class"));
        let preemptor = class_rank(field(&what, "byclass"));
        assert!(
            victim < preemptor,
            "preemption must be strictly class-ascending: {}",
            what
        );
        assert_ne!(
            field(&what, "class"),
            "critical",
            "a critical task must never be a victim: {}",
            what
        );
    }
    assert_eq!(preempt_lines, qos.victims_evicted, "every eviction is traced");
}

#[test]
fn victims_resume_and_complete_exactly_once_with_conservation() {
    let cfg = mixed_cfg(true, 800.0);
    let mut trace = Trace::new(1 << 22);
    let report = run_cloud_traced(&cfg, TaskLibrary::table1(), &mut trace).unwrap();
    let qos = report.qos.expect("qos on");

    // exactly-once: the run drains fully (the sim errors on double
    // completion or stranded requests), every eviction is matched by a
    // resume, and nothing stays checkpointed
    assert_eq!(report.submitted, report.completed);
    assert!(qos.victims_evicted > 0);
    assert_eq!(qos.victims_resumed, qos.victims_evicted, "every victim resumes");

    // conservation at the trace level: each preempted instance was
    // launched before its eviction and launched again afterwards, and
    // every region name in a preempt line matches that instance's most
    // recent launch region
    let mut last_region: BTreeMap<String, String> = BTreeMap::new();
    let mut resumes_owed: BTreeMap<String, u64> = BTreeMap::new();
    for e in trace.events() {
        let what = e.what();
        if what.starts_with("launch ") {
            let inst = field(&what, "inst").to_string();
            last_region.insert(inst.clone(), field(&what, "region").to_string());
            if let Some(owed) = resumes_owed.get_mut(&inst) {
                *owed = owed.saturating_sub(1);
            }
        } else if what.starts_with("preempt ") {
            let inst = field(&what, "inst").to_string();
            let region = field(&what, "region");
            assert_eq!(
                last_region.get(&inst).map(String::as_str),
                Some(region),
                "evicted region must be the instance's live launch region: {}",
                what
            );
            *resumes_owed.entry(inst).or_insert(0) += 1;
        }
    }
    assert!(
        resumes_owed.values().all(|&owed| owed == 0),
        "every preempted instance must relaunch: {resumes_owed:?}"
    );

    // per-class accounting covers every request exactly once
    let total: u64 = qos.per_class.iter().map(|c| c.completed).sum();
    assert_eq!(total, report.completed);
    // BestEffort is delayed, not starved: it completes everything too
    assert!(qos.class(QosClass::BestEffort).completed > 0);
}

#[test]
fn preemptive_edf_beats_fifo_on_critical_latency_at_equal_load() {
    // the bench enforces this with full rigor; the property here is the
    // cheap smoke-scale version so `cargo test` alone catches ordering
    // regressions
    let fifo = run_cloud(&mixed_cfg(false, 600.0)).unwrap();
    let edf = run_cloud(&mixed_cfg(true, 600.0)).unwrap();
    assert_eq!(fifo.submitted, edf.submitted, "equal offered load");
    let fq = fifo.qos.expect("qos on");
    let eq = edf.qos.expect("qos on");
    let (fc, ec) = (fq.class(QosClass::Critical), eq.class(QosClass::Critical));
    assert!(fc.missed > 0, "fifo must miss deadlines at this load");
    assert!(
        ec.p99_latency < fc.p99_latency,
        "edf p99 {} vs fifo p99 {}",
        ec.p99_latency,
        fc.p99_latency
    );
    assert!(
        ec.miss_rate() < fc.miss_rate(),
        "edf miss {} vs fifo miss {}",
        ec.miss_rate(),
        fc.miss_rate()
    );
    assert_eq!(fq.preemptions, 0, "fifo never preempts");
    assert!(eq.preemptions > 0, "edf must preempt under this load");
}

#[test]
fn disabled_qos_with_configured_knobs_changes_nothing() {
    let render = |trace: &Trace| -> String {
        trace.events().map(|e| format!("{} {}\n", e.at, e.what())).collect()
    };
    // plain preset, qos section untouched
    let mut plain_cfg = presets::cloud_scenario(cgra_mte::config::RegionPolicyKind::FlexibleShape);
    if let WorkloadConfig::Cloud(ref mut c) = plain_cfg.workload {
        c.duration_ms = 400.0;
    }
    let mut t_plain = Trace::new(1 << 20);
    let plain = run_cloud_traced(&plain_cfg, TaskLibrary::table1(), &mut t_plain).unwrap();

    // same preset with every knob set but the master switch off
    let mut knobs = plain_cfg.clone();
    knobs.qos.preemption = true;
    knobs.qos.tenant_class =
        [QosClass::Critical, QosClass::Interactive, QosClass::Critical, QosClass::Critical];
    knobs.qos.deadline_ms = [1.0, 1.0, 1.0, 1.0];
    knobs.qos.aging_cycles = 1;
    assert!(!knobs.qos.enabled);
    let mut t_knobs = Trace::new(1 << 20);
    let with_knobs = run_cloud_traced(&knobs, TaskLibrary::table1(), &mut t_knobs).unwrap();

    assert_eq!(render(&t_plain), render(&t_knobs), "traces must be byte-identical");
    assert_eq!(format!("{plain:?}"), format!("{with_knobs:?}"), "reports must match");
    assert!(plain.qos.is_none() && with_knobs.qos.is_none());
}
