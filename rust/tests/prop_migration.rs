//! Property tests on the migration subsystem: after any interleaved
//! sequence of allocate / release / compact operations, no two regions
//! overlap, every live region's slice ranges stay within the machine
//! bounds, and busy-slice totals are conserved (migration moves work, it
//! never creates or destroys it).

use cgra_mte::abstraction::SliceDemand;
use cgra_mte::config::{
    ArchConfig, DefragPolicyKind, RegionPolicyKind, SchedulerConfig,
};
use cgra_mte::migration::{execute_plan, DefragPlanner};
use cgra_mte::regions::{AllocOutcome, ExecutionRegion, RegionManager};
use cgra_mte::testutil::{forall_cfg, PropConfig};
use cgra_mte::util::rng::Rng;

const GLB_TOTAL: u32 = 32;
const ARR_TOTAL: u32 = 8;

/// One op: allocate (glb, array), release a random live region, or run
/// a full compaction pass.
#[derive(Clone, Copy, Debug)]
enum Op {
    Alloc(u32, u32),
    Release,
    Compact,
}

fn op_seq(rng: &mut Rng, size: u32) -> Vec<Op> {
    let len = 6 + rng.below(size as u64 * 2 + 1) as usize;
    (0..len)
        .map(|_| match rng.below(10) {
            0..=4 => Op::Alloc(
                rng.range_inclusive(0, 20) as u32,
                rng.range_inclusive(1, 7) as u32,
            ),
            5..=7 => Op::Release,
            _ => Op::Compact,
        })
        .collect()
}

fn overlaps(a: &ExecutionRegion, b: &ExecutionRegion) -> bool {
    for ra in &a.glb {
        for rb in &b.glb {
            if ra.overlaps(rb) {
                return true;
            }
        }
    }
    for ra in &a.array {
        for rb in &b.array {
            if ra.overlaps(rb) {
                return true;
            }
        }
    }
    false
}

/// Global invariants over the live set + manager.
fn invariants_hold(mgr: &RegionManager) -> bool {
    let live: Vec<&ExecutionRegion> = mgr.active().collect();
    // pairwise disjoint
    for (i, a) in live.iter().enumerate() {
        for b in live.iter().skip(i + 1) {
            if overlaps(a, b) {
                return false;
            }
        }
    }
    // in bounds
    for r in &live {
        if r.glb.iter().any(|g| g.end() > GLB_TOTAL)
            || r.array.iter().any(|a| a.end() > ARR_TOTAL)
        {
            return false;
        }
    }
    // conservation: busy-slice totals equal the sum of live footprints
    let busy_g: u32 = live.iter().map(|r| r.glb_slices()).sum();
    let busy_a: u32 = live.iter().map(|r| r.array_slices()).sum();
    mgr.glb_map().busy_count() == busy_g && mgr.array_map().busy_count() == busy_a
}

fn check_policy(policy: RegionPolicyKind) {
    let cfg = PropConfig { cases: 48, seed: 0x519A7E ^ policy as u64, max_size: 24 };
    forall_cfg(cfg, &op_seq, |ops| {
        let arch = ArchConfig::default();
        let sched = SchedulerConfig {
            region_policy: policy,
            unit_glb_slices: 4,
            unit_array_slices: 1,
            defrag_policy: DefragPolicyKind::Greedy,
            defrag_threshold: 0.0,
            ..SchedulerConfig::default()
        };
        let planner = DefragPlanner::new(&sched);
        let mut mgr = RegionManager::new(&arch, &sched);
        let mut rng = Rng::new(ops.len() as u64 + 1);

        for op in ops {
            match *op {
                Op::Alloc(g, a) => {
                    let _ = mgr.try_allocate(&SliceDemand::new(g, a));
                }
                Op::Release => {
                    let ids: Vec<_> = mgr.active().map(|r| r.id).collect();
                    if !ids.is_empty() {
                        let idx = rng.below(ids.len() as u64) as usize;
                        if mgr.release(ids[idx]).is_err() {
                            return false;
                        }
                    }
                }
                Op::Compact => {
                    let busy_before =
                        (mgr.glb_map().busy_count(), mgr.array_map().busy_count());
                    if let Some(plan) = planner.compact(&mgr) {
                        let costs = vec![1u64; plan.len()];
                        match execute_plan(&mut mgr, &plan, &costs) {
                            Ok(out) => {
                                debug_assert_eq!(out.records.len(), plan.len());
                                // compaction conserves busy totals exactly
                                if (mgr.glb_map().busy_count(), mgr.array_map().busy_count())
                                    != busy_before
                                {
                                    return false;
                                }
                                // left-compaction leaves at most one free
                                // run per class
                                if mgr.glb_map().free_runs().len() > 1
                                    || mgr.array_map().free_runs().len() > 1
                                {
                                    return false;
                                }
                            }
                            Err(_) => return false, // planner proposed junk
                        }
                    }
                }
            }
            if !invariants_hold(&mgr) {
                return false;
            }
        }

        // full teardown restores the idle machine regardless of how much
        // migration happened
        let ids: Vec<_> = mgr.active().map(|r| r.id).collect();
        for id in ids {
            if mgr.release(id).is_err() {
                return false;
            }
        }
        mgr.idle()
            && mgr.glb_map().busy_count() == 0
            && mgr.array_map().busy_count() == 0
    });
}

#[test]
fn migration_invariants_flexible() {
    check_policy(RegionPolicyKind::FlexibleShape);
}

#[test]
fn migration_invariants_variable() {
    check_policy(RegionPolicyKind::VariableSize);
}

/// Random (not planner-driven) relocations: whether each succeeds or is
/// rejected, the invariants must hold afterwards.
#[test]
fn arbitrary_relocations_preserve_invariants() {
    let gen = |rng: &mut Rng, size: u32| {
        let len = 4 + rng.below(size as u64 + 1) as usize;
        (0..len)
            .map(|_| {
                (
                    rng.range_inclusive(0, 34) as u32, // target glb start (may be OOB)
                    rng.range_inclusive(0, 9) as u32,  // target array start (may be OOB)
                )
            })
            .collect::<Vec<_>>()
    };
    forall_cfg(PropConfig { cases: 64, seed: 0xD06_F00D, max_size: 32 }, &gen, |targets| {
        let arch = ArchConfig::default();
        let sched = SchedulerConfig {
            region_policy: RegionPolicyKind::FlexibleShape,
            ..SchedulerConfig::default()
        };
        let mut mgr = RegionManager::new(&arch, &sched);
        let mut ids = Vec::new();
        for _ in 0..3 {
            match mgr.try_allocate(&SliceDemand::new(6, 2)) {
                AllocOutcome::Allocated(r) => ids.push(r.id),
                other => panic!("fill: {other:?}"),
            }
        }
        let mut rng = Rng::new(targets.len() as u64);
        for &(gs, as_) in targets {
            let id = ids[rng.below(ids.len() as u64) as usize];
            let (glen, alen) = {
                let r = mgr.region(id).expect("live");
                (r.glb[0].len, r.array[0].len)
            };
            let _ = mgr.relocate(
                id,
                Some(cgra_mte::abstraction::SliceRange::new(gs, glen)),
                Some(cgra_mte::abstraction::SliceRange::new(as_, alen)),
            );
            if !invariants_hold(&mgr) {
                return false;
            }
        }
        true
    });
}
