//! End-to-end observability properties over the sim drivers:
//!
//! 1. **Transparency** — running with obs *enabled* produces the exact
//!    trace bytes and report rendering of the obs-disabled run (the
//!    differential goldens pin the disabled path; this pins enabled
//!    against it).
//! 2. **Determinism** — two obs-enabled runs of the same seeded config
//!    produce identical journal digests and identical metrics
//!    expositions.
//! 3. **Round-trip** — the Perfetto export of a real run's journal
//!    parses with the in-tree JSON parser and carries the trace_event
//!    shape ui.perfetto.dev expects.

use cgra_mte::config::{presets, Config, RegionPolicyKind, WorkloadConfig};
use cgra_mte::obs::{perfetto, Obs};
use cgra_mte::sim::{
    run_cloud_observed, run_cloud_traced, run_edge_observed, run_edge_pool_observed,
    run_edge_pool_traced, run_edge_traced, Trace,
};
use cgra_mte::tasks::TaskLibrary;
use cgra_mte::util::json::Json;

fn render(trace: &Trace) -> String {
    trace.events().map(|e| format!("{} {}\n", e.at, e.what())).collect()
}

fn short_cloud(cfg: &mut Config, duration_ms: f64) {
    if let WorkloadConfig::Cloud(ref mut c) = cfg.workload {
        c.duration_ms = duration_ms;
    }
}

fn short_edge(cfg: &mut Config, frames: u32) {
    if let WorkloadConfig::Edge(ref mut e) = cfg.workload {
        e.frames = frames;
    }
}

#[test]
fn cloud_obs_enabled_is_trace_transparent_and_deterministic() {
    let mut cfg = presets::cloud_scenario(RegionPolicyKind::FlexibleShape);
    short_cloud(&mut cfg, 400.0);

    let mut t_off = Trace::new(1 << 20);
    let r_off = run_cloud_traced(&cfg, TaskLibrary::table1(), &mut t_off).unwrap();

    let run = || {
        let mut t = Trace::new(1 << 20);
        let mut obs = Obs::enabled(1 << 16);
        let r = run_cloud_observed(&cfg, TaskLibrary::table1(), &mut t, &mut obs).unwrap();
        (render(&t), format!("{r:?}"), obs)
    };
    let (trace_a, report_a, obs_a) = run();
    let (trace_b, report_b, obs_b) = run();

    // transparency: obs-on changes no trace byte and no report field
    assert_eq!(render(&t_off), trace_a, "obs-enabled trace diverged from obs-disabled");
    assert_eq!(format!("{r_off:?}"), report_a, "obs-enabled report diverged");

    // determinism: identical journals (digest + event count) and
    // identical metric expositions across repeat runs
    assert!(!obs_a.journal.is_empty(), "enabled journal recorded nothing");
    assert_eq!(obs_a.journal.len(), obs_b.journal.len());
    assert_eq!(obs_a.journal.digest(), obs_b.journal.digest());
    assert_eq!(obs_a.registry.render(), obs_b.registry.render());
    assert_eq!(trace_a, trace_b);
    assert_eq!(report_a, report_b);

    // the exposition carries the sim-level series
    let exposition = obs_a.registry.render();
    assert!(exposition.contains("cgra_sim_submitted_total"), "{exposition}");
    assert!(exposition.contains("cgra_req_turnaround_cycles_count"), "{exposition}");
}

#[test]
fn edge_obs_enabled_is_trace_transparent() {
    let mut cfg = presets::edge_scenario(RegionPolicyKind::FlexibleShape);
    short_edge(&mut cfg, 120);

    let mut t_off = Trace::new(1 << 20);
    let r_off = run_edge_traced(&cfg, TaskLibrary::table1(), &mut t_off).unwrap();

    let mut t_on = Trace::new(1 << 20);
    let mut obs = Obs::enabled(1 << 16);
    let r_on = run_edge_observed(&cfg, TaskLibrary::table1(), &mut t_on, &mut obs).unwrap();

    assert_eq!(render(&t_off), render(&t_on));
    assert_eq!(format!("{r_off:?}"), format!("{r_on:?}"));
    assert!(!obs.journal.is_empty());
    let exposition = obs.registry.render();
    assert!(exposition.contains("cgra_sim_frames_total"), "{exposition}");
    assert!(exposition.contains("cgra_frame_latency_cycles_count"), "{exposition}");
}

#[test]
fn sharded_pool_obs_is_transparent_and_digest_deterministic() {
    let mut cfg = presets::edge_scenario(RegionPolicyKind::FlexibleShape);
    cfg.pool.shards = 2;
    short_edge(&mut cfg, 100);

    let mut t_off = Trace::new(1 << 20);
    let r_off = run_edge_pool_traced(&cfg, TaskLibrary::table1(), &mut t_off).unwrap();

    let run = || {
        let mut t = Trace::new(1 << 20);
        let mut obs = Obs::enabled(1 << 16);
        let r = run_edge_pool_observed(&cfg, TaskLibrary::table1(), &mut t, &mut obs).unwrap();
        (render(&t), format!("{r:?}"), obs)
    };
    let (trace_a, report_a, obs_a) = run();
    let (trace_b, _, obs_b) = run();

    assert_eq!(render(&t_off), trace_a);
    assert_eq!(format!("{r_off:?}"), report_a);
    assert_eq!(trace_a, trace_b);
    assert_eq!(obs_a.journal.digest(), obs_b.journal.digest());
    // shard tags in the journal agree with the trace's `shard=` prefixes
    assert!(obs_a.journal.events().any(|e| e.shard == 0));
    let trace_saw_shard_1 = trace_a.contains("shard=1 ");
    let journal_saw_shard_1 = obs_a.journal.events().any(|e| e.shard == 1);
    assert_eq!(trace_saw_shard_1, journal_saw_shard_1, "journal shard tags diverge from trace");
}

#[test]
fn perfetto_export_of_a_real_run_round_trips_the_json_parser() {
    let mut cfg = presets::cloud_scenario(RegionPolicyKind::FlexibleShape);
    short_cloud(&mut cfg, 300.0);
    let mut t = Trace::new(1 << 20);
    let mut obs = Obs::from_config(&cfg);
    // from_config honors the [obs] gate: disabled by default
    assert!(!obs.on());
    cfg.obs.enabled = true;
    obs = Obs::from_config(&cfg);
    run_cloud_observed(&cfg, TaskLibrary::table1(), &mut t, &mut obs).unwrap();

    let text = perfetto::export_string(&obs.journal, cfg.arch.core_clock_mhz as u64);
    let json = Json::parse(&text).expect("perfetto export must be valid JSON");
    assert_eq!(json.to_string(), text, "parse → render must be the identity");
    let events = json.get("traceEvents").expect("traceEvents key");
    let Json::Arr(items) = events else {
        panic!("traceEvents is not an array");
    };
    assert!(!items.is_empty(), "no trace events exported");
    for ev in items {
        let ph = ev.get("ph").and_then(|p| p.as_str()).expect("ph field");
        assert!(["X", "i", "M"].contains(&ph), "unexpected phase {ph}");
        assert!(ev.get("pid").is_some());
    }
    assert_eq!(json.get("displayTimeUnit").and_then(|u| u.as_str()), Some("ms"));
}
