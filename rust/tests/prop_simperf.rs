//! Property tests for the simulator hot-path data structures.
//!
//! The throughput overhaul replaced linear scans with incrementally
//! maintained structures; these properties pin the structures to their
//! simple oracles under arbitrary random interleavings:
//!
//! * the binary-heap [`EventQueue`] must pop in `(time, insertion
//!   order)` — FIFO among simultaneous events — for any push/pop mix;
//! * the [`SliceMap`] free-run index (updated in place on every
//!   occupy/release) must equal a from-scratch recompute over the
//!   authoritative busy bitmap after every operation;
//! * the [`RegionManager`]'s read-only fit predicate (shared with the
//!   reusable [`cgra_mte::regions::FitProbe`] scratch) must agree with
//!   both a fresh probe and the actual allocation outcome across random
//!   allocate/release/relocate sequences.

use cgra_mte::abstraction::{SliceDemand, SliceMap, SliceRange};
use cgra_mte::config::{ArchConfig, RegionPolicyKind, SchedulerConfig};
use cgra_mte::regions::{AllocOutcome, ExecutionRegion, RegionManager};
use cgra_mte::sim::EventQueue;
use cgra_mte::testutil::{forall_cfg, PropConfig};
use cgra_mte::util::rng::Rng;

// ---------------------------------------------------------- event queue

/// Random op stream: `(dt, pop)` — push at `now + dt` (small deltas make
/// ties common), or pop when `pop` is set.
fn eq_ops(rng: &mut Rng, size: u32) -> Vec<(u64, bool)> {
    let len = 4 + rng.below(size as u64 * 4 + 1) as usize;
    (0..len).map(|_| (rng.below(4), rng.chance(0.35))).collect()
}

#[test]
fn event_queue_pops_in_time_then_insertion_order() {
    forall_cfg(PropConfig { cases: 96, seed: 0x51AFE7, max_size: 48 }, &eq_ops, |ops| {
        let mut q = EventQueue::new();
        // oracle: pending (at, seq, id) triples; pop order is min (at, seq)
        let mut model: Vec<(u64, u64, u64)> = Vec::new();
        let mut seq = 0u64;
        for &(dt, pop) in ops {
            if pop && !model.is_empty() {
                let k = model
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, m)| (m.0, m.1))
                    .map(|(i, _)| i)
                    .expect("non-empty model");
                let (at, _, id) = model.remove(k);
                if q.pop() != Some((at, id)) {
                    return false;
                }
            } else {
                let at = q.now() + dt;
                q.push(at, seq);
                model.push((at, seq, seq));
                seq += 1;
            }
        }
        // drain: the remaining events come out in full (time, seq) order
        while let Some((at, id)) = q.pop() {
            let k = match model.iter().enumerate().min_by_key(|(_, m)| (m.0, m.1)) {
                Some((i, _)) => i,
                None => return false,
            };
            let (want_at, _, want_id) = model.remove(k);
            if (at, id) != (want_at, want_id) {
                return false;
            }
        }
        model.is_empty()
    });
}

// ------------------------------------------------------- free-run index

/// From-scratch recompute of the maximal free runs, reading only the
/// authoritative bitmap (via single-slice `range_free` queries) — fully
/// independent of the incremental index it checks.
fn oracle_runs(m: &SliceMap) -> Vec<SliceRange> {
    let mut runs = Vec::new();
    let mut start: Option<u32> = None;
    for i in 0..m.len() {
        if m.range_free(&SliceRange::new(i, 1)) {
            if start.is_none() {
                start = Some(i);
            }
        } else if let Some(s) = start.take() {
            runs.push(SliceRange::new(s, i - s));
        }
    }
    if let Some(s) = start {
        runs.push(SliceRange::new(s, m.len() - s));
    }
    runs
}

/// Random op stream: `(len, from, release)` — occupy the leftmost free
/// run of `len` at/after `from`, or release a random live range.
fn sm_ops(rng: &mut Rng, size: u32) -> Vec<(u32, u32, bool)> {
    let len = 8 + rng.below(size as u64 * 3 + 1) as usize;
    (0..len)
        .map(|_| {
            (
                rng.range_inclusive(1, 5) as u32,
                rng.range_inclusive(0, 31) as u32,
                rng.chance(0.45),
            )
        })
        .collect()
}

#[test]
fn free_run_index_matches_bitmap_recompute() {
    forall_cfg(PropConfig { cases: 96, seed: 0x1DEA5, max_size: 40 }, &sm_ops, |ops| {
        let mut m = SliceMap::new(32);
        let mut live: Vec<SliceRange> = Vec::new();
        let mut rng = Rng::new(ops.len() as u64);
        for &(len, from, release) in ops {
            if release && !live.is_empty() {
                let idx = rng.below(live.len() as u64) as usize;
                let r = live.swap_remove(idx);
                m.release(&r);
            } else if let Some(r) = m.find_free_run_from(from, len) {
                m.occupy(&r);
                live.push(r);
            }
            let oracle = oracle_runs(&m);
            if m.free_runs() != oracle {
                return false;
            }
            if m.free_count() != oracle.iter().map(|r| r.len).sum::<u32>() {
                return false;
            }
            // derived queries read the same index
            let longest = oracle.iter().max_by_key(|r| r.len).copied();
            if m.longest_free_run().len != longest.map_or(0, |r| r.len) {
                return false;
            }
        }
        // full teardown coalesces back to one all-free run
        for r in live.drain(..) {
            m.release(&r);
        }
        m.free_runs() == oracle_runs(&m) && m.free_count() == 32
    });
}

// ------------------------------------------- manager + fit-probe scratch

/// Random op stream: `(glb, array, action)` — allocate (action ≥ 2),
/// release (0), or relocate-to-leftmost (1).
fn mgr_ops(rng: &mut Rng, size: u32) -> Vec<(u32, u32, u64)> {
    let len = 6 + rng.below(size as u64 * 2 + 1) as usize;
    (0..len)
        .map(|_| {
            (
                rng.range_inclusive(0, 20) as u32,
                rng.range_inclusive(1, 7) as u32,
                rng.below(5),
            )
        })
        .collect()
}

#[test]
fn fit_predicate_agrees_with_probe_and_allocation_outcome() {
    forall_cfg(PropConfig { cases: 64, seed: 0xF17B07, max_size: 32 }, &mgr_ops, |ops| {
        let arch = ArchConfig::default();
        let sched = SchedulerConfig {
            region_policy: RegionPolicyKind::FlexibleShape,
            ..SchedulerConfig::default()
        };
        let mut mgr = RegionManager::new(&arch, &sched);
        let mut live: Vec<ExecutionRegion> = Vec::new();
        let mut rng = Rng::new(ops.len() as u64 ^ 0x9E37);
        for &(glb, array, action) in ops {
            match action {
                0 if !live.is_empty() => {
                    let idx = rng.below(live.len() as u64) as usize;
                    let r = live.swap_remove(idx);
                    if mgr.release(r.id).is_err() {
                        return false;
                    }
                }
                1 if !live.is_empty() => {
                    // relocate to the leftmost free runs; a target that
                    // is free right now must always be accepted, and the
                    // index must absorb the move (checked internally by
                    // the debug oracle on every occupy/release).
                    let idx = rng.below(live.len() as u64) as usize;
                    let (id, gl, al) =
                        (live[idx].id, live[idx].glb_slices(), live[idx].array_slices());
                    let tgt_g = mgr.glb_map().find_free_run(gl);
                    let tgt_a = mgr.array_map().find_free_run(al);
                    if let (Some(g), Some(a)) = (tgt_g, tgt_a) {
                        if mgr.relocate(id, Some(g), Some(a)).is_err() {
                            return false;
                        }
                        live[idx].glb = vec![g];
                        live[idx].array = vec![a];
                    }
                }
                _ => {
                    let demand = SliceDemand::new(glb, array);
                    let fits = mgr.can_fit_now(&demand);
                    // a fresh probe with no what-if releases sees the
                    // live occupancy — it must agree with the manager
                    if mgr.fit_probe().can_fit_now(&demand) != fits {
                        return false;
                    }
                    match mgr.try_allocate(&demand) {
                        AllocOutcome::Allocated(r) => {
                            if !fits {
                                return false;
                            }
                            live.push(r);
                        }
                        AllocOutcome::NoFit => {
                            if fits {
                                return false;
                            }
                        }
                        AllocOutcome::NeverFits => {}
                    }
                }
            }
            // conservation: region bookkeeping matches the maps
            let busy_g: u32 = live.iter().map(|r| r.glb_slices()).sum();
            let busy_a: u32 = live.iter().map(|r| r.array_slices()).sum();
            if mgr.glb_map().busy_count() != busy_g
                || mgr.array_map().busy_count() != busy_a
            {
                return false;
            }
        }
        for r in live.drain(..) {
            if mgr.release(r.id).is_err() {
                return false;
            }
        }
        mgr.idle()
    });
}

#[test]
fn probe_reset_rewinds_what_if_releases() {
    // One probe, many what-ifs: releasing regions on the probe must not
    // leak into the next what-if after reset(), and must never touch the
    // underlying manager.
    let arch = ArchConfig::default();
    let sched = SchedulerConfig {
        region_policy: RegionPolicyKind::FlexibleShape,
        ..SchedulerConfig::default()
    };
    let mut mgr = RegionManager::new(&arch, &sched);
    let a = match mgr.try_allocate(&SliceDemand::new(16, 4)) {
        AllocOutcome::Allocated(r) => r,
        _ => panic!("first allocation must fit"),
    };
    let b = match mgr.try_allocate(&SliceDemand::new(16, 4)) {
        AllocOutcome::Allocated(r) => r,
        _ => panic!("second allocation must fit"),
    };
    let big = SliceDemand::new(20, 6);
    assert!(!mgr.can_fit_now(&big), "machine is full");

    let mut probe = mgr.fit_probe();
    assert!(!probe.can_fit_now(&big));
    probe.release(a.id).unwrap();
    probe.release(b.id).unwrap();
    assert!(probe.can_fit_now(&big), "what-if with both victims freed");
    probe.reset();
    assert!(!probe.can_fit_now(&big), "reset rewinds the what-if");
    probe.release(a.id).unwrap();
    assert!(!probe.can_fit_now(&big), "one victim is not enough");
    drop(probe);
    // the manager never saw any of it
    assert!(!mgr.can_fit_now(&big));
    assert_eq!(mgr.active_count(), 2);
    mgr.release(a.id).unwrap();
    mgr.release(b.id).unwrap();
    assert!(mgr.idle());
}
