//! Property tests on the fabric pool: under random submit / schedule /
//! complete / defrag sequences across shards, no task instance is ever
//! placed twice, per-shard busy-slice conservation holds (the sum of
//! live region footprints equals the occupancy maps), placement
//! accounting agrees with the shard queues — and a single-shard pool
//! is operation-for-operation identical to the bare single-fabric
//! scheduler (the golden-equivalence property that keeps
//! `pool.shards = 1` bit-for-bit compatible).

use std::collections::BTreeSet;

use cgra_mte::config::{presets, DefragPolicyKind, PlacementPolicyKind, SchedulerPolicyKind};
use cgra_mte::dpr::DprMode;
use cgra_mte::fabric::{FabricPool, ShardId};
use cgra_mte::scheduler::{RequestQueue, Scheduler};
use cgra_mte::sim::{run_cloud_pool_traced, run_cloud_traced, Trace};
use cgra_mte::tasks::{AppId, AppRequest, TaskLibrary};
use cgra_mte::testutil::{forall_cfg, PropConfig};
use cgra_mte::util::rng::Rng;

/// One pool operation.
#[derive(Clone, Copy, Debug)]
enum Op {
    /// Submit app `ALL[app % 4]` for tenant `tenant % 4`.
    Submit(u32, u32),
    /// One scheduling step across every shard.
    Step,
    /// Complete a random outstanding launch.
    Complete,
    /// Force a compaction pass on shard `s % shard_count`.
    Defrag(u32),
}

fn op_seq(rng: &mut Rng, size: u32) -> Vec<Op> {
    let len = 8 + rng.below(size as u64 * 2 + 1) as usize;
    (0..len)
        .map(|_| match rng.below(10) {
            0..=3 => Op::Submit(rng.below(4) as u32, rng.below(4) as u32),
            4..=6 => Op::Step,
            7..=8 => Op::Complete,
            _ => Op::Defrag(rng.below(4) as u32),
        })
        .collect()
}

/// Per-shard busy-slice conservation + placement-accounting coherence.
fn pool_invariants_hold(pool: &FabricPool) -> bool {
    for i in 0..pool.shard_count() {
        let mgr = pool.scheduler(ShardId(i as u32)).expect("shard exists").regions();
        let (mut g, mut a) = (0u32, 0u32);
        for r in mgr.active() {
            g += r.glb_slices();
            a += r.array_slices();
        }
        if mgr.glb_map().busy_count() != g || mgr.array_map().busy_count() != a {
            return false;
        }
    }
    pool.open_requests() == pool.queue_open_requests() as u64
}

/// Random op sequences over a multi-shard pool: no double placement,
/// conservation, coherent accounting, and a clean teardown.
#[test]
fn pool_invariants_under_random_ops() {
    let cfg = PropConfig { cases: 40, seed: 0x5AAD_F00D, max_size: 24 };
    forall_cfg(cfg, &op_seq, |ops| {
        let mut pool_cfg = presets::pool_scenario(3, PlacementPolicyKind::LeastLoaded);
        pool_cfg.scheduler.policy = SchedulerPolicyKind::FcfsFirstFit;
        pool_cfg.scheduler.defrag_policy = DefragPolicyKind::Greedy;
        pool_cfg.scheduler.defrag_threshold = 0.1;
        let mut pool = FabricPool::new(&pool_cfg, TaskLibrary::table1(), DprMode::Fast)
            .expect("pool builds");
        pool.preload_all();

        let mut rng = Rng::new(ops.len() as u64 + 7);
        let mut now = 0u64;
        let mut seq = 0u64;
        // every (request, node) instance ever launched, pool-wide
        let mut launched = BTreeSet::new();
        // outstanding launches: (shard, region)
        let mut outstanding: Vec<(ShardId, cgra_mte::regions::RegionId)> = Vec::new();

        for op in ops {
            now += 1_000;
            match *op {
                Op::Submit(tenant, app) => {
                    let req =
                        AppRequest::new(seq, tenant % 4, AppId::ALL[app as usize % 4], now);
                    if pool.try_submit(req, now).is_none() {
                        return false; // no window configured: must admit
                    }
                    seq += 1;
                }
                Op::Step => {
                    for (shard, launch) in pool.schedule(now) {
                        // a task instance must never be placed twice,
                        // on any shard
                        if !launched.insert(launch.instance) {
                            return false;
                        }
                        outstanding.push((shard, launch.region));
                    }
                }
                Op::Complete => {
                    if !outstanding.is_empty() {
                        let idx = rng.below(outstanding.len() as u64) as usize;
                        let (shard, region) = outstanding.swap_remove(idx);
                        if pool.complete(shard, region, now).is_err() {
                            return false;
                        }
                    }
                }
                Op::Defrag(s) => {
                    let shard = ShardId(s % pool.shard_count() as u32);
                    if pool.defrag_shard(shard, now).is_err() {
                        return false;
                    }
                }
            }
            if !pool_invariants_hold(&pool) {
                return false;
            }
        }

        // teardown: run everything outstanding and queued to completion
        let mut guard = 0;
        loop {
            for (shard, launch) in pool.schedule(now) {
                if !launched.insert(launch.instance) {
                    return false;
                }
                outstanding.push((shard, launch.region));
            }
            if outstanding.is_empty() {
                break;
            }
            now += 1_000;
            let (shard, region) = outstanding.remove(0);
            if pool.complete(shard, region, now).is_err() || !pool_invariants_hold(&pool) {
                return false;
            }
            guard += 1;
            if guard > 10_000 {
                return false; // livelock
            }
        }
        pool.open_requests() == 0 && pool.ready_count() == 0
    });
}

/// Golden equivalence, operation level: a single-shard pool must make
/// exactly the moves the bare scheduler makes — same launches (field
/// for field), same completion outcomes, same defrag reports, same
/// occupancy — for any op sequence.
#[test]
fn single_shard_pool_equals_bare_scheduler() {
    let cfg = PropConfig { cases: 32, seed: 0x0601_DE9, max_size: 20 };
    forall_cfg(cfg, &op_seq, |ops| {
        let mut c = presets::pool_scenario(1, PlacementPolicyKind::LeastLoaded);
        c.scheduler.policy = SchedulerPolicyKind::FcfsFirstFit;
        c.scheduler.defrag_policy = DefragPolicyKind::Greedy;
        c.scheduler.defrag_threshold = 0.1;

        let mut pool =
            FabricPool::new(&c, TaskLibrary::table1(), DprMode::Fast).expect("pool builds");
        pool.preload_all();
        let mut bare = Scheduler::new(&c, TaskLibrary::table1(), DprMode::Fast);
        bare.preload_all();
        let mut bare_queue = RequestQueue::new();

        let mut rng = Rng::new(ops.len() as u64 + 7);
        let mut now = 0u64;
        let mut seq = 0u64;
        // parallel outstanding lists (same order on both sides)
        let mut pool_out: Vec<(ShardId, cgra_mte::regions::RegionId)> = Vec::new();
        let mut bare_out: Vec<cgra_mte::regions::RegionId> = Vec::new();

        for op in ops {
            now += 1_000;
            match *op {
                Op::Submit(tenant, app) => {
                    let a = AppId::ALL[app as usize % 4];
                    if pool.try_submit(AppRequest::new(seq, tenant % 4, a, now), now).is_none() {
                        return false;
                    }
                    bare_queue.submit(AppRequest::new(seq, tenant % 4, a, now));
                    seq += 1;
                }
                Op::Step => {
                    let pl = pool.schedule(now);
                    let bl = bare.schedule(&mut bare_queue, now);
                    if pl.len() != bl.len() {
                        return false;
                    }
                    for ((shard, p), b) in pl.iter().zip(&bl) {
                        // Launch has no PartialEq; the Debug rendering
                        // covers every field
                        if *shard != ShardId(0) || format!("{p:?}") != format!("{b:?}") {
                            return false;
                        }
                        pool_out.push((*shard, p.region));
                        bare_out.push(b.region);
                    }
                }
                Op::Complete => {
                    if !pool_out.is_empty() {
                        let idx = rng.below(pool_out.len() as u64) as usize;
                        let (shard, region) = pool_out.swap_remove(idx);
                        let b_region = bare_out.swap_remove(idx);
                        if region != b_region {
                            return false;
                        }
                        let p_done = match pool.complete(shard, region, now) {
                            Ok(d) => d.map(|r| r.seq),
                            Err(_) => return false,
                        };
                        let b_inst = match bare.complete(b_region, now) {
                            Ok(i) => i,
                            Err(_) => return false,
                        };
                        let b_done = match bare_queue.mark_complete(b_inst, now) {
                            Ok(d) => d.map(|r| r.seq),
                            Err(_) => return false,
                        };
                        if p_done != b_done {
                            return false;
                        }
                    }
                }
                Op::Defrag(_) => {
                    let p_report = match pool.defrag_shard(ShardId(0), now) {
                        Ok(r) => r,
                        Err(_) => return false,
                    };
                    let b_report = bare.defrag_now(now);
                    if p_report != b_report {
                        return false;
                    }
                }
            }
            // occupancy must agree exactly after every operation
            let mgr = pool.scheduler(ShardId(0)).expect("shard 0").regions();
            let bmgr = bare.regions();
            if mgr.render() != bmgr.render()
                || pool.ready_count() != bare_queue.ready_count()
                || pool.queue_open_requests() != bare_queue.open_requests()
            {
                return false;
            }
        }
        true
    });
}

/// Golden equivalence, simulation level: `pool.shards = 1` reproduces
/// the single-fabric cloud simulator's event trace byte-for-byte over
/// random seeds (churn knobs included).
#[test]
fn single_shard_pool_sim_trace_matches_across_seeds() {
    for (i, &seed) in [3u64, 11, 42, 0xC6_5A].iter().enumerate() {
        let mut cfg = if i % 2 == 0 {
            presets::pool_scenario(1, PlacementPolicyKind::LeastLoaded)
        } else {
            // churn preset: defrag + past-saturation load, pool added on
            let mut c = presets::churn_scenario(
                cgra_mte::config::RegionPolicyKind::FlexibleShape,
                DefragPolicyKind::CostAware,
            );
            c.pool = presets::pool_scenario(1, PlacementPolicyKind::LeastLoaded).pool;
            c
        };
        if let cgra_mte::config::WorkloadConfig::Cloud(ref mut c) = cfg.workload {
            c.seed = seed;
            c.duration_ms = 250.0;
        }
        let mut t_single = Trace::new(1 << 20);
        let single =
            run_cloud_traced(&cfg, TaskLibrary::table1(), &mut t_single).expect("single runs");
        let mut t_pool = Trace::new(1 << 20);
        let pooled =
            run_cloud_pool_traced(&cfg, TaskLibrary::table1(), &mut t_pool).expect("pool runs");

        let render = |t: &Trace| -> String {
            t.events().map(|e| format!("{} {}\n", e.at, e.what())).collect()
        };
        assert_eq!(render(&t_single), render(&t_pool), "seed {seed}: trace diverged");
        assert_eq!(single.submitted, pooled.submitted, "seed {seed}");
        assert_eq!(single.completed, pooled.completed, "seed {seed}");
        assert_eq!(single.launches, pooled.launches, "seed {seed}");
        assert_eq!(single.makespan_cycles, pooled.makespan_cycles, "seed {seed}");
    }
}
