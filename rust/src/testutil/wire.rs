//! Line-protocol TCP client for driving the coordinator's serving front.
//!
//! Shared by the loopback concurrency tests and the `tcp_client`
//! example/load generator so the wire handling (one line out, one line
//! back, retry on `BUSY` backpressure) lives in exactly one place.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};

use crate::error::{Error, Result};

/// One-line-out, one-line-back client for the SUBMIT/STATS protocol of
/// [`crate::coordinator::Server`].
pub struct WireClient {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl WireClient {
    /// Connect to a serving front.
    pub fn connect(addr: SocketAddr) -> Result<WireClient> {
        let stream =
            TcpStream::connect(addr).map_err(|e| Error::io(addr.to_string(), e))?;
        let writer = stream.try_clone().map_err(|e| Error::io("clone", e))?;
        Ok(WireClient { writer, reader: BufReader::new(stream) })
    }

    /// Send one protocol line; returns the reply line (trimmed).
    pub fn send(&mut self, line: &str) -> Result<String> {
        self.writer
            .write_all(format!("{line}\n").as_bytes())
            .map_err(|e| Error::io("write", e))?;
        let mut reply = String::new();
        self.reader
            .read_line(&mut reply)
            .map_err(|e| Error::io("read", e))?;
        Ok(reply.trim_end().to_string())
    }

    /// Read the `n` continuation lines of a multi-line reply whose
    /// header named the count (`STATS SHARDS` / `STATS ENERGY` framing).
    fn read_reply_lines(&mut self, n: usize, what: &str) -> Result<Vec<String>> {
        let mut lines = Vec::with_capacity(n);
        for _ in 0..n {
            let mut line = String::new();
            let read = self
                .reader
                .read_line(&mut line)
                .map_err(|e| Error::io("read", e))?;
            if read == 0 {
                return Err(Error::Runtime(format!(
                    "connection closed mid-reply: got {} of {n} {what} lines",
                    lines.len()
                )));
            }
            lines.push(line.trim_end().to_string());
        }
        Ok(lines)
    }

    /// Shard count named by a `STATS shards=<n> …` header.
    fn header_shard_count(header: &str, what: &str) -> Result<usize> {
        header
            .strip_prefix("STATS shards=")
            .and_then(|v| v.split_whitespace().next())
            .and_then(|v| v.parse().ok())
            .ok_or_else(|| Error::Runtime(format!("bad {what} header: {header}")))
    }

    /// `STATS SHARDS`: reads the `STATS shards=<n>` header plus the `n`
    /// per-shard lines that follow, returning the per-shard lines.
    pub fn stats_shards(&mut self) -> Result<Vec<String>> {
        let header = self.send("STATS SHARDS")?;
        let n = Self::header_shard_count(&header, "STATS SHARDS")?;
        self.read_reply_lines(n, "shard")
    }

    /// `STATS ENERGY`: reads the `STATS shards=<n> …` header plus the
    /// `n` per-shard energy lines that follow (same framing as
    /// [`WireClient::stats_shards`]); returns `(header, per-shard
    /// lines)`.
    pub fn stats_energy(&mut self) -> Result<(String, Vec<String>)> {
        let header = self.send("STATS ENERGY")?;
        let n = Self::header_shard_count(&header, "STATS ENERGY")?;
        let lines = self.read_reply_lines(n, "energy")?;
        Ok((header, lines))
    }

    /// `STATS QOS`: reads the `STATS classes=<n> …` header plus the `n`
    /// per-class lines that follow; returns `(header, class lines)`.
    pub fn stats_qos(&mut self) -> Result<(String, Vec<String>)> {
        let header = self.send("STATS QOS")?;
        let n: usize = header
            .strip_prefix("STATS classes=")
            .and_then(|v| v.split_whitespace().next())
            .and_then(|v| v.parse().ok())
            .ok_or_else(|| Error::Runtime(format!("bad STATS QOS header: {header}")))?;
        let lines = self.read_reply_lines(n, "class")?;
        Ok((header, lines))
    }

    /// `STATS NOC`: single-line reply — `STATS noc=off` while `[noc]`
    /// is disabled, else `STATS noc=on …` with the merged counters.
    pub fn stats_noc(&mut self) -> Result<String> {
        let reply = self.send("STATS NOC")?;
        if !reply.starts_with("STATS noc=") {
            return Err(Error::Runtime(format!("bad STATS NOC reply: {reply}")));
        }
        Ok(reply)
    }

    /// SUBMIT with retry on `BUSY` backpressure; returns the final
    /// (non-BUSY) reply and how many BUSY retries it took.
    pub fn submit(&mut self, tenant: u32, app: &str) -> Result<(String, u32)> {
        let mut retries = 0;
        loop {
            let reply = self.send(&format!("SUBMIT {tenant} {app}"))?;
            if reply.starts_with("BUSY") {
                retries += 1;
                std::thread::sleep(std::time::Duration::from_millis(1));
                continue;
            }
            return Ok((reply, retries));
        }
    }
}
