//! Line-protocol TCP client for driving the coordinator's serving front.
//!
//! Shared by the loopback concurrency tests and the `tcp_client`
//! example/load generator so the wire handling (one line out, one line
//! back, retry on `BUSY` backpressure) lives in exactly one place.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};

use crate::coordinator::frame::{self, Opcode};
use crate::error::{Error, Result};

/// One-line-out, one-line-back client for the SUBMIT/STATS protocol of
/// [`crate::coordinator::Server`].
pub struct WireClient {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl WireClient {
    /// Connect to a serving front.
    pub fn connect(addr: SocketAddr) -> Result<WireClient> {
        let stream =
            TcpStream::connect(addr).map_err(|e| Error::io(addr.to_string(), e))?;
        let writer = stream.try_clone().map_err(|e| Error::io("clone", e))?;
        Ok(WireClient { writer, reader: BufReader::new(stream) })
    }

    /// Send one protocol line; returns the reply line (trimmed).
    pub fn send(&mut self, line: &str) -> Result<String> {
        self.writer
            .write_all(format!("{line}\n").as_bytes())
            .map_err(|e| Error::io("write", e))?;
        let mut reply = String::new();
        self.reader
            .read_line(&mut reply)
            .map_err(|e| Error::io("read", e))?;
        Ok(reply.trim_end().to_string())
    }

    /// Read the `n` continuation lines of a multi-line reply whose
    /// header named the count (`STATS SHARDS` / `STATS ENERGY` framing).
    fn read_reply_lines(&mut self, n: usize, what: &str) -> Result<Vec<String>> {
        let mut lines = Vec::with_capacity(n);
        for _ in 0..n {
            let mut line = String::new();
            let read = self
                .reader
                .read_line(&mut line)
                .map_err(|e| Error::io("read", e))?;
            if read == 0 {
                return Err(Error::Runtime(format!(
                    "connection closed mid-reply: got {} of {n} {what} lines",
                    lines.len()
                )));
            }
            lines.push(line.trim_end().to_string());
        }
        Ok(lines)
    }

    /// Shard count named by a `STATS shards=<n> …` header.
    fn header_shard_count(header: &str, what: &str) -> Result<usize> {
        header
            .strip_prefix("STATS shards=")
            .and_then(|v| v.split_whitespace().next())
            .and_then(|v| v.parse().ok())
            .ok_or_else(|| Error::Runtime(format!("bad {what} header: {header}")))
    }

    /// `STATS SHARDS`: reads the `STATS shards=<n>` header plus the `n`
    /// per-shard lines that follow, returning the per-shard lines.
    pub fn stats_shards(&mut self) -> Result<Vec<String>> {
        let header = self.send("STATS SHARDS")?;
        let n = Self::header_shard_count(&header, "STATS SHARDS")?;
        self.read_reply_lines(n, "shard")
    }

    /// `STATS ENERGY`: reads the `STATS shards=<n> …` header plus the
    /// `n` per-shard energy lines that follow (same framing as
    /// [`WireClient::stats_shards`]); returns `(header, per-shard
    /// lines)`.
    pub fn stats_energy(&mut self) -> Result<(String, Vec<String>)> {
        let header = self.send("STATS ENERGY")?;
        let n = Self::header_shard_count(&header, "STATS ENERGY")?;
        let lines = self.read_reply_lines(n, "energy")?;
        Ok((header, lines))
    }

    /// `STATS QOS`: reads the `STATS classes=<n> …` header plus the `n`
    /// per-class lines that follow; returns `(header, class lines)`.
    pub fn stats_qos(&mut self) -> Result<(String, Vec<String>)> {
        let header = self.send("STATS QOS")?;
        let n: usize = header
            .strip_prefix("STATS classes=")
            .and_then(|v| v.split_whitespace().next())
            .and_then(|v| v.parse().ok())
            .ok_or_else(|| Error::Runtime(format!("bad STATS QOS header: {header}")))?;
        let lines = self.read_reply_lines(n, "class")?;
        Ok((header, lines))
    }

    /// `STATS NOC`: single-line reply — `STATS noc=off` while `[noc]`
    /// is disabled, else `STATS noc=on …` with the merged counters.
    pub fn stats_noc(&mut self) -> Result<String> {
        let reply = self.send("STATS NOC")?;
        if !reply.starts_with("STATS noc=") {
            return Err(Error::Runtime(format!("bad STATS NOC reply: {reply}")));
        }
        Ok(reply)
    }

    /// Continuation-line count named by a multi-line reply header
    /// (`STATS shards=`/`classes=`, `METRICS lines=`, `EXPLAIN …
    /// lines=`, `DUMP lines=`); 0 for single-line replies.
    fn continuation_count(header: &str) -> usize {
        let framed = header.starts_with("STATS shards=")
            || header.starts_with("STATS classes=")
            || header.starts_with("METRICS lines=")
            || header.starts_with("EXPLAIN req=")
            || header.starts_with("DUMP lines=");
        if !framed {
            return 0;
        }
        header
            .split_whitespace()
            .find_map(|tok| {
                tok.strip_prefix("lines=")
                    .or_else(|| tok.strip_prefix("shards="))
                    .or_else(|| tok.strip_prefix("classes="))
            })
            .and_then(|v| v.parse().ok())
            .unwrap_or(0)
    }

    /// Send one protocol line and read the *whole* reply, following the
    /// count-framing rule: a header naming a continuation count
    /// ([`Self::continuation_count`]) is followed by that many lines;
    /// everything else is one line.  Multi-line replies come back
    /// joined with `\n` — byte-identical to the binary protocol's reply
    /// payload, which is what the conformance suite compares.
    pub fn send_blob(&mut self, line: &str) -> Result<String> {
        let header = self.send(line)?;
        let n = Self::continuation_count(&header);
        if n == 0 {
            return Ok(header);
        }
        let lines = self.read_reply_lines(n, "continuation")?;
        let mut blob = header;
        for l in lines {
            blob.push('\n');
            blob.push_str(&l);
        }
        Ok(blob)
    }

    /// `METRICS`: reads the `METRICS lines=<n> dropped=<d>` header plus
    /// the `n` Prometheus-style exposition lines that follow, returning
    /// the exposition lines (comment lines included).
    pub fn metrics(&mut self) -> Result<Vec<String>> {
        Ok(self.metrics_full()?.1)
    }

    /// `METRICS` returning `(header, exposition lines)` — the header
    /// also carries the journal-drop count (`dropped=<d>`).
    pub fn metrics_full(&mut self) -> Result<(String, Vec<String>)> {
        let header = self.send("METRICS")?;
        let n: usize = header
            .strip_prefix("METRICS lines=")
            .and_then(|v| v.split_whitespace().next())
            .and_then(|v| v.parse().ok())
            .ok_or_else(|| Error::Runtime(format!("bad METRICS header: {header}")))?;
        let lines = self.read_reply_lines(n, "metrics")?;
        Ok((header, lines))
    }

    /// `EXPLAIN <req>`: reads the `EXPLAIN req=<r> lines=<n>` header
    /// plus the `n` decision-chain lines; returns `(header, lines)`.
    pub fn explain(&mut self, req: u64) -> Result<(String, Vec<String>)> {
        let header = self.send(&format!("EXPLAIN {req}"))?;
        let n: usize = header
            .starts_with("EXPLAIN req=")
            .then(|| {
                header
                    .split_whitespace()
                    .find_map(|tok| tok.strip_prefix("lines="))
                    .and_then(|v| v.parse().ok())
            })
            .flatten()
            .ok_or_else(|| Error::Runtime(format!("bad EXPLAIN header: {header}")))?;
        let lines = self.read_reply_lines(n, "explain")?;
        Ok((header, lines))
    }

    /// `DUMP`: reads the `DUMP lines=1` header and returns the one-line
    /// flight-record JSON that follows.
    pub fn dump(&mut self) -> Result<String> {
        let header = self.send("DUMP")?;
        if header != "DUMP lines=1" {
            return Err(Error::Runtime(format!("bad DUMP header: {header}")));
        }
        Ok(self.read_reply_lines(1, "dump")?.remove(0))
    }

    /// `WATCH`: subscribe to the live journal stream.  Events published
    /// after the `WATCH ok` reply are queued server-side whether or not
    /// the client is reading yet; collect them with
    /// [`WireClient::watch_finish`].
    pub fn watch_subscribe(&mut self) -> Result<()> {
        let ok = self.send("WATCH")?;
        if ok != "WATCH ok" {
            return Err(Error::Runtime(format!("bad WATCH reply: {ok}")));
        }
        Ok(())
    }

    /// Read until `min_events` `EVENT` lines have arrived on a live
    /// watch, then end the stream (any request line does) and return
    /// `(events, trailer)` — the trailer is the `WATCH done events=<d>
    /// dropped=<n>` line; events that were in flight when the stream
    /// ended are included.
    pub fn watch_finish(&mut self, min_events: usize) -> Result<(Vec<String>, String)> {
        let mut events = Vec::new();
        while events.len() < min_events {
            let line = self.read_reply_lines(1, "watch")?.remove(0);
            events.push(line);
        }
        // any request line ends the stream (consumed, not executed)
        self.writer
            .write_all(b"STOP\n")
            .map_err(|e| Error::io("write", e))?;
        loop {
            let line = self.read_reply_lines(1, "watch")?.remove(0);
            if line.starts_with("WATCH done") {
                return Ok((events, line));
            }
            events.push(line);
        }
    }

    /// [`WireClient::watch_subscribe`] + [`WireClient::watch_finish`]
    /// in one call, for sessions where the event source is already
    /// running.
    pub fn watch_collect(&mut self, min_events: usize) -> Result<(Vec<String>, String)> {
        self.watch_subscribe()?;
        self.watch_finish(min_events)
    }

    /// SUBMIT with retry on `BUSY` backpressure; returns the final
    /// (non-BUSY) reply and how many BUSY retries it took.
    pub fn submit(&mut self, tenant: u32, app: &str) -> Result<(String, u32)> {
        let mut retries = 0;
        loop {
            let reply = self.send(&format!("SUBMIT {tenant} {app}"))?;
            if reply.starts_with("BUSY") {
                retries += 1;
                std::thread::sleep(std::time::Duration::from_millis(1));
                continue;
            }
            return Ok((reply, retries));
        }
    }
}

/// One reply frame from the binary protocol, decoded into owned fields.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BinReply {
    /// Reply opcode (`ReplyOk`, `ReplyBusy`, `ReplyStats`, …).
    pub opcode: Opcode,
    /// Request id echoed back from the matching request frame.
    pub req_id: u64,
    /// Reply payload: the exact text-protocol reply bytes (multi-line
    /// replies such as `STATS SHARDS` arrive as one frame).
    pub text: String,
}

/// Length-prefixed binary-framing client for the reactor front
/// (`server.protocol = "binary"` / `"auto"`).  One request frame out,
/// one reply frame back — the framed twin of [`WireClient`].
pub struct BinWireClient {
    stream: TcpStream,
    rbuf: Vec<u8>,
    next_req_id: u64,
}

impl BinWireClient {
    /// Connect to a serving front speaking the framed protocol.
    pub fn connect(addr: SocketAddr) -> Result<BinWireClient> {
        let stream =
            TcpStream::connect(addr).map_err(|e| Error::io(addr.to_string(), e))?;
        Ok(BinWireClient { stream, rbuf: Vec::new(), next_req_id: 1 })
    }

    /// Send one request frame (auto-assigned request id) and block for
    /// its reply frame.
    pub fn request(&mut self, opcode: Opcode, tenant: u16, payload: &[u8]) -> Result<BinReply> {
        let req_id = self.next_req_id;
        self.next_req_id += 1;
        let wire = frame::encode(opcode, tenant, req_id, payload);
        self.stream.write_all(&wire).map_err(|e| Error::io("write frame", e))?;
        self.read_reply()
    }

    /// Block until one complete reply frame is decodable from the
    /// connection, consuming it from the read buffer.
    pub fn read_reply(&mut self) -> Result<BinReply> {
        let mut chunk = [0u8; 4096];
        loop {
            let (done, consumed) = {
                match frame::decode(&self.rbuf) {
                    Ok(Some((f, consumed))) => {
                        let text = String::from_utf8(f.payload.to_vec()).map_err(|_| {
                            Error::Runtime("reply payload not utf-8".into())
                        })?;
                        (Some(BinReply { opcode: f.opcode, req_id: f.req_id, text }), consumed)
                    }
                    Ok(None) => (None, 0),
                    Err(e) => return Err(Error::Runtime(format!("bad reply frame: {e}"))),
                }
            };
            if let Some(reply) = done {
                self.rbuf.drain(..consumed);
                return Ok(reply);
            }
            let n = self.stream.read(&mut chunk).map_err(|e| Error::io("read frame", e))?;
            if n == 0 {
                return Err(Error::Runtime("connection closed mid-frame".into()));
            }
            self.rbuf.extend_from_slice(&chunk[..n]);
        }
    }

    /// Framed SUBMIT with retry on BUSY backpressure; returns the final
    /// (non-BUSY) reply and how many BUSY retries it took.  The payload
    /// mirrors the text form minus the tenant, which rides the header:
    /// `<app> [class] [deadline_ms]`.
    pub fn submit(&mut self, tenant: u16, args: &str) -> Result<(BinReply, u32)> {
        let mut retries = 0;
        loop {
            let reply = self.request(Opcode::Submit, tenant, args.as_bytes())?;
            if reply.opcode == Opcode::ReplyBusy {
                retries += 1;
                std::thread::sleep(std::time::Duration::from_millis(1));
                continue;
            }
            return Ok((reply, retries));
        }
    }

    /// Framed STATS; `sub` is the subcommand payload (`""` for the
    /// aggregate line, `"SHARDS"`, `"ENERGY"`, `"QOS"`, `"NOC"`, or a
    /// tenant number).
    pub fn stats(&mut self, sub: &str) -> Result<BinReply> {
        self.request(Opcode::Stats, 0, sub.as_bytes())
    }

    /// Framed QUIT; returns the `BYE` reply.
    pub fn quit(&mut self) -> Result<BinReply> {
        self.request(Opcode::Quit, 0, b"")
    }

    /// Framed EXPLAIN; the payload is the decimal request sequence
    /// number.
    pub fn explain(&mut self, req: u64) -> Result<BinReply> {
        self.request(Opcode::Explain, 0, req.to_string().as_bytes())
    }

    /// Framed DUMP; the reply payload is `DUMP lines=1\n<json>`.
    pub fn dump(&mut self) -> Result<BinReply> {
        self.request(Opcode::Dump, 0, b"")
    }

    /// Framed WATCH: subscribe to the live journal stream (events are
    /// queued server-side from the `WATCH ok` reply onward).
    pub fn watch_subscribe(&mut self) -> Result<()> {
        let ok = self.request(Opcode::Watch, 0, b"")?;
        if ok.text != "WATCH ok" {
            return Err(Error::Runtime(format!("bad WATCH reply: {}", ok.text)));
        }
        Ok(())
    }

    /// Read `min_events` `EVENT` frames on a live watch, end the stream
    /// with a no-op request (consumed by the server, not executed), and
    /// return `(event frames, trailer frame)`.
    pub fn watch_finish(&mut self, min_events: usize) -> Result<(Vec<BinReply>, BinReply)> {
        let mut events = Vec::new();
        while events.len() < min_events {
            events.push(self.read_reply()?);
        }
        let req_id = self.next_req_id;
        self.next_req_id += 1;
        let wire = frame::encode(Opcode::Stats, 0, req_id, b"");
        self.stream.write_all(&wire).map_err(|e| Error::io("write frame", e))?;
        loop {
            let r = self.read_reply()?;
            if r.text.starts_with("WATCH done") {
                return Ok((events, r));
            }
            events.push(r);
        }
    }
}
