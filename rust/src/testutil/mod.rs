//! Mini property-testing harness (`proptest` is unavailable offline),
//! plus the [`wire`] TCP client shared by the server's loopback tests
//! and the `tcp_client` example.
//!
//! [`forall`] runs a property over generated cases with linear shrinking
//! on failure: when a case fails, the harness re-runs the property on
//! progressively "smaller" cases produced by the generator's shrink
//! order (re-generation with smaller size budgets), reporting the
//! smallest failing seed.  Properties are deterministic per seed, so a
//! failure message's seed reproduces exactly.

pub mod wire;

use crate::util::rng::Rng;

/// Case generator: produces a value from an RNG and a size budget.
pub trait Gen {
    /// Generated value type.
    type Value;
    /// Generate one value; `size` scales magnitude/length (1..=255).
    fn generate(&self, rng: &mut Rng, size: u32) -> Self::Value;
}

impl<T, F: Fn(&mut Rng, u32) -> T> Gen for F {
    type Value = T;
    fn generate(&self, rng: &mut Rng, size: u32) -> T {
        self(rng, size)
    }
}

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct PropConfig {
    /// Number of cases.
    pub cases: u32,
    /// Base seed (each case derives seed+index).
    pub seed: u64,
    /// Maximum size budget (cases sweep 1..=max_size).
    pub max_size: u32,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 64, seed: 0xC6_5A, max_size: 64 }
    }
}

/// Run `property` over `cases` generated values; panics with the seed
/// and a shrunk case description on failure.
pub fn forall<G, P>(gen: &G, property: P)
where
    G: Gen,
    G::Value: std::fmt::Debug,
    P: Fn(&G::Value) -> bool,
{
    forall_cfg(PropConfig::default(), gen, property)
}

/// [`forall`] with explicit configuration.
pub fn forall_cfg<G, P>(cfg: PropConfig, gen: &G, property: P)
where
    G: Gen,
    G::Value: std::fmt::Debug,
    P: Fn(&G::Value) -> bool,
{
    for case in 0..cfg.cases {
        let seed = cfg.seed.wrapping_add(case as u64);
        // sweep sizes so small cases run early (cheap shrinking)
        let size = 1 + (case * cfg.max_size / cfg.cases.max(1)).min(cfg.max_size - 1);
        let mut rng = Rng::new(seed);
        let value = gen.generate(&mut rng, size);
        if !property(&value) {
            // shrink: retry with smaller sizes on the same seed, keep the
            // smallest size that still fails.
            let mut smallest = (size, format!("{value:?}"));
            for s in (1..size).rev() {
                let mut rng = Rng::new(seed);
                let v = gen.generate(&mut rng, s);
                if !property(&v) {
                    smallest = (s, format!("{v:?}"));
                }
            }
            panic!(
                "property failed (seed={seed}, size={}): {}",
                smallest.0, smallest.1
            );
        }
    }
}

/// Generator helpers.
pub mod gens {
    use crate::util::rng::Rng;

    /// Uniform u32 in `[lo, hi]`, magnitude capped by size.
    pub fn int_in(lo: u32, hi: u32) -> impl Fn(&mut Rng, u32) -> u32 {
        move |rng, size| {
            let span = (hi - lo).min(size * 4);
            lo + rng.below(span as u64 + 1) as u32
        }
    }

    /// Vec of values from an element generator, length scaled by size.
    pub fn vec_of<T>(
        elem: impl Fn(&mut Rng, u32) -> T,
        max_len: usize,
    ) -> impl Fn(&mut Rng, u32) -> Vec<T> {
        move |rng, size| {
            let len = rng.below((max_len.min(size as usize) + 1) as u64) as usize;
            (0..len).map(|_| elem(rng, size)).collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        forall(&gens::int_in(0, 100), |&v| v <= 100);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        forall(&gens::int_in(0, 100), |&v| v < 5);
    }

    #[test]
    fn deterministic_per_seed() {
        let gen = gens::int_in(0, 1000);
        let mut first = Vec::new();
        for case in 0..10u64 {
            let mut rng = Rng::new(100 + case);
            first.push(gen(&mut rng, 10));
        }
        for case in 0..10u64 {
            let mut rng = Rng::new(100 + case);
            assert_eq!(gen(&mut rng, 10), first[case as usize]);
        }
    }

    #[test]
    fn vec_gen_respects_bounds() {
        forall(&gens::vec_of(gens::int_in(1, 9), 16), |v| {
            v.len() <= 16 && v.iter().all(|&x| (1..=9).contains(&x))
        });
    }
}
