//! Work quantities behind each Table 1 task, from real layer shapes.
//!
//! Execution time in the simulator is `work / throughput` cycles, so the
//! work amounts must be physically grounded: ResNet-18 and MobileNet-v1
//! MAC counts are computed from their published layer shapes at 224×224
//! input, and the vision tasks process a full 1080p frame per invocation.

/// MACs of a standard conv layer: out_h·out_w·c_out·(kh·kw·c_in).
pub fn conv_macs(out_h: u64, out_w: u64, c_in: u64, c_out: u64, kh: u64, kw: u64) -> u64 {
    out_h * out_w * c_out * (kh * kw * c_in)
}

/// MACs of a depthwise conv layer: out_h·out_w·c·(kh·kw).
pub fn dw_macs(out_h: u64, out_w: u64, c: u64, kh: u64, kw: u64) -> u64 {
    out_h * out_w * c * kh * kw
}

/// ResNet-18 stage MACs (two basic blocks; stages 3–5 downsample with a
/// strided first conv and a 1×1 projection).  He et al. 2016, Table 1.
pub fn resnet18_stage_macs(stage: u32) -> u64 {
    match stage {
        // conv2_x: 56×56, 64ch, two blocks of two 3×3 convs, no projection.
        2 => 4 * conv_macs(56, 56, 64, 64, 3, 3),
        // conv3_x: 28×28, 64→128 with stride-2 entry + 1×1 projection.
        3 => stage_macs(28, 64, 128),
        // conv4_x: 14×14, 128→256.
        4 => stage_macs(14, 128, 256),
        // conv5_x: 7×7, 256→512.
        5 => stage_macs(7, 256, 512),
        _ => panic!("ResNet-18 has stages 2..=5, got {stage}"),
    }
}

fn stage_macs(hw: u64, c_in: u64, c_out: u64) -> u64 {
    // block 1: conv3x3 stride 2 (c_in→c_out), conv3x3 (c_out→c_out),
    //          1×1 stride-2 projection (c_in→c_out)
    // block 2: two conv3x3 (c_out→c_out)
    conv_macs(hw, hw, c_in, c_out, 3, 3)
        + conv_macs(hw, hw, c_out, c_out, 3, 3)
        + conv_macs(hw, hw, c_in, c_out, 1, 1)
        + 2 * conv_macs(hw, hw, c_out, c_out, 3, 3)
}

/// MobileNet-v1 merged dw+pw task MACs (Howard et al. 2017, Table 1).
///
/// Table 1's `conv_dw_pw_N_x` groups the depthwise+pointwise pairs that
/// operate at one spatial resolution: group 2 = the two pairs at 56²
/// (64→128, 128→128), group 3 = the two pairs at 28² (128→256, 256→256),
/// group 4 = the two pairs at 14² (256→512, 512→512).
pub fn mobilenet_group_macs(group: u32) -> u64 {
    let (hw, c_in, c_out) = match group {
        2 => (56, 64, 128),
        3 => (28, 128, 256),
        4 => (14, 256, 512),
        _ => panic!("MobileNet groups are 2..=4, got {group}"),
    };
    // pair 1: dw at entry resolution (stride-2 from previous stage has
    // already happened), pw c_in→c_out
    let pair1 = dw_macs(hw, hw, c_in, 3, 3) + conv_macs(hw, hw, c_in, c_out, 1, 1);
    // pair 2: dw + pw at c_out→c_out
    let pair2 = dw_macs(hw, hw, c_out, 3, 3) + conv_macs(hw, hw, c_out, c_out, 1, 1);
    pair1 + pair2
}

/// Pixels per 1080p frame — the camera pipeline and Harris work unit.
pub fn frame_pixels() -> u64 {
    1920 * 1080
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_macs_formula() {
        // 56×56 out, 64→64, 3×3: the classic 115.6M-MAC ResNet conv.
        assert_eq!(conv_macs(56, 56, 64, 64, 3, 3), 115_605_504);
    }

    #[test]
    fn resnet_stage_magnitudes() {
        // conv2_x = 4 convs of 115.6M
        assert_eq!(resnet18_stage_macs(2), 462_422_016);
        // stages 3–5 have identical MAC structure at halved hw / doubled ch
        let s3 = resnet18_stage_macs(3);
        let s4 = resnet18_stage_macs(4);
        let s5 = resnet18_stage_macs(5);
        assert_eq!(s3, s4);
        assert_eq!(s4, s5);
        // block1(57.8M + 115.6M + 6.4M) + block2(231.2M) ≈ 411M
        assert_eq!(s3, 411_041_792);
    }

    #[test]
    #[should_panic]
    fn resnet_stage_bounds() {
        resnet18_stage_macs(6);
    }

    #[test]
    fn mobilenet_group_magnitudes() {
        let g2 = mobilenet_group_macs(2);
        // dw(56²·64·9)=1.8M + pw(56²·64·128)=25.7M + dw(56²·128·9)=3.6M
        // + pw(56²·128·128)=51.4M ≈ 82.5M
        assert_eq!(g2, 82_489_344);
        // deeper groups shrink slightly (halved hw², doubled ch)
        assert!(mobilenet_group_macs(3) < g2);
        assert!(mobilenet_group_macs(4) < mobilenet_group_macs(3));
    }

    #[test]
    fn frame_is_1080p() {
        assert_eq!(frame_pixels(), 2_073_600);
    }
}
