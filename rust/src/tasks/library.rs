//! The Table 1 task library — the paper's benchmark set, verbatim.
//!
//! | App        | Task         | Ver | Tpt | Array | GLB |
//! |------------|--------------|-----|-----|-------|-----|
//! | ResNet-18  | conv2_x      | a   | 64  | 2     | 7   |
//! |            |              | b   | 256 | 6     | 7   |
//! |            | conv3_x      | a   | 64  | 2     | 4   |
//! |            |              | b   | 256 | 6     | 4   |
//! |            | conv4_x      | a   | 64  | 2     | 6   |
//! |            |              | b   | 256 | 6     | 6   |
//! |            | conv5_x      | a   | 64  | 2     | 20  |
//! |            |              | b   | 128 | 6     | 20  |
//! | MobileNet  | conv_dw_pw_2 | a   | 52  | 2     | 4   |
//! |            |              | b   | 208 | 5     | 4   |
//! |            | conv_dw_pw_3 | a   | 52  | 2     | 4   |
//! |            |              | b   | 104 | 3     | 4   |
//! |            | conv_dw_pw_4 | a   | 52  | 2     | 4   |
//! |            |              | b   | 104 | 3     | 4   |
//! | Camera     | pipeline     | a   | 3   | 4     | 4   |
//! |            |              | b   | 12  | 6     | 14  |
//! | Harris     | corner       | a   | 1   | 2     | 4   |
//! |            |              | b   | 2   | 4     | 7   |
//! |            |              | c   | 4   | 7     | 14  |
//!
//! Throughput units: MACs/cycle for the ML tasks, pixels/cycle for the
//! vision tasks, at the paper's 500 MHz clock.

use std::collections::BTreeMap;

use crate::error::{Error, Result};
use crate::tasks::spec::{TaskId, TaskSpec, VariantSpec, WorkUnit};
use crate::tasks::workload;

/// Immutable library of task specs, keyed by [`TaskId`].
#[derive(Clone, Debug)]
pub struct TaskLibrary {
    tasks: BTreeMap<TaskId, TaskSpec>,
}

impl TaskLibrary {
    /// The paper's Table 1, with work quantities from `workload`.
    pub fn table1() -> TaskLibrary {
        let mut tasks = BTreeMap::new();
        let mut insert = |spec: TaskSpec| {
            tasks.insert(spec.id.clone(), spec);
        };

        // --- ResNet-18 stages -------------------------------------------
        let resnet_rows: [(u32, f64, (u32, u32), f64, (u32, u32), u32); 4] = [
            // (stage, tpt_a, (array_a, glb_a), tpt_b, (array_b, glb_b), _)
            (2, 64.0, (2, 7), 256.0, (6, 7), 0),
            (3, 64.0, (2, 4), 256.0, (6, 4), 0),
            (4, 64.0, (2, 6), 256.0, (6, 6), 0),
            (5, 64.0, (2, 20), 128.0, (6, 20), 0),
        ];
        for (stage, ta, (aa, ga), tb, (ab, gb), _) in resnet_rows {
            insert(TaskSpec {
                id: TaskId::new(format!("resnet18.conv{stage}_x")),
                name: format!("conv{stage}_x"),
                work: workload::resnet18_stage_macs(stage),
                unit: WorkUnit::Macs,
                variants: vec![
                    VariantSpec::new('a', ta, aa, ga)
                        .with_artifact(format!("resnet_conv{stage}_a")),
                    VariantSpec::new('b', tb, ab, gb)
                        .with_artifact(format!("resnet_conv{stage}_b")),
                ],
            });
        }

        // --- MobileNet merged dw+pw groups ------------------------------
        let mobile_rows: [(u32, f64, (u32, u32), f64, (u32, u32)); 3] = [
            (2, 52.0, (2, 4), 208.0, (5, 4)),
            (3, 52.0, (2, 4), 104.0, (3, 4)),
            (4, 52.0, (2, 4), 104.0, (3, 4)),
        ];
        for (group, ta, (aa, ga), tb, (ab, gb)) in mobile_rows {
            insert(TaskSpec {
                id: TaskId::new(format!("mobilenet.conv_dw_pw_{group}_x")),
                name: format!("conv_dw_pw_{group}_x"),
                work: workload::mobilenet_group_macs(group),
                unit: WorkUnit::Macs,
                variants: vec![
                    VariantSpec::new('a', ta, aa, ga)
                        .with_artifact(format!("mobilenet_dw_pw_{group}_a")),
                    VariantSpec::new('b', tb, ab, gb)
                        .with_artifact(format!("mobilenet_dw_pw_{group}_b")),
                ],
            });
        }

        // --- Camera pipeline ---------------------------------------------
        insert(TaskSpec {
            id: TaskId::new("camera.pipeline"),
            name: "camera pipeline".into(),
            work: workload::frame_pixels(),
            unit: WorkUnit::Pixels,
            variants: vec![
                VariantSpec::new('a', 3.0, 4, 4).with_artifact("camera_pipeline_a"),
                VariantSpec::new('b', 12.0, 6, 14).with_artifact("camera_pipeline_b"),
            ],
        });

        // --- Harris corner detector ---------------------------------------
        insert(TaskSpec {
            id: TaskId::new("harris.corner"),
            name: "Harris".into(),
            work: workload::frame_pixels(),
            unit: WorkUnit::Pixels,
            variants: vec![
                VariantSpec::new('a', 1.0, 2, 4).with_artifact("harris_a"),
                VariantSpec::new('b', 2.0, 4, 7).with_artifact("harris_b"),
                VariantSpec::new('c', 4.0, 7, 14).with_artifact("harris_c"),
            ],
        });

        TaskLibrary { tasks }
    }

    /// Table 1 plus the streaming-pipeline demosaic stage.
    ///
    /// Kept out of [`TaskLibrary::table1`] so the paper-faithful presets
    /// (and their bitstream-preload and DPR-cache behavior) stay
    /// byte-identical; used wherever [`crate::tasks::AppId::Pipeline`]
    /// requests can appear — the NoC presets and the coordinator's wire
    /// front.
    pub fn table1_pipeline() -> TaskLibrary {
        let mut lib = TaskLibrary::table1();
        lib.insert(TaskSpec {
            id: TaskId::new("pipeline.demosaic"),
            name: "demosaic".into(),
            work: workload::frame_pixels(),
            unit: WorkUnit::Pixels,
            variants: vec![
                VariantSpec::new('a', 2.0, 2, 6).with_artifact("demosaic_a"),
                VariantSpec::new('b', 8.0, 4, 12).with_artifact("demosaic_b"),
            ],
        });
        lib
    }

    /// Task lookup.
    pub fn get(&self, id: &TaskId) -> Result<&TaskSpec> {
        self.tasks
            .get(id)
            .ok_or_else(|| Error::Sched(format!("unknown task '{id}'")))
    }

    /// All tasks, sorted by id.
    pub fn iter(&self) -> impl Iterator<Item = &TaskSpec> {
        self.tasks.values()
    }

    /// Task count.
    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    /// Whether the library is empty.
    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    /// Insert or replace a spec (tests and ablations build custom sets).
    pub fn insert(&mut self, spec: TaskSpec) {
        self.tasks.insert(spec.id.clone(), spec);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasks::spec::VariantId;

    #[test]
    fn table1_has_nine_tasks_and_nineteen_variants() {
        let lib = TaskLibrary::table1();
        assert_eq!(lib.len(), 9);
        let variants: usize = lib.iter().map(|t| t.variants.len()).sum();
        assert_eq!(variants, 19);
    }

    #[test]
    fn conv2x_row_matches_paper() {
        let lib = TaskLibrary::table1();
        let t = lib.get(&TaskId::new("resnet18.conv2_x")).unwrap();
        let a = t.variant(VariantId('a')).unwrap();
        let b = t.variant(VariantId('b')).unwrap();
        assert_eq!(a.throughput, 64.0);
        assert_eq!(a.demand.array_slices, 2);
        assert_eq!(a.demand.glb_slices, 7);
        assert_eq!(b.throughput, 256.0);
        assert_eq!(b.demand.array_slices, 6);
        assert_eq!(b.demand.glb_slices, 7);
    }

    #[test]
    fn conv5x_b_is_128_not_256() {
        // The paper's Table 1 lists conv5_x variant b at 128 MACs/cycle
        // (memory-bound), unlike the other stages' 256.
        let lib = TaskLibrary::table1();
        let t = lib.get(&TaskId::new("resnet18.conv5_x")).unwrap();
        assert_eq!(t.fastest().throughput, 128.0);
        assert_eq!(t.fastest().demand.glb_slices, 20);
    }

    #[test]
    fn harris_has_three_variants() {
        let lib = TaskLibrary::table1();
        let t = lib.get(&TaskId::new("harris.corner")).unwrap();
        assert_eq!(t.variants.len(), 3);
        assert_eq!(t.fastest().ver, VariantId('c'));
        assert_eq!(t.fastest().demand.array_slices, 7);
    }

    #[test]
    fn all_variants_have_artifacts() {
        let lib = TaskLibrary::table1();
        for t in lib.iter() {
            for v in &t.variants {
                assert!(v.artifact.is_some(), "{} {} missing artifact", t.id, v.ver);
            }
        }
    }

    #[test]
    fn exec_cycles_at_paper_clock() {
        // conv2_x variant a: 462.4M MACs / 64 per cycle ≈ 7.23M cycles
        // ≈ 14.5 ms at 500 MHz — sanity anchor for the cloud sim.
        let lib = TaskLibrary::table1();
        let t = lib.get(&TaskId::new("resnet18.conv2_x")).unwrap();
        let cycles = t.exec_cycles(t.variant(VariantId('a')).unwrap());
        assert_eq!(cycles, 7_225_344);
        let ms = cycles as f64 / 500e6 * 1e3;
        assert!((ms - 14.45).abs() < 0.01, "{ms}");
    }

    #[test]
    fn camera_frame_time() {
        // camera variant a: 2.07M px / 3 px-per-cycle / 500MHz ≈ 1.38 ms,
        // comfortably under a 33 ms frame budget.
        let lib = TaskLibrary::table1();
        let t = lib.get(&TaskId::new("camera.pipeline")).unwrap();
        let cycles = t.exec_cycles(t.variant(VariantId('a')).unwrap());
        let ms = cycles as f64 / 500e6 * 1e3;
        assert!((ms - 1.382).abs() < 0.01, "{ms}");
    }

    #[test]
    fn pipeline_library_extends_table1() {
        let lib = TaskLibrary::table1_pipeline();
        assert_eq!(lib.len(), 10, "table1 + demosaic");
        let t = lib.get(&TaskId::new("pipeline.demosaic")).unwrap();
        assert_eq!(t.variants.len(), 2);
        assert_eq!(t.fastest().demand.array_slices, 4);
        // every node of the pipeline app graph resolves in this library
        let g = crate::tasks::AppGraph::of(crate::tasks::AppId::Pipeline);
        for node in &g.nodes {
            lib.get(node).unwrap();
        }
    }

    #[test]
    fn unknown_task_errors() {
        let lib = TaskLibrary::table1();
        assert!(lib.get(&TaskId::new("nope")).is_err());
    }
}
