//! Task and variant descriptors.

use std::fmt;

use crate::abstraction::SliceDemand;

/// Stable identifier of a task (e.g. `resnet18.conv2_x`).
#[derive(Clone, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TaskId(pub String);

impl TaskId {
    /// Convenience constructor.
    pub fn new(s: impl Into<String>) -> Self {
        TaskId(s.into())
    }
}

impl fmt::Display for TaskId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

/// Variant letter within a task (Table 1's "Ver." column).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct VariantId(pub char);

impl fmt::Display for VariantId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

/// Unit in which a task's work and throughput are measured.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkUnit {
    /// Multiply-accumulates (ML tasks; Table 1: MACs/cycle).
    Macs,
    /// Pixels (vision tasks; Table 1: pixels/cycle).
    Pixels,
}

impl WorkUnit {
    /// Display name.
    pub fn name(&self) -> &'static str {
        match self {
            WorkUnit::Macs => "MACs",
            WorkUnit::Pixels => "pixels",
        }
    }
}

/// One schedulable task.
#[derive(Clone, Debug)]
pub struct TaskSpec {
    /// Identifier.
    pub id: TaskId,
    /// Human-readable name (Table 1 "Task" column).
    pub name: String,
    /// Work per invocation, in `unit`s.
    pub work: u64,
    /// Unit of work / throughput.
    pub unit: WorkUnit,
    /// Pre-compiled variants, ordered by ascending throughput.
    pub variants: Vec<VariantSpec>,
}

impl TaskSpec {
    /// Variant lookup.
    pub fn variant(&self, ver: VariantId) -> Option<&VariantSpec> {
        self.variants.iter().find(|v| v.ver == ver)
    }

    /// Highest-throughput variant.  `total_cmp` keeps the selection
    /// total (and panic-free) even for degenerate NaN throughputs.
    pub fn fastest(&self) -> &VariantSpec {
        self.variants
            .iter()
            .max_by(|a, b| a.throughput.total_cmp(&b.throughput))
            .expect("task with no variants")
    }

    /// Lowest-demand variant (by array slices, then GLB slices).
    pub fn smallest(&self) -> &VariantSpec {
        self.variants
            .iter()
            .min_by_key(|v| (v.demand.array_slices, v.demand.glb_slices))
            .expect("task with no variants")
    }

    /// Execution cycles for one invocation under a variant.
    pub fn exec_cycles(&self, v: &VariantSpec) -> u64 {
        debug_assert!(v.throughput > 0.0);
        (self.work as f64 / v.throughput).ceil() as u64
    }
}

/// One pre-compiled mapping of a task (a Table 1 row).
#[derive(Clone, Debug)]
pub struct VariantSpec {
    /// Variant letter.
    pub ver: VariantId,
    /// Throughput in `unit`s per cycle (Table 1 "Tpt.").
    pub throughput: f64,
    /// Quantized slice demand (Table 1 "Array slices" / "GLB slices").
    pub demand: SliceDemand,
    /// Name of the AOT artifact that computes this variant functionally
    /// (`artifacts/manifest.json` entry), when one exists.
    pub artifact: Option<String>,
}

impl VariantSpec {
    /// Construct a variant.
    pub fn new(ver: char, throughput: f64, array_slices: u32, glb_slices: u32) -> Self {
        VariantSpec {
            ver: VariantId(ver),
            throughput,
            demand: SliceDemand::new(glb_slices, array_slices),
            artifact: None,
        }
    }

    /// Attach the AOT artifact name.
    pub fn with_artifact(mut self, name: impl Into<String>) -> Self {
        self.artifact = Some(name.into());
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_task() -> TaskSpec {
        TaskSpec {
            id: TaskId::new("demo"),
            name: "demo".into(),
            work: 1000,
            unit: WorkUnit::Macs,
            variants: vec![
                VariantSpec::new('a', 10.0, 2, 4),
                VariantSpec::new('b', 40.0, 6, 4),
            ],
        }
    }

    #[test]
    fn fastest_and_smallest() {
        let t = demo_task();
        assert_eq!(t.fastest().ver, VariantId('b'));
        assert_eq!(t.smallest().ver, VariantId('a'));
    }

    #[test]
    fn exec_cycles_rounds_up() {
        let t = demo_task();
        let a = t.variant(VariantId('a')).unwrap();
        assert_eq!(t.exec_cycles(a), 100);
        let mut t2 = demo_task();
        t2.work = 1001;
        assert_eq!(t2.exec_cycles(a), 101);
    }

    #[test]
    fn variant_lookup() {
        let t = demo_task();
        assert!(t.variant(VariantId('a')).is_some());
        assert!(t.variant(VariantId('z')).is_none());
    }
}
