//! Application DAGs and request instances.
//!
//! A tenant request targets an *application*; the application expands
//! into a chain/DAG of Table 1 tasks with dependencies the scheduler must
//! respect (paper §3.1: "the scheduler checks if dependencies are met
//! before scheduling the task (e.g., in ResNet-18, conv2_x depends on
//! conv1_x)").

use std::fmt;

use crate::config::QosClass;
use crate::error::{Error, Result};
use crate::tasks::spec::TaskId;

/// The benchmark applications (paper Fig. 3a tenants, plus the
/// streaming-pipeline chain the NoC scenarios add).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum AppId {
    /// ResNet-18 (conv2_x → conv5_x chain).
    ResNet18,
    /// MobileNet-v1 (three merged dw+pw groups).
    MobileNet,
    /// Camera pipeline (single task).
    Camera,
    /// Harris corner detector (single task).
    Harris,
    /// Streaming camera→demosaic→Harris chain with explicit inter-stage
    /// frame bytes ([`crate::noc`] scenarios).  Its demosaic stage lives
    /// in [`crate::tasks::TaskLibrary::table1_pipeline`], not the plain
    /// Table 1.
    Pipeline,
}

impl AppId {
    /// The paper's Fig. 3a tenant set, in tenant order.  Deliberately
    /// *excludes* [`AppId::Pipeline`]: the default cloud workload maps
    /// tenants over this array, and the pipeline app only enters via
    /// `workload.tenant_apps` overrides.
    pub const ALL: [AppId; 4] = [AppId::ResNet18, AppId::MobileNet, AppId::Camera, AppId::Harris];

    /// Display name.
    pub fn name(&self) -> &'static str {
        match self {
            AppId::ResNet18 => "ResNet-18",
            AppId::MobileNet => "MobileNet",
            AppId::Camera => "Camera pipeline",
            AppId::Harris => "Harris",
            AppId::Pipeline => "Streaming pipeline",
        }
    }

    /// Stable config / wire name (the SUBMIT app argument).
    pub fn config_name(&self) -> &'static str {
        match self {
            AppId::ResNet18 => "resnet18",
            AppId::MobileNet => "mobilenet",
            AppId::Camera => "camera",
            AppId::Harris => "harris",
            AppId::Pipeline => "pipeline",
        }
    }

    /// Parse a config / wire name.
    pub fn from_name(name: &str) -> Result<Self> {
        match name {
            "resnet18" => Ok(AppId::ResNet18),
            "mobilenet" => Ok(AppId::MobileNet),
            "camera" => Ok(AppId::Camera),
            "harris" => Ok(AppId::Harris),
            "pipeline" => Ok(AppId::Pipeline),
            other => Err(Error::Config(format!("unknown app '{other}'"))),
        }
    }
}

impl fmt::Display for AppId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// Application task graph: nodes are Table 1 tasks, edges are
/// dependencies (predecessor indices).
#[derive(Clone, Debug)]
pub struct AppGraph {
    /// Which app this is.
    pub app: AppId,
    /// Task nodes in topological order.
    pub nodes: Vec<TaskId>,
    /// `deps[i]` = indices of nodes that must complete before node `i`.
    pub deps: Vec<Vec<usize>>,
    /// `stream_in_bytes[i]` = bytes node `i` streams in from its
    /// predecessors over the NoC before it can compute (0 for graph
    /// sources and for the pre-NoC apps, whose operands arrive
    /// off-chip).  Priced by [`crate::noc::ContentionModel`].
    pub stream_in_bytes: Vec<u64>,
}

/// Bytes per 1080p frame handed between pipeline stages (16-bit
/// raw/RGB-ish planes; what the camera stage emits per invocation).
pub const FRAME_STREAM_BYTES: u64 = 1920 * 1080 * 2;

impl AppGraph {
    /// Canonical graph of an application.
    pub fn of(app: AppId) -> AppGraph {
        match app {
            AppId::ResNet18 => AppGraph::chain(
                app,
                (2..=5)
                    .map(|s| TaskId::new(format!("resnet18.conv{s}_x")))
                    .collect(),
            ),
            AppId::MobileNet => AppGraph::chain(
                app,
                (2..=4)
                    .map(|g| TaskId::new(format!("mobilenet.conv_dw_pw_{g}_x")))
                    .collect(),
            ),
            AppId::Camera => AppGraph::chain(app, vec![TaskId::new("camera.pipeline")]),
            AppId::Harris => AppGraph::chain(app, vec![TaskId::new("harris.corner")]),
            AppId::Pipeline => AppGraph::chain_with_streams(
                app,
                vec![
                    TaskId::new("camera.pipeline"),
                    TaskId::new("pipeline.demosaic"),
                    TaskId::new("harris.corner"),
                ],
                vec![0, FRAME_STREAM_BYTES, FRAME_STREAM_BYTES],
            ),
        }
    }

    /// Linear chain: node i depends on node i-1, no inter-stage streams.
    pub fn chain(app: AppId, nodes: Vec<TaskId>) -> AppGraph {
        let n = nodes.len();
        AppGraph::chain_with_streams(app, nodes, vec![0; n])
    }

    /// Linear chain with explicit per-node stream-in bytes.
    pub fn chain_with_streams(app: AppId, nodes: Vec<TaskId>, stream_in_bytes: Vec<u64>) -> AppGraph {
        let deps = (0..nodes.len())
            .map(|i| if i == 0 { vec![] } else { vec![i - 1] })
            .collect();
        AppGraph { app, nodes, deps, stream_in_bytes }
    }

    /// Validate: deps in range, acyclic by topological-order convention.
    pub fn validate(&self) -> Result<()> {
        if self.nodes.len() != self.deps.len() {
            return Err(Error::Sched("graph nodes/deps length mismatch".into()));
        }
        if self.nodes.len() != self.stream_in_bytes.len() {
            return Err(Error::Sched("graph nodes/stream_in_bytes length mismatch".into()));
        }
        for (i, preds) in self.deps.iter().enumerate() {
            for &p in preds {
                if p >= i {
                    return Err(Error::Sched(format!(
                        "graph not topologically ordered: node {i} depends on {p}"
                    )));
                }
            }
        }
        Ok(())
    }

    /// Number of task nodes.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    /// Whether the graph has no nodes.
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }
}

/// Identifier of one task instance within one request.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TaskInstanceId {
    /// Request sequence number (coordinator-global).
    pub request: u64,
    /// Node index within the request's app graph.
    pub node: usize,
}

impl fmt::Display for TaskInstanceId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "r{}#{}", self.request, self.node)
    }
}

/// One in-flight application request (a tenant submission).
#[derive(Clone, Debug)]
pub struct AppRequest {
    /// Global sequence number.
    pub seq: u64,
    /// Submitting tenant index (0–3 in the cloud scenario).
    pub tenant: u32,
    /// Application.
    pub app: AppId,
    /// Arrival time in simulation cycles.
    pub arrival_cycle: u64,
    /// QoS priority class ([`crate::qos`]); `BestEffort` unless the QoS
    /// subsystem assigns one.
    pub class: QosClass,
    /// Absolute completion deadline in cycles (`None` = no deadline).
    pub deadline: Option<u64>,
    /// Completion state per graph node.
    pub done: Vec<bool>,
}

impl AppRequest {
    /// New request with no completed nodes, BestEffort, no deadline.
    pub fn new(seq: u64, tenant: u32, app: AppId, arrival_cycle: u64) -> Self {
        let n = AppGraph::of(app).len();
        AppRequest {
            seq,
            tenant,
            app,
            arrival_cycle,
            class: QosClass::BestEffort,
            deadline: None,
            done: vec![false; n],
        }
    }

    /// Attach a QoS class and optional absolute deadline.
    pub fn with_qos(mut self, class: QosClass, deadline: Option<u64>) -> Self {
        self.class = class;
        self.deadline = deadline;
        self
    }

    /// Whether every node has completed.
    pub fn complete(&self) -> bool {
        self.done.iter().all(|&d| d)
    }

    /// Nodes whose dependencies are satisfied but are not yet done.
    pub fn ready_nodes(&self, graph: &AppGraph) -> Vec<usize> {
        (0..graph.len())
            .filter(|&i| !self.done[i] && graph.deps[i].iter().all(|&p| self.done[p]))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet_graph_is_a_4_chain() {
        let g = AppGraph::of(AppId::ResNet18);
        g.validate().unwrap();
        assert_eq!(g.len(), 4);
        assert_eq!(g.deps[0], Vec::<usize>::new());
        assert_eq!(g.deps[3], vec![2]);
        assert_eq!(g.nodes[0].0, "resnet18.conv2_x");
        assert_eq!(g.nodes[3].0, "resnet18.conv5_x");
    }

    #[test]
    fn single_task_apps() {
        for app in [AppId::Camera, AppId::Harris] {
            let g = AppGraph::of(app);
            g.validate().unwrap();
            assert_eq!(g.len(), 1);
        }
    }

    #[test]
    fn ready_nodes_respect_chain_deps() {
        let g = AppGraph::of(AppId::MobileNet);
        let mut req = AppRequest::new(0, 1, AppId::MobileNet, 0);
        assert_eq!(req.ready_nodes(&g), vec![0]);
        req.done[0] = true;
        assert_eq!(req.ready_nodes(&g), vec![1]);
        req.done[1] = true;
        req.done[2] = true;
        assert!(req.complete());
        assert!(req.ready_nodes(&g).is_empty());
    }

    #[test]
    fn invalid_graph_rejected() {
        let g = AppGraph {
            app: AppId::Camera,
            nodes: vec![TaskId::new("a"), TaskId::new("b")],
            deps: vec![vec![1], vec![]],
            stream_in_bytes: vec![0, 0],
        };
        assert!(g.validate().is_err());
        let g = AppGraph {
            app: AppId::Camera,
            nodes: vec![TaskId::new("a")],
            deps: vec![vec![]],
            stream_in_bytes: vec![],
        };
        assert!(g.validate().is_err());
    }

    #[test]
    fn pipeline_graph_streams_frames_between_stages() {
        let g = AppGraph::of(AppId::Pipeline);
        g.validate().unwrap();
        assert_eq!(g.len(), 3);
        assert_eq!(g.nodes[1].0, "pipeline.demosaic");
        assert_eq!(g.stream_in_bytes, vec![0, FRAME_STREAM_BYTES, FRAME_STREAM_BYTES]);
        // the paper's Fig. 3a apps stream nothing between stages
        for app in AppId::ALL {
            assert!(AppGraph::of(app).stream_in_bytes.iter().all(|&b| b == 0));
        }
    }

    #[test]
    fn app_config_names_round_trip() {
        for app in AppId::ALL.into_iter().chain([AppId::Pipeline]) {
            assert_eq!(AppId::from_name(app.config_name()).unwrap(), app);
        }
        assert!(AppId::from_name("unknown").is_err());
    }

    #[test]
    fn app_names_unique() {
        let names: Vec<_> = AppId::ALL.iter().map(|a| a.name()).collect();
        let mut dedup = names.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), names.len());
    }
}
