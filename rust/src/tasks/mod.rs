//! Task model: the benchmark applications of Table 1.
//!
//! * [`TaskSpec`] — one schedulable unit (a ResNet stage, a MobileNet
//!   merged dw+pw stage, the camera pipeline, Harris), with its *work*
//!   per invocation (MACs or pixels) derived from real layer shapes.
//! * [`VariantSpec`] — one pre-compiled mapping of a task: throughput
//!   (units/cycle) + quantized [`crate::abstraction::SliceDemand`] + the
//!   AOT artifact that computes it functionally.  Table 1 of the paper is
//!   reproduced verbatim by [`library::TaskLibrary::table1`].
//! * [`graph`] — application DAGs: a tenant request is an app instance
//!   whose tasks carry dependencies (conv2_x → conv3_x → …).
//! * [`workload`] — the MAC/pixel work quantities behind each task,
//!   computed from the real ResNet-18 / MobileNet-v1 layer shapes at
//!   224×224 and a 1080p frame for the vision tasks.

pub mod graph;
pub mod library;
mod spec;
pub mod workload;

pub use graph::{AppGraph, AppId, AppRequest, TaskInstanceId};
pub use library::TaskLibrary;
pub use spec::{TaskId, TaskSpec, VariantId, VariantSpec, WorkUnit};
