//! GLB-resident bitstream cache.
//!
//! Fast-DPR requires the bitstream to already sit in GLB SRAM (paper
//! §2.3: GLB banks "store and stream bitstreams to the tile array").
//! Cached bitstreams consume real bank capacity, so the cache has a
//! budget: a fraction of total GLB bytes reserved for configuration
//! storage (Amber dedicates every other bank; we default to half).
//! Eviction is LRU, with two refinements the preemption engine relies
//! on ([`crate::qos`]):
//!
//! * **Pinning** — the scheduler pins the bitstream of every running or
//!   launching task ([`BitstreamCache::pin`]), so eviction can never
//!   discard configuration state that a checkpointed victim's fast-DPR
//!   relaunch (or a live migration's restream) is about to need.  Pins
//!   are counted, since several regions may run the same variant.
//! * **O(1) membership** — residency and byte accounting live in a
//!   `HashMap` index; the LRU order is a lazily-invalidated deque of
//!   `(use_seq, id)` stamps (a lookup pushes a fresh stamp instead of
//!   repositioning, and eviction skips stale stamps), so `lookup` and
//!   `insert` no longer scan the whole deque per call.

use std::collections::{HashMap, VecDeque};

use crate::config::ArchConfig;

use super::bitstream::{Bitstream, BitstreamId};

/// Hit/miss counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Reconfigurations served from GLB-resident bitstreams.
    pub hits: u64,
    /// Reconfigurations that had to DMA from the host first.
    pub misses: u64,
    /// Entries evicted to make room.
    pub evictions: u64,
}

impl CacheStats {
    /// Hit fraction (0 when no lookups yet).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// One resident bitstream.
#[derive(Clone, Debug)]
struct Entry {
    bytes: u64,
    /// Stamp of the entry's most recent use; deque stamps below this
    /// are stale.
    last_use: u64,
    /// Pin count: > 0 exempts the entry from eviction.
    pins: u32,
}

/// LRU bitstream cache with a byte budget, pinning, and an O(1)
/// residency index.
#[derive(Clone, Debug)]
pub struct BitstreamCache {
    /// Residency index: id → entry.
    index: HashMap<BitstreamId, Entry>,
    /// Recency stamps, oldest first.  An id may appear several times;
    /// only the stamp equal to its entry's `last_use` is live.
    order: VecDeque<(u64, BitstreamId)>,
    /// Monotonic use counter feeding the stamps.
    use_seq: u64,
    capacity_bytes: u64,
    used_bytes: u64,
    stats: CacheStats,
}

impl BitstreamCache {
    /// Budget = half the GLB, matching Amber's every-other-bank scheme.
    pub fn new(arch: &ArchConfig) -> Self {
        let capacity = arch.glb_slices() as u64 * arch.glb_slice_bytes() / 2;
        BitstreamCache::with_capacity(capacity)
    }

    /// Explicit byte budget.
    pub fn with_capacity(capacity_bytes: u64) -> Self {
        BitstreamCache {
            index: HashMap::new(),
            order: VecDeque::new(),
            use_seq: 0,
            capacity_bytes,
            used_bytes: 0,
            stats: CacheStats::default(),
        }
    }

    /// Bytes currently cached.
    pub fn used_bytes(&self) -> u64 {
        self.used_bytes
    }

    /// Budget in bytes.
    pub fn capacity_bytes(&self) -> u64 {
        self.capacity_bytes
    }

    /// Cached entry count.
    pub fn len(&self) -> usize {
        self.index.len()
    }

    /// Whether nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    /// Counters.
    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    /// Push a fresh recency stamp for `id` (O(1) amortized — stale
    /// stamps are skipped lazily at eviction time, and the deque is
    /// compacted whenever stale stamps outnumber live entries, so it
    /// stays O(entries) even across eviction-free runs with millions of
    /// lookups).
    fn touch(&mut self, id: &BitstreamId) {
        self.use_seq += 1;
        let seq = self.use_seq;
        if let Some(e) = self.index.get_mut(id) {
            e.last_use = seq;
        }
        self.order.push_back((seq, id.clone()));
        if self.order.len() > 16 && self.order.len() > 2 * self.index.len() {
            let index = &self.index;
            self.order
                .retain(|(s, i)| index.get(i).map(|e| e.last_use == *s).unwrap_or(false));
        }
    }

    /// Whether `id` is resident; refreshes its LRU position when it is.
    pub fn lookup(&mut self, id: &BitstreamId) -> bool {
        if self.index.contains_key(id) {
            self.touch(id);
            true
        } else {
            false
        }
    }

    /// Pin a resident bitstream against eviction (counted; no-op when
    /// absent — e.g. the AXI mode's empty cache, or an over-budget
    /// bitstream that was never admitted).
    pub fn pin(&mut self, id: &BitstreamId) {
        if let Some(e) = self.index.get_mut(id) {
            e.pins += 1;
        }
    }

    /// Drop one pin (saturating; no-op when absent).
    pub fn unpin(&mut self, id: &BitstreamId) {
        if let Some(e) = self.index.get_mut(id) {
            e.pins = e.pins.saturating_sub(1);
        }
    }

    /// Current pin count of a resident bitstream (0 when absent).
    pub fn pins(&self, id: &BitstreamId) -> u32 {
        self.index.get(id).map(|e| e.pins).unwrap_or(0)
    }

    /// Insert (idempotent), evicting LRU *unpinned* entries to fit the
    /// budget.  Bitstreams that cannot fit even after evicting every
    /// unpinned entry are not cached (pinned residents are never
    /// sacrificed for a newcomer).
    pub fn insert(&mut self, bs: &Bitstream) {
        if self.index.contains_key(&bs.id) {
            return;
        }
        let bytes = bs.bytes();
        if bytes > self.capacity_bytes {
            return;
        }
        // room check against what eviction could ever reclaim
        let pinned_bytes: u64 =
            self.index.values().filter(|e| e.pins > 0).map(|e| e.bytes).sum();
        if pinned_bytes + bytes > self.capacity_bytes {
            return;
        }
        while self.used_bytes + bytes > self.capacity_bytes {
            let Some((seq, id)) = self.order.pop_front() else {
                debug_assert!(false, "used_bytes > 0 implies live stamps");
                break;
            };
            // stale stamp (the entry was touched since, or is gone) — skip
            let (live, pinned) = match self.index.get(&id) {
                Some(e) => (e.last_use == seq, e.pins > 0),
                None => (false, false),
            };
            if !live {
                continue;
            }
            if pinned {
                // re-stamp at the back so the pinned entry is only
                // reconsidered after everything else; the pinned-bytes
                // guard above ensures an unpinned victim still exists
                self.touch(&id);
                continue;
            }
            let evicted = self.index.remove(&id).expect("live entry");
            self.used_bytes -= evicted.bytes;
            self.stats.evictions += 1;
        }
        self.index.insert(bs.id.clone(), Entry { bytes, last_use: 0, pins: 0 });
        self.used_bytes += bytes;
        self.touch(&bs.id);
    }

    /// Record a hit (engine bookkeeping).
    pub fn record_hit(&mut self) {
        self.stats.hits += 1;
    }

    /// Record a miss.
    pub fn record_miss(&mut self) {
        self.stats.misses += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bs(name: &str, words: u64) -> Bitstream {
        Bitstream {
            id: BitstreamId::new(name, 'a'),
            words,
            array_slices: 1,
            region_agnostic: true,
            home_slice: 0,
        }
    }

    fn id(name: &str) -> BitstreamId {
        BitstreamId::new(name, 'a')
    }

    #[test]
    fn default_budget_is_half_glb() {
        let c = BitstreamCache::new(&ArchConfig::default());
        assert_eq!(c.capacity_bytes(), 32 * 128 * 1024 / 2);
    }

    #[test]
    fn insert_lookup_cycle() {
        let mut c = BitstreamCache::with_capacity(1024);
        assert!(!c.lookup(&id("x")));
        c.insert(&bs("x", 10));
        assert!(c.lookup(&id("x")));
        assert_eq!(c.used_bytes(), 40);
        // idempotent
        c.insert(&bs("x", 10));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn lru_eviction_order() {
        let mut c = BitstreamCache::with_capacity(120);
        c.insert(&bs("a", 10)); // 40 B
        c.insert(&bs("b", 10));
        c.insert(&bs("c", 10)); // full: a,b,c
        assert!(c.lookup(&id("a"))); // refresh a
        c.insert(&bs("d", 10)); // evicts b (LRU)
        assert!(!c.lookup(&id("b")));
        assert!(c.lookup(&id("a")));
        assert!(c.lookup(&id("c")));
        assert_eq!(c.stats().evictions, 1);
    }

    #[test]
    fn oversized_bitstream_not_cached() {
        let mut c = BitstreamCache::with_capacity(100);
        c.insert(&bs("huge", 1000));
        assert!(c.is_empty());
        assert_eq!(c.used_bytes(), 0);
    }

    #[test]
    fn hit_rate_math() {
        let mut c = BitstreamCache::with_capacity(100);
        assert_eq!(c.stats().hit_rate(), 0.0);
        c.record_hit();
        c.record_hit();
        c.record_miss();
        assert!((c.stats().hit_rate() - 2.0 / 3.0).abs() < 1e-12);
    }

    // ------------------------------------------------------------ pinning

    #[test]
    fn pinned_entries_survive_eviction_pressure() {
        let mut c = BitstreamCache::with_capacity(120);
        c.insert(&bs("running", 10)); // LRU — would be the first victim
        c.insert(&bs("b", 10));
        c.insert(&bs("c", 10));
        c.pin(&id("running"));
        c.insert(&bs("d", 10)); // must evict b, not the pinned LRU
        assert!(c.lookup(&id("running")), "pinned bitstream must stay resident");
        assert!(!c.lookup(&id("b")));
        assert!(c.lookup(&id("d")));
        // unpin makes it evictable again
        c.unpin(&id("running"));
        c.insert(&bs("e", 10));
        assert_eq!(c.len(), 3);
        assert_eq!(c.used_bytes(), 120);
    }

    #[test]
    fn pins_are_counted_across_concurrent_runners() {
        let mut c = BitstreamCache::with_capacity(80);
        c.insert(&bs("shared", 10));
        c.pin(&id("shared"));
        c.pin(&id("shared")); // two regions run the same variant
        assert_eq!(c.pins(&id("shared")), 2);
        c.unpin(&id("shared"));
        assert_eq!(c.pins(&id("shared")), 1, "one completion leaves one pin");
        c.insert(&bs("b", 10));
        c.insert(&bs("c", 10)); // evicts unpinned "b", never "shared"
        assert!(c.lookup(&id("shared")), "still-pinned entry survives");
        assert!(c.lookup(&id("c")));
        assert!(!c.lookup(&id("b")));
        // pin/unpin on absent ids are safe no-ops
        c.pin(&id("ghost"));
        c.unpin(&id("ghost"));
        assert_eq!(c.pins(&id("ghost")), 0);
        c.unpin(&id("shared"));
        c.unpin(&id("shared")); // saturating below zero
        assert_eq!(c.pins(&id("shared")), 0);
    }

    #[test]
    fn fully_pinned_cache_refuses_newcomers_without_evicting() {
        let mut c = BitstreamCache::with_capacity(80);
        c.insert(&bs("a", 10));
        c.insert(&bs("b", 10));
        c.pin(&id("a"));
        c.pin(&id("b"));
        c.insert(&bs("c", 10));
        assert_eq!(c.len(), 2, "no room ever reclaimable: newcomer dropped");
        assert_eq!(c.stats().evictions, 0);
        assert_eq!(c.used_bytes(), 80);
    }

    // --------------------------------------------- eviction edge cases

    #[test]
    fn exact_fit_insert_takes_the_whole_budget() {
        let mut c = BitstreamCache::with_capacity(80);
        c.insert(&bs("a", 10));
        c.insert(&bs("b", 10)); // 80/80 used — exactly full
        assert_eq!(c.used_bytes(), 80);
        assert_eq!(c.len(), 2);
        assert_eq!(c.stats().evictions, 0);
        // an exact-fit replacement evicts precisely the LRU entry
        c.insert(&bs("c", 10));
        assert_eq!(c.used_bytes(), 80);
        assert_eq!(c.stats().evictions, 1);
        assert!(!c.lookup(&id("a")));
    }

    #[test]
    fn reinsert_never_double_counts_used_bytes() {
        let mut c = BitstreamCache::with_capacity(200);
        for _ in 0..5 {
            c.insert(&bs("x", 10));
        }
        assert_eq!(c.used_bytes(), 40);
        assert_eq!(c.len(), 1);
        // interleave lookups (stale-stamp pressure) and re-inserts
        for _ in 0..5 {
            assert!(c.lookup(&id("x")));
            c.insert(&bs("x", 10));
        }
        assert_eq!(c.used_bytes(), 40);
        assert_eq!(c.len(), 1);
        assert_eq!(c.stats().evictions, 0);
    }

    #[test]
    fn stale_stamps_do_not_evict_recently_used_entries() {
        let mut c = BitstreamCache::with_capacity(120);
        c.insert(&bs("a", 10));
        c.insert(&bs("b", 10));
        c.insert(&bs("c", 10));
        // touch "a" many times: the deque now holds several stale "a"
        // stamps ahead of b/c
        for _ in 0..10 {
            assert!(c.lookup(&id("a")));
        }
        c.insert(&bs("d", 10));
        assert!(c.lookup(&id("a")), "hot entry must survive its stale stamps");
        assert!(!c.lookup(&id("b")), "true LRU is evicted");
        assert_eq!(c.stats().evictions, 1);
    }

    #[test]
    fn recency_stamps_stay_bounded_across_eviction_free_runs() {
        let mut c = BitstreamCache::with_capacity(1024);
        c.insert(&bs("a", 10));
        c.insert(&bs("b", 10));
        for _ in 0..10_000 {
            assert!(c.lookup(&id("a")));
            assert!(c.lookup(&id("b")));
        }
        // compaction keeps the stamp deque O(entries), not O(lookups)
        assert!(c.order.len() <= 17, "stamps must compact: {}", c.order.len());
        assert_eq!(c.len(), 2);
        assert_eq!(c.used_bytes(), 80);
        // LRU semantics survive compaction
        c.insert(&bs("filler", 200)); // 800 B: forces eviction pressure
        assert!(c.lookup(&id("filler")));
    }

    #[test]
    fn eviction_frees_until_the_newcomer_fits() {
        let mut c = BitstreamCache::with_capacity(120);
        c.insert(&bs("a", 10));
        c.insert(&bs("b", 10));
        c.insert(&bs("c", 10));
        c.insert(&bs("big", 25)); // 100 B: evicts a, b and c
        assert_eq!(c.stats().evictions, 3);
        assert_eq!(c.len(), 1);
        assert_eq!(c.used_bytes(), 100);
        assert!(c.lookup(&id("big")));
    }
}
