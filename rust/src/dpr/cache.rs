//! GLB-resident bitstream cache.
//!
//! Fast-DPR requires the bitstream to already sit in GLB SRAM (paper
//! §2.3: GLB banks "store and stream bitstreams to the tile array").
//! Cached bitstreams consume real bank capacity, so the cache has a
//! budget: a fraction of total GLB bytes reserved for configuration
//! storage (Amber dedicates every other bank; we default to half).
//! Eviction is LRU.

use std::collections::VecDeque;

use crate::config::ArchConfig;

use super::bitstream::{Bitstream, BitstreamId};

/// Hit/miss counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Reconfigurations served from GLB-resident bitstreams.
    pub hits: u64,
    /// Reconfigurations that had to DMA from the host first.
    pub misses: u64,
    /// Entries evicted to make room.
    pub evictions: u64,
}

impl CacheStats {
    /// Hit fraction (0 when no lookups yet).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// LRU bitstream cache with a byte budget.
#[derive(Clone, Debug)]
pub struct BitstreamCache {
    /// LRU order: front = least recently used.
    entries: VecDeque<(BitstreamId, u64)>,
    capacity_bytes: u64,
    used_bytes: u64,
    stats: CacheStats,
}

impl BitstreamCache {
    /// Budget = half the GLB, matching Amber's every-other-bank scheme.
    pub fn new(arch: &ArchConfig) -> Self {
        let capacity = arch.glb_slices() as u64 * arch.glb_slice_bytes() / 2;
        BitstreamCache::with_capacity(capacity)
    }

    /// Explicit byte budget.
    pub fn with_capacity(capacity_bytes: u64) -> Self {
        BitstreamCache {
            entries: VecDeque::new(),
            capacity_bytes,
            used_bytes: 0,
            stats: CacheStats::default(),
        }
    }

    /// Bytes currently cached.
    pub fn used_bytes(&self) -> u64 {
        self.used_bytes
    }

    /// Budget in bytes.
    pub fn capacity_bytes(&self) -> u64 {
        self.capacity_bytes
    }

    /// Cached entry count.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Counters.
    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    /// Whether `id` is resident; refreshes LRU position when it is.
    pub fn lookup(&mut self, id: &BitstreamId) -> bool {
        if let Some(pos) = self.entries.iter().position(|(e, _)| e == id) {
            let entry = self.entries.remove(pos).expect("position valid");
            self.entries.push_back(entry);
            true
        } else {
            false
        }
    }

    /// Insert (idempotent), evicting LRU entries to fit the budget.
    /// Bitstreams larger than the whole budget are not cached.
    pub fn insert(&mut self, bs: &Bitstream) {
        if self.entries.iter().any(|(e, _)| *e == bs.id) {
            return;
        }
        let bytes = bs.bytes();
        if bytes > self.capacity_bytes {
            return;
        }
        while self.used_bytes + bytes > self.capacity_bytes {
            let (_, evicted) = self.entries.pop_front().expect("used>0 implies entries");
            self.used_bytes -= evicted;
            self.stats.evictions += 1;
        }
        self.entries.push_back((bs.id.clone(), bytes));
        self.used_bytes += bytes;
    }

    /// Record a hit (engine bookkeeping).
    pub fn record_hit(&mut self) {
        self.stats.hits += 1;
    }

    /// Record a miss.
    pub fn record_miss(&mut self) {
        self.stats.misses += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bs(name: &str, words: u64) -> Bitstream {
        Bitstream {
            id: BitstreamId::new(name, 'a'),
            words,
            array_slices: 1,
            region_agnostic: true,
            home_slice: 0,
        }
    }

    #[test]
    fn default_budget_is_half_glb() {
        let c = BitstreamCache::new(&ArchConfig::default());
        assert_eq!(c.capacity_bytes(), 32 * 128 * 1024 / 2);
    }

    #[test]
    fn insert_lookup_cycle() {
        let mut c = BitstreamCache::with_capacity(1024);
        assert!(!c.lookup(&BitstreamId::new("x", 'a')));
        c.insert(&bs("x", 10));
        assert!(c.lookup(&BitstreamId::new("x", 'a')));
        assert_eq!(c.used_bytes(), 40);
        // idempotent
        c.insert(&bs("x", 10));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn lru_eviction_order() {
        let mut c = BitstreamCache::with_capacity(120);
        c.insert(&bs("a", 10)); // 40 B
        c.insert(&bs("b", 10));
        c.insert(&bs("c", 10)); // full: a,b,c
        assert!(c.lookup(&BitstreamId::new("a", 'a'))); // refresh a
        c.insert(&bs("d", 10)); // evicts b (LRU)
        assert!(!c.lookup(&BitstreamId::new("b", 'a')));
        assert!(c.lookup(&BitstreamId::new("a", 'a')));
        assert!(c.lookup(&BitstreamId::new("c", 'a')));
        assert_eq!(c.stats().evictions, 1);
    }

    #[test]
    fn oversized_bitstream_not_cached() {
        let mut c = BitstreamCache::with_capacity(100);
        c.insert(&bs("huge", 1000));
        assert!(c.is_empty());
        assert_eq!(c.used_bytes(), 0);
    }

    #[test]
    fn hit_rate_math() {
        let mut c = BitstreamCache::with_capacity(100);
        assert_eq!(c.stats().hit_rate(), 0.0);
        c.record_hit();
        c.record_hit();
        c.record_miss();
        assert!((c.stats().hit_rate() - 2.0 / 3.0).abs() < 1e-12);
    }
}
