//! Dynamic partial reconfiguration: the paper's second mechanism (§2.3).
//!
//! Two engines are modeled:
//!
//! * [`Axi4LiteDpr`] — the baseline: the host writes configuration
//!   registers one 32-bit word at a time over an AXI4-Lite bus (two bus
//!   beats per write) at bus clock.  Reconfiguring the whole array this
//!   way costs ~milliseconds — 14.4 % of the baseline autonomous-system
//!   latency in the paper's Fig. 5.
//! * [`FastDpr`] — the proposal, following Amber's DPR design: each GLB
//!   bank streams a cached, *region-agnostic* bitstream into its
//!   array-slice at 64 bit/cycle at core clock, all slices in parallel;
//!   a destination-region register relocates the stream to any free
//!   slice (bitstream relocation).  Reconfiguration drops to
//!   microseconds (<5 % of latency in Fig. 5).
//!
//! [`BitstreamCache`] models the GLB's bitstream-storage role: preloaded
//! bitstreams occupy real bank capacity; without relocation (the
//! DESIGN.md §6.4 ablation) a cached bitstream only matches the region it
//! was compiled for and any other destination is a miss.

mod bitstream;
mod cache;
mod engine;

pub use bitstream::{Bitstream, BitstreamId};
pub use cache::{BitstreamCache, CacheStats};
pub use engine::{Axi4LiteDpr, DprEngine, DprMode, DprOutcome, FastDpr};
