//! DPR timing engines: AXI4-Lite baseline vs parallel fast-DPR.

use crate::abstraction::SliceRange;
use crate::config::{ArchConfig, DprConfig};

use super::bitstream::Bitstream;
use super::cache::BitstreamCache;

/// Which reconfiguration path a simulation uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DprMode {
    /// Sequential AXI4-Lite configuration writes (baseline).
    Axi4Lite,
    /// Parallel per-slice GLB streaming with relocation (proposed).
    Fast,
}

/// Result of a reconfiguration request.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DprOutcome {
    /// Core-clock cycles the reconfiguration occupies the target slices.
    pub cycles: u64,
    /// Whether the bitstream was already resident in the GLB cache
    /// (fast-DPR only; AXI always streams from the host).
    pub cache_hit: bool,
}

/// Baseline engine: host-driven AXI4-Lite register writes.
#[derive(Clone, Debug)]
pub struct Axi4LiteDpr {
    cfg: DprConfig,
    axi_clock_mhz: u32,
    core_clock_mhz: u32,
}

impl Axi4LiteDpr {
    /// Build from configs.
    pub fn new(arch: &ArchConfig, cfg: &DprConfig) -> Self {
        Axi4LiteDpr {
            cfg: cfg.clone(),
            axi_clock_mhz: arch.axi_clock_mhz,
            core_clock_mhz: arch.core_clock_mhz,
        }
    }

    /// Core-clock cycles to write a whole bitstream over the bus.
    ///
    /// Each 32-bit config word costs `axi_cycles_per_word` *bus* cycles
    /// (address + data phases); wider config words take proportionally
    /// more writes.  The result is converted to core cycles, which is the
    /// clock every other latency in the simulator is measured in.
    pub fn reconfig_cycles(&self, bs: &Bitstream) -> u64 {
        let writes = bs.words * 32u64.div_ceil(self.cfg.axi_word_bits as u64).max(1);
        let bus_cycles = writes * self.cfg.axi_cycles_per_word as u64;
        // core_cycles = bus_cycles * (core_clk / bus_clk)
        bus_cycles * self.core_clock_mhz as u64 / self.axi_clock_mhz as u64
    }
}

/// Proposed engine: per-slice parallel streaming from GLB banks.
#[derive(Clone, Debug)]
pub struct FastDpr {
    cfg: DprConfig,
    /// Fixed per-reconfiguration overhead in core cycles: destination-
    /// register write, stream arm, column clock-gate handshake.
    pub overhead_cycles: u64,
}

impl FastDpr {
    /// Build from configs.
    pub fn new(_arch: &ArchConfig, cfg: &DprConfig) -> Self {
        FastDpr { cfg: cfg.clone(), overhead_cycles: 16 }
    }

    /// Core-clock cycles to stream a *cached* bitstream into its region.
    ///
    /// One GLB bank feeds one array-slice (paper §2.3), all slices in
    /// parallel, `fast_word_bits` per cycle at core clock, so the cost is
    /// the per-slice word count — independent of how many slices the task
    /// spans.
    pub fn stream_cycles(&self, bs: &Bitstream) -> u64 {
        let words_per_cycle = (self.cfg.fast_word_bits / 32).max(1) as u64;
        bs.words_per_slice().div_ceil(words_per_cycle) + self.overhead_cycles
    }

    /// Core-clock cycles to DMA a missing bitstream from the host into
    /// GLB banks before streaming (cache-miss penalty).
    pub fn host_load_cycles(&self, bs: &Bitstream) -> u64 {
        // Host DMA over the full AXI4 data port: model as 16 B/cycle at
        // core clock (a conservative 8 GB/s at 500 MHz).
        bs.bytes().div_ceil(16)
    }
}

/// Facade combining mode, engines, and the GLB bitstream cache.
#[derive(Clone, Debug)]
pub struct DprEngine {
    mode: DprMode,
    axi: Axi4LiteDpr,
    fast: FastDpr,
    cache: BitstreamCache,
    relocation: bool,
}

impl DprEngine {
    /// Build an engine in the given mode.
    pub fn new(arch: &ArchConfig, cfg: &DprConfig, mode: DprMode) -> Self {
        DprEngine {
            mode,
            axi: Axi4LiteDpr::new(arch, cfg),
            fast: FastDpr::new(arch, cfg),
            cache: BitstreamCache::new(arch),
            relocation: cfg.relocation,
        }
    }

    /// Active mode.
    pub fn mode(&self) -> DprMode {
        self.mode
    }

    /// Access cache statistics.
    pub fn cache(&self) -> &BitstreamCache {
        &self.cache
    }

    /// Preload a bitstream into the GLB cache (fast-DPR; the scheduler
    /// calls this ahead of need, paper: "pre-load bitstreams of the next
    /// task to the GLB in advance").  No-op under AXI mode.
    pub fn preload(&mut self, bs: &Bitstream) {
        if self.mode == DprMode::Fast {
            self.cache.insert(bs);
        }
    }

    /// Pin a resident bitstream against cache eviction — the scheduler
    /// pins every running/launching task's bitstream so a preemption
    /// relaunch ([`crate::qos`]) or migration restream can never find
    /// its configuration state evicted.  Counted; no-op under AXI mode
    /// (nothing is cached there).
    pub fn pin(&mut self, id: &super::bitstream::BitstreamId) {
        self.cache.pin(id);
    }

    /// Drop one pin (no-op when absent).
    pub fn unpin(&mut self, id: &super::bitstream::BitstreamId) {
        self.cache.unpin(id);
    }

    /// Cycles to restream `bs` for a live-migration relocation
    /// ([`crate::migration`]).  A migrating task's bitstream is by
    /// definition resident (it was streamed at launch), so this is the
    /// pure stream cost under fast-DPR — and the full bus write under
    /// AXI, where migration is prohibitively slow.  Read-only: the cache
    /// and its hit/miss counters are untouched.
    pub fn migration_stream_cycles(&self, bs: &Bitstream) -> u64 {
        match self.mode {
            DprMode::Axi4Lite => self.axi.reconfig_cycles(bs),
            DprMode::Fast => self.fast.stream_cycles(bs),
        }
    }

    /// Cost of reconfiguring `dest` (array-slice range) with `bs`.
    ///
    /// Under fast-DPR, a cache hit streams directly; relocation decides
    /// whether a hit at a *different* region still counts (region-
    /// agnostic bitstreams, §2.3).  A miss pays the host DMA then streams.
    pub fn reconfigure(&mut self, bs: &Bitstream, dest: &SliceRange) -> DprOutcome {
        match self.mode {
            DprMode::Axi4Lite => DprOutcome { cycles: self.axi.reconfig_cycles(bs), cache_hit: false },
            DprMode::Fast => {
                let usable = self.cache.lookup(&bs.id)
                    && (self.relocation
                        || (bs.region_agnostic && dest.start == 0)
                        || (!bs.region_agnostic && bs.home_slice == dest.start));
                if usable {
                    self.cache.record_hit();
                    DprOutcome { cycles: self.fast.stream_cycles(bs), cache_hit: true }
                } else {
                    self.cache.record_miss();
                    self.cache.insert(bs);
                    DprOutcome {
                        cycles: self.fast.host_load_cycles(bs) + self.fast.stream_cycles(bs),
                        cache_hit: false,
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpr::bitstream::BitstreamId;

    fn arch() -> ArchConfig {
        ArchConfig::default()
    }

    fn cfg() -> DprConfig {
        DprConfig::default()
    }

    /// A two-slice bitstream at the calibrated per-slice word count
    /// (48 PE × 64 + 16 MEM × 96 + 64 × 32 route = 6656 words/slice).
    fn two_slice_bs() -> Bitstream {
        Bitstream {
            id: BitstreamId::new("resnet18.conv2_x", 'a'),
            words: 2 * 6656,
            array_slices: 2,
            region_agnostic: true,
            home_slice: 0,
        }
    }

    #[test]
    fn axi_reconfig_is_milliseconds() {
        let e = Axi4LiteDpr::new(&arch(), &cfg());
        let cycles = e.reconfig_cycles(&two_slice_bs());
        // 13312 words × 2 bus-cycles × (500/100) = 133,120 core cycles
        assert_eq!(cycles, 133_120);
        let us = cycles as f64 / 500e6 * 1e6;
        assert!((us - 266.2).abs() < 1.0, "{us}");
    }

    #[test]
    fn fast_stream_is_microseconds_and_parallel() {
        let f = FastDpr::new(&arch(), &cfg());
        let bs2 = two_slice_bs();
        let mut bs6 = two_slice_bs();
        bs6.words = 6 * 6656;
        bs6.array_slices = 6;
        // per-slice cost identical regardless of slice count (parallel)
        assert_eq!(f.stream_cycles(&bs2), f.stream_cycles(&bs6));
        // 6656/2 + 16 = 3344 cycles ≈ 6.7 µs at 500 MHz
        assert_eq!(f.stream_cycles(&bs2), 3344);
    }

    #[test]
    fn fast_vs_axi_speedup_order_of_magnitude() {
        let a = Axi4LiteDpr::new(&arch(), &cfg());
        let f = FastDpr::new(&arch(), &cfg());
        let bs = two_slice_bs();
        let speedup = a.reconfig_cycles(&bs) as f64 / f.stream_cycles(&bs) as f64;
        assert!(speedup > 30.0, "speedup {speedup}");
    }

    #[test]
    fn engine_axi_mode_never_caches() {
        let mut e = DprEngine::new(&arch(), &cfg(), DprMode::Axi4Lite);
        let bs = two_slice_bs();
        e.preload(&bs);
        let out = e.reconfigure(&bs, &SliceRange::new(0, 2));
        assert!(!out.cache_hit);
        assert_eq!(out.cycles, 133_120);
    }

    #[test]
    fn engine_fast_hit_after_preload_any_region() {
        let mut e = DprEngine::new(&arch(), &cfg(), DprMode::Fast);
        let bs = two_slice_bs();
        e.preload(&bs);
        // relocation on: hit even at a non-home region
        let out = e.reconfigure(&bs, &SliceRange::new(4, 2));
        assert!(out.cache_hit);
        assert_eq!(out.cycles, 3344);
    }

    #[test]
    fn engine_fast_miss_pays_host_dma_then_hits() {
        let mut e = DprEngine::new(&arch(), &cfg(), DprMode::Fast);
        let bs = two_slice_bs();
        let miss = e.reconfigure(&bs, &SliceRange::new(0, 2));
        assert!(!miss.cache_hit);
        // 13312 words × 4 B / 16 B-per-cycle = 3328 + stream 3344
        assert_eq!(miss.cycles, 3328 + 3344);
        let hit = e.reconfigure(&bs, &SliceRange::new(2, 2));
        assert!(hit.cache_hit);
    }

    #[test]
    fn no_relocation_hits_only_at_home() {
        let mut dcfg = cfg();
        dcfg.relocation = false;
        let mut e = DprEngine::new(&arch(), &dcfg, DprMode::Fast);
        let mut bs = two_slice_bs();
        bs.region_agnostic = false;
        bs.home_slice = 2;
        e.preload(&bs);
        assert!(!e.reconfigure(&bs, &SliceRange::new(4, 2)).cache_hit);
        assert!(e.reconfigure(&bs, &SliceRange::new(2, 2)).cache_hit);
    }

    #[test]
    fn migration_stream_cost_matches_mode_and_keeps_cache_stats() {
        let bs = two_slice_bs();
        let mut fast = DprEngine::new(&arch(), &cfg(), DprMode::Fast);
        fast.preload(&bs);
        let hits_before = fast.cache().stats();
        assert_eq!(fast.migration_stream_cycles(&bs), 3344);
        assert_eq!(fast.cache().stats(), hits_before, "read-only costing");
        let axi = DprEngine::new(&arch(), &cfg(), DprMode::Axi4Lite);
        assert_eq!(axi.migration_stream_cycles(&bs), 133_120);
    }

    #[test]
    fn engine_hit_miss_counters_track_reconfigurations() {
        let mut e = DprEngine::new(&arch(), &cfg(), DprMode::Fast);
        let bs = two_slice_bs();
        assert_eq!(e.cache().stats(), crate::dpr::CacheStats::default());
        let _ = e.reconfigure(&bs, &SliceRange::new(0, 2)); // miss + insert
        let _ = e.reconfigure(&bs, &SliceRange::new(2, 2)); // hit
        let _ = e.reconfigure(&bs, &SliceRange::new(4, 2)); // hit
        let s = e.cache().stats();
        assert_eq!((s.hits, s.misses, s.evictions), (2, 1, 0));
        assert!((s.hit_rate() - 2.0 / 3.0).abs() < 1e-12);
        // AXI mode records nothing: it never consults the cache
        let mut axi = DprEngine::new(&arch(), &cfg(), DprMode::Axi4Lite);
        let _ = axi.reconfigure(&bs, &SliceRange::new(0, 2));
        assert_eq!(axi.cache().stats(), crate::dpr::CacheStats::default());
    }

    #[test]
    fn engine_pin_protects_a_running_tasks_bitstream() {
        // capacity for exactly one two-slice bitstream
        let mut e = DprEngine::new(&arch(), &cfg(), DprMode::Fast);
        e.cache = BitstreamCache::with_capacity(2 * 6656 * 4);
        let running = two_slice_bs();
        let _ = e.reconfigure(&running, &SliceRange::new(0, 2));
        e.pin(&running.id);
        // another task's bitstream cannot displace the pinned one
        let mut other = two_slice_bs();
        other.id = BitstreamId::new("harris.corner", 'b');
        let out = e.reconfigure(&other, &SliceRange::new(2, 2));
        assert!(!out.cache_hit);
        let relaunch = e.reconfigure(&running, &SliceRange::new(4, 2));
        assert!(relaunch.cache_hit, "preemption relaunch must find the bitstream resident");
        // after completion the pin drops and the entry becomes evictable
        e.unpin(&running.id);
        let _ = e.reconfigure(&other, &SliceRange::new(2, 2));
        assert!(!e.cache().is_empty());
        // pin/unpin are harmless no-ops under AXI mode
        let mut axi = DprEngine::new(&arch(), &cfg(), DprMode::Axi4Lite);
        axi.pin(&running.id);
        axi.unpin(&running.id);
    }

    #[test]
    fn wider_axi_words_fewer_writes() {
        let mut dcfg = cfg();
        dcfg.axi_word_bits = 64;
        let e = Axi4LiteDpr::new(&arch(), &dcfg);
        // still one write per 32-bit word is impossible: 64-bit bus halves
        // nothing here because words are 32-bit — ceil(32/64)=1 write/word.
        assert_eq!(e.reconfig_cycles(&two_slice_bs()), 133_120);
        dcfg.axi_word_bits = 16;
        let e16 = Axi4LiteDpr::new(&arch(), &dcfg);
        assert_eq!(e16.reconfig_cycles(&two_slice_bs()), 2 * 133_120);
    }
}
