//! Bitstream model.

use std::fmt;

/// Identifier of a compiled bitstream: task id + variant letter.
#[derive(Clone, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BitstreamId {
    /// Task identifier (e.g. `resnet18.conv2_x`).
    pub task: String,
    /// Variant letter.
    pub ver: char,
}

impl BitstreamId {
    /// Convenience constructor.
    pub fn new(task: impl Into<String>, ver: char) -> Self {
        BitstreamId { task: task.into(), ver }
    }
}

impl fmt::Display for BitstreamId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}", self.task, self.ver)
    }
}

/// A compiled configuration bitstream.
///
/// Produced by `compiler::bitgen` from a variant's slice demand and the
/// per-tile config-register counts; consumed by the DPR engines.
#[derive(Clone, Debug, PartialEq)]
pub struct Bitstream {
    /// Identity (task + variant).
    pub id: BitstreamId,
    /// Total 32-bit configuration words.
    pub words: u64,
    /// Array-slices this bitstream configures.
    pub array_slices: u32,
    /// Whether the bitstream is region-agnostic (compiled for the
    /// leftmost region, relocatable via the destination register —
    /// paper §2.3).  Amber-style region-aware bitstreams are pinned to
    /// one region.
    pub region_agnostic: bool,
    /// For region-aware bitstreams: the array-slice index the column ids
    /// were baked for.  Ignored when `region_agnostic`.
    pub home_slice: u32,
}

impl Bitstream {
    /// Size in bytes.
    pub fn bytes(&self) -> u64 {
        self.words * 4
    }

    /// Size in bits — the configuration-stream energy model's input
    /// ([`crate::energy::EnergyModel::dpr_stream_pj`] charges per bit).
    pub fn bits(&self) -> u64 {
        self.words * 32
    }

    /// Config words per array-slice (fast-DPR streams these in parallel).
    pub fn words_per_slice(&self) -> u64 {
        debug_assert!(self.array_slices > 0);
        self.words.div_ceil(self.array_slices as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bs(words: u64, slices: u32) -> Bitstream {
        Bitstream {
            id: BitstreamId::new("t", 'a'),
            words,
            array_slices: slices,
            region_agnostic: true,
            home_slice: 0,
        }
    }

    #[test]
    fn bytes_and_per_slice_words() {
        let b = bs(6656 * 2, 2);
        assert_eq!(b.bytes(), 6656 * 8);
        assert_eq!(b.words_per_slice(), 6656);
    }

    #[test]
    fn ragged_slice_division_rounds_up() {
        let b = bs(100, 3);
        assert_eq!(b.words_per_slice(), 34);
    }

    #[test]
    fn id_display() {
        assert_eq!(BitstreamId::new("camera.pipeline", 'b').to_string(), "camera.pipeline:b");
    }
}
