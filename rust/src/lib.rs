//! # cgra-mte — Multi-Task Execution on Coarse-Grained Reconfigurable Arrays
//!
//! A full-system reproduction of *"Hardware Abstractions and Hardware
//! Mechanisms to Support Multi-Task Execution on Coarse-Grained
//! Reconfigurable Arrays"* (Kong et al., Stanford, 2023).
//!
//! The crate is the **Layer-3 coordinator** of a three-layer stack:
//!
//! * **L1/L2 (build time, Python)** — the benchmark tasks of the paper's
//!   Table 1 (ResNet-18 / MobileNet conv stages, camera pipeline, Harris)
//!   written in JAX over Pallas kernels and AOT-lowered to HLO text in
//!   `artifacts/` (`make artifacts`).
//! * **L3 (this crate, Rust)** — the paper's actual contribution: the
//!   slice-granular hardware abstraction ([`abstraction`]), flexible-shape
//!   execution regions ([`regions`]), fast dynamic partial reconfiguration
//!   ([`dpr`]), the greedy multi-task scheduler ([`scheduler`]), the
//!   live-migration defragmentation subsystem ([`migration`]), the
//!   per-component energy model, power-gated slices and power-cap
//!   governor ([`energy`]), the QoS layer — priority classes, deadlines
//!   and preemptive scheduling with checkpointed eviction ([`qos`]) —
//!   corridor-granular NoC bandwidth provisioning with contention-charged
//!   streams and communication-aware placement ([`noc`]),
//!   the discrete-event CGRA timing model
//!   ([`sim`]), the sharded fabric pool with placement routing
//!   ([`fabric`]), and the multi-tenant request coordinator
//!   ([`coordinator`]).
//! * **Runtime** — [`runtime`] executes the artifacts on the request
//!   path.  Two backends serve one API: the default deterministic
//!   in-process stub (fully offline), and the PJRT C API client
//!   (`--features xla`).  Python never runs at serve time.
//!
//! The serving front ([`coordinator::Server`]) is a concurrent
//! worker-pool TCP server: per-tenant bounded admission queues, N
//! scheduler workers batching concurrent SUBMITs into shared scheduler
//! invocations, explicit `BUSY` backpressure, and graceful drain on
//! shutdown.  The socket-facing layer is selectable: the default
//! thread-per-connection front, or a single-threaded nonblocking
//! reactor (`server.mode = "reactor"`, epoll on Linux) that makes idle
//! connections ~free and speaks an optional length-prefixed binary
//! framing ([`coordinator::frame`]) negotiated per connection.
//!
//! See `README.md` for the quickstart and wire protocol, `DESIGN.md`
//! for the architecture inventory, and `EXPERIMENTS.md` for
//! paper-vs-measured results and the bench index.
#![deny(rustdoc::broken_intra_doc_links)]

pub mod abstraction;
pub mod arch;
pub mod bench;
pub mod compiler;
pub mod config;
pub mod coordinator;
pub mod dpr;
pub mod energy;
pub mod error;
pub mod fabric;
pub mod metrics;
pub mod migration;
pub mod noc;
pub mod obs;
pub mod qos;
pub mod regions;
pub mod runtime;
pub mod scheduler;
pub mod sim;
pub mod tasks;
pub mod testutil;
pub mod util;

pub use error::{Error, Result};
