//! Live telemetry streaming hub backing the `WATCH` wire verb.
//!
//! Both serving fronts publish rendered journal/metric event lines
//! into one [`WatchHub`]; each subscribed connection owns a bounded
//! queue that the connection drains at its own pace.  A slow consumer
//! never blocks the publisher (the shard executors or the reactor
//! loop): when its queue is full the new event is **dropped and
//! counted** — per subscriber and hub-wide — so backpressure shows up
//! as a number instead of a stall.  An optional notifier hook lets the
//! reactor wake its poll loop when fresh events arrive.

use std::collections::VecDeque;
use std::fmt;
use std::sync::{Arc, Mutex};

/// Wake-up hook invoked after events are published (reactor waker).
pub type Notifier = Arc<dyn Fn() + Send + Sync>;

struct Subscriber {
    token: u64,
    queue: VecDeque<String>,
    delivered: u64,
    dropped: u64,
}

struct HubInner {
    subs: Vec<Subscriber>,
    next_token: u64,
    dropped_total: u64,
    published_total: u64,
    notifier: Option<Notifier>,
}

impl fmt::Debug for HubInner {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("HubInner")
            .field("subs", &self.subs.len())
            .field("next_token", &self.next_token)
            .field("dropped_total", &self.dropped_total)
            .field("published_total", &self.published_total)
            .finish()
    }
}

/// Shared fan-out hub with bounded per-subscriber queues.
#[derive(Clone, Debug)]
pub struct WatchHub {
    inner: Arc<Mutex<HubInner>>,
    cap: usize,
}

impl WatchHub {
    /// Hub whose subscriber queues hold up to `queue_cap` events.
    pub fn new(queue_cap: usize) -> WatchHub {
        WatchHub {
            inner: Arc::new(Mutex::new(HubInner {
                subs: Vec::new(),
                next_token: 1,
                dropped_total: 0,
                published_total: 0,
                notifier: None,
            })),
            cap: queue_cap.max(1),
        }
    }

    /// Install the publish wake-up hook (replaces any previous one).
    pub fn set_notifier(&self, f: Notifier) {
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        inner.notifier = Some(f);
    }

    /// Register a subscriber; the token addresses its queue.
    pub fn subscribe(&self) -> u64 {
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let token = inner.next_token;
        inner.next_token += 1;
        inner.subs.push(Subscriber {
            token,
            queue: VecDeque::new(),
            delivered: 0,
            dropped: 0,
        });
        token
    }

    /// Remove a subscriber; returns its `(delivered, dropped)` totals.
    pub fn unsubscribe(&self, token: u64) -> Option<(u64, u64)> {
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let idx = inner.subs.iter().position(|s| s.token == token)?;
        let s = inner.subs.swap_remove(idx);
        Some((s.delivered, s.dropped))
    }

    /// Whether anyone is listening (publishers can skip rendering).
    pub fn has_subscribers(&self) -> bool {
        let inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        !inner.subs.is_empty()
    }

    /// Fan one event line out to every subscriber.  Full queues drop
    /// the new event and count it; nothing ever blocks.
    pub fn publish(&self, line: &str) {
        let notifier = {
            let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
            if inner.subs.is_empty() {
                return;
            }
            inner.published_total += 1;
            let cap = self.cap;
            let mut newly_dropped = 0u64;
            for s in &mut inner.subs {
                if s.queue.len() >= cap {
                    s.dropped += 1;
                    newly_dropped += 1;
                } else {
                    s.queue.push_back(line.to_string());
                }
            }
            inner.dropped_total += newly_dropped;
            inner.notifier.clone()
        };
        if let Some(f) = notifier {
            f();
        }
    }

    /// Fan a batch out (one lock acquisition, one wake-up).
    pub fn publish_all<I: IntoIterator<Item = String>>(&self, lines: I) {
        let notifier = {
            let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
            if inner.subs.is_empty() {
                return;
            }
            let cap = self.cap;
            let mut published = 0u64;
            let mut newly_dropped = 0u64;
            for line in lines {
                published += 1;
                for s in &mut inner.subs {
                    if s.queue.len() >= cap {
                        s.dropped += 1;
                        newly_dropped += 1;
                    } else {
                        s.queue.push_back(line.clone());
                    }
                }
            }
            inner.published_total += published;
            inner.dropped_total += newly_dropped;
            if published == 0 {
                None
            } else {
                inner.notifier.clone()
            }
        };
        if let Some(f) = notifier {
            f();
        }
    }

    /// Pop up to `max` queued events for `token`, oldest first.
    pub fn drain(&self, token: u64, max: usize) -> Vec<String> {
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let Some(s) = inner.subs.iter_mut().find(|s| s.token == token) else {
            return Vec::new();
        };
        let n = s.queue.len().min(max);
        let out: Vec<String> = s.queue.drain(..n).collect();
        s.delivered += out.len() as u64;
        out
    }

    /// Per-subscriber `(queued, delivered, dropped)` snapshot.
    pub fn stats(&self, token: u64) -> Option<(usize, u64, u64)> {
        let inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        inner
            .subs
            .iter()
            .find(|s| s.token == token)
            .map(|s| (s.queue.len(), s.delivered, s.dropped))
    }

    /// Events dropped hub-wide across all subscribers.
    pub fn dropped_total(&self) -> u64 {
        let inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        inner.dropped_total
    }

    /// Events published while at least one subscriber was registered.
    pub fn published_total(&self) -> u64 {
        let inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        inner.published_total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn publish_is_ordered_and_bounded() {
        let hub = WatchHub::new(3);
        let t = hub.subscribe();
        for i in 0..5 {
            hub.publish(&format!("ev{i}"));
        }
        // queue holds the oldest 3; the 2 overflow events were dropped
        assert_eq!(hub.drain(t, 10), vec!["ev0", "ev1", "ev2"]);
        assert_eq!(hub.stats(t), Some((0, 3, 2)));
        assert_eq!(hub.dropped_total(), 2);
        // draining frees capacity again
        hub.publish("ev5");
        assert_eq!(hub.drain(t, 10), vec!["ev5"]);
        assert_eq!(hub.unsubscribe(t), Some((4, 2)));
        assert!(!hub.has_subscribers());
    }

    #[test]
    fn slow_subscriber_does_not_affect_fast_one() {
        let hub = WatchHub::new(2);
        let slow = hub.subscribe();
        let fast = hub.subscribe();
        for i in 0..6 {
            hub.publish(&format!("e{i}"));
            // fast consumer drains every event immediately
            assert_eq!(hub.drain(fast, 10).len(), 1);
        }
        let (_, fast_delivered, fast_dropped) = hub.stats(fast).unwrap();
        assert_eq!((fast_delivered, fast_dropped), (6, 0));
        let (queued, _, slow_dropped) = hub.stats(slow).unwrap();
        assert_eq!(queued, 2, "slow queue pinned at cap");
        assert_eq!(slow_dropped, 4, "overflow counted, not blocked");
    }

    #[test]
    fn publish_without_subscribers_is_free() {
        let hub = WatchHub::new(4);
        hub.publish("nobody listening");
        assert_eq!(hub.published_total(), 0);
        let t = hub.subscribe();
        hub.publish_all(vec!["a".to_string(), "b".to_string()]);
        assert_eq!(hub.published_total(), 2);
        assert_eq!(hub.drain(t, 1), vec!["a"]);
        assert_eq!(hub.drain(t, 10), vec!["b"]);
    }

    #[test]
    fn notifier_fires_on_publish() {
        let hub = WatchHub::new(4);
        let hits = Arc::new(AtomicU64::new(0));
        let h2 = hits.clone();
        hub.set_notifier(Arc::new(move || {
            h2.fetch_add(1, Ordering::SeqCst);
        }));
        hub.publish("no subscriber — no wake");
        assert_eq!(hits.load(Ordering::SeqCst), 0);
        let _t = hub.subscribe();
        hub.publish("wake");
        hub.publish_all(vec!["batch".to_string()]);
        assert_eq!(hits.load(Ordering::SeqCst), 2);
    }
}
