//! Structured simulation events — the shared vocabulary between the
//! human-readable [`crate::sim::Trace`] and the request-scoped
//! [`crate::obs::Journal`].
//!
//! Every trace line the sim drivers used to `format!` inline is now one
//! [`SimEvent`] variant; the `Display` impl reproduces the legacy line
//! **byte for byte** (the differential goldens digest rendered traces,
//! so this grammar is pinned).  The optional `shard` field carries the
//! pool drivers' `shard={n} ` prefix — it is `Some` only when the pool
//! has more than one shard, and only the arrive/launch/preempt lines
//! ever carry it (matching the historical `shard_tag` behavior).

use std::fmt;

use crate::qos::PreemptionRecord;
use crate::scheduler::Launch;

/// A structured simulation event with an exact legacy text rendering.
#[derive(Clone, Debug)]
pub enum SimEvent {
    /// A cloud request entered the admission queue.
    Arrive { shard: Option<u32>, seq: u64, tenant: u32, app: &'static str },
    /// An edge frame task entered the admission queue.
    ArriveFrame { shard: Option<u32>, seq: u64, tenant: u32, frame: u32, app: &'static str },
    /// A cloud request was rejected by admission (queue full).
    Busy { seq: u64, tenant: u32 },
    /// An edge frame task was rejected by admission.
    BusyFrame { seq: u64, frame: u32 },
    /// A cloud request completed.
    Done { seq: u64, tenant: u32 },
    /// An edge frame tick started.
    Frame { k: u32 },
    /// All tasks of an edge frame completed.
    FrameDone { k: u32, total: u64, reconfig: u64 },
    /// An entire edge frame was rejected at admission.
    FrameRejected { k: u32 },
    /// The scheduler placed a task instance on a region.
    Launch { shard: Option<u32>, launch: Launch },
    /// The QoS engine checkpointed and evicted a running task.
    Preempt { shard: Option<u32>, rec: PreemptionRecord },
}

impl SimEvent {
    /// Shard the event happened on (0 for single-fabric sims).
    pub fn shard_id(&self) -> u32 {
        match self {
            SimEvent::Arrive { shard, .. }
            | SimEvent::ArriveFrame { shard, .. }
            | SimEvent::Launch { shard, .. }
            | SimEvent::Preempt { shard, .. } => shard.unwrap_or(0),
            _ => 0,
        }
    }
}

fn shard_tag(f: &mut fmt::Formatter<'_>, shard: &Option<u32>) -> fmt::Result {
    if let Some(s) = shard {
        write!(f, "shard={s} ")?;
    }
    Ok(())
}

impl fmt::Display for SimEvent {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SimEvent::Arrive { shard, seq, tenant, app } => {
                shard_tag(f, shard)?;
                write!(f, "arrive seq={seq} tenant={tenant} app={app}")
            }
            SimEvent::ArriveFrame { shard, seq, frame, app, .. } => {
                shard_tag(f, shard)?;
                write!(f, "arrive seq={seq} frame={frame} app={app}")
            }
            SimEvent::Busy { seq, tenant } => write!(f, "busy seq={seq} tenant={tenant}"),
            SimEvent::BusyFrame { seq, frame } => write!(f, "busy seq={seq} frame={frame}"),
            SimEvent::Done { seq, tenant } => write!(f, "done seq={seq} tenant={tenant}"),
            SimEvent::Frame { k } => write!(f, "frame k={k}"),
            SimEvent::FrameDone { k, total, reconfig } => {
                write!(f, "frame-done k={k} total={total} reconfig={reconfig}")
            }
            SimEvent::FrameRejected { k } => write!(f, "frame-rejected k={k}"),
            SimEvent::Launch { shard, launch } => {
                shard_tag(f, shard)?;
                write!(
                    f,
                    "launch inst={} task={} ver={} region={} dpr={} exec={} finish={}",
                    launch.instance,
                    launch.task,
                    launch.ver,
                    launch.region,
                    launch.dpr_cycles,
                    launch.exec_cycles,
                    launch.finish
                )
            }
            SimEvent::Preempt { shard, rec } => {
                shard_tag(f, shard)?;
                write!(
                    f,
                    "preempt inst={} task={} class={} by={} byclass={} region={} remaining={} ckpt={}",
                    rec.victim,
                    rec.victim_task,
                    rec.victim_class.name(),
                    rec.preemptor,
                    rec.preemptor_class.name(),
                    rec.victim_region,
                    rec.remaining_cycles,
                    rec.checkpoint_cycles
                )
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_matches_legacy_grammar() {
        let ev = SimEvent::Arrive { shard: None, seq: 3, tenant: 1, app: "MobileNet" };
        assert_eq!(ev.to_string(), "arrive seq=3 tenant=1 app=MobileNet");
        let ev = SimEvent::Arrive { shard: Some(2), seq: 3, tenant: 1, app: "MobileNet" };
        assert_eq!(ev.to_string(), "shard=2 arrive seq=3 tenant=1 app=MobileNet");
        let ev = SimEvent::ArriveFrame { shard: None, seq: 9, tenant: 2, frame: 4, app: "Camera" };
        assert_eq!(ev.to_string(), "arrive seq=9 frame=4 app=Camera");
        assert_eq!(SimEvent::Busy { seq: 7, tenant: 0 }.to_string(), "busy seq=7 tenant=0");
        assert_eq!(SimEvent::BusyFrame { seq: 7, frame: 2 }.to_string(), "busy seq=7 frame=2");
        assert_eq!(SimEvent::Done { seq: 5, tenant: 3 }.to_string(), "done seq=5 tenant=3");
        assert_eq!(SimEvent::Frame { k: 11 }.to_string(), "frame k=11");
        assert_eq!(
            SimEvent::FrameDone { k: 1, total: 800, reconfig: 60 }.to_string(),
            "frame-done k=1 total=800 reconfig=60"
        );
        assert_eq!(SimEvent::FrameRejected { k: 6 }.to_string(), "frame-rejected k=6");
    }

    #[test]
    fn shard_id_defaults_to_zero() {
        assert_eq!(SimEvent::Frame { k: 0 }.shard_id(), 0);
        let ev = SimEvent::Arrive { shard: Some(3), seq: 0, tenant: 0, app: "x" };
        assert_eq!(ev.shard_id(), 3);
    }
}
