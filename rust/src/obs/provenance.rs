//! Decision provenance — *why* the scheduler chose what it chose.
//!
//! The lifecycle [`crate::obs::Journal`] records *what* happened
//! (submitted → placed → executing → completed); this module records
//! the reasoning at every scheduler choice point as structured
//! [`Decision`] records in a bounded ring:
//!
//! * variant selection — the chosen mapping plus every rejected
//!   alternative with its policy score and root cause
//!   ([`AltVerdict`]: slice NoFit, power-cap refusal, never-fits),
//! * all-variants-NoFit events with per-alternative causes,
//! * preemption victim ranking (candidates in eviction order, which
//!   were evicted),
//! * defragmentation plan accept/reject with the cost-model numbers
//!   (migration cycles vs. rescued execution gain),
//! * pool placement scoring per shard (feasibility, load, corridor
//!   pressure, energy margin, best-effort runway).
//!
//! The ring is queryable by request id (the `EXPLAIN <req_id>` wire
//! verb), renders to a deterministic one-line-per-decision text
//! grammar, folds to an FNV-1a digest (the determinism regression
//! hook, like [`crate::obs::Journal::digest`]), and exports to JSON
//! for the flight recorder.  Overflow drops the oldest record and
//! counts it, so truncated postmortems are detectable.

use std::collections::VecDeque;
use std::fmt;

use crate::util::json::Json;

use super::journal::NO_REQ;

/// Why a variant alternative was not (or was) launched.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AltVerdict {
    /// This alternative was selected and launched.
    Chosen,
    /// Free slices exist but not contiguously (the defrag trigger).
    NoFitSlices,
    /// The power-cap governor refused the projected draw.
    PowerCap,
    /// No machine state can ever host this alternative.
    NeverFits,
    /// A preferred alternative was chosen first; this one was never
    /// attempted.
    NotTried,
}

impl AltVerdict {
    /// Stable wire/text name.
    pub fn name(&self) -> &'static str {
        match self {
            AltVerdict::Chosen => "chosen",
            AltVerdict::NoFitSlices => "nofit-slices",
            AltVerdict::PowerCap => "power-cap",
            AltVerdict::NeverFits => "never-fits",
            AltVerdict::NotTried => "not-tried",
        }
    }
}

/// One variant alternative the selection policy walked.
#[derive(Clone, Debug, PartialEq)]
pub struct VariantAlt {
    /// Variant letter.
    pub ver: char,
    /// Policy score (effective throughput under the active policy's
    /// preference order).
    pub score: f64,
    /// Replication factor the option requested (0 = plain).
    pub replicate: u32,
    /// Outcome for this alternative.
    pub verdict: AltVerdict,
}

/// One shard's placement score at admission time.
#[derive(Clone, Debug, PartialEq)]
pub struct ShardScore {
    /// Shard id.
    pub shard: u32,
    /// Open requests in the shard's admission window.
    pub open: u64,
    /// Whether the demand can ever fit this shard.
    pub feasible: bool,
    /// Whether the demand fits right now (no defrag needed).
    pub fits_now: bool,
    /// Busy array-slice fraction.
    pub busy: f64,
    /// Corridor bandwidth pressure (0 when `[noc]` is off).
    pub corridor: f64,
    /// Marginal placement power in pJ/cycle (0 when `[energy]` is off).
    pub marginal_pj: f64,
    /// Longest lower-class runway in cycles (Critical placement).
    pub be_runway: u64,
}

/// One preemption victim candidate, in eviction order.
#[derive(Clone, Debug, PartialEq)]
pub struct VictimRank {
    /// Region the candidate runs on.
    pub region: u64,
    /// Candidate's QoS class name.
    pub class: &'static str,
    /// Remaining runway in cycles.
    pub remaining: u64,
    /// Whether the selection actually evicted it.
    pub evicted: bool,
}

/// The reasoning payload of one decision record.
#[derive(Clone, Debug, PartialEq)]
pub enum DecisionKind {
    /// A launch's variant selection: the chosen mapping plus every
    /// alternative walked before (rejected, with cause) and after
    /// (never attempted) it in policy preference order.
    Variant {
        /// Task launched.
        task: String,
        /// Chosen variant letter.
        chosen: char,
        /// Replicas granted.
        replicas: u32,
        /// Chosen option's policy score.
        score: f64,
        /// Whether this was a checkpoint resume.
        resumed: bool,
        /// Every alternative in preference order.
        alts: Vec<VariantAlt>,
    },
    /// Every alternative failed; per-alternative root causes.
    NoFit {
        /// Task that could not launch.
        task: String,
        /// Every alternative with its failure cause.
        alts: Vec<VariantAlt>,
    },
    /// Preemption victim selection for a blocked higher-class task.
    Preempt {
        /// The blocked preemptor's task.
        task: String,
        /// Candidates in eviction order with the evicted subset marked.
        candidates: Vec<VictimRank>,
        /// How many victims were checkpointed and evicted.
        evicted: u32,
    },
    /// Defragmentation plan accept/reject with cost-model numbers.
    Defrag {
        /// Task the plan would rescue.
        task: String,
        /// Blocked variant the plan targets.
        ver: char,
        /// Relocation steps in the plan.
        moves: u32,
        /// Total migration cycles the plan costs.
        cost: u64,
        /// Execution cycles the rescued variant earns back.
        gain: u64,
        /// Whether the plan was committed.
        accepted: bool,
    },
    /// Pool placement scoring across shards at admission.
    Placement {
        /// Submitting tenant.
        tenant: u32,
        /// Shard chosen (`None` = rejected BUSY).
        chosen: Option<u32>,
        /// Shard rescued via cross-shard defrag, if any.
        rescued: Option<u32>,
        /// Every shard's score.
        shards: Vec<ShardScore>,
    },
}

impl DecisionKind {
    /// Stable one-word name (digest + rendering).
    pub fn name(&self) -> &'static str {
        match self {
            DecisionKind::Variant { .. } => "variant",
            DecisionKind::NoFit { .. } => "nofit",
            DecisionKind::Preempt { .. } => "preempt",
            DecisionKind::Defrag { .. } => "defrag",
            DecisionKind::Placement { .. } => "placement",
        }
    }
}

/// One decision record: where, when, for which request, and why.
#[derive(Clone, Debug, PartialEq)]
pub struct Decision {
    /// Cycle the decision was made.
    pub at: u64,
    /// Owning request seq ([`NO_REQ`] for fabric-scoped decisions).
    pub req: u64,
    /// Shard the decision was made on (0 single-fabric).
    pub shard: u32,
    /// Monotonic decision number, assigned by the ring at push.
    pub seq: u64,
    /// The reasoning payload.
    pub kind: DecisionKind,
}

impl Decision {
    /// Build a record; the ring assigns `seq` on push.
    pub fn new(at: u64, req: u64, kind: DecisionKind) -> Decision {
        Decision { at, req, shard: 0, seq: 0, kind }
    }
}

fn fmt_score(f: &mut fmt::Formatter<'_>, v: f64) -> fmt::Result {
    // integral scores print as integers (the deterministic convention
    // shared with the registry exposition)
    if v.fract() == 0.0 && v.abs() < 1e15 {
        write!(f, "{}", v as i64)
    } else {
        write!(f, "{v:.3}")
    }
}

impl fmt::Display for Decision {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "at={} shard={} ", self.at, self.shard)?;
        if self.req == NO_REQ {
            write!(f, "req=- ")?;
        } else {
            write!(f, "req={} ", self.req)?;
        }
        match &self.kind {
            DecisionKind::Variant { task, chosen, replicas, score, resumed, alts } => {
                write!(f, "variant task={task} chosen={chosen} repl={replicas} score=")?;
                fmt_score(f, *score)?;
                if *resumed {
                    write!(f, " resumed")?;
                }
                write!(f, " alts=[")?;
                for (i, a) in alts.iter().enumerate() {
                    if i > 0 {
                        write!(f, " ")?;
                    }
                    write!(f, "{}:", a.ver)?;
                    fmt_score(f, a.score)?;
                    write!(f, ":{}", a.verdict.name())?;
                }
                write!(f, "]")
            }
            DecisionKind::NoFit { task, alts } => {
                write!(f, "nofit task={task} alts=[")?;
                for (i, a) in alts.iter().enumerate() {
                    if i > 0 {
                        write!(f, " ")?;
                    }
                    write!(f, "{}:", a.ver)?;
                    fmt_score(f, a.score)?;
                    write!(f, ":{}", a.verdict.name())?;
                }
                write!(f, "]")
            }
            DecisionKind::Preempt { task, candidates, evicted } => {
                write!(f, "preempt task={task} evicted={evicted} candidates=[")?;
                for (i, c) in candidates.iter().enumerate() {
                    if i > 0 {
                        write!(f, " ")?;
                    }
                    write!(
                        f,
                        "r{}:{}:{}:{}",
                        c.region,
                        c.class,
                        c.remaining,
                        if c.evicted { "evicted" } else { "kept" }
                    )?;
                }
                write!(f, "]")
            }
            DecisionKind::Defrag { task, ver, moves, cost, gain, accepted } => {
                write!(
                    f,
                    "defrag task={task} ver={ver} moves={moves} cost={cost} gain={gain} {}",
                    if *accepted { "accepted" } else { "rejected" }
                )
            }
            DecisionKind::Placement { tenant, chosen, rescued, shards } => {
                write!(f, "placement tenant={tenant} chosen=")?;
                match chosen {
                    Some(s) => write!(f, "{s}")?,
                    None => write!(f, "busy")?,
                }
                if let Some(r) = rescued {
                    write!(f, " rescued={r}")?;
                }
                write!(f, " shards=[")?;
                for (i, s) in shards.iter().enumerate() {
                    if i > 0 {
                        write!(f, " ")?;
                    }
                    write!(
                        f,
                        "{}:open={}:feasible={}:fits={}:busy=",
                        s.shard, s.open, s.feasible, s.fits_now
                    )?;
                    fmt_score(f, s.busy)?;
                    if s.corridor != 0.0 {
                        write!(f, ":corridor=")?;
                        fmt_score(f, s.corridor)?;
                    }
                    if s.marginal_pj != 0.0 {
                        write!(f, ":pj=")?;
                        fmt_score(f, s.marginal_pj)?;
                    }
                    if s.be_runway != 0 {
                        write!(f, ":runway={}", s.be_runway)?;
                    }
                }
                write!(f, "]")
            }
        }
    }
}

/// Bounded ring of decision records with drop-and-count overflow.
#[derive(Clone, Debug, Default)]
pub struct ProvenanceRing {
    ring: VecDeque<Decision>,
    cap: usize,
    dropped: u64,
    next_seq: u64,
}

impl ProvenanceRing {
    /// Ring retaining the newest `cap` decisions.
    pub fn new(cap: usize) -> ProvenanceRing {
        ProvenanceRing { ring: VecDeque::new(), cap: cap.max(1), dropped: 0, next_seq: 0 }
    }

    /// Append a decision, assigning its monotonic seq; drops (and
    /// counts) the oldest record when full.
    pub fn push(&mut self, mut d: Decision) {
        d.seq = self.next_seq;
        self.next_seq += 1;
        if self.ring.len() == self.cap {
            self.ring.pop_front();
            self.dropped += 1;
        }
        self.ring.push_back(d);
    }

    /// Retained decisions, oldest first.
    pub fn decisions(&self) -> impl Iterator<Item = &Decision> {
        self.ring.iter()
    }

    /// Retained decisions owned by request `req`, oldest first.
    pub fn for_req(&self, req: u64) -> Vec<&Decision> {
        self.ring.iter().filter(|d| d.req == req).collect()
    }

    /// Retained record count.
    pub fn len(&self) -> usize {
        self.ring.len()
    }

    /// Whether the ring holds no records.
    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }

    /// Decisions dropped to overflow.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Total decisions ever recorded (retained + dropped).
    pub fn recorded(&self) -> u64 {
        self.next_seq
    }

    /// FNV-1a digest over the deterministic text rendering — two runs
    /// of the same config must produce equal digests.
    pub fn digest(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut eat = |bytes: &[u8]| {
            for &b in bytes {
                h ^= b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01b3);
            }
        };
        eat(&self.dropped.to_le_bytes());
        for d in &self.ring {
            eat(&d.seq.to_le_bytes());
            eat(d.to_string().as_bytes());
        }
        h
    }

    /// Export the newest `tail` decisions (plus ring counters) as JSON
    /// for the flight recorder.
    pub fn to_json(&self, tail: usize) -> Json {
        let skip = self.ring.len().saturating_sub(tail);
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("recorded".into(), Json::Num(self.recorded() as f64));
        obj.insert("dropped".into(), Json::Num(self.dropped as f64));
        obj.insert("digest".into(), Json::Str(format!("{:016x}", self.digest())));
        obj.insert(
            "decisions".into(),
            Json::Arr(
                self.ring
                    .iter()
                    .skip(skip)
                    .map(|d| {
                        let mut e = std::collections::BTreeMap::new();
                        e.insert("seq".into(), Json::Num(d.seq as f64));
                        e.insert("at".into(), Json::Num(d.at as f64));
                        e.insert("shard".into(), Json::Num(d.shard as f64));
                        if d.req != NO_REQ {
                            e.insert("req".into(), Json::Num(d.req as f64));
                        }
                        e.insert("kind".into(), Json::Str(d.kind.name().into()));
                        e.insert("line".into(), Json::Str(d.to_string()));
                        Json::Obj(e)
                    })
                    .collect(),
            ),
        );
        Json::Obj(obj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn variant_decision(req: u64, at: u64) -> Decision {
        Decision::new(
            at,
            req,
            DecisionKind::Variant {
                task: "harris.corner".into(),
                chosen: 'c',
                replicas: 1,
                score: 4.0,
                resumed: false,
                alts: vec![
                    VariantAlt {
                        ver: 'c',
                        score: 4.0,
                        replicate: 0,
                        verdict: AltVerdict::Chosen,
                    },
                    VariantAlt {
                        ver: 'b',
                        score: 2.0,
                        replicate: 0,
                        verdict: AltVerdict::NotTried,
                    },
                ],
            },
        )
    }

    #[test]
    fn rendering_grammar_is_stable() {
        let d = variant_decision(3, 120);
        assert_eq!(
            d.to_string(),
            "at=120 shard=0 req=3 variant task=harris.corner chosen=c repl=1 score=4 \
             alts=[c:4:chosen b:2:not-tried]"
        );
        let nf = Decision::new(
            9,
            NO_REQ,
            DecisionKind::Defrag {
                task: "camera.pipeline".into(),
                ver: 'b',
                moves: 2,
                cost: 900,
                gain: 400,
                accepted: false,
            },
        );
        assert_eq!(
            nf.to_string(),
            "at=9 shard=0 req=- defrag task=camera.pipeline ver=b moves=2 cost=900 gain=400 \
             rejected"
        );
        let p = Decision::new(
            5,
            7,
            DecisionKind::Placement {
                tenant: 2,
                chosen: Some(1),
                rescued: None,
                shards: vec![ShardScore {
                    shard: 1,
                    open: 3,
                    feasible: true,
                    fits_now: false,
                    busy: 0.5,
                    corridor: 0.0,
                    marginal_pj: 0.0,
                    be_runway: 0,
                }],
            },
        );
        assert_eq!(
            p.to_string(),
            "at=5 shard=0 req=7 placement tenant=2 chosen=1 \
             shards=[1:open=3:feasible=true:fits=false:busy=0.500]"
        );
    }

    #[test]
    fn ring_drops_and_counts_and_queries_by_req() {
        let mut ring = ProvenanceRing::new(2);
        ring.push(variant_decision(1, 10));
        ring.push(variant_decision(2, 20));
        ring.push(variant_decision(2, 30));
        assert_eq!(ring.len(), 2);
        assert_eq!(ring.dropped(), 1);
        assert_eq!(ring.recorded(), 3);
        assert_eq!(ring.for_req(1).len(), 0, "oldest record was dropped");
        let two = ring.for_req(2);
        assert_eq!(two.len(), 2);
        assert!(two[0].seq < two[1].seq, "query preserves decision order");
    }

    #[test]
    fn digest_is_deterministic_and_content_sensitive() {
        let mut a = ProvenanceRing::new(8);
        let mut b = ProvenanceRing::new(8);
        for i in 0..4 {
            a.push(variant_decision(i, i * 10));
            b.push(variant_decision(i, i * 10));
        }
        assert_eq!(a.digest(), b.digest());
        b.push(variant_decision(9, 90));
        assert_ne!(a.digest(), b.digest());
    }

    #[test]
    fn json_export_bounds_the_tail() {
        let mut ring = ProvenanceRing::new(8);
        for i in 0..6 {
            ring.push(variant_decision(i, i));
        }
        let doc = ring.to_json(2);
        assert_eq!(doc.req("decisions").unwrap().items().len(), 2);
        assert_eq!(doc.req_u64("recorded").unwrap(), 6);
        // round-trips the in-tree parser
        let back = Json::parse(&doc.to_string()).unwrap();
        assert_eq!(back.to_string(), doc.to_string());
    }
}
