//! Perfetto / Chrome `trace_event` JSON export of a lifecycle journal.
//!
//! Renders the [`Journal`] as a timeline loadable in `ui.perfetto.dev`
//! (or `chrome://tracing`): one *process* per shard, one *thread*
//! (track) per execution region plus a `fabric` track per shard for
//! admission-level events, complete `"X"` slices for the
//! reconfiguring/executing stages, and `"i"` instants for placement,
//! preemption, defragmentation and migration.  Timestamps convert
//! cycles to microseconds at the fabric clock.
//!
//! The document is built directly from [`Json`] values, so the output
//! is guaranteed to round-trip through the in-tree parser
//! ([`Json::parse`]) and is byte-deterministic (`Json::Obj` is a
//! sorted map).

use std::collections::BTreeMap;
use std::collections::BTreeSet;

use crate::util::json::Json;

use super::journal::{Journal, JournalKind, NO_REQ};
use super::provenance::ProvenanceRing;

/// Reserved `tid` for the per-shard fabric (admission) track; region
/// tracks use `region + 1`.
const FABRIC_TID: u64 = 0;

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn num(v: u64) -> Json {
    Json::Num(v as f64)
}

/// One trace event row.
#[allow(clippy::too_many_arguments)]
fn event(
    name: &str,
    ph: &str,
    ts_us: f64,
    dur_us: Option<f64>,
    pid: u32,
    tid: u64,
    scope: Option<&str>,
    args: Vec<(&str, Json)>,
) -> Json {
    let mut pairs = vec![
        ("name", Json::Str(name.to_string())),
        ("ph", Json::Str(ph.to_string())),
        ("ts", Json::Num(ts_us)),
        ("pid", num(pid as u64)),
        ("tid", num(tid)),
    ];
    if let Some(d) = dur_us {
        pairs.push(("dur", Json::Num(d)));
    }
    if let Some(s) = scope {
        pairs.push(("s", Json::Str(s.to_string())));
    }
    if !args.is_empty() {
        pairs.push(("args", obj(args)));
    }
    obj(pairs)
}

fn meta(name: &str, pid: u32, tid: u64, label: &str) -> Json {
    event(name, "M", 0.0, None, pid, tid, None, vec![("name", Json::Str(label.to_string()))])
}

/// Export the journal as a Chrome `trace_event` document.
///
/// `mhz` is the fabric core clock in MHz (cycles per microsecond);
/// values of 0 are treated as 1 to keep timestamps finite.
pub fn export(journal: &Journal, mhz: u64) -> Json {
    let per_us = if mhz == 0 { 1.0 } else { mhz as f64 };
    let us = |cycles: u64| cycles as f64 / per_us;

    let mut shards: BTreeSet<u32> = BTreeSet::new();
    let mut tracks: BTreeSet<(u32, u64)> = BTreeSet::new();
    let mut rows: Vec<Json> = Vec::new();

    for ev in journal.events() {
        shards.insert(ev.shard);
        let req = ev.req;
        let req_arg = |mut extra: Vec<(&'static str, Json)>| {
            if req != NO_REQ {
                extra.insert(0, ("req", num(req)));
            }
            extra
        };
        match &ev.kind {
            JournalKind::Submitted { tenant, app } => {
                rows.push(event(
                    "submitted",
                    "i",
                    us(ev.at),
                    None,
                    ev.shard,
                    FABRIC_TID,
                    Some("t"),
                    req_arg(vec![
                        ("app", Json::Str(app.clone())),
                        ("tenant", num(*tenant as u64)),
                    ]),
                ));
            }
            JournalKind::Admitted | JournalKind::Queued => {
                rows.push(event(
                    ev.kind.stage_name(),
                    "i",
                    us(ev.at),
                    None,
                    ev.shard,
                    FABRIC_TID,
                    Some("t"),
                    req_arg(vec![]),
                ));
            }
            JournalKind::Rejected => {
                rows.push(event(
                    "rejected",
                    "i",
                    us(ev.at),
                    None,
                    ev.shard,
                    FABRIC_TID,
                    Some("t"),
                    req_arg(vec![]),
                ));
            }
            JournalKind::Placed { task, region } => {
                tracks.insert((ev.shard, *region));
                rows.push(event(
                    "placed",
                    "i",
                    us(ev.at),
                    None,
                    ev.shard,
                    region + 1,
                    Some("t"),
                    req_arg(vec![("task", Json::Str(task.clone()))]),
                ));
            }
            JournalKind::Reconfiguring { region, cycles, cache_hit } => {
                tracks.insert((ev.shard, *region));
                rows.push(event(
                    "reconfiguring",
                    "X",
                    us(ev.at),
                    Some(us(*cycles)),
                    ev.shard,
                    region + 1,
                    None,
                    req_arg(vec![
                        ("cache_hit", Json::Bool(*cache_hit)),
                        ("cycles", num(*cycles)),
                    ]),
                ));
            }
            JournalKind::Executing { region, cycles } => {
                tracks.insert((ev.shard, *region));
                rows.push(event(
                    "executing",
                    "X",
                    us(ev.at),
                    Some(us(*cycles)),
                    ev.shard,
                    region + 1,
                    None,
                    req_arg(vec![("cycles", num(*cycles))]),
                ));
            }
            JournalKind::Preempted { region, remaining, ckpt } => {
                tracks.insert((ev.shard, *region));
                rows.push(event(
                    "preempted",
                    "i",
                    us(ev.at),
                    None,
                    ev.shard,
                    region + 1,
                    Some("t"),
                    req_arg(vec![("ckpt", num(*ckpt)), ("remaining", num(*remaining))]),
                ));
            }
            JournalKind::Resumed { region } => {
                tracks.insert((ev.shard, *region));
                rows.push(event(
                    "resumed",
                    "i",
                    us(ev.at),
                    None,
                    ev.shard,
                    region + 1,
                    Some("t"),
                    req_arg(vec![]),
                ));
            }
            JournalKind::Completed { tenant } => {
                rows.push(event(
                    "completed",
                    "i",
                    us(ev.at),
                    None,
                    ev.shard,
                    FABRIC_TID,
                    Some("t"),
                    req_arg(vec![("tenant", num(*tenant as u64))]),
                ));
            }
            JournalKind::FrameStart { k } => {
                rows.push(event(
                    "frame",
                    "i",
                    us(ev.at),
                    None,
                    ev.shard,
                    FABRIC_TID,
                    Some("t"),
                    vec![("k", num(*k as u64))],
                ));
            }
            JournalKind::FrameDone { k, total, reconfig } => {
                rows.push(event(
                    "frame-done",
                    "i",
                    us(ev.at),
                    None,
                    ev.shard,
                    FABRIC_TID,
                    Some("t"),
                    vec![
                        ("k", num(*k as u64)),
                        ("reconfig", num(*reconfig)),
                        ("total", num(*total)),
                    ],
                ));
            }
            JournalKind::FrameRejected { k } => {
                rows.push(event(
                    "frame-rejected",
                    "i",
                    us(ev.at),
                    None,
                    ev.shard,
                    FABRIC_TID,
                    Some("t"),
                    vec![("k", num(*k as u64))],
                ));
            }
            JournalKind::Defrag { migrated, cycles } => {
                rows.push(event(
                    "defrag",
                    "i",
                    us(ev.at),
                    None,
                    ev.shard,
                    FABRIC_TID,
                    Some("p"),
                    vec![("cycles", num(*cycles)), ("migrated", num(*migrated))],
                ));
            }
            JournalKind::Migrated { task, from, to, cycles } => {
                tracks.insert((ev.shard, *to));
                rows.push(event(
                    "migrated",
                    "i",
                    us(ev.at),
                    None,
                    ev.shard,
                    to + 1,
                    Some("t"),
                    req_arg(vec![
                        ("cycles", num(*cycles)),
                        ("from", num(*from)),
                        ("task", Json::Str(task.clone())),
                        ("to", num(*to)),
                    ]),
                ));
            }
            JournalKind::Alert { what } => {
                rows.push(event(
                    "alert",
                    "i",
                    us(ev.at),
                    None,
                    ev.shard,
                    FABRIC_TID,
                    Some("g"),
                    vec![("what", Json::Str(what.clone()))],
                ));
            }
        }
    }

    // Name the tracks up front so Perfetto groups them sensibly.
    let mut all: Vec<Json> = Vec::new();
    for &s in &shards {
        all.push(meta("process_name", s, FABRIC_TID, &format!("shard {s}")));
        all.push(meta("thread_name", s, FABRIC_TID, "fabric"));
    }
    for &(s, r) in &tracks {
        all.push(meta("thread_name", s, r + 1, &format!("R{r}")));
    }
    all.extend(rows);

    let mut doc: BTreeMap<String, Json> = BTreeMap::new();
    doc.insert("displayTimeUnit".to_string(), Json::Str("ms".to_string()));
    doc.insert("traceEvents".to_string(), Json::Arr(all));
    Json::Obj(doc)
}

/// [`export`] rendered to a JSON string.
pub fn export_string(journal: &Journal, mhz: u64) -> String {
    export(journal, mhz).to_string()
}

/// Reserved `tid` for the per-shard decision-provenance track.
const DECISIONS_TID: u64 = 999_999;

/// Export the journal plus the decision-provenance ring: the base
/// [`export`] document extended with one instant per decision on a
/// per-shard `decisions` track, and Chrome *flow* events (`ph:"s"` →
/// `ph:"f"`, id = decision seq) linking each request-scoped decision
/// to that request's first `executing` lifecycle slice — Perfetto
/// draws the arrow from *why* to *what ran*.
pub fn export_full(journal: &Journal, prov: Option<&ProvenanceRing>, mhz: u64) -> Json {
    let mut doc = export(journal, mhz);
    let Some(ring) = prov else { return doc };
    let per_us = if mhz == 0 { 1.0 } else { mhz as f64 };
    let us = |cycles: u64| cycles as f64 / per_us;

    // First executing slice per request: flow arrows land there.
    let mut exec_at: BTreeMap<u64, (u32, u64, u64)> = BTreeMap::new();
    for ev in journal.events() {
        if let JournalKind::Executing { region, .. } = &ev.kind {
            exec_at.entry(ev.req).or_insert((ev.shard, *region, ev.at));
        }
    }

    let mut shards: BTreeSet<u32> = BTreeSet::new();
    let mut rows: Vec<Json> = Vec::new();
    for d in ring.decisions() {
        shards.insert(d.shard);
        let mut args = vec![("line", Json::Str(d.to_string())), ("seq", num(d.seq))];
        if d.req != NO_REQ {
            args.insert(0, ("req", num(d.req)));
        }
        rows.push(event(
            d.kind.name(),
            "i",
            us(d.at),
            None,
            d.shard,
            DECISIONS_TID,
            Some("t"),
            args,
        ));
        if d.req == NO_REQ {
            continue;
        }
        let Some(&(eshard, eregion, eat)) = exec_at.get(&d.req) else { continue };
        let flow = |ph: &str, ts: f64, pid: u32, tid: u64| {
            let mut pairs = vec![
                ("name", Json::Str(format!("decision:{}", d.kind.name()))),
                ("cat", Json::Str("provenance".to_string())),
                ("ph", Json::Str(ph.to_string())),
                ("id", num(d.seq)),
                ("ts", Json::Num(ts)),
                ("pid", num(pid as u64)),
                ("tid", num(tid)),
            ];
            if ph == "f" {
                pairs.push(("bp", Json::Str("e".to_string())));
            }
            obj(pairs)
        };
        rows.push(flow("s", us(d.at), d.shard, DECISIONS_TID));
        rows.push(flow("f", us(eat), eshard, eregion + 1));
    }

    if let Json::Obj(m) = &mut doc {
        if let Some(Json::Arr(events)) = m.get_mut("traceEvents") {
            for &s in &shards {
                events.push(meta("thread_name", s, DECISIONS_TID, "decisions"));
            }
            events.extend(rows);
        }
    }
    doc
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_journal() -> Journal {
        let mut j = Journal::new(256);
        j.stage(0, 4, 0, JournalKind::Submitted { tenant: 1, app: "Harris".into() });
        j.stage(0, 4, 0, JournalKind::Queued);
        j.stage(20, 4, 0, JournalKind::Placed { task: "harris".into(), region: 2 });
        j.stage(20, 4, 0, JournalKind::Reconfiguring { region: 2, cycles: 50, cache_hit: false });
        j.stage(70, 4, 0, JournalKind::Executing { region: 2, cycles: 400 });
        j.stage(200, 4, 0, JournalKind::Preempted { region: 2, remaining: 270, ckpt: 10 });
        j.stage(300, 4, 1, JournalKind::Defrag { migrated: 2, cycles: 120 });
        j.stage(470, 4, 0, JournalKind::Completed { tenant: 1 });
        j
    }

    #[test]
    fn export_round_trips_through_util_json() {
        let text = export_string(&sample_journal(), 500);
        let parsed = Json::parse(&text).expect("exporter must emit valid JSON");
        assert_eq!(parsed.to_string(), text, "parse → render must be the identity");
    }

    #[test]
    fn export_has_tracks_slices_and_instants() {
        let doc = export(&sample_journal(), 500);
        let events = match doc {
            Json::Obj(ref m) => match &m["traceEvents"] {
                Json::Arr(v) => v.clone(),
                other => panic!("traceEvents not an array: {other}"),
            },
            ref other => panic!("not an object: {other}"),
        };
        let names: Vec<String> = events
            .iter()
            .filter_map(|e| match e {
                Json::Obj(m) => match (&m["name"], &m["ph"]) {
                    (Json::Str(n), Json::Str(p)) => Some(format!("{p}:{n}")),
                    _ => None,
                },
                _ => None,
            })
            .collect();
        for want in [
            "M:process_name",
            "M:thread_name",
            "i:submitted",
            "X:reconfiguring",
            "X:executing",
            "i:preempted",
            "i:defrag",
            "i:completed",
        ] {
            assert!(names.iter().any(|n| n == want), "missing {want} in {names:?}");
        }
        // 500 MHz: 50 cycles = 0.1 µs
        let reconf = events
            .iter()
            .find_map(|e| match e {
                Json::Obj(m) if m["name"] == Json::Str("reconfiguring".into()) => Some(m.clone()),
                _ => None,
            })
            .unwrap();
        assert_eq!(reconf["dur"], Json::Num(0.1));
        assert_eq!(reconf["ts"], Json::Num(0.04));
    }

    #[test]
    fn export_full_links_decisions_to_slices() {
        use crate::obs::provenance::{Decision, DecisionKind};
        let mut j = sample_journal();
        j.stage(480, NO_REQ, 0, JournalKind::Alert { what: "slo-burn class=critical".into() });
        let mut ring = ProvenanceRing::new(16);
        ring.push(Decision::new(
            18,
            4,
            DecisionKind::Variant {
                task: "harris".into(),
                chosen: 'a',
                replicas: 1,
                score: 3.0,
                resumed: false,
                alts: vec![],
            },
        ));
        ring.push(Decision::new(
            300,
            NO_REQ,
            DecisionKind::Defrag {
                task: "sum".into(),
                ver: 'b',
                moves: 1,
                cost: 100,
                gain: 400,
                accepted: true,
            },
        ));
        let doc = export_full(&j, Some(&ring), 500);
        let text = doc.to_string();
        assert_eq!(Json::parse(&text).unwrap().to_string(), text, "round-trip");
        let events = doc.get("traceEvents").unwrap().items();
        let phs: Vec<(&str, &str)> = events
            .iter()
            .filter_map(|e| match (e.get("name"), e.get("ph")) {
                (Some(Json::Str(n)), Some(Json::Str(p))) => Some((n.as_str(), p.as_str())),
                _ => None,
            })
            .collect();
        assert!(phs.contains(&("variant", "i")), "decision instant: {phs:?}");
        assert!(phs.contains(&("defrag", "i")), "fabric-scoped decision instant");
        assert!(phs.contains(&("alert", "i")), "alert instant");
        assert!(phs.contains(&("decision:variant", "s")), "flow start");
        assert!(phs.contains(&("decision:variant", "f")), "flow finish");
        // the flow finish must land on the executing slice's track/ts
        let finish = events
            .iter()
            .find(|e| {
                e.get("ph") == Some(&Json::Str("f".into()))
                    && e.get("name") == Some(&Json::Str("decision:variant".into()))
            })
            .unwrap();
        assert_eq!(finish.get("tid"), Some(&Json::Num(3.0)), "region 2 track");
        assert_eq!(finish.get("ts"), Some(&Json::Num(0.14)), "executing at cycle 70 @500MHz");
        // fabric-scoped decisions produce no flow pair
        assert!(!phs.contains(&("decision:defrag", "s")));
        // without a ring, export_full degrades to the base export
        assert_eq!(export_full(&j, None, 500).to_string(), export(&j, 500).to_string());
    }

    #[test]
    fn empty_journal_exports_empty_event_list() {
        let doc = export(&Journal::disabled(), 500);
        let text = doc.to_string();
        assert!(text.contains("\"traceEvents\":[]"), "{text}");
        assert!(Json::parse(&text).is_ok());
    }
}
