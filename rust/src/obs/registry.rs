//! Typed metrics registry: named counters, gauges and log-linear
//! histograms with Prometheus-style label sets and text exposition.
//!
//! Series handles are cheap `Arc`-backed atomics, so the registry can
//! be shared across the serving threads (connection handlers, shard
//! executors) without locks on the hot path — the registry mutex is
//! taken only at registration and exposition time.  All values are
//! integers or f64-bit gauges; exposition iterates `BTreeMap`s, so the
//! rendered text is deterministic for a deterministic run.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Label set: sorted `(key, value)` pairs.
pub type Labels = Vec<(String, String)>;

/// Build a sorted label set from `(key, value)` pairs.
pub fn labels(pairs: &[(&str, &str)]) -> Labels {
    let mut v: Labels =
        pairs.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
    v.sort();
    v
}

/// A monotonically increasing counter.
#[derive(Clone, Debug)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    /// Increment by one.
    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    /// Increment by `n`.
    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Overwrite with a sampled cumulative total (for subsystems that
    /// keep their own counters and export point-in-time snapshots).
    #[inline]
    pub fn set_total(&self, n: u64) {
        self.0.store(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A point-in-time f64 gauge.
#[derive(Clone, Debug)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    /// Set the gauge.
    #[inline]
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

// ------------------------------------------------------------ histogram

/// Sub-bucket resolution: each power-of-two octave splits into
/// `1 << SUB_BITS` linear buckets (≤ 12.5 % relative bucket width).
const SUB_BITS: u32 = 3;
const SUB: usize = 1 << SUB_BITS;
/// Bucket count covering the full u64 range (values below `2·SUB` are
/// exact; see [`bucket_index`]).
pub const HIST_BUCKETS: usize = (64 - SUB_BITS as usize + 1) * SUB;

/// Bucket index of `v` in the log-linear layout.
#[inline]
fn bucket_index(v: u64) -> usize {
    if v < (2 * SUB as u64) {
        return v as usize; // exact region: 0..16 one bucket per value
    }
    let msb = 63 - v.leading_zeros(); // >= SUB_BITS + 1
    let sub = ((v >> (msb - SUB_BITS)) & (SUB as u64 - 1)) as usize;
    ((msb - SUB_BITS) as usize + 1) * SUB + sub
}

/// `[lo, hi)` value range of bucket `idx` (inverse of [`bucket_index`]).
fn bucket_bounds(idx: usize) -> (u64, u64) {
    if idx < 2 * SUB {
        return (idx as u64, idx as u64 + 1);
    }
    let oct = (idx / SUB - 1) as u32 + SUB_BITS; // exponent of the octave base
    let sub = (idx % SUB) as u64;
    let width = 1u64 << (oct - SUB_BITS);
    let lo = (1u64 << oct) + sub * width;
    (lo, lo.saturating_add(width))
}

/// Shared histogram storage.
#[derive(Debug)]
struct HistCore {
    buckets: Vec<AtomicU64>,
    sum: AtomicU64,
    count: AtomicU64,
}

/// A log-linear histogram of u64 observations (cycles, bytes, µs).
#[derive(Clone, Debug)]
pub struct Histogram(Arc<HistCore>);

impl Histogram {
    fn new() -> Histogram {
        Histogram(Arc::new(HistCore {
            buckets: (0..HIST_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            sum: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }))
    }

    /// Record one observation.
    #[inline]
    pub fn observe(&self, v: u64) {
        self.0.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.0.sum.fetch_add(v, Ordering::Relaxed);
        self.0.count.fetch_add(1, Ordering::Relaxed);
    }

    /// Point-in-time snapshot (quantiles, merging).
    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            buckets: self.0.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            sum: self.0.sum.load(Ordering::Relaxed),
            count: self.0.count.load(Ordering::Relaxed),
        }
    }
}

/// An owned histogram snapshot: mergeable across shards, queryable for
/// interpolated quantiles.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HistSnapshot {
    buckets: Vec<u64>,
    /// Sum of all observations.
    pub sum: u64,
    /// Number of observations.
    pub count: u64,
}

impl Default for HistSnapshot {
    fn default() -> Self {
        HistSnapshot { buckets: vec![0; HIST_BUCKETS], sum: 0, count: 0 }
    }
}

impl HistSnapshot {
    /// Empty snapshot.
    pub fn new() -> HistSnapshot {
        HistSnapshot::default()
    }

    /// Record into the snapshot directly (single-threaded collectors).
    pub fn observe(&mut self, v: u64) {
        self.buckets[bucket_index(v)] += 1;
        self.sum += v;
        self.count += 1;
    }

    /// Merge another snapshot in.  Bucket-wise addition, so merging is
    /// commutative and associative — shard merge order cannot change
    /// any quantile.
    pub fn merge(&mut self, other: &HistSnapshot) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.sum += other.sum;
        self.count += other.count;
    }

    /// Mean observation (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.sum as f64 / self.count as f64
    }

    /// Interpolated quantile `q ∈ [0, 1]` (0 when empty).  Exact for
    /// values in the exact region (< 16); within one sub-bucket width
    /// (≤ 12.5 %) otherwise.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = q.clamp(0.0, 1.0) * (self.count - 1) as f64;
        let mut before = 0u64;
        for (idx, &c) in self.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if (before + c) as f64 > target {
                let (lo, hi) = bucket_bounds(idx);
                let within = (target - before as f64) / c as f64;
                return lo as f64 + within * (hi - lo) as f64;
            }
            before += c;
        }
        // numeric fallback: the highest populated bucket's lower bound
        let idx = self.buckets.iter().rposition(|&c| c > 0).unwrap_or(0);
        bucket_bounds(idx).0 as f64
    }

    /// Non-empty `(le_exclusive, cumulative_count)` bucket boundaries.
    pub fn cumulative(&self) -> Vec<(u64, u64)> {
        let mut out = Vec::new();
        let mut cum = 0u64;
        for (idx, &c) in self.buckets.iter().enumerate() {
            if c > 0 {
                cum += c;
                out.push((bucket_bounds(idx).1, cum));
            }
        }
        out
    }
}

// ------------------------------------------------------------- registry

type SeriesKey = (String, Labels);

#[derive(Debug, Default)]
struct Inner {
    counters: BTreeMap<SeriesKey, Arc<AtomicU64>>,
    gauges: BTreeMap<SeriesKey, Arc<AtomicU64>>,
    hists: BTreeMap<SeriesKey, Histogram>,
    help: BTreeMap<String, String>,
}

/// A registry of named metric series.  Cloning shares the underlying
/// store (the serving fronts hand one registry to every shard
/// executor).
#[derive(Clone, Debug, Default)]
pub struct MetricsRegistry {
    inner: Arc<Mutex<Inner>>,
}

fn valid_name(name: &str) -> bool {
    !name.is_empty()
        && name.chars().next().is_some_and(|c| c.is_ascii_lowercase() || c == '_')
        && name.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
}

impl MetricsRegistry {
    /// Fresh empty registry.
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// Register (or look up) a counter series.
    pub fn counter(&self, name: &str, lbls: &[(&str, &str)]) -> Counter {
        debug_assert!(valid_name(name), "bad metric name {name:?}");
        let key = (name.to_string(), labels(lbls));
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        Counter(inner.counters.entry(key).or_insert_with(|| Arc::new(AtomicU64::new(0))).clone())
    }

    /// Register (or look up) a gauge series.
    pub fn gauge(&self, name: &str, lbls: &[(&str, &str)]) -> Gauge {
        debug_assert!(valid_name(name), "bad metric name {name:?}");
        let key = (name.to_string(), labels(lbls));
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        Gauge(
            inner
                .gauges
                .entry(key)
                .or_insert_with(|| Arc::new(AtomicU64::new(0f64.to_bits())))
                .clone(),
        )
    }

    /// Register (or look up) a histogram series.
    pub fn histogram(&self, name: &str, lbls: &[(&str, &str)]) -> Histogram {
        debug_assert!(valid_name(name), "bad metric name {name:?}");
        let key = (name.to_string(), labels(lbls));
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        inner.hists.entry(key).or_insert_with(Histogram::new).clone()
    }

    /// Convenience: set a sampled cumulative counter in one call.
    pub fn set_counter(&self, name: &str, lbls: &[(&str, &str)], v: u64) {
        self.counter(name, lbls).set_total(v);
    }

    /// Convenience: set a gauge in one call.
    pub fn set_gauge(&self, name: &str, lbls: &[(&str, &str)], v: f64) {
        self.gauge(name, lbls).set(v);
    }

    /// Attach a `# HELP` description to a metric name (idempotent).
    pub fn describe(&self, name: &str, help: &str) {
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        inner.help.entry(name.to_string()).or_insert_with(|| help.to_string());
    }

    /// Register the constant `cgra_build_info{version,git} 1` gauge so
    /// scrapers can join every series onto the producing build.
    pub fn build_info(&self) {
        let version = env!("CARGO_PKG_VERSION");
        let git = option_env!("GIT_HASH").unwrap_or("unknown");
        self.describe("cgra_build_info", "build metadata of the exporting binary (constant 1)");
        self.set_gauge("cgra_build_info", &[("version", version), ("git", git)], 1.0);
    }

    /// Prometheus-style text exposition: `# HELP` / `# TYPE` headers
    /// plus one line per series, sorted by name then labels; histograms
    /// render cumulative `_bucket{le=…}` lines (only populated
    /// boundaries), `_sum` and `_count`.  Deterministic for
    /// deterministic inputs.
    pub fn render(&self) -> String {
        let inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let mut out = String::new();
        let mut last_header = String::new();
        let help = &inner.help;
        let mut typed_header = |out: &mut String, name: &str, kind: &str| {
            if last_header != name {
                if let Some(h) = help.get(name) {
                    let _ = writeln!(out, "# HELP {name} {h}");
                }
                let _ = writeln!(out, "# TYPE {name} {kind}");
                last_header = name.to_string();
            }
        };
        for ((name, lbls), v) in &inner.counters {
            typed_header(&mut out, name, "counter");
            let _ = writeln!(out, "{}{} {}", name, render_labels(lbls), v.load(Ordering::Relaxed));
        }
        for ((name, lbls), v) in &inner.gauges {
            typed_header(&mut out, name, "gauge");
            let _ = writeln!(
                out,
                "{}{} {}",
                name,
                render_labels(lbls),
                fmt_f64(f64::from_bits(v.load(Ordering::Relaxed)))
            );
        }
        for ((name, lbls), h) in &inner.hists {
            typed_header(&mut out, name, "histogram");
            let snap = h.snapshot();
            for (le, cum) in snap.cumulative() {
                let mut with_le = lbls.clone();
                with_le.push(("le".to_string(), le.to_string()));
                with_le.sort();
                let _ = writeln!(out, "{}_bucket{} {}", name, render_labels(&with_le), cum);
            }
            let mut inf = lbls.clone();
            inf.push(("le".to_string(), "+Inf".to_string()));
            inf.sort();
            let _ = writeln!(out, "{}_bucket{} {}", name, render_labels(&inf), snap.count);
            let _ = writeln!(out, "{}_sum{} {}", name, render_labels(lbls), snap.sum);
            let _ = writeln!(out, "{}_count{} {}", name, render_labels(lbls), snap.count);
        }
        out
    }
}

fn render_labels(lbls: &Labels) -> String {
    if lbls.is_empty() {
        return String::new();
    }
    let body: Vec<String> =
        lbls.iter().map(|(k, v)| format!("{k}=\"{}\"", v.replace('"', "\\\""))).collect();
    format!("{{{}}}", body.join(","))
}

fn fmt_f64(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_and_bounds_are_inverse() {
        for v in [0u64, 1, 7, 15, 16, 17, 100, 1000, 65_535, 1 << 40, u64::MAX] {
            let idx = bucket_index(v);
            let (lo, hi) = bucket_bounds(idx);
            assert!(lo <= v && (v < hi || hi == u64::MAX), "v={v} idx={idx} lo={lo} hi={hi}");
        }
        // buckets are contiguous through the log-linear region
        for idx in 0..1000 {
            let (_, hi) = bucket_bounds(idx);
            let (lo2, _) = bucket_bounds(idx + 1);
            assert_eq!(hi, lo2, "gap at idx {idx}");
        }
    }

    #[test]
    fn counters_and_gauges_render() {
        let reg = MetricsRegistry::new();
        let c = reg.counter("cgra_test_total", &[("shard", "0"), ("class", "critical")]);
        c.inc();
        c.add(2);
        reg.set_gauge("cgra_test_gauge", &[], 1.5);
        let text = reg.render();
        assert!(text.contains("cgra_test_total{class=\"critical\",shard=\"0\"} 3"), "{text}");
        assert!(text.contains("# TYPE cgra_test_total counter"), "{text}");
        assert!(text.contains("cgra_test_gauge 1.5"), "{text}");
        // re-registration returns the same series
        reg.counter("cgra_test_total", &[("class", "critical"), ("shard", "0")]).inc();
        let relabeled = reg.counter("cgra_test_total", &[("shard", "0"), ("class", "critical")]);
        assert_eq!(relabeled.get(), 4);
    }

    #[test]
    fn help_lines_and_build_info_render() {
        let reg = MetricsRegistry::new();
        reg.describe("cgra_helped_total", "a described counter");
        reg.counter("cgra_helped_total", &[]).inc();
        reg.counter("cgra_bare_total", &[]).inc();
        reg.build_info();
        let text = reg.render();
        assert!(text.contains("# HELP cgra_helped_total a described counter\n"), "{text}");
        assert!(text.contains("# TYPE cgra_helped_total counter"), "{text}");
        // undescribed series still get a TYPE header, just no HELP
        assert!(!text.contains("# HELP cgra_bare_total"), "{text}");
        assert!(text.contains("# HELP cgra_build_info"), "{text}");
        let line = text
            .lines()
            .find(|l| l.starts_with("cgra_build_info{"))
            .expect("build info series");
        assert!(line.contains("version=\""), "{line}");
        assert!(line.contains("git=\""), "{line}");
        assert!(line.ends_with(" 1"), "{line}");
        // HELP precedes TYPE for the same metric
        let help_at = text.find("# HELP cgra_helped_total").unwrap();
        let type_at = text.find("# TYPE cgra_helped_total").unwrap();
        assert!(help_at < type_at);
    }

    #[test]
    fn histogram_exposition_has_cumulative_buckets() {
        let reg = MetricsRegistry::new();
        let h = reg.histogram("cgra_test_cycles", &[]);
        for v in [1u64, 1, 2, 100] {
            h.observe(v);
        }
        let text = reg.render();
        assert!(text.contains("# TYPE cgra_test_cycles histogram"), "{text}");
        assert!(text.contains("cgra_test_cycles_bucket{le=\"2\"} 2"), "{text}");
        assert!(text.contains("cgra_test_cycles_bucket{le=\"3\"} 3"), "{text}");
        assert!(text.contains("cgra_test_cycles_bucket{le=\"+Inf\"} 4"), "{text}");
        assert!(text.contains("cgra_test_cycles_sum 104"), "{text}");
        assert!(text.contains("cgra_test_cycles_count 4"), "{text}");
    }

    #[test]
    fn quantile_empty_single_and_duplicates() {
        let empty = HistSnapshot::new();
        assert_eq!(empty.quantile(0.5), 0.0);
        assert_eq!(empty.mean(), 0.0);

        let mut one = HistSnapshot::new();
        one.observe(7);
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(one.quantile(q), 7.0, "single sample is every quantile");
        }

        // duplicate-heavy: 1000 copies of the same exact-region value
        let mut dup = HistSnapshot::new();
        for _ in 0..1000 {
            dup.observe(5);
        }
        for q in [0.0, 0.25, 0.5, 0.9, 1.0] {
            let got = dup.quantile(q);
            assert!((5.0..6.0).contains(&got), "q={q} got {got}");
        }
        assert_eq!(dup.mean(), 5.0);
    }

    #[test]
    fn quantile_interpolates_within_error_bound() {
        let mut h = HistSnapshot::new();
        for v in 1..=10_000u64 {
            h.observe(v);
        }
        for (q, want) in [(0.5, 5000.0), (0.9, 9000.0), (0.99, 9900.0)] {
            let got = h.quantile(q);
            let err = (got - want).abs() / want;
            assert!(err < 0.13, "q={q}: got {got}, want ~{want}, err {err}");
        }
        assert_eq!(h.quantile(0.0), 1.0);
    }

    #[test]
    fn merge_is_order_independent() {
        let mk = |vals: &[u64]| {
            let mut h = HistSnapshot::new();
            for &v in vals {
                h.observe(v);
            }
            h
        };
        // three shard-weighted snapshots of very different sizes
        let a = mk(&(0..500).map(|i| i * 3 + 1).collect::<Vec<_>>());
        let b = mk(&[42u64; 10_000]);
        let c = mk(&(0..7).map(|i| 1u64 << (i * 4)).collect::<Vec<_>>());

        let orders: Vec<Vec<&HistSnapshot>> = vec![
            vec![&a, &b, &c],
            vec![&c, &b, &a],
            vec![&b, &a, &c],
        ];
        let merged: Vec<HistSnapshot> = orders
            .into_iter()
            .map(|order| {
                let mut m = HistSnapshot::new();
                for h in order {
                    m.merge(h);
                }
                m
            })
            .collect();
        for m in &merged[1..] {
            assert_eq!(m, &merged[0], "merge must be order-independent");
        }
        for q in [0.01, 0.5, 0.999] {
            assert_eq!(merged[0].quantile(q), merged[1].quantile(q));
        }
        assert_eq!(merged[0].count, 500 + 10_000 + 7);
    }

    #[test]
    fn atomic_histogram_matches_snapshot_collector() {
        let reg = MetricsRegistry::new();
        let h = reg.histogram("cgra_x", &[]);
        let mut direct = HistSnapshot::new();
        for v in [0u64, 3, 900, 1 << 33] {
            h.observe(v);
            direct.observe(v);
        }
        assert_eq!(h.snapshot(), direct);
    }
}
