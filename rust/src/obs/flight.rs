//! Flight recorder: one-shot postmortem snapshots.
//!
//! On a watchdog alert or an explicit `DUMP` wire verb the serving
//! front folds the journal tail, the decision-provenance ring, the
//! full metrics exposition and the active `[obs]` config into a single
//! JSON artifact.  The document is built from [`crate::util::json`]
//! values, so it round-trips the in-tree parser byte-for-byte
//! (sorted-key one-line rendering) — a dumped record is also the test
//! fixture for reading one back.

use std::collections::BTreeMap;

use crate::config::ObsConfig;
use crate::error::{Error, Result};
use crate::util::json::Json;

use super::journal::Journal;
use super::provenance::ProvenanceRing;
use super::registry::MetricsRegistry;

/// Format version stamped into every record.
pub const FLIGHT_VERSION: u64 = 1;

/// Events / decisions retained per section — bounds the artifact (and
/// the framed `DUMP` reply) regardless of ring capacities.
pub const FLIGHT_TAIL: usize = 128;

fn num(v: u64) -> Json {
    Json::Num(v as f64)
}

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

/// Snapshot everything into one postmortem document.
///
/// `reason` is free-form provenance for why the dump happened
/// (`"verb:DUMP"`, `"alert:slo-burn ..."`, `"shutdown"`).
pub fn flight_record(
    reason: &str,
    at: u64,
    journal: &Journal,
    provenance: Option<&ProvenanceRing>,
    registry: &MetricsRegistry,
    cfg: &ObsConfig,
) -> Json {
    let total = journal.len();
    let tail_skip = total.saturating_sub(FLIGHT_TAIL);
    let events: Vec<Json> =
        journal.events().skip(tail_skip).map(|e| Json::Str(e.to_string())).collect();
    let journal_doc = obj(vec![
        ("digest", Json::Str(format!("{:016x}", journal.digest()))),
        ("dropped", num(journal.dropped())),
        ("retained", num(total as u64)),
        ("events", Json::Arr(events)),
    ]);
    let metrics: Vec<Json> =
        registry.render().lines().map(|l| Json::Str(l.to_string())).collect();
    let config_doc = obj(vec![
        ("enabled", Json::Bool(cfg.enabled)),
        ("journal_cap", num(cfg.journal_cap as u64)),
        ("provenance", Json::Bool(cfg.provenance)),
        ("provenance_cap", num(cfg.provenance_cap as u64)),
        ("watchdog", Json::Bool(cfg.watchdog)),
        ("slo_fast_window", num(cfg.slo_fast_window as u64)),
        ("slo_slow_window", num(cfg.slo_slow_window as u64)),
        ("slo_budget", Json::Num(cfg.slo_budget)),
        ("burn_fast", Json::Num(cfg.burn_fast)),
        ("burn_slow", Json::Num(cfg.burn_slow)),
        ("anomaly_sigma", Json::Num(cfg.anomaly_sigma)),
        ("watch_queue_cap", num(cfg.watch_queue_cap as u64)),
    ]);
    obj(vec![
        ("flight_record", num(FLIGHT_VERSION)),
        ("reason", Json::Str(reason.to_string())),
        ("at", num(at)),
        ("journal", journal_doc),
        ("provenance", provenance.map_or(Json::Null, |r| r.to_json(FLIGHT_TAIL))),
        ("metrics", Json::Arr(metrics)),
        ("config", config_doc),
    ])
}

/// Validated shape of a parsed flight record.
#[derive(Clone, Debug, PartialEq)]
pub struct FlightSummary {
    /// Format version ([`FLIGHT_VERSION`]).
    pub version: u64,
    /// Why the record was cut.
    pub reason: String,
    /// Cycle / timestamp of the snapshot.
    pub at: u64,
    /// Journal event lines retained in the record.
    pub journal_events: usize,
    /// Journal events dropped by the ring before the snapshot.
    pub journal_dropped: u64,
    /// Provenance decision lines retained (0 when provenance was off).
    pub decisions: usize,
    /// Metric exposition lines.
    pub metric_lines: usize,
}

/// Parse-and-validate a flight record document (the bench smoke leg
/// and the round-trip tests load dumps back through this).
pub fn validate_flight_record(doc: &Json) -> Result<FlightSummary> {
    let version = doc.req_u64("flight_record")?;
    if version != FLIGHT_VERSION {
        return Err(Error::parse(
            "$.flight_record",
            format!("unsupported version {version} (expected {FLIGHT_VERSION})"),
        ));
    }
    let journal = doc.req("journal")?;
    let digest = journal.req_str("digest")?;
    if digest.len() != 16 || !digest.bytes().all(|b| b.is_ascii_hexdigit()) {
        return Err(Error::parse("$.journal.digest", "expected 16 hex digits"));
    }
    let events = journal.req("events")?.items();
    if events.iter().any(|e| e.as_str().is_none()) {
        return Err(Error::parse("$.journal.events", "expected string event lines"));
    }
    let decisions = match doc.req("provenance")? {
        Json::Null => 0,
        prov => {
            prov.req_u64("recorded")?;
            prov.req("decisions")?.items().len()
        }
    };
    let cfg = doc.req("config")?;
    cfg.req_u64("journal_cap")?;
    Ok(FlightSummary {
        version,
        reason: doc.req_str("reason")?.to_string(),
        at: doc.req_u64("at")?,
        journal_events: events.len(),
        journal_dropped: journal.req_u64("dropped")?,
        decisions,
        metric_lines: doc.req("metrics")?.items().len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::journal::JournalKind;
    use crate::obs::provenance::{Decision, DecisionKind};

    fn sample_record() -> Json {
        let mut j = Journal::new(256);
        j.stage(10, 1, 0, JournalKind::Queued);
        j.stage(20, 1, 0, JournalKind::Completed { tenant: 3 });
        j.stage(25, super::super::NO_REQ, 0, JournalKind::Alert { what: "slo-burn test".into() });
        let mut ring = ProvenanceRing::new(64);
        ring.push(Decision::new(
            12,
            1,
            DecisionKind::Variant {
                task: "conv".into(),
                chosen: 'a',
                replicas: 1,
                score: 2.0,
                resumed: false,
                alts: vec![],
            },
        ));
        let reg = MetricsRegistry::new();
        reg.build_info();
        reg.counter("cgra_flight_test_total", &[]).add(7);
        flight_record("verb:DUMP", 25, &j, Some(&ring), &reg, &ObsConfig::default())
    }

    #[test]
    fn record_round_trips_the_in_tree_parser() {
        let doc = sample_record();
        let text = doc.to_string();
        let parsed = Json::parse(&text).expect("flight record must parse");
        assert_eq!(parsed, doc, "display/parse round-trip must be lossless");
        let s = validate_flight_record(&parsed).expect("valid record");
        assert_eq!(s.version, FLIGHT_VERSION);
        assert_eq!(s.reason, "verb:DUMP");
        assert_eq!(s.at, 25);
        assert_eq!(s.journal_events, 3);
        assert_eq!(s.decisions, 1);
        assert!(s.metric_lines >= 3, "build info + counter series: {}", s.metric_lines);
    }

    #[test]
    fn journal_tail_is_bounded() {
        let mut j = Journal::new(4096);
        for i in 0..(FLIGHT_TAIL as u64 + 50) {
            j.stage(i, i, 0, JournalKind::Queued);
        }
        let doc = flight_record("t", 0, &j, None, &MetricsRegistry::new(), &ObsConfig::default());
        let s = validate_flight_record(&doc).unwrap();
        assert_eq!(s.journal_events, FLIGHT_TAIL, "tail must cap the artifact");
        assert_eq!(s.decisions, 0, "provenance-off dumps validate too");
        // the tail keeps the *newest* events
        let first = doc.req("journal").unwrap().req("events").unwrap().items()[0]
            .as_str()
            .unwrap()
            .to_string();
        assert!(first.starts_with("at=50 "), "{first}");
    }

    #[test]
    fn validation_rejects_malformed_records() {
        let doc = sample_record();
        let mut m = match doc.clone() {
            Json::Obj(m) => m,
            _ => unreachable!(),
        };
        m.insert("flight_record".into(), Json::Num(99.0));
        assert!(validate_flight_record(&Json::Obj(m)).is_err(), "wrong version");
        let mut m = match doc {
            Json::Obj(m) => m,
            _ => unreachable!(),
        };
        m.remove("journal");
        assert!(validate_flight_record(&Json::Obj(m)).is_err(), "missing journal");
    }
}
