//! SLO burn-rate watchdog: multi-window burn rates over the per-class
//! SLO stream plus per-shard utilization/power anomaly scoring.
//!
//! Burn rate is the SRE convention: the deadline-miss fraction inside
//! a window divided by the error budget (`obs.slo_budget`), so a burn
//! of 1.0 spends budget exactly as fast as allowed.  Two windows guard
//! each class — a *fast* window (newest `obs.slo_fast_window`
//! deadlined completions) that reacts quickly, and a *slow* window
//! (`obs.slo_slow_window`) that filters blips: an [`Alert`] fires only
//! while **both** burn above their thresholds, and latches so a
//! sustained violation raises one alert, not one per completion.
//!
//! Shard anomaly scoring keeps a running mean/variance (Welford) per
//! shard over utilization and power samples; a sample further than
//! `obs.anomaly_sigma` standard deviations from the mean raises a
//! typed anomaly alert (also latched per excursion).
//!
//! Windows are measured in *completions*, not wall cycles, so the
//! watchdog is deterministic in both the virtual-time simulators and
//! the serving path.

use std::collections::{BTreeMap, VecDeque};
use std::fmt;

use crate::config::{ObsConfig, QosClass};

/// What a raised alert is about.
#[derive(Clone, Debug, PartialEq)]
pub enum AlertKind {
    /// A class is burning SLO budget above threshold in both windows.
    SloBurn {
        /// Affected class.
        class: QosClass,
        /// Fast-window burn rate (budget multiples).
        fast: f64,
        /// Slow-window burn rate.
        slow: f64,
    },
    /// A shard utilization sample left the running-mean envelope.
    UtilAnomaly {
        /// Sampled busy fraction.
        value: f64,
        /// Running mean at sample time.
        mean: f64,
        /// Standard-deviation distance.
        sigma: f64,
    },
    /// A shard power sample left the running-mean envelope.
    PowerAnomaly {
        /// Sampled watts.
        value: f64,
        /// Running mean at sample time.
        mean: f64,
        /// Standard-deviation distance.
        sigma: f64,
    },
}

impl AlertKind {
    /// Stable label value (registry + journal).
    pub fn name(&self) -> &'static str {
        match self {
            AlertKind::SloBurn { .. } => "slo-burn",
            AlertKind::UtilAnomaly { .. } => "util-anomaly",
            AlertKind::PowerAnomaly { .. } => "power-anomaly",
        }
    }
}

/// One typed alert raised by [`Watchdog::poll`].
#[derive(Clone, Debug, PartialEq)]
pub struct Alert {
    /// Cycle the alert was raised.
    pub at: u64,
    /// Shard the alert concerns (0 for class-wide SLO burns on a
    /// single fabric).
    pub shard: u32,
    /// What fired.
    pub kind: AlertKind,
}

impl fmt::Display for AlertKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AlertKind::SloBurn { class, fast, slow } => {
                write!(f, "slo-burn class={} fast={:.2} slow={:.2}", class.name(), fast, slow)
            }
            AlertKind::UtilAnomaly { value, mean, sigma } => {
                write!(f, "util-anomaly value={value:.3} mean={mean:.3} sigma={sigma:.1}")
            }
            AlertKind::PowerAnomaly { value, mean, sigma } => {
                write!(f, "power-anomaly value={value:.3} mean={mean:.3} sigma={sigma:.1}")
            }
        }
    }
}

impl fmt::Display for Alert {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "alert at={} shard={} {}", self.at, self.shard, self.kind)
    }
}

/// Running mean/variance (Welford's online algorithm).
#[derive(Clone, Debug, Default)]
struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    fn stddev(&self) -> f64 {
        if self.n < 2 {
            return 0.0;
        }
        (self.m2 / (self.n - 1) as f64).sqrt()
    }
}

/// Samples a shard stream needs before anomaly scoring engages — a
/// cold mean is meaningless.
const MIN_ANOMALY_SAMPLES: u64 = 16;

#[derive(Clone, Debug, Default)]
struct ShardStream {
    stats: Welford,
    /// Pending excursion awaiting the next poll (value, mean, sigma).
    pending: Option<(f64, f64, f64)>,
    latched: bool,
}

impl ShardStream {
    fn sample(&mut self, x: f64, threshold: f64) {
        let dev = self.stats.stddev();
        if self.stats.n >= MIN_ANOMALY_SAMPLES && dev > 0.0 {
            let sigma = (x - self.stats.mean).abs() / dev;
            if sigma > threshold {
                if !self.latched {
                    self.pending = Some((x, self.stats.mean, sigma));
                    self.latched = true;
                }
            } else {
                self.latched = false;
            }
        }
        self.stats.push(x);
    }
}

#[derive(Clone, Debug, Default)]
struct ShardState {
    util: ShardStream,
    power: ShardStream,
}

/// The burn-rate watchdog; see the module docs for semantics.
#[derive(Clone, Debug)]
pub struct Watchdog {
    fast_window: usize,
    slow_window: usize,
    budget: f64,
    burn_fast: f64,
    burn_slow: f64,
    anomaly_sigma: f64,
    /// Per-class miss history, newest at the back (slow-window bound).
    misses: [VecDeque<bool>; 3],
    latched: [bool; 3],
    /// Cumulative-counter absorption state per class: (deadlined,
    /// missed) seen so far ([`Watchdog::absorb_cumulative`]).
    absorbed: [(u64, u64); 3],
    shards: BTreeMap<u32, ShardState>,
    alerts_raised: u64,
}

impl Watchdog {
    /// Build from the `[obs]` knobs.
    pub fn new(cfg: &ObsConfig) -> Watchdog {
        Watchdog {
            fast_window: cfg.slo_fast_window.max(1),
            slow_window: cfg.slo_slow_window.max(cfg.slo_fast_window).max(1),
            budget: cfg.slo_budget,
            burn_fast: cfg.burn_fast,
            burn_slow: cfg.burn_slow,
            anomaly_sigma: cfg.anomaly_sigma,
            misses: std::array::from_fn(|_| VecDeque::new()),
            latched: [false; 3],
            absorbed: [(0, 0); 3],
            shards: BTreeMap::new(),
            alerts_raised: 0,
        }
    }

    /// Record one deadlined completion (sims call this per request).
    pub fn record_completion(&mut self, class: QosClass, missed: bool) {
        let w = &mut self.misses[class.index()];
        if w.len() == self.slow_window {
            w.pop_front();
        }
        w.push_back(missed);
    }

    /// Absorb cumulative per-class counters (the serving path reads
    /// lifetime `deadlined`/`missed` totals per batch): the delta since
    /// the last call is replayed as individual completions, misses
    /// last — ordering within one batch is unknown, and trailing the
    /// misses keeps the fast window maximally sensitive.
    pub fn absorb_cumulative(&mut self, class: QosClass, deadlined: u64, missed: u64) {
        let i = class.index();
        let (seen_d, seen_m) = self.absorbed[i];
        let new_d = deadlined.saturating_sub(seen_d);
        let new_m = missed.saturating_sub(seen_m).min(new_d);
        for _ in 0..new_d - new_m {
            self.record_completion(class, false);
        }
        for _ in 0..new_m {
            self.record_completion(class, true);
        }
        self.absorbed[i] = (deadlined, missed);
    }

    /// Feed one shard utilization sample (busy fraction).
    pub fn sample_util(&mut self, shard: u32, busy: f64) {
        let th = self.anomaly_sigma;
        self.shards.entry(shard).or_default().util.sample(busy, th);
    }

    /// Feed one shard power sample (watts).
    pub fn sample_power(&mut self, shard: u32, watts: f64) {
        let th = self.anomaly_sigma;
        self.shards.entry(shard).or_default().power.sample(watts, th);
    }

    /// Burn rates (fast, slow) for a class right now.
    pub fn burn_rates(&self, class: QosClass) -> (f64, f64) {
        let w = &self.misses[class.index()];
        let rate = |window: usize| -> f64 {
            let n = w.len().min(window);
            if n == 0 {
                return 0.0;
            }
            let missed = w.iter().rev().take(n).filter(|&&m| m).count();
            (missed as f64 / n as f64) / self.budget
        };
        (rate(self.fast_window), rate(self.slow_window))
    }

    /// Evaluate every condition and return the alerts that newly fired
    /// (latched: a sustained violation alerts once per excursion).
    pub fn poll(&mut self, at: u64) -> Vec<Alert> {
        let mut alerts = Vec::new();
        for class in QosClass::ALL {
            let i = class.index();
            // the fast window must be full before it can testify —
            // a single early miss is not a 1.0 miss rate
            if self.misses[i].len() < self.fast_window {
                continue;
            }
            let (fast, slow) = self.burn_rates(class);
            let firing = fast >= self.burn_fast && slow >= self.burn_slow;
            if firing && !self.latched[i] {
                self.latched[i] = true;
                alerts.push(Alert { at, shard: 0, kind: AlertKind::SloBurn { class, fast, slow } });
            } else if !firing {
                self.latched[i] = false;
            }
        }
        for (&shard, st) in self.shards.iter_mut() {
            if let Some((value, mean, sigma)) = st.util.pending.take() {
                alerts.push(Alert { at, shard, kind: AlertKind::UtilAnomaly { value, mean, sigma } });
            }
            if let Some((value, mean, sigma)) = st.power.pending.take() {
                alerts
                    .push(Alert { at, shard, kind: AlertKind::PowerAnomaly { value, mean, sigma } });
            }
        }
        self.alerts_raised += alerts.len() as u64;
        alerts
    }

    /// Total alerts raised over this watchdog's lifetime.
    pub fn alerts_raised(&self) -> u64 {
        self.alerts_raised
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ObsConfig {
        ObsConfig {
            enabled: true,
            watchdog: true,
            slo_fast_window: 4,
            slo_slow_window: 16,
            slo_budget: 0.1,
            burn_fast: 5.0,
            burn_slow: 2.0,
            anomaly_sigma: 3.0,
            ..ObsConfig::default()
        }
    }

    #[test]
    fn burn_alert_needs_both_windows_and_latches() {
        let mut w = Watchdog::new(&cfg());
        // 12 met completions: slow window healthy
        for _ in 0..12 {
            w.record_completion(QosClass::Critical, false);
        }
        assert!(w.poll(100).is_empty());
        // 2 misses: fast window burns (2/4 = 0.5 → 5.0×budget) but the
        // slow window (2/14) is only ~1.43×budget — no alert yet
        w.record_completion(QosClass::Critical, true);
        w.record_completion(QosClass::Critical, true);
        assert!(w.poll(200).is_empty(), "slow window must also burn");
        // sustained misses push the slow window over 2× budget
        for _ in 0..4 {
            w.record_completion(QosClass::Critical, true);
        }
        let alerts = w.poll(300);
        assert_eq!(alerts.len(), 1);
        match &alerts[0].kind {
            AlertKind::SloBurn { class, fast, slow } => {
                assert_eq!(*class, QosClass::Critical);
                assert!(*fast >= 5.0 && *slow >= 2.0, "fast={fast} slow={slow}");
            }
            k => panic!("wrong kind {k:?}"),
        }
        // latched: still burning, no second alert
        w.record_completion(QosClass::Critical, true);
        assert!(w.poll(400).is_empty());
        // recovery unlatches; a fresh excursion fires again
        for _ in 0..16 {
            w.record_completion(QosClass::Critical, false);
        }
        assert!(w.poll(500).is_empty());
        for _ in 0..6 {
            w.record_completion(QosClass::Critical, true);
        }
        assert_eq!(w.poll(600).len(), 1);
        assert_eq!(w.alerts_raised(), 2);
    }

    #[test]
    fn cumulative_absorption_matches_per_completion_feed() {
        let mut a = Watchdog::new(&cfg());
        let mut b = Watchdog::new(&cfg());
        for _ in 0..10 {
            a.record_completion(QosClass::Interactive, false);
        }
        for _ in 0..5 {
            a.record_completion(QosClass::Interactive, true);
        }
        b.absorb_cumulative(QosClass::Interactive, 10, 0);
        b.absorb_cumulative(QosClass::Interactive, 15, 5);
        assert_eq!(
            a.burn_rates(QosClass::Interactive),
            b.burn_rates(QosClass::Interactive)
        );
        // counters are cumulative: replaying the same totals is a no-op
        b.absorb_cumulative(QosClass::Interactive, 15, 5);
        assert_eq!(
            a.burn_rates(QosClass::Interactive),
            b.burn_rates(QosClass::Interactive)
        );
    }

    #[test]
    fn anomaly_fires_on_outlier_and_latches_per_excursion() {
        let mut w = Watchdog::new(&cfg());
        for _ in 0..32 {
            w.sample_util(1, 0.50);
            w.sample_util(1, 0.52);
        }
        assert!(w.poll(10).is_empty(), "steady stream never alerts");
        w.sample_util(1, 0.95);
        let alerts = w.poll(20);
        assert_eq!(alerts.len(), 1);
        assert_eq!(alerts[0].shard, 1);
        assert_eq!(alerts[0].kind.name(), "util-anomaly");
        // still excursed: latched
        w.sample_util(1, 0.96);
        assert!(w.poll(30).is_empty());
    }

    #[test]
    fn power_anomaly_is_typed_separately() {
        let mut w = Watchdog::new(&cfg());
        for i in 0..32 {
            w.sample_power(0, 10.0 + (i % 2) as f64 * 0.2);
        }
        w.sample_power(0, 40.0);
        let alerts = w.poll(50);
        assert_eq!(alerts.len(), 1);
        assert_eq!(alerts[0].kind.name(), "power-anomaly");
        assert!(alerts[0].to_string().starts_with("alert at=50 shard=0 power-anomaly"));
    }
}
