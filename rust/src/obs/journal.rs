//! Request-scoped lifecycle journal.
//!
//! The journal records cycle-stamped stage transitions keyed by request
//! id (`seq` on the wire and in the cloud sims, the task-graph request
//! id inside the scheduler): submitted → admitted → queued → placed →
//! reconfiguring → executing → preempted/migrated → completed.  Sim
//! drivers feed it by expanding each [`SimEvent`]; the serving path
//! feeds it directly from the leader loop.  Storage is a bounded ring
//! (oldest events drop first, like [`crate::sim::Trace`]) and the
//! whole journal folds to an FNV-1a digest so determinism is checkable
//! with one `u64` comparison across runs.

use std::collections::BTreeMap;
use std::collections::VecDeque;
use std::fmt;

use super::event::SimEvent;

/// Request id used for fabric-level events (frames, defrag) that do
/// not belong to a single request.
pub const NO_REQ: u64 = u64::MAX;

/// A lifecycle stage transition or fabric-level instant.
#[derive(Clone, Debug, PartialEq)]
pub enum JournalKind {
    /// Request arrived from the workload / wire.
    Submitted { tenant: u32, app: String },
    /// Admission accepted the request (serving path).
    Admitted,
    /// Request entered the scheduler queue.
    Queued,
    /// Admission rejected the request (queue full / power cap).
    Rejected,
    /// Scheduler bound a task instance to a region.
    Placed { task: String, region: u64 },
    /// DPR engine loading the bitstream onto the region.
    Reconfiguring { region: u64, cycles: u64, cache_hit: bool },
    /// Task body executing on the region.
    Executing { region: u64, cycles: u64 },
    /// QoS engine checkpointed and evicted the task.
    Preempted { region: u64, remaining: u64, ckpt: u64 },
    /// A checkpointed task was relaunched.
    Resumed { region: u64 },
    /// Request finished.
    Completed { tenant: u32 },
    /// Edge frame tick (fabric-level).
    FrameStart { k: u32 },
    /// Edge frame fully completed (fabric-level).
    FrameDone { k: u32, total: u64, reconfig: u64 },
    /// Edge frame rejected at admission (fabric-level).
    FrameRejected { k: u32 },
    /// Defragmentation pass (fabric-level instant).
    Defrag { migrated: u64, cycles: u64 },
    /// Live migration moved a task between regions.
    Migrated { task: String, from: u64, to: u64, cycles: u64 },
    /// Watchdog alert (fabric-level instant; `what` is the rendered
    /// [`crate::obs::watchdog::AlertKind`]).
    Alert { what: String },
}

impl JournalKind {
    fn discriminant(&self) -> u64 {
        match self {
            JournalKind::Submitted { .. } => 1,
            JournalKind::Admitted => 2,
            JournalKind::Queued => 3,
            JournalKind::Rejected => 4,
            JournalKind::Placed { .. } => 5,
            JournalKind::Reconfiguring { .. } => 6,
            JournalKind::Executing { .. } => 7,
            JournalKind::Preempted { .. } => 8,
            JournalKind::Resumed { .. } => 9,
            JournalKind::Completed { .. } => 10,
            JournalKind::FrameStart { .. } => 11,
            JournalKind::FrameDone { .. } => 12,
            JournalKind::FrameRejected { .. } => 13,
            JournalKind::Defrag { .. } => 14,
            JournalKind::Migrated { .. } => 15,
            JournalKind::Alert { .. } => 16,
        }
    }

    /// Stable stage name (Perfetto slice names, exposition labels).
    pub fn stage_name(&self) -> &'static str {
        match self {
            JournalKind::Submitted { .. } => "submitted",
            JournalKind::Admitted => "admitted",
            JournalKind::Queued => "queued",
            JournalKind::Rejected => "rejected",
            JournalKind::Placed { .. } => "placed",
            JournalKind::Reconfiguring { .. } => "reconfiguring",
            JournalKind::Executing { .. } => "executing",
            JournalKind::Preempted { .. } => "preempted",
            JournalKind::Resumed { .. } => "resumed",
            JournalKind::Completed { .. } => "completed",
            JournalKind::FrameStart { .. } => "frame",
            JournalKind::FrameDone { .. } => "frame-done",
            JournalKind::FrameRejected { .. } => "frame-rejected",
            JournalKind::Defrag { .. } => "defrag",
            JournalKind::Migrated { .. } => "migrated",
            JournalKind::Alert { .. } => "alert",
        }
    }
}

/// One journal entry: cycle stamp, request key, shard, stage payload.
#[derive(Clone, Debug, PartialEq)]
pub struct JournalEvent {
    /// Cycle the transition happened at.
    pub at: u64,
    /// Request id ([`NO_REQ`] for fabric-level events).
    pub req: u64,
    /// Shard the event happened on (0 for single-fabric runs).
    pub shard: u32,
    /// Stage transition payload.
    pub kind: JournalKind,
}

impl fmt::Display for JournalEvent {
    /// Deterministic one-line rendering shared by `EXPLAIN` replies,
    /// `WATCH` event streaming, and the flight recorder.  Grammar:
    /// `at=<cycle> shard=<s> req=<id|-> <stage> [payload fields]`.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "at={} shard={} req=", self.at, self.shard)?;
        if self.req == NO_REQ {
            write!(f, "-")?;
        } else {
            write!(f, "{}", self.req)?;
        }
        write!(f, " {}", self.kind.stage_name())?;
        match &self.kind {
            JournalKind::Submitted { tenant, app } => write!(f, " tenant={tenant} app={app}"),
            JournalKind::Admitted | JournalKind::Queued | JournalKind::Rejected => Ok(()),
            JournalKind::Placed { task, region } => write!(f, " task={task} region={region}"),
            JournalKind::Reconfiguring { region, cycles, cache_hit } => {
                write!(f, " region={region} cycles={cycles} cache_hit={cache_hit}")
            }
            JournalKind::Executing { region, cycles } => {
                write!(f, " region={region} cycles={cycles}")
            }
            JournalKind::Preempted { region, remaining, ckpt } => {
                write!(f, " region={region} remaining={remaining} ckpt={ckpt}")
            }
            JournalKind::Resumed { region } => write!(f, " region={region}"),
            JournalKind::Completed { tenant } => write!(f, " tenant={tenant}"),
            JournalKind::FrameStart { k } | JournalKind::FrameRejected { k } => {
                write!(f, " k={k}")
            }
            JournalKind::FrameDone { k, total, reconfig } => {
                write!(f, " k={k} total={total} reconfig={reconfig}")
            }
            JournalKind::Defrag { migrated, cycles } => {
                write!(f, " migrated={migrated} cycles={cycles}")
            }
            JournalKind::Migrated { task, from, to, cycles } => {
                write!(f, " task={task} from={from} to={to} cycles={cycles}")
            }
            JournalKind::Alert { what } => write!(f, " {what}"),
        }
    }
}

/// FNV-1a 64 running hash.
struct Fnv(u64);

impl Fnv {
    fn new() -> Fnv {
        Fnv(0xcbf2_9ce4_8422_2325)
    }
    fn u64(&mut self, v: u64) {
        for b in v.to_le_bytes() {
            self.0 = (self.0 ^ b as u64).wrapping_mul(0x100_0000_01b3);
        }
    }
    fn bytes(&mut self, s: &[u8]) {
        self.u64(s.len() as u64);
        for &b in s {
            self.0 = (self.0 ^ b as u64).wrapping_mul(0x100_0000_01b3);
        }
    }
}

/// Per-request lifecycle summary with per-stage durations (cycles).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ReqSummary {
    /// Owning tenant (from the Submitted/Completed events).
    pub tenant: u32,
    /// Application name, when known.
    pub app: Option<String>,
    /// Cycle the request was submitted.
    pub submitted_at: u64,
    /// Cycle the request completed (None if still in flight/rejected).
    pub completed_at: Option<u64>,
    /// Submitted → first reconfig/execute start (admission + queueing).
    pub queued_cycles: u64,
    /// Total cycles spent in DPR reconfiguration.
    pub reconfig_cycles: u64,
    /// Total cycles of execution time scheduled.
    pub exec_cycles: u64,
    /// Times the request was preempted.
    pub preemptions: u32,
    /// Times the request was live-migrated.
    pub migrations: u32,
    /// Whether admission rejected the request.
    pub rejected: bool,
}

impl ReqSummary {
    /// End-to-end turnaround in cycles, when the request completed.
    pub fn turnaround(&self) -> Option<u64> {
        self.completed_at.map(|c| c.saturating_sub(self.submitted_at))
    }
}

/// Bounded, digestable event journal.
#[derive(Clone, Debug)]
pub struct Journal {
    events: VecDeque<JournalEvent>,
    cap: usize,
    dropped: u64,
}

impl Journal {
    /// Journal retaining up to `cap` events (0 disables recording).
    pub fn new(cap: usize) -> Journal {
        Journal { events: VecDeque::new(), cap, dropped: 0 }
    }

    /// Journal that records nothing.
    pub fn disabled() -> Journal {
        Journal::new(0)
    }

    /// Whether events are being retained.
    #[inline]
    pub fn enabled(&self) -> bool {
        self.cap > 0
    }

    /// Append one event (oldest drops first past capacity).
    pub fn push(&mut self, ev: JournalEvent) {
        if self.cap == 0 {
            return;
        }
        if self.events.len() == self.cap {
            self.events.pop_front();
            self.dropped += 1;
        }
        self.events.push_back(ev);
    }

    /// Record a stage transition.
    pub fn stage(&mut self, at: u64, req: u64, shard: u32, kind: JournalKind) {
        self.push(JournalEvent { at, req, shard, kind });
    }

    /// Expand a structured sim event into its lifecycle stages.
    pub fn observe_sim(&mut self, at: u64, shard: u32, ev: &SimEvent) {
        if self.cap == 0 {
            return;
        }
        match ev {
            SimEvent::Arrive { seq, tenant, app, .. } => {
                self.stage(
                    at,
                    *seq,
                    shard,
                    JournalKind::Submitted { tenant: *tenant, app: (*app).to_string() },
                );
                self.stage(at, *seq, shard, JournalKind::Queued);
            }
            SimEvent::ArriveFrame { seq, tenant, app, .. } => {
                self.stage(
                    at,
                    *seq,
                    shard,
                    JournalKind::Submitted { tenant: *tenant, app: (*app).to_string() },
                );
                self.stage(at, *seq, shard, JournalKind::Queued);
            }
            SimEvent::Busy { seq, .. } | SimEvent::BusyFrame { seq, .. } => {
                self.stage(at, *seq, shard, JournalKind::Rejected);
            }
            SimEvent::Done { seq, tenant } => {
                self.stage(at, *seq, shard, JournalKind::Completed { tenant: *tenant });
            }
            SimEvent::Frame { k } => {
                self.stage(at, NO_REQ, shard, JournalKind::FrameStart { k: *k });
            }
            SimEvent::FrameDone { k, total, reconfig } => {
                self.stage(
                    at,
                    NO_REQ,
                    shard,
                    JournalKind::FrameDone { k: *k, total: *total, reconfig: *reconfig },
                );
            }
            SimEvent::FrameRejected { k } => {
                self.stage(at, NO_REQ, shard, JournalKind::FrameRejected { k: *k });
            }
            SimEvent::Launch { launch, .. } => {
                let req = launch.instance.request;
                let region = launch.region.0;
                if launch.resumed {
                    self.stage(at, req, shard, JournalKind::Resumed { region });
                } else {
                    self.stage(
                        at,
                        req,
                        shard,
                        JournalKind::Placed { task: launch.task.0.clone(), region },
                    );
                }
                if launch.dpr_cycles > 0 {
                    self.stage(
                        launch.start,
                        req,
                        shard,
                        JournalKind::Reconfiguring {
                            region,
                            cycles: launch.dpr_cycles,
                            cache_hit: launch.cache_hit,
                        },
                    );
                }
                self.stage(
                    launch.start + launch.dpr_cycles,
                    req,
                    shard,
                    JournalKind::Executing { region, cycles: launch.exec_cycles },
                );
            }
            SimEvent::Preempt { rec, .. } => {
                self.stage(
                    at,
                    rec.victim.request,
                    shard,
                    JournalKind::Preempted {
                        region: rec.victim_region.0,
                        remaining: rec.remaining_cycles,
                        ckpt: rec.checkpoint_cycles,
                    },
                );
            }
        }
    }

    /// Retained events, oldest first.
    pub fn events(&self) -> impl Iterator<Item = &JournalEvent> {
        self.events.iter()
    }

    /// Retained events for one request id, oldest first (`EXPLAIN`).
    pub fn events_for(&self, req: u64) -> impl Iterator<Item = &JournalEvent> {
        self.events.iter().filter(move |e| e.req == req)
    }

    /// Number of retained events.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// Whether the journal holds no events.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Events dropped past capacity.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// FNV-1a digest over every retained event (and the dropped
    /// count), canonical across runs: two identical deterministic runs
    /// must produce equal digests.
    pub fn digest(&self) -> u64 {
        let mut h = Fnv::new();
        h.u64(self.dropped);
        for ev in &self.events {
            h.u64(ev.at);
            h.u64(ev.req);
            h.u64(ev.shard as u64);
            h.u64(ev.kind.discriminant());
            match &ev.kind {
                JournalKind::Submitted { tenant, app } => {
                    h.u64(*tenant as u64);
                    h.bytes(app.as_bytes());
                }
                JournalKind::Admitted | JournalKind::Queued | JournalKind::Rejected => {}
                JournalKind::Placed { task, region } => {
                    h.bytes(task.as_bytes());
                    h.u64(*region);
                }
                JournalKind::Reconfiguring { region, cycles, cache_hit } => {
                    h.u64(*region);
                    h.u64(*cycles);
                    h.u64(*cache_hit as u64);
                }
                JournalKind::Executing { region, cycles } => {
                    h.u64(*region);
                    h.u64(*cycles);
                }
                JournalKind::Preempted { region, remaining, ckpt } => {
                    h.u64(*region);
                    h.u64(*remaining);
                    h.u64(*ckpt);
                }
                JournalKind::Resumed { region } => h.u64(*region),
                JournalKind::Completed { tenant } => h.u64(*tenant as u64),
                JournalKind::FrameStart { k } | JournalKind::FrameRejected { k } => {
                    h.u64(*k as u64)
                }
                JournalKind::FrameDone { k, total, reconfig } => {
                    h.u64(*k as u64);
                    h.u64(*total);
                    h.u64(*reconfig);
                }
                JournalKind::Defrag { migrated, cycles } => {
                    h.u64(*migrated);
                    h.u64(*cycles);
                }
                JournalKind::Migrated { task, from, to, cycles } => {
                    h.bytes(task.as_bytes());
                    h.u64(*from);
                    h.u64(*to);
                    h.u64(*cycles);
                }
                JournalKind::Alert { what } => h.bytes(what.as_bytes()),
            }
        }
        h.0
    }

    /// Fold the journal into per-request lifecycle summaries.
    pub fn summaries(&self) -> BTreeMap<u64, ReqSummary> {
        let mut out: BTreeMap<u64, ReqSummary> = BTreeMap::new();
        for ev in &self.events {
            if ev.req == NO_REQ {
                continue;
            }
            let s = out.entry(ev.req).or_default();
            match &ev.kind {
                JournalKind::Submitted { tenant, app } => {
                    s.tenant = *tenant;
                    s.app = Some(app.clone());
                    s.submitted_at = ev.at;
                }
                JournalKind::Admitted | JournalKind::Queued => {}
                JournalKind::Rejected => s.rejected = true,
                JournalKind::Placed { .. } | JournalKind::Resumed { .. } => {}
                JournalKind::Reconfiguring { cycles, .. } => {
                    if s.reconfig_cycles == 0 && s.exec_cycles == 0 {
                        s.queued_cycles = ev.at.saturating_sub(s.submitted_at);
                    }
                    s.reconfig_cycles += cycles;
                }
                JournalKind::Executing { cycles, .. } => {
                    if s.reconfig_cycles == 0 && s.exec_cycles == 0 {
                        s.queued_cycles = ev.at.saturating_sub(s.submitted_at);
                    }
                    s.exec_cycles += cycles;
                }
                JournalKind::Preempted { .. } => s.preemptions += 1,
                JournalKind::Completed { tenant } => {
                    s.tenant = *tenant;
                    s.completed_at = Some(ev.at);
                }
                JournalKind::Migrated { .. } => s.migrations += 1,
                JournalKind::FrameStart { .. }
                | JournalKind::FrameDone { .. }
                | JournalKind::FrameRejected { .. }
                | JournalKind::Defrag { .. }
                | JournalKind::Alert { .. } => {}
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Journal {
        let mut j = Journal::new(1024);
        j.stage(10, 1, 0, JournalKind::Submitted { tenant: 2, app: "Harris".into() });
        j.stage(10, 1, 0, JournalKind::Queued);
        j.stage(50, 1, 0, JournalKind::Placed { task: "harris".into(), region: 3 });
        j.stage(50, 1, 0, JournalKind::Reconfiguring { region: 3, cycles: 40, cache_hit: false });
        j.stage(90, 1, 0, JournalKind::Executing { region: 3, cycles: 200 });
        j.stage(290, 1, 0, JournalKind::Completed { tenant: 2 });
        j
    }

    #[test]
    fn digest_is_stable_and_sensitive() {
        let a = sample();
        let b = sample();
        assert_eq!(a.digest(), b.digest(), "identical journals must digest equal");
        let mut c = sample();
        c.stage(300, 2, 0, JournalKind::Rejected);
        assert_ne!(a.digest(), c.digest(), "digest must see new events");
    }

    #[test]
    fn summaries_compute_stage_durations() {
        let s = sample().summaries();
        let r = &s[&1];
        assert_eq!(r.tenant, 2);
        assert_eq!(r.app.as_deref(), Some("Harris"));
        assert_eq!(r.submitted_at, 10);
        assert_eq!(r.queued_cycles, 40, "submitted at 10, reconfig started at 50");
        assert_eq!(r.reconfig_cycles, 40);
        assert_eq!(r.exec_cycles, 200);
        assert_eq!(r.turnaround(), Some(280));
        assert_eq!(r.preemptions, 0);
    }

    #[test]
    fn ring_drops_oldest_and_digest_counts_drops() {
        let mut j = Journal::new(2);
        j.stage(1, 1, 0, JournalKind::Queued);
        j.stage(2, 2, 0, JournalKind::Queued);
        let before = j.digest();
        j.stage(3, 3, 0, JournalKind::Queued);
        assert_eq!(j.len(), 2);
        assert_eq!(j.dropped(), 1);
        assert_ne!(j.digest(), before);
        // disabled journal records nothing
        let mut d = Journal::disabled();
        d.stage(1, 1, 0, JournalKind::Queued);
        assert!(d.is_empty());
        assert!(!d.enabled());
    }

    #[test]
    fn event_lines_are_deterministic() {
        let j = sample();
        let lines: Vec<String> = j.events().map(|e| e.to_string()).collect();
        assert_eq!(lines[0], "at=10 shard=0 req=1 submitted tenant=2 app=Harris");
        assert_eq!(lines[2], "at=50 shard=0 req=1 placed task=harris region=3");
        assert_eq!(lines[3], "at=50 shard=0 req=1 reconfiguring region=3 cycles=40 cache_hit=false");
        let alert = JournalEvent {
            at: 99,
            req: NO_REQ,
            shard: 2,
            kind: JournalKind::Alert { what: "slo-burn class=critical fast=9.00 slow=2.50".into() },
        };
        assert_eq!(
            alert.to_string(),
            "at=99 shard=2 req=- alert slo-burn class=critical fast=9.00 slow=2.50"
        );
        // Alert digests and filters like any fabric-level event.
        let mut a = sample();
        a.push(alert.clone());
        assert_ne!(a.digest(), sample().digest());
        assert_eq!(a.events_for(1).count(), 6);
        assert_eq!(a.events_for(NO_REQ).count(), 1);
    }

    #[test]
    fn observe_sim_expands_launch_lifecycle() {
        use crate::regions::RegionId;
        use crate::scheduler::Launch;
        use crate::tasks::{TaskId, TaskInstanceId, VariantId};
        let mut j = Journal::new(64);
        let launch = Launch {
            instance: TaskInstanceId { request: 7, node: 0 },
            task: TaskId("conv".into()),
            ver: VariantId('a'),
            region: RegionId(2),
            replicas: 1,
            start: 100,
            dpr_cycles: 30,
            exec_cycles: 500,
            finish: 630,
            cache_hit: true,
            resumed: false,
        };
        j.observe_sim(100, 1, &SimEvent::Launch { shard: Some(1), launch });
        let kinds: Vec<&'static str> = j.events().map(|e| e.kind.stage_name()).collect();
        assert_eq!(kinds, vec!["placed", "reconfiguring", "executing"]);
        assert!(j.events().all(|e| e.req == 7 && e.shard == 1));
        let exec = j.events().find(|e| e.kind.stage_name() == "executing").unwrap();
        assert_eq!(exec.at, 130, "execution starts after DPR");
    }
}
