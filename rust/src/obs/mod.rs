//! End-to-end observability: typed metrics, lifecycle journal, exporters.
//!
//! Three pieces, all behind the `[obs]` config section:
//!
//! * [`MetricsRegistry`] — named atomic counters / gauges / log-linear
//!   histograms with Prometheus-style label sets and text exposition
//!   ([`MetricsRegistry::render`]), served over the wire as the
//!   `METRICS` command on both serving fronts.
//! * [`Journal`] — the request-scoped lifecycle journal: cycle-stamped
//!   stage transitions (submitted → admitted → queued → placed →
//!   reconfiguring → executing → preempted/migrated → completed) keyed
//!   by request id, foldable to per-request stage durations
//!   ([`Journal::summaries`]) and an FNV-1a determinism digest
//!   ([`Journal::digest`]).
//! * [`perfetto`] — a Chrome `trace_event` JSON exporter rendering the
//!   journal as a timeline (one track per shard region, slices per
//!   task stage, instants for DPR/defrag/preemption) loadable in
//!   `ui.perfetto.dev`.
//!
//! **Determinism contract:** with `[obs] enabled = false` (the
//! default) every code path is byte-identical to a build without this
//! module — the sim drivers pass [`Obs::disabled`] and never construct
//! an event unless the human-readable trace wants it too.  With obs
//! enabled, recording is deterministic: two runs of the same config
//! produce equal journal digests and equal Perfetto documents.

pub mod event;
pub mod flight;
pub mod journal;
pub mod perfetto;
pub mod provenance;
pub mod registry;
pub mod watch;
pub mod watchdog;

pub use event::SimEvent;
pub use flight::{flight_record, validate_flight_record, FlightSummary, FLIGHT_TAIL};
pub use journal::{Journal, JournalEvent, JournalKind, ReqSummary, NO_REQ};
pub use provenance::{
    AltVerdict, Decision, DecisionKind, ProvenanceRing, ShardScore, VariantAlt, VictimRank,
};
pub use registry::{Counter, Gauge, HistSnapshot, Histogram, MetricsRegistry};
pub use watch::WatchHub;
pub use watchdog::{Alert, AlertKind, Watchdog};

use crate::config::{Config, ObsConfig};
use crate::sim::Trace;

/// Observability context threaded through the sim drivers and serving
/// leaders: a journal plus a shared metrics registry (and, when the
/// `[obs]` knobs ask for them, the decision-provenance ring and the
/// burn-rate watchdog), with a master switch so disabled observability
/// costs one branch per event site.
#[derive(Clone, Debug)]
pub struct Obs {
    on: bool,
    /// Lifecycle journal (empty and non-recording when disabled).
    pub journal: Journal,
    /// Shared metrics registry.
    pub registry: MetricsRegistry,
    /// Decision-provenance ring (`[obs] provenance = true`).
    pub provenance: Option<ProvenanceRing>,
    /// SLO burn-rate watchdog (`[obs] watchdog = true`).
    pub watchdog: Option<Watchdog>,
}

impl Obs {
    /// Observability off: records nothing, exports nothing.
    pub fn disabled() -> Obs {
        Obs {
            on: false,
            journal: Journal::disabled(),
            registry: MetricsRegistry::new(),
            provenance: None,
            watchdog: None,
        }
    }

    /// Observability on with a journal capacity (no provenance ring or
    /// watchdog — the PR 9 baseline the overhead bench measures).
    pub fn enabled(journal_cap: usize) -> Obs {
        Obs {
            on: true,
            journal: Journal::new(journal_cap),
            registry: MetricsRegistry::new(),
            provenance: None,
            watchdog: None,
        }
    }

    /// Build from the `[obs]` knob set.
    pub fn from_obs_config(ocfg: &ObsConfig) -> Obs {
        if !ocfg.enabled {
            return Obs::disabled();
        }
        let mut obs = Obs::enabled(ocfg.journal_cap);
        obs.registry.build_info();
        if ocfg.provenance {
            obs.provenance = Some(ProvenanceRing::new(ocfg.provenance_cap));
        }
        if ocfg.watchdog {
            obs.watchdog = Some(Watchdog::new(ocfg));
        }
        obs
    }

    /// Build from the `[obs]` config section.
    pub fn from_config(cfg: &Config) -> Obs {
        Obs::from_obs_config(&cfg.obs)
    }

    /// Whether observability is recording.
    #[inline]
    pub fn on(&self) -> bool {
        self.on
    }

    /// Whether decision provenance is recording.
    #[inline]
    pub fn provenance_on(&self) -> bool {
        self.provenance.is_some()
    }

    /// Journal a structured sim event (no-op when disabled).
    #[inline]
    pub fn observe(&mut self, at: u64, shard: u32, ev: &SimEvent) {
        if self.on {
            self.journal.observe_sim(at, shard, ev);
        }
    }

    /// Record one provenance decision (no-op without the ring).
    #[inline]
    pub fn record_decision(&mut self, d: Decision) {
        if let Some(ring) = &mut self.provenance {
            ring.push(d);
        }
    }

    /// Journal a watchdog alert and count it in the registry.
    pub fn raise_alert(&mut self, alert: &Alert) {
        self.journal.stage(
            alert.at,
            NO_REQ,
            alert.shard,
            JournalKind::Alert { what: alert.kind.to_string() },
        );
        self.registry.counter("cgra_obs_alerts_total", &[("kind", alert.kind.name())]).inc();
    }
}

/// Emit one structured event to both the human-readable trace and the
/// journal, constructing it only if at least one consumer is active —
/// the disabled-everything path pays a single branch, preserving the
/// old `log_with` laziness guarantee.
#[inline]
pub fn note<F>(trace: &mut Trace, obs: &mut Obs, at: u64, shard: u32, make: F)
where
    F: FnOnce() -> SimEvent,
{
    if trace.enabled() || obs.on() {
        let ev = make();
        obs.observe(at, shard, &ev);
        trace.emit(at, ev);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_obs_records_nothing() {
        let mut obs = Obs::disabled();
        assert!(!obs.on());
        obs.observe(5, 0, &SimEvent::Frame { k: 1 });
        assert!(obs.journal.is_empty());
    }

    #[test]
    fn note_is_lazy_when_both_consumers_are_off() {
        let mut trace = Trace::disabled();
        let mut obs = Obs::disabled();
        let mut calls = 0u32;
        note(&mut trace, &mut obs, 1, 0, || {
            calls += 1;
            SimEvent::Frame { k: 0 }
        });
        assert_eq!(calls, 0, "event must not be constructed");

        let mut obs = Obs::enabled(16);
        note(&mut trace, &mut obs, 1, 0, || {
            calls += 1;
            SimEvent::Frame { k: 0 }
        });
        assert_eq!(calls, 1, "journal-only consumer still sees events");
        assert_eq!(obs.journal.len(), 1);
    }
}
