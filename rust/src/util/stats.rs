//! Summary statistics and histograms for metrics and the bench harness.

/// Streaming summary of a sequence of f64 samples.
///
/// Keeps all samples (experiments here are at most a few hundred thousand
/// points) so exact percentiles are available; also maintains running sum
/// for O(1) mean.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    samples: Vec<f64>,
    sum: f64,
    sorted: bool,
}

impl Summary {
    /// Empty summary.
    pub fn new() -> Self {
        Self::default()
    }

    /// Build from an iterator.
    pub fn from_iter(values: impl IntoIterator<Item = f64>) -> Self {
        let mut s = Self::new();
        for v in values {
            s.add(v);
        }
        s
    }

    /// Record one sample.
    pub fn add(&mut self, v: f64) {
        debug_assert!(v.is_finite(), "non-finite sample {v}");
        self.samples.push(v);
        self.sum += v;
        self.sorted = false;
    }

    /// Number of samples.
    pub fn count(&self) -> usize {
        self.samples.len()
    }

    /// Whether no samples have been recorded.
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Arithmetic mean (0 for an empty summary).
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            0.0
        } else {
            self.sum / self.samples.len() as f64
        }
    }

    /// Sum of all samples.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Population standard deviation.
    pub fn stddev(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var = self
            .samples
            .iter()
            .map(|v| (v - m) * (v - m))
            .sum::<f64>()
            / self.samples.len() as f64;
        var.sqrt()
    }

    fn sorted_samples(&mut self) -> &[f64] {
        if !self.sorted {
            self.samples
                .sort_by(|a, b| a.partial_cmp(b).expect("finite samples"));
            self.sorted = true;
        }
        &self.samples
    }

    /// Exact percentile in `[0, 100]` by linear interpolation.
    pub fn percentile(&mut self, p: f64) -> f64 {
        assert!((0.0..=100.0).contains(&p), "percentile {p}");
        let s = self.sorted_samples();
        if s.is_empty() {
            return 0.0;
        }
        if s.len() == 1 {
            return s[0];
        }
        let rank = p / 100.0 * (s.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        s[lo] + (s[hi] - s[lo]) * frac
    }

    /// Median (p50).
    pub fn median(&mut self) -> f64 {
        self.percentile(50.0)
    }

    /// Minimum sample.
    pub fn min(&mut self) -> f64 {
        self.sorted_samples().first().copied().unwrap_or(0.0)
    }

    /// Maximum sample.
    pub fn max(&mut self) -> f64 {
        self.sorted_samples().last().copied().unwrap_or(0.0)
    }

    /// All samples (insertion order not preserved after percentile calls).
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }
}

/// Fixed-width histogram over `[lo, hi)` with overflow/underflow buckets.
#[derive(Clone, Debug)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    buckets: Vec<u64>,
    underflow: u64,
    overflow: u64,
    count: u64,
}

impl Histogram {
    /// `nbuckets` equal-width buckets spanning `[lo, hi)`.
    pub fn new(lo: f64, hi: f64, nbuckets: usize) -> Self {
        assert!(hi > lo && nbuckets > 0);
        Histogram { lo, hi, buckets: vec![0; nbuckets], underflow: 0, overflow: 0, count: 0 }
    }

    /// Record one value.
    pub fn add(&mut self, v: f64) {
        self.count += 1;
        if v < self.lo {
            self.underflow += 1;
        } else if v >= self.hi {
            self.overflow += 1;
        } else {
            let idx = ((v - self.lo) / (self.hi - self.lo) * self.buckets.len() as f64) as usize;
            let idx = idx.min(self.buckets.len() - 1);
            self.buckets[idx] += 1;
        }
    }

    /// Total recorded values.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Bucket counts (excluding under/overflow).
    pub fn buckets(&self) -> &[u64] {
        &self.buckets
    }

    /// Values below `lo` / at-or-above `hi`.
    pub fn outliers(&self) -> (u64, u64) {
        (self.underflow, self.overflow)
    }

    /// Render a compact ASCII sparkline (for trace dumps / bench output).
    pub fn sparkline(&self) -> String {
        const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let max = self.buckets.iter().copied().max().unwrap_or(0);
        if max == 0 {
            return "▁".repeat(self.buckets.len());
        }
        self.buckets
            .iter()
            .map(|&c| GLYPHS[(c * (GLYPHS.len() as u64 - 1) / max) as usize])
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_mean_and_stddev() {
        let s = Summary::from_iter([2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.stddev() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn summary_percentiles() {
        let mut s = Summary::from_iter((1..=100).map(|v| v as f64));
        assert!((s.median() - 50.5).abs() < 1e-9);
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-12);
        assert!((s.percentile(100.0) - 100.0).abs() < 1e-12);
        assert!((s.percentile(99.0) - 99.01).abs() < 1e-9);
    }

    #[test]
    fn summary_empty_is_safe() {
        let mut s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.median(), 0.0);
        assert_eq!(s.stddev(), 0.0);
        assert!(s.is_empty());
    }

    #[test]
    fn summary_single_sample() {
        let mut s = Summary::from_iter([3.5]);
        assert_eq!(s.mean(), 3.5);
        assert_eq!(s.median(), 3.5);
        assert_eq!(s.min(), 3.5);
        assert_eq!(s.max(), 3.5);
    }

    #[test]
    fn histogram_bucketing() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.add(i as f64 + 0.5);
        }
        assert!(h.buckets().iter().all(|&c| c == 1));
        h.add(-1.0);
        h.add(99.0);
        assert_eq!(h.outliers(), (1, 1));
        assert_eq!(h.count(), 12);
    }

    #[test]
    fn histogram_sparkline_shape() {
        let mut h = Histogram::new(0.0, 4.0, 4);
        for _ in 0..8 {
            h.add(0.5);
        }
        h.add(2.5);
        let line = h.sparkline();
        assert_eq!(line.chars().count(), 4);
        assert!(line.starts_with('█'));
    }
}
