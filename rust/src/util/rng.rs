//! Deterministic PRNG and distribution samplers.
//!
//! `rand` is not available offline, so this is a self-contained
//! xoshiro256** generator (Blackman & Vigna) seeded through splitmix64,
//! plus the samplers the workload generators need: uniform, Bernoulli,
//! exponential inter-arrival times (Poisson processes) and Poisson counts.
//!
//! Determinism is a requirement, not a convenience: every experiment in
//! EXPERIMENTS.md records its seed, and the paper-shape comparisons
//! (Fig. 4, Fig. 5) must be reproducible run-to-run.

/// splitmix64 — used to expand a 64-bit seed into xoshiro state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for per-tenant / per-component RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in `[0, 1)` (53-bit precision).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)` via Lemire's rejection-free-ish method.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        // widening multiply; bias is negligible for our n << 2^64 but we
        // still reject to keep the distribution exact.
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= n.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn range_inclusive(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi, "range_inclusive({lo}, {hi})");
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Exponential variate with rate `lambda` (mean `1/lambda`) — the
    /// inter-arrival time of a Poisson process, used by the cloud tenants.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0, "exponential rate must be positive");
        // inverse CDF; guard u > 0 so ln() is finite.
        let u = loop {
            let u = self.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / lambda
    }

    /// Poisson count with mean `lambda` (Knuth for small, normal approx for
    /// large means — workload generators only need small means).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        assert!(lambda >= 0.0);
        if lambda == 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.next_f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            // normal approximation with continuity correction
            let z = self.gaussian();
            let v = lambda + lambda.sqrt() * z + 0.5;
            if v < 0.0 {
                0
            } else {
                v as u64
            }
        }
    }

    /// Standard normal via Box–Muller.
    pub fn gaussian(&mut self) -> f64 {
        let u1 = loop {
            let u = self.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        assert!(!items.is_empty(), "choose from empty slice");
        &items[self.below(items.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn forked_streams_independent() {
        let mut root = Rng::new(7);
        let mut s1 = root.fork(1);
        let mut s2 = root.fork(2);
        let same = (0..64).filter(|_| s1.next_u64() == s2.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(4);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            seen[r.below(7) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn range_inclusive_hits_both_ends() {
        let mut r = Rng::new(5);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..2_000 {
            match r.range_inclusive(3, 7) {
                3 => lo_seen = true,
                7 => hi_seen = true,
                v => assert!((3..=7).contains(&v)),
            }
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn exponential_mean_close() {
        let mut r = Rng::new(6);
        let lambda = 0.5;
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.exponential(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.1, "mean {mean}");
    }

    #[test]
    fn poisson_mean_close_small_lambda() {
        let mut r = Rng::new(7);
        let lambda = 4.0;
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.poisson(lambda) as f64).sum::<f64>() / n as f64;
        assert!((mean - lambda).abs() < 0.1, "mean {mean}");
    }

    #[test]
    fn poisson_mean_close_large_lambda() {
        let mut r = Rng::new(8);
        let lambda = 100.0;
        let n = 5_000;
        let mean: f64 = (0..n).map(|_| r.poisson(lambda) as f64).sum::<f64>() / n as f64;
        assert!((mean - lambda).abs() < 1.5, "mean {mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let samples: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(10);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn chance_extremes() {
        let mut r = Rng::new(11);
        assert!(!(0..100).any(|_| r.chance(0.0)));
        assert!((0..100).all(|_| r.chance(1.0)));
    }
}
