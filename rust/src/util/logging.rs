//! Tiny `log` facade backend writing to stderr.
//!
//! The coordinator uses the standard `log` macros throughout; binaries
//! call [`init`] once.  `CGRA_MTE_LOG` configures it with an
//! env_logger-style spec: a default level plus per-target overrides,
//! e.g. `info,coordinator=debug` or
//! `warn,cgra_mte::coordinator::reactor=trace`.  A target override
//! matches any record whose target contains the given fragment as a
//! path segment prefix (`coordinator` matches
//! `cgra_mte::coordinator::leader`); the most specific (longest)
//! matching override wins.  Defaults to `info`.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;

use log::{Level, LevelFilter, Metadata, Record};

struct StderrLogger;

static LOGGER: StderrLogger = StderrLogger;
static INITIALIZED: AtomicBool = AtomicBool::new(false);
static SPEC: OnceLock<LogSpec> = OnceLock::new();

/// A parsed `CGRA_MTE_LOG` spec: default level + per-target overrides.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LogSpec {
    /// Level for records no override matches.
    pub default: LevelFilter,
    /// `(target fragment, level)` overrides, as written in the spec.
    pub overrides: Vec<(String, LevelFilter)>,
}

impl Default for LogSpec {
    fn default() -> Self {
        LogSpec { default: LevelFilter::Info, overrides: Vec::new() }
    }
}

impl LogSpec {
    /// Parse `default[,target=level]...`.  Unrecognized pieces are
    /// ignored (logging must never take a process down); a bare
    /// `target=level` list without a leading default keeps `info`.
    pub fn parse(spec: &str) -> LogSpec {
        let mut out = LogSpec::default();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            match part.split_once('=') {
                None => {
                    if let Some(level) = parse_level(part) {
                        out.default = level;
                    }
                }
                Some((target, level)) => {
                    if let Some(level) = parse_level(level) {
                        out.overrides.push((target.trim().to_string(), level));
                    }
                }
            }
        }
        out
    }

    /// Effective level for a record target: the longest matching
    /// override, else the default.  An override matches when the target
    /// equals it, or when it appears as a `::`-delimited segment-prefix
    /// anywhere in the target path.
    pub fn level_for(&self, target: &str) -> LevelFilter {
        let mut best_len = 0usize;
        let mut level = self.default;
        for (frag, l) in &self.overrides {
            // `>=`: among equally specific overrides the last one wins
            if frag.len() >= best_len && target_matches(target, frag) {
                best_len = frag.len();
                level = *l;
            }
        }
        level
    }

    /// Most verbose level any target can reach — what `log::max_level`
    /// must be set to so the facade forwards everything the spec wants.
    pub fn max_level(&self) -> LevelFilter {
        self.overrides.iter().map(|(_, l)| *l).fold(self.default, |a, b| a.max(b))
    }
}

/// Does `frag` match `target` as a path-segment prefix?  `coordinator`
/// matches `cgra_mte::coordinator::reactor` and `coordinator`; it does
/// not match `coordinators` or `my_coordinator`.
fn target_matches(target: &str, frag: &str) -> bool {
    if frag.is_empty() {
        return false;
    }
    // walk every `::` boundary (plus the start) and test a prefix match
    // that ends at the target's end or at another `::`
    let mut starts = vec![0usize];
    let mut idx = 0;
    while let Some(found) = target[idx..].find("::") {
        idx += found + 2;
        starts.push(idx);
    }
    for s in starts {
        let rest = &target[s..];
        if let Some(tail) = rest.strip_prefix(frag) {
            if tail.is_empty() || tail.starts_with("::") {
                return true;
            }
        }
    }
    false
}

fn spec() -> &'static LogSpec {
    SPEC.get_or_init(|| {
        std::env::var("CGRA_MTE_LOG").ok().map(|v| LogSpec::parse(&v)).unwrap_or_default()
    })
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= spec().level_for(metadata.target())
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let tag = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{tag}] {}: {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

/// Parse a level name (case-insensitive); `None` if unrecognized.
pub fn parse_level(name: &str) -> Option<LevelFilter> {
    match name.trim().to_ascii_lowercase().as_str() {
        "off" => Some(LevelFilter::Off),
        "error" => Some(LevelFilter::Error),
        "warn" | "warning" => Some(LevelFilter::Warn),
        "info" => Some(LevelFilter::Info),
        "debug" => Some(LevelFilter::Debug),
        "trace" => Some(LevelFilter::Trace),
        _ => None,
    }
}

/// Install the stderr logger (idempotent).
pub fn init() {
    if INITIALIZED.swap(true, Ordering::SeqCst) {
        return;
    }
    let max = spec().max_level();
    if log::set_logger(&LOGGER).is_ok() {
        log::set_max_level(max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_level_known_names() {
        assert_eq!(parse_level("info"), Some(LevelFilter::Info));
        assert_eq!(parse_level("WARN"), Some(LevelFilter::Warn));
        assert_eq!(parse_level("warning"), Some(LevelFilter::Warn));
        assert_eq!(parse_level("off"), Some(LevelFilter::Off));
        assert_eq!(parse_level("nope"), None);
    }

    #[test]
    fn spec_parses_default_and_overrides() {
        let s = LogSpec::parse("info,coordinator=debug,cgra_mte::coordinator::reactor=trace");
        assert_eq!(s.default, LevelFilter::Info);
        assert_eq!(s.overrides.len(), 2);
        assert_eq!(s.level_for("cgra_mte::scheduler::core"), LevelFilter::Info);
        assert_eq!(s.level_for("cgra_mte::coordinator::leader"), LevelFilter::Debug);
        // longest (most specific) override wins
        assert_eq!(s.level_for("cgra_mte::coordinator::reactor"), LevelFilter::Trace);
        assert_eq!(s.max_level(), LevelFilter::Trace);
    }

    #[test]
    fn spec_matches_segment_prefixes_only() {
        let s = LogSpec::parse("warn,coordinator=debug");
        assert_eq!(s.level_for("coordinator"), LevelFilter::Debug);
        assert_eq!(s.level_for("cgra_mte::coordinator"), LevelFilter::Debug);
        // not a path segment: must not match
        assert_eq!(s.level_for("cgra_mte::coordinators"), LevelFilter::Warn);
        assert_eq!(s.level_for("my_coordinator::x"), LevelFilter::Warn);
    }

    #[test]
    fn spec_tolerates_garbage_and_bare_overrides() {
        let s = LogSpec::parse("bogus,server=warp,reactor=debug,, ");
        // unknown default level and unknown override level are ignored
        assert_eq!(s.default, LevelFilter::Info);
        assert_eq!(s.overrides, vec![("reactor".to_string(), LevelFilter::Debug)]);
        assert_eq!(s.level_for("cgra_mte::coordinator::reactor"), LevelFilter::Debug);
    }

    #[test]
    fn init_is_idempotent() {
        init();
        init(); // second call must not panic
    }
}
