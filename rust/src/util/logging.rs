//! Tiny `log` facade backend writing to stderr.
//!
//! The coordinator uses the standard `log` macros throughout; binaries call
//! [`init`] once.  Level comes from `CGRA_MTE_LOG` (error|warn|info|debug|
//! trace), defaulting to `info`.

use std::sync::atomic::{AtomicBool, Ordering};

use log::{Level, LevelFilter, Metadata, Record};

struct StderrLogger;

static LOGGER: StderrLogger = StderrLogger;
static INITIALIZED: AtomicBool = AtomicBool::new(false);

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let tag = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{tag}] {}: {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

/// Parse a level name (case-insensitive); `None` if unrecognized.
pub fn parse_level(name: &str) -> Option<LevelFilter> {
    match name.to_ascii_lowercase().as_str() {
        "off" => Some(LevelFilter::Off),
        "error" => Some(LevelFilter::Error),
        "warn" | "warning" => Some(LevelFilter::Warn),
        "info" => Some(LevelFilter::Info),
        "debug" => Some(LevelFilter::Debug),
        "trace" => Some(LevelFilter::Trace),
        _ => None,
    }
}

/// Install the stderr logger (idempotent).
pub fn init() {
    if INITIALIZED.swap(true, Ordering::SeqCst) {
        return;
    }
    let level = std::env::var("CGRA_MTE_LOG")
        .ok()
        .and_then(|v| parse_level(&v))
        .unwrap_or(LevelFilter::Info);
    if log::set_logger(&LOGGER).is_ok() {
        log::set_max_level(level);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_level_known_names() {
        assert_eq!(parse_level("info"), Some(LevelFilter::Info));
        assert_eq!(parse_level("WARN"), Some(LevelFilter::Warn));
        assert_eq!(parse_level("warning"), Some(LevelFilter::Warn));
        assert_eq!(parse_level("off"), Some(LevelFilter::Off));
        assert_eq!(parse_level("nope"), None);
    }

    #[test]
    fn init_is_idempotent() {
        init();
        init(); // second call must not panic
    }
}
