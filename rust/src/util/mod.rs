//! Foundation utilities: PRNG, statistics, JSON, logging.
//!
//! The build environment is fully offline with only the `xla` crate's
//! dependency closure vendored, so the usual ecosystem crates (`rand`,
//! `serde_json`, `criterion`, `proptest`) are unavailable.  These modules
//! provide the small, well-tested subset this project needs.

pub mod json;
pub mod logging;
pub mod rng;
pub mod stats;

/// Integer ceiling division.
#[inline]
pub fn div_ceil(a: u64, b: u64) -> u64 {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

/// Round `a` up to the next multiple of `b`.
#[inline]
pub fn round_up(a: u64, b: u64) -> u64 {
    div_ceil(a, b) * b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn div_ceil_exact_and_ragged() {
        assert_eq!(div_ceil(10, 5), 2);
        assert_eq!(div_ceil(11, 5), 3);
        assert_eq!(div_ceil(0, 5), 0);
        assert_eq!(div_ceil(1, 1), 1);
    }

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(7, 4), 8);
        assert_eq!(round_up(8, 4), 8);
        assert_eq!(round_up(0, 4), 0);
    }
}
