//! Minimal JSON parser — reads `artifacts/manifest.json`.
//!
//! `serde_json` is unavailable offline; this is a strict, recursive-descent
//! parser for the JSON actually produced by `python/compile/aot.py`
//! (objects, arrays, strings with escapes, numbers, booleans, null).  It
//! rejects trailing garbage and depth bombs.

use std::collections::BTreeMap;
use std::fmt;

use crate::error::{Error, Result};

/// Parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any JSON number (kept as f64; manifests only carry small ints/floats)
    Num(f64),
    /// String
    Str(String),
    /// Array
    Arr(Vec<Json>),
    /// Object (sorted keys for deterministic display)
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Array elements.
    pub fn items(&self) -> &[Json] {
        match self {
            Json::Arr(v) => v,
            _ => &[],
        }
    }

    /// String payload.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric payload.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric payload as u64 (must be a non-negative integer).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    /// Boolean payload.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Typed field helpers with good error messages.
    pub fn req<'a>(&'a self, key: &str) -> Result<&'a Json> {
        self.get(key)
            .ok_or_else(|| Error::parse(format!("$.{key}"), "missing required field"))
    }

    /// Required string field.
    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| Error::parse(format!("$.{key}"), "expected string"))
    }

    /// Required u64 field.
    pub fn req_u64(&self, key: &str) -> Result<u64> {
        self.req(key)?
            .as_u64()
            .ok_or_else(|| Error::parse(format!("$.{key}"), "expected unsigned integer"))
    }

    /// Required f64 field.
    pub fn req_f64(&self, key: &str) -> Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| Error::parse(format!("$.{key}"), "expected number"))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write!(f, "{:?}", s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{item}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{:?}:{v}", k)?;
                }
                write!(f, "}}")
            }
        }
    }
}

const MAX_DEPTH: usize = 64;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> Error {
        // compute line:col for diagnostics
        let (mut line, mut col) = (1usize, 1usize);
        for &b in &self.bytes[..self.pos.min(self.bytes.len())] {
            if b == b'\n' {
                line += 1;
                col = 1;
            } else {
                col += 1;
            }
        }
        Error::parse(format!("json:{line}:{col}"), msg)
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        if self.depth >= MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(format!("invalid literal, expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        self.depth += 1;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => break,
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
        self.depth -= 1;
        Ok(Json::Obj(map))
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        self.depth += 1;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => break,
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
        self.depth -= 1;
        Ok(Json::Arr(items))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pairs
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                        };
                        out.push(ch);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control character in string")),
                Some(c) => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        if start + len > self.bytes.len() {
                            return Err(self.err("truncated UTF-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..start + len])
                            .map_err(|_| self.err("invalid UTF-8"))?;
                        out.push_str(s);
                        self.pos = start + len;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).expect("ascii");
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(format!("invalid number '{text}'")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse(r#""hi""#).unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let doc = r#"{"a": [1, 2, {"b": null}], "c": {"d": true}}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("a").unwrap().items().len(), 3);
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\nb\t\"q\" A 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\" A 😀");
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse(r#""héllo — ☃""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo — ☃");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"\\x\"").is_err());
    }

    #[test]
    fn rejects_depth_bomb() {
        let bomb = "[".repeat(100) + &"]".repeat(100);
        assert!(Json::parse(&bomb).is_err());
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n": 7, "s": "x", "f": 1.5}"#).unwrap();
        assert_eq!(v.req_u64("n").unwrap(), 7);
        assert_eq!(v.req_str("s").unwrap(), "x");
        assert!((v.req_f64("f").unwrap() - 1.5).abs() < 1e-12);
        assert!(v.req_str("missing").is_err());
        assert!(v.req_u64("s").is_err());
        assert_eq!(Json::parse("-3").unwrap().as_u64(), None);
    }

    #[test]
    fn error_locations_are_line_col() {
        let err = Json::parse("{\n  \"a\": nope\n}").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("json:2"), "{msg}");
    }

    #[test]
    fn display_round_trips_structure() {
        let doc = r#"{"a":[1,2],"b":"x"}"#;
        let v = Json::parse(doc).unwrap();
        let shown = v.to_string();
        let v2 = Json::parse(&shown).unwrap();
        assert_eq!(v, v2);
    }
}
