//! Network-on-chip bandwidth provisioning (the fourth resource axis).
//!
//! The paper's abstraction partitions GLB capacity, GLB bandwidth and
//! compute; the interconnect moving data between them was previously
//! unmodeled, so every policy treated regions as communication-free.
//! This module closes that gap:
//!
//! * [`crate::abstraction::CorridorMap`] tracks per-corridor track
//!   budgets, occupied/released in lockstep with region alloc/free by
//!   [`crate::regions::RegionManager`];
//! * [`ContentionModel`] charges a launching task for shared-corridor
//!   occupancy — an oversubscribed corridor time-multiplexes its
//!   tracks, so effective stream bandwidth drops by the
//!   oversubscription factor, lengthening the communication-bound part
//!   of execution and scaling the energy model's stream duty down by
//!   the same factor (slower streams burn fewer pJ *per cycle* over
//!   more cycles);
//! * [`NocStats`]/[`NocReport`] surface what the model charged, for
//!   `STATS NOC`, [`crate::metrics::export::noc_json`] and the
//!   `ablation_noc` bench.
//!
//! Everything here is gated behind `[noc] enabled` (default **off**):
//! with the switch off no corridor is ever occupied, every slowdown is
//! exactly 1.0 and traces stay byte-identical to the pre-NoC goldens
//! (`tests/prop_noc.rs`).

use crate::abstraction::{CorridorSpan, SliceRange};
use crate::config::{ArchConfig, NocConfig};

/// Derive the corridor span a region's streams occupy.
///
/// Streams enter at the region's GLB banks on the top row and descend
/// through the vertical corridors of the array-slices the region spans;
/// a stream whose bank sits left or right of the compute run also
/// crosses every corridor in between.  The span is therefore the
/// bounding range of the GLB banks' home corridors and the array run
/// itself, and every corridor in it is charged one track per held GLB
/// slice (each bank sustains one stream).
pub fn span_for(
    glb: &[SliceRange],
    array: &[SliceRange],
    banks_per_corridor: u32,
    corridors: u32,
) -> CorridorSpan {
    let bpc = banks_per_corridor.max(1);
    let mut lo = u32::MAX;
    let mut hi = 0u32;
    let mut tracks = 0u32;
    for r in glb {
        if r.is_empty() {
            continue;
        }
        tracks += r.len;
        lo = lo.min(r.start / bpc);
        hi = hi.max((r.end() - 1) / bpc);
    }
    for r in array {
        if r.is_empty() {
            continue;
        }
        lo = lo.min(r.start);
        hi = hi.max(r.end() - 1);
    }
    if tracks == 0 || lo == u32::MAX {
        return CorridorSpan::empty();
    }
    let hi = hi.min(corridors.saturating_sub(1));
    let lo = lo.min(hi);
    CorridorSpan::new(SliceRange::new(lo, hi - lo + 1), tracks)
}

/// Static launch-time pricing of corridor contention.
///
/// The model is deliberately simple and deterministic: at launch the
/// worst oversubscription `s ≥ 1.0` along the region's corridor span is
/// sampled once and baked into the task's execution estimate, exactly
/// like DPR cycles are.  A task spending `comm_fraction` of its cycles
/// streaming runs for `exec × ((1 − f) + f·s)` cycles instead of
/// `exec`.
#[derive(Clone, Copy, Debug)]
pub struct ContentionModel {
    /// Master switch (mirrors `[noc] enabled`).
    pub enabled: bool,
    /// Fraction of a task's execution that is stream-bandwidth-bound.
    pub comm_fraction: f64,
    /// Bytes one GLB bank streams per cycle (from the arch).
    pub bank_bytes_per_cycle: u32,
}

impl ContentionModel {
    /// Model for `arch` under `cfg`.
    pub fn new(arch: &ArchConfig, cfg: &NocConfig) -> Self {
        ContentionModel {
            enabled: cfg.enabled,
            comm_fraction: cfg.comm_fraction,
            bank_bytes_per_cycle: arch.glb_bank_bytes_per_cycle,
        }
    }

    /// A disabled model (charges nothing).
    pub fn disabled() -> Self {
        ContentionModel { enabled: false, comm_fraction: 0.0, bank_bytes_per_cycle: 8 }
    }

    /// Execution cycles after charging contention: the communication-
    /// bound fraction stretches by `slowdown`, the compute-bound rest
    /// is unaffected.  Identity when disabled or uncontended.
    pub fn charged_exec(&self, exec_cycles: u64, slowdown: f64) -> u64 {
        if !self.enabled || slowdown <= 1.0 {
            return exec_cycles;
        }
        let f = self.comm_fraction.clamp(0.0, 1.0);
        let stretch = (1.0 - f) + f * slowdown;
        (exec_cycles as f64 * stretch).ceil() as u64
    }

    /// Cycles to stream `bytes` of producer output into a region
    /// holding `glb_slices` banks, at contended effective bandwidth.
    /// This prices the explicit inter-stage edges of pipeline DAGs
    /// ([`crate::tasks::AppGraph::stream_in_bytes`]); it lands on the
    /// reconfiguration side of the launch (data staged before compute).
    pub fn stream_in_cycles(&self, bytes: u64, glb_slices: u32, slowdown: f64) -> u64 {
        if !self.enabled || bytes == 0 {
            return 0;
        }
        let bw = (self.bank_bytes_per_cycle as u64 * glb_slices.max(1) as u64).max(1);
        let base = bytes.div_ceil(bw);
        (base as f64 * slowdown.max(1.0)).ceil() as u64
    }

    /// Stream-duty scale for the energy model: a corridor granting
    /// `1/s` of the demanded tracks moves `1/s` of the bytes per cycle,
    /// so the per-cycle GLB streaming energy drops by the same factor.
    pub fn duty_scale(&self, slowdown: f64) -> f64 {
        if !self.enabled || slowdown <= 1.0 {
            1.0
        } else {
            1.0 / slowdown
        }
    }
}

/// Counters the scheduler accumulates while the NoC model is live.
#[derive(Clone, Copy, Debug, Default)]
pub struct NocStats {
    /// Regions whose streams were placed on corridors.
    pub streams_placed: u64,
    /// Launches that sampled a slowdown > 1.0.
    pub contended_launches: u64,
    /// Extra execution cycles charged by contention stretching.
    pub contention_cycles: u64,
    /// Cycles spent staging inter-stage pipeline bytes.
    pub stream_in_cycles: u64,
    /// Launches placed using a producer-affinity hint.
    pub affinity_hits: u64,
    /// Sum of sampled launch slowdowns (for the mean).
    pub slowdown_sum: f64,
    /// Worst slowdown sampled at any launch.
    pub peak_slowdown: f64,
}

impl NocStats {
    /// Record one launch's sampled contention.
    pub fn on_launch(&mut self, slowdown: f64, charged: u64, stream_in: u64, hinted: bool) {
        self.streams_placed += 1;
        if slowdown > 1.0 {
            self.contended_launches += 1;
        }
        self.contention_cycles += charged;
        self.stream_in_cycles += stream_in;
        if hinted {
            self.affinity_hits += 1;
        }
        self.slowdown_sum += slowdown;
        if slowdown > self.peak_slowdown {
            self.peak_slowdown = slowdown;
        }
    }

    /// Freeze into a report.
    pub fn report(&self, corridors: u32, capacity: u32) -> NocReport {
        NocReport {
            streams_placed: self.streams_placed,
            contended_launches: self.contended_launches,
            contention_cycles: self.contention_cycles,
            stream_in_cycles: self.stream_in_cycles,
            affinity_hits: self.affinity_hits,
            mean_slowdown: if self.streams_placed == 0 {
                1.0
            } else {
                self.slowdown_sum / self.streams_placed as f64
            },
            peak_slowdown: self.peak_slowdown.max(1.0),
            corridors,
            capacity,
        }
    }
}

/// End-of-run NoC summary (per scheduler; shards merge theirs).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NocReport {
    /// Regions whose streams were placed on corridors.
    pub streams_placed: u64,
    /// Launches that saw a slowdown > 1.0.
    pub contended_launches: u64,
    /// Extra execution cycles charged by contention.
    pub contention_cycles: u64,
    /// Cycles staging inter-stage pipeline bytes.
    pub stream_in_cycles: u64,
    /// Launches placed via producer-affinity hints.
    pub affinity_hits: u64,
    /// Mean sampled launch slowdown (1.0 = uncontended).
    pub mean_slowdown: f64,
    /// Worst sampled launch slowdown.
    pub peak_slowdown: f64,
    /// Corridor count of the fabric.
    pub corridors: u32,
    /// Tracks per corridor.
    pub capacity: u32,
}

impl NocReport {
    /// Merge another shard's report into this one (weighted mean).
    pub fn merge(&mut self, other: &NocReport) {
        let n = self.streams_placed + other.streams_placed;
        if n > 0 {
            self.mean_slowdown = (self.mean_slowdown * self.streams_placed as f64
                + other.mean_slowdown * other.streams_placed as f64)
                / n as f64;
        }
        self.streams_placed = n;
        self.contended_launches += other.contended_launches;
        self.contention_cycles += other.contention_cycles;
        self.stream_in_cycles += other.stream_in_cycles;
        self.affinity_hits += other.affinity_hits;
        self.peak_slowdown = self.peak_slowdown.max(other.peak_slowdown);
        self.corridors = self.corridors.max(other.corridors);
        self.capacity = self.capacity.max(other.capacity);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model(enabled: bool) -> ContentionModel {
        ContentionModel { enabled, comm_fraction: 0.4, bank_bytes_per_cycle: 8 }
    }

    #[test]
    fn span_bounds_glb_corridors_and_array_run() {
        // banks 8..14 (corridors 2..=3 at 4 banks/corridor), array 5..=6
        let s = span_for(
            &[SliceRange::new(8, 6)],
            &[SliceRange::new(5, 2)],
            4,
            8,
        );
        assert_eq!(s.range, SliceRange::new(2, 5)); // corridors 2..=6
        assert_eq!(s.tracks, 6);
    }

    #[test]
    fn aligned_region_spans_only_its_own_corridors() {
        // banks 0..8 over corridors 0..=1, array 0..=1: perfectly aligned
        let s = span_for(&[SliceRange::new(0, 8)], &[SliceRange::new(0, 2)], 4, 8);
        assert_eq!(s.range, SliceRange::new(0, 2));
        assert_eq!(s.tracks, 8);
    }

    #[test]
    fn empty_footprint_yields_empty_span() {
        assert!(span_for(&[], &[SliceRange::new(0, 2)], 4, 8).is_empty());
    }

    #[test]
    fn charged_exec_stretches_comm_fraction_only() {
        let m = model(true);
        // s=1.5, f=0.4 → stretch = 0.6 + 0.4*1.5 = 1.2
        assert_eq!(m.charged_exec(1000, 1.5), 1200);
        assert_eq!(m.charged_exec(1000, 1.0), 1000);
        assert_eq!(model(false).charged_exec(1000, 2.0), 1000);
    }

    #[test]
    fn stream_in_scales_with_banks_and_slowdown() {
        let m = model(true);
        // 3200 bytes over 4 banks × 8 B/cyc = 100 cycles uncontended
        assert_eq!(m.stream_in_cycles(3200, 4, 1.0), 100);
        assert_eq!(m.stream_in_cycles(3200, 4, 2.0), 200);
        assert_eq!(m.stream_in_cycles(0, 4, 2.0), 0);
        assert_eq!(model(false).stream_in_cycles(3200, 4, 2.0), 0);
    }

    #[test]
    fn duty_scale_inverts_slowdown() {
        let m = model(true);
        assert_eq!(m.duty_scale(1.0), 1.0);
        assert!((m.duty_scale(2.0) - 0.5).abs() < 1e-12);
        assert_eq!(model(false).duty_scale(2.0), 1.0);
    }

    #[test]
    fn stats_accumulate_and_report() {
        let mut st = NocStats::default();
        st.on_launch(1.0, 0, 0, false);
        st.on_launch(1.5, 200, 50, true);
        let r = st.report(8, 20);
        assert_eq!(r.streams_placed, 2);
        assert_eq!(r.contended_launches, 1);
        assert_eq!(r.contention_cycles, 200);
        assert_eq!(r.stream_in_cycles, 50);
        assert_eq!(r.affinity_hits, 1);
        assert!((r.mean_slowdown - 1.25).abs() < 1e-12);
        assert!((r.peak_slowdown - 1.5).abs() < 1e-12);
    }

    #[test]
    fn reports_merge_weighted() {
        let mut a = NocStats::default();
        a.on_launch(1.0, 0, 0, false);
        let mut b = NocStats::default();
        b.on_launch(2.0, 100, 0, false);
        b.on_launch(2.0, 100, 0, false);
        let mut ra = a.report(8, 20);
        ra.merge(&b.report(8, 20));
        assert_eq!(ra.streams_placed, 3);
        assert!((ra.mean_slowdown - (1.0 + 2.0 + 2.0) / 3.0).abs() < 1e-12);
        assert!((ra.peak_slowdown - 2.0).abs() < 1e-12);
    }
}
