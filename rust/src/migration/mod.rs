//! Live task migration & fabric defragmentation.
//!
//! The paper's flexible-shape regions and fast DPR raise utilization,
//! but under sustained multi-tenant churn the slice maps fragment: free
//! slices exist, yet allocation returns `NoFit` because they are not
//! contiguous, and throughput decays exactly where the mechanisms
//! promise gains.  Following Mestra's observation that relocating
//! *running* tasks between regions recovers this lost capacity, this
//! subsystem drives the fast-DPR relocation machinery
//! ([`crate::dpr::DprEngine`], [`crate::dpr::DprMode::Fast`]) as a
//! defragmentation engine:
//!
//! * [`DefragPlanner`] scans the [`crate::regions::RegionManager`] slice
//!   maps, and when external fragmentation exceeds
//!   `scheduler.defrag_threshold` proposes a [`CompactionPlan`] — the
//!   left-compaction of every movable region, expressed as
//!   [`MigrationStep`]s.
//! * [`MigrationCostModel`] prices each step in core cycles:
//!   checkpoint/quiesce, fast-DPR restream into the new array-slices,
//!   and the bank-to-bank GLB state copy
//!   (`scheduler.migration_cost_model` selects zero / dpr-only / full).
//! * [`execute_plan`] performs the relocations against the region
//!   manager — array pass then GLB pass, each in ascending target order
//!   so targets are always free — and returns the per-task
//!   [`MigrationRecord`]s the scheduler uses to push out the migrated
//!   tasks' completion times (checkpoint → fast-DPR relocation → GLB
//!   state copy → resume).
//!
//! The scheduler ([`crate::scheduler::Scheduler`]) consults the planner
//! whenever a ready task's every variant returns `NoFit`, commits the
//! plan under `scheduler.defrag_policy` (`greedy` always; `cost-aware`
//! only when the plan's cycle cost is repaid by the execution time of
//! the task it unblocks), and charges the plan's cycles to the rescued
//! launch so the event-driven timeline stays correct.  The coordinator
//! exposes the same machinery through the `DEFRAG` wire command.

mod cost;
mod executor;
mod planner;

pub use cost::MigrationCostModel;
pub use executor::{execute_plan, MigrationOutcome, MigrationRecord};
pub use planner::{CompactionPlan, DefragPlanner, MigrationStep};

/// Cumulative migration counters kept by the scheduler.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MigrationStats {
    /// Ready tasks whose every variant returned `NoFit` at a schedule
    /// step (counted per attempt — the backlog pressure signal).
    pub nofit_events: u64,
    /// Compaction plans the planner was asked for.
    pub plans_considered: u64,
    /// Plans that were committed and executed.
    pub plans_committed: u64,
    /// Individual task relocations performed.
    pub tasks_migrated: u64,
    /// Total cycles charged for migrations (checkpoint + DPR + copy).
    pub migration_cycles: u64,
    /// Launches that succeeded only because a compaction ran first.
    pub rescued_launches: u64,
}

/// Outcome of one forced compaction pass (the `DEFRAG` wire command).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MigrationReport {
    /// Tasks relocated.
    pub migrated: u64,
    /// Total migration cycles charged.
    pub cycles: u64,
    /// (glb, array) external fragmentation before the pass.
    pub frag_before: (f64, f64),
    /// (glb, array) external fragmentation after the pass.
    pub frag_after: (f64, f64),
}
