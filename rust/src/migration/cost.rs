//! Cycle-cost model for live migration.
//!
//! A migration is checkpoint → fast-DPR relocation → GLB state copy →
//! resume.  The three components priced here:
//!
//! * **checkpoint** — draining the region's pipelines and quiescing its
//!   stream ports: a fixed handshake, same order as the fast-DPR arm
//!   overhead.
//! * **restream** — when the array range moves, the cached bitstream is
//!   restreamed into the new slices (the destination-register relocation
//!   of §2.3); the caller supplies the engine's stream cycles since they
//!   depend on the DPR mode and the bitstream.
//! * **GLB copy** — when the GLB range moves, each source bank streams
//!   its contents to its destination bank; banks copy pairwise in
//!   parallel, so the cost is one bank's capacity over its port width
//!   regardless of how many banks the region owns.

use crate::config::{ArchConfig, MigrationCostModelKind};

use super::planner::MigrationStep;

/// Fixed checkpoint/quiesce handshake, core cycles.
pub const CHECKPOINT_CYCLES: u64 = 64;

/// Prices a [`MigrationStep`] in core cycles.
#[derive(Clone, Copy, Debug)]
pub struct MigrationCostModel {
    kind: MigrationCostModelKind,
    /// Bank-to-bank GLB copy cost (full bank over the stream port).
    glb_copy_cycles: u64,
}

impl MigrationCostModel {
    /// Build from architecture parameters and the configured kind.
    pub fn new(arch: &ArchConfig, kind: MigrationCostModelKind) -> MigrationCostModel {
        let bank_bytes = arch.glb_slice_bytes();
        let per_cycle = arch.glb_bank_bytes_per_cycle.max(1) as u64;
        MigrationCostModel { kind, glb_copy_cycles: bank_bytes.div_ceil(per_cycle) }
    }

    /// Configured kind.
    pub fn kind(&self) -> MigrationCostModelKind {
        self.kind
    }

    /// Cycles a preemptive *eviction* charges the victim's region before
    /// it frees: the quiesce handshake, plus (under the full model) the
    /// GLB state copy-out that preserves the checkpoint — the same
    /// checkpoint path a migration pays, minus the restream, since the
    /// evicted task is not reinstalled anywhere yet ([`crate::qos`]).
    pub fn checkpoint_cycles(&self) -> u64 {
        match self.kind {
            MigrationCostModelKind::Zero => 0,
            MigrationCostModelKind::DprOnly => CHECKPOINT_CYCLES,
            MigrationCostModelKind::Full => CHECKPOINT_CYCLES + self.glb_copy_cycles,
        }
    }

    /// Extra cycles a checkpointed victim's *resume* launch pays on top
    /// of the DPR restream (which the engine prices): the GLB state
    /// copy-in under the full model, nothing otherwise.
    pub fn resume_extra_cycles(&self) -> u64 {
        match self.kind {
            MigrationCostModelKind::Zero | MigrationCostModelKind::DprOnly => 0,
            MigrationCostModelKind::Full => self.glb_copy_cycles,
        }
    }

    /// Cycles charged for one step.  `dpr_stream_cycles` is what the DPR
    /// engine would charge to restream this region's bitstream (only
    /// counted when the array range actually moves).
    pub fn step_cycles(&self, step: &MigrationStep, dpr_stream_cycles: u64) -> u64 {
        match self.kind {
            MigrationCostModelKind::Zero => 0,
            MigrationCostModelKind::DprOnly => {
                CHECKPOINT_CYCLES
                    + if step.moves_array() { dpr_stream_cycles } else { 0 }
            }
            MigrationCostModelKind::Full => {
                CHECKPOINT_CYCLES
                    + if step.moves_array() { dpr_stream_cycles } else { 0 }
                    + if step.moves_glb() { self.glb_copy_cycles } else { 0 }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::abstraction::SliceRange;
    use crate::regions::RegionId;

    fn step(moves_glb: bool, moves_array: bool) -> MigrationStep {
        MigrationStep {
            region: RegionId(0),
            from_glb: SliceRange::new(8, 4),
            to_glb: if moves_glb { SliceRange::new(0, 4) } else { SliceRange::new(8, 4) },
            from_array: SliceRange::new(4, 2),
            to_array: if moves_array { SliceRange::new(0, 2) } else { SliceRange::new(4, 2) },
        }
    }

    #[test]
    fn full_model_prices_all_components() {
        let m = MigrationCostModel::new(&ArchConfig::default(), MigrationCostModelKind::Full);
        // 128 KiB bank / 8 B-per-cycle = 16384 cycles
        assert_eq!(m.step_cycles(&step(true, true), 3344), 64 + 3344 + 16_384);
        assert_eq!(m.step_cycles(&step(false, true), 3344), 64 + 3344);
        assert_eq!(m.step_cycles(&step(true, false), 3344), 64 + 16_384);
    }

    #[test]
    fn dpr_only_skips_glb_copy() {
        let m = MigrationCostModel::new(&ArchConfig::default(), MigrationCostModelKind::DprOnly);
        assert_eq!(m.step_cycles(&step(true, true), 3344), 64 + 3344);
        assert_eq!(m.kind(), MigrationCostModelKind::DprOnly);
    }

    #[test]
    fn moved_glb_slices_feeds_the_energy_model() {
        // cycle cost is one bank's span (pairwise-parallel copies), but
        // energy scales with every moved bank
        assert_eq!(step(true, true).moved_glb_slices(), 4);
        assert_eq!(step(false, true).moved_glb_slices(), 0);
    }

    #[test]
    fn checkpoint_and_resume_pricing_tracks_the_kind() {
        let arch = ArchConfig::default();
        let full = MigrationCostModel::new(&arch, MigrationCostModelKind::Full);
        assert_eq!(full.checkpoint_cycles(), 64 + 16_384);
        assert_eq!(full.resume_extra_cycles(), 16_384);
        let dpr = MigrationCostModel::new(&arch, MigrationCostModelKind::DprOnly);
        assert_eq!(dpr.checkpoint_cycles(), 64);
        assert_eq!(dpr.resume_extra_cycles(), 0);
        let zero = MigrationCostModel::new(&arch, MigrationCostModelKind::Zero);
        assert_eq!(zero.checkpoint_cycles(), 0);
        assert_eq!(zero.resume_extra_cycles(), 0);
    }

    #[test]
    fn zero_model_is_free() {
        let m = MigrationCostModel::new(&ArchConfig::default(), MigrationCostModelKind::Zero);
        assert_eq!(m.step_cycles(&step(true, true), 3344), 0);
    }

    #[test]
    fn migration_is_microseconds_next_to_task_runtimes() {
        // The asymmetry that makes defragmentation worthwhile: a full
        // migration (~20k cycles ≈ 40 µs at 500 MHz) is two orders of
        // magnitude below the shortest Table 1 task (~520k cycles).
        let m = MigrationCostModel::new(&ArchConfig::default(), MigrationCostModelKind::Full);
        let worst = m.step_cycles(&step(true, true), 3344);
        assert!(worst < 25_000, "{worst}");
    }
}
