//! The migration executor: applies a [`CompactionPlan`] to the region
//! manager.
//!
//! Relocations run in two passes — array-slice moves first, then
//! GLB-slice moves — each pass in ascending *target* order.  Within one
//! slice class, left-compaction targets never overlap an unmoved
//! region's old range once every earlier (more-left) region has moved,
//! and a region's own old range is treated as free by
//! [`crate::regions::RegionManager::relocate`]; processing the classes
//! separately removes the cross-class ordering cycles a single combined
//! pass could deadlock on (A's GLB target under B's old banks while B's
//! array target sits under A's old slices).

use std::collections::BTreeMap;

use crate::error::Result;
use crate::regions::{RegionId, RegionManager};

use super::planner::{CompactionPlan, MigrationStep};

/// One executed migration, with its charged cost.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MigrationRecord {
    /// Relocated region.
    pub region: RegionId,
    /// Cycles this task is paused for (checkpoint + restream + copy).
    pub cycles: u64,
    /// The step that was applied.
    pub step: MigrationStep,
    /// `(glb, array)` power-gated domains the relocation woke
    /// ([`crate::regions::RegionManager::relocate`]); `(0, 0)` unless
    /// gating is armed.  The scheduler charges the wake energy.
    pub woken: (u32, u32),
}

/// Result of executing a plan.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MigrationOutcome {
    /// Per-task records, in plan order.
    pub records: Vec<MigrationRecord>,
    /// Total migration cycles (the migration engine runs relocations
    /// serially, so this is also the wall-clock span of the pass).
    pub total_cycles: u64,
}

/// Apply `plan` to `mgr`.  `costs` must align 1:1 with `plan.steps`
/// (the scheduler prices steps against its bitstream table before
/// executing).  On error the already-applied relocations remain — the
/// occupancy maps are still consistent, just partially compacted.
pub fn execute_plan(
    mgr: &mut RegionManager,
    plan: &CompactionPlan,
    costs: &[u64],
) -> Result<MigrationOutcome> {
    debug_assert_eq!(plan.steps.len(), costs.len(), "one cost per step");

    let mut woken: BTreeMap<RegionId, (u32, u32)> = BTreeMap::new();
    let mut record_woken = |region: RegionId, w: (u32, u32)| {
        let e = woken.entry(region).or_insert((0, 0));
        e.0 += w.0;
        e.1 += w.1;
    };

    // Pass 1: array-slice relocations, ascending target start.
    let mut array_moves: Vec<&MigrationStep> =
        plan.steps.iter().filter(|s| s.moves_array()).collect();
    array_moves.sort_by_key(|s| s.to_array.start);
    for s in array_moves {
        let w = mgr.relocate(s.region, None, Some(s.to_array))?;
        record_woken(s.region, w);
    }

    // Pass 2: GLB-slice relocations, ascending target start.
    let mut glb_moves: Vec<&MigrationStep> =
        plan.steps.iter().filter(|s| s.moves_glb()).collect();
    glb_moves.sort_by_key(|s| s.to_glb.start);
    for s in glb_moves {
        let w = mgr.relocate(s.region, Some(s.to_glb), None)?;
        record_woken(s.region, w);
    }

    let records: Vec<MigrationRecord> = plan
        .steps
        .iter()
        .zip(costs.iter())
        .map(|(s, &cycles)| MigrationRecord {
            region: s.region,
            cycles,
            step: *s,
            woken: woken.get(&s.region).copied().unwrap_or((0, 0)),
        })
        .collect();
    let total_cycles = records.iter().map(|r| r.cycles).sum();
    Ok(MigrationOutcome { records, total_cycles })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::abstraction::SliceDemand;
    use crate::config::{ArchConfig, DefragPolicyKind, RegionPolicyKind, SchedulerConfig};
    use crate::migration::DefragPlanner;
    use crate::regions::AllocOutcome;

    fn flexible() -> RegionManager {
        let arch = ArchConfig::default();
        let sched = SchedulerConfig {
            region_policy: RegionPolicyKind::FlexibleShape,
            ..SchedulerConfig::default()
        };
        RegionManager::new(&arch, &sched)
    }

    fn greedy_planner() -> DefragPlanner {
        DefragPlanner::new(&SchedulerConfig {
            defrag_policy: DefragPolicyKind::Greedy,
            defrag_threshold: 0.0,
            ..SchedulerConfig::default()
        })
    }

    #[test]
    fn executing_a_plan_defragments_the_maps() {
        let mut m = flexible();
        let d = SliceDemand::new(8, 2);
        let mut ids = Vec::new();
        for _ in 0..4 {
            match m.try_allocate(&d) {
                AllocOutcome::Allocated(r) => ids.push(r.id),
                other => panic!("{other:?}"),
            }
        }
        // punch two holes: free array {2,3} and {6,7}
        m.release(ids[1]).unwrap();
        m.release(ids[3]).unwrap();
        let (fg0, fa0) = m.fragmentation();
        assert!(fa0 > 0.0 || fg0 > 0.0);

        let plan = greedy_planner().compact(&m).expect("fragmented");
        let costs = vec![100; plan.len()];
        let out = execute_plan(&mut m, &plan, &costs).unwrap();
        assert_eq!(out.records.len(), plan.len());
        assert_eq!(out.total_cycles, 100 * plan.len() as u64);

        // after compaction both classes are hole-free
        assert_eq!(m.fragmentation(), (0.0, 0.0));
        // occupancy conserved: 2 regions × (8 glb, 2 array)
        assert_eq!(m.glb_map().busy_count(), 16);
        assert_eq!(m.array_map().busy_count(), 4);
        // ...and a previously-impossible 4-slice run now allocates
        match m.try_allocate(&SliceDemand::new(4, 4)) {
            AllocOutcome::Allocated(_) => {}
            other => panic!("compaction should have made room: {other:?}"),
        }
    }

    #[test]
    fn interleaved_holes_compact_in_one_pass() {
        let mut m = flexible();
        let d = SliceDemand::new(4, 1);
        let mut ids = Vec::new();
        for _ in 0..8 {
            match m.try_allocate(&d) {
                AllocOutcome::Allocated(r) => ids.push(r.id),
                other => panic!("{other:?}"),
            }
        }
        // free every other region: worst-case checkerboard
        for i in [1usize, 3, 5, 7] {
            m.release(ids[i]).unwrap();
        }
        let plan = greedy_planner().compact(&m).expect("checkerboard");
        assert_eq!(plan.len(), 3); // regions at 2,4,6 move; region 0 stays
        let costs = vec![0u64; plan.len()];
        execute_plan(&mut m, &plan, &costs).unwrap();
        assert_eq!(m.fragmentation(), (0.0, 0.0));
        assert_eq!(m.array_map().busy_count(), 4);
    }

    #[test]
    fn variable_size_compaction_keeps_unit_alignment() {
        let arch = ArchConfig::default();
        let sched = SchedulerConfig {
            region_policy: RegionPolicyKind::VariableSize,
            unit_glb_slices: 4,
            unit_array_slices: 1,
            ..SchedulerConfig::default()
        };
        let mut m = RegionManager::new(&arch, &sched);
        let d = SliceDemand::new(8, 2); // 2 units
        let a = m.try_allocate(&d).expect_allocated("a");
        let b = m.try_allocate(&d).expect_allocated("b");
        let c = m.try_allocate(&d).expect_allocated("c");
        let _ = (a, c);
        m.release(b.id).unwrap();
        let plan = greedy_planner().compact(&m).expect("hole");
        let costs = vec![0u64; plan.len()];
        execute_plan(&mut m, &plan, &costs).unwrap();
        // a merged 4-unit task now fits
        match m.try_allocate(&SliceDemand::new(16, 4)) {
            AllocOutcome::Allocated(r) => assert!(r.is_contiguous()),
            other => panic!("{other:?}"),
        }
    }
}
