//! The defragmentation planner: fragmentation detection + compaction
//! plan synthesis over the region manager's slice maps.

use crate::abstraction::{SliceDemand, SliceRange};
use crate::config::{DefragPolicyKind, RegionPolicyKind, SchedulerConfig};
use crate::regions::{RegionId, RegionManager};

/// One proposed relocation: where a region's slices are and where the
/// plan wants them.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MigrationStep {
    /// Region to relocate.
    pub region: RegionId,
    /// Current GLB-slice range.
    pub from_glb: SliceRange,
    /// Target GLB-slice range.
    pub to_glb: SliceRange,
    /// Current array-slice range.
    pub from_array: SliceRange,
    /// Target array-slice range.
    pub to_array: SliceRange,
}

impl MigrationStep {
    /// Whether the GLB range changes (implies a bank-to-bank state copy).
    pub fn moves_glb(&self) -> bool {
        self.from_glb != self.to_glb
    }

    /// Whether the array range changes (implies a fast-DPR restream).
    pub fn moves_array(&self) -> bool {
        self.from_array != self.to_array
    }

    /// GLB slices whose bank contents the step must copy (0 when the
    /// GLB range stays put) — the migration energy model's bank-copy
    /// input ([`crate::energy::EnergyModel::migration_step_pj`] charges
    /// per byte moved; the *cycle* cost model charges only one bank's
    /// span because banks copy pairwise in parallel, but every moved
    /// bank's bytes switch, so energy scales with this count).
    pub fn moved_glb_slices(&self) -> u32 {
        if self.moves_glb() {
            self.to_glb.len
        } else {
            0
        }
    }
}

/// An ordered set of relocations that left-compacts the busy slices.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CompactionPlan {
    /// Steps, in region-discovery order.  [`crate::migration::execute_plan`]
    /// re-sorts per slice class; the order here carries no meaning.
    pub steps: Vec<MigrationStep>,
}

impl CompactionPlan {
    /// Number of regions the plan moves.
    pub fn len(&self) -> usize {
        self.steps.len()
    }

    /// Whether the plan moves nothing.
    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }
}

/// Fragmentation detector + compaction-plan synthesizer.
///
/// Planning is pure: the planner never mutates the region manager.  Only
/// the flexible-shape and variable-size mechanisms can defragment — the
/// baseline has a single region and fixed-size regions are pre-carved at
/// immovable unit positions.
#[derive(Clone, Copy, Debug)]
pub struct DefragPlanner {
    policy: DefragPolicyKind,
    threshold: f64,
    /// Communication-aware packing ([`crate::noc`]): compaction orders
    /// the array class by each region's GLB home position, so compute
    /// lands under the banks feeding it and corridor spans shrink.
    comm_aware: bool,
}

impl DefragPlanner {
    /// Build from the scheduler configuration knobs.
    pub fn new(cfg: &SchedulerConfig) -> DefragPlanner {
        DefragPlanner {
            policy: cfg.defrag_policy,
            threshold: cfg.defrag_threshold,
            comm_aware: false,
        }
    }

    /// Arm (or disarm) the communication-aware packing objective — set
    /// by the scheduler from `[noc] enabled` + `defrag_align`.
    pub fn set_comm_aware(&mut self, on: bool) {
        self.comm_aware = on;
    }

    /// Active defrag policy.
    pub fn policy(&self) -> DefragPolicyKind {
        self.policy
    }

    /// Whether the scheduler should consult the planner at all.
    pub fn enabled(&self) -> bool {
        self.policy != DefragPolicyKind::Off
    }

    /// Propose a plan that would let `target` allocate, or `None` when
    /// fragmentation is below the threshold, the mechanism cannot
    /// defragment, nothing would move, or compaction still cannot free
    /// enough contiguous room for the demand.
    pub fn plan(&self, mgr: &RegionManager, target: &SliceDemand) -> Option<CompactionPlan> {
        let (fg, fa) = mgr.fragmentation();
        if fg < self.threshold && fa < self.threshold {
            return None;
        }
        if !Self::fits_after_compaction(mgr, target) {
            return None;
        }
        self.compaction(mgr)
    }

    /// Unconditional compaction plan (the `DEFRAG` wire command) —
    /// ignores the threshold and any target demand.
    pub fn compact(&self, mgr: &RegionManager) -> Option<CompactionPlan> {
        self.compaction(mgr)
    }

    /// Whether `target` fits once every movable region is packed left
    /// (after compaction, each slice class's free slices form one run).
    fn fits_after_compaction(mgr: &RegionManager, target: &SliceDemand) -> bool {
        match mgr.policy() {
            RegionPolicyKind::FlexibleShape => {
                mgr.glb_map().free_count() >= target.glb_slices
                    && mgr.array_map().free_count() >= target.array_slices
            }
            RegionPolicyKind::VariableSize => {
                let unit = mgr.unit();
                let used_units: u32 = mgr
                    .active()
                    .map(|r| r.array_slices() / unit.array_slices.max(1))
                    .sum();
                mgr.units_needed(target) <= mgr.unit_count().saturating_sub(used_units)
            }
            _ => false,
        }
    }

    fn compaction(&self, mgr: &RegionManager) -> Option<CompactionPlan> {
        match mgr.policy() {
            RegionPolicyKind::FlexibleShape => self.compact_flexible(mgr),
            RegionPolicyKind::VariableSize => Self::compact_variable(mgr),
            RegionPolicyKind::Baseline | RegionPolicyKind::FixedSize => None,
        }
    }

    /// Flexible-shape: GLB and array slices are decoupled, so each class
    /// packs left independently, preserving relative order per class.
    ///
    /// Comm-aware mode instead packs the array class in GLB-home order
    /// (compute under its banks).  That permutes the array class, which
    /// can form relocation cycles the two-pass executor cannot break —
    /// so the permuted plan is dry-run checked against the executor's
    /// target-order schedule and the order-preserving plan is used
    /// whenever the permuted one would wedge.
    fn compact_flexible(&self, mgr: &RegionManager) -> Option<CompactionPlan> {
        #[derive(Clone, Copy)]
        struct Entry {
            region: RegionId,
            glb: SliceRange,
            array: SliceRange,
        }
        let regions: Vec<Entry> = mgr
            .active()
            .filter(|r| r.is_contiguous())
            .map(|r| Entry {
                region: r.id,
                glb: r.glb.first().copied().unwrap_or(SliceRange::empty()),
                array: r.array.first().copied().unwrap_or(SliceRange::empty()),
            })
            .collect();
        if regions.is_empty() {
            return None;
        }

        let build = |array_by_glb: bool| -> Vec<MigrationStep> {
            let mut rs = regions.clone();
            // target array ranges: pack in ascending current order, or
            // in GLB-home order under the comm-aware objective
            let mut to_array: Vec<(RegionId, SliceRange)> = Vec::with_capacity(rs.len());
            if array_by_glb {
                rs.sort_by_key(|e| (e.glb.start, e.array.start));
            } else {
                rs.sort_by_key(|e| e.array.start);
            }
            let mut cursor = 0u32;
            for e in &rs {
                to_array.push((e.region, SliceRange::new(cursor, e.array.len)));
                cursor += e.array.len;
            }
            // target glb ranges: ascending current order, independently
            let mut to_glb: Vec<(RegionId, SliceRange)> = Vec::with_capacity(rs.len());
            rs.sort_by_key(|e| e.glb.start);
            let mut cursor = 0u32;
            for e in &rs {
                to_glb.push((e.region, SliceRange::new(cursor, e.glb.len)));
                cursor += e.glb.len;
            }

            rs.sort_by_key(|e| e.region);
            to_array.sort_by_key(|(id, _)| *id);
            to_glb.sort_by_key(|(id, _)| *id);
            rs.iter()
                .zip(to_array.iter())
                .zip(to_glb.iter())
                .map(|((e, (_, ta)), (_, tg))| MigrationStep {
                    region: e.region,
                    from_glb: e.glb,
                    // an empty range (zero-GLB demand) never needs to move
                    to_glb: if e.glb.is_empty() { e.glb } else { *tg },
                    from_array: e.array,
                    to_array: if e.array.is_empty() { e.array } else { *ta },
                })
                .filter(|s| s.moves_glb() || s.moves_array())
                .collect()
        };

        let steps = if self.comm_aware {
            let occupancy: Vec<(RegionId, SliceRange, SliceRange)> =
                regions.iter().map(|e| (e.region, e.glb, e.array)).collect();
            let comm = build(true);
            if Self::steps_apply_cleanly(&occupancy, &comm) {
                comm
            } else {
                build(false)
            }
        } else {
            build(false)
        };
        if steps.is_empty() {
            None
        } else {
            Some(CompactionPlan { steps })
        }
    }

    /// Dry-run `steps` through the executor's schedule (array pass then
    /// GLB pass, each in ascending target order) over the given
    /// `(region, glb, array)` occupancy: true iff no target ever
    /// overlaps a region that has not vacated yet.
    fn steps_apply_cleanly(
        occupancy: &[(RegionId, SliceRange, SliceRange)],
        steps: &[MigrationStep],
    ) -> bool {
        fn pass_applies(mut held: Vec<(RegionId, SliceRange)>, moves: Vec<(RegionId, SliceRange)>) -> bool {
            // `moves` arrives sorted ascending by target start
            for (region, target) in moves {
                if held.iter().any(|(id, r)| *id != region && r.overlaps(&target)) {
                    return false;
                }
                if let Some(slot) = held.iter_mut().find(|(id, _)| *id == region) {
                    slot.1 = target;
                }
            }
            true
        }
        let mut array_moves: Vec<(RegionId, SliceRange)> = steps
            .iter()
            .filter(|s| s.moves_array())
            .map(|s| (s.region, s.to_array))
            .collect();
        array_moves.sort_by_key(|(_, r)| r.start);
        let mut glb_moves: Vec<(RegionId, SliceRange)> = steps
            .iter()
            .filter(|s| s.moves_glb())
            .map(|s| (s.region, s.to_glb))
            .collect();
        glb_moves.sort_by_key(|(_, r)| r.start);
        pass_applies(
            occupancy.iter().map(|&(id, _, a)| (id, a)).collect(),
            array_moves,
        ) && pass_applies(
            occupancy.iter().map(|&(id, g, _)| (id, g)).collect(),
            glb_moves,
        )
    }

    /// Variable-size: regions are spans of adjacent units whose GLB and
    /// array ranges are linked by the unit index, so compaction works in
    /// unit space and moves both classes together.
    fn compact_variable(mgr: &RegionManager) -> Option<CompactionPlan> {
        let unit = mgr.unit();
        let ua = unit.array_slices.max(1);
        let ug = unit.glb_slices.max(1);
        let mut regions: Vec<(RegionId, SliceRange, SliceRange)> = mgr
            .active()
            .filter(|r| r.is_contiguous())
            .map(|r| {
                (
                    r.id,
                    r.glb.first().copied().unwrap_or(SliceRange::empty()),
                    r.array.first().copied().unwrap_or(SliceRange::empty()),
                )
            })
            .collect();
        if regions.is_empty() {
            return None;
        }
        regions.sort_by_key(|(_, _, array)| array.start);
        let mut cursor_units = 0u32;
        let mut steps = Vec::new();
        for (id, glb, array) in regions {
            let k = array.len / ua;
            let to_array = SliceRange::new(cursor_units * ua, array.len);
            let to_glb = SliceRange::new(cursor_units * ug, glb.len);
            cursor_units += k;
            let step = MigrationStep {
                region: id,
                from_glb: glb,
                to_glb,
                from_array: array,
                to_array,
            };
            if step.moves_glb() || step.moves_array() {
                steps.push(step);
            }
        }
        if steps.is_empty() {
            None
        } else {
            Some(CompactionPlan { steps })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ArchConfig, SchedulerConfig};
    use crate::regions::AllocOutcome;

    fn manager(policy: RegionPolicyKind) -> RegionManager {
        let arch = ArchConfig::default(); // 32 glb, 8 array
        let sched = SchedulerConfig {
            region_policy: policy,
            unit_glb_slices: 4,
            unit_array_slices: 1,
            ..SchedulerConfig::default()
        };
        RegionManager::new(&arch, &sched)
    }

    fn planner(threshold: f64) -> DefragPlanner {
        DefragPlanner::new(&SchedulerConfig {
            defrag_policy: DefragPolicyKind::Greedy,
            defrag_threshold: threshold,
            ..SchedulerConfig::default()
        })
    }

    /// Build a fragmented flexible map: three 2-array-slice regions,
    /// release the middle one → free array slices {2,3} and {6,7}.
    fn fragmented_flexible() -> (RegionManager, Vec<RegionId>) {
        let mut m = manager(RegionPolicyKind::FlexibleShape);
        let d = SliceDemand::new(8, 2);
        let ids: Vec<RegionId> = (0..3)
            .map(|_| match m.try_allocate(&d) {
                AllocOutcome::Allocated(r) => r.id,
                other => panic!("fill: {other:?}"),
            })
            .collect();
        m.release(ids[1]).unwrap();
        (m, ids)
    }

    #[test]
    fn plan_compacts_fragmented_flexible_map() {
        let (m, ids) = fragmented_flexible();
        // free array = {2,3} ∪ {6,7}: 4 free but the largest run is 2
        let p = planner(0.25);
        let target = SliceDemand::new(4, 4);
        let plan = p.plan(&m, &target).expect("fragmented enough");
        // only the last region needs to move: array [4..6) → [2..4)
        assert_eq!(plan.len(), 1);
        let s = plan.steps[0];
        assert_eq!(s.region, ids[2]);
        assert_eq!(s.from_array, SliceRange::new(4, 2));
        assert_eq!(s.to_array, SliceRange::new(2, 2));
        assert!(s.moves_array());
        assert!(s.moves_glb()); // glb packs left too
    }

    #[test]
    fn plan_respects_threshold() {
        let (m, _) = fragmented_flexible();
        let (fg, fa) = m.fragmentation();
        let above = fg.max(fa) + 0.01;
        assert!(planner(above).plan(&m, &SliceDemand::new(1, 1)).is_none());
    }

    #[test]
    fn plan_refuses_unsatisfiable_targets() {
        let (m, _) = fragmented_flexible();
        // only 4 array slices are free in total: 5 can never be freed by
        // compaction alone
        assert!(planner(0.0).plan(&m, &SliceDemand::new(1, 5)).is_none());
        // ... but 4 can
        assert!(planner(0.0).plan(&m, &SliceDemand::new(1, 4)).is_some());
    }

    #[test]
    fn compact_ignores_threshold_and_target() {
        let (m, _) = fragmented_flexible();
        assert!(planner(1.0).compact(&m).is_some());
    }

    #[test]
    fn packed_map_needs_no_plan() {
        let mut m = manager(RegionPolicyKind::FlexibleShape);
        let _ = m.try_allocate(&SliceDemand::new(8, 2));
        let _ = m.try_allocate(&SliceDemand::new(8, 2));
        assert!(planner(0.0).compact(&m).is_none());
    }

    #[test]
    fn variable_plan_moves_unit_spans() {
        let mut m = manager(RegionPolicyKind::VariableSize);
        // three 2-unit regions (8 glb + 2 array each), free the middle
        let d = SliceDemand::new(8, 2);
        let a = m.try_allocate(&d).expect_allocated("a");
        let b = m.try_allocate(&d).expect_allocated("b");
        let c = m.try_allocate(&d).expect_allocated("c");
        let _ = a;
        m.release(b.id).unwrap();
        // a 3-unit task cannot fit in the two scattered 2-unit holes
        let target = SliceDemand::new(12, 3);
        let plan = planner(0.0).plan(&m, &target).expect("viable");
        assert_eq!(plan.len(), 1);
        let s = plan.steps[0];
        assert_eq!(s.region, c.id);
        // c moves from units 4..6 to units 2..4 (both classes linked)
        assert_eq!(s.to_array, SliceRange::new(2, 2));
        assert_eq!(s.to_glb, SliceRange::new(8, 8));
    }

    #[test]
    fn immovable_mechanisms_never_plan() {
        for policy in [RegionPolicyKind::Baseline, RegionPolicyKind::FixedSize] {
            let mut m = manager(policy);
            let _ = m.try_allocate(&SliceDemand::new(4, 1));
            assert!(planner(0.0).compact(&m).is_none(), "{policy:?}");
            assert!(planner(0.0).plan(&m, &SliceDemand::new(1, 1)).is_none());
        }
    }

    #[test]
    fn disabled_planner_reports_off() {
        let p = DefragPlanner::new(&SchedulerConfig::default());
        assert!(!p.enabled());
        assert_eq!(p.policy(), DefragPolicyKind::Off);
    }

    #[test]
    fn comm_aware_packs_array_class_in_glb_order() {
        // R1 g[0,8) a[0,1), R2 g[8,16) a[1,2); shove R1's array run to
        // [2,3) so the array order (R2, R1) inverts the GLB order.
        let mut m = manager(RegionPolicyKind::FlexibleShape);
        let d = SliceDemand::new(8, 1);
        let r1 = m.try_allocate(&d).expect_allocated("r1").id;
        let r2 = m.try_allocate(&d).expect_allocated("r2").id;
        m.relocate(r1, None, Some(SliceRange::new(2, 1))).unwrap();

        // order-preserving compaction shuffles both regions down
        let plain = planner(0.0).compact(&m).expect("fragmented");
        assert_eq!(plain.len(), 2);

        // comm-aware compaction instead slots R1 under its banks: one
        // move, and the array order now mirrors the GLB order
        let mut p = planner(0.0);
        p.set_comm_aware(true);
        let plan = p.compact(&m).expect("fragmented");
        assert_eq!(plan.len(), 1);
        assert_eq!(plan.steps[0].region, r1);
        assert_eq!(plan.steps[0].to_array, SliceRange::new(0, 1));
        assert!(!plan.steps[0].moves_glb());
        let _ = r2;
    }

    #[test]
    fn comm_aware_falls_back_when_the_permutation_would_wedge() {
        // R1 g[0,4) a[0,2), R3 g[8,12) a[4,6); the hole from a released
        // middle region is refilled by R4 g[12,20) a[2,4).  GLB order
        // (R1, R3, R4) asks the array class to swap R3 and R4 — a cycle
        // the two-pass executor cannot break, so the planner must fall
        // back to the order-preserving packing.
        let mut m = manager(RegionPolicyKind::FlexibleShape);
        let d = SliceDemand::new(4, 2);
        let _r1 = m.try_allocate(&d).expect_allocated("r1").id;
        let r2 = m.try_allocate(&d).expect_allocated("r2").id;
        let _r3 = m.try_allocate(&d).expect_allocated("r3").id;
        m.release(r2).unwrap();
        let r4 = m.try_allocate(&SliceDemand::new(8, 2)).expect_allocated("r4");
        assert_eq!(r4.array[0], SliceRange::new(2, 2));
        assert_eq!(r4.glb[0], SliceRange::new(12, 8));

        let mut p = planner(0.0);
        p.set_comm_aware(true);
        let aware = p.compact(&m).expect("fragmented");
        let plain = planner(0.0).compact(&m).expect("fragmented");
        assert_eq!(aware, plain, "unexecutable permutation must fall back");
        // and the fallback plan actually executes
        let costs = vec![0u64; aware.len()];
        crate::migration::execute_plan(&mut m, &aware, &costs).unwrap();
        assert_eq!(m.fragmentation(), (0.0, 0.0));
    }
}
