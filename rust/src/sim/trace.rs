//! Bounded simulation trace for debugging and example output.
//!
//! Since the observability PR the trace is structured: each entry is a
//! [`TraceKind`] — either a typed [`SimEvent`] emitted by the sim
//! drivers (also consumed by the lifecycle journal,
//! [`crate::obs::Journal`]) or a raw pre-formatted string for ad-hoc
//! notes.  Rendering is unchanged byte-for-byte: `SimEvent`'s
//! `Display` reproduces the legacy line grammar exactly, which the
//! differential goldens enforce.

use std::collections::VecDeque;
use std::fmt;

use crate::obs::SimEvent;
use crate::sim::engine::Cycle;

/// What a trace entry records.
#[derive(Clone, Debug)]
pub enum TraceKind {
    /// Pre-formatted free text.
    Raw(String),
    /// A structured simulation event.
    Sim(SimEvent),
}

impl fmt::Display for TraceKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TraceKind::Raw(s) => f.write_str(s),
            TraceKind::Sim(ev) => write!(f, "{ev}"),
        }
    }
}

/// One trace entry.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    /// When it happened.
    pub at: Cycle,
    /// What happened.
    pub kind: TraceKind,
}

impl TraceEvent {
    /// The entry rendered as its (legacy-stable) trace line.
    pub fn what(&self) -> String {
        self.kind.to_string()
    }
}

/// Ring-buffer trace: keeps the most recent `cap` events.
#[derive(Clone, Debug)]
pub struct Trace {
    events: VecDeque<TraceEvent>,
    cap: usize,
    dropped: u64,
}

impl Trace {
    /// Trace keeping at most `cap` events.
    pub fn new(cap: usize) -> Self {
        Trace { events: VecDeque::with_capacity(cap.min(4096)), cap, dropped: 0 }
    }

    /// Disabled trace (drops everything) — zero-cost for big runs.
    pub fn disabled() -> Self {
        Trace::new(0)
    }

    /// Whether the trace retains anything at all.  Hot simulation loops
    /// consult this (or use [`Trace::log_with`]) so disabled traces pay
    /// neither the `format!` nor the call.
    #[inline]
    pub fn enabled(&self) -> bool {
        self.cap > 0
    }

    fn push(&mut self, at: Cycle, kind: TraceKind) {
        if self.events.len() == self.cap {
            self.events.pop_front();
            self.dropped += 1;
        }
        self.events.push_back(TraceEvent { at, kind });
    }

    /// Record a raw text event.
    pub fn log(&mut self, at: Cycle, what: impl Into<String>) {
        if self.cap == 0 {
            self.dropped += 1;
            return;
        }
        self.push(at, TraceKind::Raw(what.into()));
    }

    /// Record a structured event (no-op when disabled — the caller
    /// normally gates on [`Trace::enabled`] via [`crate::obs::note`],
    /// so a disabled trace never counts it as dropped).
    pub fn emit(&mut self, at: Cycle, ev: SimEvent) {
        if self.cap == 0 {
            return;
        }
        self.push(at, TraceKind::Sim(ev));
    }

    /// Record an event, rendering the message lazily: `what` runs only
    /// when the trace is enabled, so a [`Trace::disabled`] trace (the
    /// bench configuration) skips the string formatting entirely.
    /// Unlike [`Trace::log`], a disabled trace does not count the event
    /// as dropped — it was never materialized.
    #[inline]
    pub fn log_with<F, S>(&mut self, at: Cycle, what: F)
    where
        F: FnOnce() -> S,
        S: Into<String>,
    {
        if self.cap == 0 {
            return;
        }
        self.log(at, what());
    }

    /// Retained events, oldest first.
    pub fn events(&self) -> impl Iterator<Item = &TraceEvent> {
        self.events.iter()
    }

    /// Number of events dropped (capacity exceeded or disabled).
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Render with cycle → millisecond conversion.
    pub fn render(&self, core_clock_mhz: u32) -> String {
        let mut out = String::new();
        for e in &self.events {
            let ms = e.at as f64 / (core_clock_mhz as f64 * 1e3);
            out.push_str(&format!("[{ms:>10.3} ms] {}\n", e.kind));
        }
        if self.dropped > 0 {
            out.push_str(&format!("... ({} earlier events dropped)\n", self.dropped));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_buffer_keeps_latest() {
        let mut t = Trace::new(2);
        t.log(1, "a");
        t.log(2, "b");
        t.log(3, "c");
        let got: Vec<String> = t.events().map(|e| e.what()).collect();
        assert_eq!(got, vec!["b", "c"]);
        assert_eq!(t.dropped(), 1);
    }

    #[test]
    fn disabled_trace_drops_all() {
        let mut t = Trace::disabled();
        t.log(1, "x");
        assert_eq!(t.events().count(), 0);
        assert_eq!(t.dropped(), 1);
    }

    #[test]
    fn log_with_skips_closure_when_disabled() {
        let mut t = Trace::disabled();
        assert!(!t.enabled());
        let mut calls = 0u32;
        t.log_with(1, || {
            calls += 1;
            "x"
        });
        assert_eq!(calls, 0, "closure must not run on a disabled trace");
        assert_eq!(t.events().count(), 0);
        assert_eq!(t.dropped(), 0, "never-materialized events are not dropped");
    }

    #[test]
    fn log_with_logs_normally_when_enabled() {
        let mut t = Trace::new(2);
        assert!(t.enabled());
        t.log_with(1, || format!("a{}", 1));
        t.log_with(2, || "b");
        t.log_with(3, || "c");
        let got: Vec<String> = t.events().map(|e| e.what()).collect();
        assert_eq!(got, vec!["b", "c"]);
        assert_eq!(t.dropped(), 1, "ring overflow still counts as dropped");
    }

    #[test]
    fn structured_events_render_like_legacy_lines() {
        let mut t = Trace::new(4);
        t.emit(7, SimEvent::Arrive { shard: None, seq: 0, tenant: 3, app: "Harris" });
        t.log(9, "raw note");
        let got: Vec<String> = t.events().map(|e| e.what()).collect();
        assert_eq!(got, vec!["arrive seq=0 tenant=3 app=Harris", "raw note"]);
        // disabled emit is silent, mirroring log_with
        let mut d = Trace::disabled();
        d.emit(1, SimEvent::Frame { k: 0 });
        assert_eq!(d.dropped(), 0);
    }

    #[test]
    fn render_converts_to_ms() {
        let mut t = Trace::new(4);
        t.log(500_000, "tick");
        let s = t.render(500);
        assert!(s.contains("1.000 ms"), "{s}");
    }
}
