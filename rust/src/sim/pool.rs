//! Pool-scenario simulations: the cloud and autonomous drivers
//! generalized over a sharded [`crate::fabric::FabricPool`].
//!
//! These mirror [`super::cloud::run_cloud`] and
//! [`super::autonomous::run_edge`] event-for-event — same seeded RNG
//! streams, same event ordering, same trace line grammar — so a
//! single-shard pool reproduces the single-fabric simulations
//! bit-for-bit (the golden-equivalence property in
//! `tests/prop_pool.rs`).  Multi-shard pools add what a pool uniquely
//! has: placement routing, the per-shard admission window with `BUSY`
//! rejections, and cross-shard rescue defragmentation.

use std::collections::BTreeMap;

use crate::config::{
    CloudWorkloadConfig, Config, EdgeWorkloadConfig, PlacementPolicyKind, RegionPolicyKind,
    WorkloadConfig,
};
use crate::dpr::DprMode;
use crate::energy::EnergyReport;
use crate::error::{Error, Result};
use crate::fabric::{FabricPool, PoolCompletion, ShardId};
use crate::metrics::{FrameLatency, LatencyBreakdown, NtatRecord, NtatTracker, UtilizationTracker};
use crate::noc::NocReport;
use crate::obs::{self, NO_REQ, Obs, SimEvent};
use crate::qos::{QosReport, SloRecord, SloTracker};
use crate::regions::RegionId;
use crate::tasks::{AppId, AppRequest, TaskLibrary};
use crate::util::rng::Rng;

use super::autonomous::{dpr_mode_for, EVENT_APPS};
use super::cloud::{tenant_app_of, workload_library};
use super::engine::{Cycle, EventQueue};
use super::trace::Trace;

/// Per-shard slice of a pool simulation's results.
#[derive(Clone, Debug)]
pub struct ShardSimStats {
    /// Shard index.
    pub shard: u32,
    /// Task launches on this shard.
    pub launches: u64,
    /// Mean GLB busy fraction (final-state reading for idle pools).
    pub glb_utilization: f64,
    /// Mean array busy fraction.
    pub array_utilization: f64,
    /// Live migrations on this shard.
    pub migrations: u64,
    /// All-variants-NoFit events on this shard.
    pub nofit_events: u64,
    /// Joules this shard accumulated (0 when `[energy]` is off).
    pub energy_j: f64,
}

/// Result of one cloud-scenario pool run.
#[derive(Clone, Debug)]
pub struct PoolCloudReport {
    /// Shards in the pool.
    pub shards: u32,
    /// Placement policy the run used.
    pub placement: PlacementPolicyKind,
    /// Region mechanism the shards used.
    pub policy: RegionPolicyKind,
    /// Arrival-window length in cycles.
    pub duration_cycles: Cycle,
    /// Cycle the last request completed.
    pub makespan_cycles: Cycle,
    /// NTAT per request/app (pool-wide).
    pub ntat: NtatTracker,
    /// Mean pool-wide GLB busy fraction.
    pub glb_utilization: f64,
    /// Mean pool-wide array busy fraction.
    pub array_utilization: f64,
    /// Total task launches.
    pub launches: u64,
    /// Requests submitted (admitted).
    pub submitted: u64,
    /// Requests completed (== submitted after drain).
    pub completed: u64,
    /// Arrivals rejected `BUSY` (every shard at `pool.admission_window`).
    pub busy_rejections: u64,
    /// Cross-shard rescue compactions the pool ran.
    pub cross_shard_defrags: u64,
    /// Live migrations across the pool.
    pub migrations: u64,
    /// Launches rescued by per-shard defragmentation.
    pub rescued_launches: u64,
    /// All-variants-NoFit events across the pool.
    pub nofit_events: u64,
    /// Pool-wide energy accounting (`None` unless `[energy].enabled`).
    pub energy: Option<EnergyReport>,
    /// Pool-wide per-class SLO report (`None` unless `[qos].enabled`).
    pub qos: Option<QosReport>,
    /// Merged NoC contention report (`None` unless `[noc].enabled`).
    pub noc: Option<NocReport>,
    /// Per-shard breakdown.
    pub per_shard: Vec<ShardSimStats>,
}

impl PoolCloudReport {
    /// Mean NTAT across apps (same presentation as
    /// [`super::cloud::CloudReport::mean_ntat_across_apps`]).
    pub fn mean_ntat_across_apps(&self) -> f64 {
        let m = self.ntat.mean_ntat();
        if m.is_empty() {
            return 0.0;
        }
        m.values().sum::<f64>() / m.len() as f64
    }
}

/// Result of one autonomous-scenario pool run.
#[derive(Clone, Debug)]
pub struct PoolEdgeReport {
    /// Shards in the pool.
    pub shards: u32,
    /// Placement policy the run used.
    pub placement: PlacementPolicyKind,
    /// Region mechanism the shards used.
    pub policy: RegionPolicyKind,
    /// DPR mode the shards used.
    pub dpr_mode: DprMode,
    /// Per-frame latency breakdown (pool-wide).
    pub latency: LatencyBreakdown,
    /// Frames simulated.
    pub frames: u32,
    /// Frames whose *every* arrival was `BUSY`-rejected: no task of the
    /// frame ever ran, so it contributes no latency record —
    /// `latency.len() == frames - rejected_frames`.
    pub rejected_frames: u32,
    /// Frames where *some* arrivals were rejected but at least one ran:
    /// their latency records cover only the admitted subset, so under
    /// overload the headline latency is measured over degraded frames —
    /// this count makes that visible instead of silently flattering it.
    pub partial_frames: u32,
    /// Event-triggered requests.
    pub event_requests: u64,
    /// Arrivals rejected `BUSY`.
    pub busy_rejections: u64,
    /// Cross-shard rescue compactions.
    pub cross_shard_defrags: u64,
    /// Live migrations across the pool.
    pub migrations: u64,
    /// All-variants-NoFit events across the pool.
    pub nofit_events: u64,
    /// Pool-wide energy accounting (`None` unless `[energy].enabled`).
    pub energy: Option<EnergyReport>,
    /// Pool-wide per-class SLO report (`None` unless `[qos].enabled`).
    pub qos: Option<QosReport>,
    /// Merged NoC contention report (`None` unless `[noc].enabled`).
    pub noc: Option<NocReport>,
    /// Per-shard breakdown.
    pub per_shard: Vec<ShardSimStats>,
}

/// Events driving the cloud pool simulation.
#[derive(Clone, Debug)]
enum CloudEvent {
    /// Tenant `t` submits a request.
    Arrival(u32),
    /// The task on a shard's region finished.
    Completion(ShardId, RegionId),
}

/// Events driving the autonomous pool simulation.
#[derive(Clone, Debug)]
enum EdgeEvent {
    /// Start of frame `k`.
    Frame(u32),
    /// Task completion on a shard's region.
    Completion(ShardId, RegionId),
}

/// Collect per-shard stats at the end of a run.
fn per_shard_stats(pool: &FabricPool) -> Vec<ShardSimStats> {
    pool.snapshots()
        .into_iter()
        .map(|s| {
            let shard = ShardId(s.shard);
            let mig = pool
                .scheduler(shard)
                .map(|sch| sch.migration_stats())
                .unwrap_or_default();
            ShardSimStats {
                shard: s.shard,
                launches: s.launches,
                glb_utilization: s.glb_utilization,
                array_utilization: s.array_utilization,
                migrations: mig.tasks_migrated,
                nofit_events: mig.nofit_events,
                energy_j: s.energy_j,
            }
        })
        .collect()
}

/// Run the cloud scenario over a fabric pool configured by `cfg.pool`.
pub fn run_cloud_pool(cfg: &Config) -> Result<PoolCloudReport> {
    run_cloud_pool_traced(cfg, workload_library(cfg), &mut Trace::disabled())
}

/// [`run_cloud_pool`] with an explicit library and trace sink.
pub fn run_cloud_pool_traced(
    cfg: &Config,
    lib: TaskLibrary,
    trace: &mut Trace,
) -> Result<PoolCloudReport> {
    run_cloud_pool_observed(cfg, lib, trace, &mut Obs::disabled())
}

/// [`run_cloud_pool_traced`] with an observability context: structured
/// events feed the lifecycle journal (shard-tagged), and end-of-run
/// counters are exported into `obs.registry` with `shard` labels.
/// With [`Obs::disabled`] this is byte-identical to the traced run.
pub fn run_cloud_pool_observed(
    cfg: &Config,
    lib: TaskLibrary,
    trace: &mut Trace,
    obs: &mut Obs,
) -> Result<PoolCloudReport> {
    let wl: &CloudWorkloadConfig = match &cfg.workload {
        WorkloadConfig::Cloud(c) => c,
        WorkloadConfig::Edge(_) => {
            return Err(Error::Config("run_cloud_pool requires a cloud workload".into()))
        }
    };
    let mut pool = FabricPool::new(cfg, lib.clone(), DprMode::Fast)?;
    pool.preload_all();
    pool.set_obs(obs.on());
    pool.set_provenance(obs.provenance_on());
    // the `shard=` trace tag (and journal shard ids) appear on
    // multi-shard pools only, keeping single-shard traces byte-identical
    // to the single-fabric simulator's
    let multi = pool.shard_count() > 1;

    let cycles_per_ms = cfg.arch.core_clock_mhz as u64 * 1000;
    let duration: Cycle = (wl.duration_ms * cycles_per_ms as f64) as u64;

    let mut rng = Rng::new(wl.seed);
    let mut tenant_rngs: Vec<Rng> = (0..4).map(|t| rng.fork(t as u64 + 1)).collect();

    let mut events: EventQueue<CloudEvent> = EventQueue::new();
    for t in 0..4u32 {
        let dt_ms = tenant_rngs[t as usize].exponential(1.0 / wl.mean_interarrival_ms[t as usize]);
        events.push((dt_ms * cycles_per_ms as f64) as u64, CloudEvent::Arrival(t));
    }

    let mut seq = 0u64;
    let mut submitted = 0u64;
    let mut completed = 0u64;
    let mut launches = 0u64;

    // per-request accounting: seq → (app, arrival, serviced cycles)
    let mut inflight: BTreeMap<u64, (AppId, Cycle, u64)> = BTreeMap::new();

    let mut ntat = NtatTracker::new();
    let mut slo = SloTracker::new();
    let tat = obs.on().then(|| obs.registry.histogram("cgra_req_turnaround_cycles", &[]));
    let (total_glb, total_arr) = pool.total_slices();
    let mut glb_util = UtilizationTracker::new(total_glb);
    let mut arr_util = UtilizationTracker::new(total_arr);

    while let Some((now, ev)) = events.pop() {
        match ev {
            CloudEvent::Arrival(t) => {
                let app = tenant_app_of(wl, t);
                let req = AppRequest::new(seq, t, app, now).with_qos(
                    cfg.qos.class_of_tenant(t),
                    cfg.qos.deadline_of_tenant(t, now, cycles_per_ms),
                );
                match pool.try_submit(req, now) {
                    Some(shard) => {
                        inflight.insert(seq, (app, now, 0));
                        submitted += 1;
                        obs::note(trace, obs, now, shard.0, || SimEvent::Arrive {
                            shard: multi.then_some(shard.0),
                            seq,
                            tenant: t,
                            app: app.name(),
                        });
                    }
                    None => {
                        obs::note(trace, obs, now, 0, || SimEvent::Busy { seq, tenant: t });
                    }
                }
                seq += 1;
                let dt_ms =
                    tenant_rngs[t as usize].exponential(1.0 / wl.mean_interarrival_ms[t as usize]);
                let next = now + (dt_ms * cycles_per_ms as f64) as u64;
                if next < duration {
                    events.push(next, CloudEvent::Arrival(t));
                }
            }
            CloudEvent::Completion(shard, region) => {
                let done = match pool.drain_completion(shard, region, now)? {
                    // preempted: the region was released, the event is stale
                    PoolCompletion::Cancelled => continue,
                    // migrations push completions out; re-queue stale events
                    PoolCompletion::Stale(finish) => {
                        events.push(finish, CloudEvent::Completion(shard, region));
                        continue;
                    }
                    PoolCompletion::Done(done) => done,
                };
                if let Some(done) = done {
                    let (app, arrival, exec) = inflight.remove(&done.seq).ok_or_else(|| {
                        Error::SimInvariant(format!("request {} not inflight", done.seq))
                    })?;
                    completed += 1;
                    obs::note(trace, obs, now, shard.0, || {
                        SimEvent::Done { seq: done.seq, tenant: done.tenant }
                    });
                    if let Some(h) = &tat {
                        h.observe(now - arrival);
                    }
                    if cfg.qos.enabled {
                        slo.record(SloRecord {
                            class: done.class,
                            arrival,
                            completion: now,
                            deadline: done.deadline,
                        });
                    }
                    if let Some(wd) = obs.watchdog.as_mut() {
                        let rec = SloRecord {
                            class: done.class,
                            arrival,
                            completion: now,
                            deadline: done.deadline,
                        };
                        wd.record_completion(done.class, rec.missed());
                    }
                    ntat.record(NtatRecord {
                        app,
                        arrival,
                        completion: now,
                        exec_cycles: exec.max(1),
                    });
                }
            }
        }
        let step_launches = pool.schedule(now);
        for (shard, p) in pool.take_preemptions() {
            // un-run remainder re-accrues at resume: keep serviced
            // cycles (the NTAT denominator) honest
            if let Some(entry) = inflight.get_mut(&p.victim.request) {
                entry.2 = entry.2.saturating_sub(p.remaining_cycles);
            }
            obs::note(trace, obs, now, shard.0, || {
                SimEvent::Preempt { shard: multi.then_some(shard.0), rec: p }
            });
        }
        for (shard, launch) in step_launches {
            launches += 1;
            if let Some(entry) = inflight.get_mut(&launch.instance.request) {
                entry.2 += launch.dpr_cycles + launch.exec_cycles;
            }
            obs::note(trace, obs, now, shard.0, || SimEvent::Launch {
                shard: multi.then_some(shard.0),
                launch: launch.clone(),
            });
            events.push(launch.finish, CloudEvent::Completion(shard, launch.region));
        }
        if obs.on() {
            for (s, at, kind) in pool.take_obs_events() {
                obs.journal.stage(at, NO_REQ, s, kind);
            }
            if obs.provenance_on() {
                for d in pool.take_decisions() {
                    obs.record_decision(d);
                }
            }
        }
        let (busy_glb, busy_arr) = pool.busy_slices();
        glb_util.sample(now, busy_glb);
        arr_util.sample(now, busy_arr);
        let alerts = if let Some(wd) = obs.watchdog.as_mut() {
            for i in 0..pool.shard_count() {
                if let Some(sch) = pool.scheduler(ShardId(i as u32)) {
                    let (_, ua) = sch.regions().utilization();
                    wd.sample_util(i as u32, ua);
                    let watts = sch.energy().current_windowed_watts();
                    if watts > 0.0 {
                        wd.sample_power(i as u32, watts);
                    }
                }
            }
            wd.poll(now)
        } else {
            Vec::new()
        };
        for a in &alerts {
            obs.raise_alert(a);
        }
    }

    if pool.queue_open_requests() != 0 {
        return Err(Error::SimInvariant(format!(
            "{} requests never completed (deadlock?)",
            pool.queue_open_requests()
        )));
    }

    if obs.on() {
        let reg = &obs.registry;
        reg.set_counter("cgra_sim_submitted_total", &[], submitted);
        reg.set_counter("cgra_sim_completed_total", &[], completed);
        reg.set_counter("cgra_sched_launch_total", &[], launches);
        reg.set_counter("cgra_pool_busy_rejections_total", &[], pool.stats().busy_rejections);
        reg.set_counter("cgra_obs_journal_dropped_total", &[], obs.journal.dropped());
        pool.export_metrics(reg);
    }
    let mig = pool.migration_stats();
    let stats = pool.stats();
    let energy = pool.energy_report(glb_util.horizon());
    let qos = if cfg.qos.enabled { Some(slo.report(pool.qos_stats())) } else { None };
    Ok(PoolCloudReport {
        shards: pool.shard_count() as u32,
        placement: cfg.pool.placement,
        policy: cfg.scheduler.region_policy,
        duration_cycles: duration,
        makespan_cycles: glb_util.horizon(),
        ntat,
        glb_utilization: glb_util.mean(),
        array_utilization: arr_util.mean(),
        launches,
        submitted,
        completed,
        busy_rejections: stats.busy_rejections,
        cross_shard_defrags: stats.cross_shard_defrags,
        migrations: mig.tasks_migrated,
        rescued_launches: mig.rescued_launches,
        nofit_events: mig.nofit_events,
        energy,
        qos,
        noc: pool.noc_report(),
        per_shard: per_shard_stats(&pool),
    })
}

/// Run the autonomous scenario over a fabric pool configured by
/// `cfg.pool`.
pub fn run_edge_pool(cfg: &Config) -> Result<PoolEdgeReport> {
    run_edge_pool_traced(cfg, TaskLibrary::table1(), &mut Trace::disabled())
}

/// [`run_edge_pool`] with an explicit library and trace sink.
pub fn run_edge_pool_traced(
    cfg: &Config,
    lib: TaskLibrary,
    trace: &mut Trace,
) -> Result<PoolEdgeReport> {
    run_edge_pool_observed(cfg, lib, trace, &mut Obs::disabled())
}

/// [`run_edge_pool_traced`] with an observability context (see
/// [`run_cloud_pool_observed`] for the contract).
pub fn run_edge_pool_observed(
    cfg: &Config,
    lib: TaskLibrary,
    trace: &mut Trace,
    obs: &mut Obs,
) -> Result<PoolEdgeReport> {
    let wl: &EdgeWorkloadConfig = match &cfg.workload {
        WorkloadConfig::Edge(e) => e,
        WorkloadConfig::Cloud(_) => {
            return Err(Error::Config("run_edge_pool requires an edge workload".into()))
        }
    };
    let mode = dpr_mode_for(cfg.scheduler.region_policy);
    let mut pool = FabricPool::new(cfg, lib, mode)?;
    if mode == DprMode::Fast {
        pool.preload_all();
    }
    pool.set_obs(obs.on());
    pool.set_provenance(obs.provenance_on());
    let multi = pool.shard_count() > 1;

    let frame_cycles = (cfg.arch.core_clock_mhz as f64 * 1e6 / wl.fps) as u64;
    let cycles_per_ms = cfg.arch.core_clock_mhz as u64 * 1000;
    let mut rng = Rng::new(wl.seed);
    let (lo, hi) = wl.event_period_frames;
    let mut next_trigger: Vec<u32> = EVENT_APPS
        .iter()
        .map(|_| rng.range_inclusive(lo as u64, hi as u64) as u32)
        .collect();

    let mut events: EventQueue<EdgeEvent> = EventQueue::new();
    events.push(0, EdgeEvent::Frame(0));

    let mut seq = 0u64;
    let mut event_requests = 0u64;
    let mut rejected_frames = 0u32;
    let mut partial_frames = 0u32;

    // request seq → owning frame
    let mut frame_of: BTreeMap<u64, u32> = BTreeMap::new();
    // frame → (start cycle, open request count, reconfig cycles, last completion)
    let mut frames: BTreeMap<u32, (Cycle, u32, u64, Cycle)> = BTreeMap::new();

    let mut latency = LatencyBreakdown::new();
    let mut slo = SloTracker::new();
    let mut last_now = 0u64;

    while let Some((now, ev)) = events.pop() {
        last_now = now;
        match ev {
            EdgeEvent::Frame(k) => {
                frames.entry(k).or_insert((now, 0, 0, now));
                obs::note(trace, obs, now, 0, || SimEvent::Frame { k });
                // camera pipeline runs every frame, then the event streams
                let mut arrivals: Vec<(u32, AppId)> = vec![(2, AppId::Camera)];
                for (i, app) in EVENT_APPS.iter().enumerate() {
                    if next_trigger[i] == k {
                        arrivals.push((i as u32, *app));
                        event_requests += 1;
                        let step = rng.range_inclusive(lo as u64, hi as u64) as u32;
                        next_trigger[i] = k + step;
                    }
                }
                let mut rejected_in_frame = 0u32;
                for (tenant, app) in arrivals {
                    let req = AppRequest::new(seq, tenant, app, now).with_qos(
                        cfg.qos.class_of_tenant(tenant),
                        cfg.qos.deadline_of_tenant(tenant, now, cycles_per_ms),
                    );
                    match pool.try_submit(req, now) {
                        Some(shard) => {
                            frame_of.insert(seq, k);
                            frames.get_mut(&k).expect("inserted").1 += 1;
                            obs::note(trace, obs, now, shard.0, || SimEvent::ArriveFrame {
                                shard: multi.then_some(shard.0),
                                seq,
                                tenant,
                                frame: k,
                                app: app.name(),
                            });
                        }
                        None => {
                            rejected_in_frame += 1;
                            obs::note(trace, obs, now, 0, || {
                                SimEvent::BusyFrame { seq, frame: k }
                            });
                        }
                    }
                    seq += 1;
                }
                if rejected_in_frame > 0 {
                    if frames.get(&k).map(|e| e.1) == Some(0) {
                        // every arrival rejected: the entry would never
                        // see a completion — drop it now (instead of
                        // leaking it) and account the frame
                        frames.remove(&k);
                        rejected_frames += 1;
                        obs::note(trace, obs, now, 0, || SimEvent::FrameRejected { k });
                    } else {
                        // some tasks run: the frame completes, but its
                        // latency covers a degraded subset
                        partial_frames += 1;
                    }
                }
                if k + 1 < wl.frames {
                    events.push(now + frame_cycles, EdgeEvent::Frame(k + 1));
                }
            }
            EdgeEvent::Completion(shard, region) => {
                let done = match pool.drain_completion(shard, region, now)? {
                    // preempted: the region was released, the event is stale
                    PoolCompletion::Cancelled => continue,
                    // migrations push completions out; re-queue stale events
                    PoolCompletion::Stale(finish) => {
                        events.push(finish, EdgeEvent::Completion(shard, region));
                        continue;
                    }
                    PoolCompletion::Done(done) => done,
                };
                if let Some(done) = done {
                    if cfg.qos.enabled {
                        slo.record(SloRecord {
                            class: done.class,
                            arrival: done.arrival_cycle,
                            completion: now,
                            deadline: done.deadline,
                        });
                    }
                    if let Some(wd) = obs.watchdog.as_mut() {
                        let rec = SloRecord {
                            class: done.class,
                            arrival: done.arrival_cycle,
                            completion: now,
                            deadline: done.deadline,
                        };
                        wd.record_completion(done.class, rec.missed());
                    }
                    let k = frame_of.remove(&done.seq).ok_or_else(|| {
                        Error::SimInvariant(format!("request {} has no frame", done.seq))
                    })?;
                    let entry = frames.get_mut(&k).expect("frame exists");
                    entry.1 -= 1;
                    entry.3 = entry.3.max(now);
                    if entry.1 == 0 {
                        let (start, _, reconfig, last) = *entry;
                        frames.remove(&k);
                        let total = last - start;
                        obs::note(trace, obs, now, 0, || {
                            SimEvent::FrameDone { k, total, reconfig }
                        });
                        latency.record(FrameLatency {
                            reconfig_cycles: reconfig.min(total),
                            wait_exec_cycles: total.saturating_sub(reconfig),
                        });
                    }
                }
            }
        }
        let step_launches = pool.schedule(now);
        for (shard, p) in pool.take_preemptions() {
            obs::note(trace, obs, now, shard.0, || {
                SimEvent::Preempt { shard: multi.then_some(shard.0), rec: p }
            });
        }
        for (shard, launch) in step_launches {
            if let Some(&k) = frame_of.get(&launch.instance.request) {
                if let Some(entry) = frames.get_mut(&k) {
                    entry.2 += launch.dpr_cycles;
                }
            }
            obs::note(trace, obs, now, shard.0, || SimEvent::Launch {
                shard: multi.then_some(shard.0),
                launch: launch.clone(),
            });
            events.push(launch.finish, EdgeEvent::Completion(shard, launch.region));
        }
        if obs.on() {
            for (s, at, kind) in pool.take_obs_events() {
                obs.journal.stage(at, NO_REQ, s, kind);
            }
            if obs.provenance_on() {
                for d in pool.take_decisions() {
                    obs.record_decision(d);
                }
            }
        }
        let alerts = if let Some(wd) = obs.watchdog.as_mut() {
            for i in 0..pool.shard_count() {
                if let Some(sch) = pool.scheduler(ShardId(i as u32)) {
                    let (_, ua) = sch.regions().utilization();
                    wd.sample_util(i as u32, ua);
                    let watts = sch.energy().current_windowed_watts();
                    if watts > 0.0 {
                        wd.sample_power(i as u32, watts);
                    }
                }
            }
            wd.poll(now)
        } else {
            Vec::new()
        };
        for a in &alerts {
            obs.raise_alert(a);
        }
    }

    if pool.queue_open_requests() != 0 {
        return Err(Error::SimInvariant(format!(
            "{} requests never completed",
            pool.queue_open_requests()
        )));
    }

    if obs.on() {
        let reg = &obs.registry;
        reg.set_counter("cgra_sim_frames_total", &[], wl.frames as u64);
        reg.set_counter("cgra_sim_event_requests_total", &[], event_requests);
        reg.set_counter("cgra_pool_busy_rejections_total", &[], pool.stats().busy_rejections);
        let lat = reg.histogram("cgra_frame_latency_cycles", &[]);
        for f in latency.frames() {
            lat.observe(f.total());
        }
        reg.set_counter("cgra_obs_journal_dropped_total", &[], obs.journal.dropped());
        pool.export_metrics(reg);
    }

    let mig = pool.migration_stats();
    let stats = pool.stats();
    let energy = pool.energy_report(last_now);
    let qos = if cfg.qos.enabled { Some(slo.report(pool.qos_stats())) } else { None };
    Ok(PoolEdgeReport {
        shards: pool.shard_count() as u32,
        placement: cfg.pool.placement,
        policy: cfg.scheduler.region_policy,
        dpr_mode: mode,
        latency,
        frames: wl.frames,
        rejected_frames,
        partial_frames,
        event_requests,
        busy_rejections: stats.busy_rejections,
        cross_shard_defrags: stats.cross_shard_defrags,
        migrations: mig.tasks_migrated,
        nofit_events: mig.nofit_events,
        energy,
        qos,
        noc: pool.noc_report(),
        per_shard: per_shard_stats(&pool),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::sim::{run_cloud_traced, run_edge_traced};

    fn cloud_cfg(shards: u32) -> Config {
        let mut cfg = presets::pool_scenario(shards, PlacementPolicyKind::LeastLoaded);
        if let WorkloadConfig::Cloud(ref mut c) = cfg.workload {
            c.duration_ms = 400.0;
            c.seed = 17;
        }
        cfg
    }

    fn render(trace: &Trace) -> String {
        let mut out = String::new();
        for e in trace.events() {
            out.push_str(&format!("{} {}\n", e.at, e.what()));
        }
        out
    }

    #[test]
    fn single_shard_pool_matches_single_fabric_trace_and_report() {
        let cfg = cloud_cfg(1);
        let mut t_single = Trace::new(1 << 20);
        let single = run_cloud_traced(&cfg, TaskLibrary::table1(), &mut t_single).unwrap();
        let mut t_pool = Trace::new(1 << 20);
        let pooled = run_cloud_pool_traced(&cfg, TaskLibrary::table1(), &mut t_pool).unwrap();
        assert_eq!(render(&t_single), render(&t_pool), "traces must be byte-identical");
        assert_eq!(single.submitted, pooled.submitted);
        assert_eq!(single.completed, pooled.completed);
        assert_eq!(single.launches, pooled.launches);
        assert_eq!(single.makespan_cycles, pooled.makespan_cycles);
        assert!((single.mean_ntat_across_apps() - pooled.mean_ntat_across_apps()).abs() < 1e-12);
        assert_eq!(pooled.busy_rejections, 0);
        assert_eq!(pooled.cross_shard_defrags, 0);
    }

    #[test]
    fn two_shards_complete_the_same_offered_load_faster() {
        let one = run_cloud_pool(&cloud_cfg(1)).unwrap();
        let two = run_cloud_pool(&cloud_cfg(2)).unwrap();
        assert_eq!(one.submitted, two.submitted, "arrivals are seed-identical");
        assert_eq!(two.submitted, two.completed);
        assert!(
            two.mean_ntat_across_apps() <= one.mean_ntat_across_apps(),
            "2 shards {} vs 1 shard {}",
            two.mean_ntat_across_apps(),
            one.mean_ntat_across_apps()
        );
        assert_eq!(two.per_shard.len(), 2);
        assert!(two.per_shard.iter().all(|s| s.launches > 0), "both shards must serve");
    }

    #[test]
    fn admission_window_produces_busy_rejections_under_overload() {
        let mut cfg = cloud_cfg(1);
        cfg.pool.admission_window = 1;
        if let WorkloadConfig::Cloud(ref mut c) = cfg.workload {
            c.mean_interarrival_ms = [4.0, 4.0, 4.0, 4.0];
        }
        let r = run_cloud_pool(&cfg).unwrap();
        assert!(r.busy_rejections > 0, "overload must trip the window");
        assert_eq!(r.submitted, r.completed, "admitted requests still drain");
    }

    #[test]
    fn edge_pool_single_shard_matches_single_fabric() {
        let mut cfg = presets::edge_scenario(RegionPolicyKind::FlexibleShape);
        if let WorkloadConfig::Edge(ref mut e) = cfg.workload {
            e.frames = 90;
            e.seed = 23;
        }
        let mut t_single = Trace::new(1 << 20);
        let single = run_edge_traced(&cfg, TaskLibrary::table1(), &mut t_single).unwrap();
        let mut t_pool = Trace::new(1 << 20);
        let pooled = run_edge_pool_traced(&cfg, TaskLibrary::table1(), &mut t_pool).unwrap();
        assert_eq!(render(&t_single), render(&t_pool));
        assert_eq!(single.event_requests, pooled.event_requests);
        assert_eq!(single.latency.mean_total(), pooled.latency.mean_total());
        assert_eq!(single.frames, pooled.frames);
    }

    #[test]
    fn edge_pool_two_shards_runs_to_completion() {
        let mut cfg = presets::edge_scenario(RegionPolicyKind::FlexibleShape);
        cfg.pool.shards = 2;
        if let WorkloadConfig::Edge(ref mut e) = cfg.workload {
            e.frames = 90;
            e.seed = 23;
        }
        let r = run_edge_pool(&cfg).unwrap();
        assert_eq!(r.latency.len() as u32, r.frames);
        assert_eq!(r.shards, 2);
        assert_eq!(r.busy_rejections, 0);
        assert_eq!(r.rejected_frames, 0);
        assert_eq!(r.partial_frames, 0);
    }

    /// Frames arriving faster than tasks complete, under a 1-request
    /// window: fully rejected frames are dropped from the latency set
    /// and accounted, never leaked as forever-open entries.
    #[test]
    fn edge_pool_window_accounts_fully_rejected_frames() {
        let mut cfg = presets::edge_scenario(RegionPolicyKind::FlexibleShape);
        cfg.pool.admission_window = 1;
        if let WorkloadConfig::Edge(ref mut e) = cfg.workload {
            // 10 kHz frames (50 k cycles apart) vs ~10^5-cycle camera
            // tasks: the single admission slot stays busy across frames
            e.fps = 10_000.0;
            e.frames = 60;
            e.seed = 23;
        }
        let r = run_edge_pool(&cfg).unwrap();
        assert!(r.busy_rejections > 0, "overload must trip the window");
        assert!(r.rejected_frames > 0, "some frames must be fully rejected");
        assert_eq!(
            r.latency.len() as u32 + r.rejected_frames,
            r.frames,
            "every frame is either measured or accounted as rejected"
        );
        assert!(
            r.partial_frames <= r.latency.len() as u32,
            "degraded frames are a subset of the measured ones"
        );
    }

    #[test]
    fn wrong_workload_kind_rejected() {
        let cloud = cloud_cfg(1);
        assert!(run_edge_pool(&cloud).is_err());
        let edge = presets::edge_scenario(RegionPolicyKind::FlexibleShape);
        assert!(run_cloud_pool(&edge).is_err());
    }
}
