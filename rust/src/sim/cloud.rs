//! Cloud scenario simulation (paper §3.1, Fig. 3a / Fig. 4).
//!
//! Four tenants — ResNet-18, MobileNet, camera pipeline, Harris — submit
//! requests as independent Poisson processes.  The scheduler is
//! triggered on every arrival and completion; NTAT and throughput are
//! collected per application.

use std::collections::BTreeMap;

use crate::config::{CloudWorkloadConfig, Config, RegionPolicyKind, WorkloadConfig};
use crate::dpr::{CacheStats, DprMode};
use crate::energy::EnergyReport;
use crate::error::{Error, Result};
use crate::metrics::{
    FragmentationTracker, NtatRecord, NtatTracker, ThroughputTracker, UtilizationTracker,
};
use crate::noc::NocReport;
use crate::obs::{self, NO_REQ, Obs, SimEvent};
use crate::qos::{QosReport, SloRecord, SloTracker};
use crate::regions::RegionId;
use crate::scheduler::{CompletionOutcome, RequestQueue, Scheduler};
use crate::tasks::{AppGraph, AppId, AppRequest, TaskLibrary};
use crate::util::rng::Rng;

use super::engine::{Cycle, EventQueue};
use super::trace::Trace;

/// Events driving the cloud simulation.
#[derive(Clone, Debug)]
enum Event {
    /// Tenant `t` submits a request.
    Arrival(u32),
    /// The task on `region` finished.
    Completion(RegionId),
}

/// Result of one cloud run.
#[derive(Clone, Debug)]
pub struct CloudReport {
    /// Mechanism the run used.
    pub policy: RegionPolicyKind,
    /// Arrival-window length in cycles.
    pub duration_cycles: Cycle,
    /// Cycle the last request completed.
    pub makespan_cycles: Cycle,
    /// NTAT per request/app.
    pub ntat: NtatTracker,
    /// Throughput per app.
    pub throughput: ThroughputTracker,
    /// Mean GLB-slice utilization.
    pub glb_utilization: f64,
    /// Mean array-slice utilization.
    pub array_utilization: f64,
    /// DPR cache counters.
    pub dpr_stats: CacheStats,
    /// Total task launches.
    pub launches: u64,
    /// Requests submitted / completed.
    pub submitted: u64,
    /// Requests completed (== submitted after drain).
    pub completed: u64,
    /// Time-weighted mean (glb, array) external fragmentation.
    pub frag: (f64, f64),
    /// Schedule attempts where a ready task's every variant was `NoFit`.
    pub nofit_events: u64,
    /// Live migrations performed by the defragmentation subsystem.
    pub migrations: u64,
    /// Total cycles charged for those migrations.
    pub migration_cycles: u64,
    /// Launches that only succeeded because a compaction ran first.
    pub rescued_launches: u64,
    /// Energy accounting (`None` unless `[energy].enabled`).
    pub energy: Option<EnergyReport>,
    /// Per-class SLO report (`None` unless `[qos].enabled`).
    pub qos: Option<QosReport>,
    /// NoC contention report (`None` unless `[noc].enabled`).
    pub noc: Option<NocReport>,
}

impl CloudReport {
    /// Mean NTAT across apps (arithmetic mean of per-app means, matching
    /// the paper's per-application presentation).
    pub fn mean_ntat_across_apps(&self) -> f64 {
        let m = self.ntat.mean_ntat();
        if m.is_empty() {
            return 0.0;
        }
        m.values().sum::<f64>() / m.len() as f64
    }
}

/// Tenant → application assignment (Fig. 3a).
pub fn tenant_app(tenant: u32) -> AppId {
    AppId::ALL[tenant as usize % 4]
}

/// Tenant → application under a workload's optional
/// `workload.tenant_apps` override (the streaming-pipeline presets);
/// the Fig. 3a set otherwise.
pub fn tenant_app_of(wl: &CloudWorkloadConfig, tenant: u32) -> AppId {
    match &wl.tenant_apps {
        Some(apps) => apps[tenant as usize % 4],
        None => tenant_app(tenant),
    }
}

/// Task library the configured workload needs: Table 1, extended with
/// the demosaic stage when any tenant submits [`AppId::Pipeline`].
pub fn workload_library(cfg: &Config) -> TaskLibrary {
    let pipeline = matches!(
        &cfg.workload,
        WorkloadConfig::Cloud(c)
            if c.tenant_apps.is_some_and(|apps| apps.contains(&AppId::Pipeline))
    );
    if pipeline {
        TaskLibrary::table1_pipeline()
    } else {
        TaskLibrary::table1()
    }
}

/// Run the cloud scenario under `cfg`.
///
/// All mechanisms use fast-DPR here — Fig. 4 isolates the region
/// mechanisms; Fig. 5 is where the DPR paths are compared.
pub fn run_cloud(cfg: &Config) -> Result<CloudReport> {
    run_cloud_with(cfg, workload_library(cfg))
}

/// [`run_cloud`] with an explicit task library (ablations re-quantize
/// Table 1 demands for non-default slice geometries).
pub fn run_cloud_with(cfg: &Config, lib: TaskLibrary) -> Result<CloudReport> {
    run_cloud_traced(cfg, lib, &mut Trace::disabled())
}

/// [`run_cloud_with`] recording every arrival, launch and request
/// completion into `trace` — the determinism-regression and
/// pool-golden-equivalence tests compare these traces byte-for-byte
/// (same line grammar as [`super::pool::run_cloud_pool_traced`], which
/// omits the `shard=` tag on single-shard pools exactly so the traces
/// stay comparable).
pub fn run_cloud_traced(cfg: &Config, lib: TaskLibrary, trace: &mut Trace) -> Result<CloudReport> {
    run_cloud_observed(cfg, lib, trace, &mut Obs::disabled())
}

/// [`run_cloud_traced`] with an observability context: every structured
/// event additionally feeds the lifecycle journal, and end-of-run
/// counters are exported into `obs.registry`.  With [`Obs::disabled`]
/// this is byte-identical to the plain traced run (the differential
/// goldens pin that equivalence).
pub fn run_cloud_observed(
    cfg: &Config,
    lib: TaskLibrary,
    trace: &mut Trace,
    obs: &mut Obs,
) -> Result<CloudReport> {
    let wl: &CloudWorkloadConfig = match &cfg.workload {
        WorkloadConfig::Cloud(c) => c,
        WorkloadConfig::Edge(_) => {
            return Err(Error::Config("run_cloud requires a cloud workload".into()))
        }
    };
    let mut sched = Scheduler::new(cfg, lib.clone(), DprMode::Fast);
    sched.preload_all();
    sched.set_obs(obs.on());
    sched.set_provenance(obs.provenance_on());

    let cycles_per_ms = cfg.arch.core_clock_mhz as u64 * 1000;
    let duration: Cycle = (wl.duration_ms * cycles_per_ms as f64) as u64;

    let mut rng = Rng::new(wl.seed);
    let mut tenant_rngs: Vec<Rng> = (0..4).map(|t| rng.fork(t as u64 + 1)).collect();

    let mut events: EventQueue<Event> = EventQueue::new();
    // initial arrivals
    for t in 0..4u32 {
        let dt_ms = tenant_rngs[t as usize].exponential(1.0 / wl.mean_interarrival_ms[t as usize]);
        events.push((dt_ms * cycles_per_ms as f64) as u64, Event::Arrival(t));
    }

    let mut queue = RequestQueue::new();
    let mut seq = 0u64;
    let mut submitted = 0u64;
    let mut completed = 0u64;
    let mut launches = 0u64;

    // per-request accounting: seq → (app, arrival, serviced cycles)
    let mut inflight: BTreeMap<u64, (AppId, Cycle, u64)> = BTreeMap::new();
    // app → total work per request (sum of its task works), over the
    // apps the tenants actually submit (the map collapses duplicates)
    let app_work: BTreeMap<AppId, u64> = (0..4u32)
        .map(|t| tenant_app_of(wl, t))
        .map(|app| {
            let g = AppGraph::of(app);
            let w = g
                .nodes
                .iter()
                .map(|t| lib.get(t).expect("library resolves workload tasks").work)
                .sum();
            (app, w)
        })
        .collect();

    let mut ntat = NtatTracker::new();
    let mut tput = ThroughputTracker::new();
    let mut glb_util = UtilizationTracker::new(cfg.arch.glb_slices());
    let mut arr_util = UtilizationTracker::new(cfg.arch.array_slices());
    let mut frag = FragmentationTracker::new();
    let mut slo = SloTracker::new();
    let tat = obs.on().then(|| obs.registry.histogram("cgra_req_turnaround_cycles", &[]));

    while let Some((now, ev)) = events.pop() {
        match ev {
            Event::Arrival(t) => {
                // admit the request (class/deadline resolve to
                // BestEffort/None while `[qos]` is disabled)
                let app = tenant_app_of(wl, t);
                queue.submit(AppRequest::new(seq, t, app, now).with_qos(
                    cfg.qos.class_of_tenant(t),
                    cfg.qos.deadline_of_tenant(t, now, cycles_per_ms),
                ));
                inflight.insert(seq, (app, now, 0));
                obs::note(trace, obs, now, 0, || {
                    SimEvent::Arrive { shard: None, seq, tenant: t, app: app.name() }
                });
                seq += 1;
                submitted += 1;
                // next arrival for this tenant, within the window
                let dt_ms =
                    tenant_rngs[t as usize].exponential(1.0 / wl.mean_interarrival_ms[t as usize]);
                let next = now + (dt_ms * cycles_per_ms as f64) as u64;
                if next < duration {
                    events.push(next, Event::Arrival(t));
                }
            }
            Event::Completion(region) => {
                // Single-pass drain: consume a preemption's cancellation
                // marker, re-queue migration-stale events at their
                // authoritative finish, or commit the completion.
                let inst = match sched.drain_completion(region, now)? {
                    CompletionOutcome::Cancelled => continue,
                    CompletionOutcome::Stale(finish) => {
                        events.push(finish, Event::Completion(region));
                        continue;
                    }
                    CompletionOutcome::Done(inst) => inst,
                };
                if let Some(done) = queue.mark_complete(inst, now)? {
                    let (app, arrival, exec) =
                        inflight.remove(&done.seq).ok_or_else(|| {
                            Error::SimInvariant(format!("request {} not inflight", done.seq))
                        })?;
                    completed += 1;
                    obs::note(trace, obs, now, 0, || {
                        SimEvent::Done { seq: done.seq, tenant: done.tenant }
                    });
                    if let Some(h) = &tat {
                        h.observe(now - arrival);
                    }
                    if cfg.qos.enabled {
                        slo.record(SloRecord {
                            class: done.class,
                            arrival,
                            completion: now,
                            deadline: done.deadline,
                        });
                    }
                    if let Some(wd) = obs.watchdog.as_mut() {
                        let rec = SloRecord {
                            class: done.class,
                            arrival,
                            completion: now,
                            deadline: done.deadline,
                        };
                        wd.record_completion(done.class, rec.missed());
                    }
                    ntat.record(NtatRecord {
                        app,
                        arrival,
                        completion: now,
                        exec_cycles: exec.max(1),
                    });
                    tput.record(app, app_work[&app], (now - arrival).max(1));
                }
            }
        }
        // scheduler is triggered on every arrival/completion (§3.1)
        let step_launches = sched.schedule(&mut queue, now);
        for p in sched.take_preemptions() {
            // the victim's un-run remainder re-accrues at resume; take
            // it back out so serviced cycles (the NTAT denominator)
            // count real service, not the evicted window twice
            if let Some(entry) = inflight.get_mut(&p.victim.request) {
                entry.2 = entry.2.saturating_sub(p.remaining_cycles);
            }
            obs::note(trace, obs, now, 0, || SimEvent::Preempt { shard: None, rec: p });
        }
        for launch in step_launches {
            launches += 1;
            if let Some(entry) = inflight.get_mut(&launch.instance.request) {
                entry.2 += launch.dpr_cycles + launch.exec_cycles;
            }
            obs::note(trace, obs, now, 0, || {
                SimEvent::Launch { shard: None, launch: launch.clone() }
            });
            events.push(launch.finish, Event::Completion(launch.region));
        }
        if obs.on() {
            for (at, kind) in sched.take_obs_events() {
                obs.journal.stage(at, NO_REQ, 0, kind);
            }
            if obs.provenance_on() {
                for d in sched.take_decisions() {
                    obs.record_decision(d);
                }
            }
        }
        // utilization/fragmentation are piecewise-constant between events
        let (ug, ua) = sched.regions().utilization();
        glb_util.sample(now, (ug * cfg.arch.glb_slices() as f64).round() as u32);
        arr_util.sample(now, (ua * cfg.arch.array_slices() as f64).round() as u32);
        frag.sample(now, sched.regions().fragmentation());
        let alerts = if let Some(wd) = obs.watchdog.as_mut() {
            wd.sample_util(0, ua);
            let watts = sched.energy().current_windowed_watts();
            if watts > 0.0 {
                wd.sample_power(0, watts);
            }
            wd.poll(now)
        } else {
            Vec::new()
        };
        for a in &alerts {
            obs.raise_alert(a);
        }
    }

    if queue.open_requests() != 0 {
        return Err(Error::SimInvariant(format!(
            "{} requests never completed (deadlock?)",
            queue.open_requests()
        )));
    }

    debug_assert_eq!(sched.checkpointed_count(), 0, "drained run leaves no checkpoints");
    if obs.on() {
        let reg = &obs.registry;
        reg.set_counter("cgra_sim_submitted_total", &[], submitted);
        reg.set_counter("cgra_sim_completed_total", &[], completed);
        reg.set_counter("cgra_sched_launch_total", &[], launches);
        reg.set_gauge("cgra_glb_utilization", &[], glb_util.mean());
        reg.set_gauge("cgra_array_utilization", &[], arr_util.mean());
        reg.set_counter("cgra_obs_journal_dropped_total", &[], obs.journal.dropped());
        sched.export_metrics(reg, None);
    }
    let mig = sched.migration_stats();
    let energy = sched.energy_report(glb_util.horizon());
    let qos = if cfg.qos.enabled { Some(slo.report(sched.qos_stats())) } else { None };
    let noc = sched.noc_report();
    Ok(CloudReport {
        policy: cfg.scheduler.region_policy,
        duration_cycles: duration,
        makespan_cycles: glb_util.horizon(),
        ntat,
        throughput: tput,
        glb_utilization: glb_util.mean(),
        array_utilization: arr_util.mean(),
        dpr_stats: sched.dpr().cache().stats(),
        launches,
        submitted,
        completed,
        frag: frag.mean(),
        nofit_events: mig.nofit_events,
        migrations: mig.tasks_migrated,
        migration_cycles: mig.migration_cycles,
        rescued_launches: mig.rescued_launches,
        energy,
        qos,
        noc,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn quick_cfg(policy: RegionPolicyKind) -> Config {
        let mut cfg = presets::cloud_scenario(policy);
        if let WorkloadConfig::Cloud(ref mut c) = cfg.workload {
            c.duration_ms = 500.0;
            c.seed = 7;
        }
        cfg
    }

    #[test]
    fn runs_to_completion_all_mechanisms() {
        for policy in RegionPolicyKind::ALL {
            let report = run_cloud(&quick_cfg(policy)).unwrap();
            assert_eq!(report.submitted, report.completed, "{policy:?}");
            assert!(report.launches >= report.completed, "{policy:?}");
            assert!(report.mean_ntat_across_apps() >= 1.0, "{policy:?}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run_cloud(&quick_cfg(RegionPolicyKind::FlexibleShape)).unwrap();
        let b = run_cloud(&quick_cfg(RegionPolicyKind::FlexibleShape)).unwrap();
        assert_eq!(a.submitted, b.submitted);
        assert_eq!(a.makespan_cycles, b.makespan_cycles);
        assert!((a.mean_ntat_across_apps() - b.mean_ntat_across_apps()).abs() < 1e-12);
    }

    #[test]
    fn flexible_beats_baseline_on_ntat() {
        // The paper's headline: flexible-shape lowers NTAT 23–28 % below
        // baseline.  At minimum the ordering must hold on this seed.
        let base = run_cloud(&quick_cfg(RegionPolicyKind::Baseline)).unwrap();
        let flex = run_cloud(&quick_cfg(RegionPolicyKind::FlexibleShape)).unwrap();
        assert!(
            flex.mean_ntat_across_apps() < base.mean_ntat_across_apps(),
            "flexible {} vs baseline {}",
            flex.mean_ntat_across_apps(),
            base.mean_ntat_across_apps()
        );
    }

    #[test]
    fn utilization_higher_under_flexible() {
        let base = run_cloud(&quick_cfg(RegionPolicyKind::Baseline)).unwrap();
        let flex = run_cloud(&quick_cfg(RegionPolicyKind::FlexibleShape)).unwrap();
        assert!(flex.array_utilization > 0.0);
        // baseline holds the whole machine per task: slice-level busy
        // fraction is *high* but useful work is low; flexible packs
        // multiple tasks, so makespan shrinks.
        assert!(flex.makespan_cycles <= base.makespan_cycles);
    }

    #[test]
    fn edge_config_rejected() {
        let cfg = presets::edge_scenario(RegionPolicyKind::Baseline);
        assert!(run_cloud(&cfg).is_err());
    }

    // ------------------------------------------------- churn + migration

    use crate::config::DefragPolicyKind;

    #[test]
    fn churn_with_defrag_completes_and_migrates() {
        let cfg =
            presets::churn_scenario(RegionPolicyKind::FlexibleShape, DefragPolicyKind::Greedy);
        let r = run_cloud(&cfg).unwrap();
        assert_eq!(r.submitted, r.completed, "churn must drain fully");
        assert!(r.nofit_events > 0, "past-saturation load must pressure the allocator");
        assert!(r.migrations > 0, "churn fragmentation must trigger migrations");
        assert!(r.migration_cycles > 0);
        assert!(r.rescued_launches > 0);
        assert!((0.0..=1.0).contains(&r.frag.0) && (0.0..=1.0).contains(&r.frag.1));
    }

    #[test]
    fn churn_without_defrag_never_migrates() {
        let cfg = presets::churn_scenario(RegionPolicyKind::FlexibleShape, DefragPolicyKind::Off);
        let r = run_cloud(&cfg).unwrap();
        assert_eq!(r.submitted, r.completed);
        assert_eq!(r.migrations, 0);
        assert_eq!(r.rescued_launches, 0);
        assert!(r.nofit_events > 0);
    }

    // --------------------------------------------------------------- noc

    #[test]
    fn pipeline_tenants_drain_with_noc_accounting() {
        let mut cfg = quick_cfg(RegionPolicyKind::FlexibleShape);
        cfg.noc.enabled = true;
        if let WorkloadConfig::Cloud(ref mut c) = cfg.workload {
            c.tenant_apps =
                Some([AppId::Pipeline, AppId::Camera, AppId::Pipeline, AppId::Harris]);
        }
        // `run_cloud` resolves the pipeline-capable library on its own
        let r = run_cloud(&cfg).unwrap();
        assert_eq!(r.submitted, r.completed);
        let noc = r.noc.expect("noc enabled yields a report");
        assert!(noc.streams_placed > 0);
        assert!(noc.stream_in_cycles > 0, "pipeline stages must stage frames");
        assert!(noc.mean_slowdown >= 1.0);
    }

    #[test]
    fn tenant_apps_override_without_noc_still_drains() {
        // the workload override is usable on its own: no [noc] switch,
        // no report, but Pipeline requests resolve and complete
        let mut cfg = quick_cfg(RegionPolicyKind::FlexibleShape);
        if let WorkloadConfig::Cloud(ref mut c) = cfg.workload {
            c.tenant_apps =
                Some([AppId::Pipeline, AppId::Pipeline, AppId::Camera, AppId::Harris]);
        }
        let r = run_cloud(&cfg).unwrap();
        assert_eq!(r.submitted, r.completed);
        assert!(r.noc.is_none());
    }

    #[test]
    fn churn_deterministic_given_seed() {
        let cfg =
            presets::churn_scenario(RegionPolicyKind::FlexibleShape, DefragPolicyKind::CostAware);
        let a = run_cloud(&cfg).unwrap();
        let b = run_cloud(&cfg).unwrap();
        assert_eq!(a.makespan_cycles, b.makespan_cycles);
        assert_eq!(a.migrations, b.migrations);
        assert_eq!(a.nofit_events, b.nofit_events);
        assert_eq!(a.frag, b.frag);
    }
}
