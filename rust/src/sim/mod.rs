//! Discrete-event simulation of the CGRA under multi-tasked workloads.
//!
//! The timing model operates at slice granularity (see DESIGN.md
//! substitution table): task execution time = Table 1 work / throughput,
//! DPR cost from [`crate::dpr`], resource contention from
//! [`crate::regions`].  Two scenario drivers reproduce the paper's
//! evaluation: [`cloud`] (§3.1, Fig. 4) and [`autonomous`] (§3.2, Fig. 5);
//! [`pool`] generalizes both over a sharded [`crate::fabric::FabricPool`]
//! (single-shard pools are bit-for-bit equivalent to the plain drivers).

pub mod autonomous;
pub mod cloud;
mod engine;
pub mod pool;
pub mod queueing;
pub mod trace;

pub use autonomous::{run_edge, run_edge_observed, run_edge_traced, run_edge_with, EdgeReport};
pub use cloud::{run_cloud, run_cloud_observed, run_cloud_traced, run_cloud_with, CloudReport};
pub use engine::{Cycle, EventQueue};
pub use pool::{
    run_cloud_pool, run_cloud_pool_observed, run_cloud_pool_traced, run_edge_pool,
    run_edge_pool_observed, run_edge_pool_traced, PoolCloudReport, PoolEdgeReport, ShardSimStats,
};
pub use trace::{Trace, TraceEvent, TraceKind};
