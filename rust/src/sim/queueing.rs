//! Analytic queueing cross-check for the DES.
//!
//! At light load the baseline CGRA is an M/G/1 queue: Poisson arrivals
//! (the four tenants' superposition), a single server (the whole array),
//! and general service times (the mix of app execution times).  The
//! Pollaczek–Khinchine formula then predicts the mean wait exactly, so
//! the simulator can be *validated* against closed-form theory — a test
//! no amount of unit testing provides.
//!
//!   W = λ·E[S²] / (2·(1 − ρ)),  ρ = λ·E[S]
//!
//! The integration test `sim::queueing::tests::des_matches_mg1` drives
//! the DES at a load where the model's assumptions hold (single-task
//! baseline, no DPR cost, exponential arrivals) and checks the measured
//! mean wait against the prediction within Monte-Carlo tolerance.

/// M/G/1 mean waiting time (Pollaczek–Khinchine), in the same time unit
/// as the inputs.  `lambda` = total arrival rate, `s_mean`/`s2_mean` =
/// first and second moments of service time.
pub fn mg1_mean_wait(lambda: f64, s_mean: f64, s2_mean: f64) -> f64 {
    assert!(lambda > 0.0 && s_mean > 0.0 && s2_mean >= s_mean * s_mean);
    let rho = lambda * s_mean;
    assert!(rho < 1.0, "M/G/1 requires utilization < 1, got {rho}");
    lambda * s2_mean / (2.0 * (1.0 - rho))
}

/// Utilization of the single server.
pub fn mg1_utilization(lambda: f64, s_mean: f64) -> f64 {
    lambda * s_mean
}

/// Service moments of a discrete service-time mix `(prob, time)`.
pub fn service_moments(mix: &[(f64, f64)]) -> (f64, f64) {
    let total_p: f64 = mix.iter().map(|(p, _)| p).sum();
    assert!((total_p - 1.0).abs() < 1e-9, "probabilities must sum to 1");
    let m1 = mix.iter().map(|(p, s)| p * s).sum();
    let m2 = mix.iter().map(|(p, s)| p * s * s).sum();
    (m1, m2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets, RegionPolicyKind, WorkloadConfig};
    use crate::sim::run_cloud;
    use crate::tasks::{AppGraph, AppId, TaskLibrary};

    #[test]
    fn pk_formula_sanity() {
        // M/M/1 special case: E[S²] = 2/µ² ⇒ W = ρ/(µ−λ)
        let (lambda, mu) = (0.5, 1.0);
        let w = mg1_mean_wait(lambda, 1.0 / mu, 2.0 / (mu * mu));
        let expect = lambda / (mu * (mu - lambda));
        assert!((w - expect).abs() < 1e-12);
    }

    #[test]
    fn moments_of_mix() {
        let (m1, m2) = service_moments(&[(0.5, 2.0), (0.5, 4.0)]);
        assert_eq!(m1, 3.0);
        assert_eq!(m2, 10.0);
    }

    #[test]
    #[should_panic]
    fn saturated_queue_rejected() {
        mg1_mean_wait(1.0, 2.0, 8.0);
    }

    /// The DES validation: baseline CGRA at light load is M/G/1.
    #[test]
    fn des_matches_mg1() {
        // Arrange identical mean inter-arrival T for all 4 tenants so the
        // superposed process is Poisson with λ = 4/T.
        let t_ms = 60.0;
        let mut cfg = presets::cloud_scenario(RegionPolicyKind::Baseline);
        if let WorkloadConfig::Cloud(ref mut c) = cfg.workload {
            c.mean_interarrival_ms = [t_ms; 4];
            c.duration_ms = 60_000.0; // long run for tight confidence
            c.seed = 2027;
        }

        // Service time per app under the baseline: the whole app chain's
        // exec at its fastest variants (greedy, whole machine), plus the
        // (preloaded fast-DPR) reconfig per task — a few µs, negligible
        // but included for exactness.
        let lib = TaskLibrary::table1();
        let cycles_per_ms = 500_000.0;
        let service_ms = |app: AppId| -> f64 {
            AppGraph::of(app)
                .nodes
                .iter()
                .map(|tid| {
                    let t = lib.get(tid).unwrap();
                    t.exec_cycles(t.fastest()) as f64 / cycles_per_ms
                })
                .sum::<f64>()
        };
        let mix: Vec<(f64, f64)> = AppId::ALL.iter().map(|&a| (0.25, service_ms(a))).collect();
        let (s1, s2) = service_moments(&mix);
        let lambda = 4.0 / t_ms; // requests per ms
        let predicted_wait_ms = mg1_mean_wait(lambda, s1, s2);

        let report = run_cloud(&cfg).unwrap();
        // measured mean wait = mean(TAT − exec) over all requests
        let mean_wait_ms = report
            .ntat
            .records()
            .iter()
            .map(|r| (r.tat() - r.exec_cycles) as f64 / cycles_per_ms)
            .sum::<f64>()
            / report.ntat.records().len() as f64;

        let rel_err = (mean_wait_ms - predicted_wait_ms).abs() / predicted_wait_ms;
        assert!(
            rel_err < 0.15,
            "DES wait {mean_wait_ms:.3} ms vs M/G/1 {predicted_wait_ms:.3} ms (err {:.1}%)",
            rel_err * 100.0
        );

        // utilization should match ρ as well
        let rho = mg1_utilization(lambda, s1);
        // baseline holds the whole machine while serving: busy fraction
        // of the array equals ρ (modulo drain-window edge effects).
        assert!(
            (report.array_utilization - rho).abs() < 0.05,
            "util {} vs rho {rho}",
            report.array_utilization
        );
    }
}
