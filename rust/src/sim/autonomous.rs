//! Autonomous-system scenario (paper §3.2, Fig. 3b / Fig. 5).
//!
//! A camera feeds RAW frames at 30 fps; every frame runs the camera
//! pipeline, and event streams (following [30]'s methodology) trigger
//! additional applications with a uniform 3–7-frame period per event
//! type.  The baseline CGRA maps one task at a time and reconfigures
//! over AXI4-Lite; the partitioned mechanisms run tasks concurrently and
//! use fast-DPR (Fig. 5's caption).

use std::collections::BTreeMap;

use crate::config::{Config, EdgeWorkloadConfig, RegionPolicyKind, WorkloadConfig};
use crate::dpr::{CacheStats, DprMode};
use crate::energy::EnergyReport;
use crate::error::{Error, Result};
use crate::metrics::{FrameLatency, LatencyBreakdown};
use crate::obs::{self, NO_REQ, Obs, SimEvent};
use crate::qos::{QosReport, SloRecord, SloTracker};
use crate::regions::RegionId;
use crate::scheduler::{CompletionOutcome, RequestQueue, Scheduler};
use crate::tasks::{AppId, AppRequest, TaskLibrary};
use crate::util::rng::Rng;

use super::engine::{Cycle, EventQueue};
use super::trace::Trace;

/// Event-triggered applications: Harris (e.g. feature tracking on a
/// detected object) and MobileNet (e.g. classification of a detected
/// region).  The paper simplified its task set similarly (§3.2 fn. 2).
pub const EVENT_APPS: [AppId; 2] = [AppId::Harris, AppId::MobileNet];

#[derive(Clone, Debug)]
enum Event {
    /// Start of frame `k`.
    Frame(u32),
    /// Task completion on a region.
    Completion(RegionId),
}

/// Result of one autonomous run.
#[derive(Clone, Debug)]
pub struct EdgeReport {
    /// Mechanism the run used.
    pub policy: RegionPolicyKind,
    /// DPR mode the run used.
    pub dpr_mode: DprMode,
    /// Per-frame latency breakdown (Fig. 5 bars).
    pub latency: LatencyBreakdown,
    /// DPR cache counters.
    pub dpr_stats: CacheStats,
    /// Frames simulated.
    pub frames: u32,
    /// Total event-triggered requests.
    pub event_requests: u64,
    /// Schedule attempts where a ready task's every variant was `NoFit`.
    pub nofit_events: u64,
    /// Live migrations performed by the defragmentation subsystem.
    pub migrations: u64,
    /// Total cycles charged for those migrations.
    pub migration_cycles: u64,
    /// Energy accounting (`None` unless `[energy].enabled`).
    pub energy: Option<EnergyReport>,
    /// Per-class SLO report (`None` unless `[qos].enabled`).
    pub qos: Option<QosReport>,
}

impl EdgeReport {
    /// Mean frame latency in milliseconds.
    pub fn mean_latency_ms(&self, core_clock_mhz: u32) -> f64 {
        self.latency.mean_total() / (core_clock_mhz as f64 * 1e3)
    }

    /// p50 frame latency in milliseconds (Fig. 5 companion tails).
    pub fn p50_latency_ms(&self, core_clock_mhz: u32) -> f64 {
        self.latency.p50_total() / (core_clock_mhz as f64 * 1e3)
    }

    /// p95 frame latency in milliseconds.
    pub fn p95_latency_ms(&self, core_clock_mhz: u32) -> f64 {
        self.latency.p95_total() / (core_clock_mhz as f64 * 1e3)
    }

    /// p99 frame latency in milliseconds.
    pub fn p99_latency_ms(&self, core_clock_mhz: u32) -> f64 {
        self.latency.p99_total() / (core_clock_mhz as f64 * 1e3)
    }
}

/// DPR mode Fig. 5 assigns to each mechanism: AXI4-Lite for the
/// baseline, fast-DPR for every partitioned mechanism.
pub fn dpr_mode_for(policy: RegionPolicyKind) -> DprMode {
    match policy {
        RegionPolicyKind::Baseline => DprMode::Axi4Lite,
        _ => DprMode::Fast,
    }
}

/// Run the autonomous scenario under `cfg`.
pub fn run_edge(cfg: &Config) -> Result<EdgeReport> {
    run_edge_with(cfg, TaskLibrary::table1())
}

/// [`run_edge`] with an explicit task library (used by ablations).
pub fn run_edge_with(cfg: &Config, lib: TaskLibrary) -> Result<EdgeReport> {
    run_edge_traced(cfg, lib, &mut Trace::disabled())
}

/// [`run_edge_with`] recording frames, arrivals, launches and frame
/// completions into `trace` (same line grammar as
/// [`super::pool::run_edge_pool_traced`] on a single-shard pool — the
/// determinism and golden-equivalence tests diff the rendered traces).
pub fn run_edge_traced(cfg: &Config, lib: TaskLibrary, trace: &mut Trace) -> Result<EdgeReport> {
    run_edge_observed(cfg, lib, trace, &mut Obs::disabled())
}

/// [`run_edge_traced`] with an observability context: structured events
/// additionally feed the lifecycle journal, and end-of-run counters are
/// exported into `obs.registry`.  With [`Obs::disabled`] this is
/// byte-identical to the plain traced run.
pub fn run_edge_observed(
    cfg: &Config,
    lib: TaskLibrary,
    trace: &mut Trace,
    obs: &mut Obs,
) -> Result<EdgeReport> {
    let wl: &EdgeWorkloadConfig = match &cfg.workload {
        WorkloadConfig::Edge(e) => e,
        WorkloadConfig::Cloud(_) => {
            return Err(Error::Config("run_edge requires an edge workload".into()))
        }
    };
    let mode = dpr_mode_for(cfg.scheduler.region_policy);
    let mut sched = Scheduler::new(cfg, lib, mode);
    if mode == DprMode::Fast {
        sched.preload_all();
    }
    sched.set_obs(obs.on());
    sched.set_provenance(obs.provenance_on());

    let frame_cycles = (cfg.arch.core_clock_mhz as f64 * 1e6 / wl.fps) as u64;
    let cycles_per_ms = cfg.arch.core_clock_mhz as u64 * 1000;
    let mut rng = Rng::new(wl.seed);
    // next trigger frame per event stream
    let (lo, hi) = wl.event_period_frames;
    let mut next_trigger: Vec<u32> = EVENT_APPS
        .iter()
        .map(|_| rng.range_inclusive(lo as u64, hi as u64) as u32)
        .collect();

    let mut events: EventQueue<Event> = EventQueue::new();
    events.push(0, Event::Frame(0));

    let mut queue = RequestQueue::new();
    let mut seq = 0u64;
    let mut event_requests = 0u64;

    // request seq → owning frame
    let mut frame_of: BTreeMap<u64, u32> = BTreeMap::new();
    // frame → (start cycle, open request count, reconfig cycles, last completion)
    let mut frames: BTreeMap<u32, (Cycle, u32, u64, Cycle)> = BTreeMap::new();

    let mut latency = LatencyBreakdown::new();
    let mut slo = SloTracker::new();
    let mut last_now = 0u64;

    while let Some((now, ev)) = events.pop() {
        last_now = now;
        match ev {
            Event::Frame(k) => {
                let entry = frames.entry(k).or_insert((now, 0, 0, now));
                obs::note(trace, obs, now, 0, || SimEvent::Frame { k });
                // camera pipeline runs every frame
                queue.submit(AppRequest::new(seq, 2, AppId::Camera, now).with_qos(
                    cfg.qos.class_of_tenant(2),
                    cfg.qos.deadline_of_tenant(2, now, cycles_per_ms),
                ));
                frame_of.insert(seq, k);
                entry.1 += 1;
                obs::note(trace, obs, now, 0, || {
                    let app = AppId::Camera.name();
                    SimEvent::ArriveFrame { shard: None, seq, tenant: 2, frame: k, app }
                });
                seq += 1;
                // event streams
                for (i, app) in EVENT_APPS.iter().enumerate() {
                    if next_trigger[i] == k {
                        queue.submit(AppRequest::new(seq, i as u32, *app, now).with_qos(
                            cfg.qos.class_of_tenant(i as u32),
                            cfg.qos.deadline_of_tenant(i as u32, now, cycles_per_ms),
                        ));
                        frame_of.insert(seq, k);
                        frames.get_mut(&k).expect("inserted").1 += 1;
                        obs::note(trace, obs, now, 0, || SimEvent::ArriveFrame {
                            shard: None,
                            seq,
                            tenant: i as u32,
                            frame: k,
                            app: app.name(),
                        });
                        seq += 1;
                        event_requests += 1;
                        let step = rng.range_inclusive(lo as u64, hi as u64) as u32;
                        next_trigger[i] = k + step;
                    }
                }
                if k + 1 < wl.frames {
                    events.push(now + frame_cycles, Event::Frame(k + 1));
                }
            }
            Event::Completion(region) => {
                // Single-pass drain: consume a preemption's cancellation
                // marker, re-queue migration-stale events at their
                // authoritative finish, or commit the completion.
                let inst = match sched.drain_completion(region, now)? {
                    CompletionOutcome::Cancelled => continue,
                    CompletionOutcome::Stale(finish) => {
                        events.push(finish, Event::Completion(region));
                        continue;
                    }
                    CompletionOutcome::Done(inst) => inst,
                };
                if let Some(done) = queue.mark_complete(inst, now)? {
                    if cfg.qos.enabled {
                        slo.record(SloRecord {
                            class: done.class,
                            arrival: done.arrival_cycle,
                            completion: now,
                            deadline: done.deadline,
                        });
                    }
                    if let Some(wd) = obs.watchdog.as_mut() {
                        let rec = SloRecord {
                            class: done.class,
                            arrival: done.arrival_cycle,
                            completion: now,
                            deadline: done.deadline,
                        };
                        wd.record_completion(done.class, rec.missed());
                    }
                    let k = frame_of.remove(&done.seq).ok_or_else(|| {
                        Error::SimInvariant(format!("request {} has no frame", done.seq))
                    })?;
                    let entry = frames.get_mut(&k).expect("frame exists");
                    entry.1 -= 1;
                    entry.3 = entry.3.max(now);
                    if entry.1 == 0 {
                        // frame complete: record its latency breakdown
                        let (start, _, reconfig, last) = *entry;
                        frames.remove(&k);
                        let total = last - start;
                        obs::note(trace, obs, now, 0, || {
                            SimEvent::FrameDone { k, total, reconfig }
                        });
                        latency.record(FrameLatency {
                            reconfig_cycles: reconfig.min(total),
                            wait_exec_cycles: total.saturating_sub(reconfig),
                        });
                    }
                }
            }
        }
        let step_launches = sched.schedule(&mut queue, now);
        for p in sched.take_preemptions() {
            obs::note(trace, obs, now, 0, || SimEvent::Preempt { shard: None, rec: p });
        }
        for launch in step_launches {
            if let Some(&k) = frame_of.get(&launch.instance.request) {
                if let Some(entry) = frames.get_mut(&k) {
                    entry.2 += launch.dpr_cycles;
                }
            }
            obs::note(trace, obs, now, 0, || {
                SimEvent::Launch { shard: None, launch: launch.clone() }
            });
            events.push(launch.finish, Event::Completion(launch.region));
        }
        if obs.on() {
            for (at, kind) in sched.take_obs_events() {
                obs.journal.stage(at, NO_REQ, 0, kind);
            }
            if obs.provenance_on() {
                for d in sched.take_decisions() {
                    obs.record_decision(d);
                }
            }
        }
        let alerts = if let Some(wd) = obs.watchdog.as_mut() {
            let (_, ua) = sched.regions().utilization();
            wd.sample_util(0, ua);
            let watts = sched.energy().current_windowed_watts();
            if watts > 0.0 {
                wd.sample_power(0, watts);
            }
            wd.poll(now)
        } else {
            Vec::new()
        };
        for a in &alerts {
            obs.raise_alert(a);
        }
    }

    if queue.open_requests() != 0 {
        return Err(Error::SimInvariant(format!(
            "{} requests never completed",
            queue.open_requests()
        )));
    }

    debug_assert_eq!(sched.checkpointed_count(), 0, "drained run leaves no checkpoints");
    if obs.on() {
        let reg = &obs.registry;
        reg.set_counter("cgra_sim_frames_total", &[], wl.frames as u64);
        reg.set_counter("cgra_sim_event_requests_total", &[], event_requests);
        let lat = reg.histogram("cgra_frame_latency_cycles", &[]);
        for f in latency.frames() {
            lat.observe(f.total());
        }
        reg.set_counter("cgra_obs_journal_dropped_total", &[], obs.journal.dropped());
        sched.export_metrics(reg, None);
    }
    let mig = sched.migration_stats();
    let energy = sched.energy_report(last_now);
    let qos = if cfg.qos.enabled { Some(slo.report(sched.qos_stats())) } else { None };
    Ok(EdgeReport {
        policy: cfg.scheduler.region_policy,
        dpr_mode: mode,
        latency,
        dpr_stats: sched.dpr().cache().stats(),
        frames: wl.frames,
        event_requests,
        nofit_events: mig.nofit_events,
        migrations: mig.tasks_migrated,
        migration_cycles: mig.migration_cycles,
        energy,
        qos,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn quick_cfg(policy: RegionPolicyKind) -> Config {
        let mut cfg = presets::edge_scenario(policy);
        if let WorkloadConfig::Edge(ref mut e) = cfg.workload {
            e.frames = 120;
            e.seed = 11;
        }
        cfg
    }

    #[test]
    fn runs_all_mechanisms() {
        for policy in RegionPolicyKind::ALL {
            let r = run_edge(&quick_cfg(policy)).unwrap();
            assert_eq!(r.latency.len() as u32, r.frames, "{policy:?}");
            assert!(r.event_requests > 0, "{policy:?}");
        }
    }

    #[test]
    fn baseline_uses_axi_and_pays_for_it() {
        let base = run_edge(&quick_cfg(RegionPolicyKind::Baseline)).unwrap();
        let flex = run_edge(&quick_cfg(RegionPolicyKind::FlexibleShape)).unwrap();
        assert_eq!(base.dpr_mode, DprMode::Axi4Lite);
        assert_eq!(flex.dpr_mode, DprMode::Fast);
        // the paper's Fig. 5 shape: flexible+fast-DPR cuts mean latency
        assert!(
            flex.latency.mean_total() < base.latency.mean_total(),
            "flex {} vs base {}",
            flex.latency.mean_total(),
            base.latency.mean_total()
        );
        // reconfig share drops from double digits to <5 %
        assert!(flex.latency.reconfig_share() < base.latency.reconfig_share());
        assert!(flex.latency.reconfig_share() < 0.05, "{}", flex.latency.reconfig_share());
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run_edge(&quick_cfg(RegionPolicyKind::VariableSize)).unwrap();
        let b = run_edge(&quick_cfg(RegionPolicyKind::VariableSize)).unwrap();
        assert_eq!(a.latency.mean_total(), b.latency.mean_total());
        assert_eq!(a.event_requests, b.event_requests);
    }

    #[test]
    fn cloud_config_rejected() {
        let cfg = presets::cloud_scenario(RegionPolicyKind::Baseline);
        assert!(run_edge(&cfg).is_err());
    }

    #[test]
    fn edge_churn_with_defrag_completes() {
        use crate::config::DefragPolicyKind;
        let mut cfg = presets::edge_churn_scenario(
            RegionPolicyKind::FlexibleShape,
            DefragPolicyKind::CostAware,
        );
        if let WorkloadConfig::Edge(ref mut e) = cfg.workload {
            e.frames = 240;
            e.seed = 13;
        }
        let r = run_edge(&cfg).unwrap();
        assert_eq!(r.latency.len() as u32, r.frames);
        assert!(r.event_requests > 0);
        // every event stream fires nearly every frame: more concurrent
        // tasks than the relaxed schedule
        let relaxed = run_edge(&quick_cfg(RegionPolicyKind::FlexibleShape)).unwrap();
        assert!(
            r.event_requests * relaxed.frames as u64
                > relaxed.event_requests * r.frames as u64,
            "churn {}/{} vs relaxed {}/{}",
            r.event_requests,
            r.frames,
            relaxed.event_requests,
            relaxed.frames
        );
        // defrag machinery ran consistently (counters are coherent even
        // when the light edge load never fragments)
        assert!(r.migrations == 0 || r.migration_cycles > 0);
    }
}
