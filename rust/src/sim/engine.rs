//! Generic discrete-event queue.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Simulation time in core-clock cycles.
pub type Cycle = u64;

struct Entry<E> {
    at: Cycle,
    seq: u64,
    event: E,
}

// Min-heap by (at, seq): earliest first; seq breaks ties FIFO.
impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed for BinaryHeap max-heap → min-heap behaviour
        (other.at, other.seq).cmp(&(self.at, self.seq))
    }
}

/// Time-ordered event queue with FIFO tie-breaking.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
    now: Cycle,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    /// Empty queue at cycle 0.
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), seq: 0, now: 0 }
    }

    /// Schedule `event` at absolute cycle `at` (must not precede now).
    pub fn push(&mut self, at: Cycle, event: E) {
        debug_assert!(at >= self.now, "scheduling into the past: {at} < {}", self.now);
        self.heap.push(Entry { at, seq: self.seq, event });
        self.seq += 1;
    }

    /// Pop the earliest event, advancing the clock to it.
    pub fn pop(&mut self) -> Option<(Cycle, E)> {
        let entry = self.heap.pop()?;
        self.now = entry.at;
        Some((entry.at, entry.event))
    }

    /// Time of the next event, if any.
    pub fn peek_time(&self) -> Option<Cycle> {
        self.heap.peek().map(|e| e.at)
    }

    /// Current simulation time (time of the last popped event).
    pub fn now(&self) -> Cycle {
        self.now
    }

    /// Pending event count.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Whether no events remain.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(30, "c");
        q.push(10, "a");
        q.push(20, "b");
        assert_eq!(q.pop(), Some((10, "a")));
        assert_eq!(q.pop(), Some((20, "b")));
        assert_eq!(q.now(), 20);
        assert_eq!(q.pop(), Some((30, "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn ties_break_fifo() {
        let mut q = EventQueue::new();
        q.push(5, 1);
        q.push(5, 2);
        q.push(5, 3);
        assert_eq!(q.pop().unwrap().1, 1);
        assert_eq!(q.pop().unwrap().1, 2);
        assert_eq!(q.pop().unwrap().1, 3);
    }

    #[test]
    fn peek_does_not_advance() {
        let mut q = EventQueue::new();
        q.push(7, ());
        assert_eq!(q.peek_time(), Some(7));
        assert_eq!(q.now(), 0);
        assert_eq!(q.len(), 1);
        q.pop();
        assert!(q.is_empty());
    }
}
