//! Slice identities, contiguous ranges, and the physical slice map.

use std::fmt;

use crate::config::ArchConfig;

/// Identifier of one GLB-slice (== one GLB bank, paper §2.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct GlbSliceId(pub u32);

/// Identifier of one array-slice (== `slice_cols` adjacent columns).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ArraySliceId(pub u32);

impl fmt::Display for GlbSliceId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "G{}", self.0)
    }
}

impl fmt::Display for ArraySliceId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "A{}", self.0)
    }
}

/// A contiguous, half-open range of slice indices `[start, start+len)`.
///
/// The paper limits execution regions to contiguous slice placements
/// (§2.3 "we limit the placement … to be contiguous to simplify our
/// study"); `SliceRange` encodes that constraint in the type.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct SliceRange {
    /// First slice index.
    pub start: u32,
    /// Number of slices.
    pub len: u32,
}

impl SliceRange {
    /// New range (may be empty).
    pub fn new(start: u32, len: u32) -> Self {
        SliceRange { start, len }
    }

    /// Empty range at origin.
    pub fn empty() -> Self {
        SliceRange { start: 0, len: 0 }
    }

    /// Whether the range holds no slices.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// One-past-the-end index.
    pub fn end(&self) -> u32 {
        self.start + self.len
    }

    /// Whether `idx` lies inside.
    pub fn contains(&self, idx: u32) -> bool {
        idx >= self.start && idx < self.end()
    }

    /// Whether two ranges share any slice.
    pub fn overlaps(&self, other: &SliceRange) -> bool {
        !self.is_empty()
            && !other.is_empty()
            && self.start < other.end()
            && other.start < self.end()
    }

    /// Iterate contained indices.
    pub fn iter(&self) -> impl Iterator<Item = u32> {
        self.start..self.end()
    }
}

impl fmt::Display for SliceRange {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.is_empty() {
            write!(f, "[∅]")
        } else {
            write!(f, "[{}..{})", self.start, self.end())
        }
    }
}

/// Occupancy tracker for one slice class (GLB or array).
///
/// This is the "simplified and quantized view of hardware resources"
/// (§2.3) the scheduler sees: a bitmap of free/busy slices with
/// contiguous-run queries.
///
/// Alongside the bitmap, the map incrementally maintains the canonical
/// free-run list (sorted, maximal, coalesced) and the free-slice count,
/// updated on every [`occupy`](SliceMap::occupy)/
/// [`release`](SliceMap::release) instead of being recomputed per
/// query.  All run queries (`find_free_run*`, `longest_free_run`,
/// `free_runs`, `free_count`, `fragmentation`) read the index; the
/// bitmap stays authoritative for `range_free`, `render`, and the
/// debug-mode consistency oracle (`tests/prop_simperf.rs` checks the
/// index against a from-scratch bitmap recompute under random
/// occupy/release sequences).
#[derive(Clone, Debug)]
pub struct SliceMap {
    busy: Vec<bool>,
    /// Maximal free runs, sorted by start — the incrementally
    /// maintained index.
    runs: Vec<SliceRange>,
    /// Free slice count (== sum of `runs` lengths).
    free: u32,
}

impl SliceMap {
    /// All-free map of `n` slices.
    pub fn new(n: u32) -> Self {
        let runs = if n > 0 { vec![SliceRange::new(0, n)] } else { Vec::new() };
        SliceMap { busy: vec![false; n as usize], runs, free: n }
    }

    /// Total slice count.
    pub fn len(&self) -> u32 {
        self.busy.len() as u32
    }

    /// Whether the map has zero slices.
    pub fn is_empty(&self) -> bool {
        self.busy.is_empty()
    }

    /// Free slice count.
    pub fn free_count(&self) -> u32 {
        self.free
    }

    /// Busy slice count.
    pub fn busy_count(&self) -> u32 {
        self.len() - self.free_count()
    }

    /// Whether every slice in `range` is free.
    pub fn range_free(&self, range: &SliceRange) -> bool {
        range.end() <= self.len() && range.iter().all(|i| !self.busy[i as usize])
    }

    /// Find the leftmost free contiguous run of length `len`.
    pub fn find_free_run(&self, len: u32) -> Option<SliceRange> {
        self.find_free_run_from(0, len)
    }

    /// Find the leftmost free run of length `len` starting at or after
    /// `from` (used to co-locate GLB slices near their array slices).
    pub fn find_free_run_from(&self, from: u32, len: u32) -> Option<SliceRange> {
        if len == 0 {
            return Some(SliceRange::new(from.min(self.len()), 0));
        }
        for r in &self.runs {
            if r.end() <= from {
                continue;
            }
            let start = r.start.max(from);
            if start + len <= r.end() {
                return Some(SliceRange::new(start, len));
            }
        }
        None
    }

    /// Longest free contiguous run anywhere (leftmost on ties).
    pub fn longest_free_run(&self) -> SliceRange {
        let mut best = SliceRange::empty();
        for r in &self.runs {
            if r.len > best.len {
                best = *r;
            }
        }
        best
    }

    /// Mark `range` busy. Panics (debug) if any slice was already busy —
    /// double-allocation is a scheduler bug, not a recoverable state.
    pub fn occupy(&mut self, range: &SliceRange) {
        debug_assert!(self.range_free(range), "double-occupancy of {range}");
        if range.is_empty() {
            return;
        }
        for i in range.iter() {
            self.busy[i as usize] = true;
        }
        // A contiguous all-free range lies inside exactly one maximal
        // free run: split it around the newly busy span.
        let idx = self.runs.partition_point(|r| r.start <= range.start) - 1;
        let run = self.runs[idx];
        debug_assert!(run.start <= range.start && range.end() <= run.end());
        let left = SliceRange::new(run.start, range.start - run.start);
        let right = SliceRange::new(range.end(), run.end() - range.end());
        match (left.is_empty(), right.is_empty()) {
            (true, true) => {
                self.runs.remove(idx);
            }
            (false, true) => self.runs[idx] = left,
            (true, false) => self.runs[idx] = right,
            (false, false) => {
                self.runs[idx] = left;
                self.runs.insert(idx + 1, right);
            }
        }
        self.free -= range.len;
        self.debug_check_index();
    }

    /// Mark `range` free.
    pub fn release(&mut self, range: &SliceRange) {
        for i in range.iter() {
            debug_assert!(self.busy[i as usize], "double-release of slice {i}");
            self.busy[i as usize] = false;
        }
        if range.is_empty() {
            return;
        }
        // Insert the freed span, coalescing with adjacent runs so the
        // list stays maximal.
        let idx = self.runs.partition_point(|r| r.start < range.start);
        let mut merged = *range;
        if idx > 0 && self.runs[idx - 1].end() == merged.start {
            let left = self.runs.remove(idx - 1);
            merged = SliceRange::new(left.start, left.len + merged.len);
            // removal shifted the right neighbour down to idx - 1
            if idx - 1 < self.runs.len() && self.runs[idx - 1].start == merged.end() {
                let right = self.runs.remove(idx - 1);
                merged = SliceRange::new(merged.start, merged.len + right.len);
            }
            self.runs.insert(idx - 1, merged);
        } else {
            if idx < self.runs.len() && self.runs[idx].start == merged.end() {
                let right = self.runs.remove(idx);
                merged = SliceRange::new(merged.start, merged.len + right.len);
            }
            self.runs.insert(idx, merged);
        }
        self.free += range.len;
        self.debug_check_index();
    }

    /// Canonical free list: every maximal free run, left to right.
    ///
    /// Runs are maximal by construction (adjacent free slices always
    /// merge into one range), so this is the coalesced view the
    /// defragmentation planner ([`crate::migration`]) works from.
    pub fn free_runs(&self) -> Vec<SliceRange> {
        self.runs.clone()
    }

    /// Borrowed view of the free-run index (no allocation) — the hot
    /// path for power-gating and fragmentation sampling.
    pub fn free_runs_ref(&self) -> &[SliceRange] {
        &self.runs
    }

    /// External fragmentation in `[0, 1]`: 1 − longest-free-run / free.
    /// Zero when all free slices are contiguous (or none are free).
    pub fn fragmentation(&self) -> f64 {
        let free = self.free_count();
        if free == 0 {
            return 0.0;
        }
        1.0 - self.longest_free_run().len as f64 / free as f64
    }

    /// Debug-mode oracle: the incremental index must always equal a
    /// from-scratch recompute over the bitmap.
    #[inline]
    fn debug_check_index(&self) {
        #[cfg(debug_assertions)]
        {
            let mut scan = Vec::new();
            let mut start: Option<u32> = None;
            for i in 0..self.len() {
                if !self.busy[i as usize] {
                    if start.is_none() {
                        start = Some(i);
                    }
                } else if let Some(s) = start.take() {
                    scan.push(SliceRange::new(s, i - s));
                }
            }
            if let Some(s) = start {
                scan.push(SliceRange::new(s, self.len() - s));
            }
            debug_assert_eq!(self.runs, scan, "free-run index diverged from bitmap");
            debug_assert_eq!(
                self.free,
                scan.iter().map(|r| r.len).sum::<u32>(),
                "free counter diverged from bitmap"
            );
        }
    }

    /// Render as `.`/`#` occupancy string (trace output, Fig. 2 dumps).
    pub fn render(&self) -> String {
        self.busy.iter().map(|&b| if b { '#' } else { '.' }).collect()
    }
}

/// Build the two slice maps from an architecture description.
pub fn maps_for(arch: &ArchConfig) -> (SliceMap, SliceMap) {
    (SliceMap::new(arch.glb_slices()), SliceMap::new(arch.array_slices()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn range_basics() {
        let r = SliceRange::new(2, 3);
        assert_eq!(r.end(), 5);
        assert!(r.contains(2) && r.contains(4) && !r.contains(5));
        assert_eq!(r.iter().collect::<Vec<_>>(), vec![2, 3, 4]);
        assert_eq!(r.to_string(), "[2..5)");
        assert!(SliceRange::empty().is_empty());
    }

    #[test]
    fn range_overlap() {
        let a = SliceRange::new(0, 4);
        assert!(a.overlaps(&SliceRange::new(3, 2)));
        assert!(!a.overlaps(&SliceRange::new(4, 2)));
        assert!(!a.overlaps(&SliceRange::empty()));
    }

    #[test]
    fn occupy_release_cycle() {
        let mut m = SliceMap::new(8);
        let r = SliceRange::new(2, 3);
        assert!(m.range_free(&r));
        m.occupy(&r);
        assert_eq!(m.busy_count(), 3);
        assert!(!m.range_free(&r));
        m.release(&r);
        assert_eq!(m.free_count(), 8);
    }

    #[test]
    fn find_free_run_skips_busy() {
        let mut m = SliceMap::new(8);
        m.occupy(&SliceRange::new(0, 2)); // ##......
        m.occupy(&SliceRange::new(4, 1)); // ##..#...
        assert_eq!(m.find_free_run(2), Some(SliceRange::new(2, 2)));
        assert_eq!(m.find_free_run(3), Some(SliceRange::new(5, 3)));
        assert_eq!(m.find_free_run(4), None);
    }

    #[test]
    fn find_free_run_from_offset() {
        let m = SliceMap::new(8);
        assert_eq!(m.find_free_run_from(3, 2), Some(SliceRange::new(3, 2)));
        assert_eq!(m.find_free_run_from(7, 2), None);
    }

    #[test]
    fn zero_len_run_is_empty_range() {
        let m = SliceMap::new(4);
        let r = m.find_free_run(0).unwrap();
        assert!(r.is_empty());
    }

    #[test]
    fn longest_free_run_and_fragmentation() {
        let mut m = SliceMap::new(8);
        assert_eq!(m.longest_free_run(), SliceRange::new(0, 8));
        assert_eq!(m.fragmentation(), 0.0);
        m.occupy(&SliceRange::new(3, 1)); // ...#....
        assert_eq!(m.longest_free_run(), SliceRange::new(4, 4));
        let frag = m.fragmentation();
        assert!((frag - (1.0 - 4.0 / 7.0)).abs() < 1e-12);
    }

    #[test]
    fn render_shows_occupancy() {
        let mut m = SliceMap::new(4);
        m.occupy(&SliceRange::new(1, 2));
        assert_eq!(m.render(), ".##.");
    }

    #[test]
    fn free_runs_are_maximal_and_canonical() {
        let mut m = SliceMap::new(8);
        assert_eq!(m.free_runs(), vec![SliceRange::new(0, 8)]);
        m.occupy(&SliceRange::new(2, 2)); // ..##....
        m.occupy(&SliceRange::new(6, 1)); // ..##..#.
        assert_eq!(
            m.free_runs(),
            vec![SliceRange::new(0, 2), SliceRange::new(4, 2), SliceRange::new(7, 1)]
        );
        // releasing in two adjacent halves still yields one merged run
        m.release(&SliceRange::new(2, 1));
        m.release(&SliceRange::new(3, 1));
        assert_eq!(m.free_runs(), vec![SliceRange::new(0, 6), SliceRange::new(7, 1)]);
        let fully_busy = {
            let mut b = SliceMap::new(2);
            b.occupy(&SliceRange::new(0, 2));
            b
        };
        assert!(fully_busy.free_runs().is_empty());
    }

    #[test]
    fn maps_for_paper_arch() {
        let (glb, arr) = maps_for(&ArchConfig::default());
        assert_eq!(glb.len(), 32);
        assert_eq!(arr.len(), 8);
    }
}
