//! The paper's scheduler-visible hardware abstraction (§2.2).
//!
//! The three key resources — GLB memory capacity, GLB memory bandwidth,
//! and tile-array compute — are quantized into homogeneous **GLB-slices**
//! (one per GLB bank) and **array-slices** (one per `slice_cols` columns
//! of the tile array).  Slices are the *only* currency the compiler and
//! scheduler trade in: the compiler expresses a task variant's footprint
//! as a [`SliceDemand`], and the scheduler allocates [`SliceRange`]s of
//! the physical [`SliceMap`].
//!
//! A fourth resource — interconnect bandwidth — is tracked at corridor
//! granularity by [`CorridorMap`] (see `corridor` module docs): unlike
//! slices it never blocks placement, but oversubscribed corridors slow
//! the streams that share them.

mod corridor;
mod resource;
mod slice;

pub use corridor::{CorridorMap, CorridorSpan};
pub use resource::{RawUsage, SliceDemand};
pub use slice::{maps_for, ArraySliceId, GlbSliceId, SliceMap, SliceRange};
