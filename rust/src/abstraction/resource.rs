//! Raw resource usage and its quantization into slice demands.
//!
//! The compiler measures a task variant's *raw* footprint (bytes of GLB
//! capacity, bytes/s of GLB bandwidth, PE/MEM tile counts) from its
//! dataflow graph, then quantizes it into whole slices — the paper's
//! worked example (§2.2): a `conv2_x` layer using 750 KB, 17.3 MB/s,
//! 80 PE and 17 MEM tiles becomes **7 GLB-slices + 2 array-slices**.

use crate::config::ArchConfig;
use crate::util::div_ceil;

/// Raw (un-quantized) resource usage of a task variant.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RawUsage {
    /// GLB capacity in bytes.
    pub glb_bytes: u64,
    /// GLB bandwidth in bytes per second.
    pub glb_bw_bytes_per_sec: f64,
    /// PE tiles used.
    pub pe_tiles: u32,
    /// MEM tiles used.
    pub mem_tiles: u32,
}

impl RawUsage {
    /// Quantize into slice demand under an architecture (paper §2.2).
    ///
    /// GLB-slices must satisfy **both** the capacity and the bandwidth
    /// requirement (each bank contributes capacity *and* a stream port);
    /// array-slices must satisfy both the PE and the MEM tile counts.
    ///
    /// Bandwidth is measured (f64), so the slice count is taken with a
    /// relative tolerance: a requirement that is an exact multiple of
    /// the per-slice bandwidth must not round up to a phantom extra
    /// slice just because the division landed at `k + 1 ulp`.
    pub fn quantize(&self, arch: &ArchConfig) -> SliceDemand {
        debug_assert!(
            self.glb_bw_bytes_per_sec.is_finite() && self.glb_bw_bytes_per_sec >= 0.0,
            "glb_bw_bytes_per_sec must be finite and non-negative, got {}",
            self.glb_bw_bytes_per_sec
        );
        let cap_slices = div_ceil(self.glb_bytes, arch.glb_slice_bytes());
        let bw_per_slice = arch.glb_slice_bw_bytes_per_sec();
        let ratio = (self.glb_bw_bytes_per_sec / bw_per_slice).max(0.0);
        // relative epsilon shields exactly-divisible requirements from
        // f64 round-off; physical bandwidths are nowhere near 2^40
        // slices, so the shave can never drop a genuinely needed slice
        let bw_slices = (ratio * (1.0 - 1e-12)).ceil() as u64;
        let glb = cap_slices.max(bw_slices).max(if self.glb_bytes > 0 || self.glb_bw_bytes_per_sec > 0.0 { 1 } else { 0 });

        let pe_slices = div_ceil(self.pe_tiles as u64, arch.pe_tiles_per_slice() as u64);
        let mem_slices = div_ceil(self.mem_tiles as u64, arch.mem_tiles_per_slice() as u64);
        let array = pe_slices.max(mem_slices).max(1);

        SliceDemand { glb_slices: glb as u32, array_slices: array as u32 }
    }
}

/// Quantized slice demand — the currency of compiler ⇄ scheduler.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct SliceDemand {
    /// GLB-slices required.
    pub glb_slices: u32,
    /// Array-slices required.
    pub array_slices: u32,
}

impl SliceDemand {
    /// Construct directly (Table 1 rows are given in slices).
    pub fn new(glb_slices: u32, array_slices: u32) -> Self {
        SliceDemand { glb_slices, array_slices }
    }

    /// Whether this demand fits within `other` treated as a budget.
    pub fn fits_within(&self, other: &SliceDemand) -> bool {
        self.glb_slices <= other.glb_slices && self.array_slices <= other.array_slices
    }

    /// Component-wise sum.
    pub fn plus(&self, other: &SliceDemand) -> SliceDemand {
        SliceDemand {
            glb_slices: self.glb_slices + other.glb_slices,
            array_slices: self.array_slices + other.array_slices,
        }
    }

    /// Scale both components (naive unroll).
    pub fn scaled(&self, factor: u32) -> SliceDemand {
        SliceDemand {
            glb_slices: self.glb_slices * factor,
            array_slices: self.array_slices * factor,
        }
    }
}

impl std::fmt::Display for SliceDemand {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}g+{}a", self.glb_slices, self.array_slices)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's §2.2 worked example: conv2_x uses 750 KB GLB, 17.3 MB/s,
    /// 80 PE tiles, 17 MEM tiles ⇒ 7 GLB-slices (capacity-bound: ceil(750/128)
    /// = 6... the paper says 7, counting an output bank) and 2 array-slices.
    #[test]
    fn paper_conv2x_example_quantizes_to_2_array_slices() {
        let arch = ArchConfig::default();
        let usage = RawUsage {
            glb_bytes: 750 * 1024,
            glb_bw_bytes_per_sec: 17.3e6,
            pe_tiles: 80,
            mem_tiles: 17,
        };
        let d = usage.quantize(&arch);
        // capacity: ceil(750/128) = 6 slices; Table 1 lists 7 because the
        // Amber mapping double-buffers one bank — the task library pins the
        // Table 1 numbers directly, this checks the quantization math.
        assert_eq!(d.array_slices, 2);
        assert_eq!(d.glb_slices, 6);
    }

    #[test]
    fn unrolled_conv2x_needs_6_array_slices() {
        let arch = ArchConfig::default();
        // 4x unroll: 288 PE, 33 MEM, same GLB footprint (paper §2.2).
        let usage = RawUsage {
            glb_bytes: 750 * 1024,
            glb_bw_bytes_per_sec: 17.3e6,
            pe_tiles: 288,
            mem_tiles: 33,
        };
        let d = usage.quantize(&arch);
        assert_eq!(d.array_slices, 6);
    }

    #[test]
    fn bandwidth_can_dominate_capacity() {
        let arch = ArchConfig::default();
        // tiny capacity but 20 GB/s of streaming: bw-bound slice count.
        let usage = RawUsage {
            glb_bytes: 1024,
            glb_bw_bytes_per_sec: 20e9,
            pe_tiles: 1,
            mem_tiles: 0,
        };
        let d = usage.quantize(&arch);
        // per-slice bw = 8 B/c * 500 MHz = 4 GB/s ⇒ 5 slices
        assert_eq!(d.glb_slices, 5);
    }

    #[test]
    fn exactly_divisible_bandwidth_needs_no_phantom_slice() {
        let arch = ArchConfig::default();
        let per_slice = arch.glb_slice_bw_bytes_per_sec(); // 4 GB/s
        for k in 1..=8u32 {
            // requirements that are exact multiples of the per-slice
            // bandwidth, including ones built from decimal arithmetic
            // (0.1 GB steps) that is inexact in binary
            for bw in [per_slice * k as f64, 0.1 * per_slice * (10 * k) as f64] {
                let usage = RawUsage {
                    glb_bytes: 0,
                    glb_bw_bytes_per_sec: bw,
                    pe_tiles: 1,
                    mem_tiles: 0,
                };
                assert_eq!(
                    usage.quantize(&arch).glb_slices,
                    k,
                    "bw {bw} must need exactly {k} slices"
                );
            }
        }
        // just past a boundary still rounds up
        let over = RawUsage {
            glb_bytes: 0,
            glb_bw_bytes_per_sec: per_slice * 2.0 + 1.0,
            pe_tiles: 1,
            mem_tiles: 0,
        };
        assert_eq!(over.quantize(&arch).glb_slices, 3);
    }

    #[test]
    fn zero_capacity_nonzero_bandwidth_still_needs_a_bank() {
        let arch = ArchConfig::default();
        let usage = RawUsage {
            glb_bytes: 0,
            glb_bw_bytes_per_sec: 1.0, // one byte per second
            pe_tiles: 1,
            mem_tiles: 0,
        };
        let d = usage.quantize(&arch);
        assert_eq!(d.glb_slices, 1, "any streaming needs a stream port");
    }

    #[test]
    #[should_panic(expected = "finite and non-negative")]
    #[cfg(debug_assertions)]
    fn nan_bandwidth_is_rejected_in_debug() {
        let usage = RawUsage {
            glb_bytes: 0,
            glb_bw_bytes_per_sec: f64::NAN,
            pe_tiles: 1,
            mem_tiles: 0,
        };
        let _ = usage.quantize(&ArchConfig::default());
    }

    #[test]
    #[should_panic(expected = "finite and non-negative")]
    #[cfg(debug_assertions)]
    fn negative_bandwidth_is_rejected_in_debug() {
        let usage = RawUsage {
            glb_bytes: 0,
            glb_bw_bytes_per_sec: -1.0,
            pe_tiles: 1,
            mem_tiles: 0,
        };
        let _ = usage.quantize(&ArchConfig::default());
    }

    #[test]
    fn mem_tiles_can_dominate_pe() {
        let arch = ArchConfig::default();
        let usage = RawUsage {
            glb_bytes: 0,
            glb_bw_bytes_per_sec: 0.0,
            pe_tiles: 10,   // < 48 ⇒ 1 slice
            mem_tiles: 40,  // > 16 ⇒ 3 slices
        };
        assert_eq!(usage.quantize(&arch).array_slices, 3);
    }

    #[test]
    fn zero_usage_still_needs_an_array_slice() {
        let arch = ArchConfig::default();
        let usage = RawUsage { glb_bytes: 0, glb_bw_bytes_per_sec: 0.0, pe_tiles: 0, mem_tiles: 0 };
        let d = usage.quantize(&arch);
        assert_eq!(d.array_slices, 1);
        assert_eq!(d.glb_slices, 0);
    }

    #[test]
    fn demand_algebra() {
        let a = SliceDemand::new(2, 1);
        let b = SliceDemand::new(3, 2);
        assert!(a.fits_within(&b));
        assert!(!b.fits_within(&a));
        assert_eq!(a.plus(&b), SliceDemand::new(5, 3));
        assert_eq!(a.scaled(3), SliceDemand::new(6, 3));
        assert_eq!(a.to_string(), "2g+1a");
    }
}
