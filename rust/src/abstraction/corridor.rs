//! Corridor-granular interconnect bandwidth tracking.
//!
//! The mesh routes every GLB↔region stream along the top row and then
//! down the destination columns ([`crate::arch::Interconnect`]).  The
//! vertical track bundles above each array-slice — one **corridor** per
//! array-slice, `tracks_per_dir × slice_cols` tracks wide — are
//! therefore a shared, finite resource exactly like GLB capacity or
//! compute slices.  `CorridorMap` promotes them to a first-class
//! partitioned resource: regions *demand* tracks across the corridors
//! their streams traverse, the map *grants* at most the physical
//! capacity per corridor, and the surplus (demand beyond capacity) is
//! the oversubscription the contention model ([`crate::noc`]) charges.
//!
//! Unlike the slice maps, corridors never refuse an allocation: wires
//! are time-multiplexed, so oversubscription slows streams instead of
//! blocking placement.  The map mirrors [`super::SliceMap`]'s
//! incremental-index discipline — the total-demand and oversubscribed-
//! corridor counters are maintained on every occupy/release and checked
//! against a from-scratch recompute by the debug-mode oracle.

use std::fmt;

use super::slice::SliceRange;

/// The corridors one region's streams traverse: a contiguous corridor
/// index range, each corridor charged `tracks` of demand.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CorridorSpan {
    /// Corridor indices crossed (corridor = array-slice index).
    pub range: SliceRange,
    /// Track demand charged to every corridor in `range` (one track per
    /// concurrently streaming GLB bank).
    pub tracks: u32,
}

impl CorridorSpan {
    /// New span.
    pub fn new(range: SliceRange, tracks: u32) -> Self {
        CorridorSpan { range, tracks }
    }

    /// A span demanding nothing.
    pub fn empty() -> Self {
        CorridorSpan { range: SliceRange::empty(), tracks: 0 }
    }

    /// Whether the span charges no demand.
    pub fn is_empty(&self) -> bool {
        self.range.is_empty() || self.tracks == 0
    }
}

impl fmt::Display for CorridorSpan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}×{}", self.range, self.tracks)
    }
}

/// Per-corridor track-demand tracker (see module docs).
#[derive(Clone, Debug)]
pub struct CorridorMap {
    /// Demanded tracks per corridor (may exceed `capacity`).
    demand: Vec<u32>,
    /// Physical tracks per corridor (`tracks_per_dir × slice_cols`).
    capacity: u32,
    /// Incrementally maintained sum of `demand`.
    total_demand: u64,
    /// Incrementally maintained count of corridors with
    /// `demand > capacity`.
    oversubscribed: u32,
}

impl CorridorMap {
    /// All-idle map of `corridors` corridors, `capacity` tracks each.
    pub fn new(corridors: u32, capacity: u32) -> Self {
        CorridorMap {
            demand: vec![0; corridors as usize],
            capacity: capacity.max(1),
            total_demand: 0,
            oversubscribed: 0,
        }
    }

    /// Corridor count (== array-slice count).
    pub fn corridors(&self) -> u32 {
        self.demand.len() as u32
    }

    /// Physical track capacity per corridor.
    pub fn capacity(&self) -> u32 {
        self.capacity
    }

    /// Demanded tracks on corridor `c` (may exceed capacity).
    pub fn demand(&self, c: u32) -> u32 {
        self.demand[c as usize]
    }

    /// Tracks actually granted on corridor `c`: physical wires are the
    /// hard ceiling, surplus demand time-multiplexes.  The conservation
    /// invariant (`tests/prop_noc.rs`) is exactly
    /// `granted(c) <= capacity()` for every corridor.
    pub fn granted(&self, c: u32) -> u32 {
        self.demand[c as usize].min(self.capacity)
    }

    /// Total demanded tracks over all corridors.
    pub fn total_demand(&self) -> u64 {
        self.total_demand
    }

    /// Whether no corridor carries any demand.
    pub fn is_idle(&self) -> bool {
        self.total_demand == 0
    }

    /// Corridors whose demand exceeds capacity.
    pub fn oversubscribed_count(&self) -> u32 {
        self.oversubscribed
    }

    /// Oversubscription factor of corridor `c`: `demand / capacity`,
    /// floored at 1.0 (an undersubscribed corridor runs at full speed).
    pub fn oversub(&self, c: u32) -> f64 {
        (self.demand[c as usize] as f64 / self.capacity as f64).max(1.0)
    }

    /// Worst oversubscription over the corridors of `range` (1.0 when
    /// the range is empty or nothing is contended).
    pub fn max_oversub_in(&self, range: &SliceRange) -> f64 {
        let mut worst = 1.0f64;
        for c in range.iter() {
            if c >= self.corridors() {
                break;
            }
            let o = self.oversub(c);
            if o > worst {
                worst = o;
            }
        }
        worst
    }

    /// Worst oversubscription of `range` if `span` were occupied on top
    /// of the current state — the communication-aware placement score
    /// (a dry run; the map is not mutated).
    pub fn projected_oversub(&self, span: &CorridorSpan) -> f64 {
        let mut worst = 1.0f64;
        for c in span.range.iter() {
            if c >= self.corridors() {
                break;
            }
            let d = self.demand[c as usize] + span.tracks;
            let o = (d as f64 / self.capacity as f64).max(1.0);
            if o > worst {
                worst = o;
            }
        }
        worst
    }

    /// Charge `span`'s demand.
    pub fn occupy(&mut self, span: &CorridorSpan) {
        if span.is_empty() {
            return;
        }
        debug_assert!(
            span.range.end() <= self.corridors(),
            "corridor span {span} out of range"
        );
        for c in span.range.iter() {
            let d = &mut self.demand[c as usize];
            let was_over = *d > self.capacity;
            *d += span.tracks;
            if !was_over && *d > self.capacity {
                self.oversubscribed += 1;
            }
        }
        self.total_demand += span.range.len as u64 * span.tracks as u64;
        self.debug_check_index();
    }

    /// Return `span`'s demand.  Panics (debug) when releasing demand
    /// that was never charged — an unbalanced release is a region-
    /// lifecycle bug, not a recoverable state.
    pub fn release(&mut self, span: &CorridorSpan) {
        if span.is_empty() {
            return;
        }
        for c in span.range.iter() {
            let d = &mut self.demand[c as usize];
            debug_assert!(*d >= span.tracks, "corridor {c} demand underflow");
            let was_over = *d > self.capacity;
            *d = d.saturating_sub(span.tracks);
            if was_over && *d <= self.capacity {
                self.oversubscribed -= 1;
            }
        }
        self.total_demand =
            self.total_demand.saturating_sub(span.range.len as u64 * span.tracks as u64);
        self.debug_check_index();
    }

    /// Debug-mode oracle: the incremental counters must always equal a
    /// from-scratch recompute over the demand vector.
    #[inline]
    fn debug_check_index(&self) {
        #[cfg(debug_assertions)]
        {
            let total: u64 = self.demand.iter().map(|&d| d as u64).sum();
            debug_assert_eq!(self.total_demand, total, "total-demand counter diverged");
            let over = self.demand.iter().filter(|&&d| d > self.capacity).count() as u32;
            debug_assert_eq!(self.oversubscribed, over, "oversubscribed counter diverged");
        }
    }

    /// Render per-corridor demand as `demand/capacity` cells.
    pub fn render(&self) -> String {
        self.demand
            .iter()
            .map(|d| format!("{d}/{}", self.capacity))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn map() -> CorridorMap {
        // paper geometry: 8 corridors, 5 tracks × 4 cols = 20 each
        CorridorMap::new(8, 20)
    }

    #[test]
    fn fresh_map_is_idle() {
        let m = map();
        assert_eq!(m.corridors(), 8);
        assert_eq!(m.capacity(), 20);
        assert!(m.is_idle());
        assert_eq!(m.oversubscribed_count(), 0);
        assert_eq!(m.max_oversub_in(&SliceRange::new(0, 8)), 1.0);
    }

    #[test]
    fn occupy_release_round_trip() {
        let mut m = map();
        let s = CorridorSpan::new(SliceRange::new(1, 3), 7);
        m.occupy(&s);
        assert_eq!(m.demand(1), 7);
        assert_eq!(m.demand(3), 7);
        assert_eq!(m.demand(0), 0);
        assert_eq!(m.total_demand(), 21);
        m.release(&s);
        assert!(m.is_idle());
        assert_eq!(m.demand(2), 0);
    }

    #[test]
    fn grants_are_capped_at_capacity() {
        let mut m = map();
        let s = CorridorSpan::new(SliceRange::new(0, 2), 14);
        m.occupy(&s);
        m.occupy(&s);
        assert_eq!(m.demand(0), 28);
        assert_eq!(m.granted(0), 20, "grant never exceeds the physical tracks");
        assert_eq!(m.oversubscribed_count(), 2);
        assert!((m.oversub(0) - 1.4).abs() < 1e-12);
        assert_eq!(m.oversub(5), 1.0);
    }

    #[test]
    fn max_oversub_scans_the_span() {
        let mut m = map();
        m.occupy(&CorridorSpan::new(SliceRange::new(2, 1), 30));
        assert!((m.max_oversub_in(&SliceRange::new(0, 8)) - 1.5).abs() < 1e-12);
        assert_eq!(m.max_oversub_in(&SliceRange::new(4, 4)), 1.0);
        assert_eq!(m.max_oversub_in(&SliceRange::empty()), 1.0);
    }

    #[test]
    fn projected_oversub_is_a_dry_run() {
        let mut m = map();
        m.occupy(&CorridorSpan::new(SliceRange::new(0, 4), 15));
        let probe = CorridorSpan::new(SliceRange::new(0, 2), 10);
        assert!((m.projected_oversub(&probe) - 1.25).abs() < 1e-12);
        // the map did not change
        assert_eq!(m.demand(0), 15);
        let clear = CorridorSpan::new(SliceRange::new(4, 2), 10);
        assert_eq!(m.projected_oversub(&clear), 1.0);
    }

    #[test]
    fn empty_spans_are_no_ops() {
        let mut m = map();
        m.occupy(&CorridorSpan::empty());
        m.occupy(&CorridorSpan::new(SliceRange::new(0, 3), 0));
        m.release(&CorridorSpan::empty());
        assert!(m.is_idle());
    }

    #[test]
    fn render_shows_demand_over_capacity() {
        let mut m = CorridorMap::new(2, 20);
        m.occupy(&CorridorSpan::new(SliceRange::new(0, 1), 4));
        assert_eq!(m.render(), "4/20 0/20");
    }
}
