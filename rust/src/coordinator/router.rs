//! Multi-tenant request router and admission.
//!
//! Two admission layers live here:
//!
//! * [`Router`] — virtual-time bookkeeping used by the [`super::Leader`]:
//!   per-tenant in-flight windows and sequence assignment.
//! * [`AdmissionQueues`] — the wall-clock front door of the TCP server:
//!   bounded per-tenant queues that the socket front pushes into
//!   (connection threads under `server.mode = "threaded"`, the single
//!   reactor thread under `"reactor"` — the queues are front-agnostic)
//!   and scheduler workers drain in round-robin batches.  A full queue
//!   rejects immediately (the server replies `BUSY`), so backpressure is
//!   explicit and memory is bounded.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::config::QosClass;
use crate::error::{Error, Result};
use crate::scheduler::RequestQueue;
use crate::tasks::{AppGraph, AppId, AppRequest};

/// Tenant identity (the cloud scenario has four, Fig. 3a).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TenantId(pub u32);

/// Per-tenant counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RouterStats {
    /// Requests admitted.
    pub admitted: u64,
    /// Requests rejected by the admission limit.
    pub rejected: u64,
    /// Requests fully completed.
    pub completed: u64,
}

/// Where a router draws request sequence numbers from: its own local
/// counter (the single-fabric leader), or an atomic shared by every
/// per-shard leader of a sharded server — seqs must stay globally
/// unique and admission-ordered when N shard executors admit
/// concurrently.
#[derive(Clone, Debug)]
enum SeqSource {
    Local(u64),
    Shared(Arc<AtomicU64>),
}

/// Routes tenant submissions into the scheduler's request queue with
/// per-tenant bookkeeping and a simple per-tenant admission limit.
#[derive(Clone, Debug)]
pub struct Router {
    seq: SeqSource,
    /// in-flight request count per tenant.
    inflight: BTreeMap<TenantId, u64>,
    stats: BTreeMap<TenantId, RouterStats>,
    /// per-tenant cap on in-flight requests (backpressure).
    max_inflight: u64,
    /// request seq → tenant (for completion accounting).
    owner: BTreeMap<u64, TenantId>,
}

impl Router {
    /// Router with a per-tenant in-flight cap.
    pub fn new(max_inflight: u64) -> Router {
        Router {
            seq: SeqSource::Local(0),
            inflight: BTreeMap::new(),
            stats: BTreeMap::new(),
            max_inflight: max_inflight.max(1),
            owner: BTreeMap::new(),
        }
    }

    /// Router drawing sequence numbers from a pool-shared counter — one
    /// per shard leader of a sharded coordinator, so completions merged
    /// from every shard carry globally unique seqs.
    pub fn new_shared(max_inflight: u64, seqs: Arc<AtomicU64>) -> Router {
        Router {
            seq: SeqSource::Shared(seqs),
            inflight: BTreeMap::new(),
            stats: BTreeMap::new(),
            max_inflight: max_inflight.max(1),
            owner: BTreeMap::new(),
        }
    }

    /// Claim the next sequence number.
    fn alloc_seq(&mut self) -> u64 {
        match &mut self.seq {
            SeqSource::Local(n) => {
                let s = *n;
                *n += 1;
                s
            }
            SeqSource::Shared(a) => a.fetch_add(1, Ordering::Relaxed),
        }
    }

    /// Submit an application request for a tenant at cycle `now`.
    /// Returns the request sequence number, or an error when the
    /// tenant's in-flight window is full (caller applies backpressure).
    pub fn submit(
        &mut self,
        queue: &mut RequestQueue,
        tenant: TenantId,
        app: AppId,
        now: u64,
    ) -> Result<u64> {
        self.submit_classed(queue, tenant, app, now, QosClass::BestEffort, None)
    }

    /// [`Router::submit`] carrying an explicit QoS class and optional
    /// absolute deadline ([`crate::qos`]).
    pub fn submit_classed(
        &mut self,
        queue: &mut RequestQueue,
        tenant: TenantId,
        app: AppId,
        now: u64,
        class: QosClass,
        deadline: Option<u64>,
    ) -> Result<u64> {
        let inflight = self.inflight.entry(tenant).or_insert(0);
        let stats = self.stats.entry(tenant).or_default();
        if *inflight >= self.max_inflight {
            stats.rejected += 1;
            return Err(Error::Sched(format!(
                "tenant {} at in-flight limit {}",
                tenant.0, self.max_inflight
            )));
        }
        *inflight += 1;
        stats.admitted += 1;
        // the field borrows above must end before alloc_seq reborrows self
        let seq = self.alloc_seq();
        self.owner.insert(seq, tenant);
        queue.submit(AppRequest::new(seq, tenant.0, app, now).with_qos(class, deadline));
        Ok(seq)
    }

    /// Record a request completion (by seq).
    pub fn complete(&mut self, seq: u64) -> Result<TenantId> {
        let tenant = self
            .owner
            .remove(&seq)
            .ok_or_else(|| Error::Sched(format!("completion for unknown request {seq}")))?;
        *self.inflight.get_mut(&tenant).expect("owner implies inflight") -= 1;
        self.stats.get_mut(&tenant).expect("stats exist").completed += 1;
        Ok(tenant)
    }

    /// Stats for a tenant.
    pub fn stats(&self, tenant: TenantId) -> RouterStats {
        self.stats.get(&tenant).copied().unwrap_or_default()
    }

    /// Total in-flight requests.
    pub fn inflight_total(&self) -> u64 {
        self.inflight.values().sum()
    }

    /// Number of task nodes an app expands to (capacity planning).
    pub fn app_tasks(app: AppId) -> usize {
        AppGraph::of(app).len()
    }

    /// Next sequence number that will be assigned.  Exact for a local
    /// counter; for a pool-shared counter it is a point-in-time read
    /// (another shard may claim it first), so sharded callers correlate
    /// batches through `Leader::serve_batch` instead.
    pub fn next_seq(&self) -> u64 {
        match &self.seq {
            SeqSource::Local(n) => *n,
            SeqSource::Shared(a) => a.load(Ordering::Relaxed),
        }
    }
}

/// Internal state of [`AdmissionQueues`]: one bounded FIFO per tenant.
#[derive(Debug)]
struct QueueState<T> {
    shards: Vec<VecDeque<T>>,
    /// Closed queues reject pushes; drains continue until empty.
    closed: bool,
    /// Round-robin drain cursor (fairness across tenants).
    cursor: usize,
}

/// Sharded, bounded multi-tenant admission queues.
///
/// Connection threads [`AdmissionQueues::try_push`] one item per SUBMIT;
/// a full shard (or a closed queue) returns the item back so the caller
/// can reply `BUSY` without blocking.  Scheduler workers block in
/// [`AdmissionQueues::pop_batch`], which drains up to `max` items
/// round-robin across tenants — one item per tenant per lap — so a
/// flooding tenant cannot starve the others, and concurrently queued
/// SUBMITs leave as one batch (a single scheduler invocation).
#[derive(Debug)]
pub struct AdmissionQueues<T> {
    depth: usize,
    tenants: usize,
    state: Mutex<QueueState<T>>,
    ready: Condvar,
}

impl<T> AdmissionQueues<T> {
    /// Queues for `tenants` tenants, each bounded to `depth` items.
    pub fn new(tenants: usize, depth: usize) -> AdmissionQueues<T> {
        let tenants = tenants.max(1);
        AdmissionQueues {
            depth: depth.max(1),
            tenants,
            state: Mutex::new(QueueState {
                shards: (0..tenants).map(|_| VecDeque::new()).collect(),
                closed: false,
                cursor: 0,
            }),
            ready: Condvar::new(),
        }
    }

    /// Number of tenant shards.
    pub fn tenants(&self) -> usize {
        self.tenants
    }

    /// Per-tenant capacity.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Enqueue for `tenant`; returns the item back when the shard is
    /// full, the tenant id is out of range, or the queues are closed —
    /// the caller applies backpressure (`BUSY`).
    pub fn try_push(&self, tenant: TenantId, item: T) -> std::result::Result<(), T> {
        let mut s = self.state.lock().expect("admission queue poisoned");
        let idx = tenant.0 as usize;
        if s.closed || idx >= s.shards.len() || s.shards[idx].len() >= self.depth {
            return Err(item);
        }
        s.shards[idx].push_back(item);
        drop(s);
        self.ready.notify_one();
        Ok(())
    }

    /// Items currently queued across all tenants.
    pub fn pending(&self) -> usize {
        let s = self.state.lock().expect("admission queue poisoned");
        s.shards.iter().map(|q| q.len()).sum()
    }

    /// Block until items are available (or the queues close), then drain
    /// up to `max` of them round-robin across tenants.  Returns `None`
    /// only when the queues are closed *and* empty — workers use that as
    /// their exit signal, so every admitted item is eventually drained.
    pub fn pop_batch(&self, max: usize) -> Option<Vec<(TenantId, T)>> {
        let max = max.max(1);
        let mut s = self.state.lock().expect("admission queue poisoned");
        loop {
            let pending: usize = s.shards.iter().map(|q| q.len()).sum();
            if pending > 0 {
                let n = s.shards.len();
                let start = s.cursor;
                let mut out = Vec::with_capacity(max.min(pending));
                'fill: loop {
                    let mut took = false;
                    for lap in 0..n {
                        let idx = (start + lap) % n;
                        if let Some(item) = s.shards[idx].pop_front() {
                            out.push((TenantId(idx as u32), item));
                            took = true;
                            if out.len() >= max {
                                break 'fill;
                            }
                        }
                    }
                    if !took {
                        break;
                    }
                }
                // The next batch starts *after* the last tenant this one
                // drained, not merely one past where it started: with
                // `max` below the tenant count at saturation, a
                // start-plus-one rotation re-serves the tenants right
                // after the cursor every batch while the far tenants
                // wait out a whole cursor revolution.  Resuming at
                // last-served + 1 makes the drain a true round-robin
                // (every tenant exactly once per `n/max` batches), so
                // tenant 0 can never starve the later tenants.
                if let Some((last, _)) = out.last() {
                    s.cursor = (last.0 as usize + 1) % n;
                }
                return Some(out);
            }
            if s.closed {
                return None;
            }
            s = self.ready.wait(s).expect("admission queue poisoned");
        }
    }

    /// Close the queues: further pushes are rejected, blocked workers
    /// wake, and remaining items drain before `pop_batch` returns `None`.
    pub fn close(&self) {
        let mut s = self.state.lock().expect("admission queue poisoned");
        s.closed = true;
        drop(s);
        self.ready.notify_all();
    }

    /// Whether [`AdmissionQueues::close`] was called.
    pub fn is_closed(&self) -> bool {
        self.state.lock().expect("admission queue poisoned").closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn submit_complete_cycle() {
        let mut r = Router::new(2);
        let mut q = RequestQueue::new();
        let s0 = r.submit(&mut q, TenantId(0), AppId::Camera, 0).unwrap();
        let s1 = r.submit(&mut q, TenantId(0), AppId::Camera, 5).unwrap();
        assert_eq!((s0, s1), (0, 1));
        assert_eq!(r.inflight_total(), 2);
        // window full
        assert!(r.submit(&mut q, TenantId(0), AppId::Camera, 6).is_err());
        assert_eq!(r.stats(TenantId(0)).rejected, 1);
        // other tenants unaffected
        r.submit(&mut q, TenantId(1), AppId::Harris, 7).unwrap();

        assert_eq!(r.complete(s0).unwrap(), TenantId(0));
        assert_eq!(r.stats(TenantId(0)).completed, 1);
        r.submit(&mut q, TenantId(0), AppId::Camera, 8).unwrap();
    }

    #[test]
    fn unknown_completion_errors() {
        let mut r = Router::new(1);
        assert!(r.complete(99).is_err());
    }

    #[test]
    fn app_task_counts() {
        assert_eq!(Router::app_tasks(AppId::ResNet18), 4);
        assert_eq!(Router::app_tasks(AppId::Camera), 1);
    }

    #[test]
    fn admission_bounded_and_rejects_when_full() {
        let q: AdmissionQueues<u32> = AdmissionQueues::new(2, 2);
        assert_eq!((q.tenants(), q.depth()), (2, 2));
        assert!(q.try_push(TenantId(0), 1).is_ok());
        assert!(q.try_push(TenantId(0), 2).is_ok());
        // shard full → item handed back
        assert_eq!(q.try_push(TenantId(0), 3), Err(3));
        // other tenant unaffected
        assert!(q.try_push(TenantId(1), 4).is_ok());
        // out-of-range tenant rejected
        assert_eq!(q.try_push(TenantId(9), 5), Err(5));
        assert_eq!(q.pending(), 3);
    }

    #[test]
    fn pop_batch_drains_round_robin() {
        let q: AdmissionQueues<u32> = AdmissionQueues::new(3, 8);
        for i in 0..3 {
            q.try_push(TenantId(0), 10 + i).unwrap();
        }
        q.try_push(TenantId(2), 30).unwrap();
        // one item per tenant per lap: 0,2 first lap, then 0,0
        let batch = q.pop_batch(8).unwrap();
        let order: Vec<(u32, u32)> = batch.iter().map(|(t, v)| (t.0, *v)).collect();
        assert_eq!(order, vec![(0, 10), (2, 30), (0, 11), (0, 12)]);
        assert_eq!(q.pending(), 0);
    }

    #[test]
    fn saturated_pop_batch_drains_tenants_round_robin() {
        // Every tenant saturated, batches smaller than the tenant count:
        // the rotating start offset must hand each tenant exactly one
        // slot per revolution — tenant 0 (or any tenant adjacent to the
        // cursor) cannot starve the others.
        let q: AdmissionQueues<u32> = AdmissionQueues::new(4, 8);
        for tenant in 0..4u32 {
            for i in 0..6 {
                q.try_push(TenantId(tenant), tenant * 10 + i).unwrap();
            }
        }
        let mut served = [0u32; 4];
        let mut batches = Vec::new();
        for _ in 0..12 {
            let batch = q.pop_batch(2).unwrap();
            assert_eq!(batch.len(), 2);
            for (t, _) in &batch {
                served[t.0 as usize] += 1;
            }
            batches.push((batch[0].0 .0, batch[1].0 .0));
        }
        assert_eq!(q.pending(), 0);
        assert_eq!(served, [6, 6, 6, 6], "equal service at saturation");
        // the drain sequence is the strict rotation (0,1),(2,3),(0,1)…
        assert_eq!(batches[0], (0, 1));
        assert_eq!(batches[1], (2, 3));
        assert_eq!(batches[2], (0, 1));
        assert_eq!(batches[3], (2, 3));
    }

    #[test]
    fn shared_seq_routers_never_collide() {
        let seqs = Arc::new(AtomicU64::new(0));
        let mut a = Router::new_shared(8, seqs.clone());
        let mut b = Router::new_shared(8, seqs.clone());
        let mut qa = RequestQueue::new();
        let mut qb = RequestQueue::new();
        let mut all = Vec::new();
        for i in 0..4 {
            all.push(a.submit(&mut qa, TenantId(0), AppId::Harris, i).unwrap());
            all.push(b.submit(&mut qb, TenantId(1), AppId::Camera, i).unwrap());
        }
        let mut sorted = all.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), all.len(), "duplicate seqs across shard routers");
        assert_eq!(seqs.load(Ordering::Relaxed), 8);
        assert_eq!(a.next_seq(), 8);
        // completions resolve on the router that issued the seq
        assert_eq!(a.complete(all[0]).unwrap(), TenantId(0));
        assert!(b.complete(all[0]).is_err(), "foreign seq is unknown");
    }

    #[test]
    fn pop_batch_respects_max() {
        let q: AdmissionQueues<u32> = AdmissionQueues::new(1, 8);
        for i in 0..5 {
            q.try_push(TenantId(0), i).unwrap();
        }
        assert_eq!(q.pop_batch(2).unwrap().len(), 2);
        assert_eq!(q.pending(), 3);
    }

    #[test]
    fn close_rejects_pushes_drains_then_signals_exit() {
        let q: AdmissionQueues<u32> = AdmissionQueues::new(2, 4);
        q.try_push(TenantId(1), 7).unwrap();
        q.close();
        assert!(q.is_closed());
        assert_eq!(q.try_push(TenantId(0), 8), Err(8));
        // remaining items still drain, then None
        assert_eq!(q.pop_batch(4).unwrap().len(), 1);
        assert!(q.pop_batch(4).is_none());
    }

    #[test]
    fn close_wakes_blocked_workers() {
        let q = std::sync::Arc::new(AdmissionQueues::<u32>::new(1, 1));
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.pop_batch(4));
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        assert!(h.join().unwrap().is_none());
    }
}
