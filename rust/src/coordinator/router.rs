//! Multi-tenant request router and admission.

use std::collections::BTreeMap;

use crate::error::{Error, Result};
use crate::scheduler::RequestQueue;
use crate::tasks::{AppGraph, AppId, AppRequest};

/// Tenant identity (the cloud scenario has four, Fig. 3a).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TenantId(pub u32);

/// Per-tenant counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RouterStats {
    /// Requests admitted.
    pub admitted: u64,
    /// Requests rejected by the admission limit.
    pub rejected: u64,
    /// Requests fully completed.
    pub completed: u64,
}

/// Routes tenant submissions into the scheduler's request queue with
/// per-tenant bookkeeping and a simple per-tenant admission limit.
#[derive(Clone, Debug)]
pub struct Router {
    next_seq: u64,
    /// in-flight request count per tenant.
    inflight: BTreeMap<TenantId, u64>,
    stats: BTreeMap<TenantId, RouterStats>,
    /// per-tenant cap on in-flight requests (backpressure).
    max_inflight: u64,
    /// request seq → tenant (for completion accounting).
    owner: BTreeMap<u64, TenantId>,
}

impl Router {
    /// Router with a per-tenant in-flight cap.
    pub fn new(max_inflight: u64) -> Router {
        Router {
            next_seq: 0,
            inflight: BTreeMap::new(),
            stats: BTreeMap::new(),
            max_inflight: max_inflight.max(1),
            owner: BTreeMap::new(),
        }
    }

    /// Submit an application request for a tenant at cycle `now`.
    /// Returns the request sequence number, or an error when the
    /// tenant's in-flight window is full (caller applies backpressure).
    pub fn submit(
        &mut self,
        queue: &mut RequestQueue,
        tenant: TenantId,
        app: AppId,
        now: u64,
    ) -> Result<u64> {
        let inflight = self.inflight.entry(tenant).or_insert(0);
        let stats = self.stats.entry(tenant).or_default();
        if *inflight >= self.max_inflight {
            stats.rejected += 1;
            return Err(Error::Sched(format!(
                "tenant {} at in-flight limit {}",
                tenant.0, self.max_inflight
            )));
        }
        let seq = self.next_seq;
        self.next_seq += 1;
        *inflight += 1;
        stats.admitted += 1;
        self.owner.insert(seq, tenant);
        queue.submit(AppRequest::new(seq, tenant.0, app, now));
        Ok(seq)
    }

    /// Record a request completion (by seq).
    pub fn complete(&mut self, seq: u64) -> Result<TenantId> {
        let tenant = self
            .owner
            .remove(&seq)
            .ok_or_else(|| Error::Sched(format!("completion for unknown request {seq}")))?;
        *self.inflight.get_mut(&tenant).expect("owner implies inflight") -= 1;
        self.stats.get_mut(&tenant).expect("stats exist").completed += 1;
        Ok(tenant)
    }

    /// Stats for a tenant.
    pub fn stats(&self, tenant: TenantId) -> RouterStats {
        self.stats.get(&tenant).copied().unwrap_or_default()
    }

    /// Total in-flight requests.
    pub fn inflight_total(&self) -> u64 {
        self.inflight.values().sum()
    }

    /// Number of task nodes an app expands to (capacity planning).
    pub fn app_tasks(app: AppId) -> usize {
        AppGraph::of(app).len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn submit_complete_cycle() {
        let mut r = Router::new(2);
        let mut q = RequestQueue::new();
        let s0 = r.submit(&mut q, TenantId(0), AppId::Camera, 0).unwrap();
        let s1 = r.submit(&mut q, TenantId(0), AppId::Camera, 5).unwrap();
        assert_eq!((s0, s1), (0, 1));
        assert_eq!(r.inflight_total(), 2);
        // window full
        assert!(r.submit(&mut q, TenantId(0), AppId::Camera, 6).is_err());
        assert_eq!(r.stats(TenantId(0)).rejected, 1);
        // other tenants unaffected
        r.submit(&mut q, TenantId(1), AppId::Harris, 7).unwrap();

        assert_eq!(r.complete(s0).unwrap(), TenantId(0));
        assert_eq!(r.stats(TenantId(0)).completed, 1);
        r.submit(&mut q, TenantId(0), AppId::Camera, 8).unwrap();
    }

    #[test]
    fn unknown_completion_errors() {
        let mut r = Router::new(1);
        assert!(r.complete(99).is_err());
    }

    #[test]
    fn app_task_counts() {
        assert_eq!(Router::app_tasks(AppId::ResNet18), 4);
        assert_eq!(Router::app_tasks(AppId::Camera), 1);
    }
}
