//! Task ⇄ artifact binding: functional execution of scheduled tasks.
//!
//! When the leader launches a task variant, the binding resolves its AOT
//! artifact (from the Table 1 library's `artifact` field), executes it
//! through PJRT, and optionally verifies the golden checksum — giving the
//! live coordinator bit-real task outputs next to the slice-level timing
//! model.  Execution happens on shard executor threads regardless of
//! which socket front admitted the request, so the reactor's single
//! event-loop thread never blocks on PJRT.

use crate::error::{Error, Result};
use crate::runtime::{ExecOutput, RuntimeClient};
use crate::tasks::{TaskId, TaskLibrary, VariantId};

/// Executes launched tasks against their artifacts.
pub struct TaskBinding {
    runtime: RuntimeClient,
    lib: TaskLibrary,
    /// verify golden checksums on every execution (cheap; on by default).
    pub verify: bool,
}

impl TaskBinding {
    /// Bind a runtime client to the task library.
    pub fn new(runtime: RuntimeClient, lib: TaskLibrary) -> TaskBinding {
        TaskBinding { runtime, lib, verify: true }
    }

    /// Artifact name for a (task, variant).
    pub fn artifact_name(&self, task: &TaskId, ver: VariantId) -> Result<String> {
        let spec = self.lib.get(task)?;
        let v = spec
            .variant(ver)
            .ok_or_else(|| Error::Sched(format!("{task} has no variant {ver}")))?;
        v.artifact
            .clone()
            .ok_or_else(|| Error::Artifact(format!("{task}:{ver} has no artifact")))
    }

    /// Pre-compile every artifact the library references (startup cost,
    /// keeps the request path compile-free).  Returns total compile ms.
    pub fn warmup(&mut self) -> Result<f64> {
        let mut total_us = 0.0;
        let names: Vec<String> = self
            .lib
            .iter()
            .flat_map(|t| t.variants.iter().filter_map(|v| v.artifact.clone()))
            .collect();
        for name in names {
            total_us += self.runtime.ensure_compiled(&name)?;
        }
        Ok(total_us / 1e3)
    }

    /// Execute a (task, variant) on deterministic inputs; verifies the
    /// golden checksum when `verify` is set.
    pub fn execute(&mut self, task: &TaskId, ver: VariantId) -> Result<ExecOutput> {
        let name = self.artifact_name(task, ver)?;
        if self.verify {
            self.runtime.verify_golden(&name)
        } else {
            self.runtime.execute_golden(&name)
        }
    }

    /// The underlying runtime (stats).
    pub fn runtime(&self) -> &RuntimeClient {
        &self.runtime
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn binding() -> Option<TaskBinding> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return None;
        }
        let rt = RuntimeClient::from_dir(&dir).unwrap();
        Some(TaskBinding::new(rt, TaskLibrary::table1()))
    }

    #[test]
    fn resolves_artifact_names() {
        let Some(b) = binding() else { return };
        assert_eq!(
            b.artifact_name(&TaskId::new("camera.pipeline"), VariantId('b')).unwrap(),
            "camera_pipeline_b"
        );
        assert!(b.artifact_name(&TaskId::new("camera.pipeline"), VariantId('z')).is_err());
        assert!(b.artifact_name(&TaskId::new("nope"), VariantId('a')).is_err());
    }

    #[test]
    fn executes_and_verifies_a_task() {
        let Some(mut b) = binding() else { return };
        let out = b.execute(&TaskId::new("harris.corner"), VariantId('a')).unwrap();
        assert_eq!(out.shape, vec![1, 64, 64]);
        assert!(out.exec_us > 0.0);
    }
}
