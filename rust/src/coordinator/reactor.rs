//! Nonblocking event-loop serving front (`server.mode = "reactor"`).
//!
//! One thread owns every client socket: a hand-rolled reactor over
//! `epoll` (declared directly against the platform libc — the crate
//! keeps its zero-heavy-deps stance) with a portable nonblocking-scan
//! fallback for platforms without epoll or when `epoll_create1` fails.
//! Each connection is a small state machine — protocol negotiation on
//! the first byte, incremental buffer parsing, an in-order pending-reply
//! queue — so ten thousand idle connections cost zero wakeups, where the
//! thread-per-connection front pays a 100 ms-timeout `read` tick per
//! connection forever.
//!
//! The scheduler side is *unchanged*: requests land in the same
//! [`AdmissionQueues`](super::router::AdmissionQueues) behind the same
//! [`admit`](super::server) / [`stats_reply`](super::server) /
//! [`defrag_reply`](super::server) protocol core the threaded front
//! uses, with the same counters, BUSY backpressure, and graceful-drain
//! semantics — the conformance suite (`tests/protocol_conformance.rs`)
//! holds the two fronts byte-identical.
//!
//! Reply routing: an admitted SUBMIT allocates an in-order *pending
//! slot* on its connection and hands the scheduler worker a
//! [`CompletionSink`]; the worker's reply travels over an mpsc channel
//! back to the reactor, which a self-pipe waker nudges out of its poll
//! wait.  A generation counter on each connection slot keeps a late
//! completion for a closed connection from reaching whoever reused the
//! slot.  `DEFRAG` — a blocking broadcast over every shard executor —
//! runs on a dedicated control thread so the event loop never blocks.
//!
//! Graceful drain mirrors the threaded front: on shutdown the listener
//! closes, connections owed nothing close immediately, connections with
//! in-flight submissions stay until their replies flush (bounded by the
//! same 10 s quiescence deadline), then the loop exits.
//!
//! An optional idle timeout (`server.idle_timeout_ms`) reaps
//! connections that have not *completed a request* recently — raw bytes
//! do not count as progress, so a slow-loris peer dribbling one byte
//! per tick cannot hold a socket open indefinitely.

use std::collections::VecDeque;
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::WireProtocolKind;
use crate::error::{Error, Result};

use super::frame;
use super::server::{
    admit, defrag_reply, dump_reply, explain_reply, metrics_reply, parse_submit, stats_reply,
    ReplySink, Shared, WATCH_DRAIN_MAX,
};

/// Hard cap on concurrently open connections (slab slots).
const MAX_CONNS: usize = 65_536;
/// Longest accepted text-protocol line (bytes before the newline).
const MAX_LINE: usize = 64 * 1024;
/// Per-connection write-buffer cap: a peer that stops reading while
/// replies accumulate past this is closed rather than buffered without
/// bound.
const WBUF_CAP: usize = 1024 * 1024;
/// Base poll timeout: the loop re-checks the stop flag and the idle
/// sweep at least this often (mirrors the threaded front's 100 ms read
/// tick — but paid once per *loop*, not once per connection).
const POLL_TIMEOUT_MS: i32 = 100;
/// How long a draining shutdown waits for in-flight replies before
/// force-closing (the threaded front's quiescence deadline).
const DRAIN_DEADLINE: Duration = Duration::from_secs(10);

/// Poller token of the TCP listener.
const TOKEN_LISTENER: u64 = u64::MAX;
/// Poller token of the self-pipe waker.
const TOKEN_WAKER: u64 = u64::MAX - 1;

// ---------------------------------------------------------------------
// Self-pipe waker
// ---------------------------------------------------------------------

#[cfg(unix)]
mod wake {
    use std::io::{Read, Write};
    use std::os::unix::net::UnixStream;

    /// Write half of the self-pipe: worker/control threads nudge the
    /// event loop out of its poll wait by writing one byte.
    pub struct Waker {
        tx: UnixStream,
    }

    /// Read half, registered with the poller and drained on wakeup.
    pub(super) struct WakeRx {
        pub(super) rx: UnixStream,
    }

    pub(super) fn pair() -> std::io::Result<(Waker, WakeRx)> {
        let (tx, rx) = UnixStream::pair()?;
        tx.set_nonblocking(true)?;
        rx.set_nonblocking(true)?;
        Ok((Waker { tx }, WakeRx { rx }))
    }

    impl Waker {
        /// Best-effort wake: a full pipe already guarantees a pending
        /// wakeup, so the result is deliberately ignored.
        pub fn wake(&self) {
            let _ = (&self.tx).write(&[1u8]);
        }
    }

    impl WakeRx {
        /// Discard every buffered wake byte.
        pub(super) fn drain(&mut self) {
            let mut sink = [0u8; 64];
            loop {
                match self.rx.read(&mut sink) {
                    Ok(0) => break,
                    Ok(_) => continue,
                    Err(_) => break,
                }
            }
        }
    }
}

#[cfg(not(unix))]
mod wake {
    /// No socketpair on this platform: the scan poller's bounded sleep
    /// (≤ 1 ms when idle) picks completions up instead.
    pub struct Waker;
    pub(super) struct WakeRx;

    pub(super) fn pair() -> std::io::Result<(Waker, WakeRx)> {
        Ok((Waker, WakeRx))
    }

    impl Waker {
        pub fn wake(&self) {}
    }

    impl WakeRx {
        pub(super) fn drain(&mut self) {}
    }
}

pub(super) use wake::Waker;

// ---------------------------------------------------------------------
// epoll FFI (linux) + portable scan fallback
// ---------------------------------------------------------------------

#[cfg(target_os = "linux")]
mod sys {
    //! Minimal `epoll` declarations.  Every Rust binary on Linux links
    //! the platform libc already; declaring the four entry points here
    //! keeps the crate free of a `libc` dependency.

    pub(super) const EPOLL_CLOEXEC: i32 = 0o2000000;
    pub(super) const EPOLL_CTL_ADD: i32 = 1;
    pub(super) const EPOLL_CTL_DEL: i32 = 2;
    pub(super) const EPOLL_CTL_MOD: i32 = 3;
    pub(super) const EPOLLIN: u32 = 0x1;
    pub(super) const EPOLLOUT: u32 = 0x4;
    pub(super) const EPOLLERR: u32 = 0x8;
    pub(super) const EPOLLHUP: u32 = 0x10;

    /// `struct epoll_event`.  Packed on x86-64, where the kernel ABI
    /// leaves no padding between the 32-bit mask and the 64-bit data
    /// word; fields must be read by value, never by reference.
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    pub(super) struct EpollEvent {
        pub(super) events: u32,
        pub(super) data: u64,
    }

    extern "C" {
        pub(super) fn epoll_create1(flags: i32) -> i32;
        pub(super) fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        pub(super) fn epoll_wait(
            epfd: i32,
            events: *mut EpollEvent,
            maxevents: i32,
            timeout: i32,
        ) -> i32;
        pub(super) fn close(fd: i32) -> i32;
    }
}

/// Raw-fd alias: a real descriptor where epoll exists, unit elsewhere
/// (the scan poller never looks at it).
#[cfg(target_os = "linux")]
type Fd = i32;
#[cfg(not(target_os = "linux"))]
type Fd = ();

#[cfg(target_os = "linux")]
fn fd_of<T: std::os::fd::AsRawFd>(t: &T) -> Fd {
    t.as_raw_fd()
}
#[cfg(not(target_os = "linux"))]
fn fd_of<T>(_t: &T) -> Fd {}

/// One epoll instance (closed on drop).
#[cfg(target_os = "linux")]
struct Epoll {
    epfd: i32,
}

#[cfg(target_os = "linux")]
impl Epoll {
    fn new() -> std::io::Result<Epoll> {
        // SAFETY: plain syscall with no pointer arguments.
        let epfd = unsafe { sys::epoll_create1(sys::EPOLL_CLOEXEC) };
        if epfd < 0 {
            return Err(std::io::Error::last_os_error());
        }
        Ok(Epoll { epfd })
    }

    fn ctl(&self, op: i32, fd: i32, mask: u32, token: u64) -> std::io::Result<()> {
        let mut ev = sys::EpollEvent { events: mask, data: token };
        // SAFETY: `ev` outlives the call; the kernel copies it out.
        let rc = unsafe { sys::epoll_ctl(self.epfd, op, fd, &mut ev) };
        if rc < 0 {
            return Err(std::io::Error::last_os_error());
        }
        Ok(())
    }

    /// Wait for events, appending `(token, readable, writable)` tuples.
    fn wait(&self, out: &mut Vec<(u64, bool, bool)>, timeout_ms: i32) -> std::io::Result<()> {
        const CAP: usize = 256;
        let mut events = [sys::EpollEvent { events: 0, data: 0 }; CAP];
        // SAFETY: the buffer is valid for CAP entries and the kernel
        // writes at most `maxevents` of them.
        let n = unsafe { sys::epoll_wait(self.epfd, events.as_mut_ptr(), CAP as i32, timeout_ms) };
        if n < 0 {
            let e = std::io::Error::last_os_error();
            if e.kind() == ErrorKind::Interrupted {
                return Ok(());
            }
            return Err(e);
        }
        for ev in events.iter().take(n as usize) {
            // copy packed fields by value (a reference would be UB)
            let mask = ev.events;
            let token = ev.data;
            // error/hangup surfaces as readability: the read path maps
            // it to a clean close
            let readable = mask & (sys::EPOLLIN | sys::EPOLLERR | sys::EPOLLHUP) != 0;
            let writable = mask & (sys::EPOLLOUT | sys::EPOLLERR | sys::EPOLLHUP) != 0;
            out.push((token, readable, writable));
        }
        Ok(())
    }
}

#[cfg(target_os = "linux")]
impl Drop for Epoll {
    fn drop(&mut self) {
        // SAFETY: closing the fd we created; double-close is impossible
        // because Drop runs once.
        unsafe {
            sys::close(self.epfd);
        }
    }
}

/// Readiness source: epoll where available, else a nonblocking scan of
/// every socket with a bounded idle sleep.
enum Poller {
    #[cfg(target_os = "linux")]
    Epoll(Epoll),
    Scan,
}

impl Poller {
    fn new() -> Poller {
        #[cfg(target_os = "linux")]
        {
            match Epoll::new() {
                Ok(ep) => return Poller::Epoll(ep),
                Err(e) => log::warn!("epoll_create1 failed ({e}); using scan poller"),
            }
        }
        Poller::Scan
    }

    fn is_scan(&self) -> bool {
        matches!(self, Poller::Scan)
    }

    fn add(&self, fd: Fd, token: u64, writable: bool) {
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(ep) => {
                let mask = sys::EPOLLIN | if writable { sys::EPOLLOUT } else { 0 };
                if let Err(e) = ep.ctl(sys::EPOLL_CTL_ADD, fd, mask, token) {
                    log::warn!("epoll add failed for token {token}: {e}");
                }
            }
            Poller::Scan => {
                let _ = (fd, token, writable);
            }
        }
    }

    fn modify(&self, fd: Fd, token: u64, writable: bool) {
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(ep) => {
                let mask = sys::EPOLLIN | if writable { sys::EPOLLOUT } else { 0 };
                if let Err(e) = ep.ctl(sys::EPOLL_CTL_MOD, fd, mask, token) {
                    log::warn!("epoll modify failed for token {token}: {e}");
                }
            }
            Poller::Scan => {
                let _ = (fd, token, writable);
            }
        }
    }

    fn del(&self, fd: Fd) {
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(ep) => {
                // dropping the socket would deregister it anyway; the
                // explicit DEL just keeps the interest list tight
                let _ = ep.ctl(sys::EPOLL_CTL_DEL, fd, 0, 0);
            }
            Poller::Scan => {
                let _ = fd;
            }
        }
    }
}

// ---------------------------------------------------------------------
// Completion routing
// ---------------------------------------------------------------------

/// One reply line routed from a scheduler worker (or the control
/// thread) back to the event loop.
pub(super) struct Completion {
    conn: usize,
    gen: u64,
    slot: u64,
    line: String,
}

/// The reactor half of a [`ReplySink`]: identifies the connection (by
/// slab index + generation) and the in-order pending slot the reply
/// fulfills, and wakes the event loop after enqueueing.
#[derive(Clone)]
pub(super) struct CompletionSink {
    tx: mpsc::Sender<Completion>,
    waker: Arc<Waker>,
    conn: usize,
    gen: u64,
    slot: u64,
}

impl CompletionSink {
    /// Deliver one reply line to the event loop (best-effort, like the
    /// threaded front's channel send).
    pub(super) fn deliver(&self, line: String) {
        let _ = self.tx.send(Completion {
            conn: self.conn,
            gen: self.gen,
            slot: self.slot,
            line,
        });
        self.waker.wake();
    }
}

/// Control-plane work offloaded from the event loop.
enum ControlMsg {
    /// Run the blocking DEFRAG broadcast and complete `slot`.
    Defrag { conn: usize, gen: u64, slot: u64 },
}

// ---------------------------------------------------------------------
// Per-connection state machine
// ---------------------------------------------------------------------

/// Wire protocol a connection negotiated (from its first byte).
#[derive(Clone, Copy, PartialEq, Eq)]
enum Proto {
    /// Nothing received yet.
    Unknown,
    /// Line-oriented text protocol.
    Text,
    /// Length-prefixed binary framing ([`frame`]).
    Binary,
}

/// A reply owed to the peer, delivered in request order.
struct Pending {
    /// Per-connection slot id ([`Conn::alloc_slot`]).
    slot: u64,
    /// Request id echoed on binary replies (0 on text).
    req_id: u64,
    /// `None` while the scheduler still owes the line.
    line: Option<String>,
}

struct Conn {
    stream: TcpStream,
    /// Generation guard against slab-slot reuse (see [`Completion`]).
    gen: u64,
    proto: Proto,
    /// Unparsed received bytes.
    rbuf: Vec<u8>,
    /// Encoded-but-unsent reply bytes (`wpos` = flushed prefix).
    wbuf: Vec<u8>,
    wpos: usize,
    /// Replies owed, in request order.
    pending: VecDeque<Pending>,
    next_slot: u64,
    /// Last instant a *complete request* was parsed (raw bytes do not
    /// count — the slow-loris distinction) or the connection opened.
    last_progress: Instant,
    /// Stop reading and close once every owed reply has flushed.
    close_after_flush: bool,
    /// Whether the poller registration currently includes writability.
    want_write: bool,
    /// Live `WATCH` subscription: `(hub token, req_id of the WATCH
    /// request)`.  While set, published journal events are pushed as
    /// `EVENT` replies and the next complete request ends the stream.
    watch: Option<(u64, u64)>,
}

impl Conn {
    fn new(stream: TcpStream, gen: u64) -> Conn {
        Conn {
            stream,
            gen,
            proto: Proto::Unknown,
            rbuf: Vec::new(),
            wbuf: Vec::new(),
            wpos: 0,
            pending: VecDeque::new(),
            next_slot: 0,
            last_progress: Instant::now(),
            close_after_flush: false,
            want_write: false,
            watch: None,
        }
    }

    /// Allocate the next in-order pending-reply slot.
    fn alloc_slot(&mut self, req_id: u64) -> u64 {
        self.next_slot += 1;
        let slot = self.next_slot;
        self.pending.push_back(Pending { slot, req_id, line: None });
        slot
    }

    /// Fulfill a pending slot with its reply line.
    fn fulfill(&mut self, slot: u64, line: String) {
        if let Some(p) = self.pending.iter_mut().find(|p| p.slot == slot) {
            p.line = Some(line);
        }
    }

    /// Push an immediately-ready reply (STATS, errors, BYE, BUSY).
    fn push_reply(&mut self, req_id: u64, line: String, close: bool) {
        let slot = self.alloc_slot(req_id);
        self.fulfill(slot, line);
        if close {
            self.close_after_flush = true;
        }
    }

    /// Whether the peer is owed nothing (safe to reap/close).
    fn drained(&self) -> bool {
        self.pending.is_empty() && self.wpos >= self.wbuf.len()
    }
}

/// What to do with a connection after servicing it.
#[derive(Debug, PartialEq, Eq)]
enum Verdict {
    Keep,
    Close,
}

/// Shared-state handles the per-connection service path needs; bundled
/// so the borrow of one `Conn` out of the slab stays disjoint from
/// them.
struct Ctx<'a> {
    shared: &'a Shared,
    completions: &'a mpsc::Sender<Completion>,
    waker: &'a Arc<Waker>,
    control: Option<&'a mpsc::Sender<ControlMsg>>,
    protocol: WireProtocolKind,
    stopping: bool,
}

/// Pull every available byte off the socket into `rbuf`.  Returns
/// `false` once the peer has closed or errored (no further requests).
fn read_into(conn: &mut Conn) -> bool {
    let mut tmp = [0u8; 16 * 1024];
    loop {
        match conn.stream.read(&mut tmp) {
            Ok(0) => return false,
            Ok(n) => {
                conn.rbuf.extend_from_slice(&tmp[..n]);
                if n < tmp.len() {
                    // short read: the socket buffer is drained, and
                    // level-triggered readiness re-reports any race
                    return true;
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => return true,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => return false,
        }
    }
}

/// Parse and dispatch every complete request currently buffered.
fn parse_and_dispatch(ctx: &Ctx<'_>, conn: &mut Conn, idx: usize) {
    let mut off = 0usize;
    while !conn.close_after_flush {
        if conn.proto == Proto::Unknown {
            match conn.rbuf.get(off) {
                None => break,
                Some(&b) if b == frame::MAGIC[0] => {
                    if ctx.protocol == WireProtocolKind::Text {
                        conn.push_reply(0, "ERR binary protocol disabled".into(), true);
                        break;
                    }
                    conn.proto = Proto::Binary;
                }
                Some(_) => {
                    if ctx.protocol == WireProtocolKind::Binary {
                        conn.push_reply(0, "ERR text protocol disabled".into(), true);
                        break;
                    }
                    conn.proto = Proto::Text;
                }
            }
        }
        let buf = &conn.rbuf[off..];
        if buf.is_empty() {
            break;
        }
        match conn.proto {
            Proto::Text => match buf.iter().position(|&b| b == b'\n') {
                None => {
                    if buf.len() > MAX_LINE {
                        conn.push_reply(0, "ERR line too long".into(), true);
                    }
                    break;
                }
                Some(pos) => {
                    let line = match std::str::from_utf8(&buf[..pos]) {
                        Ok(s) => s.trim_end().to_string(),
                        Err(_) => {
                            conn.push_reply(0, "ERR invalid utf-8".into(), true);
                            off += pos + 1;
                            break;
                        }
                    };
                    off += pos + 1;
                    if conn.watch.is_some() {
                        // any complete request on a watching connection
                        // ends the stream; the request is consumed
                        end_watch(ctx.shared, conn);
                    } else {
                        dispatch_text(ctx, conn, idx, &line);
                    }
                }
            },
            Proto::Binary => match frame::decode(buf) {
                Ok(None) => break,
                Ok(Some((f, consumed))) => {
                    off += consumed;
                    if conn.watch.is_some() {
                        end_watch(ctx.shared, conn);
                    } else {
                        let req_id = f.req_id;
                        let action = frame_action(ctx, &f);
                        apply_action(ctx, conn, idx, req_id, action);
                    }
                }
                Err(e) => {
                    conn.push_reply(0, format!("ERR bad frame: {e}"), true);
                    break;
                }
            },
            Proto::Unknown => unreachable!("negotiated above"),
        }
    }
    if off > 0 {
        // `off` only advances on complete requests, so this is the
        // progress signal the idle sweep trusts
        conn.rbuf.drain(..off);
        conn.last_progress = Instant::now();
    }
}

/// Owned dispatch decision for one binary frame (owned so the borrow of
/// the receive buffer ends before the connection is mutated).
enum FrameAction {
    Immediate { line: String, close: bool },
    Submit(super::server::ParsedSubmit),
    Defrag,
    Watch,
}

/// Begin a `WATCH` subscription on this connection (both encodings).
fn begin_watch(shared: &Shared, conn: &mut Conn, req_id: u64) {
    match &shared.obs {
        None => conn.push_reply(req_id, "ERR obs disabled".into(), false),
        Some(obs) => {
            conn.watch = Some((obs.watch.subscribe(), req_id));
            conn.push_reply(req_id, "WATCH ok".into(), false);
        }
    }
}

/// End a live `WATCH`: flush any still-queued events, unsubscribe, and
/// push the `WATCH done` trailer (echoing the subscribing request id).
fn end_watch(shared: &Shared, conn: &mut Conn) {
    let Some((token, req_id)) = conn.watch.take() else {
        return;
    };
    if let Some(obs) = &shared.obs {
        for ev in obs.watch.drain(token, usize::MAX) {
            conn.push_reply(0, format!("EVENT {ev}"), false);
        }
        let (delivered, dropped) = obs.watch.unsubscribe(token).unwrap_or((0, 0));
        conn.push_reply(req_id, format!("WATCH done events={delivered} dropped={dropped}"), false);
    }
}

fn frame_action(ctx: &Ctx<'_>, f: &frame::Frame<'_>) -> FrameAction {
    let utf8_err = || FrameAction::Immediate {
        line: "ERR bad frame: payload not utf-8".into(),
        close: true,
    };
    match f.opcode {
        frame::Opcode::Submit => match std::str::from_utf8(f.payload) {
            Err(_) => utf8_err(),
            Ok(args) => {
                match parse_submit(Some(f.tenant as u32), args.split_whitespace()) {
                    Ok(p) => FrameAction::Submit(p),
                    Err(e) => FrameAction::Immediate { line: e, close: false },
                }
            }
        },
        frame::Opcode::Stats => match std::str::from_utf8(f.payload) {
            Err(_) => utf8_err(),
            Ok(sub) => FrameAction::Immediate {
                line: stats_reply(ctx.shared, sub.split_whitespace().next()),
                close: false,
            },
        },
        frame::Opcode::Defrag => FrameAction::Defrag,
        frame::Opcode::Explain => match std::str::from_utf8(f.payload) {
            Err(_) => utf8_err(),
            Ok(arg) => FrameAction::Immediate {
                line: explain_reply(ctx.shared, arg.split_whitespace().next()),
                close: false,
            },
        },
        frame::Opcode::Watch => FrameAction::Watch,
        frame::Opcode::Dump => FrameAction::Immediate {
            line: dump_reply(ctx.shared),
            close: false,
        },
        frame::Opcode::Quit => FrameAction::Immediate { line: "BYE".into(), close: true },
        frame::Opcode::Shutdown => {
            ctx.shared.begin_shutdown();
            FrameAction::Immediate { line: "BYE shutting down".into(), close: true }
        }
        reply => FrameAction::Immediate {
            line: format!("ERR bad frame: reply opcode 0x{:02x} in request", reply.as_u8()),
            close: true,
        },
    }
}

fn apply_action(ctx: &Ctx<'_>, conn: &mut Conn, idx: usize, req_id: u64, action: FrameAction) {
    match action {
        FrameAction::Immediate { line, close } => conn.push_reply(req_id, line, close),
        FrameAction::Submit(p) => dispatch_submit(ctx, conn, idx, req_id, p),
        FrameAction::Defrag => dispatch_defrag(ctx, conn, idx, req_id),
        FrameAction::Watch => begin_watch(ctx.shared, conn, req_id),
    }
}

fn dispatch_text(ctx: &Ctx<'_>, conn: &mut Conn, idx: usize, line: &str) {
    let mut parts = line.split_whitespace();
    match parts.next().map(|s| s.to_ascii_uppercase()).as_deref() {
        Some("SUBMIT") => {
            let tenant = parts.next().and_then(|t| t.parse::<u32>().ok());
            match parse_submit(tenant, parts) {
                Err(e) => conn.push_reply(0, e, false),
                Ok(p) => dispatch_submit(ctx, conn, idx, 0, p),
            }
        }
        Some("STATS") => conn.push_reply(0, stats_reply(ctx.shared, parts.next()), false),
        Some("METRICS") => conn.push_reply(0, metrics_reply(ctx.shared), false),
        Some("EXPLAIN") => conn.push_reply(0, explain_reply(ctx.shared, parts.next()), false),
        Some("WATCH") => begin_watch(ctx.shared, conn, 0),
        Some("DUMP") => conn.push_reply(0, dump_reply(ctx.shared), false),
        Some("DEFRAG") => dispatch_defrag(ctx, conn, idx, 0),
        Some("QUIT") => conn.push_reply(0, "BYE".into(), true),
        Some("SHUTDOWN") => {
            ctx.shared.begin_shutdown();
            conn.push_reply(0, "BYE shutting down".into(), true);
        }
        Some(other) => conn.push_reply(0, format!("ERR unknown command '{other}'"), false),
        None => conn.push_reply(0, "ERR empty command".into(), false),
    }
}

fn dispatch_submit(
    ctx: &Ctx<'_>,
    conn: &mut Conn,
    idx: usize,
    req_id: u64,
    p: super::server::ParsedSubmit,
) {
    let slot = conn.alloc_slot(req_id);
    let sink = ReplySink::Reactor(CompletionSink {
        tx: ctx.completions.clone(),
        waker: ctx.waker.clone(),
        conn: idx,
        gen: conn.gen,
        slot,
    });
    if let Some(busy) = admit(ctx.shared, p, sink) {
        conn.fulfill(slot, busy);
    }
}

fn dispatch_defrag(ctx: &Ctx<'_>, conn: &mut Conn, idx: usize, req_id: u64) {
    let slot = conn.alloc_slot(req_id);
    let sent = ctx.control.is_some_and(|tx| {
        tx.send(ControlMsg::Defrag { conn: idx, gen: conn.gen, slot }).is_ok()
    });
    if !sent {
        conn.fulfill(slot, "ERR coordinator unavailable".into());
    }
}

/// Encode every leading ready reply and push the write buffer to the
/// socket.
fn flush(conn: &mut Conn) -> Verdict {
    while conn.pending.front().is_some_and(|p| p.line.is_some()) {
        let p = conn.pending.pop_front().expect("front checked above");
        let line = p.line.expect("readiness checked above");
        match conn.proto {
            Proto::Binary => {
                let op = frame::Opcode::for_reply_line(&line);
                frame::encode_into(&mut conn.wbuf, op, 0, p.req_id, line.as_bytes());
            }
            _ => {
                conn.wbuf.extend_from_slice(line.as_bytes());
                conn.wbuf.push(b'\n');
            }
        }
    }
    if conn.wbuf.len() - conn.wpos > WBUF_CAP {
        // peer stopped reading while replies piled up
        return Verdict::Close;
    }
    while conn.wpos < conn.wbuf.len() {
        match conn.stream.write(&conn.wbuf[conn.wpos..]) {
            Ok(0) => return Verdict::Close,
            Ok(n) => conn.wpos += n,
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => return Verdict::Close,
        }
    }
    if conn.wpos >= conn.wbuf.len() {
        conn.wbuf.clear();
        conn.wpos = 0;
        if conn.close_after_flush && conn.pending.is_empty() {
            return Verdict::Close;
        }
    } else if conn.wpos >= 64 * 1024 {
        // reclaim the flushed prefix of a large partially-sent buffer
        conn.wbuf.drain(..conn.wpos);
        conn.wpos = 0;
    }
    Verdict::Keep
}

/// Service one connection after a readiness event (or scan pass).
fn service_conn(ctx: &Ctx<'_>, conn: &mut Conn, idx: usize, readable: bool) -> Verdict {
    if readable && !conn.close_after_flush && !ctx.stopping {
        if !read_into(conn) {
            // peer closed/errored: flush anything owed, then close
            conn.close_after_flush = true;
        }
        parse_and_dispatch(ctx, conn, idx);
    }
    flush(conn)
}

// ---------------------------------------------------------------------
// The event loop
// ---------------------------------------------------------------------

/// Handle to a running reactor front.
pub(super) struct ReactorHandle {
    pub(super) join: JoinHandle<()>,
    /// Wakes the loop so an externally-set stop flag is seen promptly.
    pub(super) waker: Arc<Waker>,
}

/// Spawn the reactor event loop (and its DEFRAG control thread) over an
/// already-bound nonblocking listener.
pub(super) fn spawn(
    shared: Arc<Shared>,
    listener: TcpListener,
    protocol: WireProtocolKind,
    idle_timeout: Option<Duration>,
) -> Result<ReactorHandle> {
    let (waker, wake_rx) =
        wake::pair().map_err(|e| Error::Runtime(format!("reactor waker: {e}")))?;
    let waker = Arc::new(waker);
    if let Some(obs) = &shared.obs {
        // journal publishes land on executor threads; nudge the event
        // loop so watchers see them without waiting for the poll tick
        let w = waker.clone();
        obs.watch.set_notifier(Arc::new(move || w.wake()));
    }
    let (completions_tx, completions_rx) = mpsc::channel::<Completion>();
    let (control_tx, control_rx) = mpsc::channel::<ControlMsg>();

    let control = {
        let shared = shared.clone();
        let completions = completions_tx.clone();
        let waker = waker.clone();
        std::thread::Builder::new()
            .name("cgra-control".into())
            .spawn(move || {
                while let Ok(msg) = control_rx.recv() {
                    match msg {
                        ControlMsg::Defrag { conn, gen, slot } => {
                            let line = defrag_reply(&shared);
                            let _ = completions.send(Completion { conn, gen, slot, line });
                            waker.wake();
                        }
                    }
                }
            })
            .map_err(|e| Error::Runtime(format!("spawn control thread: {e}")))?
    };

    let waker_r = waker.clone();
    let join = std::thread::Builder::new()
        .name("cgra-reactor".into())
        .spawn(move || {
            let reactor = Reactor {
                shared,
                listener: Some(listener),
                poller: Poller::new(),
                wake_rx,
                conns: Vec::new(),
                free: Vec::new(),
                live: 0,
                next_gen: 0,
                completions_rx,
                completions_tx,
                waker: waker_r,
                control_tx: Some(control_tx),
                protocol,
                idle_timeout,
                stopping: false,
                stop_at: None,
                last_sweep: Instant::now(),
                progress: true,
            };
            reactor.run();
            // control_tx dropped with the reactor: the control thread's
            // recv fails once queued work drains, then it joins
            let _ = control.join();
        })
        .map_err(|e| Error::Runtime(format!("spawn reactor: {e}")))?;

    Ok(ReactorHandle { join, waker })
}

struct Reactor {
    shared: Arc<Shared>,
    listener: Option<TcpListener>,
    poller: Poller,
    wake_rx: wake::WakeRx,
    conns: Vec<Option<Conn>>,
    free: Vec<usize>,
    live: usize,
    next_gen: u64,
    completions_rx: mpsc::Receiver<Completion>,
    completions_tx: mpsc::Sender<Completion>,
    waker: Arc<Waker>,
    control_tx: Option<mpsc::Sender<ControlMsg>>,
    protocol: WireProtocolKind,
    idle_timeout: Option<Duration>,
    stopping: bool,
    stop_at: Option<Instant>,
    last_sweep: Instant,
    /// Whether the previous pass did any work (scan-poller pacing).
    progress: bool,
}

impl Reactor {
    fn run(mut self) {
        if let Some(l) = &self.listener {
            self.poller.add(fd_of(l), TOKEN_LISTENER, false);
        }
        #[cfg(unix)]
        self.poller.add(fd_of(&self.wake_rx.rx), TOKEN_WAKER, false);

        let mut ready: Vec<(u64, bool, bool)> = Vec::new();
        loop {
            if !self.stopping && self.shared.stop.load(Ordering::SeqCst) {
                self.enter_stopping();
            }
            if self.stopping {
                self.reap(|c| c.drained());
                let deadline_passed =
                    self.stop_at.map(|t| t.elapsed() > DRAIN_DEADLINE).unwrap_or(true);
                if self.live == 0 || deadline_passed {
                    break;
                }
            }

            ready.clear();
            if self.poller.is_scan() {
                if !self.progress {
                    std::thread::sleep(Duration::from_millis(1));
                }
                ready.push((TOKEN_LISTENER, true, true));
                ready.push((TOKEN_WAKER, true, false));
                for idx in 0..self.conns.len() {
                    if self.conns[idx].is_some() {
                        ready.push((idx as u64, true, true));
                    }
                }
            } else {
                #[cfg(target_os = "linux")]
                if let Poller::Epoll(ep) = &self.poller {
                    if let Err(e) = ep.wait(&mut ready, POLL_TIMEOUT_MS) {
                        log::error!("epoll_wait failed: {e}; reactor exiting");
                        break;
                    }
                }
            }

            self.progress = false;
            for &(token, readable, _writable) in &ready {
                match token {
                    TOKEN_LISTENER => self.accept_ready(),
                    TOKEN_WAKER => self.wake_rx.drain(),
                    idx => self.on_conn(idx as usize, readable),
                }
            }
            self.drain_completions();
            self.drain_watchers();
            self.maybe_sweep();
        }
    }

    fn enter_stopping(&mut self) {
        self.stopping = true;
        self.stop_at = Some(Instant::now());
        if let Some(l) = self.listener.take() {
            self.poller.del(fd_of(&l));
        }
        // stop forwarding control-plane work so the control thread can
        // exit once its queue drains
        self.control_tx = None;
        // end every live WATCH so the trailer flushes before the
        // drained-connection reap sees the socket as owed-nothing
        let mut touched = Vec::new();
        for (idx, slot) in self.conns.iter_mut().enumerate() {
            if let Some(conn) = slot.as_mut() {
                if conn.watch.is_some() {
                    end_watch(&self.shared, conn);
                    let _ = flush(conn);
                    touched.push(idx);
                }
            }
        }
        for idx in touched {
            self.sync_write_interest(idx);
        }
    }

    /// Close every connection matching `pred`.
    fn reap(&mut self, pred: impl Fn(&Conn) -> bool) {
        let mut doomed = Vec::new();
        for (i, slot) in self.conns.iter().enumerate() {
            if let Some(c) = slot {
                if pred(c) {
                    doomed.push(i);
                }
            }
        }
        for idx in doomed {
            self.close_conn(idx);
        }
    }

    fn accept_ready(&mut self) {
        if self.stopping {
            return;
        }
        let Some(listener) = &self.listener else { return };
        loop {
            match listener.accept() {
                Ok((stream, _)) => {
                    self.progress = true;
                    if self.live >= MAX_CONNS {
                        drop(stream); // over the slab cap: refuse
                        continue;
                    }
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    stream.set_nodelay(true).ok();
                    self.next_gen += 1;
                    let conn = Conn::new(stream, self.next_gen);
                    let idx = match self.free.pop() {
                        Some(i) => {
                            self.conns[i] = Some(conn);
                            i
                        }
                        None => {
                            self.conns.push(Some(conn));
                            self.conns.len() - 1
                        }
                    };
                    self.live += 1;
                    let fd = fd_of(&self.conns[idx].as_ref().expect("just placed").stream);
                    self.poller.add(fd, idx as u64, false);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => break,
            }
        }
    }

    fn on_conn(&mut self, idx: usize, readable: bool) {
        let ctx = Ctx {
            shared: &self.shared,
            completions: &self.completions_tx,
            waker: &self.waker,
            control: self.control_tx.as_ref(),
            protocol: self.protocol,
            stopping: self.stopping,
        };
        let verdict = match self.conns.get_mut(idx).and_then(|s| s.as_mut()) {
            None => return,
            Some(conn) => {
                let before = conn.rbuf.len() + conn.pending.len() + conn.wbuf.len();
                let v = service_conn(&ctx, conn, idx, readable);
                let after = conn.rbuf.len() + conn.pending.len() + conn.wbuf.len();
                if before != after || v == Verdict::Close {
                    self.progress = true;
                }
                v
            }
        };
        match verdict {
            Verdict::Close => self.close_conn(idx),
            Verdict::Keep => self.sync_write_interest(idx),
        }
    }

    fn drain_completions(&mut self) {
        while let Ok(c) = self.completions_rx.try_recv() {
            self.progress = true;
            let verdict = match self.conns.get_mut(c.conn).and_then(|s| s.as_mut()) {
                None => continue,
                Some(conn) => {
                    if conn.gen != c.gen {
                        continue; // slot was reused by a newer connection
                    }
                    conn.fulfill(c.slot, c.line);
                    conn.last_progress = Instant::now();
                    flush(conn)
                }
            };
            match verdict {
                Verdict::Close => self.close_conn(c.conn),
                Verdict::Keep => self.sync_write_interest(c.conn),
            }
        }
    }

    fn sync_write_interest(&mut self, idx: usize) {
        let Some(conn) = self.conns.get_mut(idx).and_then(|s| s.as_mut()) else {
            return;
        };
        let want = conn.wpos < conn.wbuf.len();
        if want != conn.want_write {
            conn.want_write = want;
            self.poller.modify(fd_of(&conn.stream), idx as u64, want);
        }
    }

    fn close_conn(&mut self, idx: usize) {
        if let Some(slot) = self.conns.get_mut(idx) {
            if let Some(conn) = slot.take() {
                // release a live WATCH subscription so the hub stops
                // queueing (and counting drops) for a dead peer
                if let (Some((token, _)), Some(obs)) = (conn.watch, self.shared.obs.as_ref()) {
                    let _ = obs.watch.unsubscribe(token);
                }
                self.poller.del(fd_of(&conn.stream));
                self.live -= 1;
                self.free.push(idx);
                self.progress = true;
            }
        }
    }

    /// Push freshly-published journal events to every watching
    /// connection (a quiet subscriber is owed nothing until the hub has
    /// queued something for it).
    fn drain_watchers(&mut self) {
        let Some(obs) = self.shared.obs.as_ref() else { return };
        if !obs.watch.has_subscribers() {
            return;
        }
        let mut verdicts = Vec::new();
        for (idx, slot) in self.conns.iter_mut().enumerate() {
            let Some(conn) = slot.as_mut() else { continue };
            let Some((token, _)) = conn.watch else { continue };
            let events = obs.watch.drain(token, WATCH_DRAIN_MAX);
            if events.is_empty() {
                continue;
            }
            self.progress = true;
            for ev in events {
                conn.push_reply(0, format!("EVENT {ev}"), false);
            }
            conn.last_progress = Instant::now();
            verdicts.push((idx, flush(conn)));
        }
        for (idx, v) in verdicts {
            match v {
                Verdict::Close => self.close_conn(idx),
                Verdict::Keep => self.sync_write_interest(idx),
            }
        }
    }

    /// Reap idle connections (those owed nothing whose last completed
    /// request is older than the configured idle timeout).  Watching
    /// connections are exempt: a quiet stream is still a live stream.
    fn maybe_sweep(&mut self) {
        let Some(timeout) = self.idle_timeout else { return };
        let interval = (timeout / 4).max(Duration::from_millis(10));
        let now = Instant::now();
        if now.duration_since(self.last_sweep) < interval {
            return;
        }
        self.last_sweep = now;
        self.reap(|c| {
            c.watch.is_none() && c.drained() && now.duration_since(c.last_progress) > timeout
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[cfg(unix)]
    #[test]
    fn waker_pair_wakes_and_drains() {
        let (waker, mut rx) = wake::pair().unwrap();
        waker.wake();
        waker.wake();
        // drain consumes everything without blocking
        rx.drain();
        let mut probe = [0u8; 8];
        // nonblocking: nothing left
        assert!(matches!(
            (&rx.rx).read(&mut probe),
            Err(ref e) if e.kind() == ErrorKind::WouldBlock
        ));
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn epoll_reports_readiness_with_tokens() {
        use std::os::unix::net::UnixStream;

        let ep = Epoll::new().unwrap();
        let (a, b) = UnixStream::pair().unwrap();
        a.set_nonblocking(true).unwrap();
        ep.ctl(sys::EPOLL_CTL_ADD, fd_of(&a), sys::EPOLLIN, 42).unwrap();
        let mut out = Vec::new();
        ep.wait(&mut out, 0).unwrap();
        assert!(out.is_empty(), "no data yet: {out:?}");
        (&b).write_all(&[9u8]).unwrap();
        let mut out = Vec::new();
        ep.wait(&mut out, 1000).unwrap();
        assert_eq!(out.len(), 1, "{out:?}");
        assert_eq!(out[0].0, 42);
        assert!(out[0].1, "readable");
        ep.ctl(sys::EPOLL_CTL_DEL, fd_of(&a), 0, 0).unwrap();
    }

    #[test]
    fn conn_pending_replies_stay_in_request_order() {
        // a loopback listener gives us a real TcpStream to build a Conn
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let peer = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let (stream, _) = listener.accept().unwrap();
        stream.set_nonblocking(true).unwrap();
        let mut conn = Conn::new(stream, 1);
        conn.proto = Proto::Text;
        let first = conn.alloc_slot(0);
        let second = conn.alloc_slot(0);
        // out-of-order fulfillment must not reorder delivery
        conn.fulfill(second, "OK second".into());
        assert_eq!(flush(&mut conn), Verdict::Keep);
        assert!(conn.wbuf.is_empty(), "first reply still owed");
        conn.fulfill(first, "OK first".into());
        assert_eq!(flush(&mut conn), Verdict::Keep);
        let mut got = String::new();
        let mut reader = std::io::BufReader::new(&peer);
        std::io::BufRead::read_line(&mut reader, &mut got).unwrap();
        assert_eq!(got, "OK first\n");
        got.clear();
        std::io::BufRead::read_line(&mut reader, &mut got).unwrap();
        assert_eq!(got, "OK second\n");
        assert!(conn.drained());
    }
}
