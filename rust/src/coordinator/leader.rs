//! The leader loop: the deployable end-to-end serving path.
//!
//! Drives the identical scheduler/region/DPR machinery as the simulator,
//! but every launch also executes its artifact through PJRT so the
//! output tensors are real.  Virtual time (cycles) carries the paper's
//! timing model; wall time measures the actual compute cost of the
//! functional layer.  This is what `examples/cloud_multitenant.rs` runs
//! and what EXPERIMENTS.md §End-to-end records.
//!
//! The leader is wire-agnostic: both serving fronts (threaded and
//! reactor, either wire encoding) funnel into the same
//! [`Submission`]s here, which is what lets
//! `tests/protocol_conformance.rs` assert byte-identical replies and
//! identical final STATS digests across all of them.

use std::collections::BTreeMap;
use std::sync::atomic::AtomicU64;
use std::sync::Arc;

use crate::config::{Config, QosClass, QosConfig};
use crate::dpr::DprMode;
use crate::error::{Error, Result};
use crate::metrics::{FragmentationGauge, NtatRecord, NtatTracker};
use crate::migration::MigrationReport;
use crate::qos::{QosReport, SloRecord, SloTracker};
use crate::regions::RegionId;
use crate::scheduler::{RequestQueue, Scheduler};
use crate::sim::EventQueue;
use crate::tasks::{AppId, TaskLibrary};

use super::binding::TaskBinding;
use super::router::{Router, TenantId};

/// One submission handed to the leader: tenant, app, virtual arrival
/// cycle, plus optional QoS overrides.  `class`/`deadline_ms` default
/// (`None`) to the `[qos]` config's per-tenant assignment — which is
/// BestEffort / no deadline while the subsystem is disabled.
#[derive(Clone, Copy, Debug)]
pub struct Submission {
    /// Submitting tenant.
    pub tenant: TenantId,
    /// Application.
    pub app: AppId,
    /// Virtual arrival cycle.
    pub at: u64,
    /// Explicit QoS class (wire `SUBMIT <t> <app> <class>`).
    pub class: Option<QosClass>,
    /// Explicit relative deadline in milliseconds from `at`.
    pub deadline_ms: Option<f64>,
}

impl Submission {
    /// Submission with config-default QoS.
    pub fn new(tenant: TenantId, app: AppId, at: u64) -> Submission {
        Submission { tenant, app, at, class: None, deadline_ms: None }
    }
}

/// One served request's outcome.
#[derive(Clone, Debug)]
pub struct ServeOutcome {
    /// Request sequence number.
    pub seq: u64,
    /// Submitting tenant.
    pub tenant: TenantId,
    /// Application.
    pub app: AppId,
    /// Virtual-time turn-around (cycles).
    pub tat_cycles: u64,
    /// Virtual-time NTAT.
    pub ntat: f64,
    /// Wall-clock microseconds spent in PJRT execution for this request.
    pub compute_us: f64,
    /// Output checksum of the request's final task (functional result).
    pub final_output_sum: f64,
}

/// Aggregate serving statistics.
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    /// Completed requests in completion order.
    pub outcomes: Vec<ServeOutcome>,
    /// Virtual-time NTAT tracker (per-app summaries).
    pub ntat: NtatTracker,
    /// Total PJRT wall time (µs).
    pub total_compute_us: f64,
    /// Total task launches.
    pub launches: u64,
    /// Warmup (compile) wall time, ms.
    pub warmup_ms: f64,
}

/// The live coordinator.
pub struct Leader {
    sched: Scheduler,
    queue: RequestQueue,
    router: Router,
    binding: TaskBinding,
    stats: ServeStats,
    /// QoS defaults for submissions without explicit class/deadline.
    qos: QosConfig,
    /// Virtual cycles per millisecond (deadline conversion).
    cycles_per_ms: u64,
    /// Bounded per-class SLO history (`STATS QOS`): cumulative counters
    /// plus a rolling percentile window, so a long-lived server's
    /// memory and per-report cost stay O(window).
    slo: RollingSlo,
}

enum Ev {
    Completion(RegionId),
}

/// Bounded SLO accumulator for the long-lived serving path: per-class
/// counters are cumulative forever, while latency percentiles and
/// slack statistics are computed over a rolling window of the most
/// recent records — so [`Leader::qos_report`] costs O(window) per call
/// and memory never grows with server lifetime (the sims keep using
/// the exact full-run [`SloTracker`]).
struct RollingSlo {
    /// One window per class, so a flood of BestEffort completions can
    /// never evict the (rarer) Critical latency records.
    windows: [std::collections::VecDeque<SloRecord>; 3],
    cap: usize,
    completed: [u64; 3],
    deadlined: [u64; 3],
    missed: [u64; 3],
}

impl RollingSlo {
    fn new(cap: usize) -> RollingSlo {
        RollingSlo {
            windows: std::array::from_fn(|_| std::collections::VecDeque::new()),
            cap: cap.max(1),
            completed: [0; 3],
            deadlined: [0; 3],
            missed: [0; 3],
        }
    }

    fn record(&mut self, rec: SloRecord) {
        let i = rec.class.index();
        self.completed[i] += 1;
        if rec.deadline.is_some() {
            self.deadlined[i] += 1;
        }
        if rec.missed() {
            self.missed[i] += 1;
        }
        if self.windows[i].len() == self.cap {
            self.windows[i].pop_front();
        }
        self.windows[i].push_back(rec);
    }

    /// Report: windowed percentiles/slack, lifetime counters.
    fn report(&self, stats: crate::qos::QosStats) -> QosReport {
        let mut tracker = SloTracker::new();
        for window in &self.windows {
            for r in window {
                tracker.record(*r);
            }
        }
        let mut report = tracker.report(stats);
        for row in report.per_class.iter_mut() {
            let i = row.class.index();
            row.completed = self.completed[i];
            row.deadlined = self.deadlined[i];
            row.missed = self.missed[i];
        }
        report
    }
}

/// Per-request in-flight bookkeeping of one serve loop.
struct InflightReq {
    app: AppId,
    arrival: u64,
    exec_cycles: u64,
    compute_us: f64,
    last_sum: f64,
    class: QosClass,
    deadline: Option<u64>,
}

impl Leader {
    /// Build a leader: scheduler per `cfg`, artifacts from
    /// `cfg.artifacts_dir`, all artifacts pre-compiled (warmup).
    pub fn new(cfg: &Config) -> Result<Leader> {
        Self::build(cfg, Router::new(64))
    }

    /// Build a *shard* leader for a sharded server: identical fabric,
    /// but request sequence numbers come from the pool-shared counter so
    /// completions merged across shard executors stay globally unique
    /// and admission-ordered.
    pub fn new_shard(cfg: &Config, seqs: Arc<AtomicU64>) -> Result<Leader> {
        Self::build(cfg, Router::new_shared(64, seqs))
    }

    fn build(cfg: &Config, router: Router) -> Result<Leader> {
        let runtime = crate::runtime::RuntimeClient::from_dir(&cfg.artifacts_dir)?;
        // Serve wire `pipeline` requests only when the manifest carries
        // the demosaic artifacts: the built-in synthetic manifest always
        // does, while an on-disk artifact build may predate the stage —
        // such a leader keeps the paper-exact Table 1 library.
        let lib = if runtime.manifest().get("demosaic_a").is_ok()
            && runtime.manifest().get("demosaic_b").is_ok()
        {
            TaskLibrary::table1_pipeline()
        } else {
            TaskLibrary::table1()
        };
        let mut sched = Scheduler::new(cfg, lib.clone(), DprMode::Fast);
        sched.preload_all();
        sched.set_obs(cfg.obs.enabled);
        sched.set_provenance(cfg.obs.enabled && cfg.obs.provenance);
        let mut binding = TaskBinding::new(runtime, lib);
        let warmup_ms = binding.warmup()?;
        Ok(Leader {
            sched,
            queue: RequestQueue::new(),
            router,
            binding,
            stats: ServeStats { warmup_ms, ..ServeStats::default() },
            qos: cfg.qos.clone(),
            cycles_per_ms: cfg.arch.core_clock_mhz as u64 * 1000,
            slo: RollingSlo::new(4096),
        })
    }

    /// Serve a batch of (tenant, app) submissions arriving at the given
    /// virtual cycles, running every launched task's artifact.  Returns
    /// when all requests have completed.  QoS classes/deadlines come
    /// from the `[qos]` config defaults; use [`Leader::serve_batch`]
    /// with explicit [`Submission`]s to override per request.
    pub fn serve(&mut self, submissions: &[(TenantId, AppId, u64)]) -> Result<&ServeStats> {
        let subs: Vec<Submission> =
            submissions.iter().map(|&(t, app, at)| Submission::new(t, app, at)).collect();
        self.serve_assigning(&subs)?;
        Ok(&self.stats)
    }

    /// [`Leader::serve`] + drain: returns one entry per submission (in
    /// submission order) with that request's outcome, or `None` when the
    /// scheduler produced none.  This is the sharded server's executor
    /// path — with a pool-shared sequence counter a batch's seqs are
    /// increasing but not necessarily contiguous (another shard may
    /// interleave claims), so correlation must use the actually assigned
    /// seqs rather than `next_seq` arithmetic.
    pub fn serve_batch(
        &mut self,
        submissions: &[Submission],
    ) -> Result<Vec<Option<ServeOutcome>>> {
        let assigned = self.serve_assigning(submissions)?;
        let mut drained: BTreeMap<u64, ServeOutcome> =
            self.drain_outcomes().into_iter().map(|o| (o.seq, o)).collect();
        Ok(assigned.iter().map(|seq| drained.remove(seq)).collect())
    }

    /// The serve loop; returns the seq assigned to each submission, in
    /// the submissions' original order.
    fn serve_assigning(&mut self, submissions: &[Submission]) -> Result<Vec<u64>> {
        // request bookkeeping by seq
        let mut inflight: BTreeMap<u64, InflightReq> = BTreeMap::new();
        let mut events: EventQueue<Ev> = EventQueue::new();
        // launch bookkeeping for completion events: region → finish
        let mut region_info: BTreeMap<RegionId, u64> = BTreeMap::new();

        let mut arrivals: Vec<(usize, &Submission)> = submissions.iter().enumerate().collect();
        arrivals.sort_by_key(|(_, s)| s.at);
        let mut assigned: Vec<u64> = vec![0; submissions.len()];
        let mut next_arrival = 0usize;
        let mut now = 0u64;

        loop {
            // admit every arrival due at or before `now`
            while next_arrival < arrivals.len() && arrivals[next_arrival].1.at <= now {
                let (idx, &sub) = arrivals[next_arrival];
                let class = sub.class.unwrap_or_else(|| self.qos.class_of_tenant(sub.tenant.0));
                let deadline = match sub.deadline_ms {
                    Some(ms) if ms > 0.0 => {
                        Some(sub.at + (ms * self.cycles_per_ms as f64) as u64)
                    }
                    Some(_) => None,
                    None => self.qos.deadline_of_tenant(sub.tenant.0, sub.at, self.cycles_per_ms),
                };
                let seq = self.router.submit_classed(
                    &mut self.queue,
                    sub.tenant,
                    sub.app,
                    sub.at,
                    class,
                    deadline,
                )?;
                assigned[idx] = seq;
                inflight.insert(
                    seq,
                    InflightReq {
                        app: sub.app,
                        arrival: sub.at,
                        exec_cycles: 0,
                        compute_us: 0.0,
                        last_sum: 0.0,
                        class,
                        deadline,
                    },
                );
                next_arrival += 1;
            }

            // schedule + functionally execute every launch.  A resumed
            // (checkpoint-restored) launch does NOT re-run its
            // artifact: the original launch already computed its output
            // and charged its compute time.
            for launch in self.sched.schedule(&mut self.queue, now) {
                self.stats.launches += 1;
                let entry = inflight.get_mut(&launch.instance.request).ok_or_else(|| {
                    Error::SimInvariant(format!("launch for unknown request {}", launch.instance))
                })?;
                if !launch.resumed {
                    let out = self.binding.execute(&launch.task, launch.ver)?;
                    entry.compute_us += out.exec_us;
                    entry.last_sum = out.checksum().sum;
                    self.stats.total_compute_us += out.exec_us;
                }
                entry.exec_cycles += launch.dpr_cycles + launch.exec_cycles;
                region_info.insert(launch.region, launch.finish);
                events.push(launch.finish, Ev::Completion(launch.region));
            }
            // drain eviction records: a victim's un-run remainder
            // re-accrues at resume, so take it back out of serviced
            // cycles (also keeps the log from growing unboundedly in a
            // long-lived server — counters live in qos_stats/SloTracker)
            for p in self.sched.take_preemptions() {
                if let Some(entry) = inflight.get_mut(&p.victim.request) {
                    entry.exec_cycles = entry.exec_cycles.saturating_sub(p.remaining_cycles);
                }
            }

            // advance to the next event: completion or arrival
            let next_event = events.peek_time();
            let next_arr = arrivals.get(next_arrival).map(|(_, s)| s.at);
            match (next_event, next_arr) {
                (None, None) => break,
                (Some(e), Some(a)) if a < e => {
                    now = a;
                    continue;
                }
                (None, Some(a)) => {
                    now = a;
                    continue;
                }
                _ => {}
            }
            let (t, Ev::Completion(region)) = events.pop().expect("peeked");
            now = t;
            // a preempted task's region was released; its checkpointed
            // instance resumes on a fresh region with its own event
            if self.sched.take_cancelled(region) {
                region_info.remove(&region);
                continue;
            }
            // migrations push completions out; re-queue stale events at
            // the scheduler's authoritative finish
            if let Some(finish) = self.sched.finish_of(region) {
                if finish > now {
                    events.push(finish, Ev::Completion(region));
                    continue;
                }
            }
            region_info.remove(&region);
            let inst = self.sched.complete(region, now)?;
            if let Some(done) = self.queue.mark_complete(inst, now)? {
                let req = inflight.remove(&done.seq).expect("inflight");
                let tenant = self.router.complete(done.seq)?;
                let tat = now - req.arrival;
                let exec = req.exec_cycles.max(1);
                let ntat = tat as f64 / exec as f64;
                self.slo.record(SloRecord {
                    class: req.class,
                    arrival: req.arrival,
                    completion: now,
                    deadline: req.deadline,
                });
                self.stats.ntat.record(NtatRecord {
                    app: req.app,
                    arrival: req.arrival,
                    completion: now,
                    exec_cycles: exec,
                });
                self.stats.outcomes.push(ServeOutcome {
                    seq: done.seq,
                    tenant,
                    app: req.app,
                    tat_cycles: tat,
                    ntat,
                    compute_us: req.compute_us,
                    final_output_sum: req.last_sum,
                });
            }
        }
        Ok(assigned)
    }

    /// Serving statistics so far.
    pub fn stats(&self) -> &ServeStats {
        &self.stats
    }

    /// Next request sequence number the router will assign — exact for
    /// a single-fabric leader; a point-in-time read for shard leaders
    /// (the sharded server correlates batches through
    /// [`Leader::serve_batch`] instead).
    pub fn next_seq(&self) -> u64 {
        self.router.next_seq()
    }

    /// Remove and return every completed outcome recorded so far,
    /// resetting the per-request history (the NTAT record list included)
    /// while preserving aggregate counters — launches, total compute
    /// time, warmup.  The long-lived TCP server drains after every batch
    /// so serving history cannot grow without bound; batch-scoped
    /// callers (the `serve` CLI, examples) never drain and keep
    /// cumulative stats.
    pub fn drain_outcomes(&mut self) -> Vec<ServeOutcome> {
        self.stats.ntat = NtatTracker::default();
        std::mem::take(&mut self.stats.outcomes)
    }

    /// Open-request backlog per tenant.  `serve` drains its batch fully
    /// on success, so a non-empty map afterwards identifies tenants
    /// whose requests were stranded by a mid-batch error.
    pub fn backlog_by_tenant(&self) -> BTreeMap<u32, usize> {
        self.queue.open_requests_by_tenant()
    }

    /// The scheduler (region/DPR inspection).
    pub fn scheduler(&self) -> &Scheduler {
        &self.sched
    }

    /// Drain the scheduler's observability instants — the defrag and
    /// migration events recorded while `[obs].enabled` armed them
    /// (always empty otherwise).
    pub fn take_obs_events(&mut self) -> Vec<(u64, crate::obs::JournalKind)> {
        self.sched.take_obs_events()
    }

    /// Drain the scheduler's decision-provenance records — variant
    /// choices, NoFit root causes, preemption rankings, defrag verdicts
    /// — recorded while `[obs].provenance` armed them (always empty
    /// otherwise).  The `EXPLAIN` wire source.
    pub fn take_decisions(&mut self) -> Vec<crate::obs::Decision> {
        self.sched.take_decisions()
    }

    /// Point-in-time fragmentation reading of the fabric.
    pub fn fragmentation(&self) -> FragmentationGauge {
        FragmentationGauge::read(self.sched.regions())
    }

    /// Point-in-time energy reading of the fabric: `(total joules,
    /// windowed watts, governor throttle count)`.  All zero when
    /// `[energy]` accounting is off.
    pub fn energy_snapshot(&self) -> (f64, f64, u64) {
        let e = self.sched.energy();
        (e.total_joules(), e.current_windowed_watts(), e.throttled())
    }

    /// NoC contention report of this leader's fabric (`None` unless
    /// `[noc]` is enabled).  The `STATS NOC` source.
    pub fn noc_report(&self) -> Option<crate::noc::NocReport> {
        self.sched.noc_report()
    }

    /// Per-class SLO report over everything this leader has served —
    /// lifetime completed/deadlined/missed counters, latency
    /// percentiles over the most recent records — with the scheduler's
    /// preemption counters attached.  The `STATS QOS` source; O(window)
    /// per call.
    pub fn qos_report(&self) -> QosReport {
        self.slo.report(self.sched.qos_stats())
    }

    /// Force one compaction pass (the `DEFRAG` wire command).  Between
    /// batches the fabric is drained, so this usually reports a no-op;
    /// it exists as the operator-facing control-plane surface over the
    /// same machinery the scheduler drives automatically mid-batch.
    pub fn defrag(&mut self) -> MigrationReport {
        self.sched.defrag_now(0)
    }

    /// The artifact binding (runtime stats).
    pub fn binding(&self) -> &TaskBinding {
        &self.binding
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[cfg(feature = "xla")]
    fn artifacts_available() -> bool {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/manifest.json")
            .exists()
    }

    /// Same batch as `serves_a_mixed_batch_end_to_end`, driven through
    /// the stub executor's synthetic manifest — runs in every offline
    /// `cargo test`, not just when artifacts are built.
    #[cfg(not(feature = "xla"))]
    #[test]
    fn serves_a_mixed_batch_on_stub_runtime() {
        let mut cfg = presets::paper_default();
        cfg.artifacts_dir = crate::runtime::SYNTHETIC_DIR.into();
        let mut leader = Leader::new(&cfg).unwrap();
        assert_eq!(leader.next_seq(), 0);
        let cycles_per_ms = 500_000;
        let subs = vec![
            (TenantId(2), AppId::Camera, 0),
            (TenantId(3), AppId::Harris, cycles_per_ms / 2),
            (TenantId(1), AppId::MobileNet, cycles_per_ms),
        ];
        let stats = leader.serve(&subs).unwrap();
        assert_eq!(stats.outcomes.len(), 3);
        // camera (1 task) + harris (1) + mobilenet (3 chained)
        assert_eq!(stats.launches, 5);
        assert!(stats.total_compute_us > 0.0);
        assert!(stats.warmup_ms > 0.0);
        for o in &stats.outcomes {
            assert!(o.ntat >= 1.0, "{o:?}");
            assert!(o.final_output_sum.is_finite());
        }
        assert_eq!(leader.next_seq(), 3);
        assert_eq!(leader.scheduler().regions().active_count(), 0);
        assert!(leader.backlog_by_tenant().is_empty());
        // draining hands the history out and resets it, keeping counters
        let drained = leader.drain_outcomes();
        assert_eq!(drained.len(), 3);
        assert!(leader.stats().outcomes.is_empty());
        assert_eq!(leader.stats().launches, 5);
    }

    /// A shard leader draws seqs from the pool-shared counter and
    /// `serve_batch` correlates outcomes by the seqs actually assigned
    /// (they need not start at zero or be contiguous pool-wide).
    #[cfg(not(feature = "xla"))]
    #[test]
    fn serve_batch_correlates_outcomes_in_submission_order() {
        use std::sync::atomic::{AtomicU64, Ordering};
        use std::sync::Arc;

        let mut cfg = presets::paper_default();
        cfg.artifacts_dir = crate::runtime::SYNTHETIC_DIR.into();
        let seqs = Arc::new(AtomicU64::new(5));
        let mut leader = Leader::new_shard(&cfg, seqs.clone()).unwrap();
        let subs = vec![
            Submission::new(TenantId(3), AppId::Harris, 0),
            Submission::new(TenantId(2), AppId::Camera, 0),
        ];
        let outcomes = leader.serve_batch(&subs).unwrap();
        assert_eq!(outcomes.len(), 2);
        let a = outcomes[0].as_ref().expect("harris completes");
        let b = outcomes[1].as_ref().expect("camera completes");
        assert_eq!(a.seq, 5, "first submission gets the first shared seq");
        assert_eq!(b.seq, 6);
        assert_eq!(a.tenant, TenantId(3));
        assert_eq!(b.tenant, TenantId(2));
        // serve_batch drains: history empty, aggregate counters kept
        assert!(leader.stats().outcomes.is_empty());
        assert_eq!(leader.stats().launches, 2);
        assert_eq!(seqs.load(Ordering::Relaxed), 7);
    }

    /// Explicit per-submission class/deadline overrides flow through the
    /// router into the cumulative SLO report (the `STATS QOS` source).
    #[cfg(not(feature = "xla"))]
    #[test]
    fn explicit_qos_submissions_feed_the_slo_report() {
        use crate::config::QosClass;

        let mut cfg = presets::paper_default();
        cfg.artifacts_dir = crate::runtime::SYNTHETIC_DIR.into();
        let mut leader = Leader::new(&cfg).unwrap();
        let mut met = Submission::new(TenantId(3), AppId::Harris, 0);
        met.class = Some(QosClass::Critical);
        met.deadline_ms = Some(60_000.0); // generous: always met
        let mut missed = Submission::new(TenantId(2), AppId::Camera, 0);
        missed.class = Some(QosClass::Critical);
        missed.deadline_ms = Some(0.0001); // ~50 cycles: always missed
        let outcomes = leader.serve_batch(&[met, missed]).unwrap();
        assert!(outcomes.iter().all(|o| o.is_some()));
        let report = leader.qos_report();
        let crit = report.class(QosClass::Critical);
        assert_eq!(crit.completed, 2);
        assert_eq!(crit.deadlined, 2);
        assert_eq!(crit.missed, 1);
        assert!((crit.miss_rate() - 0.5).abs() < 1e-12);
        // default submissions stay BestEffort with no deadline
        let be = report.class(QosClass::BestEffort);
        assert_eq!(be.completed, 0);
        leader.serve(&[(TenantId(1), AppId::Harris, 0)]).unwrap();
        assert_eq!(leader.qos_report().class(QosClass::BestEffort).completed, 1);
        assert_eq!(leader.qos_report().class(QosClass::BestEffort).deadlined, 0);
    }

    /// Between batches the fabric is drained, so the control-plane
    /// defrag is a coherent no-op and the gauge reads zero.
    #[cfg(not(feature = "xla"))]
    #[test]
    fn defrag_between_batches_is_a_clean_noop() {
        let mut cfg = presets::paper_default();
        cfg.artifacts_dir = crate::runtime::SYNTHETIC_DIR.into();
        let mut leader = Leader::new(&cfg).unwrap();
        leader.serve(&[(TenantId(0), AppId::Harris, 0)]).unwrap();
        let g = leader.fragmentation();
        assert_eq!((g.glb_frag, g.array_frag), (0.0, 0.0));
        assert_eq!(g.glb_free, 32);
        let report = leader.defrag();
        assert_eq!(report.migrated, 0);
        assert_eq!(report.cycles, 0);
        assert_eq!(report.frag_before, report.frag_after);
    }

    #[cfg(feature = "xla")]
    #[test]
    fn serves_a_mixed_batch_end_to_end() {
        if !artifacts_available() {
            return;
        }
        let mut cfg = presets::paper_default();
        cfg.artifacts_dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
        let mut leader = Leader::new(&cfg).unwrap();
        let cycles_per_ms = 500_000;
        let subs = vec![
            (TenantId(2), AppId::Camera, 0),
            (TenantId(3), AppId::Harris, cycles_per_ms / 2),
            (TenantId(1), AppId::MobileNet, cycles_per_ms),
        ];
        let stats = leader.serve(&subs).unwrap();
        assert_eq!(stats.outcomes.len(), 3);
        // camera (1 task) + harris (1) + mobilenet (3 chained)
        assert_eq!(stats.launches, 5);
        assert!(stats.total_compute_us > 0.0);
        assert!(stats.warmup_ms > 0.0);
        for o in &stats.outcomes {
            assert!(o.ntat >= 1.0, "{o:?}");
            assert!(o.final_output_sum.is_finite());
        }
        // every region released at the end
        assert_eq!(leader.scheduler().regions().active_count(), 0);
    }
}
