//! The leader loop: the deployable end-to-end serving path.
//!
//! Drives the identical scheduler/region/DPR machinery as the simulator,
//! but every launch also executes its artifact through PJRT so the
//! output tensors are real.  Virtual time (cycles) carries the paper's
//! timing model; wall time measures the actual compute cost of the
//! functional layer.  This is what `examples/cloud_multitenant.rs` runs
//! and what EXPERIMENTS.md §End-to-end records.

use std::collections::BTreeMap;
use std::sync::atomic::AtomicU64;
use std::sync::Arc;

use crate::config::Config;
use crate::dpr::DprMode;
use crate::error::{Error, Result};
use crate::metrics::{FragmentationGauge, NtatRecord, NtatTracker};
use crate::migration::MigrationReport;
use crate::regions::RegionId;
use crate::scheduler::{RequestQueue, Scheduler};
use crate::sim::EventQueue;
use crate::tasks::{AppId, TaskLibrary};

use super::binding::TaskBinding;
use super::router::{Router, TenantId};

/// One served request's outcome.
#[derive(Clone, Debug)]
pub struct ServeOutcome {
    /// Request sequence number.
    pub seq: u64,
    /// Submitting tenant.
    pub tenant: TenantId,
    /// Application.
    pub app: AppId,
    /// Virtual-time turn-around (cycles).
    pub tat_cycles: u64,
    /// Virtual-time NTAT.
    pub ntat: f64,
    /// Wall-clock microseconds spent in PJRT execution for this request.
    pub compute_us: f64,
    /// Output checksum of the request's final task (functional result).
    pub final_output_sum: f64,
}

/// Aggregate serving statistics.
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    /// Completed requests in completion order.
    pub outcomes: Vec<ServeOutcome>,
    /// Virtual-time NTAT tracker (per-app summaries).
    pub ntat: NtatTracker,
    /// Total PJRT wall time (µs).
    pub total_compute_us: f64,
    /// Total task launches.
    pub launches: u64,
    /// Warmup (compile) wall time, ms.
    pub warmup_ms: f64,
}

/// The live coordinator.
pub struct Leader {
    sched: Scheduler,
    queue: RequestQueue,
    router: Router,
    binding: TaskBinding,
    stats: ServeStats,
}

enum Ev {
    Completion(RegionId),
}

impl Leader {
    /// Build a leader: scheduler per `cfg`, artifacts from
    /// `cfg.artifacts_dir`, all artifacts pre-compiled (warmup).
    pub fn new(cfg: &Config) -> Result<Leader> {
        Self::build(cfg, Router::new(64))
    }

    /// Build a *shard* leader for a sharded server: identical fabric,
    /// but request sequence numbers come from the pool-shared counter so
    /// completions merged across shard executors stay globally unique
    /// and admission-ordered.
    pub fn new_shard(cfg: &Config, seqs: Arc<AtomicU64>) -> Result<Leader> {
        Self::build(cfg, Router::new_shared(64, seqs))
    }

    fn build(cfg: &Config, router: Router) -> Result<Leader> {
        let lib = TaskLibrary::table1();
        let mut sched = Scheduler::new(cfg, lib.clone(), DprMode::Fast);
        sched.preload_all();
        let runtime = crate::runtime::RuntimeClient::from_dir(&cfg.artifacts_dir)?;
        let mut binding = TaskBinding::new(runtime, lib);
        let warmup_ms = binding.warmup()?;
        Ok(Leader {
            sched,
            queue: RequestQueue::new(),
            router,
            binding,
            stats: ServeStats { warmup_ms, ..ServeStats::default() },
        })
    }

    /// Serve a batch of (tenant, app) submissions arriving at the given
    /// virtual cycles, running every launched task's artifact.  Returns
    /// when all requests have completed.
    pub fn serve(&mut self, submissions: &[(TenantId, AppId, u64)]) -> Result<&ServeStats> {
        self.serve_assigning(submissions)?;
        Ok(&self.stats)
    }

    /// [`Leader::serve`] + drain: returns one entry per submission (in
    /// submission order) with that request's outcome, or `None` when the
    /// scheduler produced none.  This is the sharded server's executor
    /// path — with a pool-shared sequence counter a batch's seqs are
    /// increasing but not necessarily contiguous (another shard may
    /// interleave claims), so correlation must use the actually assigned
    /// seqs rather than `next_seq` arithmetic.
    pub fn serve_batch(
        &mut self,
        submissions: &[(TenantId, AppId, u64)],
    ) -> Result<Vec<Option<ServeOutcome>>> {
        let assigned = self.serve_assigning(submissions)?;
        let mut drained: BTreeMap<u64, ServeOutcome> =
            self.drain_outcomes().into_iter().map(|o| (o.seq, o)).collect();
        Ok(assigned.iter().map(|seq| drained.remove(seq)).collect())
    }

    /// The serve loop; returns the seq assigned to each submission, in
    /// the submissions' original order.
    fn serve_assigning(&mut self, submissions: &[(TenantId, AppId, u64)]) -> Result<Vec<u64>> {
        // request bookkeeping: seq → (app, arrival, exec cycles, compute µs, last sum)
        let mut inflight: BTreeMap<u64, (AppId, u64, u64, f64, f64)> = BTreeMap::new();
        let mut events: EventQueue<Ev> = EventQueue::new();
        // launch bookkeeping for completion events: region → (seq, dpr+exec)
        let mut region_info: BTreeMap<RegionId, u64> = BTreeMap::new();

        let mut arrivals: Vec<(usize, &(TenantId, AppId, u64))> =
            submissions.iter().enumerate().collect();
        arrivals.sort_by_key(|(_, s)| s.2);
        let mut assigned: Vec<u64> = vec![0; submissions.len()];
        let mut next_arrival = 0usize;
        let mut now = 0u64;

        loop {
            // admit every arrival due at or before `now`
            while next_arrival < arrivals.len() && arrivals[next_arrival].1 .2 <= now {
                let (idx, &(tenant, app, at)) = arrivals[next_arrival];
                let seq = self.router.submit(&mut self.queue, tenant, app, at)?;
                assigned[idx] = seq;
                inflight.insert(seq, (app, at, 0, 0.0, 0.0));
                next_arrival += 1;
            }

            // schedule + functionally execute every launch
            for launch in self.sched.schedule(&mut self.queue, now) {
                self.stats.launches += 1;
                let out = self.binding.execute(&launch.task, launch.ver)?;
                let entry = inflight.get_mut(&launch.instance.request).ok_or_else(|| {
                    Error::SimInvariant(format!("launch for unknown request {}", launch.instance))
                })?;
                entry.2 += launch.dpr_cycles + launch.exec_cycles;
                entry.3 += out.exec_us;
                entry.4 = out.checksum().sum;
                self.stats.total_compute_us += out.exec_us;
                region_info.insert(launch.region, launch.finish);
                events.push(launch.finish, Ev::Completion(launch.region));
            }

            // advance to the next event: completion or arrival
            let next_event = events.peek_time();
            let next_arr = arrivals.get(next_arrival).map(|(_, s)| s.2);
            match (next_event, next_arr) {
                (None, None) => break,
                (Some(e), Some(a)) if a < e => {
                    now = a;
                    continue;
                }
                (None, Some(a)) => {
                    now = a;
                    continue;
                }
                _ => {}
            }
            let (t, Ev::Completion(region)) = events.pop().expect("peeked");
            now = t;
            // migrations push completions out; re-queue stale events at
            // the scheduler's authoritative finish
            if let Some(finish) = self.sched.finish_of(region) {
                if finish > now {
                    events.push(finish, Ev::Completion(region));
                    continue;
                }
            }
            region_info.remove(&region);
            let inst = self.sched.complete(region, now)?;
            if let Some(done) = self.queue.mark_complete(inst, now)? {
                let (app, arrival, exec, compute_us, last_sum) =
                    inflight.remove(&done.seq).expect("inflight");
                let tenant = self.router.complete(done.seq)?;
                let tat = now - arrival;
                let ntat = tat as f64 / exec.max(1) as f64;
                self.stats.ntat.record(NtatRecord {
                    app,
                    arrival,
                    completion: now,
                    exec_cycles: exec.max(1),
                });
                self.stats.outcomes.push(ServeOutcome {
                    seq: done.seq,
                    tenant,
                    app,
                    tat_cycles: tat,
                    ntat,
                    compute_us,
                    final_output_sum: last_sum,
                });
            }
        }
        Ok(assigned)
    }

    /// Serving statistics so far.
    pub fn stats(&self) -> &ServeStats {
        &self.stats
    }

    /// Next request sequence number the router will assign — exact for
    /// a single-fabric leader; a point-in-time read for shard leaders
    /// (the sharded server correlates batches through
    /// [`Leader::serve_batch`] instead).
    pub fn next_seq(&self) -> u64 {
        self.router.next_seq()
    }

    /// Remove and return every completed outcome recorded so far,
    /// resetting the per-request history (the NTAT record list included)
    /// while preserving aggregate counters — launches, total compute
    /// time, warmup.  The long-lived TCP server drains after every batch
    /// so serving history cannot grow without bound; batch-scoped
    /// callers (the `serve` CLI, examples) never drain and keep
    /// cumulative stats.
    pub fn drain_outcomes(&mut self) -> Vec<ServeOutcome> {
        self.stats.ntat = NtatTracker::default();
        std::mem::take(&mut self.stats.outcomes)
    }

    /// Open-request backlog per tenant.  `serve` drains its batch fully
    /// on success, so a non-empty map afterwards identifies tenants
    /// whose requests were stranded by a mid-batch error.
    pub fn backlog_by_tenant(&self) -> BTreeMap<u32, usize> {
        self.queue.open_requests_by_tenant()
    }

    /// The scheduler (region/DPR inspection).
    pub fn scheduler(&self) -> &Scheduler {
        &self.sched
    }

    /// Point-in-time fragmentation reading of the fabric.
    pub fn fragmentation(&self) -> FragmentationGauge {
        FragmentationGauge::read(self.sched.regions())
    }

    /// Point-in-time energy reading of the fabric: `(total joules,
    /// windowed watts, governor throttle count)`.  All zero when
    /// `[energy]` accounting is off.
    pub fn energy_snapshot(&self) -> (f64, f64, u64) {
        let e = self.sched.energy();
        (e.total_joules(), e.current_windowed_watts(), e.throttled())
    }

    /// Force one compaction pass (the `DEFRAG` wire command).  Between
    /// batches the fabric is drained, so this usually reports a no-op;
    /// it exists as the operator-facing control-plane surface over the
    /// same machinery the scheduler drives automatically mid-batch.
    pub fn defrag(&mut self) -> MigrationReport {
        self.sched.defrag_now(0)
    }

    /// The artifact binding (runtime stats).
    pub fn binding(&self) -> &TaskBinding {
        &self.binding
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[cfg(feature = "xla")]
    fn artifacts_available() -> bool {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/manifest.json")
            .exists()
    }

    /// Same batch as `serves_a_mixed_batch_end_to_end`, driven through
    /// the stub executor's synthetic manifest — runs in every offline
    /// `cargo test`, not just when artifacts are built.
    #[cfg(not(feature = "xla"))]
    #[test]
    fn serves_a_mixed_batch_on_stub_runtime() {
        let mut cfg = presets::paper_default();
        cfg.artifacts_dir = crate::runtime::SYNTHETIC_DIR.into();
        let mut leader = Leader::new(&cfg).unwrap();
        assert_eq!(leader.next_seq(), 0);
        let cycles_per_ms = 500_000;
        let subs = vec![
            (TenantId(2), AppId::Camera, 0),
            (TenantId(3), AppId::Harris, cycles_per_ms / 2),
            (TenantId(1), AppId::MobileNet, cycles_per_ms),
        ];
        let stats = leader.serve(&subs).unwrap();
        assert_eq!(stats.outcomes.len(), 3);
        // camera (1 task) + harris (1) + mobilenet (3 chained)
        assert_eq!(stats.launches, 5);
        assert!(stats.total_compute_us > 0.0);
        assert!(stats.warmup_ms > 0.0);
        for o in &stats.outcomes {
            assert!(o.ntat >= 1.0, "{o:?}");
            assert!(o.final_output_sum.is_finite());
        }
        assert_eq!(leader.next_seq(), 3);
        assert_eq!(leader.scheduler().regions().active_count(), 0);
        assert!(leader.backlog_by_tenant().is_empty());
        // draining hands the history out and resets it, keeping counters
        let drained = leader.drain_outcomes();
        assert_eq!(drained.len(), 3);
        assert!(leader.stats().outcomes.is_empty());
        assert_eq!(leader.stats().launches, 5);
    }

    /// A shard leader draws seqs from the pool-shared counter and
    /// `serve_batch` correlates outcomes by the seqs actually assigned
    /// (they need not start at zero or be contiguous pool-wide).
    #[cfg(not(feature = "xla"))]
    #[test]
    fn serve_batch_correlates_outcomes_in_submission_order() {
        use std::sync::atomic::{AtomicU64, Ordering};
        use std::sync::Arc;

        let mut cfg = presets::paper_default();
        cfg.artifacts_dir = crate::runtime::SYNTHETIC_DIR.into();
        let seqs = Arc::new(AtomicU64::new(5));
        let mut leader = Leader::new_shard(&cfg, seqs.clone()).unwrap();
        let subs = vec![(TenantId(3), AppId::Harris, 0), (TenantId(2), AppId::Camera, 0)];
        let outcomes = leader.serve_batch(&subs).unwrap();
        assert_eq!(outcomes.len(), 2);
        let a = outcomes[0].as_ref().expect("harris completes");
        let b = outcomes[1].as_ref().expect("camera completes");
        assert_eq!(a.seq, 5, "first submission gets the first shared seq");
        assert_eq!(b.seq, 6);
        assert_eq!(a.tenant, TenantId(3));
        assert_eq!(b.tenant, TenantId(2));
        // serve_batch drains: history empty, aggregate counters kept
        assert!(leader.stats().outcomes.is_empty());
        assert_eq!(leader.stats().launches, 2);
        assert_eq!(seqs.load(Ordering::Relaxed), 7);
    }

    /// Between batches the fabric is drained, so the control-plane
    /// defrag is a coherent no-op and the gauge reads zero.
    #[cfg(not(feature = "xla"))]
    #[test]
    fn defrag_between_batches_is_a_clean_noop() {
        let mut cfg = presets::paper_default();
        cfg.artifacts_dir = crate::runtime::SYNTHETIC_DIR.into();
        let mut leader = Leader::new(&cfg).unwrap();
        leader.serve(&[(TenantId(0), AppId::Harris, 0)]).unwrap();
        let g = leader.fragmentation();
        assert_eq!((g.glb_frag, g.array_frag), (0.0, 0.0));
        assert_eq!(g.glb_free, 32);
        let report = leader.defrag();
        assert_eq!(report.migrated, 0);
        assert_eq!(report.cycles, 0);
        assert_eq!(report.frag_before, report.frag_after);
    }

    #[cfg(feature = "xla")]
    #[test]
    fn serves_a_mixed_batch_end_to_end() {
        if !artifacts_available() {
            return;
        }
        let mut cfg = presets::paper_default();
        cfg.artifacts_dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
        let mut leader = Leader::new(&cfg).unwrap();
        let cycles_per_ms = 500_000;
        let subs = vec![
            (TenantId(2), AppId::Camera, 0),
            (TenantId(3), AppId::Harris, cycles_per_ms / 2),
            (TenantId(1), AppId::MobileNet, cycles_per_ms),
        ];
        let stats = leader.serve(&subs).unwrap();
        assert_eq!(stats.outcomes.len(), 3);
        // camera (1 task) + harris (1) + mobilenet (3 chained)
        assert_eq!(stats.launches, 5);
        assert!(stats.total_compute_us > 0.0);
        assert!(stats.warmup_ms > 0.0);
        for o in &stats.outcomes {
            assert!(o.ntat >= 1.0, "{o:?}");
            assert!(o.final_output_sum.is_finite());
        }
        // every region released at the end
        assert_eq!(leader.scheduler().regions().active_count(), 0);
    }
}
