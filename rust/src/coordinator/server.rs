//! Concurrent TCP serving front: a sharded worker-pool coordinator.
//!
//! The request path is: accept loop → per-connection reader threads →
//! bounded per-tenant admission queues ([`AdmissionQueues`]) → N
//! scheduler workers that drain round-robin batches → a single leader
//! executor thread that owns the [`Leader`] (and with it the one fabric
//! plus the runtime client, which is not `Send` under `--features xla`).
//! SUBMITs arriving concurrently on different connections are folded
//! into one scheduler invocation per batch, and workers overlap reply
//! fan-out with the executor's next batch.
//!
//! Wire protocol (one line per request, one line per reply):
//!
//! ```text
//! SUBMIT <tenant 0-3> <resnet18|mobilenet|camera|harris>
//!   → OK seq=<n> ntat=<x> tat_ms=<x> compute_us=<x> sum=<x>
//!   → BUSY tenant=<t> queue_depth=<d>     (admission queue full)
//!   → ERR <reason>
//! STATS
//!   → STATS served=<n> queued=<n> rejected=<n> failed=<n> pending=<n>
//!           workers=<n> queue_depth=<n> frag_glb=<x> frag_arr=<x>
//!           migrations=<n>
//! STATS <tenant>
//!   → STATS tenant=<t> served=<n> queued=<n> rejected=<n>
//! DEFRAG
//!   → DEFRAG migrated=<n> cycles=<n> frag_glb=<a>-><b> frag_arr=<a>-><b>
//!   → ERR coordinator unavailable         (executor gone / shutting down)
//! QUIT
//!   → BYE                                 (closes this connection)
//! SHUTDOWN
//!   → BYE shutting down                   (graceful server shutdown)
//! ```
//!
//! `frag_glb`/`frag_arr` are the leader fabric's external-fragmentation
//! gauges ([`crate::metrics::FragmentationGauge`]), refreshed by the
//! executor after every batch; `DEFRAG` forces one compaction pass of
//! the live-migration subsystem ([`crate::migration`]) on the leader.
//!
//! Backpressure is explicit: each tenant's queue is bounded by
//! `server.queue_depth` ([`crate::config::ServerConfig`]); a SUBMIT that
//! finds it full is refused immediately with `BUSY` rather than buffered
//! without bound.  Shutdown via [`Server::shutdown`] or the `SHUTDOWN`
//! wire command is graceful: accepting stops, admitted submissions drain
//! through the scheduler, replies are delivered, then all threads join.
//! (No signal handler is installed — the std library exposes none — so
//! Ctrl-C terminates the process immediately rather than draining.)

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::config::Config;
use crate::error::{Error, Result};
use crate::metrics::ServeCounters;
use crate::tasks::AppId;

use super::leader::Leader;
use super::router::{AdmissionQueues, TenantId};

/// Tenants the wire protocol admits (the cloud scenario's four, Fig. 3a).
pub const TENANTS: u32 = 4;

/// Parse an application name from the wire.
pub fn parse_app(name: &str) -> Option<AppId> {
    match name.to_ascii_lowercase().as_str() {
        "resnet18" | "resnet-18" | "resnet" => Some(AppId::ResNet18),
        "mobilenet" => Some(AppId::MobileNet),
        "camera" | "camera_pipeline" => Some(AppId::Camera),
        "harris" => Some(AppId::Harris),
        _ => None,
    }
}

/// One admitted SUBMIT awaiting a scheduler worker.
struct SubmitJob {
    app: AppId,
    /// Reply line sink of the submitting connection.
    reply: mpsc::Sender<String>,
}

/// Per-submission outcome fields extracted for wire formatting.
struct OutcomeLine {
    seq: u64,
    ntat: f64,
    tat_cycles: u64,
    compute_us: f64,
    sum: f64,
}

/// Work handed to the leader executor thread.
enum ExecRequest {
    /// A batch of admitted submissions.  `resp` carries one entry per
    /// submission (in order); `None` means the scheduler produced no
    /// outcome for that seq.
    Batch {
        subs: Vec<(TenantId, AppId, u64)>,
        resp: mpsc::Sender<std::result::Result<Vec<Option<OutcomeLine>>, String>>,
    },
    /// The `DEFRAG` wire command: force one compaction pass and reply
    /// with the formatted wire line.
    Defrag { resp: mpsc::Sender<String> },
}

/// State shared by connection threads, workers, and STATS rendering.
struct Shared {
    queues: AdmissionQueues<SubmitJob>,
    counters: ServeCounters,
    stop: AtomicBool,
    /// Virtual cycles per millisecond (from the core clock).
    cycles_per_ms: u64,
    workers: usize,
    queue_depth: usize,
    /// Channel to the leader executor for control-plane commands
    /// (`DEFRAG`).  Dropped at shutdown so the executor can exit once
    /// the workers finish draining.
    exec: Mutex<Option<mpsc::Sender<ExecRequest>>>,
    /// Latest GLB fragmentation gauge (f64 bits; executor-refreshed).
    frag_glb_bits: AtomicU64,
    /// Latest array fragmentation gauge (f64 bits).
    frag_arr_bits: AtomicU64,
    /// Cumulative live migrations across the server's lifetime —
    /// accumulated by delta so a leader rebuild (which resets the
    /// scheduler's own counter) never makes the published value regress.
    migrations: AtomicU64,
    /// Last cumulative reading taken from the current leader.
    leader_migrations: AtomicU64,
}

impl Shared {
    fn from_config(cfg: &Config) -> Shared {
        Shared {
            queues: AdmissionQueues::new(TENANTS as usize, cfg.server.queue_depth as usize),
            counters: ServeCounters::new(TENANTS as usize),
            stop: AtomicBool::new(false),
            cycles_per_ms: cfg.arch.core_clock_mhz as u64 * 1000,
            workers: cfg.server.workers.max(1) as usize,
            queue_depth: cfg.server.queue_depth as usize,
            exec: Mutex::new(None),
            frag_glb_bits: AtomicU64::new(0),
            frag_arr_bits: AtomicU64::new(0),
            migrations: AtomicU64::new(0),
            leader_migrations: AtomicU64::new(0),
        }
    }

    /// Begin graceful shutdown: stop accepting, reject new submissions,
    /// let admitted ones drain.
    fn begin_shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        self.queues.close();
        // drop the control-plane sender so the executor's recv() can
        // fail once the workers (the only other senders) exit
        if let Ok(mut exec) = self.exec.lock() {
            *exec = None;
        }
    }

    /// Refresh the fragmentation/migration snapshot from the leader.
    /// `leader_total` is the *current leader's* cumulative migration
    /// count; only the executor thread calls this, so the delta
    /// arithmetic below is single-writer.
    fn record_fabric(&self, frag: (f64, f64), leader_total: u64) {
        self.frag_glb_bits.store(frag.0.to_bits(), Ordering::Relaxed);
        self.frag_arr_bits.store(frag.1.to_bits(), Ordering::Relaxed);
        let last = self.leader_migrations.swap(leader_total, Ordering::Relaxed);
        // a fresh leader (post-rebuild) restarts its counter from zero:
        // everything it reports is new; otherwise only the growth is
        let delta = if leader_total < last { leader_total } else { leader_total - last };
        self.migrations.fetch_add(delta, Ordering::Relaxed);
    }
}

/// Handle one protocol line; returns the reply (without newline) and
/// whether the connection should close.  `reply_tx`/`reply_rx` are the
/// connection's private reply channel: a successful SUBMIT parks on
/// `reply_rx` until a scheduler worker delivers the outcome line.
fn handle_line(
    shared: &Shared,
    reply_tx: &mpsc::Sender<String>,
    reply_rx: &mpsc::Receiver<String>,
    line: &str,
) -> (String, bool) {
    let mut parts = line.split_whitespace();
    match parts.next().map(|s| s.to_ascii_uppercase()).as_deref() {
        Some("SUBMIT") => {
            let tenant = match parts.next().and_then(|t| t.parse::<u32>().ok()) {
                Some(t) if t < TENANTS => TenantId(t),
                _ => return (format!("ERR bad tenant (0-{})", TENANTS - 1), false),
            };
            let app = match parts.next().and_then(parse_app) {
                Some(a) => a,
                None => return ("ERR bad app (resnet18|mobilenet|camera|harris)".into(), false),
            };
            let job = SubmitJob { app, reply: reply_tx.clone() };
            match shared.queues.try_push(tenant, job) {
                Ok(()) => {
                    shared.counters.record_queued(tenant.0 as usize);
                    // Graceful drain delivers replies for admitted jobs
                    // even during shutdown, so keep waiting through stop;
                    // give up only once the pipeline has been quiescent
                    // (stopped + nothing queued) for ~10s — the sign of a
                    // lost worker, not a slow batch.
                    let mut quiescent_ticks = 0u32;
                    loop {
                        match reply_rx.recv_timeout(Duration::from_millis(100)) {
                            Ok(reply) => break (reply, false),
                            Err(mpsc::RecvTimeoutError::Timeout) => {
                                if shared.stop.load(Ordering::SeqCst)
                                    && shared.queues.pending() == 0
                                {
                                    quiescent_ticks += 1;
                                    if quiescent_ticks > 100 {
                                        break ("ERR coordinator unavailable".into(), true);
                                    }
                                } else {
                                    quiescent_ticks = 0;
                                }
                            }
                            Err(mpsc::RecvTimeoutError::Disconnected) => {
                                break ("ERR coordinator unavailable".into(), true)
                            }
                        }
                    }
                }
                Err(_) => {
                    shared.counters.record_rejected(tenant.0 as usize);
                    (
                        format!("BUSY tenant={} queue_depth={}", tenant.0, shared.queue_depth),
                        false,
                    )
                }
            }
        }
        Some("STATS") => match parts.next() {
            Some(t) => match t.parse::<u32>() {
                Ok(t) if t < TENANTS => {
                    let s = shared.counters.tenant(t as usize);
                    (
                        format!(
                            "STATS tenant={t} served={} queued={} rejected={}",
                            s.served, s.queued, s.rejected
                        ),
                        false,
                    )
                }
                _ => (format!("ERR bad tenant (0-{})", TENANTS - 1), false),
            },
            None => {
                let s = shared.counters.totals();
                (
                    format!(
                        "STATS served={} queued={} rejected={} failed={} pending={} \
                         workers={} queue_depth={} frag_glb={:.3} frag_arr={:.3} migrations={}",
                        s.served,
                        s.queued,
                        s.rejected,
                        shared.counters.failed(),
                        shared.queues.pending(),
                        shared.workers,
                        shared.queue_depth,
                        f64::from_bits(shared.frag_glb_bits.load(Ordering::Relaxed)),
                        f64::from_bits(shared.frag_arr_bits.load(Ordering::Relaxed)),
                        shared.migrations.load(Ordering::Relaxed),
                    ),
                    false,
                )
            }
        },
        Some("DEFRAG") => {
            let sender = shared
                .exec
                .lock()
                .ok()
                .and_then(|guard| guard.clone());
            match sender {
                Some(tx) => {
                    let (rtx, rrx) = mpsc::channel();
                    if tx.send(ExecRequest::Defrag { resp: rtx }).is_ok() {
                        match rrx.recv_timeout(Duration::from_secs(10)) {
                            Ok(reply) => (reply, false),
                            Err(_) => ("ERR defrag timed out".into(), false),
                        }
                    } else {
                        ("ERR coordinator unavailable".into(), false)
                    }
                }
                None => ("ERR coordinator unavailable".into(), false),
            }
        }
        Some("QUIT") => ("BYE".into(), true),
        Some("SHUTDOWN") => {
            shared.begin_shutdown();
            ("BYE shutting down".into(), true)
        }
        Some(other) => (format!("ERR unknown command '{other}'"), false),
        None => ("ERR empty command".into(), false),
    }
}

/// Scheduler worker: drain admission batches, hand each to the leader
/// executor as one scheduler invocation, fan the replies back out.
fn run_worker(shared: Arc<Shared>, exec_tx: mpsc::Sender<ExecRequest>, batch_max: usize) {
    while let Some(batch) = shared.queues.pop_batch(batch_max) {
        let subs: Vec<(TenantId, AppId, u64)> =
            batch.iter().map(|(tenant, job)| (*tenant, job.app, 0)).collect();
        let (resp_tx, resp_rx) = mpsc::channel();
        if exec_tx.send(ExecRequest::Batch { subs, resp: resp_tx }).is_err() {
            for (_, job) in batch {
                shared.counters.record_failed();
                let _ = job.reply.send("ERR coordinator executor unavailable".into());
            }
            continue;
        }
        match resp_rx.recv() {
            Ok(Ok(lines)) => {
                for ((tenant, job), line) in batch.into_iter().zip(lines) {
                    match line {
                        Some(o) => {
                            // count before replying so a client's
                            // follow-up STATS observes its own request
                            shared.counters.record_served(tenant.0 as usize);
                            let _ = job.reply.send(format!(
                                "OK seq={} ntat={:.2} tat_ms={:.3} compute_us={:.0} sum={:+.4}",
                                o.seq,
                                o.ntat,
                                o.tat_cycles as f64 / shared.cycles_per_ms as f64,
                                o.compute_us,
                                o.sum
                            ));
                        }
                        None => {
                            shared.counters.record_failed();
                            let _ = job.reply.send("ERR request did not complete".into());
                        }
                    }
                }
            }
            Ok(Err(e)) => {
                for (_, job) in batch {
                    shared.counters.record_failed();
                    let _ = job.reply.send(format!("ERR {e}"));
                }
            }
            Err(_) => {
                for (_, job) in batch {
                    shared.counters.record_failed();
                    let _ = job.reply.send("ERR coordinator executor died".into());
                }
            }
        }
    }
}

/// Leader executor: the single thread that owns the fabric.  Each
/// received batch is one `Leader::serve` invocation; outcomes are
/// correlated to submissions by sequence number (the router assigns them
/// in admission order) and drained per batch so a long-lived server's
/// history stays bounded.
fn run_executor(
    cfg: &Config,
    mut leader: Leader,
    rx: mpsc::Receiver<ExecRequest>,
    shared: &Shared,
) {
    while let Ok(req) = rx.recv() {
        match req {
            ExecRequest::Defrag { resp } => {
                let r = leader.defrag();
                let g = leader.fragmentation();
                shared.record_fabric(
                    (g.glb_frag, g.array_frag),
                    leader.scheduler().migration_stats().tasks_migrated,
                );
                let _ = resp.send(format!(
                    "DEFRAG migrated={} cycles={} frag_glb={:.3}->{:.3} frag_arr={:.3}->{:.3}",
                    r.migrated,
                    r.cycles,
                    r.frag_before.0,
                    r.frag_after.0,
                    r.frag_before.1,
                    r.frag_after.1,
                ));
            }
            ExecRequest::Batch { subs, resp } => {
                let first_seq = leader.next_seq();
                // map the &ServeStats away immediately so the borrow of
                // `leader` ends before the arms below drain or rebuild it
                let served = leader.serve(&subs).map(|_| ()).map_err(|e| e.to_string());
                let result = match served {
                    Ok(()) => {
                        let mut drained: std::collections::BTreeMap<u64, super::ServeOutcome> =
                            leader.drain_outcomes().into_iter().map(|o| (o.seq, o)).collect();
                        let lines = (0..subs.len())
                            .map(|i| {
                                let seq = first_seq + i as u64;
                                drained.remove(&seq).map(|o| OutcomeLine {
                                    seq,
                                    ntat: o.ntat,
                                    tat_cycles: o.tat_cycles,
                                    compute_us: o.compute_us,
                                    sum: o.final_output_sum,
                                })
                            })
                            .collect();
                        Ok(lines)
                    }
                    Err(e) => {
                        // `serve` is not transactional: a mid-batch failure
                        // can strand admitted requests in the router/queue
                        // and would poison every later batch.  Log which
                        // tenants lost work, then rebuild the leader to a
                        // clean fabric.
                        log::error!(
                            "batch of {} failed: {e} (stranded backlog by tenant: {:?})",
                            subs.len(),
                            leader.backlog_by_tenant()
                        );
                        match Leader::new(cfg) {
                            Ok(fresh) => leader = fresh,
                            Err(re) => {
                                log::error!("leader rebuild after failed batch also failed: {re}")
                            }
                        }
                        Err(e)
                    }
                };
                let g = leader.fragmentation();
                shared.record_fabric(
                    (g.glb_frag, g.array_frag),
                    leader.scheduler().migration_stats().tasks_migrated,
                );
                let _ = resp.send(result);
            }
        }
    }
}

fn handle_connection(stream: TcpStream, shared: &Shared) -> std::io::Result<()> {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(Duration::from_millis(100))).ok();
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    let (reply_tx, reply_rx) = mpsc::channel::<String>();
    loop {
        if shared.stop.load(Ordering::SeqCst) {
            break;
        }
        match reader.read_line(&mut line) {
            Ok(0) => break, // client closed
            Ok(_) => {
                let (reply, close) = handle_line(shared, &reply_tx, &reply_rx, line.trim_end());
                line.clear();
                writer.write_all(reply.as_bytes())?;
                writer.write_all(b"\n")?;
                if close {
                    break;
                }
            }
            Err(ref e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // timeout tick: re-check the stop flag.  `read_line` has
                // already appended any partial line it read to `line`,
                // so do NOT clear it here — the next read completes it.
                continue;
            }
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// A running server handle.
pub struct Server {
    /// Bound local address (useful with port 0).
    pub addr: std::net::SocketAddr,
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    executor: Option<JoinHandle<()>>,
}

impl Server {
    /// Start serving on `bind` (e.g. `127.0.0.1:0` for an ephemeral
    /// port).  Spawns the leader executor (which builds the [`Leader`]
    /// on its own thread — the PJRT client is not `Send`),
    /// `cfg.server.workers` scheduler workers, and the accept loop.
    pub fn start(cfg: &Config, bind: &str) -> Result<Server> {
        let listener =
            TcpListener::bind(bind).map_err(|e| Error::io(bind.to_string(), e))?;
        let addr = listener.local_addr().map_err(|e| Error::io(bind.to_string(), e))?;
        listener.set_nonblocking(true).map_err(|e| Error::io(bind.to_string(), e))?;

        let shared = Arc::new(Shared::from_config(cfg));

        // Leader executor: owns scheduler + runtime for the whole server.
        let (exec_tx, exec_rx) = mpsc::channel::<ExecRequest>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let leader_cfg = cfg.clone();
        let shared_e = shared.clone();
        let executor = std::thread::Builder::new()
            .name("cgra-leader".into())
            .spawn(move || {
                let leader = match Leader::new(&leader_cfg) {
                    Ok(l) => {
                        let _ = ready_tx.send(Ok(()));
                        l
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                run_executor(&leader_cfg, leader, exec_rx, &shared_e);
            })
            .map_err(|e| Error::Runtime(format!("spawn executor: {e}")))?;
        match ready_rx.recv() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => {
                let _ = executor.join();
                return Err(e);
            }
            Err(_) => return Err(Error::Runtime("server executor died during startup".into())),
        }

        // Scheduler workers: drain admission queues into executor batches.
        let batch_max = cfg.server.batch_max.max(1) as usize;
        let mut workers = Vec::with_capacity(shared.workers);
        for i in 0..shared.workers {
            let shared_w = shared.clone();
            let tx = exec_tx.clone();
            let worker = std::thread::Builder::new()
                .name(format!("cgra-worker-{i}"))
                .spawn(move || run_worker(shared_w, tx, batch_max))
                .map_err(|e| Error::Runtime(format!("spawn worker {i}: {e}")))?;
            workers.push(worker);
        }
        // Connection threads reach the executor for DEFRAG through this
        // shared sender; `begin_shutdown` drops it, after which the
        // workers (the remaining senders) exiting lets the executor's
        // recv fail and the thread join.
        if let Ok(mut exec) = shared.exec.lock() {
            *exec = Some(exec_tx.clone());
        }
        drop(exec_tx);

        // Accept loop: one reader thread per connection.
        let shared_a = shared.clone();
        let accept = std::thread::Builder::new()
            .name("cgra-accept".into())
            .spawn(move || {
                let mut conns: Vec<JoinHandle<()>> = Vec::new();
                while !shared_a.stop.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let shared_c = shared_a.clone();
                            conns.push(std::thread::spawn(move || {
                                let _ = handle_connection(stream, &shared_c);
                            }));
                        }
                        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            conns.retain(|h| !h.is_finished());
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
                for h in conns {
                    let _ = h.join();
                }
            })
            .map_err(|e| Error::Runtime(format!("spawn accept loop: {e}")))?;

        Ok(Server { addr, shared, accept: Some(accept), workers, executor: Some(executor) })
    }

    /// Graceful shutdown: stop accepting, drain admitted submissions,
    /// deliver their replies, join every thread.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    /// Block until the `SHUTDOWN` wire command requests shutdown, then
    /// drain and join.  (Ctrl-C/SIGTERM terminate the process without
    /// reaching this drain path — no signal handler is installed.)
    pub fn wait(mut self) {
        while !self.shared.stop.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(100));
        }
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.shared.begin_shutdown();
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        if let Some(e) = self.executor.take() {
            let _ = e.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        // idempotent: `shutdown`/`wait` already took the handles
        self.stop_and_join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_shared(depth: usize) -> Shared {
        Shared {
            queues: AdmissionQueues::new(TENANTS as usize, depth),
            counters: ServeCounters::new(TENANTS as usize),
            stop: AtomicBool::new(false),
            cycles_per_ms: 500_000,
            workers: 2,
            queue_depth: depth,
            exec: Mutex::new(None),
            frag_glb_bits: AtomicU64::new(0),
            frag_arr_bits: AtomicU64::new(0),
            migrations: AtomicU64::new(0),
            leader_migrations: AtomicU64::new(0),
        }
    }

    fn line(shared: &Shared, input: &str) -> (String, bool) {
        let (tx, rx) = mpsc::channel();
        handle_line(shared, &tx, &rx, input)
    }

    #[test]
    fn parse_app_aliases_and_rejects() {
        assert_eq!(parse_app("resnet18"), Some(AppId::ResNet18));
        assert_eq!(parse_app("ResNet-18"), Some(AppId::ResNet18));
        assert_eq!(parse_app("RESNET"), Some(AppId::ResNet18));
        assert_eq!(parse_app("mobilenet"), Some(AppId::MobileNet));
        assert_eq!(parse_app("CAMERA"), Some(AppId::Camera));
        assert_eq!(parse_app("camera_pipeline"), Some(AppId::Camera));
        assert_eq!(parse_app("harris"), Some(AppId::Harris));
        assert_eq!(parse_app("nope"), None);
        assert_eq!(parse_app(""), None);
    }

    #[test]
    fn protocol_errors_without_leader() {
        let shared = test_shared(4);
        assert!(line(&shared, "SUBMIT 9 camera").0.starts_with("ERR bad tenant"));
        assert!(line(&shared, "SUBMIT x camera").0.starts_with("ERR bad tenant"));
        assert!(line(&shared, "SUBMIT 1 nope").0.starts_with("ERR bad app"));
        assert!(line(&shared, "FROB").0.starts_with("ERR unknown command"));
        assert!(line(&shared, "").0.starts_with("ERR empty"));
        assert!(line(&shared, "STATS 12").0.starts_with("ERR bad tenant"));
        let (bye, close) = line(&shared, "QUIT");
        assert_eq!(bye, "BYE");
        assert!(close);
        // none of the above touched the admission counters
        assert_eq!(shared.counters.totals(), crate::metrics::TenantSnapshot::default());
    }

    #[test]
    fn busy_backpressure_reply_when_queue_full() {
        let shared = test_shared(1);
        // fill tenant 2's queue directly (no worker is draining)
        let (tx, _rx) = mpsc::channel();
        shared
            .queues
            .try_push(TenantId(2), SubmitJob { app: AppId::Camera, reply: tx })
            .unwrap_or_else(|_| panic!("first push fits"));
        let (reply, close) = line(&shared, "SUBMIT 2 camera");
        assert_eq!(reply, "BUSY tenant=2 queue_depth=1");
        assert!(!close);
        assert_eq!(shared.counters.tenant(2).rejected, 1);
        // other tenants still admitted… but nothing drains them in this
        // test, so only check the error-free tenants' rejection count
        assert_eq!(shared.counters.tenant(0).rejected, 0);
    }

    #[test]
    fn stats_renders_counters_and_pending() {
        let shared = test_shared(8);
        shared.counters.record_queued(0);
        shared.counters.record_served(0);
        shared.counters.record_queued(3);
        shared.counters.record_rejected(3);
        let (stats, close) = line(&shared, "STATS");
        assert!(!close);
        assert!(stats.contains("served=1"), "{stats}");
        assert!(stats.contains("queued=2"), "{stats}");
        assert!(stats.contains("rejected=1"), "{stats}");
        assert!(stats.contains("pending=0"), "{stats}");
        assert!(stats.contains("workers=2"), "{stats}");
        assert!(stats.contains("frag_glb=0.000"), "{stats}");
        assert!(stats.contains("frag_arr=0.000"), "{stats}");
        assert!(stats.contains("migrations=0"), "{stats}");
        let (t3, _) = line(&shared, "STATS 3");
        assert_eq!(t3, "STATS tenant=3 served=0 queued=1 rejected=1");
    }

    #[test]
    fn stats_reflect_recorded_fabric_snapshot() {
        let shared = test_shared(4);
        shared.record_fabric((0.5, 0.25), 7);
        let (stats, _) = line(&shared, "STATS");
        assert!(stats.contains("frag_glb=0.500"), "{stats}");
        assert!(stats.contains("frag_arr=0.250"), "{stats}");
        assert!(stats.contains("migrations=7"), "{stats}");
        // leader rebuild resets the leader-side counter to 0 then counts
        // 2 fresh migrations: the published total must keep growing
        shared.record_fabric((0.0, 0.0), 2);
        let (stats, _) = line(&shared, "STATS");
        assert!(stats.contains("migrations=9"), "{stats}");
        // steady growth on the same leader adds only the delta
        shared.record_fabric((0.0, 0.0), 5);
        let (stats, _) = line(&shared, "STATS");
        assert!(stats.contains("migrations=12"), "{stats}");
    }

    #[test]
    fn defrag_without_executor_is_unavailable() {
        let shared = test_shared(4);
        let (reply, close) = line(&shared, "DEFRAG");
        assert_eq!(reply, "ERR coordinator unavailable");
        assert!(!close);
    }

    #[test]
    fn shutdown_command_begins_graceful_stop() {
        let shared = test_shared(4);
        let (reply, close) = line(&shared, "SHUTDOWN");
        assert_eq!(reply, "BYE shutting down");
        assert!(close);
        assert!(shared.stop.load(Ordering::SeqCst));
        assert!(shared.queues.is_closed());
        // post-shutdown SUBMITs are refused with BUSY
        let (reply, _) = line(&shared, "SUBMIT 0 harris");
        assert!(reply.starts_with("BUSY"), "{reply}");
    }

    /// End-to-end over a real socket on the stub runtime backend (the
    /// synthetic manifest needs no artifacts on disk).
    #[cfg(not(feature = "xla"))]
    #[test]
    fn end_to_end_over_tcp() {
        use std::io::{BufRead, BufReader, Write};

        let mut cfg = crate::config::presets::paper_default();
        cfg.artifacts_dir = crate::runtime::SYNTHETIC_DIR.into();
        let server = Server::start(&cfg, "127.0.0.1:0").unwrap();

        let stream = std::net::TcpStream::connect(server.addr).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        let send = |w: &mut std::net::TcpStream, r: &mut BufReader<std::net::TcpStream>, line: &str| {
            w.write_all(format!("{line}\n").as_bytes()).unwrap();
            let mut reply = String::new();
            r.read_line(&mut reply).unwrap();
            reply.trim_end().to_string()
        };

        let reply = send(&mut writer, &mut reader, "SUBMIT 3 harris");
        assert!(reply.starts_with("OK seq=0"), "{reply}");
        assert!(reply.contains("ntat="), "{reply}");

        let stats = send(&mut writer, &mut reader, "STATS");
        assert!(stats.contains("served=1"), "{stats}");
        assert!(stats.contains("frag_glb="), "{stats}");
        let t3 = send(&mut writer, &mut reader, "STATS 3");
        assert!(t3.contains("tenant=3 served=1 queued=1 rejected=0"), "{t3}");

        // control-plane defrag: fabric is drained between batches, so
        // this reports a clean no-op over the wire
        let defrag = send(&mut writer, &mut reader, "DEFRAG");
        assert!(defrag.starts_with("DEFRAG migrated=0"), "{defrag}");

        let bye = send(&mut writer, &mut reader, "QUIT");
        assert_eq!(bye, "BYE");

        server.shutdown();
    }
}
