//! Minimal TCP serving front for the live coordinator.
//!
//! A line protocol good enough to drive the leader from external load
//! generators (and to demonstrate the system as a deployable service —
//! the request path is: socket → router → scheduler → slice allocation →
//! fast-DPR accounting → PJRT execution → reply):
//!
//! ```text
//! SUBMIT <tenant 0-3> <resnet18|mobilenet|camera|harris>
//!   → OK seq=<n> ntat=<x> tat_ms=<x> compute_us=<x> sum=<x>
//! STATS
//!   → STATS inflight=<n> served=<n> launches=<n> compute_ms=<x>
//! QUIT
//!   → BYE (closes the connection)
//! ```
//!
//! Each SUBMIT is served synchronously (batch of one) — the protocol is
//! deliberately simple; batching across connections is the scheduler's
//! job in the simulated scenarios.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};

use crate::config::Config;
use crate::error::{Error, Result};
use crate::tasks::AppId;

use super::leader::Leader;
use super::router::TenantId;

/// Parse an application name from the wire.
pub fn parse_app(name: &str) -> Option<AppId> {
    match name.to_ascii_lowercase().as_str() {
        "resnet18" | "resnet-18" | "resnet" => Some(AppId::ResNet18),
        "mobilenet" => Some(AppId::MobileNet),
        "camera" | "camera_pipeline" => Some(AppId::Camera),
        "harris" => Some(AppId::Harris),
        _ => None,
    }
}

/// Handle one protocol line; returns the reply (without newline) and
/// whether the connection should close.
pub fn handle_line(leader: &mut Leader, line: &str) -> (String, bool) {
    let mut parts = line.split_whitespace();
    match parts.next().map(|s| s.to_ascii_uppercase()).as_deref() {
        Some("SUBMIT") => {
            let tenant = match parts.next().and_then(|t| t.parse::<u32>().ok()) {
                Some(t) if t < 4 => TenantId(t),
                _ => return ("ERR bad tenant (0-3)".into(), false),
            };
            let app = match parts.next().and_then(parse_app) {
                Some(a) => a,
                None => return ("ERR bad app (resnet18|mobilenet|camera|harris)".into(), false),
            };
            match leader.serve(&[(tenant, app, 0)]) {
                Ok(stats) => match stats.outcomes.last() {
                    Some(o) => (
                        format!(
                            "OK seq={} ntat={:.2} tat_ms={:.3} compute_us={:.0} sum={:+.4}",
                            o.seq,
                            o.ntat,
                            o.tat_cycles as f64 / 500e3,
                            o.compute_us,
                            o.final_output_sum
                        ),
                        false,
                    ),
                    None => ("ERR request did not complete".into(), false),
                },
                Err(e) => (format!("ERR {e}"), false),
            }
        }
        Some("STATS") => {
            let s = leader.stats();
            (
                format!(
                    "STATS served={} launches={} compute_ms={:.1} warmup_ms={:.0}",
                    s.outcomes.len(),
                    s.launches,
                    s.total_compute_us / 1e3,
                    s.warmup_ms
                ),
                false,
            )
        }
        Some("QUIT") => ("BYE".into(), true),
        Some(other) => (format!("ERR unknown command '{other}'"), false),
        None => ("ERR empty command".into(), false),
    }
}

/// A running server handle.
pub struct Server {
    /// Bound local address (useful with port 0).
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start serving on `bind` (e.g. `127.0.0.1:0` for an ephemeral
    /// port).  The leader (whose PJRT client is not `Send`) is built and
    /// owned by a single server thread, which handles connections
    /// sequentially — the serving model of the simulated scenarios, where
    /// one coordinator owns the machine.
    pub fn start(cfg: &Config, bind: &str) -> Result<Server> {
        let listener = TcpListener::bind(bind)
            .map_err(|e| Error::io(bind.to_string(), e))?;
        let addr = listener
            .local_addr()
            .map_err(|e| Error::io(bind.to_string(), e))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| Error::io(bind.to_string(), e))?;

        let stop = Arc::new(AtomicBool::new(false));
        let stop_flag = stop.clone();
        let cfg = cfg.clone();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let thread = std::thread::spawn(move || {
            // Leader lives entirely on this thread (PJRT client is !Send).
            let mut leader = match Leader::new(&cfg) {
                Ok(l) => {
                    let _ = ready_tx.send(Ok(()));
                    l
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            while !stop_flag.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let _ = handle_connection(stream, &mut leader, &stop_flag);
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
        });
        match ready_rx.recv() {
            Ok(Ok(())) => Ok(Server { addr, stop, thread: Some(thread) }),
            Ok(Err(e)) => {
                let _ = thread.join();
                Err(e)
            }
            Err(_) => Err(Error::Runtime("server thread died during startup".into())),
        }
    }

    /// Signal shutdown and join the accept loop.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

fn handle_connection(
    stream: TcpStream,
    leader: &mut Leader,
    stop: &AtomicBool,
) -> std::io::Result<()> {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(std::time::Duration::from_millis(100))).ok();
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => break, // client closed
            Ok(_) => {
                let (reply, close) = handle_line(leader, line.trim_end());
                writer.write_all(reply.as_bytes())?;
                writer.write_all(b"\n")?;
                if close {
                    break;
                }
            }
            Err(ref e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue; // timeout tick: re-check stop flag
            }
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use std::io::{BufRead, BufReader, Write};

    #[test]
    fn parse_app_names() {
        assert_eq!(parse_app("resnet18"), Some(AppId::ResNet18));
        assert_eq!(parse_app("ResNet-18"), Some(AppId::ResNet18));
        assert_eq!(parse_app("CAMERA"), Some(AppId::Camera));
        assert_eq!(parse_app("nope"), None);
    }

    fn artifacts_available() -> Option<String> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json")
            .exists()
            .then(|| dir.display().to_string())
    }

    #[test]
    fn protocol_errors_without_socket() {
        let Some(dir) = artifacts_available() else { return };
        let mut cfg = presets::paper_default();
        cfg.artifacts_dir = dir;
        let mut leader = Leader::new(&cfg).unwrap();
        assert!(handle_line(&mut leader, "SUBMIT 9 camera").0.starts_with("ERR"));
        assert!(handle_line(&mut leader, "SUBMIT 1 nope").0.starts_with("ERR"));
        assert!(handle_line(&mut leader, "FROB").0.starts_with("ERR"));
        assert!(handle_line(&mut leader, "").0.starts_with("ERR"));
        let (bye, close) = handle_line(&mut leader, "QUIT");
        assert_eq!(bye, "BYE");
        assert!(close);
    }

    #[test]
    fn end_to_end_over_tcp() {
        let Some(dir) = artifacts_available() else { return };
        let mut cfg = presets::paper_default();
        cfg.artifacts_dir = dir;
        let server = Server::start(&cfg, "127.0.0.1:0").unwrap();

        let stream = std::net::TcpStream::connect(server.addr).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);

        writer.write_all(b"SUBMIT 3 harris\n").unwrap();
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        assert!(reply.starts_with("OK seq=0"), "{reply}");
        assert!(reply.contains("ntat="), "{reply}");

        writer.write_all(b"STATS\n").unwrap();
        let mut stats = String::new();
        reader.read_line(&mut stats).unwrap();
        assert!(stats.contains("served=1"), "{stats}");

        writer.write_all(b"QUIT\n").unwrap();
        let mut bye = String::new();
        reader.read_line(&mut bye).unwrap();
        assert_eq!(bye.trim(), "BYE");

        server.shutdown();
    }
}
