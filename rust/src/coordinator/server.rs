//! Concurrent TCP serving front: a sharded worker-pool coordinator.
//!
//! The request path is: accept loop → per-connection reader threads →
//! bounded per-tenant admission queues ([`AdmissionQueues`]) → N
//! scheduler workers that drain round-robin batches → `pool.shards`
//! **per-shard leader executor threads**, each owning one [`Leader`]
//! (its own fabric, scheduler and runtime client, which is not `Send`
//! under `--features xla`).  Workers place each batch on a shard under
//! the `pool.placement` policy (least-loaded by outstanding batches;
//! `sticky` pins a tenant to its first shard; `best-fit` degenerates to
//! least-loaded here because every shard is built from the same
//! geometry).  All shard leaders draw request seqs from one shared
//! counter, so the per-shard completion streams merge back into a
//! single globally-unique [`crate::coordinator::Router`] sequence,
//! exactly as before sharding.  With `pool.shards = 1` (the default) the server is
//! byte-for-byte the single-executor coordinator of earlier PRs.
//!
//! This module owns the **thread-per-connection** front (`server.mode =
//! "threaded"`, the default): the accept loop spawns one blocking
//! reader thread per client.  `server.mode = "reactor"` swaps the
//! socket-facing layer for the nonblocking event loop in
//! `coordinator/reactor.rs` — same admission queues, workers, executors
//! and counters; only how bytes reach them changes.  Both fronts (and
//! both wire encodings — the text protocol below and the binary
//! framing of [`crate::coordinator::frame`]) funnel through one
//! protocol core in this module (`parse_submit` / `admit` /
//! `stats_reply` / `defrag_reply`), which is what lets the conformance
//! suite (`tests/protocol_conformance.rs`) hold every reply
//! byte-identical across fronts.
//!
//! Wire protocol (one line per request, one line per reply, except
//! `STATS SHARDS` which replies `1 + pool.shards` lines):
//!
//! ```text
//! SUBMIT <tenant 0-3> <resnet18|mobilenet|camera|harris|pipeline> [class] [deadline_ms]
//!   → OK seq=<n> ntat=<x> tat_ms=<x> compute_us=<x> sum=<x>
//!   → BUSY tenant=<t> queue_depth=<d>     (admission queue full)
//!   → ERR <reason>
//!   class    = critical | interactive | best-effort   (default: the
//!              `[qos]` config's per-tenant class)
//!   deadline_ms = relative virtual-time deadline; 0 clears it
//! STATS
//!   → STATS served=<n> queued=<n> rejected=<n> failed=<n> pending=<n>
//!           workers=<n> queue_depth=<n> frag_glb=<x> frag_arr=<x>
//!           migrations=<n> shards=<n> placement=<policy>
//! STATS <tenant>
//!   → STATS tenant=<t> served=<n> queued=<n> rejected=<n>
//! STATS SHARDS
//!   → STATS shards=<n>                    (then one line per shard:)
//!   → STATS shard=<i> frag_glb=<x> frag_arr=<x> migrations=<n> batches=<n>
//! STATS ENERGY
//!   → STATS shards=<n> energy_j=<x> cap_w=<x> throttle_shrinks=<n>
//!           placement=<policy>            (then one line per shard:)
//!   → STATS shard=<i> energy_j=<x> power_w=<x> throttled=<n>
//! STATS QOS
//!   → STATS classes=3 preemptions=<n> evicted=<n> resumed=<n>
//!                                         (then one line per class:)
//!   → STATS class=<name> completed=<n> deadlined=<n> missed=<n>
//!           miss_rate=<x> p50_ms=<x> p95_ms=<x> p99_ms=<x>
//! STATS NOC
//!   → STATS noc=off                       (`[noc]` disabled)
//!   → STATS noc=on streams=<n> contended=<n> contention_cycles=<n>
//!           stream_in_cycles=<n> affinity_hits=<n> mean_slowdown=<x>
//!           peak_slowdown=<x> corridors=<n> capacity=<n>
//! METRICS
//!   → METRICS lines=<n> dropped=<n>       (then n exposition lines:)
//!   → <Prometheus-style text — serving counters always, plus the
//!     `[obs]` metrics registry when `obs.enabled`; `dropped` counts
//!     journal events lost to the ring cap>
//! EXPLAIN <req>
//!   → EXPLAIN req=<r> lines=<n>           (then n decision-chain lines:)
//!   → <every journal event and provenance decision recorded for that
//!     request seq — lifecycle stages, variant choices with rejected
//!     alternatives, NoFit root causes, preemption rankings>
//!   → ERR obs disabled                    (`[obs]` off)
//! WATCH
//!   → WATCH ok                            (then, until the client
//!     sends any line or closes, one line per live journal event:)
//!   → EVENT <journal line>
//!   → WATCH done events=<n> dropped=<n>   (drops = slow-subscriber
//!     queue overflow; the stream never blocks the serving path)
//! DUMP
//!   → DUMP lines=1                        (then one line:)
//!   → <flight-recorder JSON: journal tail + provenance ring tail +
//!     metrics exposition + `[obs]` config>
//! DEFRAG
//!   → DEFRAG migrated=<n> cycles=<n> frag_glb=<a>-><b> frag_arr=<a>-><b>
//!   → ERR coordinator unavailable         (executors gone / shutting down)
//! QUIT
//!   → BYE                                 (closes this connection)
//! SHUTDOWN
//!   → BYE shutting down                   (graceful server shutdown)
//! ```
//!
//! `frag_glb`/`frag_arr` on the aggregate `STATS` line are the mean of
//! the per-shard external-fragmentation gauges
//! ([`crate::metrics::FragmentationGauge`]), refreshed by each executor
//! after every batch; `migrations` is the pool-wide sum.  `DEFRAG`
//! forces one compaction pass of the live-migration subsystem
//! ([`crate::migration`]) on **every** shard and reports the merged
//! outcome (summed migrated/cycles, mean fragmentation).
//!
//! Backpressure is explicit: each tenant's queue is bounded by
//! `server.queue_depth` ([`crate::config::ServerConfig`]); a SUBMIT that
//! finds it full is refused immediately with `BUSY` rather than buffered
//! without bound.  Shutdown via [`Server::shutdown`] or the `SHUTDOWN`
//! wire command is graceful: accepting stops, admitted submissions drain
//! through the scheduler, replies are delivered, then all threads join.
//! (No signal handler is installed — the std library exposes none — so
//! Ctrl-C terminates the process immediately rather than draining.)

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::config::{Config, ObsConfig, PlacementPolicyKind, QosClass, ServerModeKind};
use crate::error::{Error, Result};
use crate::metrics::ServeCounters;
use crate::noc::NocReport;
use crate::obs::{
    flight_record, Alert, Journal, JournalEvent, JournalKind, MetricsRegistry, ProvenanceRing,
    WatchHub, Watchdog, NO_REQ,
};
use crate::qos::QosReport;
use crate::tasks::AppId;

use super::leader::{Leader, ServeOutcome, Submission};
use super::router::{AdmissionQueues, TenantId};

/// Tenants the wire protocol admits (the cloud scenario's four, Fig. 3a).
pub const TENANTS: u32 = 4;

/// Parse an application name from the wire.
pub fn parse_app(name: &str) -> Option<AppId> {
    match name.to_ascii_lowercase().as_str() {
        "resnet18" | "resnet-18" | "resnet" => Some(AppId::ResNet18),
        "mobilenet" => Some(AppId::MobileNet),
        "camera" | "camera_pipeline" => Some(AppId::Camera),
        "harris" => Some(AppId::Harris),
        "pipeline" | "streaming_pipeline" => Some(AppId::Pipeline),
        _ => None,
    }
}

/// Where a submission's reply line goes: the threaded front's
/// per-connection channel, or the reactor front's completion routing.
pub(super) enum ReplySink {
    /// Thread-per-connection front: the reader thread parks on the
    /// receiving half until a worker sends the outcome line.
    Channel(mpsc::Sender<String>),
    /// Reactor front: routes the line to the event loop by connection
    /// slot + generation, then wakes it.
    Reactor(super::reactor::CompletionSink),
}

impl ReplySink {
    /// Best-effort delivery (a connection that vanished mid-flight is
    /// not an error — the counters were already updated).
    pub(super) fn deliver(&self, line: String) {
        match self {
            ReplySink::Channel(tx) => {
                let _ = tx.send(line);
            }
            ReplySink::Reactor(sink) => sink.deliver(line),
        }
    }
}

/// One admitted SUBMIT awaiting a scheduler worker.
struct SubmitJob {
    app: AppId,
    /// Explicit QoS class from the wire (`None` = config default).
    class: Option<QosClass>,
    /// Explicit relative deadline in ms (`None` = config default).
    deadline_ms: Option<f64>,
    /// Reply line sink of the submitting connection.
    reply: ReplySink,
}

/// A validated SUBMIT, independent of front and wire encoding (the
/// text line and the binary frame both parse into this).
pub(super) struct ParsedSubmit {
    tenant: TenantId,
    app: AppId,
    class: Option<QosClass>,
    deadline_ms: Option<f64>,
}

/// Parse the SUBMIT argument list shared by both wire encodings:
/// `<app> [class] [deadline_ms]`, with the tenant already split off by
/// the caller (the text front reads it from the line, the binary front
/// from the frame header).  Errors are complete reply lines.
pub(super) fn parse_submit<'a>(
    tenant: Option<u32>,
    mut parts: impl Iterator<Item = &'a str>,
) -> std::result::Result<ParsedSubmit, String> {
    let tenant = match tenant {
        Some(t) if t < TENANTS => TenantId(t),
        _ => return Err(format!("ERR bad tenant (0-{})", TENANTS - 1)),
    };
    let app = match parts.next().and_then(parse_app) {
        Some(a) => a,
        None => return Err("ERR bad app (resnet18|mobilenet|camera|harris|pipeline)".into()),
    };
    // optional: [class] [deadline_ms]
    let mut class: Option<QosClass> = None;
    let mut deadline_ms: Option<f64> = None;
    if let Some(tok) = parts.next() {
        match QosClass::from_name(&tok.to_ascii_lowercase()) {
            Ok(c) => class = Some(c),
            Err(_) => return Err("ERR bad class (critical|interactive|best-effort)".into()),
        }
        if let Some(tok) = parts.next() {
            match tok.parse::<f64>() {
                Ok(ms) if ms.is_finite() && ms >= 0.0 => deadline_ms = Some(ms),
                _ => return Err("ERR bad deadline_ms".into()),
            }
        }
    }
    Ok(ParsedSubmit { tenant, app, class, deadline_ms })
}

/// Admit a validated SUBMIT into its tenant's bounded queue.  `None`
/// means admitted (the reply arrives later through `sink`); `Some` is
/// the immediate `BUSY` backpressure reply.
pub(super) fn admit(shared: &Shared, p: ParsedSubmit, sink: ReplySink) -> Option<String> {
    let ParsedSubmit { tenant, app, class, deadline_ms } = p;
    let job = SubmitJob { app, class, deadline_ms, reply: sink };
    match shared.queues.try_push(tenant, job) {
        Ok(()) => {
            shared.counters.record_queued(tenant.0 as usize);
            None
        }
        Err(_) => {
            shared.counters.record_rejected(tenant.0 as usize);
            Some(format!("BUSY tenant={} queue_depth={}", tenant.0, shared.queue_depth))
        }
    }
}

/// Per-submission outcome fields extracted for wire formatting.
struct OutcomeLine {
    seq: u64,
    ntat: f64,
    tat_cycles: u64,
    compute_us: f64,
    sum: f64,
}

/// Outcome of one shard's compaction pass (the `DEFRAG` wire command
/// broadcasts to every shard and merges these).
struct DefragReply {
    migrated: u64,
    cycles: u64,
    before: (f64, f64),
    after: (f64, f64),
}

/// Work handed to a shard's leader executor thread.
enum ExecRequest {
    /// A batch of admitted submissions.  `resp` carries one entry per
    /// submission (in order); `None` means the scheduler produced no
    /// outcome for that seq.
    Batch {
        subs: Vec<Submission>,
        resp: mpsc::Sender<std::result::Result<Vec<Option<OutcomeLine>>, String>>,
    },
    /// The `DEFRAG` wire command: force one compaction pass on this
    /// shard and report its slice of the merged reply.
    Defrag { resp: mpsc::Sender<DefragReply> },
}

/// Per-shard gauge slots, executor-refreshed after every batch.
struct ShardGauges {
    /// Latest GLB fragmentation gauge (f64 bits).
    frag_glb_bits: AtomicU64,
    /// Latest array fragmentation gauge (f64 bits).
    frag_arr_bits: AtomicU64,
    /// Cumulative live migrations on this shard across the server's
    /// lifetime — accumulated by delta so a leader rebuild (which resets
    /// the scheduler's own counter) never makes the published value
    /// regress.
    migrations: AtomicU64,
    /// Last cumulative reading taken from the shard's current leader.
    leader_migrations: AtomicU64,
    /// Batches executed on this shard.
    batches: AtomicU64,
    /// Batches dispatched but not yet answered (placement load).
    outstanding: AtomicU64,
    /// Latest cumulative joules (f64 bits), executor-refreshed.
    energy_j_bits: AtomicU64,
    /// Latest windowed-average power in watts (f64 bits).
    power_w_bits: AtomicU64,
    /// Milliseconds since server start when `power_w_bits` was last
    /// refreshed — a shard only refreshes when *it* processes a batch,
    /// so the throttle path must age readings out (see `batch_cap`).
    power_at_ms: AtomicU64,
    /// Latest governor throttle count of the shard's current leader.
    throttled: AtomicU64,
}

impl ShardGauges {
    fn new() -> ShardGauges {
        ShardGauges {
            frag_glb_bits: AtomicU64::new(0),
            frag_arr_bits: AtomicU64::new(0),
            migrations: AtomicU64::new(0),
            leader_migrations: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            outstanding: AtomicU64::new(0),
            energy_j_bits: AtomicU64::new(0),
            power_w_bits: AtomicU64::new(0),
            power_at_ms: AtomicU64::new(0),
            throttled: AtomicU64::new(0),
        }
    }
}

/// State shared by connection threads (or the reactor), workers, and
/// STATS rendering.
pub(super) struct Shared {
    queues: AdmissionQueues<SubmitJob>,
    counters: ServeCounters,
    pub(super) stop: AtomicBool,
    /// Virtual cycles per millisecond (from the core clock).
    cycles_per_ms: u64,
    workers: usize,
    queue_depth: usize,
    /// Batch placement policy across shard executors.
    placement: PlacementPolicyKind,
    /// Tenant → shard affinity (sticky placement).
    sticky: Mutex<BTreeMap<u32, usize>>,
    /// `[energy].power_cap_watts` (0 = uncapped): workers shrink their
    /// admission batches while any shard's windowed power exceeds it.
    power_cap_watts: f64,
    /// Times a worker shrank its `pop_batch` window because a shard was
    /// over the power cap.
    throttle_shrinks: AtomicU64,
    /// Server start instant (ages power readings in `batch_cap`).
    started: std::time::Instant,
    /// Channels to the per-shard leader executors, for control-plane
    /// commands (`DEFRAG`).  Emptied at shutdown so each executor can
    /// exit once the workers (the remaining senders) finish draining.
    exec: Mutex<Vec<mpsc::Sender<ExecRequest>>>,
    /// One gauge slot per shard.
    shards: Vec<ShardGauges>,
    /// Latest per-shard QoS report, executor-refreshed after every
    /// batch (`STATS QOS` merges across shards).
    qos: Mutex<Vec<Option<QosReport>>>,
    /// Latest per-shard NoC contention report, executor-refreshed after
    /// every batch (`STATS NOC` merges across shards; all `None` while
    /// `[noc]` is disabled).
    noc: Mutex<Vec<Option<NocReport>>>,
    /// Observability surfaces (`[obs].enabled`): the typed metrics
    /// registry every shard executor exports into after each batch, and
    /// the request-lifecycle journal they append to.  `None` keeps the
    /// serving path identical to earlier, obs-less builds.
    pub(super) obs: Option<ObsShared>,
    /// `--dump-metrics` artifact path: flight-recorder snapshots are
    /// written here on watchdog alerts and at shutdown.
    dump_metrics: Option<std::path::PathBuf>,
}

/// Server-side observability state shared by executors and both fronts.
pub(super) struct ObsShared {
    /// Typed metrics registry; the `METRICS` wire command renders it.
    pub(super) registry: MetricsRegistry,
    /// Request-lifecycle journal, fed from served outcomes and the
    /// scheduler's migration/defrag instants.
    pub(super) journal: Mutex<Journal>,
    /// Decision-provenance ring (`[obs].provenance`): the structured
    /// why behind every scheduler choice, queryable via `EXPLAIN`.
    pub(super) provenance: Option<Mutex<ProvenanceRing>>,
    /// Live-stream hub for `WATCH` subscribers.  Always present —
    /// publishing is a no-op without subscribers, and a full subscriber
    /// queue drops-and-counts rather than blocking the serving path.
    pub(super) watch: WatchHub,
    /// SLO burn-rate / utilization / power watchdog (`[obs].watchdog`),
    /// fed by every shard executor and polled after each batch.
    pub(super) watchdog: Option<Mutex<Watchdog>>,
    /// The `[obs]` config block, embedded into flight records.
    pub(super) obs_cfg: ObsConfig,
}

impl ObsShared {
    /// Append one event to the journal, mirroring its rendered line to
    /// any `WATCH` subscribers first so the stream order matches the
    /// journal order.
    pub(super) fn stage(&self, at: u64, req: u64, shard: u32, kind: JournalKind) {
        let ev = JournalEvent { at, req, shard, kind };
        if self.watch.has_subscribers() {
            self.watch.publish(&ev.to_string());
        }
        if let Ok(mut j) = self.journal.lock() {
            j.push(ev);
        }
    }

    /// Journal + count + stream one watchdog alert — the serving-front
    /// arm of [`crate::obs::Obs::raise_alert`].
    pub(super) fn raise_alert(&self, alert: &Alert) {
        self.registry
            .counter("cgra_obs_alerts_total", &[("kind", alert.kind.name())])
            .inc();
        self.stage(
            alert.at,
            NO_REQ,
            alert.shard,
            JournalKind::Alert { what: alert.kind.to_string() },
        );
    }

    /// Cut one flight-recorder snapshot: journal tail + provenance ring
    /// tail + metrics exposition + `[obs]` config, as a JSON document.
    /// `None` only under lock poisoning.
    pub(super) fn flight(&self, reason: &str, at: u64) -> Option<crate::util::json::Json> {
        let journal = self.journal.lock().ok()?;
        let prov = match &self.provenance {
            Some(ring) => Some(ring.lock().ok()?),
            None => None,
        };
        Some(flight_record(reason, at, &journal, prov.as_deref(), &self.registry, &self.obs_cfg))
    }
}

impl Shared {
    fn from_config(cfg: &Config) -> Shared {
        let shard_count = cfg.pool.shards.max(1) as usize;
        Shared {
            queues: AdmissionQueues::new(TENANTS as usize, cfg.server.queue_depth as usize),
            counters: ServeCounters::new(TENANTS as usize),
            stop: AtomicBool::new(false),
            cycles_per_ms: cfg.arch.core_clock_mhz as u64 * 1000,
            workers: cfg.server.workers.max(1) as usize,
            queue_depth: cfg.server.queue_depth as usize,
            placement: cfg.pool.placement,
            power_cap_watts: if cfg.energy.enabled { cfg.energy.power_cap_watts } else { 0.0 },
            throttle_shrinks: AtomicU64::new(0),
            started: std::time::Instant::now(),
            sticky: Mutex::new(BTreeMap::new()),
            exec: Mutex::new(Vec::new()),
            shards: (0..shard_count).map(|_| ShardGauges::new()).collect(),
            qos: Mutex::new(vec![None; shard_count]),
            noc: Mutex::new(vec![None; shard_count]),
            obs: cfg.obs.enabled.then(|| {
                let registry = MetricsRegistry::new();
                registry.build_info();
                ObsShared {
                    registry,
                    journal: Mutex::new(Journal::new(cfg.obs.journal_cap)),
                    provenance: cfg
                        .obs
                        .provenance
                        .then(|| Mutex::new(ProvenanceRing::new(cfg.obs.provenance_cap))),
                    watch: WatchHub::new(cfg.obs.watch_queue_cap),
                    watchdog: cfg.obs.watchdog.then(|| Mutex::new(Watchdog::new(&cfg.obs))),
                    obs_cfg: cfg.obs.clone(),
                }
            }),
            dump_metrics: None,
        }
    }

    /// Write a flight-recorder snapshot to the `--dump-metrics` path
    /// (temp file + rename, so a reader never observes a half-written
    /// artifact).  With `[obs]` disabled the artifact degrades to the
    /// plain metrics exposition.  No-op without a configured path.
    pub(super) fn dump_flight(&self, reason: &str) {
        let Some(path) = &self.dump_metrics else {
            return;
        };
        let body = match &self.obs {
            Some(obs) => {
                let at = self.started.elapsed().as_millis() as u64;
                match obs.flight(reason, at) {
                    Some(doc) => format!("{doc}\n"),
                    None => return,
                }
            }
            None => {
                let reply = metrics_reply(self);
                let mut body = String::new();
                for l in reply.lines().skip(1) {
                    body.push_str(l);
                    body.push('\n');
                }
                body
            }
        };
        let tmp = path.with_extension("tmp");
        if std::fs::write(&tmp, body).is_ok() {
            let _ = std::fs::rename(&tmp, path);
        }
    }

    /// Number of fabric shards behind this server.
    fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Begin graceful shutdown: stop accepting, reject new submissions,
    /// let admitted ones drain.
    pub(super) fn begin_shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        self.queues.close();
        // drop the control-plane senders so each executor's recv() can
        // fail once the workers (the only other senders) exit
        if let Ok(mut exec) = self.exec.lock() {
            exec.clear();
        }
    }

    /// Choose the shard a batch should execute on.  Least-loaded by
    /// outstanding batches (lowest id breaks ties); `sticky` pins a
    /// tenant to the shard its first batch landed on; `best-fit` has no
    /// shape signal here (every shard shares one geometry), so it
    /// degenerates to least-loaded.
    ///
    /// Deliberately *not* [`crate::fabric::FabricRouter`]: placement
    /// here is batch-granular over lock-free load gauges on identical
    /// shards, with no per-request demand to score feasibility against —
    /// the router's ShardLoad probing would add a lock and fabricated
    /// inputs for no additional signal.
    fn pick_shard(&self, tenant: u32) -> usize {
        if self.shards.len() <= 1 {
            return 0;
        }
        let least = |shards: &[ShardGauges]| -> usize {
            (0..shards.len())
                .min_by_key(|&i| (shards[i].outstanding.load(Ordering::Relaxed), i))
                .unwrap_or(0)
        };
        match self.placement {
            // best-fit has no shape signal here (identical shards) and
            // energy-aware no power signal at batch granularity beyond
            // the outstanding gauge — both degenerate to least-loaded;
            // the per-request energy scoring lives in the fabric pool's
            // router ([`crate::fabric::FabricRouter`]).
            PlacementPolicyKind::LeastLoaded
            | PlacementPolicyKind::BestFit
            | PlacementPolicyKind::EnergyAware => least(&self.shards),
            PlacementPolicyKind::Sticky => {
                let mut map = self.sticky.lock().expect("sticky map poisoned");
                *map.entry(tenant).or_insert_with(|| least(&self.shards))
            }
        }
    }

    /// `pick_shard` + immediately bump the chosen shard's outstanding
    /// gauge, so a concurrent worker scanning right after sees the load
    /// and picks elsewhere (pick-then-reserve-later lets every
    /// simultaneous worker pile onto the same least-loaded shard).  The
    /// caller owns the reservation: `release_shard` on send failure or
    /// reply receipt.
    fn pick_and_reserve(&self, tenant: u32) -> usize {
        let shard = self.pick_shard(tenant);
        self.reserve_shard(shard);
        shard
    }

    /// Bump a shard's outstanding-batch gauge.
    fn reserve_shard(&self, shard: usize) {
        self.shards[shard].outstanding.fetch_add(1, Ordering::Relaxed);
    }

    /// Drop a shard's outstanding-batch reservation.
    fn release_shard(&self, shard: usize) {
        self.shards[shard].outstanding.fetch_sub(1, Ordering::Relaxed);
    }

    /// Refresh one shard's fragmentation/migration snapshot.
    /// `leader_total` is that shard's *current leader's* cumulative
    /// migration count; only the shard's executor thread calls this, so
    /// the delta arithmetic below is single-writer per slot.
    fn record_fabric(&self, shard: usize, frag: (f64, f64), leader_total: u64) {
        let Some(slot) = self.shards.get(shard) else {
            return;
        };
        slot.frag_glb_bits.store(frag.0.to_bits(), Ordering::Relaxed);
        slot.frag_arr_bits.store(frag.1.to_bits(), Ordering::Relaxed);
        let last = slot.leader_migrations.swap(leader_total, Ordering::Relaxed);
        // a fresh leader (post-rebuild) restarts its counter from zero:
        // everything it reports is new; otherwise only the growth is
        let delta = if leader_total < last { leader_total } else { leader_total - last };
        slot.migrations.fetch_add(delta, Ordering::Relaxed);
    }

    /// Refresh one shard's energy snapshot (executor-refreshed, like
    /// `record_fabric`).
    fn record_energy(&self, shard: usize, joules: f64, watts: f64, throttled: u64) {
        let Some(slot) = self.shards.get(shard) else {
            return;
        };
        slot.energy_j_bits.store(joules.to_bits(), Ordering::Relaxed);
        slot.power_w_bits.store(watts.to_bits(), Ordering::Relaxed);
        slot
            .power_at_ms
            .store(self.started.elapsed().as_millis() as u64, Ordering::Relaxed);
        slot.throttled.store(throttled, Ordering::Relaxed);
    }

    /// Refresh one shard's QoS report (executor-refreshed, like
    /// `record_fabric`).
    fn record_qos(&self, shard: usize, report: QosReport) {
        if shard >= self.shards.len() {
            return;
        }
        if let Ok(mut slots) = self.qos.lock() {
            slots[shard] = Some(report);
        }
    }

    /// Refresh one shard's NoC report (executor-refreshed, like
    /// `record_fabric`; `None` while `[noc]` is disabled).
    fn record_noc(&self, shard: usize, report: Option<NocReport>) {
        if shard >= self.shards.len() {
            return;
        }
        if let Ok(mut slots) = self.noc.lock() {
            slots[shard] = report;
        }
    }

    /// Merge the per-shard NoC reports for `STATS NOC` (`None` when no
    /// shard has one — `[noc]` disabled).
    fn noc_merged(&self) -> Option<NocReport> {
        let slots = self.noc.lock().map(|g| g.clone()).unwrap_or_default();
        let mut merged: Option<NocReport> = None;
        for report in slots.into_iter().flatten() {
            match merged {
                None => merged = Some(report),
                Some(ref mut m) => m.merge(&report),
            }
        }
        merged
    }

    /// Merge the per-shard QoS reports for `STATS QOS`: counts are
    /// summed; latency percentiles report the worst (max) shard — the
    /// conservative read for an SLO surface.
    fn qos_merged(&self) -> QosReport {
        let slots = self.qos.lock().map(|g| g.clone()).unwrap_or_default();
        let mut merged: Option<QosReport> = None;
        for report in slots.into_iter().flatten() {
            match merged {
                None => merged = Some(report),
                Some(ref mut m) => {
                    for (row, other) in m.per_class.iter_mut().zip(report.per_class.iter()) {
                        row.completed += other.completed;
                        row.deadlined += other.deadlined;
                        row.missed += other.missed;
                        row.p50_latency = row.p50_latency.max(other.p50_latency);
                        row.p95_latency = row.p95_latency.max(other.p95_latency);
                        row.p99_latency = row.p99_latency.max(other.p99_latency);
                        row.mean_slack = row.mean_slack.min(other.mean_slack);
                        row.min_slack = row.min_slack.min(other.min_slack);
                    }
                    m.preemptions += report.preemptions;
                    m.victims_evicted += report.victims_evicted;
                    m.victims_resumed += report.victims_resumed;
                    m.preempt_cycles += report.preempt_cycles;
                }
            }
        }
        merged.unwrap_or_else(|| {
            crate::qos::SloTracker::new().report(crate::qos::QosStats::default())
        })
    }

    /// How long an over-cap reading keeps throttling without being
    /// refreshed.  A shard only refreshes its gauge when it processes a
    /// batch, so a shard that went quiet while hot must age out instead
    /// of serializing admission forever on a stale reading.
    const POWER_READING_FRESH_MS: u64 = 2_000;

    /// Admission batch size for the next `pop_batch`: the configured
    /// maximum, shrunk to 1 while any shard's *fresh* windowed power
    /// reading exceeds `[energy].power_cap_watts` — the serving-path
    /// arm of the power-cap governor (the scheduler-level governor
    /// still gates individual launches inside each batch).
    fn batch_cap(&self, batch_max: usize) -> usize {
        if self.power_cap_watts <= 0.0 {
            return batch_max;
        }
        let now_ms = self.started.elapsed().as_millis() as u64;
        let over = self.shards.iter().any(|s| {
            f64::from_bits(s.power_w_bits.load(Ordering::Relaxed)) > self.power_cap_watts
                && now_ms.saturating_sub(s.power_at_ms.load(Ordering::Relaxed))
                    <= Self::POWER_READING_FRESH_MS
        });
        if over {
            self.throttle_shrinks.fetch_add(1, Ordering::Relaxed);
            1
        } else {
            batch_max
        }
    }

    /// Pool-wide cumulative joules.
    fn energy_total(&self) -> f64 {
        self.shards
            .iter()
            .map(|s| f64::from_bits(s.energy_j_bits.load(Ordering::Relaxed)))
            .sum()
    }

    /// Mean (glb, array) fragmentation across shards.
    fn frag_mean(&self) -> (f64, f64) {
        let n = self.shards.len().max(1) as f64;
        let mut g = 0.0;
        let mut a = 0.0;
        for s in &self.shards {
            g += f64::from_bits(s.frag_glb_bits.load(Ordering::Relaxed));
            a += f64::from_bits(s.frag_arr_bits.load(Ordering::Relaxed));
        }
        (g / n, a / n)
    }

    /// Pool-wide cumulative migrations.
    fn migrations_total(&self) -> u64 {
        self.shards.iter().map(|s| s.migrations.load(Ordering::Relaxed)).sum()
    }
}

/// Handle one protocol line; returns the reply (without newline) and
/// whether the connection should close.  `reply_tx`/`reply_rx` are the
/// connection's private reply channel: a successful SUBMIT parks on
/// `reply_rx` until a scheduler worker delivers the outcome line.
fn handle_line(
    shared: &Shared,
    reply_tx: &mpsc::Sender<String>,
    reply_rx: &mpsc::Receiver<String>,
    line: &str,
) -> (String, bool) {
    let mut parts = line.split_whitespace();
    match parts.next().map(|s| s.to_ascii_uppercase()).as_deref() {
        Some("SUBMIT") => {
            let tenant = parts.next().and_then(|t| t.parse::<u32>().ok());
            let parsed = match parse_submit(tenant, parts) {
                Ok(p) => p,
                Err(e) => return (e, false),
            };
            match admit(shared, parsed, ReplySink::Channel(reply_tx.clone())) {
                Some(busy) => (busy, false),
                None => {
                    // Graceful drain delivers replies for admitted jobs
                    // even during shutdown, so keep waiting through stop;
                    // give up only once the pipeline has been quiescent
                    // (stopped + nothing queued) for ~10s — the sign of a
                    // lost worker, not a slow batch.
                    let mut quiescent_ticks = 0u32;
                    loop {
                        match reply_rx.recv_timeout(Duration::from_millis(100)) {
                            Ok(reply) => break (reply, false),
                            Err(mpsc::RecvTimeoutError::Timeout) => {
                                if shared.stop.load(Ordering::SeqCst)
                                    && shared.queues.pending() == 0
                                {
                                    quiescent_ticks += 1;
                                    if quiescent_ticks > 100 {
                                        break ("ERR coordinator unavailable".into(), true);
                                    }
                                } else {
                                    quiescent_ticks = 0;
                                }
                            }
                            Err(mpsc::RecvTimeoutError::Disconnected) => {
                                break ("ERR coordinator unavailable".into(), true)
                            }
                        }
                    }
                }
            }
        }
        Some("STATS") => (stats_reply(shared, parts.next()), false),
        Some("METRICS") => (metrics_reply(shared), false),
        Some("EXPLAIN") => (explain_reply(shared, parts.next()), false),
        Some("DUMP") => (dump_reply(shared), false),
        // both fronts stream WATCH at the socket layer when obs is on;
        // reaching the shared dispatcher means there is nothing to watch
        Some("WATCH") => ("ERR obs disabled".into(), false),
        Some("DEFRAG") => (defrag_reply(shared), false),
        Some("QUIT") => ("BYE".into(), true),
        Some("SHUTDOWN") => {
            shared.begin_shutdown();
            ("BYE shutting down".into(), true)
        }
        Some(other) => (format!("ERR unknown command '{other}'"), false),
        None => ("ERR empty command".into(), false),
    }
}

/// Render any `STATS [sub]` reply.  Shared by both fronts and both wire
/// encodings; multi-line surfaces join with `\n` and their header line
/// names how many follow.
pub(super) fn stats_reply(shared: &Shared, sub: Option<&str>) -> String {
    match sub {
        Some(t) if t.eq_ignore_ascii_case("qos") => {
            // 1 + 3 lines: header names the class-line count.
            let merged = shared.qos_merged();
            let to_ms = |cycles: f64| cycles / shared.cycles_per_ms as f64;
            let mut out = format!(
                "STATS classes={} preemptions={} evicted={} resumed={}",
                merged.per_class.len(),
                merged.preemptions,
                merged.victims_evicted,
                merged.victims_resumed,
            );
            for row in &merged.per_class {
                out.push_str(&format!(
                    "\nSTATS class={} completed={} deadlined={} missed={} miss_rate={:.3} \
                     p50_ms={:.3} p95_ms={:.3} p99_ms={:.3}",
                    row.class.name(),
                    row.completed,
                    row.deadlined,
                    row.missed,
                    row.miss_rate(),
                    to_ms(row.p50_latency),
                    to_ms(row.p95_latency),
                    to_ms(row.p99_latency),
                ));
            }
            out
        }
        Some(t) if t.eq_ignore_ascii_case("noc") => match shared.noc_merged() {
            None => "STATS noc=off".to_string(),
            Some(r) => format!(
                "STATS noc=on streams={} contended={} contention_cycles={} \
                 stream_in_cycles={} affinity_hits={} mean_slowdown={:.3} \
                 peak_slowdown={:.3} corridors={} capacity={}",
                r.streams_placed,
                r.contended_launches,
                r.contention_cycles,
                r.stream_in_cycles,
                r.affinity_hits,
                r.mean_slowdown,
                r.peak_slowdown,
                r.corridors,
                r.capacity,
            ),
        },
        Some(t) if t.eq_ignore_ascii_case("energy") => {
            // 1 + shard_count lines, same framing as STATS SHARDS:
            // the header names how many per-shard lines follow.
            let mut out = format!(
                "STATS shards={} energy_j={:.6} cap_w={:.3} throttle_shrinks={} placement={}",
                shared.shard_count(),
                shared.energy_total(),
                shared.power_cap_watts,
                shared.throttle_shrinks.load(Ordering::Relaxed),
                shared.placement.name(),
            );
            for (i, slot) in shared.shards.iter().enumerate() {
                out.push_str(&format!(
                    "\nSTATS shard={i} energy_j={:.6} power_w={:.3} throttled={}",
                    f64::from_bits(slot.energy_j_bits.load(Ordering::Relaxed)),
                    f64::from_bits(slot.power_w_bits.load(Ordering::Relaxed)),
                    slot.throttled.load(Ordering::Relaxed),
                ));
            }
            out
        }
        Some(t) if t.eq_ignore_ascii_case("shards") => {
            // 1 + shard_count lines: the header names how many
            // follow, so line-oriented clients stay in sync.
            let mut out = format!("STATS shards={}", shared.shard_count());
            for (i, slot) in shared.shards.iter().enumerate() {
                out.push_str(&format!(
                    "\nSTATS shard={i} frag_glb={:.3} frag_arr={:.3} migrations={} batches={}",
                    f64::from_bits(slot.frag_glb_bits.load(Ordering::Relaxed)),
                    f64::from_bits(slot.frag_arr_bits.load(Ordering::Relaxed)),
                    slot.migrations.load(Ordering::Relaxed),
                    slot.batches.load(Ordering::Relaxed),
                ));
            }
            out
        }
        Some(t) => match t.parse::<u32>() {
            Ok(t) if t < TENANTS => {
                let s = shared.counters.tenant(t as usize);
                format!(
                    "STATS tenant={t} served={} queued={} rejected={}",
                    s.served, s.queued, s.rejected
                )
            }
            _ => format!("ERR bad tenant (0-{})", TENANTS - 1),
        },
        None => {
            let s = shared.counters.totals();
            let frag = shared.frag_mean();
            format!(
                "STATS served={} queued={} rejected={} failed={} pending={} \
                 workers={} queue_depth={} frag_glb={:.3} frag_arr={:.3} migrations={} \
                 shards={} placement={}",
                s.served,
                s.queued,
                s.rejected,
                shared.counters.failed(),
                shared.queues.pending(),
                shared.workers,
                shared.queue_depth,
                frag.0,
                frag.1,
                shared.migrations_total(),
                shared.shard_count(),
                shared.placement.name(),
            )
        }
    }
}

/// Render the `METRICS` reply: a Prometheus-style text exposition of
/// the serving counters (always) plus the `[obs]` registry (when
/// enabled), framed like `STATS SHARDS` — the header names how many
/// exposition lines follow so line-oriented clients stay in sync.
///
/// The admission identity `queued == served + failed + inflight` holds
/// *within one reply*: `inflight` is derived from the same counter
/// snapshot the other three lines render, not sampled separately.
pub(super) fn metrics_reply(shared: &Shared) -> String {
    let mut lines: Vec<String> = Vec::new();
    let totals = shared.counters.totals();
    let failed = shared.counters.failed();
    let inflight = totals.queued.saturating_sub(totals.served + failed);
    lines.push(format!("cgra_serve_queued_total {}", totals.queued));
    lines.push(format!("cgra_serve_served_total {}", totals.served));
    lines.push(format!("cgra_serve_failed_total {failed}"));
    lines.push(format!("cgra_serve_rejected_total {}", totals.rejected));
    lines.push(format!("cgra_serve_inflight {inflight}"));
    lines.push(format!("cgra_serve_shards {}", shared.shard_count()));
    lines.push(format!("cgra_serve_migrations_total {}", shared.migrations_total()));
    let mut dropped = 0u64;
    if let Some(obs) = &shared.obs {
        if let Ok(j) = obs.journal.lock() {
            dropped = j.dropped();
        }
        obs.registry.set_counter("cgra_obs_journal_dropped_total", &[], dropped);
        obs.registry.set_counter(
            "cgra_obs_watch_dropped_total",
            &[],
            obs.watch.dropped_total(),
        );
        lines.extend(obs.registry.render().lines().map(str::to_string));
    }
    let mut out = format!("METRICS lines={} dropped={dropped}", lines.len());
    for l in &lines {
        out.push('\n');
        out.push_str(l);
    }
    out
}

/// Render the `EXPLAIN <req>` reply: the full decision chain recorded
/// for one request sequence number — its journal lifecycle events, then
/// every provenance decision (variant selection with rejected
/// alternatives, NoFit root causes, preemption victim rankings) —
/// framed like `METRICS` (the header names how many lines follow).
pub(super) fn explain_reply(shared: &Shared, arg: Option<&str>) -> String {
    let Some(obs) = &shared.obs else {
        return "ERR obs disabled".into();
    };
    let Some(req) = arg.and_then(|a| a.parse::<u64>().ok()) else {
        return "ERR bad req (decimal sequence number)".into();
    };
    let mut lines: Vec<String> = Vec::new();
    if let Ok(j) = obs.journal.lock() {
        lines.extend(j.events_for(req).map(|e| e.to_string()));
    }
    if let Some(ring) = &obs.provenance {
        if let Ok(r) = ring.lock() {
            lines.extend(r.for_req(req).into_iter().map(|d| d.to_string()));
        }
    }
    let mut out = format!("EXPLAIN req={req} lines={}", lines.len());
    for l in &lines {
        out.push('\n');
        out.push_str(l);
    }
    out
}

/// Render the `DUMP` reply: one flight-recorder JSON document cut at
/// the instant of the request (header line + one JSON line, so the
/// `METRICS`-style count framing holds).
pub(super) fn dump_reply(shared: &Shared) -> String {
    let Some(obs) = &shared.obs else {
        return "ERR obs disabled".into();
    };
    let at = shared.started.elapsed().as_millis() as u64;
    match obs.flight("verb:DUMP", at) {
        Some(doc) => format!("DUMP lines=1\n{doc}"),
        None => "ERR flight recorder unavailable".into(),
    }
}

/// Run the `DEFRAG` wire command: broadcast a compaction pass to every
/// shard executor and merge the replies (summed migrated/cycles, mean
/// gauges).  Shared by both fronts; the reactor runs it on its control
/// thread so the event loop never blocks on the broadcast.
pub(super) fn defrag_reply(shared: &Shared) -> String {
    let senders: Vec<mpsc::Sender<ExecRequest>> =
        shared.exec.lock().map(|guard| guard.clone()).unwrap_or_default();
    if senders.is_empty() {
        return "ERR coordinator unavailable".into();
    }
    let (rtx, rrx) = mpsc::channel();
    let mut expected = 0usize;
    for tx in &senders {
        if tx.send(ExecRequest::Defrag { resp: rtx.clone() }).is_ok() {
            expected += 1;
        }
    }
    drop(rtx);
    if expected == 0 {
        return "ERR coordinator unavailable".into();
    }
    // one overall deadline, not 10 s per shard — a 64-shard pool must
    // not hold the connection for minutes
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    let mut merged: Vec<DefragReply> = Vec::with_capacity(expected);
    for _ in 0..expected {
        let left = deadline.saturating_duration_since(std::time::Instant::now());
        match rrx.recv_timeout(left) {
            Ok(r) => merged.push(r),
            Err(_) => return "ERR defrag timed out".into(),
        }
    }
    let n = merged.len() as f64;
    let migrated: u64 = merged.iter().map(|r| r.migrated).sum();
    let cycles: u64 = merged.iter().map(|r| r.cycles).sum();
    let before_g = merged.iter().map(|r| r.before.0).sum::<f64>() / n;
    let after_g = merged.iter().map(|r| r.after.0).sum::<f64>() / n;
    let before_a = merged.iter().map(|r| r.before.1).sum::<f64>() / n;
    let after_a = merged.iter().map(|r| r.after.1).sum::<f64>() / n;
    format!(
        "DEFRAG migrated={migrated} cycles={cycles} \
         frag_glb={before_g:.3}->{after_g:.3} frag_arr={before_a:.3}->{after_a:.3}",
    )
}

/// Scheduler worker: drain admission batches, place each on a shard
/// executor as one scheduler invocation, fan the replies back out.
///
/// Sticky placement is a *per-tenant* affinity while `pop_batch`
/// deliberately interleaves tenants, so under `sticky` the batch splits
/// into one group per target shard (each tenant reaches its pinned
/// fabric); the load-based policies keep the whole batch together on
/// one shard — the shared-scheduler-invocation win.
fn run_worker(shared: Arc<Shared>, execs: Vec<mpsc::Sender<ExecRequest>>, batch_max: usize) {
    while let Some(batch) = shared.queues.pop_batch(shared.batch_cap(batch_max)) {
        if shared.placement == PlacementPolicyKind::Sticky && shared.shard_count() > 1 {
            let mut groups: BTreeMap<usize, Vec<(TenantId, SubmitJob)>> = BTreeMap::new();
            for (tenant, job) in batch {
                groups.entry(shared.pick_shard(tenant.0)).or_default().push((tenant, job));
            }
            // send every group before collecting any reply, so the
            // target shard executors run the groups concurrently
            let pending: Vec<PendingBatch> = groups
                .into_iter()
                .filter_map(|(shard, group)| {
                    shared.reserve_shard(shard);
                    send_batch(&shared, &execs, shard, group)
                })
                .collect();
            for p in pending {
                collect_batch(&shared, p);
            }
        } else {
            let shard = shared.pick_and_reserve(batch.first().map(|(t, _)| t.0).unwrap_or(0));
            if let Some(p) = send_batch(&shared, &execs, shard, batch) {
                collect_batch(&shared, p);
            }
        }
    }
}

/// One dispatched batch awaiting its shard's reply.
struct PendingBatch {
    shard: usize,
    batch: Vec<(TenantId, SubmitJob)>,
    resp: mpsc::Receiver<std::result::Result<Vec<Option<OutcomeLine>>, String>>,
}

/// Send one batch to `shard`'s executor (whose outstanding gauge the
/// caller already reserved).  On send failure the reservation is
/// released and every job gets an error reply; otherwise the returned
/// handle is collected later via `collect_batch`.
fn send_batch(
    shared: &Shared,
    execs: &[mpsc::Sender<ExecRequest>],
    shard: usize,
    batch: Vec<(TenantId, SubmitJob)>,
) -> Option<PendingBatch> {
    let subs: Vec<Submission> = batch
        .iter()
        .map(|(tenant, job)| Submission {
            tenant: *tenant,
            app: job.app,
            at: 0,
            class: job.class,
            deadline_ms: job.deadline_ms,
        })
        .collect();
    let (resp_tx, resp_rx) = mpsc::channel();
    if execs[shard].send(ExecRequest::Batch { subs, resp: resp_tx }).is_err() {
        shared.release_shard(shard);
        for (_, job) in batch {
            shared.counters.record_failed();
            job.reply.deliver("ERR coordinator executor unavailable".into());
        }
        return None;
    }
    Some(PendingBatch { shard, batch, resp: resp_rx })
}

/// Await one dispatched batch's outcome and fan the replies out.
fn collect_batch(shared: &Shared, pending: PendingBatch) {
    let PendingBatch { shard, batch, resp } = pending;
    let resp = resp.recv();
    shared.release_shard(shard);
    shared.shards[shard].batches.fetch_add(1, Ordering::Relaxed);
    match resp {
        Ok(Ok(lines)) => {
            for ((tenant, job), line) in batch.into_iter().zip(lines) {
                match line {
                    Some(o) => {
                        // count before replying so a client's follow-up
                        // STATS observes its own request
                        shared.counters.record_served(tenant.0 as usize);
                        job.reply.deliver(format!(
                            "OK seq={} ntat={:.2} tat_ms={:.3} compute_us={:.0} sum={:+.4}",
                            o.seq,
                            o.ntat,
                            o.tat_cycles as f64 / shared.cycles_per_ms as f64,
                            o.compute_us,
                            o.sum
                        ));
                    }
                    None => {
                        shared.counters.record_failed();
                        job.reply.deliver("ERR request did not complete".into());
                    }
                }
            }
        }
        Ok(Err(e)) => {
            for (_, job) in batch {
                shared.counters.record_failed();
                job.reply.deliver(format!("ERR {e}"));
            }
        }
        Err(_) => {
            for (_, job) in batch {
                shared.counters.record_failed();
                job.reply.deliver("ERR coordinator executor died".into());
            }
        }
    }
}

/// Append one batch's served outcomes to the lifecycle journal: each
/// request's completion, stamped at its batch-relative completion cycle
/// (server submissions arrive at virtual cycle 0, so the turnaround IS
/// the completion instant) — the serving-path arm of the journal the
/// sim drivers feed through [`crate::obs::Obs::observe`].
fn record_outcomes(obs: &ObsShared, shard: u32, outcomes: &[Option<ServeOutcome>]) {
    for o in outcomes.iter().flatten() {
        obs.stage(o.tat_cycles, o.seq, shard, JournalKind::Completed { tenant: o.tenant.0 });
    }
}

/// Shard leader executor: the single thread that owns one shard's
/// fabric.  Each received batch is one `Leader::serve_batch` invocation
/// (outcomes correlated by the seqs the pool-shared router actually
/// assigned), drained per batch so a long-lived server's history stays
/// bounded.
fn run_executor(
    shard: usize,
    cfg: &Config,
    seqs: &Arc<AtomicU64>,
    mut leader: Leader,
    rx: mpsc::Receiver<ExecRequest>,
    shared: &Shared,
) {
    while let Ok(req) = rx.recv() {
        match req {
            ExecRequest::Defrag { resp } => {
                let r = leader.defrag();
                let g = leader.fragmentation();
                shared.record_fabric(
                    shard,
                    (g.glb_frag, g.array_frag),
                    leader.scheduler().migration_stats().tasks_migrated,
                );
                let (joules, watts, throttled) = leader.energy_snapshot();
                shared.record_energy(shard, joules, watts, throttled);
                let _ = resp.send(DefragReply {
                    migrated: r.migrated,
                    cycles: r.cycles,
                    before: r.frag_before,
                    after: r.frag_after,
                });
            }
            ExecRequest::Batch { subs, resp } => {
                let result = match leader.serve_batch(&subs) {
                    Ok(outcomes) => {
                        if let Some(obs) = &shared.obs {
                            record_outcomes(obs, shard as u32, &outcomes);
                        }
                        Ok(outcomes
                            .into_iter()
                            .map(|o| {
                                o.map(|o| OutcomeLine {
                                    seq: o.seq,
                                    ntat: o.ntat,
                                    tat_cycles: o.tat_cycles,
                                    compute_us: o.compute_us,
                                    sum: o.final_output_sum,
                                })
                            })
                            .collect())
                    }
                    Err(e) => {
                        // `serve` is not transactional: a mid-batch failure
                        // can strand admitted requests in the router/queue
                        // and would poison every later batch.  Log which
                        // tenants lost work, then rebuild this shard's
                        // leader to a clean fabric (seqs keep drawing from
                        // the shared counter, so no collision with peers).
                        log::error!(
                            target: "cgra_mte::coordinator::leader",
                            "shard {shard}: batch of {} failed: {e} \
                             (stranded backlog by tenant: {:?})",
                            subs.len(),
                            leader.backlog_by_tenant()
                        );
                        match Leader::new_shard(cfg, seqs.clone()) {
                            Ok(fresh) => leader = fresh,
                            Err(re) => log::error!(
                                target: "cgra_mte::coordinator::leader",
                                "shard {shard}: leader rebuild after failed batch also failed: {re}"
                            ),
                        }
                        Err(e.to_string())
                    }
                };
                let g = leader.fragmentation();
                shared.record_fabric(
                    shard,
                    (g.glb_frag, g.array_frag),
                    leader.scheduler().migration_stats().tasks_migrated,
                );
                let (joules, watts, throttled) = leader.energy_snapshot();
                shared.record_energy(shard, joules, watts, throttled);
                let qos_report = leader.qos_report();
                if let Some(obs) = &shared.obs {
                    let sl = shard.to_string();
                    obs.registry.counter("cgra_serve_batches_total", &[("shard", &sl)]).inc();
                    leader.scheduler().export_metrics(&obs.registry, Some(shard as u32));
                    for (at, kind) in leader.take_obs_events() {
                        obs.stage(at, NO_REQ, shard as u32, kind);
                    }
                    if let Some(ring) = &obs.provenance {
                        let taken = leader.take_decisions();
                        if !taken.is_empty() {
                            if let Ok(mut r) = ring.lock() {
                                for mut d in taken {
                                    d.shard = shard as u32;
                                    r.push(d);
                                }
                            }
                        }
                    }
                    if let Some(wd) = &obs.watchdog {
                        let alerts = match wd.lock() {
                            Ok(mut w) => {
                                for row in &qos_report.per_class {
                                    w.absorb_cumulative(row.class, row.deadlined, row.missed);
                                }
                                let (_, ua) = leader.scheduler().regions().utilization();
                                w.sample_util(shard as u32, ua);
                                if watts > 0.0 {
                                    w.sample_power(shard as u32, watts);
                                }
                                w.poll(shared.started.elapsed().as_millis() as u64)
                            }
                            Err(_) => Vec::new(),
                        };
                        for a in &alerts {
                            obs.raise_alert(a);
                        }
                        if !alerts.is_empty() {
                            shared.dump_flight("alert");
                        }
                    }
                }
                shared.record_qos(shard, qos_report);
                shared.record_noc(shard, leader.noc_report());
                let _ = resp.send(result);
            }
        }
    }
}

/// Per-iteration drain cap while streaming a `WATCH` subscription.
pub(super) const WATCH_DRAIN_MAX: usize = 256;

/// Stream journal events to a `WATCH` subscriber on the threaded front:
/// `WATCH ok`, then one `EVENT <journal line>` per published event,
/// until the client sends any line (which ends the watch and is
/// consumed, not executed), the peer closes, or the server stops — then
/// `WATCH done events=<delivered> dropped=<dropped>`.  Returns whether
/// the connection should close (peer gone).
fn serve_watch(
    shared: &Shared,
    obs: &ObsShared,
    writer: &mut TcpStream,
    reader: &mut BufReader<TcpStream>,
    line: &mut String,
) -> std::io::Result<bool> {
    let token = obs.watch.subscribe();
    let res = watch_loop(shared, obs, token, writer, reader, line);
    let (delivered, dropped) = obs.watch.unsubscribe(token).unwrap_or((0, 0));
    match res {
        // peer closed mid-watch: no one is listening for the trailer
        Ok(true) => Ok(true),
        Ok(false) => {
            writer.write_all(
                format!("WATCH done events={delivered} dropped={dropped}\n").as_bytes(),
            )?;
            Ok(false)
        }
        Err(e) => Err(e),
    }
}

/// Inner loop of [`serve_watch`]; returns whether the peer closed.  The
/// connection's existing 100 ms read timeout doubles as the poll tick.
fn watch_loop(
    shared: &Shared,
    obs: &ObsShared,
    token: u64,
    writer: &mut TcpStream,
    reader: &mut BufReader<TcpStream>,
    line: &mut String,
) -> std::io::Result<bool> {
    writer.write_all(b"WATCH ok\n")?;
    loop {
        for ev in obs.watch.drain(token, WATCH_DRAIN_MAX) {
            writer.write_all(format!("EVENT {ev}\n").as_bytes())?;
        }
        if shared.stop.load(Ordering::SeqCst) {
            return Ok(false);
        }
        match reader.read_line(line) {
            Ok(0) => return Ok(true),
            Ok(_) => {
                line.clear();
                // deliver anything already queued before the trailer
                for ev in obs.watch.drain(token, WATCH_DRAIN_MAX) {
                    writer.write_all(format!("EVENT {ev}\n").as_bytes())?;
                }
                return Ok(false);
            }
            Err(ref e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut => {}
            Err(e) => return Err(e),
        }
    }
}

fn handle_connection(stream: TcpStream, shared: &Shared) -> std::io::Result<()> {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(Duration::from_millis(100))).ok();
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    let (reply_tx, reply_rx) = mpsc::channel::<String>();
    loop {
        if shared.stop.load(Ordering::SeqCst) {
            break;
        }
        match reader.read_line(&mut line) {
            Ok(0) => break, // client closed
            Ok(_) => {
                let is_watch = line
                    .split_whitespace()
                    .next()
                    .is_some_and(|t| t.eq_ignore_ascii_case("WATCH"));
                if is_watch {
                    if let Some(obs) = &shared.obs {
                        line.clear();
                        if serve_watch(shared, obs, &mut writer, &mut reader, &mut line)? {
                            break;
                        }
                        continue;
                    }
                    // obs off: fall through to the dispatcher's ERR
                }
                let (reply, close) = handle_line(shared, &reply_tx, &reply_rx, line.trim_end());
                line.clear();
                writer.write_all(reply.as_bytes())?;
                writer.write_all(b"\n")?;
                if close {
                    break;
                }
            }
            Err(ref e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // timeout tick: re-check the stop flag.  `read_line` has
                // already appended any partial line it read to `line`,
                // so do NOT clear it here — the next read completes it.
                continue;
            }
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// A running server handle.
pub struct Server {
    /// Bound local address (useful with port 0).
    pub addr: std::net::SocketAddr,
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
    reactor: Option<super::reactor::ReactorHandle>,
    workers: Vec<JoinHandle<()>>,
    executors: Vec<JoinHandle<()>>,
}

impl Server {
    /// Start serving on `bind` (e.g. `127.0.0.1:0` for an ephemeral
    /// port).  Spawns one leader executor per `pool.shards` (each builds
    /// its [`Leader`] on its own thread — the PJRT client is not
    /// `Send`), `cfg.server.workers` scheduler workers, and the
    /// socket-facing front `server.mode` selects (the thread-per-
    /// connection accept loop, or the nonblocking reactor).
    pub fn start(cfg: &Config, bind: &str) -> Result<Server> {
        Server::start_with_dump(cfg, bind, None)
    }

    /// [`Server::start`] plus a `--dump-metrics` artifact path: the
    /// server writes a flight-recorder snapshot there whenever the
    /// watchdog raises an alert and again at shutdown (atomically, via
    /// temp file + rename; last write wins).
    pub fn start_with_dump(
        cfg: &Config,
        bind: &str,
        dump_metrics: Option<std::path::PathBuf>,
    ) -> Result<Server> {
        let listener =
            TcpListener::bind(bind).map_err(|e| Error::io(bind.to_string(), e))?;
        let addr = listener.local_addr().map_err(|e| Error::io(bind.to_string(), e))?;
        listener.set_nonblocking(true).map_err(|e| Error::io(bind.to_string(), e))?;

        let mut inner = Shared::from_config(cfg);
        inner.dump_metrics = dump_metrics;
        let shared = Arc::new(inner);

        // Shard leader executors: each owns one fabric + runtime; all
        // draw request seqs from this shared counter so completions
        // merged across shards stay globally unique.  Every executor is
        // spawned before any readiness is awaited — leader warmup
        // (artifact compilation) runs once in parallel, not once per
        // shard in sequence.
        let seqs = Arc::new(AtomicU64::new(0));
        let mut exec_txs: Vec<mpsc::Sender<ExecRequest>> = Vec::new();
        let mut executors: Vec<JoinHandle<()>> = Vec::new();
        let mut readiness: Vec<mpsc::Receiver<Result<()>>> = Vec::new();
        for shard in 0..shared.shard_count() {
            let (exec_tx, exec_rx) = mpsc::channel::<ExecRequest>();
            let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
            let leader_cfg = cfg.clone();
            let shared_e = shared.clone();
            let seqs_e = seqs.clone();
            let executor = std::thread::Builder::new()
                .name(format!("cgra-leader-{shard}"))
                .spawn(move || {
                    let leader = match Leader::new_shard(&leader_cfg, seqs_e.clone()) {
                        Ok(l) => {
                            let _ = ready_tx.send(Ok(()));
                            l
                        }
                        Err(e) => {
                            let _ = ready_tx.send(Err(e));
                            return;
                        }
                    };
                    run_executor(shard, &leader_cfg, &seqs_e, leader, exec_rx, &shared_e);
                })
                .map_err(|e| Error::Runtime(format!("spawn executor {shard}: {e}")))?;
            executors.push(executor);
            readiness.push(ready_rx);
            exec_txs.push(exec_tx);
        }
        for (shard, ready_rx) in readiness.into_iter().enumerate() {
            let outcome = ready_rx.recv();
            match outcome {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    drop(exec_txs);
                    for h in executors {
                        let _ = h.join();
                    }
                    return Err(e);
                }
                Err(_) => {
                    drop(exec_txs);
                    for h in executors {
                        let _ = h.join();
                    }
                    return Err(Error::Runtime(format!(
                        "server executor {shard} died during startup"
                    )));
                }
            }
        }

        // Scheduler workers: drain admission queues into shard batches.
        let batch_max = cfg.server.batch_max.max(1) as usize;
        let mut workers = Vec::with_capacity(shared.workers);
        for i in 0..shared.workers {
            let shared_w = shared.clone();
            let txs = exec_txs.clone();
            let worker = std::thread::Builder::new()
                .name(format!("cgra-worker-{i}"))
                .spawn(move || run_worker(shared_w, txs, batch_max))
                .map_err(|e| Error::Runtime(format!("spawn worker {i}: {e}")))?;
            workers.push(worker);
        }
        // Connection threads reach the executors for DEFRAG through
        // these shared senders; `begin_shutdown` clears them, after
        // which the workers (the remaining senders) exiting lets each
        // executor's recv fail and the thread join.
        if let Ok(mut exec) = shared.exec.lock() {
            *exec = exec_txs.clone();
        }
        drop(exec_txs);

        // Socket-facing front.  Threaded: an accept loop spawning one
        // reader thread per connection.  Reactor: a single nonblocking
        // event loop owning every socket (coordinator/reactor.rs).
        let (accept, reactor) = match cfg.server.mode {
            ServerModeKind::Threaded => {
                let shared_a = shared.clone();
                let accept = std::thread::Builder::new()
                    .name("cgra-accept".into())
                    .spawn(move || {
                        let mut conns: Vec<JoinHandle<()>> = Vec::new();
                        while !shared_a.stop.load(Ordering::SeqCst) {
                            match listener.accept() {
                                Ok((stream, _)) => {
                                    let shared_c = shared_a.clone();
                                    let spawned = std::thread::Builder::new()
                                        .name("cgra-conn".into())
                                        .spawn(move || {
                                            let _ = handle_connection(stream, &shared_c);
                                        });
                                    match spawned {
                                        Ok(h) => conns.push(h),
                                        // thread exhaustion: refuse this
                                        // connection, keep accepting
                                        Err(e) => {
                                            log::warn!("connection thread spawn failed: {e}")
                                        }
                                    }
                                }
                                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                                    conns.retain(|h| !h.is_finished());
                                    std::thread::sleep(Duration::from_millis(5));
                                }
                                Err(_) => break,
                            }
                        }
                        for h in conns {
                            let _ = h.join();
                        }
                    })
                    .map_err(|e| Error::Runtime(format!("spawn accept loop: {e}")))?;
                (Some(accept), None)
            }
            ServerModeKind::Reactor => {
                let idle = cfg.server.idle_timeout_ms;
                let handle = super::reactor::spawn(
                    shared.clone(),
                    listener,
                    cfg.server.protocol,
                    (idle > 0).then(|| Duration::from_millis(idle)),
                )?;
                (None, Some(handle))
            }
        };

        Ok(Server { addr, shared, accept, reactor, workers, executors })
    }

    /// Graceful shutdown: stop accepting, drain admitted submissions,
    /// deliver their replies, join every thread.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    /// Block until the `SHUTDOWN` wire command requests shutdown, then
    /// drain and join.  (Ctrl-C/SIGTERM terminate the process without
    /// reaching this drain path — no signal handler is installed.)
    pub fn wait(mut self) {
        while !self.shared.stop.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(100));
        }
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.shared.begin_shutdown();
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        if let Some(r) = self.reactor.take() {
            // nudge the event loop out of its poll wait so it observes
            // the stop flag promptly, then let it drain and exit
            r.waker.wake();
            let _ = r.join.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // `drain` leaves the vec empty, so the Drop-after-shutdown
        // second call skips the dump instead of rewriting it
        let had_executors = !self.executors.is_empty();
        for e in self.executors.drain(..) {
            let _ = e.join();
        }
        if had_executors {
            // final-state artifact, after every executor exported its
            // last batch (alert-time snapshots were already written;
            // the shutdown record supersedes them with the full journal)
            self.shared.dump_flight("shutdown");
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        // idempotent: `shutdown`/`wait` already took the handles
        self.stop_and_join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_shared(depth: usize) -> Shared {
        test_shared_sharded(depth, 1)
    }

    fn test_shared_sharded(depth: usize, shards: u32) -> Shared {
        let mut cfg = crate::config::presets::paper_default();
        cfg.server.queue_depth = depth as u32;
        cfg.pool.shards = shards;
        Shared::from_config(&cfg)
    }

    fn line(shared: &Shared, input: &str) -> (String, bool) {
        let (tx, rx) = mpsc::channel();
        handle_line(shared, &tx, &rx, input)
    }

    #[test]
    fn parse_app_aliases_and_rejects() {
        assert_eq!(parse_app("resnet18"), Some(AppId::ResNet18));
        assert_eq!(parse_app("ResNet-18"), Some(AppId::ResNet18));
        assert_eq!(parse_app("RESNET"), Some(AppId::ResNet18));
        assert_eq!(parse_app("mobilenet"), Some(AppId::MobileNet));
        assert_eq!(parse_app("CAMERA"), Some(AppId::Camera));
        assert_eq!(parse_app("camera_pipeline"), Some(AppId::Camera));
        assert_eq!(parse_app("harris"), Some(AppId::Harris));
        assert_eq!(parse_app("pipeline"), Some(AppId::Pipeline));
        assert_eq!(parse_app("STREAMING_PIPELINE"), Some(AppId::Pipeline));
        assert_eq!(parse_app("nope"), None);
        assert_eq!(parse_app(""), None);
    }

    #[test]
    fn protocol_errors_without_leader() {
        let shared = test_shared(4);
        assert!(line(&shared, "SUBMIT 9 camera").0.starts_with("ERR bad tenant"));
        assert!(line(&shared, "SUBMIT x camera").0.starts_with("ERR bad tenant"));
        assert!(line(&shared, "SUBMIT 1 nope").0.starts_with("ERR bad app"));
        assert!(line(&shared, "SUBMIT 1 camera magic").0.starts_with("ERR bad class"));
        assert!(line(&shared, "SUBMIT 1 camera critical soon").0.starts_with("ERR bad deadline"));
        assert!(line(&shared, "SUBMIT 1 camera critical -5").0.starts_with("ERR bad deadline"));
        assert!(line(&shared, "FROB").0.starts_with("ERR unknown command"));
        assert!(line(&shared, "").0.starts_with("ERR empty"));
        assert!(line(&shared, "STATS 12").0.starts_with("ERR bad tenant"));
        let (bye, close) = line(&shared, "QUIT");
        assert_eq!(bye, "BYE");
        assert!(close);
        // none of the above touched the admission counters
        assert_eq!(shared.counters.totals(), crate::metrics::TenantSnapshot::default());
    }

    #[test]
    fn parse_submit_validates_like_the_text_front() {
        // the binary front hands the tenant in from the frame header
        assert!(parse_submit(None, "camera".split_whitespace()).is_err());
        assert!(parse_submit(Some(9), "camera".split_whitespace()).is_err());
        assert!(parse_submit(Some(1), "nope".split_whitespace()).is_err());
        assert!(parse_submit(Some(1), "camera magic".split_whitespace()).is_err());
        assert!(parse_submit(Some(1), "camera critical -5".split_whitespace()).is_err());
        let p = parse_submit(Some(2), "camera critical 5".split_whitespace()).unwrap();
        assert_eq!(p.tenant, TenantId(2));
        assert_eq!(p.app, AppId::Camera);
        assert_eq!(p.class, Some(QosClass::Critical));
        assert_eq!(p.deadline_ms, Some(5.0));
        let bare = parse_submit(Some(0), "harris".split_whitespace()).unwrap();
        assert_eq!(bare.class, None);
        assert_eq!(bare.deadline_ms, None);
    }

    #[test]
    fn busy_backpressure_reply_when_queue_full() {
        let shared = test_shared(1);
        // fill tenant 2's queue directly (no worker is draining)
        let (tx, _rx) = mpsc::channel();
        shared
            .queues
            .try_push(
                TenantId(2),
                SubmitJob {
                    app: AppId::Camera,
                    class: None,
                    deadline_ms: None,
                    reply: ReplySink::Channel(tx),
                },
            )
            .unwrap_or_else(|_| panic!("first push fits"));
        let (reply, close) = line(&shared, "SUBMIT 2 camera");
        assert_eq!(reply, "BUSY tenant=2 queue_depth=1");
        assert!(!close);
        assert_eq!(shared.counters.tenant(2).rejected, 1);
        // other tenants still admitted… but nothing drains them in this
        // test, so only check the error-free tenants' rejection count
        assert_eq!(shared.counters.tenant(0).rejected, 0);
    }

    #[test]
    fn stats_renders_counters_and_pending() {
        let shared = test_shared(8);
        shared.counters.record_queued(0);
        shared.counters.record_served(0);
        shared.counters.record_queued(3);
        shared.counters.record_rejected(3);
        let (stats, close) = line(&shared, "STATS");
        assert!(!close);
        assert!(stats.contains("served=1"), "{stats}");
        assert!(stats.contains("queued=2"), "{stats}");
        assert!(stats.contains("rejected=1"), "{stats}");
        assert!(stats.contains("pending=0"), "{stats}");
        assert!(stats.contains("workers=2"), "{stats}");
        assert!(stats.contains("frag_glb=0.000"), "{stats}");
        assert!(stats.contains("frag_arr=0.000"), "{stats}");
        assert!(stats.contains("migrations=0"), "{stats}");
        let (t3, _) = line(&shared, "STATS 3");
        assert_eq!(t3, "STATS tenant=3 served=0 queued=1 rejected=1");
    }

    #[test]
    fn stats_reflect_recorded_fabric_snapshot() {
        let shared = test_shared(4);
        shared.record_fabric(0, (0.5, 0.25), 7);
        let (stats, _) = line(&shared, "STATS");
        assert!(stats.contains("frag_glb=0.500"), "{stats}");
        assert!(stats.contains("frag_arr=0.250"), "{stats}");
        assert!(stats.contains("migrations=7"), "{stats}");
        assert!(stats.contains("shards=1"), "{stats}");
        // leader rebuild resets the leader-side counter to 0 then counts
        // 2 fresh migrations: the published total must keep growing
        shared.record_fabric(0, (0.0, 0.0), 2);
        let (stats, _) = line(&shared, "STATS");
        assert!(stats.contains("migrations=9"), "{stats}");
        // steady growth on the same leader adds only the delta
        shared.record_fabric(0, (0.0, 0.0), 5);
        let (stats, _) = line(&shared, "STATS");
        assert!(stats.contains("migrations=12"), "{stats}");
    }

    #[test]
    fn sharded_stats_aggregate_and_per_shard_lines() {
        let shared = test_shared_sharded(4, 2);
        shared.record_fabric(0, (0.5, 0.25), 3);
        shared.record_fabric(1, (0.1, 0.05), 4);
        // the aggregate line averages gauges and sums migrations
        let (stats, _) = line(&shared, "STATS");
        assert!(stats.contains("frag_glb=0.300"), "{stats}");
        assert!(stats.contains("frag_arr=0.150"), "{stats}");
        assert!(stats.contains("migrations=7"), "{stats}");
        assert!(stats.contains("shards=2"), "{stats}");
        // STATS SHARDS: a header naming the line count, then one line
        // per shard
        let (reply, close) = line(&shared, "STATS SHARDS");
        assert!(!close);
        let lines: Vec<&str> = reply.lines().collect();
        assert_eq!(lines.len(), 3, "{reply}");
        assert_eq!(lines[0], "STATS shards=2");
        assert!(lines[1].contains("shard=0"), "{reply}");
        assert!(lines[1].contains("frag_glb=0.500"), "{reply}");
        assert!(lines[1].contains("migrations=3"), "{reply}");
        assert!(lines[2].contains("shard=1"), "{reply}");
        assert!(lines[2].contains("migrations=4"), "{reply}");
        // out-of-range record_fabric is ignored, not a panic
        shared.record_fabric(9, (1.0, 1.0), 100);
        let (stats, _) = line(&shared, "STATS");
        assert!(stats.contains("migrations=7"), "{stats}");
    }

    #[test]
    fn stats_names_the_placement_policy() {
        let shared = test_shared(4);
        let (stats, _) = line(&shared, "STATS");
        assert!(stats.contains("placement=least-loaded"), "{stats}");
        let mut cfg = crate::config::presets::paper_default();
        cfg.pool.placement = crate::config::PlacementPolicyKind::Sticky;
        let sticky = Shared::from_config(&cfg);
        let (stats, _) = line(&sticky, "STATS");
        assert!(stats.contains("placement=sticky"), "{stats}");
    }

    #[test]
    fn stats_energy_renders_header_and_per_shard_lines() {
        let shared = test_shared_sharded(4, 2);
        shared.record_energy(0, 1.5, 2.25, 3);
        shared.record_energy(1, 0.5, 0.75, 0);
        let (reply, close) = line(&shared, "STATS ENERGY");
        assert!(!close);
        let lines: Vec<&str> = reply.lines().collect();
        assert_eq!(lines.len(), 3, "{reply}");
        assert!(lines[0].starts_with("STATS shards=2"), "{reply}");
        assert!(lines[0].contains("energy_j=2.000000"), "{reply}");
        assert!(lines[0].contains("cap_w=0.000"), "{reply}");
        assert!(lines[0].contains("placement=least-loaded"), "{reply}");
        assert!(lines[1].contains("shard=0"), "{reply}");
        assert!(lines[1].contains("energy_j=1.500000"), "{reply}");
        assert!(lines[1].contains("power_w=2.250"), "{reply}");
        assert!(lines[1].contains("throttled=3"), "{reply}");
        assert!(lines[2].contains("shard=1"), "{reply}");
        // out-of-range shard writes are ignored
        shared.record_energy(9, 100.0, 100.0, 9);
        let (reply, _) = line(&shared, "STATS ENERGY");
        assert!(reply.contains("energy_j=2.000000"), "{reply}");
    }

    #[test]
    fn stats_qos_renders_header_and_merged_class_lines() {
        use crate::qos::{QosStats, SloRecord, SloTracker};

        let shared = test_shared_sharded(4, 2);
        // empty: header + 3 zeroed class lines
        let (reply, close) = line(&shared, "STATS QOS");
        assert!(!close);
        let lines: Vec<&str> = reply.lines().collect();
        assert_eq!(lines.len(), 4, "{reply}");
        assert_eq!(lines[0], "STATS classes=3 preemptions=0 evicted=0 resumed=0");
        assert!(lines[1].contains("class=best-effort completed=0"), "{reply}");
        // record two shards and check the merge: counts sum, p99 is max
        let mut a = SloTracker::new();
        a.record(SloRecord {
            class: crate::config::QosClass::Critical,
            arrival: 0,
            completion: 500_000, // 1 ms at 500 MHz
            deadline: Some(400_000),
        });
        shared.record_qos(0, a.report(QosStats { preemptions: 2, ..Default::default() }));
        let mut b = SloTracker::new();
        b.record(SloRecord {
            class: crate::config::QosClass::Critical,
            arrival: 0,
            completion: 1_500_000, // 3 ms
            deadline: None,
        });
        shared.record_qos(1, b.report(QosStats::default()));
        let (reply, _) = line(&shared, "STATS QOS");
        let lines: Vec<&str> = reply.lines().collect();
        assert!(lines[0].contains("preemptions=2"), "{reply}");
        let crit = lines.iter().find(|l| l.contains("class=critical")).unwrap();
        assert!(crit.contains("completed=2"), "{reply}");
        assert!(crit.contains("deadlined=1"), "{reply}");
        assert!(crit.contains("missed=1"), "{reply}");
        assert!(crit.contains("miss_rate=1.000"), "{reply}");
        assert!(crit.contains("p99_ms=3.000"), "worst shard wins: {reply}");
        // out-of-range shard writes are ignored
        shared.record_qos(9, SloTracker::new().report(QosStats::default()));
    }

    #[test]
    fn stats_noc_renders_off_then_merged_report() {
        let shared = test_shared_sharded(4, 2);
        // no shard has reported: the subsystem reads as off
        let (reply, close) = line(&shared, "STATS NOC");
        assert!(!close);
        assert_eq!(reply, "STATS noc=off");
        let hot = NocReport {
            streams_placed: 2,
            contended_launches: 1,
            contention_cycles: 100,
            stream_in_cycles: 43_200,
            affinity_hits: 1,
            mean_slowdown: 1.5,
            peak_slowdown: 2.0,
            corridors: 8,
            capacity: 20,
        };
        let cold = NocReport {
            streams_placed: 2,
            contended_launches: 0,
            contention_cycles: 0,
            stream_in_cycles: 0,
            affinity_hits: 0,
            mean_slowdown: 1.0,
            peak_slowdown: 1.0,
            corridors: 8,
            capacity: 20,
        };
        shared.record_noc(0, Some(hot));
        shared.record_noc(1, Some(cold));
        let (reply, _) = line(&shared, "STATS NOC");
        assert!(reply.contains("noc=on"), "{reply}");
        assert!(reply.contains("streams=4"), "{reply}");
        assert!(reply.contains("contended=1"), "{reply}");
        assert!(reply.contains("stream_in_cycles=43200"), "{reply}");
        // weighted mean: (1.5·2 + 1.0·2) / 4
        assert!(reply.contains("mean_slowdown=1.250"), "{reply}");
        assert!(reply.contains("peak_slowdown=2.000"), "{reply}");
        assert!(reply.contains("corridors=8 capacity=20"), "{reply}");
        // out-of-range shard writes are ignored
        shared.record_noc(9, Some(hot));
        let (reply, _) = line(&shared, "STATS NOC");
        assert!(reply.contains("streams=4"), "{reply}");
    }

    #[test]
    fn batch_cap_shrinks_only_over_the_power_cap() {
        // uncapped: never shrinks, even with high recorded power
        let uncapped = test_shared(4);
        uncapped.record_energy(0, 1.0, 99.0, 0);
        assert_eq!(uncapped.batch_cap(8), 8);
        assert_eq!(uncapped.throttle_shrinks.load(Ordering::Relaxed), 0);
        // capped: shrink to 1 while any shard reads over the cap
        let mut cfg = crate::config::presets::paper_default();
        cfg.energy.enabled = true;
        cfg.energy.power_cap_watts = 2.0;
        let capped = Shared::from_config(&cfg);
        assert_eq!(capped.batch_cap(8), 8, "under cap");
        capped.record_energy(0, 1.0, 2.5, 1);
        assert_eq!(capped.batch_cap(8), 1, "over cap");
        assert_eq!(capped.throttle_shrinks.load(Ordering::Relaxed), 1);
        capped.record_energy(0, 1.0, 1.5, 1);
        assert_eq!(capped.batch_cap(8), 8, "cap pressure cleared");
        // cap configured but accounting disabled: stays inert
        let mut off = crate::config::presets::paper_default();
        off.energy.power_cap_watts = 2.0;
        let off = Shared::from_config(&off);
        off.record_energy(0, 1.0, 9.0, 0);
        assert_eq!(off.batch_cap(8), 8);
    }

    #[test]
    fn pick_shard_policies_are_deterministic() {
        // least-loaded: lowest outstanding, then lowest id
        let shared = test_shared_sharded(4, 3);
        assert_eq!(shared.pick_shard(0), 0);
        shared.shards[0].outstanding.fetch_add(1, Ordering::Relaxed);
        assert_eq!(shared.pick_shard(0), 1);
        shared.shards[1].outstanding.fetch_add(2, Ordering::Relaxed);
        assert_eq!(shared.pick_shard(0), 2);
        // sticky: first placement least-loaded, then pinned
        let mut cfg = crate::config::presets::paper_default();
        cfg.pool.shards = 2;
        cfg.pool.placement = crate::config::PlacementPolicyKind::Sticky;
        let sticky = Shared::from_config(&cfg);
        sticky.shards[0].outstanding.fetch_add(5, Ordering::Relaxed);
        assert_eq!(sticky.pick_shard(3), 1);
        sticky.shards[1].outstanding.fetch_add(50, Ordering::Relaxed);
        assert_eq!(sticky.pick_shard(3), 1, "tenant stays pinned");
        assert_eq!(sticky.pick_shard(2), 0, "new tenant gets least-loaded");
        // single shard short-circuits
        let one = test_shared(4);
        assert_eq!(one.pick_shard(9), 0);
    }

    #[test]
    fn defrag_without_executor_is_unavailable() {
        let shared = test_shared(4);
        let (reply, close) = line(&shared, "DEFRAG");
        assert_eq!(reply, "ERR coordinator unavailable");
        assert!(!close);
    }

    #[test]
    fn shutdown_command_begins_graceful_stop() {
        let shared = test_shared(4);
        let (reply, close) = line(&shared, "SHUTDOWN");
        assert_eq!(reply, "BYE shutting down");
        assert!(close);
        assert!(shared.stop.load(Ordering::SeqCst));
        assert!(shared.queues.is_closed());
        // post-shutdown SUBMITs are refused with BUSY
        let (reply, _) = line(&shared, "SUBMIT 0 harris");
        assert!(reply.starts_with("BUSY"), "{reply}");
    }

    fn test_shared_obs() -> Shared {
        let mut cfg = crate::config::presets::paper_default();
        cfg.obs.enabled = true;
        cfg.obs.provenance = true;
        Shared::from_config(&cfg)
    }

    #[test]
    fn obs_verbs_error_while_obs_disabled() {
        let shared = test_shared(4);
        assert_eq!(line(&shared, "EXPLAIN 0").0, "ERR obs disabled");
        assert_eq!(line(&shared, "DUMP").0, "ERR obs disabled");
        assert_eq!(line(&shared, "WATCH").0, "ERR obs disabled");
    }

    #[test]
    fn explain_renders_the_request_decision_chain() {
        let shared = test_shared_obs();
        let obs = shared.obs.as_ref().unwrap();
        obs.stage(10, 3, 0, JournalKind::Completed { tenant: 1 });
        if let Some(ring) = &obs.provenance {
            let d = crate::obs::Decision::new(
                8,
                3,
                crate::obs::DecisionKind::NoFit { task: "harris".into(), alts: vec![] },
            );
            ring.lock().unwrap().push(d);
        }
        let (reply, close) = line(&shared, "EXPLAIN 3");
        assert!(!close);
        let lines: Vec<&str> = reply.lines().collect();
        assert_eq!(lines[0], "EXPLAIN req=3 lines=2", "{reply}");
        assert!(lines[1].contains("req=3"), "{reply}");
        assert!(lines[1].contains("completed"), "{reply}");
        assert!(lines[2].contains("nofit"), "{reply}");
        // an unknown request is an empty chain, not an error
        assert_eq!(line(&shared, "EXPLAIN 99").0, "EXPLAIN req=99 lines=0");
        assert!(line(&shared, "EXPLAIN x").0.starts_with("ERR bad req"));
        assert!(line(&shared, "EXPLAIN").0.starts_with("ERR bad req"));
    }

    #[test]
    fn dump_reply_is_a_valid_flight_record() {
        let shared = test_shared_obs();
        shared.obs.as_ref().unwrap().stage(5, 1, 0, JournalKind::Completed { tenant: 2 });
        let (reply, close) = line(&shared, "DUMP");
        assert!(!close);
        let mut it = reply.lines();
        assert_eq!(it.next().unwrap(), "DUMP lines=1");
        let doc = crate::util::json::Json::parse(it.next().unwrap()).unwrap();
        let summary = crate::obs::validate_flight_record(&doc).unwrap();
        assert_eq!(summary.reason, "verb:DUMP");
        assert_eq!(summary.journal_events, 1);
    }

    #[test]
    fn metrics_header_counts_journal_drops() {
        let mut cfg = crate::config::presets::paper_default();
        cfg.obs.enabled = true;
        cfg.obs.journal_cap = 2;
        let shared = Shared::from_config(&cfg);
        let obs = shared.obs.as_ref().unwrap();
        for i in 0..5u64 {
            obs.stage(i, i, 0, JournalKind::Completed { tenant: 0 });
        }
        let (reply, _) = line(&shared, "METRICS");
        let header = reply.lines().next().unwrap().to_string();
        assert!(header.ends_with("dropped=3"), "{header}");
        assert!(reply.contains("cgra_obs_journal_dropped_total 3"), "{reply}");
        assert!(reply.contains("cgra_obs_watch_dropped_total 0"), "{reply}");
        // the header's count still names the exposition length exactly
        let n: usize = header
            .split_whitespace()
            .find_map(|t| t.strip_prefix("lines="))
            .unwrap()
            .parse()
            .unwrap();
        assert_eq!(reply.lines().count(), 1 + n, "{reply}");
        // obs off: the field is present and zero
        let off = test_shared(4);
        let (reply, _) = line(&off, "METRICS");
        assert!(reply.lines().next().unwrap().ends_with("dropped=0"), "{reply}");
    }

    #[test]
    fn staged_events_mirror_to_watch_subscribers() {
        let shared = test_shared_obs();
        let obs = shared.obs.as_ref().unwrap();
        // no subscriber: publishing is skipped, the journal still grows
        obs.stage(1, 7, 0, JournalKind::Completed { tenant: 0 });
        assert_eq!(obs.watch.published_total(), 0);
        let token = obs.watch.subscribe();
        obs.stage(2, 8, 0, JournalKind::Completed { tenant: 1 });
        let got = obs.watch.drain(token, 16);
        assert_eq!(got.len(), 1, "{got:?}");
        assert!(got[0].contains("req=8"), "{got:?}");
        assert_eq!(obs.journal.lock().unwrap().len(), 2);
        assert_eq!(obs.watch.unsubscribe(token), Some((1, 0)));
    }

    #[test]
    fn raised_alerts_reach_journal_registry_and_stream() {
        let shared = test_shared_obs();
        let obs = shared.obs.as_ref().unwrap();
        let token = obs.watch.subscribe();
        let alert = Alert {
            at: 40,
            shard: 1,
            kind: crate::obs::AlertKind::UtilAnomaly { value: 0.9, mean: 0.2, sigma: 4.0 },
        };
        obs.raise_alert(&alert);
        let streamed = obs.watch.drain(token, 8);
        assert_eq!(streamed.len(), 1, "{streamed:?}");
        assert!(streamed[0].contains("alert"), "{streamed:?}");
        assert!(streamed[0].contains("util-anomaly"), "{streamed:?}");
        obs.watch.unsubscribe(token);
        let (reply, _) = line(&shared, "METRICS");
        assert!(
            reply.contains("cgra_obs_alerts_total{kind=\"util-anomaly\"} 1"),
            "{reply}"
        );
    }

    #[test]
    fn dump_flight_writes_an_atomic_artifact() {
        let dir = std::env::temp_dir().join(format!(
            "cgra-dump-test-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("flight.json");
        let mut shared = test_shared_obs();
        shared.dump_metrics = Some(path.clone());
        shared.obs.as_ref().unwrap().stage(3, 0, 0, JournalKind::Completed { tenant: 0 });
        shared.dump_flight("alert");
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = crate::util::json::Json::parse(&text).unwrap();
        let summary = crate::obs::validate_flight_record(&doc).unwrap();
        assert_eq!(summary.reason, "alert");
        assert_eq!(summary.journal_events, 1);
        // obs disabled: degrades to the plain exposition
        let mut plain = test_shared(4);
        plain.dump_metrics = Some(path.clone());
        plain.dump_flight("shutdown");
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("cgra_serve_served_total"), "{text}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// End-to-end over a real socket on the stub runtime backend (the
    /// synthetic manifest needs no artifacts on disk).
    #[cfg(not(feature = "xla"))]
    #[test]
    fn end_to_end_over_tcp() {
        use std::io::{BufRead, BufReader, Write};

        let mut cfg = crate::config::presets::paper_default();
        cfg.artifacts_dir = crate::runtime::SYNTHETIC_DIR.into();
        let server = Server::start(&cfg, "127.0.0.1:0").unwrap();

        let stream = std::net::TcpStream::connect(server.addr).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        let send = |w: &mut std::net::TcpStream, r: &mut BufReader<std::net::TcpStream>, line: &str| {
            w.write_all(format!("{line}\n").as_bytes()).unwrap();
            let mut reply = String::new();
            r.read_line(&mut reply).unwrap();
            reply.trim_end().to_string()
        };

        let reply = send(&mut writer, &mut reader, "SUBMIT 3 harris");
        assert!(reply.starts_with("OK seq=0"), "{reply}");
        assert!(reply.contains("ntat="), "{reply}");

        let stats = send(&mut writer, &mut reader, "STATS");
        assert!(stats.contains("served=1"), "{stats}");
        assert!(stats.contains("frag_glb="), "{stats}");
        let t3 = send(&mut writer, &mut reader, "STATS 3");
        assert!(t3.contains("tenant=3 served=1 queued=1 rejected=0"), "{t3}");

        // a classed SUBMIT with a generous deadline is served and the
        // QoS surface reflects it (header + 3 class lines)
        let reply = send(&mut writer, &mut reader, "SUBMIT 3 harris critical 60000");
        assert!(reply.starts_with("OK seq=1"), "{reply}");
        writer.write_all(b"STATS QOS\n").unwrap();
        let mut qos_lines = Vec::new();
        for _ in 0..4 {
            let mut l = String::new();
            reader.read_line(&mut l).unwrap();
            qos_lines.push(l.trim_end().to_string());
        }
        assert!(qos_lines[0].starts_with("STATS classes=3"), "{qos_lines:?}");
        let crit = qos_lines.iter().find(|l| l.contains("class=critical")).unwrap();
        assert!(crit.contains("completed=1"), "{qos_lines:?}");
        assert!(crit.contains("missed=0"), "{qos_lines:?}");

        // the pipeline app is servable over the wire (the synthetic
        // manifest carries its demosaic artifacts); with `[noc]` off
        // the contention surface stays dark
        let reply = send(&mut writer, &mut reader, "SUBMIT 0 pipeline");
        assert!(reply.starts_with("OK seq=2"), "{reply}");
        let noc = send(&mut writer, &mut reader, "STATS NOC");
        assert_eq!(noc, "STATS noc=off");

        // control-plane defrag: fabric is drained between batches, so
        // this reports a clean no-op over the wire
        let defrag = send(&mut writer, &mut reader, "DEFRAG");
        assert!(defrag.starts_with("DEFRAG migrated=0"), "{defrag}");

        let bye = send(&mut writer, &mut reader, "QUIT");
        assert_eq!(bye, "BYE");

        server.shutdown();
    }
}
