//! The live (non-simulated) multi-tenant coordinator.
//!
//! While [`crate::sim`] reproduces the paper's evaluation in virtual
//! time, this module is the deployable serving path: tenants submit
//! application requests over TCP, a sharded worker pool batches them
//! (per-tenant bounded admission queues → N scheduler workers →
//! `pool.shards` per-shard leader executors sharing one request-seq
//! counter), each shard's scheduler places them on the slice-level
//! abstraction exactly as in the simulation, and every launched task
//! *actually executes* its artifact through the [`crate::runtime`]
//! backend — the CGRA's functional behaviour with the paper's timing
//! model alongside.  Python never runs here.
//!
//! See `server` for the wire protocol and the concurrency architecture,
//! and `DESIGN.md` §Coordinator for the module map.

mod binding;
pub mod frame;
mod leader;
mod reactor;
mod router;
pub mod server;

pub use binding::TaskBinding;
pub use leader::{Leader, ServeOutcome, ServeStats, Submission};
pub use router::{AdmissionQueues, Router, RouterStats, TenantId};
pub use server::{parse_app, Server, TENANTS};
