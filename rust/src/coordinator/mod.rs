//! The live (non-simulated) multi-tenant coordinator.
//!
//! While [`crate::sim`] reproduces the paper's evaluation in virtual
//! time, this module is the deployable serving path: tenants submit
//! application requests, the scheduler places them on the slice-level
//! abstraction exactly as in the simulation, and every launched task
//! *actually executes* its AOT artifact through the PJRT runtime —
//! the CGRA's functional behaviour with the paper's timing model
//! alongside.  Python never runs here.

mod binding;
mod leader;
mod router;
pub mod server;

pub use binding::TaskBinding;
pub use leader::{Leader, ServeOutcome, ServeStats};
pub use router::{Router, RouterStats, TenantId};
pub use server::{Server, parse_app};
